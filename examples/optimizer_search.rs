//! Table IV reproduction as an example: run the §VI.A exhaustive search
//! for each benchmark net on the simulated GPU and on the host CPU,
//! print the optimal primitive per layer and the chosen input size.
//!
//!     cargo run --release --example optimizer_search [--scale tiny|small|paper]

use znni::device::Device;
use znni::net::zoo::{benchmark_nets, NetScale};
use znni::net::PoolingMode;
use znni::optimizer::{plan_table, search, CostModel, SearchSpace};
use znni::util::bench::Table;
use znni::util::human_bytes;
use znni::util::pool::TaskPool;

fn main() {
    let scale = NetScale::from_env();
    let pool = TaskPool::global();
    eprintln!("calibrating cost model...");
    let cm = CostModel::calibrate(pool, 10);
    let gpu = Device::titan_x();
    let host = Device::host();

    for (dev_name, mk_space) in [
        ("sim-titan-x (GPU-only)", true),
        ("host (CPU-only)", false),
    ] {
        println!("\n== optimal layer primitives on {dev_name}, scale {scale:?} ==");
        let mut table = Table::new(&["", "n337", "n537", "n726", "n926"]);
        let mut columns = Vec::new();
        for net in benchmark_nets(scale) {
            let modes = vec![PoolingMode::Mpf; net.pool_count()];
            let min = net.min_extent(&modes).unwrap();
            let mut space = if mk_space {
                SearchSpace::gpu_only(gpu.clone(), min + 32)
            } else {
                SearchSpace::cpu_only(host.clone(), min + 32)
            };
            space.max_candidates = 8;
            let plan = search(&net, &space, &cm);
            columns.push(plan.map(|p| plan_table(&p)));
        }
        let max_rows = columns.iter().flatten().map(|c| c.len()).max().unwrap_or(0);
        for r in 0..max_rows {
            let mut row = vec![String::new()];
            for c in &columns {
                match c {
                    Some(rows) if r < rows.len() => {
                        if row[0].is_empty() {
                            row[0] = rows[r].0.clone();
                        }
                        row.push(rows[r].1.clone());
                    }
                    Some(_) => row.push(String::new()),
                    None => row.push("infeasible".into()),
                }
            }
            table.row(row);
        }
        table.print();
        let (g, h) = (human_bytes(gpu.ram_bytes), human_bytes(host.ram_bytes));
        println!("(memory budget: GPU {g} / host {h})");
    }
}
