//! §VII.C demo: CPU–GPU pipelined inference over a stream of patches,
//! comparing pipelined wall-clock against sequential execution.
//!
//!     cargo run --release --example pipeline_demo

use std::sync::Arc;

use znni::conv::{Activation, Weights};
use znni::layers::{ConvLayer, LayerPrimitive, MpfLayer, Placement};
use znni::memory::model::ConvAlgo;
use znni::pipeline::Pipeline;
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::TaskPool;

fn stack() -> Vec<Box<dyn LayerPrimitive>> {
    vec![
        Box::new(ConvLayer::new(
            Arc::new(Weights::random(4, 1, [3, 3, 3], 1)),
            ConvAlgo::FftDataParallel,
            Activation::Relu,
        )),
        Box::new(MpfLayer { window: [2, 2, 2], placement: Placement::Cpu }),
        Box::new(ConvLayer::new(
            Arc::new(Weights::random(4, 4, [3, 3, 3], 2)),
            ConvAlgo::GpuFft,
            Activation::Relu,
        )),
        Box::new(ConvLayer::new(
            Arc::new(Weights::random(2, 4, [3, 3, 3], 3)),
            ConvAlgo::GpuDensePrecomp,
            Activation::Relu,
        )),
    ]
}

fn main() {
    let pool = TaskPool::global();
    let theta = 2; // conv+MPF on the CPU side, convs on the GPU side
    let n = 19;
    let patches = 6;
    println!("pipeline: head = first {theta} layers (CPU), tail = rest (sim-GPU); {patches} patches of {n}³");

    let mk_inputs = || -> Vec<Tensor5> {
        (0..patches).map(|i| Tensor5::random(Shape5::new(1, 1, n, n, n), i as u64)).collect()
    };

    let pipe = Pipeline::split(stack(), theta);
    let t0 = std::time::Instant::now();
    let outs = pipe.run_stream(mk_inputs(), pool);
    let streamed = t0.elapsed().as_secs_f64();

    let t0 = std::time::Instant::now();
    let seq = pipe.run_sequential(mk_inputs(), pool);
    let sequential = t0.elapsed().as_secs_f64();

    let diff: f32 = outs
        .iter()
        .zip(&seq)
        .map(|(a, b)| a.max_abs_diff(b))
        .fold(0.0, f32::max);
    println!("pipelined:  {streamed:.3}s  ({:.3}s/patch)", streamed / patches as f64);
    println!("sequential: {sequential:.3}s  ({:.3}s/patch)", sequential / patches as f64);
    println!("outputs identical: max |Δ| = {diff:.2e}");
    println!("note: this testbed is single-core, so the overlap is structural; on a real CPU+GPU pair the pipelined walltime approaches max(head, tail).");
}
