//! Measured autotuning demo: calibrate every primitive on this
//! machine, persist the profile, and show what the measurement changes.
//!
//! Runs `CostModel::calibrate_full_report` — each conv/pool primitive
//! micro-benchmarked through a warm `ExecCtx` at a ladder of extents,
//! plus the real per-batch dispatch overhead — prints the evidence,
//! saves `znni-profile.json`, round-trips it, and compares the serving
//! config searched with measured numbers against the static defaults.
//!
//!     cargo run --release --example calibrate [profile_path]

use znni::device::Device;
use znni::memory::model::ConvAlgo;
use znni::net::zoo::tiny_net;
use znni::optimizer::{search_serving, CostModel, SearchSpace};
use znni::server::ServingLoad;
use znni::util::bench::{Scale, Table};
use znni::util::human_throughput;
use znni::util::pool::TaskPool;

fn main() -> anyhow::Result<()> {
    let path =
        std::env::args().nth(1).unwrap_or_else(|| "znni-profile.json".to_string());
    let pool = TaskPool::global();
    let ladder: &[usize] = match Scale::from_env() {
        Scale::Tiny => &[6, 8],
        Scale::Small => &[8, 12, 16],
        Scale::Paper => &[16, 24, 32, 48],
    };
    println!(
        "calibrating {} primitives on {} workers, ladder {:?}...",
        ConvAlgo::ALL.len(),
        pool.workers(),
        ladder
    );
    let (cm, report) = CostModel::calibrate_full_report(pool, ladder);

    let mut t = Table::new(&["primitive", "extent", "work", "secs", "rate"]);
    for (algo, samples) in &report.conv {
        for s in samples {
            t.row(vec![
                algo.name().to_string(),
                format!("{}^3", s.extent),
                format!("{:.3e}", s.work),
                format!("{:.6}", s.secs),
                format!("{:.3e}/s", s.rate()),
            ]);
        }
    }
    for s in &report.pool {
        t.row(vec![
            "MPF (voxels)".to_string(),
            format!("{}^3", s.extent),
            format!("{:.3e}", s.work),
            format!("{:.6}", s.secs),
            format!("{:.3e}/s", s.rate()),
        ]);
    }
    t.print();
    println!(
        "dispatch overhead: {:.1} us/batch (replaces the {:.0} us default)",
        report.dispatch_overhead_secs * 1e6,
        znni::optimizer::cost::DEFAULT_DISPATCH_OVERHEAD_SECS * 1e6,
    );

    // Persist + round-trip.
    cm.save_profile(&path)?;
    let loaded = CostModel::load_profile(&path)?;
    assert_eq!(loaded.dispatch_overhead_secs, cm.dispatch_overhead_secs);
    println!("profile saved to {path} (round-trip verified)");

    // What the measurement changes: serving config under measured vs
    // default cost models.
    let net = tiny_net(4);
    let host = Device::host();
    let load = ServingLoad { clients: 8, volume_extent: 32 };
    let space = SearchSpace::cpu_only(host, 23);
    let defaults = CostModel::default_rates(pool.workers());
    for (label, model) in [("default", &defaults), ("measured", &loaded)] {
        if let Some((plan, cfg)) = search_serving(&net, &space, model, &load) {
            println!(
                "{label:>8}: input {}^3, est {} -> shards={} queue_depth={} \
                 max_batch={} batch_wait={:?}",
                plan.input.x,
                human_throughput(plan.est_throughput()),
                cfg.shards,
                cfg.queue_depth,
                cfg.max_batch_requests,
                cfg.max_batch_wait,
            );
        }
    }
    Ok(())
}
