//! Serving demo: the coordinator takes whole-volume requests, splits
//! them into patches (overlap-save), runs the optimized plan, and
//! reassembles — reporting serving metrics.
//!
//!     cargo run --release --example serve [volume_extent] [num_requests]

use znni::coordinator::{Coordinator, InferenceRequest};
use znni::device::Device;
use znni::optimizer::{compile, make_weights, search, CostModel, SearchSpace};
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::TaskPool;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(32);
    let requests: usize = std::env::args().nth(2).and_then(|a| a.parse().ok()).unwrap_or(3);
    let pool = TaskPool::global();
    let net = znni::net::zoo::tiny_net(4);
    let cm = CostModel::calibrate(pool, 8);
    let space = SearchSpace::cpu_only(Device::host(), n.min(23));
    let plan = search(&net, &space, &cm).expect("feasible plan");
    let weights = make_weights(&net, 11);
    let cp = compile(&net, &plan, &weights)?;
    let coord = Coordinator::new(net, cp)?;
    println!(
        "serving {requests} request(s) of {n}³ with patch {}³ (cover {:?})",
        coord.net.field_of_view()[0].max(plan.input.x),
        coord.cover()
    );
    let reqs = (0..requests)
        .map(|i| InferenceRequest {
            id: i as u64,
            volume: Tensor5::random(Shape5::new(1, 1, n, n, n), i as u64),
        })
        .collect();
    let (resps, metrics) = coord.serve(reqs, pool)?;
    for r in &resps {
        println!("  request {} -> {} ({} voxels)", r.id, r.output.shape(), r.voxels);
    }
    println!("{}", metrics.report());
    Ok(())
}
