//! Serving demo: the coordinator takes whole-volume requests, splits
//! them into patches (overlap-save), runs the optimized plan, and
//! reassembles — reporting serving metrics, including the steady-state
//! memory discipline of the arena-backed execution contexts: after a
//! warmup round the patch loop performs zero transient allocations, and
//! the per-worker arena high-water mark stays within the plan's
//! Table II workspace requirement.
//!
//!     cargo run --release --example serve [volume_extent] [num_requests]

use znni::coordinator::{Coordinator, InferenceRequest};
use znni::device::Device;
use znni::optimizer::{compile, make_weights, search, CostModel, SearchSpace};
use znni::tensor::{Shape5, Tensor5};
use znni::util::human_bytes;
use znni::util::pool::TaskPool;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(32);
    let requests: usize = std::env::args().nth(2).and_then(|a| a.parse().ok()).unwrap_or(3);
    let pool = TaskPool::global();
    let net = znni::net::zoo::tiny_net(4);
    let cm = CostModel::calibrate(pool, 8);
    let space = SearchSpace::cpu_only(Device::host(), n.min(23));
    let plan = search(&net, &space, &cm).expect("feasible plan");
    let weights = make_weights(&net, 11);
    let cp = compile(&net, &plan, &weights)?;
    let coord = Coordinator::new(net, cp)?;
    let planned = coord.workspace_req(pool.workers());
    println!(
        "serving {requests} request(s) of {n}³ with patch {}³ (cover {:?}), planned arena {} / worker",
        coord.net.field_of_view()[0].max(plan.input.x),
        coord.cover(),
        human_bytes(planned.bytes),
    );

    let mk_reqs = |base: u64| -> Vec<InferenceRequest> {
        (0..requests)
            .map(|i| InferenceRequest {
                id: base + i as u64,
                volume: Tensor5::random(Shape5::new(1, 1, n, n, n), base + i as u64),
            })
            .collect()
    };

    // Round 1: cold — the arenas warm up (transient allocations here
    // are the one-time working-set build).
    let (resps, warm) = coord.serve(mk_reqs(0), pool)?;
    for r in &resps {
        println!("  request {} -> {} ({} voxels)", r.id, r.output.shape(), r.voxels);
    }
    println!("warmup : {}", warm.report());

    // Round 2: steady state — every buffer comes from the warm arenas.
    let (_, steady) = coord.serve(mk_reqs(1000), pool)?;
    println!("steady : {}", steady.report());
    println!(
        "steady-state: {} transient allocations after warmup; worker cache footprint {} \
         (per-layer Table II plan {}), process arena hwm {}",
        steady.arena_fresh_allocs,
        human_bytes(steady.arena_hwm_bytes),
        human_bytes(planned.bytes),
        human_bytes(znni::memory::arena_hwm()),
    );
    Ok(())
}
