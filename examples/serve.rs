//! Serving demo: the async batched frontend under closed-loop load.
//!
//! One `optimizer::search_serving` call picks the execution plan *and*
//! the serving configuration (shards, queue depth, batch wait) from the
//! same Table II model. The demo then:
//!
//! 1. measures a **serial** coordinator (one request per serve call,
//!    all workers) on a request stream,
//! 2. starts the sharded batched [`znni::server::Server`] and drives it
//!    with a closed-loop multi-client load generator (submit → wait →
//!    repeat, retrying on backpressure) over the same stream,
//!
//! and reports both throughputs plus the serving metrics: queue-depth
//! high-water mark, p50/p99 latency, batch occupancy, per-shard steals
//! and arena gauges — and the steady-state allocation discipline
//! (zero transient allocations after warmup).
//!
//!     cargo run --release --example serve [volume_extent] [clients] [rounds]

use std::sync::Arc;

use znni::approaches::run_server;
use znni::device::Device;
use znni::optimizer::{compile, make_weights, plan_table, search_serving, CostModel, SearchSpace};
use znni::server::{Server, ServingLoad};
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::TaskPool;
use znni::util::{human_bytes, human_throughput};

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(32);
    let clients: usize = std::env::args().nth(2).and_then(|a| a.parse().ok()).unwrap_or(4);
    let rounds: usize = std::env::args().nth(3).and_then(|a| a.parse().ok()).unwrap_or(3);
    let pool = Arc::new(TaskPool::new());
    let net = znni::net::zoo::tiny_net(4);
    // Reuse a saved calibration profile when one exists (see
    // `examples/calibrate.rs`); otherwise measure a quick ladder now.
    // Either way the serving-config search below runs on measured
    // rates and this machine's real batch-dispatch overhead. A profile
    // taken with a different worker count would mis-size the shard
    // search, so a mismatched (or unreadable) one triggers a fresh
    // calibration instead of being trusted silently.
    let cm = match CostModel::load_profile("znni-profile.json") {
        Ok(cm) if cm.threads == pool.workers() => {
            println!("calibration: loaded znni-profile.json");
            cm
        }
        Ok(cm) => {
            println!(
                "calibration: znni-profile.json was taken with {} threads, pool has {} — \
                 recalibrating",
                cm.threads,
                pool.workers()
            );
            CostModel::calibrate_full(&pool, &[8, 12])
        }
        Err(e) => {
            println!("calibration: no usable profile ({e}) — measuring a quick ladder");
            CostModel::calibrate_full(&pool, &[8, 12])
        }
    };
    println!("calibration: dispatch overhead {:.1} us/batch", cm.dispatch_overhead_secs * 1e6);
    let host = Device::host();
    let load = ServingLoad { clients, volume_extent: n };

    // Plan + serving config from one search call.
    let space = SearchSpace::cpu_only(host.clone(), n.min(23));
    let (plan, cfg) = search_serving(&net, &space, &cm, &load).expect("feasible serving plan");
    for (k, v) in plan_table(&plan) {
        println!("  {k:<12} {v}");
    }
    println!(
        "searched config: shards={} queue_depth={} max_batch={} batch_wait={:?} budget={}",
        cfg.shards,
        cfg.queue_depth,
        cfg.max_batch_requests,
        cfg.max_batch_wait,
        human_bytes(cfg.memory_budget),
    );

    // Closed-loop load generator: serial reference vs batched server.
    // (run_server searches its own plan/config; report the config the
    // measurement actually ran with, which may differ from the above.)
    let weights = make_weights(&net, 11);
    let r = run_server(&net, &weights, &host, &cm, pool.clone(), n.min(23), &load, rounds)?;
    println!(
        "measured config: shards={} queue_depth={} max_batch={} batch_wait={:?}",
        r.config.shards,
        r.config.queue_depth,
        r.config.max_batch_requests,
        r.config.max_batch_wait,
    );
    println!(
        "serial  : {} requests, {} voxels in {:.3}s -> {}",
        r.requests,
        r.serial_voxels,
        r.serial_wall_secs,
        human_throughput(r.serial_throughput()),
    );
    println!(
        "batched : {} requests, {} voxels in {:.3}s -> {} ({:.2}x serial)",
        r.requests,
        r.voxels,
        r.wall_secs,
        human_throughput(r.throughput()),
        r.throughput() / r.serial_throughput().max(1e-12),
    );
    println!(
        "latency : p50={:.3}ms p99={:.3}ms occupancy={:.2} rejected={} expired={} failed={}",
        r.p50_latency.as_secs_f64() * 1e3,
        r.p99_latency.as_secs_f64() * 1e3,
        r.batch_occupancy,
        r.rejected,
        r.expired,
        r.failed,
    );

    // Steady-state allocation discipline through the server: warm one
    // round, then verify a second round allocates nothing.
    let cp = compile(&net, &plan, &weights)?;
    let server = Server::start(net.clone(), cp, cfg, pool)?;
    let mk = |seed: u64| Tensor5::random(Shape5::new(1, net.f_in, n, n, n), seed);
    for round in 0..2u64 {
        let tickets: Vec<_> = (0..clients.max(1) as u64)
            .map(|i| server.submit(mk(round * 100 + i)).expect("admitted"))
            .collect();
        for t in tickets {
            t.wait().expect("served");
        }
        let m = server.metrics();
        let fresh: u64 = m.per_shard.iter().map(|s| s.arena_fresh_allocs).sum();
        let label = if round == 0 { "warmup" } else { "steady" };
        println!("{label} : {}", m.report());
        if round == 1 {
            println!(
                "steady-state: arena fresh allocs so far {fresh}, process arena hwm {}",
                human_bytes(znni::memory::arena_hwm()),
            );
            // The RAM the weight-spectrum cache is buying throughput
            // with (0 when the plan chose to recompute or
            // ZNNI_KERNEL_CACHE=off): one shared allocation across all
            // shards, reported beside the per-worker arena footprint.
            println!(
                "footprint : kernel-spectra cache {} (plan budgeted {}), \
                 per-worker Table II arena {}",
                human_bytes(m.kernel_cache_bytes),
                human_bytes(plan.kernel_cache_bytes),
                human_bytes(plan.est_memory - plan.kernel_cache_bytes),
            );
        }
    }
    Ok(())
}
