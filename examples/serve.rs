//! Multi-tenant serving demo: several zoo nets behind one batched
//! frontend, under closed-loop load.
//!
//! One `optimizer::search_serving_multi` call picks a per-tenant
//! execution plan, an SWRR dispatch weight, a byte quota, *and* the
//! shared serving configuration (shards, queue depth, batch wait) from
//! the same Table II model. The demo then:
//!
//! 1. starts one sharded [`znni::server::tenants::TenantServer`]
//!    hosting every tenant's compiled plan,
//! 2. drives each tenant with its own closed-loop load generators
//!    (submit → wait → repeat, retrying on backpressure) over a shared
//!    measurement window,
//!
//! and reports aggregate and per-tenant throughput, p50/p99 latency,
//! rejects, and the steady-state allocation discipline (zero transient
//! allocations after warmup) with every tenant resident.
//!
//!     cargo run --release --example serve [volume_extent] [clients_per_tenant] [rounds]
//!
//! The tenant set comes from `ZNNI_TENANTS` (comma-separated zoo names,
//! default `n337,n537`; the bench miniatures `mini337`..`mini926` also
//! resolve, handy with `ZNNI_SCALE=tiny` for a fast run). The first
//! listed tenant gets SWRR weight 2, the rest weight 1, so the weighted
//! fair dispatch is visible in the per-tenant split.

use std::sync::Arc;

use znni::approaches::run_server_multi;
use znni::device::Device;
use znni::net::zoo::{bench_miniatures, net_by_name, NetScale};
use znni::net::NetSpec;
use znni::optimizer::{
    compile, make_weights, plan_table, search_serving_multi, CostModel, SearchSpace,
};
use znni::server::tenants::{Tenant, TenantServer};
use znni::server::ServingLoad;
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::TaskPool;
use znni::util::{human_bytes, human_throughput};

/// Resolve `ZNNI_TENANTS` (default `n337,n537`) against the zoo at the
/// `ZNNI_SCALE` scale, falling back to the bench miniatures by name.
fn tenant_nets() -> anyhow::Result<Vec<NetSpec>> {
    let scale = NetScale::from_env();
    let spec = std::env::var("ZNNI_TENANTS").unwrap_or_else(|_| "n337,n537".to_string());
    let minis = bench_miniatures();
    let mut nets = Vec::new();
    for name in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let net = net_by_name(name, scale)
            .or_else(|| minis.iter().find(|m| m.name == name).cloned())
            .ok_or_else(|| anyhow::anyhow!("unknown net '{name}' in ZNNI_TENANTS"))?;
        nets.push(net);
    }
    if nets.is_empty() {
        anyhow::bail!("ZNNI_TENANTS named no tenants");
    }
    Ok(nets)
}

fn main() -> anyhow::Result<()> {
    let nets = tenant_nets()?;
    // Every tenant's volume must cover its field of view; default the
    // shared extent to the widest tenant's FoV and never go below it.
    let max_fov =
        nets.iter().map(|nt| *nt.field_of_view().iter().max().unwrap_or(&1)).max().unwrap_or(1);
    let n: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(max_fov);
    let n = n.max(max_fov);
    let clients: usize = std::env::args().nth(2).and_then(|a| a.parse().ok()).unwrap_or(2);
    let rounds: usize = std::env::args().nth(3).and_then(|a| a.parse().ok()).unwrap_or(2);
    let pool = Arc::new(TaskPool::new());
    // Reuse a saved calibration profile when one exists (see
    // `examples/calibrate.rs`); otherwise measure a quick ladder now. A
    // profile taken with a different worker count would mis-size the
    // shard search, so a mismatched one triggers a fresh calibration.
    let cm = match CostModel::load_profile("znni-profile.json") {
        Ok(cm) if cm.threads == pool.workers() => {
            println!("calibration: loaded znni-profile.json");
            cm
        }
        Ok(cm) => {
            println!(
                "calibration: znni-profile.json was taken with {} threads, pool has {} — \
                 recalibrating",
                cm.threads,
                pool.workers()
            );
            CostModel::calibrate_full(&pool, &[8, 12])
        }
        Err(e) => {
            println!("calibration: no usable profile ({e}) — measuring a quick ladder");
            CostModel::calibrate_full(&pool, &[8, 12])
        }
    };
    println!("calibration: dispatch overhead {:.1} us/batch", cm.dispatch_overhead_secs * 1e6);
    let host = Device::host();
    let tenants: Vec<(NetSpec, ServingLoad, u32)> = nets
        .iter()
        .enumerate()
        .map(|(i, nt)| {
            let w = if i == 0 { 2 } else { 1 };
            (nt.clone(), ServingLoad { clients, volume_extent: n }, w)
        })
        .collect();

    // Per-tenant plans, weights, quotas, and the shared config from one
    // search call.
    let space = SearchSpace::cpu_only(host.clone(), n);
    let (tplans, cfg) =
        search_serving_multi(&tenants, &space, &cm).expect("feasible multi-tenant serving plan");
    println!(
        "searched config: shards={} queue_depth={} max_batch={} batch_wait={:?} budget={}",
        cfg.shards,
        cfg.queue_depth,
        cfg.max_batch_requests,
        cfg.max_batch_wait,
        human_bytes(cfg.memory_budget),
    );
    for tp in &tplans {
        let quota = human_bytes(tp.quota_bytes);
        println!("tenant {} (weight {}, quota {}):", tp.name, tp.weight, quota);
        for (k, v) in plan_table(&tp.plan) {
            println!("  {k:<12} {v}");
        }
    }

    // Closed-loop load generators, one set per tenant, shared window.
    // (run_server_multi searches its own plans/config; report the
    // config the measurement actually ran with.)
    let r = run_server_multi(&tenants, &host, &cm, pool.clone(), n, rounds)?;
    println!(
        "measured config: shards={} queue_depth={} max_batch={} batch_wait={:?}",
        r.config.shards,
        r.config.queue_depth,
        r.config.max_batch_requests,
        r.config.max_batch_wait,
    );
    println!(
        "aggregate: {} requests in {:.3}s -> {} (occupancy {:.2})",
        r.tenants.iter().map(|t| t.requests).sum::<u64>(),
        r.wall_secs,
        human_throughput(r.throughput()),
        r.batch_occupancy,
    );
    for t in &r.tenants {
        println!(
            "  {:<8} w={} {} requests -> {} | p50={:.3}ms p99={:.3}ms | \
             rejected={} expired={} failed={}",
            t.name,
            t.weight,
            t.requests,
            human_throughput(r.tenant_throughput(&t.name)),
            t.p50_latency.as_secs_f64() * 1e3,
            t.p99_latency.as_secs_f64() * 1e3,
            t.rejected,
            t.expired,
            t.failed,
        );
    }

    // Steady-state allocation discipline with every tenant resident:
    // warm one round, then verify a second round allocates nothing.
    let mut built = Vec::with_capacity(tplans.len());
    for (i, tp) in tplans.iter().enumerate() {
        let weights = make_weights(&tenants[i].0, 11 + i as u64);
        let plan = compile(&tenants[i].0, &tp.plan, &weights)?;
        built.push(Tenant {
            net: tenants[i].0.clone(),
            plan,
            weight: tp.weight,
            quota_bytes: tp.quota_bytes,
        });
    }
    let server = TenantServer::start(built, cfg.clone(), pool)?;
    for round in 0..2u64 {
        for (ti, (net, ..)) in tenants.iter().enumerate() {
            // Sequential submits per tenant: the quota floor (one
            // request) always admits, and every shard gets warmed.
            for s in 0..cfg.shards as u64 {
                let seed = round * 1000 + ti as u64 * 100 + s;
                let vol = Tensor5::random(Shape5::new(1, net.f_in, n, n, n), seed);
                server.submit(&net.name, vol).expect("admitted").wait().expect("served");
            }
        }
        let m = server.metrics();
        let fresh: u64 = m.merged.per_shard.iter().map(|s| s.arena_fresh_allocs).sum();
        let label = if round == 0 { "warmup" } else { "steady" };
        println!("{label} : {}", m.merged.report());
        if round == 1 {
            println!(
                "steady-state: arena fresh allocs so far {fresh}, process arena hwm {}",
                human_bytes(znni::memory::arena_hwm()),
            );
            for tm in &m.tenants {
                println!(
                    "  {:<8} kernel-spectra cache {} inflight {}",
                    tm.name,
                    human_bytes(tm.metrics.kernel_cache_bytes),
                    human_bytes(tm.inflight_bytes),
                );
            }
        }
    }
    Ok(())
}
