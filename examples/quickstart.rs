//! Quickstart: build a small ConvNet, let the optimizer pick primitives
//! (§VI.A), run one patch, and cross-check against the AOT-compiled
//! JAX/Pallas artifact if `make artifacts` has been run.
//!
//!     cargo run --release --example quickstart

use znni::device::Device;
use znni::optimizer::{compile, make_weights, plan_table, search, CostModel, SearchSpace};
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::TaskPool;
use znni::util::human_throughput;

fn main() -> anyhow::Result<()> {
    let pool = TaskPool::global();

    // 1. A network: conv(4,3³) → pool 2³ → conv(4,3³) → conv(2,3³).
    let net = znni::net::zoo::tiny_net(4);
    println!("net: {}\n{}", net.name, net.to_text());

    // 2. Optimize the execution plan for this machine.
    let cm = CostModel::calibrate(pool, 8);
    let space = SearchSpace::cpu_only(Device::host(), 21);
    let plan = search(&net, &space, &cm).expect("feasible plan");
    for (k, v) in plan_table(&plan) {
        println!("  {k:<12} {v}");
    }

    // 3. Compile with weights; size the execution arena from the plan
    //    (same Table II model the search used) and run a patch.
    let weights = make_weights(&net, 42);
    let cp = compile(&net, &plan, &weights)?;
    let mut ctx = cp.make_ctx(pool)?;
    let input = Tensor5::random(plan.input, 7);
    let t0 = std::time::Instant::now();
    let out = cp.run(input, &mut ctx);
    let secs = t0.elapsed().as_secs_f64();
    let osh = out.shape();
    println!(
        "ran {} -> {} in {:.3}s ({})",
        plan.input,
        osh,
        secs,
        human_throughput((osh.s * osh.x * osh.y * osh.z) as f64 / secs)
    );

    // 4. Cross-check against the JAX/Pallas AOT artifact (three-layer
    //    round trip) when the patch size matches the lowered shape.
    match znni::runtime::Runtime::open("artifacts") {
        Ok(rt) if plan.input == Shape5::new(1, 1, 13, 13, 13) => {
            let input = Tensor5::random(plan.input, 7);
            let bufs: Vec<&[f32]> =
                weights.iter().flat_map(|w| [w.raw(), w.raw_bias()]).collect();
            let pjrt_out = rt.execute_tensor("tiny_net13", &input, &bufs)?;
            let native = cp.run(input, &mut ctx);
            let diff = pjrt_out.max_abs_diff(&native);
            println!("PJRT artifact vs native primitives: max |Δ| = {diff:.2e}");
        }
        Ok(_) => println!("(artifact shape differs from chosen plan; skipping cross-check)"),
        Err(e) => println!("(artifacts unavailable: {e})"),
    }
    Ok(())
}
