//! End-to-end driver: sliding-window boundary "segmentation" of a
//! synthetic 3D electron-microscopy-like volume — the workload the
//! paper's introduction motivates (petascale connectomics imagery).
//!
//! Generates a smoothed-noise volume with membrane-like sheets, runs
//! full patch-based sliding-window inference through the coordinator
//! with an optimizer-chosen plan, verifies the MPF output against the
//! dense per-window reference on a sub-volume, and reports throughput.
//!
//!     cargo run --release --example em_segmentation [volume_extent]

use znni::coordinator::{Coordinator, InferenceRequest};
use znni::device::Device;
use znni::inference::dense_reference;
use znni::net::PoolingMode;
use znni::optimizer::{compile, make_weights, search, CostModel, SearchSpace};
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::TaskPool;
use znni::util::prng::Rng;
use znni::util::{human_bytes, human_throughput};

/// Synthetic EM-ish volume: band-limited noise plus a few membrane-like
/// planes with higher intensity (box-blurred for smoothness).
fn synth_em_volume(n: usize, seed: u64) -> Tensor5 {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; n * n * n];
    rng.fill_uniform(&mut v);
    // Membranes: a few oblique planes of elevated intensity.
    for plane in 0..4 {
        let a = 1 + plane % 3;
        let b = 1 + (plane / 2) % 2;
        let c0 = (plane * n) / 3;
        for x in 0..n {
            for y in 0..n {
                for z in 0..n {
                    if (a * x + b * y + z) % n == c0 {
                        v[(x * n + y) * n + z] += 2.0;
                    }
                }
            }
        }
    }
    // One pass of 3³ box blur for band-limiting.
    let mut out = v.clone();
    for x in 1..n - 1 {
        for y in 1..n - 1 {
            for z in 1..n - 1 {
                let mut acc = 0.0;
                for dx in 0..3 {
                    for dy in 0..3 {
                        for dz in 0..3 {
                            acc += v[((x + dx - 1) * n + y + dy - 1) * n + z + dz - 1];
                        }
                    }
                }
                out[(x * n + y) * n + z] = acc / 27.0;
            }
        }
    }
    Tensor5::from_vec(Shape5::new(1, 1, n, n, n), out)
}

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(40);
    let pool = TaskPool::global();
    let net = znni::net::zoo::tiny_net(4);
    let fov = net.field_of_view();
    println!("== ZNNi end-to-end: synthetic EM volume {n}³, net {} (FoV {fov:?}) ==", net.name);

    println!("generating volume...");
    let volume = synth_em_volume(n, 2016);

    println!("optimizing plan (§VI.A)...");
    let cm = CostModel::calibrate(pool, 8);
    let space = SearchSpace::cpu_only(Device::host(), (n).min(29));
    let plan = search(&net, &space, &cm).expect("feasible plan");
    println!(
        "  patch {}³, est memory {}, primitives: {:?}",
        plan.input.x,
        human_bytes(plan.est_memory),
        plan.layers.iter().map(|l| l.tag()).collect::<Vec<_>>()
    );

    let weights = make_weights(&net, 8888);
    let cp = compile(&net, &plan, &weights)?;
    let coordinator = Coordinator::new(net.clone(), cp)?;

    println!("running sliding-window inference through the coordinator...");
    let (resps, metrics) = coordinator.serve(
        vec![InferenceRequest { id: 1, volume: volume.clone_tensor() }],
        pool,
    )?;
    let output = &resps[0].output;
    println!("  output {} | {}", output.shape(), metrics.report());

    // Validation: dense per-window reference on a small corner.
    println!("validating against dense per-window reference (corner sub-volume)...");
    let sub = fov[0] + 3;
    let mut corner = Tensor5::zeros(Shape5::new(1, 1, sub, sub, sub));
    for x in 0..sub {
        for y in 0..sub {
            for z in 0..sub {
                corner.set(0, 0, x, y, z, volume.at(0, 0, x, y, z));
            }
        }
    }
    // Window runner: max-pool modes, direct conv.
    let modes = vec![PoolingMode::MaxPool; net.pool_count()];
    let wshapes = net.shapes(Shape5::from_spatial(1, 1, fov), &modes)?;
    let wplan = znni::optimizer::Plan {
        net_name: net.name.clone(),
        input: Shape5::from_spatial(1, 1, fov),
        layers: net
            .layers
            .iter()
            .map(|l| match l {
                znni::net::LayerSpec::Conv { .. } => znni::optimizer::PlanLayer::Conv {
                    algo: znni::memory::model::ConvAlgo::DirectMkl,
                    cache_kernels: false,
                    precision: znni::precision::Precision::F32,
                },
                znni::net::LayerSpec::Pool { .. } => znni::optimizer::PlanLayer::Pool {
                    mode: PoolingMode::MaxPool,
                },
            })
            .collect(),
        shapes: wshapes,
        est_secs: 1.0,
        est_memory: 0,
        kernel_cache_bytes: 0,
        out_voxels: 1,
    };
    let wcp = compile(&net, &wplan, &weights)?;
    let mut wctx = znni::exec::ExecCtx::new(pool);
    let mut runner = |t: Tensor5| wcp.run(t, &mut wctx);
    let expect = dense_reference(&net, &mut runner, &corner);
    let mut worst = 0.0f32;
    let esh = expect.shape();
    for f in 0..esh.f {
        for x in 0..esh.x {
            for y in 0..esh.y {
                for z in 0..esh.z {
                    worst = worst.max((expect.at(0, f, x, y, z) - output.at(0, f, x, y, z)).abs());
                }
            }
        }
    }
    println!("  max |Δ| vs dense reference on {}³ corner: {worst:.2e}", sub);
    assert!(worst < 1e-3, "MPF pipeline disagrees with dense reference");

    println!(
        "DONE: {} of boundary-probability output at {}",
        human_bytes(output.shape().bytes_f32()),
        human_throughput(metrics.throughput())
    );
    Ok(())
}
