//! NUMA first-touch cost and live-replan cutover pause (PR 10).
//!
//! Two measurements behind the placement work:
//!
//! * **Arena page touch** — the cost of first-touching a warm arena's
//!   free-list pages (what a pinned shard worker pays once at start so
//!   every later batch reads node-local pages) versus re-walking pages
//!   already resident. On a multi-socket box the gap is the local-vs-
//!   interleaved page placement the ZNNi fast-RAM thesis is about; on a
//!   single node it still bounds the warmup the owner-touch pass adds.
//! * **Plan cutover pause** — how long `Server::swap_plan` takes to
//!   install a different compiled plan on a warm serving server
//!   (kernel-cache warm + per-shard coordinator swap), and what a
//!   serving round costs before and after — the pause the live
//!   replanner imposes when it changes its mind.
//!
//! Results go to stdout and `BENCH_numa.json` (default
//! `../BENCH_numa.json`; override with `ZNNI_BENCH_OUT`).

use std::sync::Arc;
use std::time::Instant;

use znni::device::Device;
use znni::exec::Arena;
use znni::memory::model::ConvAlgo;
use znni::optimizer::{compile, make_weights, search, CostModel, SearchSpace};
use znni::server::{Server, ServerConfig};
use znni::tensor::{Shape5, Tensor5};
use znni::util::bench::{time_n, Scale, Table};
use znni::util::json::Json;
use znni::util::pool::TaskPool;

fn main() {
    let pool = Arc::new(TaskPool::new());
    let scale = Scale::from_env();
    let (touch_elems, rounds, swaps) = match scale {
        Scale::Paper => (1usize << 26, 6usize, 5usize),
        Scale::Small => (1 << 24, 4, 3),
        Scale::Tiny => (1 << 22, 2, 2),
    };
    let touch_mb = (touch_elems * 4) as f64 / (1 << 20) as f64;
    println!(
        "== NUMA first-touch + replan cutover: {touch_mb:.0} MiB arena, {swaps} swaps \
         (numa mode: {:?}, {} node(s)) ==",
        znni::util::numa::numa_mode(),
        znni::util::numa::topology().node_count(),
    );

    // -- Arena page touch: first walk (commits pages) vs resident walk.
    let t0 = Instant::now();
    let mut arena = Arena::new();
    let buf = arena.take_f32_raw(touch_elems);
    arena.put_f32(buf);
    let cold_bytes = arena.touch_pages();
    let cold = t0.elapsed();
    let warm = time_n(1, 5, || {
        arena.touch_pages();
    });
    let cold_gbs = cold_bytes as f64 / cold.as_secs_f64().max(1e-12) / 1e9;
    let warm_gbs = cold_bytes as f64 / warm.median.as_secs_f64().max(1e-12) / 1e9;

    let mut table = Table::new(&["case", "time", "GB/s"]);
    table.row(vec![
        "first touch (alloc+commit)".into(),
        format!("{:.3}ms", cold.as_secs_f64() * 1e3),
        format!("{cold_gbs:.1}"),
    ]);
    table.row(vec![
        "resident re-touch".into(),
        format!("{:.3}ms", warm.median.as_secs_f64() * 1e3),
        format!("{warm_gbs:.1}"),
    ]);

    // -- Plan cutover pause on a warm serving server.
    let net = znni::net::zoo::tiny_net(2);
    let cm = CostModel::default_rates(pool.workers());
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
    space.max_candidates = 2;
    let plan_a = search(&net, &space, &cm).expect("feasible direct plan");
    let mut fft_space = space.clone();
    fft_space.algos = vec![ConvAlgo::FftTaskParallel];
    let plan_b = search(&net, &fft_space, &cm).expect("feasible fft plan");
    let weights = make_weights(&net, 77);
    let cfg = ServerConfig { shards: 2, queue_depth: 8, ..ServerConfig::default() };
    let server = Server::start(
        net.clone(),
        compile(&net, &plan_a, &weights).expect("compile plan A"),
        cfg,
        pool.clone(),
    )
    .expect("server start");
    let serve_round = |server: &Server, base: u64| -> f64 {
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..rounds as u64)
            .map(|i| {
                server
                    .submit(Tensor5::random(Shape5::new(1, 1, 20, 20, 20), base + i))
                    .expect("admitted")
            })
            .collect();
        for t in tickets {
            t.wait().expect("served");
        }
        t0.elapsed().as_secs_f64()
    };
    let pre_round = serve_round(&server, 100);
    let mut cutovers: Vec<f64> = Vec::with_capacity(swaps);
    for k in 0..swaps {
        // Alternate A→B→A…: every swap installs a genuinely different
        // plan, and the server keeps serving between swaps.
        let next = if k % 2 == 0 { &plan_b } else { &plan_a };
        let cp = compile(&net, next, &weights).expect("compile swap target");
        let t0 = Instant::now();
        server.swap_plan(cp).expect("swap");
        cutovers.push(t0.elapsed().as_secs_f64());
        serve_round(&server, 1000 + 100 * k as u64);
    }
    let post_round = serve_round(&server, 9000);
    cutovers.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cut_median = cutovers[cutovers.len() / 2];
    let cut_min = cutovers[0];
    let m = server.metrics();
    table.row(vec![
        "plan cutover (swap_plan)".into(),
        format!("{:.3}ms", cut_median * 1e3),
        "-".into(),
    ]);
    table.print();
    println!(
        "rounds: pre-swap {:.3}ms, post-swap {:.3}ms | swaps={} completed={}",
        pre_round * 1e3,
        post_round * 1e3,
        m.plan_swaps,
        m.completed,
    );

    let doc: Vec<(String, Json)> = vec![
        ("scale".into(), Json::Str(format!("{scale:?}"))),
        ("numa_nodes".into(), Json::Num(znni::util::numa::topology().node_count() as f64)),
        ("touch_mb".into(), Json::Num(touch_mb)),
        ("cold_first_touch_secs".into(), Json::Num(cold.as_secs_f64())),
        ("warm_retouch_secs".into(), Json::Num(warm.median.as_secs_f64())),
        ("cold_touch_gb_per_s".into(), Json::Num(cold_gbs)),
        ("warm_touch_gb_per_s".into(), Json::Num(warm_gbs)),
        ("swaps".into(), Json::Num(m.plan_swaps as f64)),
        ("cutover_median_secs".into(), Json::Num(cut_median)),
        ("cutover_min_secs".into(), Json::Num(cut_min)),
        ("pre_swap_round_secs".into(), Json::Num(pre_round)),
        ("post_swap_round_secs".into(), Json::Num(post_round)),
        ("completed_requests".into(), Json::Num(m.completed as f64)),
    ];
    let path = std::env::var("ZNNI_BENCH_OUT").unwrap_or_else(|_| "../BENCH_numa.json".into());
    match std::fs::write(&path, Json::Object(doc).to_pretty_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
