//! §IV claims on the convolutional primitives:
//! * direct "MKL" ≈ 2× naive;
//! * task-parallel FFT ≫ data-parallel FFT when f·S is large
//!   (paper: up to 10× on a 4-way Xeon — structural here on 1 core);
//! * FFT-based beats direct for larger kernels.

use std::sync::Arc;
use std::time::Duration;

use znni::conv::{Activation, Weights};
use znni::exec::ExecCtx;
use znni::layers::{ConvLayer, LayerPrimitive};
use znni::memory::model::ConvAlgo;
use znni::tensor::{Shape5, Tensor5};
use znni::util::bench::{time_budget, Scale, Table};
use znni::util::pool::TaskPool;

fn main() {
    let pool = TaskPool::global();
    let mut ctx = ExecCtx::new(pool);
    let scale = Scale::from_env();
    let (n, f, s) = match scale {
        Scale::Paper => (48, 16, 2),
        Scale::Small => (20, 8, 2),
        Scale::Tiny => (12, 4, 1),
    };
    println!("== Convolutional primitive comparison (n={n}, f=f'={f}, S={s}) ==");
    let mut table = Table::new(&["kernel", "algo", "ms/layer", "GFLOP/s", "vs naive"]);
    let budget = Duration::from_millis(500);
    for &k in &[2usize, 3, 5] {
        let w = Arc::new(Weights::random(f, f, [k, k, k], 7));
        let sh = Shape5::new(s, f, n, n, n);
        let mut naive_ms = 0.0;
        for algo in [
            ConvAlgo::DirectNaive,
            ConvAlgo::DirectMkl,
            ConvAlgo::FftDataParallel,
            ConvAlgo::FftTaskParallel,
            ConvAlgo::GpuFft,
        ] {
            let layer = ConvLayer::new(w.clone(), algo, Activation::Relu);
            let flops = layer.flops(sh);
            let sample = time_budget(budget, || {
                let t = Tensor5::random(sh, 3);
                std::hint::black_box(layer.execute(t, &mut ctx));
            });
            let ms = sample.secs() * 1e3;
            if algo == ConvAlgo::DirectNaive {
                naive_ms = ms;
            }
            table.row(vec![
                format!("{k}^3"),
                algo.tag().into(),
                format!("{ms:.2}"),
                format!("{:.2}", flops / sample.secs() / 1e9),
                format!("{:.2}x", naive_ms / ms),
            ]);
        }
    }
    table.print();
}
