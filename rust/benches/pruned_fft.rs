//! §III claim: pruned FFTs of kernels are ~5× faster than naive
//! transforms on the CPU (10× on GPU). Regenerates the speedup table.

use znni::fft::fft3d::{Fft3, Fft3Scratch};
use znni::fft::plan::{fft_3d_flops_naive, fft_3d_flops_pruned};
use znni::fft::fft_optimal_size;
use znni::tensor::Complex32;
use znni::util::bench::{time_budget, Table};
use znni::util::prng::Rng;
use std::time::Duration;

fn main() {
    println!("== Pruned FFT speedup (paper §III: ~5x for kernels on CPU) ==");
    let mut table = Table::new(&[
        "kernel", "padded", "naive ms", "pruned ms", "speedup", "model-speedup",
    ]);
    let budget = Duration::from_millis(300);
    for &k in &[3usize, 5, 7, 9] {
        for &n in &[32usize, 48, 64] {
            let pn = fft_optimal_size(n);
            let plan = Fft3::new([pn, pn, pn]);
            let mut sc = Fft3Scratch::new();
            let mut rng = Rng::new(k as u64 * 100 + n as u64);
            let img: Vec<f32> = (0..k * k * k).map(|_| rng.f32_range(-1.0, 1.0)).collect();
            let mut out = vec![Complex32::ZERO; plan.complex_len()];
            let t_naive =
                time_budget(budget, || plan.forward_naive(&img, [k, k, k], &mut out, &mut sc));
            let t_pruned = time_budget(budget, || plan.forward(&img, [k, k, k], &mut out, &mut sc));
            let model = fft_3d_flops_naive([pn; 3]) / fft_3d_flops_pruned([k; 3], [pn; 3]);
            table.row(vec![
                format!("{k}^3"),
                format!("{pn}^3"),
                format!("{:.2}", t_naive.secs() * 1e3),
                format!("{:.2}", t_pruned.secs() * 1e3),
                format!("{:.2}x", t_naive.secs() / t_pruned.secs()),
                format!("{model:.2}x"),
            ]);
        }
    }
    table.print();
}
