//! Scalar-vs-dispatched microbench for the SIMD kernel layer.
//!
//! Measures each kernel family on paper-sized workloads (the FFT-conv
//! spectrum MAD on a conv2-scale spectrum, the direct-conv z-row axpy,
//! the radix-2/4 butterfly combines, and the pooling row max), first
//! with the dispatch forced to the scalar tier, then with the detected
//! tier, and reports the speedup. Results are also written as JSON
//! (default `../BENCH_simd.json`, i.e. the repository root when run via
//! `cargo bench --bench simd_kernels`; override with `ZNNI_BENCH_OUT`).
//!
//! Acceptance target (ISSUE 1): dispatched `mad_spectra` ≥ 2× scalar on
//! AVX2+FMA hardware.

use std::time::Duration;

use znni::simd::{self, Tier};
use znni::tensor::Complex32;
use znni::util::bench::{time_budget, Scale, Table};
use znni::util::prng::Rng;

struct Row {
    name: &'static str,
    elems: usize,
    scalar_ns: f64,
    simd_ns: f64,
}

fn rand_f32(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.f32_range(-1.0, 1.0)).collect()
}

fn rand_c32(n: usize, seed: u64) -> Vec<Complex32> {
    let mut r = Rng::new(seed);
    (0..n)
        .map(|_| Complex32::new(r.f32_range(-1.0, 1.0), r.f32_range(-1.0, 1.0)))
        .collect()
}

/// Time `f` twice: forced-scalar and auto-dispatched.
fn measure(budget: Duration, mut f: impl FnMut()) -> (f64, f64) {
    simd::force(Some(Tier::Scalar));
    let s = time_budget(budget, &mut f);
    simd::force(None);
    let v = time_budget(budget, &mut f);
    simd::force(None);
    (s.median.as_nanos() as f64, v.median.as_nanos() as f64)
}

fn main() {
    let scale = Scale::from_env();
    // Spectrum size of an FFT-conv layer: padded x·y·(z/2+1) complex
    // bins. `paper` ≈ a 96³ conv2 layer, `small` ≈ 48³, `tiny` for CI.
    let (spec, rows, row_len, m2, m4, fft_n) = match scale {
        Scale::Paper => (96 * 96 * 49, 512, 110, 512, 256, 1024),
        Scale::Small => (48 * 48 * 25, 256, 110, 256, 128, 512),
        Scale::Tiny => (16 * 16 * 9, 32, 30, 32, 16, 128),
    };
    let budget = match scale {
        Scale::Paper => Duration::from_millis(500),
        Scale::Small => Duration::from_millis(200),
        Scale::Tiny => Duration::from_millis(50),
    };

    println!(
        "simd_kernels: detected tier = {} (ZNNI_SIMD to override), scale = {scale:?}",
        simd::detect().name()
    );

    let mut out: Vec<Row> = Vec::new();

    // ---- mad_spectra: acc += a·b over a conv-layer spectrum ----
    {
        let a = rand_c32(spec, 1);
        let b = rand_c32(spec, 2);
        let mut acc = rand_c32(spec, 3);
        let (s, v) = measure(budget, || simd::mad_spectra(&mut acc, &a, &b));
        out.push(Row { name: "mad_spectra", elems: spec, scalar_ns: s, simd_ns: v });
    }

    // ---- cmul: dst = a·b (GPU-scheme PARALLEL-MULT) ----
    {
        let a = rand_c32(spec, 4);
        let b = rand_c32(spec, 5);
        let mut dst = vec![Complex32::ZERO; spec];
        let (s, v) = measure(budget, || simd::cmul(&mut dst, &a, &b));
        out.push(Row { name: "cmul", elems: spec, scalar_ns: s, simd_ns: v });
    }

    // ---- axpy: direct-conv z-row FMA over `rows` kernel taps ----
    {
        let img = rand_f32(rows * row_len, 6);
        let mut dst = rand_f32(row_len, 7);
        let (s, v) = measure(budget, || {
            for r in 0..rows {
                simd::axpy(&mut dst, &img[r * row_len..(r + 1) * row_len], 0.123);
            }
        });
        out.push(Row { name: "axpy_rows", elems: rows * row_len, scalar_ns: s, simd_ns: v });
    }

    // ---- max rows: pooling element-wise max over `rows` rows ----
    {
        let img = rand_f32(rows * row_len, 8);
        let mut dst = rand_f32(row_len, 9);
        let (s, v) = measure(budget, || {
            for r in 0..rows {
                simd::max_assign(&mut dst, &img[r * row_len..(r + 1) * row_len]);
            }
        });
        out.push(Row { name: "maxpool_rows", elems: rows * row_len, scalar_ns: s, simd_ns: v });
    }

    // ---- radix-2 / radix-4 butterfly combines ----
    {
        let tw: Vec<Complex32> = (0..fft_n)
            .map(|j| Complex32::cis(-2.0 * std::f64::consts::PI * j as f64 / fft_n as f64))
            .collect();
        let d2 = rand_c32(2 * m2, 10);
        let mut buf2 = d2.clone();
        let (s, v) = measure(budget, || {
            buf2.copy_from_slice(&d2);
            simd::radix2_combine(&mut buf2, m2, &tw, fft_n / (2 * m2), fft_n);
        });
        out.push(Row { name: "radix2_combine", elems: 2 * m2, scalar_ns: s, simd_ns: v });

        let d4 = rand_c32(4 * m4, 11);
        let mut buf4 = d4.clone();
        let (s, v) = measure(budget, || {
            buf4.copy_from_slice(&d4);
            simd::radix4_combine(&mut buf4, m4, &tw, fft_n / (4 * m4), fft_n);
        });
        out.push(Row { name: "radix4_combine", elems: 4 * m4, scalar_ns: s, simd_ns: v });
    }

    // ---- report ----
    let mut table = Table::new(&["kernel", "elems", "scalar", "dispatched", "speedup"]);
    for r in &out {
        table.row(vec![
            r.name.to_string(),
            r.elems.to_string(),
            format!("{:.1} µs", r.scalar_ns / 1e3),
            format!("{:.1} µs", r.simd_ns / 1e3),
            format!("{:.2}×", r.scalar_ns / r.simd_ns.max(1.0)),
        ]);
    }
    table.print();

    let path = std::env::var("ZNNI_BENCH_OUT").unwrap_or_else(|_| "../BENCH_simd.json".into());
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"tier\": \"{}\",\n  \"arch\": \"{}\",\n  \"scale\": \"{:?}\",\n  \"kernels\": [\n",
        simd::detect().name(),
        std::env::consts::ARCH,
        scale
    ));
    for (i, r) in out.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"elems\": {}, \"scalar_ns\": {:.0}, \"simd_ns\": {:.0}, \"speedup\": {:.3}}}{}\n",
            r.name,
            r.elems,
            r.scalar_ns,
            r.simd_ns,
            r.scalar_ns / r.simd_ns.max(1.0),
            if i + 1 < out.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
