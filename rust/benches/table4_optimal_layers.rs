//! Table IV: optimal primitive choice per layer + optimal input size
//! for the four benchmark nets on the simulated 12 GB GPU. Pure
//! cost-model search (no execution), so this runs the REAL Table III
//! nets at Small scale by default (ZNNI_SCALE=paper for 80 maps).

use znni::device::Device;
use znni::net::zoo::{benchmark_nets, NetScale};
use znni::net::PoolingMode;
use znni::optimizer::{plan_table, search, CostModel, SearchSpace};
use znni::util::bench::Table;
use znni::util::pool::TaskPool;

fn main() {
    let scale = NetScale::from_env();
    let pool = TaskPool::global();
    eprintln!("calibrating...");
    let cm = CostModel::calibrate(pool, 10);
    let gpu = Device::titan_x();
    println!("== Table IV: optimal GPU-only layer primitives (scale {scale:?}, 12 GiB device) ==");
    let nets = benchmark_nets(scale);
    let mut plans = Vec::new();
    for net in &nets {
        let modes = vec![PoolingMode::Mpf; net.pool_count()];
        let min = net.min_extent(&modes).unwrap();
        let mut space = SearchSpace::gpu_only(gpu.clone(), min + 64);
        space.max_candidates = 16;
        plans.push(search(net, &space, &cm).map(|p| plan_table(&p)));
    }
    let mut t = Table::new(&["", "n337", "n537", "n726", "n926"]);
    let rows = plans.iter().flatten().map(|p| p.len()).max().unwrap_or(0);
    for r in 0..rows {
        let mut row = vec![String::new()];
        for p in &plans {
            match p {
                Some(rows_) if r < rows_.len() => {
                    if row[0].is_empty() {
                        row[0] = rows_[r].0.clone();
                    }
                    row.push(rows_[r].1.clone());
                }
                Some(_) => row.push(String::new()),
                None => row.push("infeasible".into()),
            }
        }
        t.row(row);
    }
    t.print();
    println!("(paper shape: layer 1 uses the lean CuDNN1 — the memory frontier beats raw speed;");
    println!(" later layers switch to FFT for the large-kernel nets n726/n926)");
}
