//! Fig 5: measured throughput vs input image size for CPU-only and
//! GPU-only execution of the benchmark nets. ZNNI_SCALE=paper uses the
//! true Table III nets; the default uses the topology-preserving
//! miniatures (see net::zoo::bench_miniatures).

use znni::device::Device;
use znni::net::zoo::{bench_miniatures, benchmark_nets, NetScale};
use znni::net::{NetSpec, PoolingMode};
use znni::optimizer::{compile, make_weights, search, CostModel, SearchSpace};
use znni::tensor::Tensor5;
use znni::util::bench::{Scale, Table};
use znni::util::human_throughput;
use znni::util::pool::TaskPool;

fn nets() -> Vec<NetSpec> {
    match Scale::from_env() {
        Scale::Paper => benchmark_nets(NetScale::Paper),
        Scale::Small => bench_miniatures(),
        Scale::Tiny => bench_miniatures().into_iter().take(2).collect(),
    }
}

fn main() {
    let pool = TaskPool::global();
    eprintln!("calibrating...");
    let cm = CostModel::calibrate(pool, 10);
    let host = Device::host();
    let gpu = Device::titan_x();
    println!("== Fig 5: throughput vs input size (measured) ==");
    for net in nets() {
        let modes = vec![PoolingMode::Mpf; net.pool_count()];
        let min = net.min_extent(&modes).unwrap();
        let extents = net.valid_extents(min, min + 24, &modes);
        let mut t = Table::new(&["input", "CPU-only Vx/s", "GPU-only Vx/s"]);
        println!("\n-- {} (FoV {:?}) --", net.name, net.field_of_view());
        let weights = make_weights(&net, 5);
        for n in extents.into_iter().take(6) {
            let mut row = vec![format!("{n}^3")];
            for gpu_mode in [false, true] {
                let mut space = if gpu_mode {
                    SearchSpace::gpu_only(gpu.clone(), n)
                } else {
                    SearchSpace::cpu_only(host.clone(), n)
                };
                space.min_extent = n;
                space.max_candidates = 1;
                match search(&net, &space, &cm) {
                    Some(plan) => {
                        let cp = compile(&net, &plan, &weights).unwrap();
                        let mut ctx = cp.make_ctx(pool).unwrap();
                        let input = Tensor5::random(plan.input, 3);
                        let t0 = std::time::Instant::now();
                        let out = cp.run(input, &mut ctx);
                        let mut secs = t0.elapsed().as_secs_f64();
                        if gpu_mode {
                            secs += gpu.transfer_secs(
                                plan.input.bytes_f32() + out.shape().bytes_f32(),
                            );
                        }
                        let osh = out.shape();
                        let vox = (osh.s * osh.x * osh.y * osh.z) as f64;
                        row.push(human_throughput(vox / secs));
                    }
                    None => row.push("infeasible".into()),
                }
            }
            t.row(row);
        }
        t.print();
    }
    println!("\n(paper shape: throughput grows with input size until the device memory frontier)");
}
