//! Fig 7: measured throughput vs memory consumed for the four
//! approaches (CPU-only, GPU-only, GPU + host RAM, CPU-GPU), sweeping
//! the memory budget. Miniature nets by default; ZNNI_SCALE=paper uses
//! the Table III nets.

use std::sync::Arc;

use znni::approaches::{run_approach, Approach};
use znni::device::Device;
use znni::net::zoo::{bench_miniatures, benchmark_nets, NetScale};
use znni::net::{NetSpec, PoolingMode};
use znni::optimizer::CostModel;
use znni::util::bench::{Scale, Table};
use znni::util::{human_bytes, human_throughput};
use znni::util::pool::TaskPool;

fn nets() -> Vec<NetSpec> {
    match Scale::from_env() {
        Scale::Paper => benchmark_nets(NetScale::Paper),
        Scale::Small => bench_miniatures(),
        Scale::Tiny => bench_miniatures().into_iter().take(1).collect(),
    }
}

fn main() {
    let pool = TaskPool::global();
    eprintln!("calibrating...");
    let cm = CostModel::calibrate(pool, 10);
    println!("== Fig 7: throughput vs memory budget (measured + modelled transfers) ==");
    // Budgets scaled down from the paper's 256 GB host / 12 GB device.
    let budgets: &[(u64, u64)] = &[
        (8 << 20, 2 << 20), // host 8 MiB, device 2 MiB — memory binds hard
        (32 << 20, 8 << 20),
        (128 << 20, 32 << 20),
        (512 << 20, 128 << 20),
    ];
    for net in nets() {
        println!("\n-- {} --", net.name);
        let weights: Vec<Arc<_>> = znni::optimizer::make_weights(&net, 5);
        let modes = vec![PoolingMode::Mpf; net.pool_count()];
        let min = net.min_extent(&modes).unwrap();
        let mut t =
            Table::new(&["host RAM", "dev RAM", "CPU-only", "GPU-only", "GPU+host", "CPU-GPU"]);
        for &(host_b, gpu_b) in budgets {
            let host = Device::host_with_ram(host_b);
            let gpu = Device::gpu_with_ram(gpu_b);
            let mut row = vec![human_bytes(host_b).to_string(), human_bytes(gpu_b).to_string()];
            for a in Approach::ALL {
                match run_approach(a, &net, &weights, &host, &gpu, &cm, pool, min + 44) {
                    Ok(r) => row.push(format!(
                        "{} @{}³",
                        human_throughput(r.throughput()),
                        r.input_extent
                    )),
                    Err(_) => row.push("infeasible".into()),
                }
            }
            t.row(row);
        }
        t.print();
    }
    println!("\n(paper shape: GPU-only saturates at the device frontier; GPU+host and CPU-GPU keep");
    println!(" scaling with host RAM; CPU-GPU is the top line)");
}
