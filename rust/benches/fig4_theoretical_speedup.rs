//! Fig 4: theoretical speedup of MPF pooling networks (FFT-based
//! costs) vs memory, for several batch sizes, on a 1-pool and a 2-pool
//! network. Reproduces the paper's finding: with ≥2 pooling layers,
//! batch size 1 achieves the highest speedup at any memory budget;
//! 1-pool networks can prefer larger batches.

use znni::net::spec::{LayerSpec, NetSpec};
use znni::optimizer::theory::speedup_series;
use znni::util::bench::Table;
use znni::util::human_bytes;

fn net(pools: usize) -> NetSpec {
    let mut layers = vec![LayerSpec::Conv { f_out: 8, k: [3; 3] }];
    for _ in 0..pools {
        layers.push(LayerSpec::Pool { p: [2; 3] });
        layers.push(LayerSpec::Conv { f_out: 8, k: [3; 3] });
    }
    layers.push(LayerSpec::Conv { f_out: 3, k: [3; 3] });
    NetSpec { name: format!("fig4-{pools}pool"), f_in: 1, layers }
}

fn main() {
    for pools in [1usize, 2] {
        let n = net(pools);
        let tag = if pools == 1 { 'a' } else { 'b' };
        println!("\n== Fig 4{}: {} (batch sizes 1/2/4/8) ==", tag, n.name);
        let series = speedup_series(&n, &[1, 2, 4, 8], 61, 4);
        let mut t = Table::new(&["memory", "S=1", "S=2", "S=4", "S=8"]);
        // Align by memory decade: print each S's speedup at its points;
        // use the S=1 memory grid and interpolate others by nearest ≤.
        let grid: Vec<u64> = series[0].points.iter().map(|(m, _)| *m).collect();
        for (gi, mem) in grid.iter().enumerate() {
            if gi % 2 == 1 {
                continue; // thin the table
            }
            let mut row = vec![human_bytes(*mem).to_string()];
            for s in &series {
                let v = s
                    .points
                    .iter()
                    .filter(|(m, _)| m <= mem)
                    .map(|(_, v)| *v)
                    .fold(f64::NAN, f64::max);
                row.push(if v.is_nan() { "-".into() } else { format!("{v:.1}x") });
            }
            t.row(row);
        }
        t.print();
        // Paper-shape check: for the 2-pool net the S=1 column should
        // dominate at the largest common memory point.
        if pools == 2 {
            // Compare at the largest memory point BOTH series cover.
            let m1 = series[0].points.last().unwrap().0;
            let m4 = series[2].points.last().unwrap().0;
            let m_common = m1.min(m4);
            let best_at = |s: &znni::optimizer::theory::SpeedupSeries| {
                s.points
                    .iter()
                    .filter(|(m, _)| *m <= m_common)
                    .map(|(_, v)| *v)
                    .fold(0.0, f64::max)
            };
            let v1 = best_at(&series[0]);
            let v4 = best_at(&series[2]);
            println!(
                "2-pool check at {}: S=1 best {v1:.1}x vs S=4 best {v4:.1}x  ({})",
                human_bytes(m_common),
                if v1 >= v4 * 0.95 { "paper shape HOLDS" } else { "paper shape VIOLATED" }
            );
        }
    }
}
