//! Single- vs two-tenant serving throughput, with per-tenant p99
//! latency (PR 8).
//!
//! Each zoo miniature is first served alone (a one-tenant
//! `TenantServer` via `run_server_multi`), then both share one server
//! with equal weights. The comparison shows what co-tenancy costs each
//! model: the shared run splits the same shards, so per-tenant
//! throughput should land near the weighted share of its solo run
//! while p99 stays bounded (the SWRR dispatcher never lets one tenant
//! monopolize a shard).
//!
//! Results go to stdout and `BENCH_multi_tenant.json` (default
//! `../BENCH_multi_tenant.json`, i.e. the repository root when run via
//! `cargo bench --bench multi_tenant`; override with `ZNNI_BENCH_OUT`).

use std::sync::Arc;

use znni::approaches::run_server_multi;
use znni::device::Device;
use znni::net::NetSpec;
use znni::optimizer::CostModel;
use znni::server::ServingLoad;
use znni::util::bench::{Scale, Table};
use znni::util::json::Json;
use znni::util::pool::TaskPool;

fn main() {
    let pool = Arc::new(TaskPool::new());
    let scale = Scale::from_env();
    let (clients, rounds) = match scale {
        Scale::Paper => (4usize, 3usize),
        Scale::Small => (2, 2),
        Scale::Tiny => (2, 1),
    };
    // mini537's field of view is 18³: volumes of 20³ cover both nets.
    let extent = 20usize;
    let max_extent = 19usize;
    let minis = znni::net::zoo::bench_miniatures();
    let nets: Vec<NetSpec> = vec![minis[0].clone(), minis[1].clone()];
    let host = Device::host_with_ram(4 << 30);
    let cm = CostModel::default_rates(pool.workers());
    let load = ServingLoad { clients, volume_extent: extent };
    println!(
        "== Multi-tenant serving: {} + {}, {extent}³ volumes, {clients} clients/tenant ==",
        nets[0].name, nets[1].name
    );

    // Solo baselines: each net alone on the server.
    let solo: Vec<_> = nets
        .iter()
        .map(|net| {
            let tenants = vec![(net.clone(), load, 1u32)];
            run_server_multi(&tenants, &host, &cm, pool.clone(), max_extent, rounds)
                .expect("solo serving run")
        })
        .collect();

    // Shared run: both tenants, equal weights, same offered load each.
    let tenants: Vec<_> = nets.iter().map(|net| (net.clone(), load, 1u32)).collect();
    let shared = run_server_multi(&tenants, &host, &cm, pool, max_extent, rounds)
        .expect("two-tenant serving run");

    let mut table =
        Table::new(&["tenant", "solo vox/s", "shared vox/s", "ratio", "solo p99", "shared p99"]);
    let mut doc: Vec<(String, Json)> = vec![
        ("scale".into(), Json::Str(format!("{scale:?}"))),
        ("extent".into(), Json::Num(extent as f64)),
        ("clients_per_tenant".into(), Json::Num(clients as f64)),
        ("rounds".into(), Json::Num(rounds as f64)),
        ("shared_total_vox_per_s".into(), Json::Num(shared.throughput())),
        ("shared_batch_occupancy".into(), Json::Num(shared.batch_occupancy)),
    ];
    for (net, solo_r) in nets.iter().zip(&solo) {
        let solo_tp = solo_r.tenant_throughput(&net.name);
        let shared_tp = shared.tenant_throughput(&net.name);
        let ratio = shared_tp / solo_tp.max(1e-9);
        let solo_t = &solo_r.tenants[0];
        let shared_t = shared
            .tenants
            .iter()
            .find(|t| t.name == net.name)
            .expect("tenant present in shared run");
        table.row(vec![
            net.name.clone(),
            format!("{solo_tp:.0}"),
            format!("{shared_tp:.0}"),
            format!("{ratio:.2}×"),
            format!("{:.3}ms", solo_t.p99_latency.as_secs_f64() * 1e3),
            format!("{:.3}ms", shared_t.p99_latency.as_secs_f64() * 1e3),
        ]);
        doc.push((
            net.name.clone(),
            Json::Object(vec![
                ("solo_vox_per_s".into(), Json::Num(solo_tp)),
                ("shared_vox_per_s".into(), Json::Num(shared_tp)),
                ("ratio".into(), Json::Num(ratio)),
                ("solo_p99_secs".into(), Json::Num(solo_t.p99_latency.as_secs_f64())),
                ("shared_p99_secs".into(), Json::Num(shared_t.p99_latency.as_secs_f64())),
                ("shared_requests".into(), Json::Num(shared_t.requests as f64)),
                ("quota_bytes".into(), Json::Num(shared_t.quota_bytes as f64)),
            ]),
        ));
    }
    table.print();
    println!(
        "shared config: shards={} queue_depth={} max_batch={} | total {:.0} vox/s",
        shared.config.shards,
        shared.config.queue_depth,
        shared.config.max_batch_requests,
        shared.throughput(),
    );

    let path =
        std::env::var("ZNNI_BENCH_OUT").unwrap_or_else(|_| "../BENCH_multi_tenant.json".into());
    match std::fs::write(&path, Json::Object(doc).to_pretty_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
