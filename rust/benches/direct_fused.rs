//! Register-tiled fused direct conv vs the established CPU families,
//! plus the conv→pool fusion pay-off (ISSUE 7).
//!
//! Two measurements on n337-shaped small-kernel (3³) layers:
//!
//! * **conv** — one conv layer timed per algorithm (DirectM,
//!   DirectFused, FFT-TP) through the same warm [`ExecCtx`]: the
//!   head-to-head the optimizer's default rates model;
//! * **pair** — a conv→max-pool pair run separately (DirectFused then
//!   `max_pool`) vs as the single fused primitive
//!   ([`znni::layers::FusedConvPoolLayer`]), which never materializes
//!   the pre-pool tensor — the column pair shows the time saved and the
//!   Table II bytes dropped.
//!
//! Results go to stdout and `BENCH_direct_fused.json` (default
//! `../BENCH_direct_fused.json`, i.e. the repository root when run via
//! `cargo bench --bench direct_fused`; override with `ZNNI_BENCH_OUT`).

use std::sync::Arc;
use std::time::Duration;

use znni::conv::{Activation, Weights};
use znni::exec::ExecCtx;
use znni::layers::{ConvLayer, FusedConvPoolLayer, LayerPrimitive, MaxPoolLayer, Placement};
use znni::memory::model::{conv_memory_bytes, conv_pool_fused_memory_bytes, ConvAlgo, ConvDims};
use znni::tensor::{Shape5, Tensor5};
use znni::util::bench::{time_budget, Scale, Table};
use znni::util::json::Json;
use znni::util::pool::TaskPool;

fn main() {
    let pool = TaskPool::global();
    let scale = Scale::from_env();
    // Even extents so the 2³ pool window tiles the (n-2)³ conv output.
    let (n, f) = match scale {
        Scale::Paper => (48usize, 16usize),
        Scale::Small => (20, 8),
        Scale::Tiny => (10, 4),
    };
    let budget = match scale {
        Scale::Paper => Duration::from_millis(1500),
        Scale::Small => Duration::from_millis(600),
        Scale::Tiny => Duration::from_millis(250),
    };
    let sh = Shape5::new(1, f, n, n, n);
    let d = ConvDims { s: 1, f_in: f, f_out: f, n: [n; 3], k: [3; 3] };
    println!("== Fused direct conv: {n}³ patches, f=f'={f}, k=3³ ==");

    let mut doc: Vec<(String, Json)> = vec![
        ("scale".into(), Json::Str(format!("{scale:?}"))),
        ("extent".into(), Json::Num(n as f64)),
        ("maps".into(), Json::Num(f as f64)),
        ("workers".into(), Json::Num(pool.workers() as f64)),
    ];

    let w = Arc::new(Weights::random(f, f, [3, 3, 3], 0xF5ED));
    let mut ctx = ExecCtx::new(pool);
    let base = Tensor5::random(sh, 3);
    let run = |layer: &dyn LayerPrimitive, ctx: &mut ExecCtx<'_>| {
        // Warm the arena, then timed iterations copy the same input
        // into an arena-recycled tensor (execute consumes its input).
        let out = layer.execute(base.clone_tensor(), ctx);
        ctx.retire(out);
        time_budget(budget, || {
            let mut t = ctx.tensor5(sh);
            t.data_mut().copy_from_slice(base.data());
            let out = layer.execute(t, ctx);
            ctx.retire(out);
        })
    };

    // Head-to-head conv layer per algorithm.
    let mut table = Table::new(&["algorithm", "patch ms", "model bytes"]);
    let mut conv_doc: Vec<(String, Json)> = Vec::new();
    for algo in [ConvAlgo::DirectMkl, ConvAlgo::DirectFused, ConvAlgo::FftTaskParallel] {
        let layer = ConvLayer::new(w.clone(), algo, Activation::Relu);
        let t = run(&layer, &mut ctx);
        let bytes = conv_memory_bytes(algo, &d, pool.workers());
        table.row(vec![
            algo.name().to_string(),
            format!("{:.3}", t.secs() * 1e3),
            znni::util::human_bytes(bytes),
        ]);
        conv_doc.push((
            algo.tag().to_string(),
            Json::Object(vec![
                ("secs".into(), Json::Num(t.secs())),
                ("model_bytes".into(), Json::Num(bytes as f64)),
            ]),
        ));
    }
    table.print();
    doc.push(("conv".into(), Json::Object(conv_doc)));

    // The conv→pool pair: separate primitives vs the fused one.
    let p = [2usize, 2, 2];
    let conv = ConvLayer::new(w.clone(), ConvAlgo::DirectFused, Activation::Relu);
    let maxp = MaxPoolLayer { window: p, placement: Placement::Cpu };
    let fused = FusedConvPoolLayer { weights: w, window: p, act: Activation::Relu };
    {
        let out = conv.execute(base.clone_tensor(), &mut ctx);
        let out = maxp.execute(out, &mut ctx);
        ctx.retire(out);
    }
    let separate = time_budget(budget, || {
        let mut t = ctx.tensor5(sh);
        t.data_mut().copy_from_slice(base.data());
        let out = conv.execute(t, &mut ctx);
        let out = maxp.execute(out, &mut ctx);
        ctx.retire(out);
    });
    let fused_t = run(&fused, &mut ctx);
    let (s_ms, f_ms) = (separate.secs() * 1e3, fused_t.secs() * 1e3);
    let speedup = s_ms / f_ms.max(1e-9);
    let unfused_bytes = conv_memory_bytes(ConvAlgo::DirectFused, &d, pool.workers());
    let fused_bytes = conv_pool_fused_memory_bytes(&d, p, pool.workers());
    let mut table = Table::new(&["conv→pool pair", "patch ms", "model bytes"]);
    table.row(vec![
        "separate (conv+pool)".into(),
        format!("{s_ms:.3}"),
        znni::util::human_bytes(unfused_bytes),
    ]);
    table.row(vec![
        "fused (DirectFP)".into(),
        format!("{f_ms:.3}"),
        znni::util::human_bytes(fused_bytes),
    ]);
    table.row(vec!["speedup".into(), format!("{speedup:.2}×"), String::new()]);
    table.print();
    doc.push((
        "pair".into(),
        Json::Object(vec![
            ("separate_secs".into(), Json::Num(separate.secs())),
            ("fused_secs".into(), Json::Num(fused_t.secs())),
            ("speedup".into(), Json::Num(speedup)),
            ("unfused_model_bytes".into(), Json::Num(unfused_bytes as f64)),
            ("fused_model_bytes".into(), Json::Num(fused_bytes as f64)),
        ]),
    ));

    let path =
        std::env::var("ZNNI_BENCH_OUT").unwrap_or_else(|_| "../BENCH_direct_fused.json".into());
    match std::fs::write(&path, Json::Object(doc).to_pretty_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
