//! Table I: input/output shape relations and FLOP counts. Verifies the
//! shape algebra and checks measured time scales with the analytic
//! FLOPs (time/FLOPs roughly constant per algorithm).

use std::sync::Arc;
use std::time::Duration;

use znni::conv::{conv_out_shape, Activation, Weights};
use znni::layers::{ConvLayer, LayerPrimitive};
use znni::memory::model::{ConvAlgo, ConvDims};
use znni::pool::{max_pool_out_shape, mpf_out_shape};
use znni::tensor::{Shape5, Tensor5};
use znni::util::bench::{time_budget, Table};
use znni::util::pool::TaskPool;

fn main() {
    println!("== Table I: shapes ==");
    let mut t = Table::new(&["layer", "input", "output", "FLOPs"]);
    let sh = Shape5::new(2, 4, 16, 16, 16);
    let d = ConvDims { s: 2, f_in: 4, f_out: 8, n: [16; 3], k: [3; 3] };
    t.row(vec![
        "Conv direct".into(),
        sh.to_string(),
        conv_out_shape(sh, 8, [3; 3]).to_string(),
        format!("{:.2e}", d.direct_flops()),
    ]);
    t.row(vec![
        "Conv FFT".into(),
        sh.to_string(),
        conv_out_shape(sh, 8, [3; 3]).to_string(),
        format!("{:.2e}", d.fft_flops()),
    ]);
    t.row(vec![
        "Max pooling".into(),
        sh.to_string(),
        max_pool_out_shape(sh, [2; 3]).to_string(),
        format!("{:.2e}", sh.len() as f64),
    ]);
    let msh = Shape5::new(2, 4, 15, 15, 15);
    t.row(vec![
        "Max frag pooling".into(),
        msh.to_string(),
        mpf_out_shape(msh, [2; 3]).to_string(),
        format!("{:.2e}", msh.len() as f64 * 8.0),
    ]);
    t.print();

    println!("\n== time ∝ FLOPs check (GFLOP/s should be ~flat per algo) ==");
    let pool = TaskPool::global();
    let mut ctx = znni::exec::ExecCtx::new(pool);
    let mut t2 = Table::new(&["algo", "n", "FLOPs", "ms", "GFLOP/s"]);
    let budget = Duration::from_millis(400);
    for algo in [ConvAlgo::DirectMkl, ConvAlgo::FftTaskParallel] {
        for &n in &[10usize, 14, 18, 24] {
            let w = Arc::new(Weights::random(4, 4, [3; 3], 5));
            let layer = ConvLayer::new(w, algo, Activation::Relu);
            let sh = Shape5::new(1, 4, n, n, n);
            let flops = layer.flops(sh);
            let s = time_budget(budget, || {
                let inp = Tensor5::random(sh, 3);
                std::hint::black_box(layer.execute(inp, &mut ctx));
            });
            t2.row(vec![
                algo.tag().into(),
                format!("{n}"),
                format!("{flops:.2e}"),
                format!("{:.2}", s.secs() * 1e3),
                format!("{:.2}", flops / s.secs() / 1e9),
            ]);
        }
    }
    t2.print();
}
