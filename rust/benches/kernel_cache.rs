//! Cached vs recomputed kernel spectra, warm-path patch time per FFT
//! family (ISSUE 5).
//!
//! Runs one conv layer per FFT family (FFT-DP, FFT-TP, GPU-FFT) two
//! ways through the *same* warm `ExecCtx`:
//!
//! * **recompute** — the pre-cache behaviour: every execute
//!   forward-transforms all `f'·f` kernels again;
//! * **cached** — the layer's [`znni::conv::precomp::PrecomputedKernels`]
//!   is built once up front (as `CompiledPlan::warm_kernel_caches`
//!   would) and every execute reads the resident spectra.
//!
//! Both paths are warmed before timing, so the numbers compare
//! steady-state patch time — the regime the optimizer's
//! `conv_secs_cached` models when it drops the kernel-transform FLOPs.
//!
//! Results go to stdout and `BENCH_kernel_cache.json` (default
//! `../BENCH_kernel_cache.json`, i.e. the repository root when run via
//! `cargo bench --bench kernel_cache`; override with `ZNNI_BENCH_OUT`).

use std::sync::Arc;
use std::time::Duration;

use znni::conv::precomp::{force_cache_mode, CacheMode};
use znni::conv::{Activation, Weights};
use znni::exec::ExecCtx;
use znni::layers::{ConvLayer, LayerPrimitive};
use znni::memory::model::ConvAlgo;
use znni::tensor::{Shape5, Tensor5};
use znni::util::bench::{time_budget, Scale, Table};
use znni::util::json::Json;
use znni::util::pool::TaskPool;

fn main() {
    let pool = TaskPool::global();
    let scale = Scale::from_env();
    let (n, f) = match scale {
        Scale::Paper => (48usize, 16usize),
        Scale::Small => (20, 8),
        Scale::Tiny => (10, 4),
    };
    let budget = match scale {
        Scale::Paper => Duration::from_millis(1500),
        Scale::Small => Duration::from_millis(600),
        Scale::Tiny => Duration::from_millis(250),
    };
    // The bench *is* the cache measurement — pin the mode so an
    // inherited ZNNI_KERNEL_CACHE=off cannot silently turn the cached
    // column into a second recompute column.
    force_cache_mode(Some(CacheMode::Force));
    let sh = Shape5::new(1, f, n, n, n);
    println!("== Kernel-spectra cache: {n}³ patches, f=f'={f}, k=3³ ==");

    let mut table = Table::new(&["family", "recompute ms", "cached ms", "speedup", "cache bytes"]);
    let mut doc: Vec<(String, Json)> = vec![
        ("scale".into(), Json::Str(format!("{scale:?}"))),
        ("extent".into(), Json::Num(n as f64)),
        ("maps".into(), Json::Num(f as f64)),
        ("workers".into(), Json::Num(pool.workers() as f64)),
    ];
    for algo in [ConvAlgo::FftDataParallel, ConvAlgo::FftTaskParallel, ConvAlgo::GpuFft] {
        let w = Arc::new(Weights::random(f, f, [3, 3, 3], 0xCACE));
        let plain = ConvLayer::new(w.clone(), algo, Activation::Relu);
        let cached = ConvLayer::new(w, algo, Activation::Relu).with_kernel_cache(true);
        cached.warm(sh, pool); // build spectra outside the timed region

        let mut ctx = ExecCtx::new(pool);
        // Warm the arena + FFT plan cache on both paths before timing.
        for layer in [&plain, &cached] {
            let out = layer.execute(Tensor5::random(sh, 1), &mut ctx);
            ctx.retire(out);
        }
        // The input is generated once; each timed iteration only copies
        // it into an arena-recycled tensor (execute consumes its
        // input), so RNG cost does not dilute the cached-vs-recompute
        // ratio — the columns compare conv time, not input synthesis.
        let base = Tensor5::random(sh, 3);
        let mut run = |layer: &ConvLayer| {
            time_budget(budget, || {
                let mut t = ctx.tensor5(sh);
                t.data_mut().copy_from_slice(base.data());
                let out = layer.execute(t, &mut ctx);
                ctx.retire(out);
            })
        };
        let recompute = run(&plain);
        let cached_t = run(&cached);

        let (r_ms, c_ms) = (recompute.secs() * 1e3, cached_t.secs() * 1e3);
        let speedup = r_ms / c_ms.max(1e-9);
        let bytes = cached.kernel_cache_bytes();
        table.row(vec![
            algo.name().to_string(),
            format!("{r_ms:.3}"),
            format!("{c_ms:.3}"),
            format!("{speedup:.2}×"),
            znni::util::human_bytes(bytes),
        ]);
        doc.push((
            algo.tag().to_string(),
            Json::Object(vec![
                ("recompute_secs".into(), Json::Num(recompute.secs())),
                ("cached_secs".into(), Json::Num(cached_t.secs())),
                ("speedup".into(), Json::Num(speedup)),
                ("cache_bytes".into(), Json::Num(bytes as f64)),
            ]),
        ));
    }
    table.print();
    force_cache_mode(None);

    let path =
        std::env::var("ZNNI_BENCH_OUT").unwrap_or_else(|_| "../BENCH_kernel_cache.json".into());
    match std::fs::write(&path, Json::Object(doc).to_pretty_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
