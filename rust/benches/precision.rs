//! Reduced-precision storage (f16 / bf16) vs the f32 baseline under a
//! tight memory budget (ISSUE 9).
//!
//! The paper's thesis is that throughput is RAM-bound (§V): halving the
//! bytes at rest buys either twice the resident kernel spectra or a
//! bigger patch under the same Table II budget. This bench makes that
//! trade visible end to end. It first finds a roomy f32 plan for
//! `tiny_net` at 4 GiB, then re-runs the optimizer search under *half*
//! that plan's memory for each `ZNNI_PRECISION` mode (`f32`, `f16`,
//! `bf16`, `auto`) and reports, per mode:
//!
//! * the achievable patch extent the search settles on,
//! * the resident kernel-spectra row (halved by the half formats),
//! * the plan's estimated memory, and
//! * measured warm throughput (output voxels/s) through the compiled
//!   plan — including the real widen/narrow conversion cost the
//!   optimizer only models.
//!
//! Results go to stdout and `BENCH_precision.json` (default
//! `../BENCH_precision.json`, i.e. the repository root when run via
//! `cargo bench --bench precision`; override with `ZNNI_BENCH_OUT`).

use std::time::Duration;

use znni::conv::precomp::{force_cache_mode, CacheMode};
use znni::device::Device;
use znni::exec::ExecCtx;
use znni::memory::model::ConvAlgo;
use znni::net::zoo::tiny_net;
use znni::optimizer::{compile, make_weights, search, CostModel, SearchSpace};
use znni::precision::{force_precision_mode, PrecisionMode};
use znni::util::bench::{time_budget, Scale, Table};
use znni::util::json::Json;
use znni::util::pool::TaskPool;

fn main() {
    let pool = TaskPool::global();
    let scale = Scale::from_env();
    let max_extent = match scale {
        Scale::Paper => 33usize,
        Scale::Small => 21,
        Scale::Tiny => 15,
    };
    let budget = match scale {
        Scale::Paper => Duration::from_millis(1500),
        Scale::Small => Duration::from_millis(600),
        Scale::Tiny => Duration::from_millis(250),
    };
    // Pin the cache mode: the resident-row comparison is the point of
    // this bench, so an inherited ZNNI_KERNEL_CACHE=off must not
    // silently zero the column. Precision itself is forced per mode
    // below.
    force_cache_mode(Some(CacheMode::Auto));

    let net = tiny_net(2);
    let cm = CostModel::default_rates(pool.workers());
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), max_extent);
    space.algos = vec![ConvAlgo::FftTaskParallel];
    space.max_candidates = 1;

    // Roomy f32 reference: at 4 GiB the budget is not binding, so this
    // fixes the extent ceiling the tight searches are squeezed from.
    force_precision_mode(Some(PrecisionMode::F32));
    let roomy = search(&net, &space, &cm).expect("4 GiB must admit tiny_net");
    let tight_ram = roomy.est_memory / 2;
    let mut tight = SearchSpace::cpu_only(Device::host_with_ram(tight_ram), max_extent);
    tight.algos = vec![ConvAlgo::FftTaskParallel];
    tight.max_candidates = 1;

    println!(
        "== Reduced-precision storage: {} under {} (half of the roomy f32 plan's {}) ==",
        net.name,
        znni::util::human_bytes(tight_ram),
        znni::util::human_bytes(roomy.est_memory),
    );

    let mut table =
        Table::new(&["mode", "extent", "resident row", "est memory", "warm ms", "Mvox/s"]);
    let mut doc: Vec<(String, Json)> = vec![
        ("scale".into(), Json::Str(format!("{scale:?}"))),
        ("workers".into(), Json::Num(pool.workers() as f64)),
        ("max_extent".into(), Json::Num(max_extent as f64)),
        ("roomy_extent".into(), Json::Num(roomy.input.x as f64)),
        ("roomy_est_memory".into(), Json::Num(roomy.est_memory as f64)),
        ("tight_ram".into(), Json::Num(tight_ram as f64)),
    ];
    let weights = make_weights(&net, 0x9C);
    for (mode, tag) in [
        (PrecisionMode::F32, "f32"),
        (PrecisionMode::F16, "f16"),
        (PrecisionMode::Bf16, "bf16"),
        (PrecisionMode::Auto, "auto"),
    ] {
        force_precision_mode(Some(mode));
        let plan = search(&net, &tight, &cm)
            .unwrap_or_else(|| panic!("{tag}: tight budget must stay feasible"));
        let cp = compile(&net, &plan, &weights).expect("searched plan compiles");

        // Warm throughput through the compiled plan: cache build, arena
        // growth and FFT planning all happen before the timed region,
        // so the columns compare steady-state patch time — conversion
        // cost included.
        let mut ctx = ExecCtx::new(pool);
        let base = znni::tensor::Tensor5::random(plan.input, 3);
        let out = cp.run(base.clone_tensor(), &mut ctx);
        ctx.retire(out);
        let timing = time_budget(budget, || {
            let mut t = ctx.tensor5(plan.input);
            t.data_mut().copy_from_slice(base.data());
            let out = cp.run(t, &mut ctx);
            ctx.retire(out);
        });

        let secs = timing.secs();
        let vox_per_s = plan.out_voxels as f64 / secs.max(1e-9);
        let resident = cp.kernel_cache_bytes();
        table.row(vec![
            tag.to_string(),
            plan.input.x.to_string(),
            znni::util::human_bytes(resident),
            znni::util::human_bytes(plan.est_memory),
            format!("{:.3}", secs * 1e3),
            format!("{:.3}", vox_per_s / 1e6),
        ]);
        doc.push((
            tag.to_string(),
            Json::Object(vec![
                ("extent".into(), Json::Num(plan.input.x as f64)),
                ("resident_bytes".into(), Json::Num(resident as f64)),
                ("est_memory".into(), Json::Num(plan.est_memory as f64)),
                ("warm_secs".into(), Json::Num(secs)),
                ("vox_per_s".into(), Json::Num(vox_per_s)),
            ]),
        ));
    }
    table.print();
    force_precision_mode(None);
    force_cache_mode(None);

    let path =
        std::env::var("ZNNI_BENCH_OUT").unwrap_or_else(|_| "../BENCH_precision.json".into());
    match std::fs::write(&path, Json::Object(doc).to_pretty_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
