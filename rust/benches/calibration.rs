//! Measured-autotuner bench: run the full calibration ladder and
//! record what the measurement changes (ISSUE 4).
//!
//! Runs `CostModel::calibrate_full_report`, prints the fitted rate per
//! algorithm family plus the measured per-batch dispatch overhead, and
//! compares `optimizer::search_serving`'s chosen serving config under
//! the measured model vs the static defaults.
//!
//! Results go to stdout and `BENCH_calibration.json` (default
//! `../BENCH_calibration.json`, i.e. the repository root when run via
//! `cargo bench --bench calibration`; override with `ZNNI_BENCH_OUT`).

use znni::device::Device;
use znni::net::zoo::tiny_net;
use znni::optimizer::cost::DEFAULT_DISPATCH_OVERHEAD_SECS;
use znni::optimizer::{search_serving, CostModel, SearchSpace};
use znni::server::ServingLoad;
use znni::util::bench::{Scale, Table};
use znni::util::json::Json;
use znni::util::pool::TaskPool;

fn main() {
    let pool = TaskPool::global();
    let scale = Scale::from_env();
    let ladder: Vec<usize> = match scale {
        Scale::Paper => vec![16, 24, 32, 48],
        Scale::Small => vec![8, 12, 16],
        Scale::Tiny => vec![6, 8],
    };
    println!("== Calibration ladder {ladder:?} on {} workers ==", pool.workers());
    let (cm, report) = CostModel::calibrate_full_report(pool, &ladder);

    let host = Device::host_with_ram(8 << 30);
    let mut table = Table::new(&["algorithm", "fitted rate", "probes"]);
    let mut rates_json: Vec<(String, Json)> = Vec::new();
    for (algo, samples) in &report.conv {
        let fitted = cm.rate(*algo, &host);
        table.row(vec![
            algo.name().to_string(),
            format!("{fitted:.3e} FLOP/s"),
            samples
                .iter()
                .map(|s| format!("{}^3:{:.2e}/s", s.extent, s.rate()))
                .collect::<Vec<_>>()
                .join(" "),
        ]);
        rates_json.push((algo.tag().to_string(), Json::Num(fitted)));
    }
    table.row(vec![
        "MPF pooling".to_string(),
        format!("{:.3e} vox/s", cm.pool_rate),
        report
            .pool
            .iter()
            .map(|s| format!("{}^3:{:.2e}/s", s.extent, s.rate()))
            .collect::<Vec<_>>()
            .join(" "),
    ]);
    table.print();
    println!(
        "dispatch overhead: {:.1} us/batch measured (default assumption {:.0} us)",
        report.dispatch_overhead_secs * 1e6,
        DEFAULT_DISPATCH_OVERHEAD_SECS * 1e6,
    );

    // Serving-config deltas: measured model vs static defaults.
    let net = tiny_net(4);
    let load = ServingLoad { clients: 8, volume_extent: 32 };
    let space = SearchSpace::cpu_only(host.clone(), 23);
    let defaults = CostModel::default_rates(pool.workers());
    let d_cfg = search_serving(&net, &space, &defaults, &load).map(|(_, c)| c);
    let m_cfg = search_serving(&net, &space, &cm, &load).map(|(_, c)| c);
    for (label, cfg) in [("default", &d_cfg), ("measured", &m_cfg)] {
        match cfg {
            Some(c) => println!(
                "{label:>8}: shards={} queue_depth={} max_batch={} batch_wait={:?}",
                c.shards, c.queue_depth, c.max_batch_requests, c.max_batch_wait
            ),
            None => println!("{label:>8}: no feasible config"),
        }
    }

    let doc = Json::Object(vec![
        ("scale".into(), Json::Str(format!("{scale:?}"))),
        ("workers".into(), Json::Num(pool.workers() as f64)),
        (
            "ladder".into(),
            Json::Array(ladder.iter().map(|&e| Json::Num(e as f64)).collect()),
        ),
        ("rates_flops_per_sec".into(), Json::Object(rates_json)),
        ("pool_rate_voxels_per_sec".into(), Json::Num(cm.pool_rate)),
        ("dispatch_overhead_secs".into(), Json::Num(report.dispatch_overhead_secs)),
        (
            "default_dispatch_overhead_secs".into(),
            Json::Num(DEFAULT_DISPATCH_OVERHEAD_SECS),
        ),
        // 0 = no feasible config (never `null`: the CI artifact check
        // greps the emitted JSONs for unpopulated fields).
        (
            "serving_shards_default".into(),
            Json::Num(d_cfg.as_ref().map(|c| c.shards as f64).unwrap_or(0.0)),
        ),
        (
            "serving_shards_measured".into(),
            Json::Num(m_cfg.as_ref().map(|c| c.shards as f64).unwrap_or(0.0)),
        ),
    ]);
    let path =
        std::env::var("ZNNI_BENCH_OUT").unwrap_or_else(|_| "../BENCH_calibration.json".into());
    match std::fs::write(&path, doc.to_pretty_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
