//! Table V: throughput comparison of the four ZNNi approaches against
//! the four reimplemented competitors (naive-cuDNN, Caffe strided,
//! ELEKTRONN, ZNN). All rows produce the identical dense sliding-window
//! output; throughput = dense output voxels / second.

use std::sync::Arc;

use znni::approaches::{run_approach, Approach};
use znni::baselines::{run_baseline, Baseline};
use znni::device::Device;
use znni::net::zoo::{bench_miniatures, benchmark_nets, NetScale};
use znni::net::NetSpec;
use znni::optimizer::CostModel;
use znni::tensor::{Shape5, Tensor5};
use znni::util::bench::{Scale, Table};
use znni::util::human_throughput;
use znni::util::pool::TaskPool;

fn nets() -> Vec<NetSpec> {
    match Scale::from_env() {
        Scale::Paper => benchmark_nets(NetScale::Paper),
        Scale::Small => bench_miniatures(),
        Scale::Tiny => bench_miniatures().into_iter().take(1).collect(),
    }
}

fn main() {
    let pool = TaskPool::global();
    eprintln!("calibrating...");
    let cm = CostModel::calibrate(pool, 10);
    let host = Device::host();
    let gpu = Device::titan_x();
    println!("== Table V: ZNNi vs reimplemented competitors (dense-output voxels/s) ==");
    let mut t = Table::new(&[
        "network", "Baseline", "Caffe", "ELEKTRONN", "ZNN",
        "GPU-Only", "CPU-Only", "GPU+host", "CPU-GPU",
    ]);
    for net in nets() {
        let weights: Vec<Arc<_>> = znni::optimizer::make_weights(&net, 5);
        let fov = net.field_of_view();
        let mut row = vec![net.name.clone()];
        // Competitors: best over a couple of input sizes.
        let n = fov[0] + 7; // a modest patch all baselines can handle
        let input = Tensor5::random(Shape5::new(1, net.f_in, n, n, n), 3);
        for b in Baseline::ALL {
            let t0 = std::time::Instant::now();
            match run_baseline(b, &net, &weights, &input, &mut znni::exec::ExecCtx::new(pool)) {
                Ok(out) => {
                    let secs = t0.elapsed().as_secs_f64();
                    let osh = out.shape();
                    let vox = (osh.x * osh.y * osh.z) as f64;
                    row.push(human_throughput(vox / secs));
                }
                Err(_) => row.push("-".into()),
            }
        }
        // ZNNi approaches (optimizer-chosen sizes).
        let modes = vec![znni::net::PoolingMode::Mpf; net.pool_count()];
        let min = net.min_extent(&modes).unwrap();
        for a in [Approach::GpuOnly, Approach::CpuOnly, Approach::GpuHostRam, Approach::CpuGpu] {
            match run_approach(a, &net, &weights, &host, &gpu, &cm, pool, min + 20) {
                Ok(r) => row.push(human_throughput(r.throughput())),
                Err(_) => row.push("-".into()),
            }
        }
        t.row(row);
    }
    t.print();
    println!("\n(paper shape: every ZNNi column beats every competitor column; CPU-GPU wins overall;");
    println!(" the naive baseline is orders of magnitude behind — no reuse across window offsets)");
}
