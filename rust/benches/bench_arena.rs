//! Warm-ctx vs alloc-per-call microbench for the arena-backed
//! execution contexts (ISSUE 2).
//!
//! Runs one task-parallel FFT conv layer (the paper's flagship CPU
//! primitive and the heaviest allocator customer: input spectra, output
//! spectra, per-chip primary buffers, the output tensor) two ways:
//!
//! * **alloc-per-call** — a fresh `ExecCtx` per execute, so every
//!   spectrum/workspace/output is a fresh heap allocation (the
//!   pre-arena behaviour);
//! * **warm-ctx** — one `ExecCtx` reused across calls; after the first
//!   call every take hits the arena free lists.
//!
//! Results go to stdout and `BENCH_arena.json` (default
//! `../BENCH_arena.json`, i.e. the repository root when run via
//! `cargo bench --bench bench_arena`; override with `ZNNI_BENCH_OUT`).

use std::time::Duration;

use znni::conv::{fft_tp::conv_fft_tp, Activation, Weights};
use znni::exec::ExecCtx;
use znni::tensor::{Shape5, Tensor5};
use znni::util::bench::{time_budget, Scale, Table};
use znni::util::pool::TaskPool;

fn main() {
    let pool = TaskPool::global();
    let scale = Scale::from_env();
    let (n, f, s) = match scale {
        Scale::Paper => (48usize, 16usize, 2usize),
        Scale::Small => (24, 8, 1),
        Scale::Tiny => (12, 4, 1),
    };
    let budget = match scale {
        Scale::Paper => Duration::from_millis(1500),
        Scale::Small => Duration::from_millis(700),
        Scale::Tiny => Duration::from_millis(300),
    };
    let sh = Shape5::new(s, f, n, n, n);
    let w = Weights::random(f, f, [3, 3, 3], 7);
    println!("== Arena microbench: fft_tp layer {n}³, f=f'={f}, S={s} ==");

    // Alloc-per-call: cold context every execute.
    let cold = time_budget(budget, || {
        let mut ctx = ExecCtx::new(pool);
        let t = Tensor5::random(sh, 3);
        let out = conv_fft_tp(t, &w, Activation::Relu, &mut ctx);
        std::hint::black_box(&out);
    });

    // Warm context: one arena for the whole stream.
    let mut ctx = ExecCtx::new(pool);
    let warm = time_budget(budget, || {
        let t = Tensor5::random(sh, 3);
        let out = conv_fft_tp(t, &w, Activation::Relu, &mut ctx);
        ctx.retire(out);
    });
    let stats = ctx.arena.stats();

    let cold_ms = cold.secs() * 1e3;
    let warm_ms = warm.secs() * 1e3;
    let mut table = Table::new(&["mode", "ms/layer", "speedup", "arena fresh", "arena reuses"]);
    table.row(vec![
        "alloc-per-call".into(),
        format!("{cold_ms:.2}"),
        "1.00×".into(),
        "-".into(),
        "-".into(),
    ]);
    table.row(vec![
        "warm-ctx".into(),
        format!("{warm_ms:.2}"),
        format!("{:.2}×", cold_ms / warm_ms.max(1e-9)),
        stats.fresh_allocs.to_string(),
        stats.reuses.to_string(),
    ]);
    table.print();
    println!(
        "arena hwm {} (held {} / outstanding {})",
        znni::util::human_bytes(stats.hwm_bytes),
        znni::util::human_bytes(stats.held_bytes),
        znni::util::human_bytes(stats.outstanding_bytes),
    );

    let path = std::env::var("ZNNI_BENCH_OUT").unwrap_or_else(|_| "../BENCH_arena.json".into());
    let json = format!(
        "{{\n  \"scale\": \"{:?}\",\n  \"layer\": \"fft_tp {n}^3 f={f} S={s}\",\n  \"alloc_per_call_ms\": {:.3},\n  \"warm_ctx_ms\": {:.3},\n  \"speedup\": {:.3},\n  \"arena_fresh_allocs\": {},\n  \"arena_reuses\": {},\n  \"arena_hwm_bytes\": {}\n}}\n",
        scale,
        cold_ms,
        warm_ms,
        cold_ms / warm_ms.max(1e-9),
        stats.fresh_allocs,
        stats.reuses,
        stats.hwm_bytes,
    );
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
