//! Table II: memory required by each implementation — analytic model vs
//! measured peak from the allocation ledger.

use std::sync::Arc;

use znni::conv::{Activation, Weights};
use znni::layers::{ConvLayer, LayerPrimitive};
use znni::memory::model::{conv_memory_bytes, ConvAlgo, ConvDims};
use znni::tensor::{Shape5, Tensor5};
use znni::util::bench::Table;
use znni::util::human_bytes;
use znni::util::pool::TaskPool;

fn main() {
    let pool = TaskPool::global();
    println!("== Table II: memory model vs measured peak ==");
    let mut t = Table::new(&["algorithm", "model", "measured", "measured/model"]);
    let d = ConvDims { s: 2, f_in: 6, f_out: 6, n: [18; 3], k: [3; 3] };
    let sh = Shape5::from_spatial(d.s, d.f_in, d.n);
    for algo in ConvAlgo::ALL {
        let w = Arc::new(Weights::random(d.f_out, d.f_in, d.k, 3));
        let layer = ConvLayer::new(w, algo, Activation::Relu);
        let model = conv_memory_bytes(algo, &d, pool.workers());
        let input = Tensor5::random(sh, 5);
        let in_bytes = sh.bytes_f32();
        // Cold context per measurement so arena takes register like the
        // direct allocations they replaced.
        let (_out, peak) = znni::memory::measure(|| {
            let mut ctx = znni::exec::ExecCtx::new(pool);
            layer.execute(input, &mut ctx)
        });
        let measured = peak + in_bytes;
        t.row(vec![
            algo.name().into(),
            human_bytes(model).to_string(),
            human_bytes(measured).to_string(),
            format!("{:.2}", measured as f64 / model as f64),
        ]);
    }
    t.print();
    println!("(model must upper-bound measured; GPU-FFT model includes the K scratch constant)");
}
