//! Baseline comparators for Table V (§VIII).
//!
//! Each reimplements the *algorithm* of a publicly available competitor
//! on our substrate, so the comparison isolates algorithmic choices:
//!
//! * **Baseline (cuDNN)** — the naive approach: compute every
//!   subsampling offset of each max-pooling layer separately with plain
//!   pooling (no reuse across offsets). Dense conv + max-pool.
//! * **Caffe (strided kernels)** — dense convolution with *dilated*
//!   kernels after each pooling (Tschopp 2015): no batch blow-up, but a
//!   training-oriented memory profile (keeps every intermediate, as the
//!   paper observed it could only run the smallest net).
//! * **ELEKTRONN** — MPF pooling like ZNNi, but convolution fixed to
//!   the dense (cuDNN-style) primitive.
//! * **ZNN** — max-filtering + FFT-based sparse (dilated) convolution
//!   on the CPU (Zlateski et al. 2015): dense sliding-window semantics
//!   with kernels dilated by the cumulative pooling stride.

use crate::conv::{Activation, Weights};
use crate::exec::ExecCtx;
use crate::net::{LayerSpec, NetSpec, PoolingMode};
use crate::tensor::{Shape5, Tensor5, Vec3};
use crate::util::pool::TaskPool;
use crate::util::sendptr::SendPtr;

/// Which baseline algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Baseline {
    /// Per-patch cuDNN-style dense conv, no reuse (Table V).
    NaiveCudnn,
    /// Caffe-style strided patching.
    CaffeStrided,
    /// ELEKTRONN-style dense inference.
    Elektronn,
    /// ZNN FFT-based CPU inference.
    Znn,
}

impl Baseline {
    /// All baselines, in Table V order.
    pub const ALL: [Baseline; 4] =
        [Baseline::NaiveCudnn, Baseline::CaffeStrided, Baseline::Elektronn, Baseline::Znn];

    /// Display name (Table V row).
    pub fn name(&self) -> &'static str {
        match self {
            Baseline::NaiveCudnn => "Baseline (cuDNN)",
            Baseline::CaffeStrided => "Caffe",
            Baseline::Elektronn => "ELEKTRONN",
            Baseline::Znn => "ZNN",
        }
    }
}

/// Max-filtering: sliding max with window p, stride 1 (ZNN's pooling).
/// Output extent n − p + 1.
pub fn max_filter(input: &Tensor5, p: Vec3, pool: &TaskPool) -> Tensor5 {
    let ish = input.shape();
    let osh = Shape5 {
        x: ish.x - p[0] + 1,
        y: ish.y - p[1] + 1,
        z: ish.z - p[2] + 1,
        ..ish
    };
    let mut out = Tensor5::zeros(osh);
    let outp = SendPtr(out.data_mut().as_mut_ptr());
    let ol = osh.image_len();
    pool.parallel_for(ish.s * ish.f, |sf| {
        let (s, f) = (sf / ish.f, sf % ish.f);
        let img = input.image(s, f);
        let o = unsafe { outp.slice_mut(osh.image_offset(s, f), ol) };
        for x in 0..osh.x {
            for y in 0..osh.y {
                for z in 0..osh.z {
                    let mut m = f32::NEG_INFINITY;
                    for a in 0..p[0] {
                        for b in 0..p[1] {
                            let row = ((x + a) * ish.y + (y + b)) * ish.z + z;
                            for c in 0..p[2] {
                                m = m.max(img[row + c]);
                            }
                        }
                    }
                    o[(x * osh.y + y) * osh.z + z] = m;
                }
            }
        }
    });
    out
}

/// Dilate a weight set by `d` (insert d−1 zeros between taps): the
/// "strided kernels" / "sparse convolution" of Caffe and ZNN.
pub fn dilate_weights(w: &Weights, d: Vec3) -> Weights {
    let nk = [
        (w.k[0] - 1) * d[0] + 1,
        (w.k[1] - 1) * d[1] + 1,
        (w.k[2] - 1) * d[2] + 1,
    ];
    let mut out = Weights::zeros(w.f_out, w.f_in, nk);
    for j in 0..w.f_out {
        for i in 0..w.f_in {
            let src = w.kernel(j, i);
            let dst = out.kernel_mut(j, i);
            for a in 0..w.k[0] {
                for b in 0..w.k[1] {
                    for c in 0..w.k[2] {
                        dst[((a * d[0]) * nk[1] + b * d[1]) * nk[2] + c * d[2]] =
                            src[(a * w.k[1] + b) * w.k[2] + c];
                    }
                }
            }
        }
        out.set_bias(j, w.bias(j));
    }
    out
}

/// Run a baseline over one input patch, returning the *dense*
/// sliding-window output (extent n − FoV + 1 per dim) so all baselines
/// and ZNNi modes are compared on identical work.
pub fn run_baseline(
    b: Baseline,
    net: &NetSpec,
    weights: &[std::sync::Arc<Weights>],
    input: &Tensor5,
    ctx: &mut ExecCtx<'_>,
) -> anyhow::Result<Tensor5> {
    match b {
        Baseline::NaiveCudnn => run_naive_subsampling(net, weights, input, ctx),
        Baseline::CaffeStrided | Baseline::Znn => run_dilated(b, net, weights, input, ctx),
        Baseline::Elektronn => run_elektronn(net, weights, input, ctx),
    }
}

/// Naive: for every combined pooling offset, run the plain max-pool net
/// on the shifted input, then interleave — no reuse across offsets.
fn run_naive_subsampling(
    net: &NetSpec,
    weights: &[std::sync::Arc<Weights>],
    input: &Tensor5,
    ctx: &mut ExecCtx<'_>,
) -> anyhow::Result<Tensor5> {
    let ish = input.shape();
    let fov = net.field_of_view();
    let stride = net.total_stride();
    let odims = [ish.x - fov[0] + 1, ish.y - fov[1] + 1, ish.z - fov[2] + 1];
    let mut out = Tensor5::zeros(Shape5::from_spatial(1, net.f_out(), odims));
    // For each offset, crop the largest shifted sub-volume whose sizes
    // satisfy the max-pool divisibility, run, and scatter at stride.
    for ox in 0..stride[0] {
        for oy in 0..stride[1] {
            for oz in 0..stride[2] {
                let off = [ox, oy, oz];
                // positions covered: off + stride·t < odims
                let cnt = [
                    (odims[0] + stride[0] - 1 - off[0]) / stride[0],
                    (odims[1] + stride[1] - 1 - off[1]) / stride[1],
                    (odims[2] + stride[2] - 1 - off[2]) / stride[2],
                ];
                if cnt.iter().any(|&c| c == 0) {
                    continue;
                }
                // input region needed: fov + (cnt-1)*stride per dim
                let idims = [
                    fov[0] + (cnt[0] - 1) * stride[0],
                    fov[1] + (cnt[1] - 1) * stride[1],
                    fov[2] + (cnt[2] - 1) * stride[2],
                ];
                let mut sub = Tensor5::zeros(Shape5::from_spatial(1, ish.f, idims));
                for f in 0..ish.f {
                    for x in 0..idims[0] {
                        for y in 0..idims[1] {
                            for z in 0..idims[2] {
                                sub.set(0, f, x, y, z, input.at(0, f, ox + x, oy + y, oz + z));
                            }
                        }
                    }
                }
                let res = forward_plain(net, weights, sub, PoolingMode::MaxPool, ctx)?;
                let rsh = res.shape();
                debug_assert_eq!([rsh.x, rsh.y, rsh.z], cnt);
                for f in 0..rsh.f {
                    for x in 0..rsh.x {
                        for y in 0..rsh.y {
                            for z in 0..rsh.z {
                                out.set(
                                    0,
                                    f,
                                    off[0] + stride[0] * x,
                                    off[1] + stride[1] * y,
                                    off[2] + stride[2] * z,
                                    res.at(0, f, x, y, z),
                                );
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Plain forward with uniform pooling mode and dense direct conv.
fn forward_plain(
    net: &NetSpec,
    weights: &[std::sync::Arc<Weights>],
    input: Tensor5,
    mode: PoolingMode,
    ctx: &mut ExecCtx<'_>,
) -> anyhow::Result<Tensor5> {
    let mut cur = input;
    let mut wi = 0;
    for l in &net.layers {
        let out = match l {
            LayerSpec::Conv { .. } => {
                let w = &weights[wi];
                wi += 1;
                crate::conv::direct::conv_direct_mkl(&cur, w, Activation::Relu, ctx)
            }
            LayerSpec::Pool { p } => match mode {
                PoolingMode::MaxPool => crate::pool::max_pool(&cur, *p, ctx),
                PoolingMode::Mpf => crate::pool::mpf_forward(&cur, *p, ctx),
            },
        };
        ctx.retire(cur);
        cur = out;
    }
    Ok(cur)
}

/// Dilated-kernel dense network (Caffe "strided kernels" / ZNN "sparse
/// convolution"): pooling becomes max-filtering (stride 1) and every
/// subsequent kernel is dilated by the cumulative pooling factor. The
/// output is dense directly. Caffe uses dense direct convolution; ZNN
/// uses the FFT-based primitive for the (dilated) convolutions.
fn run_dilated(
    b: Baseline,
    net: &NetSpec,
    weights: &[std::sync::Arc<Weights>],
    input: &Tensor5,
    ctx: &mut ExecCtx<'_>,
) -> anyhow::Result<Tensor5> {
    let mut cur = input.clone_tensor();
    let mut dil: Vec3 = [1, 1, 1];
    let mut wi = 0;
    for l in &net.layers {
        cur = match l {
            LayerSpec::Conv { .. } => {
                let w = dilate_weights(&weights[wi], dil);
                wi += 1;
                match b {
                    // ZNN: FFT-based sparse convolution. The dilated
                    // kernel's zero taps cost nothing in the spectrum
                    // product; the pruned FFT skips their lines.
                    Baseline::Znn => {
                        crate::conv::fft_tp::conv_fft_tp(cur, &w, Activation::Relu, ctx)
                    }
                    // Caffe: dense direct convolution of the dilated
                    // kernel (zero taps skipped in the inner loop).
                    _ => {
                        let out =
                            crate::conv::direct::conv_direct_mkl(&cur, &w, Activation::Relu, ctx);
                        ctx.retire(cur);
                        out
                    }
                }
            }
            LayerSpec::Pool { p } => {
                let pd = [
                    p[0] * dil[0] - dil[0] + 1,
                    p[1] * dil[1] - dil[1] + 1,
                    p[2] * dil[2] - dil[2] + 1,
                ];
                let filtered = max_filter(&cur, pd, ctx.pool());
                for d in 0..3 {
                    dil[d] *= p[d];
                }
                ctx.retire(cur);
                filtered
            }
        };
    }
    Ok(cur)
}

/// ELEKTRONN: MPF pooling (like ZNNi) + dense conv primitives, then
/// recombine fragments to the dense output.
fn run_elektronn(
    net: &NetSpec,
    weights: &[std::sync::Arc<Weights>],
    input: &Tensor5,
    ctx: &mut ExecCtx<'_>,
) -> anyhow::Result<Tensor5> {
    let modes = vec![PoolingMode::Mpf; net.pool_count()];
    let raw = forward_plain(net, weights, input.clone_tensor(), PoolingMode::Mpf, ctx)?;
    let map = crate::inference::fragment_map(net, &modes)?;
    let dense = crate::inference::recombine(&raw, 1, &map, ctx);
    ctx.retire(raw);
    Ok(dense)
}

/// Memory-model estimate for a baseline on a cubic input (for the
/// Table V "largest input that fits" search). Training-oriented
/// frameworks (Caffe, ELEKTRONN) keep all intermediates resident.
pub fn baseline_memory_bytes(b: Baseline, net: &NetSpec, extent: usize) -> Option<u64> {
    let modes = match b {
        Baseline::Elektronn => vec![PoolingMode::Mpf; net.pool_count()],
        _ => vec![PoolingMode::MaxPool; net.pool_count()],
    };
    let input = Shape5::new(1, net.f_in, extent, extent, extent);
    match b {
        Baseline::CaffeStrided | Baseline::Elektronn => {
            // Dense semantics: every intermediate kept (training-style).
            // Approximate the dilated shapes by the undecimated extent.
            let mut total = input.bytes_f32();
            let mut f = net.f_in;
            let mut n = [extent, extent, extent];
            for l in &net.layers {
                match l {
                    LayerSpec::Conv { f_out, k } => {
                        for d in 0..3 {
                            n[d] = n[d].checked_sub(k[d] - 1)?;
                        }
                        f = *f_out;
                    }
                    LayerSpec::Pool { p } => {
                        for d in 0..3 {
                            n[d] = n[d].checked_sub(p[d] - 1)?;
                        }
                    }
                }
                total += (f * n[0] * n[1] * n[2] * 4) as u64;
            }
            Some(total)
        }
        _ => {
            // Inference-style: two live tensors (input+output of the
            // current layer).
            let shapes = net.shapes(input, &modes).ok()?;
            let mut peak = 0u64;
            let mut prev = input;
            for s in &shapes {
                peak = peak.max(prev.bytes_f32() + s.bytes_f32());
                prev = *s;
            }
            Some(peak)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo::tiny_net;
    use crate::optimizer::make_weights;
    use crate::util::pool::ChipTopology;
    use crate::util::quick::assert_allclose;

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    #[test]
    fn max_filter_window1_is_identity() {
        let p = tpool();
        let t = Tensor5::random(Shape5::new(1, 2, 4, 4, 4), 3);
        let o = max_filter(&t, [1, 1, 1], &p);
        assert_eq!(o.data(), t.data());
    }

    #[test]
    fn max_filter_matches_manual() {
        let p = tpool();
        let t = Tensor5::random(Shape5::new(1, 1, 4, 4, 4), 5);
        let o = max_filter(&t, [2, 2, 2], &p);
        assert_eq!(o.shape(), Shape5::new(1, 1, 3, 3, 3));
        let mut m = f32::NEG_INFINITY;
        for a in 0..2 {
            for b in 0..2 {
                for c in 0..2 {
                    m = m.max(t.at(0, 0, 1 + a, 2 + b, 0 + c));
                }
            }
        }
        assert_eq!(o.at(0, 0, 1, 2, 0), m);
    }

    #[test]
    fn dilation_roundtrip() {
        let w = Weights::random(2, 2, [3, 3, 3], 1);
        let d = dilate_weights(&w, [2, 2, 2]);
        assert_eq!(d.k, [5, 5, 5]);
        assert_eq!(d.kernel(1, 0)[0], w.kernel(1, 0)[0]);
        assert_eq!(d.kernel(1, 0)[(2 * 5 + 2) * 5 + 2], w.kernel(1, 0)[(1 * 3 + 1) * 3 + 1]);
        assert_eq!(d.kernel(1, 0)[1], 0.0);
        // d = 1 is the identity.
        let same = dilate_weights(&w, [1, 1, 1]);
        assert_eq!(same.kernel(0, 1), w.kernel(0, 1));
    }

    /// All four baselines must produce the SAME dense sliding-window
    /// output (they differ in speed/memory, not semantics).
    #[test]
    fn all_baselines_agree_on_dense_output() {
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        let net = tiny_net(2);
        let weights = make_weights(&net, 11);
        let input = Tensor5::random(Shape5::new(1, 1, 15, 15, 15), 13);
        let reference =
            run_baseline(Baseline::NaiveCudnn, &net, &weights, &input, &mut ctx).unwrap();
        let fov = net.field_of_view();
        assert_eq!(
            reference.shape(),
            Shape5::new(1, 2, 15 - fov[0] + 1, 15 - fov[1] + 1, 15 - fov[2] + 1)
        );
        for b in [Baseline::CaffeStrided, Baseline::Elektronn, Baseline::Znn] {
            let out = run_baseline(b, &net, &weights, &input, &mut ctx).unwrap();
            assert_allclose(out.data(), reference.data(), 1e-3, 1e-2, b.name());
        }
    }

    #[test]
    fn training_style_memory_exceeds_inference_style() {
        let net = tiny_net(8);
        let m_caffe = baseline_memory_bytes(Baseline::CaffeStrided, &net, 32).unwrap();
        let m_naive = baseline_memory_bytes(Baseline::NaiveCudnn, &net, 32).unwrap();
        assert!(m_caffe > m_naive);
    }
}
