//! Multi-tenant serving: several compiled plans — the Table III zoo as
//! tenants — behind one admission door, one shard set and one device
//! budget.
//!
//! The single-model [`Server`](super::Server) hosts exactly one
//! [`CompiledPlan`]; mixed-model traffic ("millions of users", several
//! nets) would need one box per net. [`TenantServer`] generalizes it:
//!
//! - **Budget split, not budget rewrite.** Each tenant gets an
//!   admission *quota* — its slice of the device budget, derived from
//!   the same Table II [`request_memory_bytes`] currency the
//!   single-model server admits with (see
//!   [`crate::optimizer::search_serving_multi`], which sizes shards
//!   and splits the budget in one call). Admission tracks queued +
//!   in-flight bytes per tenant; a tenant over its quota is answered
//!   [`RejectReason::OverQuota`] while every other tenant keeps
//!   admitting — per-tenant backpressure, never global.
//! - **Weighted-fair dispatch, strict per-tenant EDF.** Every shard
//!   keeps one EDF queue *per tenant* and picks the next tenant to
//!   dispatch by smooth weighted round-robin ([`swrr_pick`]), so a
//!   weight-2 tenant gets twice the batch slots of a weight-1 tenant
//!   under saturation while each tenant's own requests still dispatch
//!   in strict deadline order. Batches never mix tenants (each batch
//!   runs one tenant's coordinator on that tenant's patch shape).
//! - **Shared spectra, mixed shapes.** Tenant plans route different
//!   padded FFT shapes through their layers; the per-shape
//!   [`crate::conv::precomp::SpectraMap`] keeps every shape class hot
//!   after its first warm, and memory pressure sheds shapes
//!   largest-first across all tenants.
//! - **Per-tenant observability.** [`TenantServer::metrics`] returns a
//!   full [`ServerMetrics`] per tenant (p50/p99, rejects, occupancy,
//!   kernel-cache bytes) plus a merged global view.
//!
//! Fault tolerance carries over unchanged: shard supervisors catch
//! batch panics, answer the batch with [`ServeError::Internal`], reset
//! *every* tenant's arenas on that shard and restart the loop — the
//! other tenants' queued requests survive untouched.
//!
//! NUMA placement also carries over from the single-model server: on a
//! multi-node host (with `ZNNI_NUMA=auto`) each shard is assigned a
//! home node round-robin, every tenant coordinator on that shard pins
//! its workers there and first-touches its arenas from the pinned
//! threads, and work stealing prefers same-node victims — cross-node
//! steals only happen when a victim's queue tail has gone stale (see
//! [`crate::util::numa`]). Single-node hosts take none of these paths.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::{Coordinator, InferenceRequest};
use crate::memory::model::request_memory_bytes;
use crate::net::NetSpec;
use crate::optimizer::CompiledPlan;
use crate::tensor::{Tensor5, Vec3};
use crate::util::faults::{self, FaultSite};
use crate::util::pool::TaskPool;
use crate::util::sync::{recover_lock, recover_wait_timeout};

use super::{
    edf_le, tenant_shape_error, LatencyRing, Queued, Rejected, RejectReason, ServeError,
    ServerConfig, ServerMetrics, ShardSnapshot, ShardStats, Ticket, IDLE_WAIT,
    PRESSURE_CLEAR_STREAK,
};

/// One tenant: a network, its compiled plan, and its share of the box.
pub struct Tenant {
    /// The served network; `net.name` is the tenant id callers submit
    /// against (must be unique across the tenant set).
    pub net: NetSpec,
    /// The tenant's compiled execution plan.
    pub plan: CompiledPlan,
    /// Dispatch weight: under saturation a weight-2 tenant receives
    /// twice the batch slots of a weight-1 tenant.
    pub weight: u32,
    /// Admission quota in bytes: the cap on the tenant's queued +
    /// in-flight Table II request footprint. Derived by
    /// [`crate::optimizer::search_serving_multi`] as the tenant's slice
    /// of the device budget.
    pub quota_bytes: u64,
}

/// Per-tenant serving state shared by admission and the shard loops.
struct TenantState {
    name: String,
    weight: u32,
    quota_bytes: u64,
    f_in: usize,
    f_out: usize,
    fov: Vec3,
    patch: Vec3,
    /// Queued + in-flight Table II bytes — the quota gauge. Decremented
    /// by [`InflightGuard::drop`] when a request leaves accounting,
    /// whatever the exit path (served, expired, failed, disconnected).
    inflight: Arc<AtomicU64>,
    submitted: AtomicU64,
    rejected: AtomicU64,
    expired: AtomicU64,
    completed_late: AtomicU64,
    completed: AtomicU64,
    batches: AtomicU64,
    batch_requests: AtomicU64,
    queue_depth_hwm: AtomicUsize,
    panics: AtomicU64,
    restarts: AtomicU64,
    latencies: Mutex<LatencyRing>,
}

/// Decrements a tenant's in-flight gauge on drop, so quota release is
/// tied to the request actually leaving the server — no exit path
/// (response, expiry, batch failure, panic-dropped sender) can leak
/// quota.
struct InflightGuard {
    gauge: Arc<AtomicU64>,
    bytes: u64,
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.gauge.fetch_sub(self.bytes, Ordering::SeqCst);
    }
}

/// A queued request plus its quota guard.
struct TQueued {
    inner: Queued,
    guard: InflightGuard,
}

/// Insert into a per-tenant deadline-sorted queue (EDF with FIFO
/// tie-breaking, like the single-model server's queue).
fn edf_insert_t(q: &mut VecDeque<TQueued>, item: TQueued) {
    let idx = q.partition_point(|x| edf_le(x.inner.deadline, item.inner.deadline));
    q.insert(idx, item);
}

/// Smooth weighted round-robin over the backlogged tenants.
///
/// Classic nginx-style SWRR: every backlogged tenant's credit grows by
/// its weight, the highest credit wins the slot, and the winner pays
/// back the total weight in play. Over any window the slot share of
/// each continuously-backlogged tenant converges to `weight / Σ
/// weights`, and consecutive picks interleave (no long monopolies).
/// Tenants with empty queues neither gain nor pay credit, so an idle
/// tenant cannot bank an unbounded burst. Returns `None` when nothing
/// is backlogged.
fn swrr_pick(credits: &mut [i64], weights: &[u32], backlogged: &[bool]) -> Option<usize> {
    let mut total = 0i64;
    let mut best: Option<usize> = None;
    for t in 0..weights.len() {
        if !backlogged[t] {
            continue;
        }
        credits[t] += i64::from(weights[t]);
        total += i64::from(weights[t]);
        if best.map(|b| credits[t] > credits[b]).unwrap_or(true) {
            best = Some(t);
        }
    }
    if let Some(b) = best {
        credits[b] -= total;
    }
    best
}

/// One shard's tenant-partitioned state: an EDF queue and stats row per
/// tenant, SWRR credits, and the dispatch condvar.
struct TenantShard {
    /// One EDF queue per tenant — strict per-tenant deadline order.
    queues: Vec<Mutex<VecDeque<TQueued>>>,
    /// Per-tenant shard stats (merged coordinator metrics, steals, …).
    stats: Vec<Mutex<ShardStats>>,
    /// SWRR credit per tenant (see [`swrr_pick`]).
    credits: Mutex<Vec<i64>>,
    /// Paired with `cvar`; submits take it before notifying so a
    /// dispatcher checking queues under it cannot miss the wakeup.
    idle: Mutex<()>,
    cvar: Condvar,
}

/// Why a tenant shard loop returned to its supervisor.
enum TExit {
    Shutdown,
    /// A batch of the given tenant panicked; restart with fresh arenas.
    Restart(usize),
}

enum TBatchOutcome {
    Served,
    Panicked,
}

struct TenantInner {
    cfg: ServerConfig,
    pool: Arc<TaskPool>,
    tenants: Vec<TenantState>,
    /// `coordinators[shard][tenant]` — each shard owns one warm-arena
    /// coordinator per tenant, all sharing that tenant's plan `Arc`.
    coordinators: Vec<Vec<Coordinator>>,
    shards: Vec<TenantShard>,
    /// `home_nodes[shard]` — the shard's home NUMA node, or `None` when
    /// placement is inactive (single-node host or `ZNNI_NUMA=off`).
    /// Drives the two-tier steal policy: same-home victims are always
    /// fair game, cross-node victims only past the staleness threshold.
    home_nodes: Vec<Option<usize>>,
    /// Σ over tenants of one shard's warm worker arenas — the fixed
    /// term of every batch admission inequality (all tenants' arenas
    /// are resident on every shard).
    shard_ws_bytes: u64,
    shutdown: AtomicBool,
    next_id: AtomicU64,
    rr: AtomicUsize,
    /// Server-wide micro-batch cap: halved under memory pressure,
    /// restored as it clears (same half/double policy as the
    /// single-model server).
    batch_limit: AtomicUsize,
    pressured: AtomicBool,
    clear_streak: AtomicUsize,
    mem_pressure_events: AtomicU64,
    shed_cache_bytes: AtomicU64,
    /// Panics/restarts not attributable to one tenant's batch (a panic
    /// escaping the dispatch loop itself).
    orphan_panics: AtomicU64,
    orphan_restarts: AtomicU64,
}

/// Per-tenant slice of a [`TenantServerMetrics`] snapshot.
#[derive(Clone, Debug)]
pub struct TenantMetrics {
    /// Tenant id (the network name).
    pub name: String,
    /// Dispatch weight.
    pub weight: u32,
    /// Admission quota in bytes.
    pub quota_bytes: u64,
    /// Queued + in-flight Table II bytes at snapshot time.
    pub inflight_bytes: u64,
    /// Full serving metrics for this tenant alone (p50/p99, rejects,
    /// occupancy, kernel-cache bytes, per-shard rows). Memory-pressure
    /// gauges are server-wide and reported only on the merged view.
    pub metrics: ServerMetrics,
}

/// Snapshot of a [`TenantServer`]: one [`ServerMetrics`] per tenant
/// plus the merged global view.
#[derive(Clone, Debug)]
pub struct TenantServerMetrics {
    /// Per-tenant metrics, in tenant declaration order.
    pub tenants: Vec<TenantMetrics>,
    /// All tenants merged: counters summed, kernel-cache bytes summed
    /// across the distinct tenant plans, latency percentiles over the
    /// union of all tenants' samples.
    pub merged: ServerMetrics,
}

/// The multi-tenant serving frontend. Construct with
/// [`TenantServer::start`]; dropping it drains every tenant queue
/// gracefully and joins the shard threads.
pub struct TenantServer {
    inner: Arc<TenantInner>,
    handles: Vec<JoinHandle<()>>,
}

impl TenantServer {
    /// Start `cfg.shards` shard threads over the tenant set. Each shard
    /// hosts one warm-arena coordinator per tenant; `cfg.queue_depth`
    /// bounds each *per-tenant* per-shard queue and
    /// `cfg.memory_budget` bounds one shard's batch (any tenant's
    /// requests plus *all* tenants' resident arenas). Fails at start —
    /// never mid-serve — if the budget cannot hold every tenant's warm
    /// arenas, or on an empty / duplicate-named / zero-weight tenant
    /// set.
    pub fn start(tenants: Vec<Tenant>, cfg: ServerConfig, pool: Arc<TaskPool>) -> Result<Self> {
        if tenants.is_empty() {
            bail!("tenant server needs at least one tenant");
        }
        if cfg.shards == 0 || cfg.queue_depth == 0 || cfg.max_batch_requests == 0 {
            bail!("server config must have at least one shard, queue slot and batch slot");
        }
        for t in &tenants {
            if t.weight == 0 {
                bail!("tenant {} has weight 0 — it would never dispatch", t.net.name);
            }
            if t.quota_bytes == 0 {
                bail!("tenant {} has a zero quota — it would never admit", t.net.name);
            }
        }
        for (i, a) in tenants.iter().enumerate() {
            if tenants[..i].iter().any(|b| b.net.name == a.net.name) {
                bail!("duplicate tenant name {:?}", a.net.name);
            }
        }
        let shard_workers = (pool.workers() / cfg.shards).max(1);
        // CompiledPlan owns boxed primitives and is not Clone: each
        // tenant's plan moves into one Arc shared by every shard.
        let mut specs = Vec::with_capacity(tenants.len());
        let mut plans = Vec::with_capacity(tenants.len());
        let mut shard_ws_bytes = 0u64;
        for t in tenants {
            let Tenant { net, plan, weight, quota_bytes } = t;
            let plan = Arc::new(plan);
            shard_ws_bytes = shard_ws_bytes
                .saturating_add(plan.workspace_req(shard_workers).times(shard_workers).total());
            plans.push(plan);
            specs.push((net, weight, quota_bytes));
        }
        if shard_ws_bytes >= cfg.memory_budget {
            bail!(
                "server memory budget {} cannot hold one shard's warm arenas {} across {} \
                 tenants — no request is admissible",
                cfg.memory_budget,
                shard_ws_bytes,
                specs.len()
            );
        }
        // Spectra build at start, never on a request's critical path;
        // each tenant's padded shapes land in the layers' per-shape
        // spectra maps.
        for plan in &plans {
            plan.warm_kernel_caches(&pool);
        }
        // Same placement policy as the single-model server: on an
        // active multi-node topology, each shard gets a home node
        // round-robin and every tenant coordinator on that shard pins
        // its serve workers there.
        let numa = crate::util::numa::topology();
        let active = crate::util::numa::placement_active(numa);
        let mut home_nodes: Vec<Option<usize>> = vec![None; cfg.shards];
        let mut coordinators: Vec<Vec<Coordinator>> = Vec::with_capacity(cfg.shards);
        for si in 0..cfg.shards {
            let home_set = if active {
                let node = crate::util::numa::home_node_for_shard(numa, si);
                home_nodes[si] = Some(node);
                Some(Arc::new(numa.nodes[node].cpus.clone()))
            } else {
                None
            };
            let mut row = Vec::with_capacity(specs.len());
            for ((net, _, _), plan) in specs.iter().zip(&plans) {
                let mut c = Coordinator::with_shared_plan(net.clone(), plan.clone())?;
                c.workers = shard_workers;
                c.home_cpus = home_set.clone();
                row.push(c);
            }
            coordinators.push(row);
        }
        let states: Vec<TenantState> = specs
            .iter()
            .enumerate()
            .map(|(ti, (net, weight, quota_bytes))| TenantState {
                name: net.name.clone(),
                weight: *weight,
                quota_bytes: *quota_bytes,
                f_in: net.f_in,
                f_out: net.f_out(),
                fov: net.field_of_view(),
                patch: coordinators[0][ti].patch(),
                inflight: Arc::new(AtomicU64::new(0)),
                submitted: AtomicU64::new(0),
                rejected: AtomicU64::new(0),
                expired: AtomicU64::new(0),
                completed_late: AtomicU64::new(0),
                completed: AtomicU64::new(0),
                batches: AtomicU64::new(0),
                batch_requests: AtomicU64::new(0),
                queue_depth_hwm: AtomicUsize::new(0),
                panics: AtomicU64::new(0),
                restarts: AtomicU64::new(0),
                latencies: Mutex::new(LatencyRing::default()),
            })
            .collect();
        let shards = (0..cfg.shards)
            .map(|_| TenantShard {
                queues: (0..states.len()).map(|_| Mutex::new(VecDeque::new())).collect(),
                stats: (0..states.len()).map(|_| Mutex::new(ShardStats::default())).collect(),
                credits: Mutex::new(vec![0; states.len()]),
                idle: Mutex::new(()),
                cvar: Condvar::new(),
            })
            .collect();
        let max_batch_requests = cfg.max_batch_requests;
        let inner = Arc::new(TenantInner {
            cfg,
            pool,
            tenants: states,
            coordinators,
            shards,
            home_nodes,
            shard_ws_bytes,
            shutdown: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            batch_limit: AtomicUsize::new(max_batch_requests),
            pressured: AtomicBool::new(false),
            clear_streak: AtomicUsize::new(0),
            mem_pressure_events: AtomicU64::new(0),
            shed_cache_bytes: AtomicU64::new(0),
            orphan_panics: AtomicU64::new(0),
            orphan_restarts: AtomicU64::new(0),
        });
        let handles = (0..inner.cfg.shards)
            .map(|si| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("znni-tshard{si}"))
                    .spawn(move || inner.supervise(si))
                    .expect("spawn tenant shard thread")
            })
            .collect();
        Ok(TenantServer { inner, handles })
    }

    /// The serving configuration.
    pub fn config(&self) -> &ServerConfig {
        &self.inner.cfg
    }

    /// Tenant names, in declaration order.
    pub fn tenant_names(&self) -> Vec<String> {
        self.inner.tenants.iter().map(|t| t.name.clone()).collect()
    }

    /// The patch extent a tenant's shards execute, or `None` for an
    /// unknown tenant.
    pub fn patch(&self, tenant: &str) -> Option<Vec3> {
        self.inner.tenants.iter().find(|t| t.name == tenant).map(|t| t.patch)
    }

    /// Submit to a tenant with the config's default deadline. Never
    /// blocks; see [`TenantServer::submit_with_deadline`].
    pub fn submit(&self, tenant: &str, volume: Tensor5) -> Result<Ticket, Rejected> {
        self.submit_with_deadline(tenant, volume, self.inner.cfg.default_deadline)
    }

    /// Submit a volume to the named tenant with an explicit deadline
    /// (measured from now). Never blocks: shape mismatches come back as
    /// [`RejectReason::WrongTenantShape`] naming the tenant and its
    /// accepted shapes, quota exhaustion as
    /// [`RejectReason::OverQuota`], and full queues as backpressure —
    /// all with the volume returned intact for retry.
    pub fn submit_with_deadline(
        &self,
        tenant: &str,
        volume: Tensor5,
        deadline: Option<Duration>,
    ) -> Result<Ticket, Rejected> {
        let inner = &*self.inner;
        if inner.shutdown.load(Ordering::SeqCst) {
            return Err(Rejected { volume, reason: RejectReason::ShuttingDown });
        }
        let Some(ti) = inner.tenants.iter().position(|t| t.name == tenant) else {
            let known: Vec<&str> = inner.tenants.iter().map(|t| t.name.as_str()).collect();
            let detail = format!("unknown tenant {tenant:?} (serving {known:?})");
            return Err(Rejected { volume, reason: RejectReason::BadShape { detail } });
        };
        let t = &inner.tenants[ti];
        let sh = volume.shape();
        if sh.s != 1 {
            let detail = format!("expected a single volume (s = 1), got {}", sh);
            return Err(Rejected { volume, reason: RejectReason::BadShape { detail } });
        }
        if let Some(detail) = tenant_shape_error(sh, t.f_in, t.patch) {
            t.rejected.fetch_add(1, Ordering::SeqCst);
            let reason = RejectReason::WrongTenantShape {
                tenant: t.name.clone(),
                f_in: t.f_in,
                min_extent: t.patch,
                detail,
            };
            return Err(Rejected { volume, reason });
        }
        let bytes = request_memory_bytes(t.f_in, t.f_out, [sh.x, sh.y, sh.z], t.fov);
        if bytes.saturating_add(inner.shard_ws_bytes) > inner.cfg.memory_budget {
            t.rejected.fetch_add(1, Ordering::SeqCst);
            return Err(Rejected {
                volume,
                reason: RejectReason::TooLarge { bytes, budget: inner.cfg.memory_budget },
            });
        }
        if bytes > t.quota_bytes {
            t.rejected.fetch_add(1, Ordering::SeqCst);
            return Err(Rejected {
                volume,
                reason: RejectReason::TooLarge { bytes, budget: t.quota_bytes },
            });
        }
        // Atomically claim quota: queued + in-flight bytes may not
        // exceed the tenant's slice of the budget. The claim is
        // released by the request's InflightGuard on *any* exit path.
        let mut cur = t.inflight.load(Ordering::SeqCst);
        loop {
            if cur.saturating_add(bytes) > t.quota_bytes {
                t.rejected.fetch_add(1, Ordering::SeqCst);
                return Err(Rejected {
                    volume,
                    reason: RejectReason::OverQuota {
                        tenant: t.name.clone(),
                        inflight_bytes: cur,
                        quota: t.quota_bytes,
                    },
                });
            }
            match t.inflight.compare_exchange(
                cur,
                cur + bytes,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
        let guard = InflightGuard { gauge: t.inflight.clone(), bytes };
        let id = inner.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        let now = Instant::now();
        let mut item = Some(TQueued {
            inner: Queued {
                id,
                volume,
                enqueued: now,
                deadline: deadline.map(|d| now + d),
                bytes,
                tx,
            },
            guard,
        });
        // Round-robin placement with fallback scan over the tenant's
        // per-shard EDF queues; under memory pressure the effective
        // depth halves, exactly like the single-model server.
        let pressured = inner.pressured.load(Ordering::SeqCst);
        let eff_depth = if pressured {
            (inner.cfg.queue_depth / 2).max(1)
        } else {
            inner.cfg.queue_depth
        };
        let start = inner.rr.fetch_add(1, Ordering::SeqCst);
        for k in 0..inner.shards.len() {
            let si = (start + k) % inner.shards.len();
            let shard = &inner.shards[si];
            let mut q = recover_lock(&shard.queues[ti]);
            if q.len() < eff_depth {
                edf_insert_t(&mut q, item.take().unwrap());
                let depth = q.len();
                drop(q);
                t.queue_depth_hwm.fetch_max(depth, Ordering::SeqCst);
                t.submitted.fetch_add(1, Ordering::SeqCst);
                // Take the idle lock before notifying: a dispatcher
                // between its queue check and its wait holds it, so the
                // wakeup cannot fall between the two.
                drop(recover_lock(&shard.idle));
                shard.cvar.notify_one();
                if depth > 1 && inner.shards.len() > 1 {
                    let sib = &inner.shards[(si + 1) % inner.shards.len()];
                    drop(recover_lock(&sib.idle));
                    sib.cvar.notify_one();
                }
                return Ok(Ticket { id, rx });
            }
        }
        t.rejected.fetch_add(1, Ordering::SeqCst);
        let volume = item.take().unwrap().inner.volume;
        let reason = if pressured {
            RejectReason::MemoryPressure { depth: eff_depth }
        } else {
            RejectReason::QueueFull { depth: inner.cfg.queue_depth }
        };
        Err(Rejected { volume, reason })
    }

    /// Snapshot per-tenant and merged serving metrics.
    pub fn metrics(&self) -> TenantServerMetrics {
        let inner = &*self.inner;
        let mut tenants = Vec::with_capacity(inner.tenants.len());
        let mut all_samples: Vec<u64> = Vec::new();
        for (ti, t) in inner.tenants.iter().enumerate() {
            let per_shard: Vec<ShardSnapshot> = inner
                .shards
                .iter()
                .map(|sh| {
                    let st = recover_lock(&sh.stats[ti]);
                    ShardSnapshot {
                        batches: st.batches,
                        requests: st.requests,
                        steals: st.steals,
                        local_steals: st.local_steals,
                        remote_steals: st.remote_steals,
                        expired: st.expired,
                        panics: st.panics,
                        restarts: st.restarts,
                        queue_len: recover_lock(&sh.queues[ti]).len(),
                        patches: st.metrics.patches,
                        voxels: st.metrics.voxels,
                        busy_secs: st.metrics.busy_secs,
                        arena_hwm_bytes: st.metrics.arena_hwm_bytes,
                        arena_fresh_allocs: st.metrics.arena_fresh_allocs,
                        assembly_lock_wait_secs: st.metrics.assembly_lock_wait_secs,
                        kernel_cache_bytes: st.metrics.kernel_cache_bytes,
                    }
                })
                .collect();
            let mut samples = recover_lock(&t.latencies).samples_us.clone();
            all_samples.extend_from_slice(&samples);
            let [p50, p99] = LatencyRing::percentiles(&mut samples, [0.50, 0.99]);
            let metrics = ServerMetrics {
                submitted: t.submitted.load(Ordering::SeqCst),
                rejected: t.rejected.load(Ordering::SeqCst),
                expired: t.expired.load(Ordering::SeqCst),
                completed_late: t.completed_late.load(Ordering::SeqCst),
                completed: t.completed.load(Ordering::SeqCst),
                batches: t.batches.load(Ordering::SeqCst),
                batch_requests: t.batch_requests.load(Ordering::SeqCst),
                queue_depth_hwm: t.queue_depth_hwm.load(Ordering::SeqCst),
                queued_now: per_shard.iter().map(|s| s.queue_len).sum(),
                p50_latency: p50,
                p99_latency: p99,
                voxels: per_shard.iter().map(|s| s.voxels).sum(),
                // One plan shared across shards via Arc: max, not sum.
                kernel_cache_bytes: inner.coordinators[0][ti].plan().kernel_cache_bytes(),
                panics: t.panics.load(Ordering::SeqCst),
                restarts: t.restarts.load(Ordering::SeqCst),
                mem_pressure_events: 0,
                shed_kernel_cache_bytes: 0,
                current_max_batch: inner.batch_limit.load(Ordering::SeqCst),
                per_shard,
            };
            tenants.push(TenantMetrics {
                name: t.name.clone(),
                weight: t.weight,
                quota_bytes: t.quota_bytes,
                inflight_bytes: t.inflight.load(Ordering::SeqCst),
                metrics,
            });
        }
        let merged = merge_metrics(&tenants, inner, &mut all_samples);
        TenantServerMetrics { tenants, merged }
    }
}

/// Fold the per-tenant views into one global [`ServerMetrics`]:
/// counters summed, kernel-cache bytes summed across the distinct
/// tenant plans, percentiles over the union of latency samples, and
/// per-shard rows aggregated across tenants.
fn merge_metrics(
    tenants: &[TenantMetrics],
    inner: &TenantInner,
    all_samples: &mut [u64],
) -> ServerMetrics {
    let [p50, p99] = LatencyRing::percentiles(all_samples, [0.50, 0.99]);
    let shards = inner.cfg.shards;
    let mut per_shard = vec![ShardSnapshot::default(); shards];
    for tm in tenants {
        for (agg, s) in per_shard.iter_mut().zip(&tm.metrics.per_shard) {
            agg.batches += s.batches;
            agg.requests += s.requests;
            agg.steals += s.steals;
            agg.local_steals += s.local_steals;
            agg.remote_steals += s.remote_steals;
            agg.expired += s.expired;
            agg.panics += s.panics;
            agg.restarts += s.restarts;
            agg.queue_len += s.queue_len;
            agg.patches += s.patches;
            agg.voxels += s.voxels;
            agg.busy_secs += s.busy_secs;
            agg.arena_hwm_bytes = agg.arena_hwm_bytes.max(s.arena_hwm_bytes);
            agg.arena_fresh_allocs += s.arena_fresh_allocs;
            agg.assembly_lock_wait_secs += s.assembly_lock_wait_secs;
            agg.kernel_cache_bytes += s.kernel_cache_bytes;
        }
    }
    let sum = |f: fn(&ServerMetrics) -> u64| tenants.iter().map(|t| f(&t.metrics)).sum::<u64>();
    ServerMetrics {
        submitted: sum(|m| m.submitted),
        rejected: sum(|m| m.rejected),
        expired: sum(|m| m.expired),
        completed_late: sum(|m| m.completed_late),
        completed: sum(|m| m.completed),
        batches: sum(|m| m.batches),
        batch_requests: sum(|m| m.batch_requests),
        queue_depth_hwm: tenants.iter().map(|t| t.metrics.queue_depth_hwm).max().unwrap_or(0),
        queued_now: tenants.iter().map(|t| t.metrics.queued_now).sum(),
        p50_latency: p50,
        p99_latency: p99,
        voxels: sum(|m| m.voxels),
        // Distinct plans per tenant: the global cache footprint is the
        // sum of the tenants' (per-plan max) reports.
        kernel_cache_bytes: tenants.iter().map(|t| t.metrics.kernel_cache_bytes).sum(),
        panics: sum(|m| m.panics) + inner.orphan_panics.load(Ordering::SeqCst),
        restarts: sum(|m| m.restarts) + inner.orphan_restarts.load(Ordering::SeqCst),
        mem_pressure_events: inner.mem_pressure_events.load(Ordering::SeqCst),
        shed_kernel_cache_bytes: inner.shed_cache_bytes.load(Ordering::SeqCst),
        current_max_batch: inner.batch_limit.load(Ordering::SeqCst),
        per_shard,
    }
}

impl Drop for TenantServer {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        for sh in &self.inner.shards {
            drop(recover_lock(&sh.idle));
            sh.cvar.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl TenantInner {
    /// Shard supervisor, mirroring the single-model server's: restart
    /// the loop after a batch panic, resetting *every* tenant's arenas
    /// on this shard so the restarted loop re-warms a consistent set.
    fn supervise(&self, si: usize) {
        loop {
            match catch_unwind(AssertUnwindSafe(|| self.shard_loop(si))) {
                Ok(TExit::Shutdown) => return,
                Ok(TExit::Restart(ti)) => {
                    self.tenants[ti].restarts.fetch_add(1, Ordering::SeqCst);
                    recover_lock(&self.shards[si].stats[ti]).restarts += 1;
                }
                Err(_) => {
                    // A panic escaped run_batch's isolation; dropped
                    // Queued senders resolve their tickets Disconnected
                    // and dropped InflightGuards release their quota.
                    self.orphan_panics.fetch_add(1, Ordering::SeqCst);
                    self.orphan_restarts.fetch_add(1, Ordering::SeqCst);
                }
            }
            for c in &self.coordinators[si] {
                c.reset_arenas();
            }
        }
    }

    /// Pick the next (tenant, request) from this shard's local queues
    /// by SWRR over the backlogged tenants; strict EDF within the
    /// winning tenant's queue.
    fn try_pick_local(&self, si: usize) -> Option<(usize, TQueued)> {
        let shard = &self.shards[si];
        let n = self.tenants.len();
        let mut backlogged = vec![false; n];
        for (t, b) in backlogged.iter_mut().enumerate() {
            *b = !recover_lock(&shard.queues[t]).is_empty();
        }
        let weights: Vec<u32> = self.tenants.iter().map(|t| t.weight).collect();
        let pick = {
            let mut credits = recover_lock(&shard.credits);
            swrr_pick(&mut credits, &weights, &backlogged)?
        };
        // A sibling may have stolen the last item between the peek and
        // this pop; the caller just retries.
        recover_lock(&shard.queues[pick]).pop_front().map(|q| (pick, q))
    }

    /// Queue-tail age past which a cross-node steal is worth the remote
    /// memory traffic (same rule as the single-model server).
    fn steal_staleness(&self) -> Duration {
        self.cfg.max_batch_wait.max(Duration::from_micros(500)) * 2
    }

    /// Steal one request from a sibling shard's queue tails — least
    /// urgent work first, scanning tenants in SWRR-agnostic order (the
    /// stolen request still dispatches under its own tenant's plan).
    ///
    /// Two locality tiers: same-home-node victims are stolen from
    /// unconditionally (on a single-node host every home is `None`, so
    /// all steals are tier 1 — identical to pre-NUMA behavior); a
    /// cross-node victim gives up its tail only once that request has
    /// waited past [`TenantInner::steal_staleness`], so transient
    /// imbalance stays node-local.
    fn try_steal(&self, si: usize) -> Option<(usize, TQueued)> {
        let n = self.shards.len();
        for k in 1..n {
            let vi = (si + k) % n;
            if self.home_nodes[vi] != self.home_nodes[si] {
                continue;
            }
            for t in 0..self.tenants.len() {
                let stolen = recover_lock(&self.shards[vi].queues[t]).pop_back();
                if let Some(q) = stolen {
                    let mut st = recover_lock(&self.shards[si].stats[t]);
                    st.steals += 1;
                    st.local_steals += 1;
                    return Some((t, q));
                }
            }
        }
        let threshold = self.steal_staleness();
        for k in 1..n {
            let vi = (si + k) % n;
            if self.home_nodes[vi] == self.home_nodes[si] {
                continue;
            }
            for t in 0..self.tenants.len() {
                let mut q = recover_lock(&self.shards[vi].queues[t]);
                let stale =
                    q.back().map(|x| x.inner.enqueued.elapsed() >= threshold).unwrap_or(false);
                let stolen = if stale { q.pop_back() } else { None };
                drop(q);
                if let Some(q) = stolen {
                    let mut st = recover_lock(&self.shards[si].stats[t]);
                    st.steals += 1;
                    st.remote_steals += 1;
                    return Some((t, q));
                }
            }
        }
        None
    }

    fn any_local(&self, si: usize) -> bool {
        let shard = &self.shards[si];
        shard.queues.iter().any(|q| !recover_lock(q).is_empty())
    }

    /// Block until a request is available (own queues, then steal).
    /// Returns `None` on shutdown once every queue this shard can reach
    /// is drained.
    fn next_request(&self, si: usize) -> Option<(usize, TQueued)> {
        loop {
            if let Some(p) = self.try_pick_local(si) {
                return Some(p);
            }
            if let Some(p) = self.try_steal(si) {
                return Some(p);
            }
            let shard = &self.shards[si];
            let guard = recover_lock(&shard.idle);
            if self.any_local(si) {
                continue;
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            let (g, _) = recover_wait_timeout(&shard.cvar, guard, IDLE_WAIT);
            drop(g);
        }
    }

    fn shard_loop(&self, si: usize) -> TExit {
        loop {
            let Some((ti, first)) = self.next_request(si) else { return TExit::Shutdown };
            let mut batch_bytes = first.inner.bytes;
            let mut batch = vec![first];
            let wait_until = Instant::now() + self.cfg.max_batch_wait;
            let limit =
                self.batch_limit.load(Ordering::SeqCst).clamp(1, self.cfg.max_batch_requests);
            // Coalesce only from the *same tenant's* local queue —
            // batches never mix tenants (one coordinator, one patch
            // shape per batch).
            while batch.len() < limit {
                let popped = recover_lock(&self.shards[si].queues[ti]).pop_front();
                match popped {
                    Some(q) => {
                        if batch_bytes
                            .saturating_add(q.inner.bytes)
                            .saturating_add(self.shard_ws_bytes)
                            > self.cfg.memory_budget
                        {
                            edf_insert_t(&mut recover_lock(&self.shards[si].queues[ti]), q);
                            break;
                        }
                        batch_bytes += q.inner.bytes;
                        batch.push(q);
                    }
                    None => {
                        let now = Instant::now();
                        if now >= wait_until || self.shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        let shard = &self.shards[si];
                        let guard = recover_lock(&shard.idle);
                        if recover_lock(&shard.queues[ti]).is_empty() {
                            let (g, _) =
                                recover_wait_timeout(&shard.cvar, guard, wait_until - now);
                            drop(g);
                        }
                    }
                }
            }
            if let TBatchOutcome::Panicked = self.run_batch(si, ti, batch) {
                return TExit::Restart(ti);
            }
        }
    }

    /// Memory-pressure probe (same policy as the single-model server):
    /// halve the batch cap and shed the largest kernel-spectra shape
    /// across *all* tenants' plans; restore once a pressure-free streak
    /// brings the cap back to full.
    fn check_pressure(&self) {
        let injected = faults::fire_reserve(FaultSite::ArenaTake);
        let budget = self.cfg.memory_budget.saturating_mul(self.cfg.shards as u64);
        let over = budget < u64::MAX && crate::memory::current() > budget;
        if injected || over {
            self.mem_pressure_events.fetch_add(1, Ordering::SeqCst);
            self.pressured.store(true, Ordering::SeqCst);
            self.clear_streak.store(0, Ordering::SeqCst);
            let cur = self.batch_limit.load(Ordering::SeqCst);
            self.batch_limit.store((cur / 2).max(1), Ordering::SeqCst);
            // Shed from the tenant holding the most resident spectra.
            let fattest = (0..self.tenants.len())
                .max_by_key(|&t| self.coordinators[0][t].plan().kernel_cache_bytes());
            if let Some(t) = fattest {
                let shed = self.coordinators[0][t].plan().shed_largest_kernel_cache();
                if shed > 0 {
                    self.shed_cache_bytes.fetch_add(shed, Ordering::SeqCst);
                }
            }
        } else if self.pressured.load(Ordering::SeqCst) {
            let streak = self.clear_streak.fetch_add(1, Ordering::SeqCst) + 1;
            if streak >= PRESSURE_CLEAR_STREAK {
                self.clear_streak.store(0, Ordering::SeqCst);
                let cur = self.batch_limit.load(Ordering::SeqCst);
                let next = (cur.saturating_mul(2)).clamp(1, self.cfg.max_batch_requests);
                self.batch_limit.store(next, Ordering::SeqCst);
                if next >= self.cfg.max_batch_requests {
                    self.pressured.store(false, Ordering::SeqCst);
                    for t in 0..self.tenants.len() {
                        self.coordinators[0][t].plan().restore_kernel_caches();
                    }
                }
            }
        }
    }

    fn run_batch(&self, si: usize, ti: usize, batch: Vec<TQueued>) -> TBatchOutcome {
        self.check_pressure();
        let tenant = &self.tenants[ti];
        let now = Instant::now();
        let mut reqs = Vec::with_capacity(batch.len());
        let mut metas = Vec::with_capacity(batch.len());
        let mut expired_here = 0u64;
        for tq in batch {
            let q = tq.inner;
            if let Some(d) = q.deadline {
                if now > d {
                    expired_here += 1;
                    tenant.expired.fetch_add(1, Ordering::SeqCst);
                    let waited = q.enqueued.elapsed();
                    // Quota released before the reply: a client that
                    // retries on expiry never races its own guard.
                    drop(tq.guard);
                    let _ = q.tx.send(Err(ServeError::DeadlineExceeded { waited }));
                    continue;
                }
            }
            reqs.push(InferenceRequest { id: q.id, volume: q.volume });
            metas.push((q.tx, q.enqueued, q.deadline, tq.guard));
        }
        if expired_here > 0 {
            recover_lock(&self.shards[si].stats[ti]).expired += expired_here;
        }
        if reqs.is_empty() {
            return TBatchOutcome::Served;
        }
        let n = reqs.len();
        let served = catch_unwind(AssertUnwindSafe(|| {
            faults::fire(FaultSite::ShardDispatch);
            self.coordinators[si][ti].serve(reqs, &self.pool)
        }));
        match served {
            Ok(Ok((resps, m))) => {
                tenant.batches.fetch_add(1, Ordering::SeqCst);
                tenant.batch_requests.fetch_add(n as u64, Ordering::SeqCst);
                {
                    let mut st = recover_lock(&self.shards[si].stats[ti]);
                    st.batches += 1;
                    st.requests += n as u64;
                    st.metrics.merge(&m);
                }
                let done = Instant::now();
                for (mut resp, (tx, enqueued, deadline, guard)) in resps.into_iter().zip(metas) {
                    let lat = done.duration_since(enqueued);
                    resp.latency = lat;
                    if deadline.map(|d| done > d).unwrap_or(false) {
                        tenant.completed_late.fetch_add(1, Ordering::SeqCst);
                    }
                    recover_lock(&tenant.latencies).record(lat.as_micros() as u64);
                    tenant.completed.fetch_add(1, Ordering::SeqCst);
                    // Release quota before waking the client: whoever
                    // sees the response also sees the freed bytes.
                    drop(guard);
                    let _ = tx.send(Ok(resp));
                }
                TBatchOutcome::Served
            }
            Ok(Err(e)) => {
                let msg = e.to_string();
                for (tx, _, _, guard) in metas {
                    drop(guard);
                    let _ = tx.send(Err(ServeError::Failed(msg.clone())));
                }
                TBatchOutcome::Served
            }
            Err(payload) => {
                let msg = faults::panic_message(payload.as_ref()).unwrap_or("panic");
                let site = faults::site_of_panic(msg)
                    .map(|s| s.name().to_string())
                    .unwrap_or_else(|| msg.to_string());
                tenant.panics.fetch_add(1, Ordering::SeqCst);
                recover_lock(&self.shards[si].stats[ti]).panics += 1;
                for (tx, _, _, guard) in metas {
                    drop(guard);
                    let _ = tx.send(Err(ServeError::Internal { site: site.clone() }));
                }
                TBatchOutcome::Panicked
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Simulate a saturated shard: every tenant always backlogged.
    fn swrr_rounds(weights: &[u32], rounds: usize) -> Vec<usize> {
        let mut credits = vec![0i64; weights.len()];
        let backlogged = vec![true; weights.len()];
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..rounds {
            let pick = swrr_pick(&mut credits, weights, &backlogged).unwrap();
            counts[pick] += 1;
        }
        counts
    }

    #[test]
    fn swrr_is_weight_proportional_under_saturation() {
        let counts = swrr_rounds(&[1, 2, 1], 400);
        assert_eq!(counts.iter().sum::<usize>(), 400);
        assert_eq!(counts[1], 200, "weight-2 tenant gets exactly half the slots");
        assert_eq!(counts[0], 100);
        assert_eq!(counts[2], 100);
    }

    #[test]
    fn swrr_interleaves_rather_than_monopolizes() {
        // With weights [1, 3] the heavy tenant must not take runs of 3
        // followed by starving the light one beyond its share window:
        // in any 4 consecutive slots the light tenant appears once.
        let mut credits = vec![0i64; 2];
        let backlogged = vec![true; 2];
        let picks: Vec<usize> =
            (0..40).map(|_| swrr_pick(&mut credits, &[1, 3], &backlogged).unwrap()).collect();
        for w in picks.windows(4) {
            assert!(w.contains(&0), "light tenant starved in window {w:?}");
            assert!(w.contains(&1), "heavy tenant starved in window {w:?}");
        }
    }

    #[test]
    fn swrr_skips_idle_tenants_without_banking_credit() {
        let weights = [1, 1];
        let mut credits = vec![0i64; 2];
        // Tenant 1 idle for many rounds: tenant 0 wins every slot.
        for _ in 0..10 {
            assert_eq!(swrr_pick(&mut credits, &weights, &[true, false]), Some(0));
        }
        // Once tenant 1 backlogs it gets its fair share immediately but
        // no compensation burst: over the next 10 slots, 5 each.
        let mut counts = [0usize; 2];
        for _ in 0..10 {
            counts[swrr_pick(&mut credits, &weights, &[true, true]).unwrap()] += 1;
        }
        assert_eq!(counts, [5, 5]);
        // Nothing backlogged → no pick, no credit drift.
        assert_eq!(swrr_pick(&mut credits, &weights, &[false, false]), None);
    }

    #[test]
    fn inflight_guard_releases_on_drop() {
        let gauge = Arc::new(AtomicU64::new(0));
        gauge.fetch_add(100, Ordering::SeqCst);
        let g = InflightGuard { gauge: gauge.clone(), bytes: 100 };
        assert_eq!(gauge.load(Ordering::SeqCst), 100);
        drop(g);
        assert_eq!(gauge.load(Ordering::SeqCst), 0);
    }
}
