//! Metrics-driven live replanning: decide *when* a sustained load
//! shift justifies re-running the serving plan search.
//!
//! The controller is deliberately pure — tick-based, no clocks, no
//! I/O — so its stability properties are unit-testable. The sampling
//! thread ([`crate::server::Server::start_replanner`]) feeds it one
//! [`ReplanSample`] per interval; [`ReplanController::observe`]
//! returns `Some(trigger)` only when
//!
//! 1. a **baseline** has formed (the mean of the first
//!    [`ReplanConfig::window`] samples),
//! 2. a signal has stayed outside the baseline's **hysteresis band**
//!    for [`ReplanConfig::sustain`] *consecutive* samples (an
//!    excursion that dips back in resets the count), and
//! 3. the **cooldown** from the previous trigger has elapsed.
//!
//! After a trigger the baseline re-forms from scratch, so subsequent
//! shifts are judged against the *new* operating point. The
//! plan-thrash failure mode — noisy metrics causing repeated expensive
//! searches and cutovers — is structurally excluded: inside the band
//! nothing fires, a short excursion is absorbed by `sustain`, and even
//! a genuine oscillation fires at most once per `cooldown` ticks.

use std::time::Duration;

/// One observation of the serving metrics, taken per sampling tick.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplanSample {
    /// 99th-percentile submit-to-response latency, in microseconds.
    pub p99_us: u64,
    /// Cumulative deadline misses (expired + completed late) — the
    /// controller differences consecutive samples into a per-tick rate.
    pub deadline_misses: u64,
    /// Mean requests per dispatched batch
    /// ([`crate::server::ServerMetrics::batch_occupancy`]).
    pub batch_occupancy: f64,
}

/// Knobs of the replan controller. Tick-denominated fields count
/// sampling intervals, so wall-clock behavior scales with
/// [`ReplanConfig::sample_every`].
#[derive(Clone, Debug)]
pub struct ReplanConfig {
    /// Samples averaged into the baseline before shifts are judged.
    pub window: usize,
    /// Consecutive out-of-band samples required to trigger.
    pub sustain: usize,
    /// Relative half-width of the no-trigger band around the baseline
    /// (0.5 ⇒ a signal must move ±50% to count as out-of-band).
    pub hysteresis: f64,
    /// Ticks after a trigger during which no new trigger fires.
    pub cooldown: usize,
    /// Wall-clock spacing between samples (used by the sampling
    /// thread; the controller itself is tick-based).
    pub sample_every: Duration,
}

impl Default for ReplanConfig {
    fn default() -> Self {
        ReplanConfig {
            window: 8,
            sustain: 4,
            hysteresis: 0.5,
            cooldown: 32,
            sample_every: Duration::from_millis(50),
        }
    }
}

impl ReplanConfig {
    /// Overrides from `ZNNI_REPLAN` — a comma list
    /// `window,sustain,hysteresis,cooldown,sample_ms` where any field
    /// may be left empty to keep its default (e.g. `ZNNI_REPLAN=4,,0.3`
    /// changes only the window and the band).
    pub fn from_env() -> Self {
        match std::env::var("ZNNI_REPLAN") {
            Ok(v) => Self::parse(&v),
            Err(_) => ReplanConfig::default(),
        }
    }

    /// Parse one `ZNNI_REPLAN` spec (separated out for testability).
    fn parse(spec: &str) -> Self {
        let mut cfg = ReplanConfig::default();
        let parts: Vec<&str> = spec.split(',').collect();
        if let Some(x) = parts.first().and_then(|s| s.trim().parse::<usize>().ok()) {
            cfg.window = x.max(1);
        }
        if let Some(x) = parts.get(1).and_then(|s| s.trim().parse::<usize>().ok()) {
            cfg.sustain = x.max(1);
        }
        if let Some(x) = parts.get(2).and_then(|s| s.trim().parse::<f64>().ok()) {
            if x > 0.0 && x.is_finite() {
                cfg.hysteresis = x;
            }
        }
        if let Some(x) = parts.get(3).and_then(|s| s.trim().parse::<usize>().ok()) {
            cfg.cooldown = x;
        }
        if let Some(x) = parts.get(4).and_then(|s| s.trim().parse::<u64>().ok()) {
            cfg.sample_every = Duration::from_millis(x.max(1));
        }
        cfg
    }
}

/// Which signal left the band and fired the trigger.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplanTrigger {
    /// p99 latency shifted out of the baseline band.
    P99Shift,
    /// Deadline misses started accruing at an out-of-band rate.
    MissRate,
    /// Batch occupancy shifted out of the baseline band.
    Occupancy,
}

/// Absolute floors under the relative deviation test, one per tracked
/// signal (p99 µs, miss rate per tick, batch occupancy): near-zero
/// baselines would otherwise make any nonzero sample an infinite
/// relative shift. One microsecond of p99, a quarter-miss-per-tick and
/// 0.05 requests of occupancy are below measurement noise.
const DEVIATION_FLOORS: [f64; 3] = [1.0, 0.25, 0.05];

/// The pure hysteresis/cooldown state machine. Feed one sample per
/// sampling tick through [`ReplanController::observe`].
pub struct ReplanController {
    cfg: ReplanConfig,
    /// Samples collected toward the (re-)forming baseline, as
    /// `[p99_us, miss_delta, occupancy]` rows.
    warmup: Vec<[f64; 3]>,
    baseline: Option<[f64; 3]>,
    /// Previous cumulative miss counter, for differencing into a rate.
    last_misses: Option<u64>,
    out_streak: usize,
    cooldown_left: usize,
    triggers: u64,
}

impl ReplanController {
    /// A fresh controller: no baseline yet, no cooldown pending.
    pub fn new(cfg: ReplanConfig) -> Self {
        ReplanController {
            cfg,
            warmup: Vec::new(),
            baseline: None,
            last_misses: None,
            out_streak: 0,
            cooldown_left: 0,
            triggers: 0,
        }
    }

    /// Total triggers fired so far.
    pub fn triggers(&self) -> u64 {
        self.triggers
    }

    /// Ingest one sample; `Some` exactly when a sustained out-of-band
    /// shift should re-run the plan search now.
    pub fn observe(&mut self, s: ReplanSample) -> Option<ReplanTrigger> {
        let miss_delta = match self.last_misses {
            Some(prev) => s.deadline_misses.saturating_sub(prev) as f64,
            None => 0.0,
        };
        self.last_misses = Some(s.deadline_misses);
        let x = [s.p99_us as f64, miss_delta, s.batch_occupancy];
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
        }
        let Some(base) = self.baseline else {
            self.warmup.push(x);
            if self.warmup.len() >= self.cfg.window {
                let mut mean = [0.0f64; 3];
                for row in &self.warmup {
                    for (m, v) in mean.iter_mut().zip(row) {
                        *m += v;
                    }
                }
                for m in &mut mean {
                    *m /= self.warmup.len() as f64;
                }
                self.baseline = Some(mean);
                self.warmup.clear();
            }
            return None;
        };
        let out = (0..3).find(|&i| {
            let dev = (x[i] - base[i]).abs() / base[i].abs().max(DEVIATION_FLOORS[i]);
            dev > self.cfg.hysteresis
        });
        match out {
            Some(i) if self.cooldown_left == 0 => {
                self.out_streak += 1;
                if self.out_streak >= self.cfg.sustain {
                    self.out_streak = 0;
                    // Re-form the baseline at the new operating point;
                    // cooldown guards the interval until it has.
                    self.baseline = None;
                    self.cooldown_left = self.cfg.cooldown;
                    self.triggers += 1;
                    return Some(match i {
                        0 => ReplanTrigger::P99Shift,
                        1 => ReplanTrigger::MissRate,
                        _ => ReplanTrigger::Occupancy,
                    });
                }
            }
            // Out of band but still cooling down: suppressed, and the
            // streak does not accrue toward a fire-on-expiry.
            Some(_) => {}
            None => self.out_streak = 0,
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ReplanConfig {
        ReplanConfig {
            window: 4,
            sustain: 3,
            hysteresis: 0.5,
            cooldown: 8,
            sample_every: Duration::from_millis(1),
        }
    }

    fn p99(us: u64) -> ReplanSample {
        ReplanSample { p99_us: us, deadline_misses: 0, batch_occupancy: 1.0 }
    }

    fn warm(c: &mut ReplanController, us: u64) {
        for _ in 0..cfg().window {
            assert!(c.observe(p99(us)).is_none(), "warmup must not trigger");
        }
    }

    #[test]
    fn noise_within_band_never_triggers() {
        let mut c = ReplanController::new(cfg());
        warm(&mut c, 1000);
        // ±30% jitter around the 1000 µs baseline stays inside the
        // ±50% band no matter how long it persists.
        for i in 0..200 {
            let us = if i % 2 == 0 { 1300 } else { 750 };
            assert!(c.observe(p99(us)).is_none());
        }
        assert_eq!(c.triggers(), 0);
    }

    #[test]
    fn short_excursions_are_absorbed_by_sustain() {
        let mut c = ReplanController::new(cfg());
        warm(&mut c, 1000);
        // Two out-of-band samples (sustain is 3), then back in band —
        // the streak resets, so repeating this forever never fires.
        for _ in 0..50 {
            assert!(c.observe(p99(5000)).is_none());
            assert!(c.observe(p99(5000)).is_none());
            assert!(c.observe(p99(1000)).is_none());
        }
        assert_eq!(c.triggers(), 0);
    }

    #[test]
    fn sustained_shift_triggers_once_then_rebaselines() {
        let mut c = ReplanController::new(cfg());
        warm(&mut c, 1000);
        assert!(c.observe(p99(5000)).is_none());
        assert!(c.observe(p99(5000)).is_none());
        assert_eq!(c.observe(p99(5000)), Some(ReplanTrigger::P99Shift));
        // The shifted level is now the new normal: staying there fires
        // nothing further, ever (cooldown first, then the re-formed
        // baseline absorbs it).
        for _ in 0..100 {
            assert!(c.observe(p99(5000)).is_none());
        }
        assert_eq!(c.triggers(), 1);
    }

    #[test]
    fn cooldown_blocks_oscillation_retrigger() {
        let mut c = ReplanController::new(ReplanConfig {
            window: 2,
            sustain: 2,
            hysteresis: 0.5,
            cooldown: 12,
            sample_every: Duration::from_millis(1),
        });
        for _ in 0..2 {
            assert!(c.observe(p99(1000)).is_none());
        }
        assert!(c.observe(p99(5000)).is_none());
        assert_eq!(c.observe(p99(5000)), Some(ReplanTrigger::P99Shift));
        // The metric oscillates straight back: the baseline re-forms at
        // the old level...
        for _ in 0..2 {
            assert!(c.observe(p99(1000)).is_none());
        }
        // ...and the next excursion — out-of-band and sustained — is
        // still held off for the remainder of the cooldown —
        for _ in 0..9 {
            assert!(c.observe(p99(5000)).is_none());
        }
        assert_eq!(c.triggers(), 1, "cooldown must absorb the oscillation");
        // — then fires exactly once more when it has elapsed.
        assert!(c.observe(p99(5000)).is_none());
        assert_eq!(c.observe(p99(5000)), Some(ReplanTrigger::P99Shift));
        assert_eq!(c.triggers(), 2);
    }

    #[test]
    fn miss_rate_shift_triggers_with_attribution() {
        let mut c = ReplanController::new(cfg());
        // Miss-free baseline at a steady p99.
        warm(&mut c, 1000);
        // Misses start accruing (cumulative counter grows each tick)
        // while p99 stays in band: the trigger must name the miss rate.
        let mut misses = 0;
        let mut got = None;
        for _ in 0..cfg().sustain {
            misses += 2;
            got = c.observe(ReplanSample {
                p99_us: 1000,
                deadline_misses: misses,
                batch_occupancy: 1.0,
            });
        }
        assert_eq!(got, Some(ReplanTrigger::MissRate));
    }

    #[test]
    fn env_spec_parses_with_defaults_for_empty_fields() {
        let c = ReplanConfig::parse("4,2,0.3,16,25");
        assert_eq!(c.window, 4);
        assert_eq!(c.sustain, 2);
        assert!((c.hysteresis - 0.3).abs() < 1e-12);
        assert_eq!(c.cooldown, 16);
        assert_eq!(c.sample_every, Duration::from_millis(25));
        let d = ReplanConfig::parse("6,,nonsense");
        assert_eq!(d.window, 6);
        assert_eq!(d.sustain, ReplanConfig::default().sustain);
        assert!((d.hysteresis - ReplanConfig::default().hysteresis).abs() < 1e-12);
        // Zero-ish fields clamp to sane minima.
        let e = ReplanConfig::parse("0,0,-1,0,0");
        assert_eq!(e.window, 1);
        assert_eq!(e.sustain, 1);
        assert!(e.hysteresis > 0.0);
        assert_eq!(e.cooldown, 0);
        assert_eq!(e.sample_every, Duration::from_millis(1));
    }
}
