//! Asynchronous batched serving frontend: sharded coordinators with
//! admission control.
//!
//! The paper's law — throughput is maximized by amortizing fixed
//! overheads over the largest workload the memory budget admits (§III,
//! Fig. 5) — applies at the *request* level too: aggregating many small
//! inference requests into large coordinator batches is the serving
//! analogue of processing a bigger image. This module is the L4 front
//! that turns a stream of independent client requests into batched
//! [`Coordinator::serve`] calls (threads + channels, zero external
//! deps), in the spirit of PZnet's production scheduling layer
//! (Popovych et al. 2019) and ZNN's work-stealing shards (Zlateski et
//! al. 2015):
//!
//! ```text
//!  clients ──► submit() ──► per-shard bounded queues ──► shard loop
//!              (reject on     (round-robin admission,     (steal when
//!               full/too       Table II byte check)        idle, micro-
//!               large)                                     batch, serve)
//! ```
//!
//! * **Admission control** — every shard queue is bounded
//!   ([`ServerConfig::queue_depth`]); a saturated server *rejects*
//!   ([`RejectReason::QueueFull`]) instead of blocking, returning the
//!   volume to the caller for retry. Requests are sized at submit time
//!   with the same Table II model the optimizer ranks plans with
//!   ([`crate::memory::model::request_memory_bytes`]); a request that
//!   cannot ever fit the shard budget is rejected up front.
//! * **Micro-batching** — a shard coalesces queued requests (waiting at
//!   most [`ServerConfig::max_batch_wait`]) into the largest batch the
//!   memory budget admits: Σ request bytes + the shard's warm worker
//!   arenas ([`crate::optimizer::CompiledPlan::workspace_req`] ×
//!   workers) must stay within [`ServerConfig::memory_budget`].
//! * **Shards + work stealing** — each shard owns a [`Coordinator`]
//!   replica (its own warm per-worker arena set) over one shared
//!   [`CompiledPlan`]; FFT twiddle tables live in the process-wide plan
//!   cache. An idle shard steals from the tail of a busy sibling's
//!   queue before sleeping.
//! * **Deadlines + EDF** — a request may carry a deadline. Each shard
//!   queue is kept in **earliest-deadline-first** order (deadline-free
//!   requests sort last, FIFO among ties), so the micro-batcher always
//!   dispatches the most urgent admissible batch; stealing takes from
//!   the *tail* — the victim's least urgent work. The batcher drops
//!   already-expired requests at dispatch time and answers
//!   [`ServeError::DeadlineExceeded`] instead of wasting compute;
//!   requests that complete past their deadline count into
//!   [`ServerMetrics::completed_late`]. Both kinds of miss aggregate in
//!   [`ServerMetrics::deadline_misses`].
//! * **Fault tolerance** — every batch dispatch runs under
//!   `catch_unwind`: a panic anywhere in a shard's compute path answers
//!   each batch member with a typed [`ServeError::Internal`] (no ticket
//!   ever hangs) and a per-shard **supervisor** restarts the serving
//!   loop with fresh warm arenas, leaving undispatched requests in the
//!   EDF queue. Memory pressure (ledger over budget, or an injected
//!   `arena_take:reserve_fail` failpoint from [`crate::util::faults`])
//!   degrades gracefully instead of panicking: admission tightens
//!   ([`RejectReason::MemoryPressure`]), the largest resident
//!   kernel-spectra cache row is shed (the optimizer's fallback order)
//!   and the micro-batch cap halves until pressure clears. See
//!   `docs/ARCHITECTURE.md`, "Fault tolerance & degradation".
//! * **NUMA placement** — on genuinely multi-node machines (under
//!   `ZNNI_NUMA=auto`, see [`crate::util::numa`]) each shard gets a
//!   home node: its serve workers pin to the node's CPUs and
//!   owner-touch their warm arenas there (first-touched pages land
//!   node-local — the paper's "fast access to more RAM" requires it),
//!   and stealing prefers same-node victims — a cross-node steal only
//!   happens once a victim's queue tail has gone stale. On single-node
//!   hosts none of these paths run: no affinity syscalls, identical
//!   scheduling, bit-identical outputs.
//! * **Live replanning** — a [`replan::ReplanController`] fed from this
//!   server's own metrics decides when a sustained load shift justifies
//!   re-running [`crate::optimizer::search_serving`];
//!   [`Server::swap_plan`] then installs the new compiled plan *between
//!   batches* (each shard's coordinator slot is mutex-held for exactly
//!   one batch), with kernel-spectra caches warmed before cutover and
//!   the serving weights reused, so in-flight batches finish on the
//!   plan that dispatched them and outputs are unchanged across the
//!   swap.
//!
//! Use [`crate::optimizer::search_serving`] to derive both the plan and
//! the [`ServerConfig`] from one search call; with a
//! [`crate::optimizer::CostModel::calibrate_full`]-calibrated cost
//! model, its shard/batch trade-offs use this machine's *measured*
//! dispatch overhead.
//!
//! ```
//! use std::sync::Arc;
//! use znni::device::Device;
//! use znni::net::zoo::tiny_net;
//! use znni::optimizer::{compile, make_weights, search_serving, CostModel, SearchSpace};
//! use znni::server::{Server, ServingLoad};
//! use znni::tensor::{Shape5, Tensor5};
//! use znni::util::pool::{ChipTopology, TaskPool};
//!
//! let net = tiny_net(2);
//! let cm = CostModel::default_rates(2); // or CostModel::calibrate_full / load_profile
//! let space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
//! let load = ServingLoad { clients: 2, volume_extent: 18 };
//! let (plan, cfg) = search_serving(&net, &space, &cm, &load).expect("feasible");
//! let cp = compile(&net, &plan, &make_weights(&net, 1)).unwrap();
//! let pool = Arc::new(TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 }));
//! let server = Server::start(net, cp, cfg, pool).unwrap();
//! let ticket = server.submit(Tensor5::random(Shape5::new(1, 1, 18, 18, 18), 5)).unwrap();
//! let response = ticket.wait().unwrap();
//! assert!(response.output.shape().x > 0);
//! assert_eq!(server.metrics().completed, 1);
//! ```

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::{Coordinator, InferenceRequest, InferenceResponse, Metrics};
use crate::memory::model::request_memory_bytes;
use crate::net::NetSpec;
use crate::optimizer::{CompiledPlan, CostModel, SearchSpace};
use crate::tensor::{Shape5, Tensor5, Vec3};
use crate::util::faults::{self, FaultSite};
use crate::util::pool::TaskPool;
use crate::util::sync::{recover_lock, recover_wait_timeout};

pub mod replan;
pub mod tenants;

/// Latency samples retained for the p50/p99 estimate (ring buffer).
const LATENCY_CAP: usize = 1 << 14;

/// Idle backstop for the shard dispatch wait. Submits and shutdown
/// notify the shard condvar directly, so this bound only limits how
/// long a missed *steal* opportunity (work queued on a sibling) can
/// wait before the idle shard re-polls.
const IDLE_WAIT: Duration = Duration::from_millis(50);

/// Consecutive pressure-free batches a shard must observe before the
/// halved micro-batch cap is doubled one step back toward
/// [`ServerConfig::max_batch_requests`].
const PRESSURE_CLEAR_STREAK: usize = 4;

/// Serving configuration — searched coarsely by
/// [`crate::optimizer::search_serving`] alongside the execution plan.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Number of coordinator shards (each with its own warm arena set).
    pub shards: usize,
    /// Bound of each shard's admission queue; a submit that finds every
    /// queue at this depth is rejected, never blocked.
    pub queue_depth: usize,
    /// Maximum requests coalesced into one coordinator batch.
    pub max_batch_requests: usize,
    /// How long a shard waits for co-batchable requests before
    /// dispatching a partial batch.
    pub max_batch_wait: Duration,
    /// Byte budget one shard's batch may occupy: Σ request (input +
    /// dense output) bytes plus the shard's warm worker arenas.
    pub memory_budget: u64,
    /// Deadline applied by [`Server::submit`] when the caller gives
    /// none. `None` ⇒ requests never expire.
    pub default_deadline: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            shards: 1,
            queue_depth: 8,
            max_batch_requests: 4,
            max_batch_wait: Duration::from_millis(2),
            memory_budget: u64::MAX,
            default_deadline: None,
        }
    }
}

/// Offered load the serving-config search models: how many closed-loop
/// clients drive the server and the cubic extent of their volumes.
#[derive(Clone, Copy, Debug)]
pub struct ServingLoad {
    /// Closed-loop clients driving the server.
    pub clients: usize,
    /// Cubic extent of each client's request volumes.
    pub volume_extent: usize,
}

/// Why a submit was turned away at the door.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// Every shard queue is at `queue_depth` — backpressure; retry.
    QueueFull {
        /// The configured per-shard queue bound that was hit.
        depth: usize,
    },
    /// The request's Table II footprint cannot fit the shard budget
    /// even alone — it will never be admitted.
    TooLarge {
        /// The request's Table II footprint.
        bytes: u64,
        /// The configured per-shard batch budget.
        budget: u64,
    },
    /// Volume shape does not match the served network / patch.
    BadShape {
        /// What was wrong with the shape.
        detail: String,
    },
    /// Volume shape does not fit the tenant it was submitted to: wrong
    /// channel count or smaller than the tenant's patch. Carries the
    /// tenant id and the shapes that tenant accepts, so a client that
    /// mixed up its models can tell *which* plan turned it away.
    WrongTenantShape {
        /// Name of the tenant (network) the submit addressed.
        tenant: String,
        /// Input channel count the tenant accepts.
        f_in: usize,
        /// Minimum spatial extent (the tenant plan's patch).
        min_extent: Vec3,
        /// What was wrong with the submitted shape.
        detail: String,
    },
    /// The tenant's admission quota (its slice of the device budget,
    /// split via `request_memory_bytes`) is exhausted by requests
    /// already queued or in flight. Per-tenant backpressure: *this*
    /// tenant must retry, other tenants keep admitting.
    OverQuota {
        /// Name of the tenant whose quota is exhausted.
        tenant: String,
        /// Bytes currently queued + in flight for the tenant.
        inflight_bytes: u64,
        /// The tenant's quota in bytes.
        quota: u64,
    },
    /// The server is shedding load because its shards are running under
    /// memory pressure: admission operates at a reduced queue depth
    /// until pressure clears. Backpressure; retry later.
    MemoryPressure {
        /// The reduced per-shard admission depth in effect.
        depth: usize,
    },
    /// The server is shutting down.
    ShuttingDown,
}

/// A rejected submit: the volume comes back so the caller can retry.
pub struct Rejected {
    /// The volume, returned intact so the caller can retry.
    pub volume: Tensor5,
    /// Why the request was turned away.
    pub reason: RejectReason,
}

impl std::fmt::Debug for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Rejected")
            .field("volume", &self.volume.shape())
            .field("reason", &self.reason)
            .finish()
    }
}

/// Why an admitted request did not produce an output.
#[derive(Clone, Debug)]
pub enum ServeError {
    /// The request sat in the queue past its deadline.
    DeadlineExceeded {
        /// How long the request waited before being dropped.
        waited: Duration,
    },
    /// The underlying coordinator batch failed.
    Failed(String),
    /// The shard serving this request panicked. The panic was isolated
    /// by `catch_unwind` — every batch member gets this typed answer
    /// instead of a hung ticket — and the supervisor restarted the
    /// shard with fresh warm arenas.
    Internal {
        /// The failpoint site (or raw panic message) the fault was
        /// attributed to.
        site: String,
    },
    /// [`Ticket::wait_timeout`] gave up before the response arrived.
    /// The request is still in flight; waiting again may succeed.
    TimedOut {
        /// How long the caller waited before giving up.
        waited: Duration,
    },
    /// The server dropped before answering.
    Disconnected,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::DeadlineExceeded { waited } => {
                write!(f, "deadline exceeded after {:?} in queue", waited)
            }
            ServeError::Failed(msg) => write!(f, "serve failed: {msg}"),
            ServeError::Internal { site } => {
                write!(f, "internal error isolated at {site}; shard restarted")
            }
            ServeError::TimedOut { waited } => {
                write!(f, "no response within {:?}; request still in flight", waited)
            }
            ServeError::Disconnected => write!(f, "server disconnected"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Handle for one admitted request; redeem with [`Ticket::wait`].
pub struct Ticket {
    /// Request id assigned at submit time.
    pub id: u64,
    rx: Receiver<Result<InferenceResponse, ServeError>>,
}

impl std::fmt::Debug for Ticket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ticket").field("id", &self.id).finish()
    }
}

impl Ticket {
    /// Block until the response (or error) arrives. Panic isolation in
    /// the shard loop guarantees this cannot hang: a panicked batch
    /// answers [`ServeError::Internal`], and a dropped server answers
    /// [`ServeError::Disconnected`].
    pub fn wait(self) -> Result<InferenceResponse, ServeError> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ServeError::Disconnected),
        }
    }

    /// Wait at most `timeout` for the response. On
    /// [`ServeError::TimedOut`] the ticket stays valid — the request is
    /// still in flight, so the caller may wait (or poll) again.
    pub fn wait_timeout(&self, timeout: Duration) -> Result<InferenceResponse, ServeError> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => Err(ServeError::TimedOut { waited: timeout }),
            Err(RecvTimeoutError::Disconnected) => Err(ServeError::Disconnected),
        }
    }
}

/// One queued request.
struct Queued {
    id: u64,
    volume: Tensor5,
    enqueued: Instant,
    deadline: Option<Instant>,
    /// Table II request footprint (input + dense output bytes).
    bytes: u64,
    tx: Sender<Result<InferenceResponse, ServeError>>,
}

/// EDF order: does `a` dispatch no later than `b`? Deadline-free
/// requests sort last; ties (including two `None`s) are FIFO because
/// [`edf_insert`] places a new request *after* its equals.
fn edf_le(a: Option<Instant>, b: Option<Instant>) -> bool {
    match (a, b) {
        (Some(x), Some(y)) => x <= y,
        (Some(_), None) => true,
        (None, d) => d.is_none(),
    }
}

/// Insert into a deadline-sorted queue, keeping earliest-deadline-first
/// order with FIFO tie-breaking. The queue head is therefore always the
/// most urgent request; the tail is what work stealing takes.
fn edf_insert(q: &mut VecDeque<Queued>, item: Queued) {
    let idx = q.partition_point(|x| edf_le(x.deadline, item.deadline));
    q.insert(idx, item);
}

/// Shape admission check shared by the single-model [`Server`] and the
/// multi-tenant [`tenants::TenantServer`]: `None` if `sh` fits a tenant
/// with `f_in` input channels and minimum extent `patch`, else the
/// detail string for [`RejectReason::WrongTenantShape`].
fn tenant_shape_error(sh: Shape5, f_in: usize, patch: Vec3) -> Option<String> {
    if sh.f != f_in {
        return Some(format!("expected {} input channels, got {}", f_in, sh.f));
    }
    for d in 0..3 {
        if patch[d] > [sh.x, sh.y, sh.z][d] {
            return Some(format!("volume {} smaller than patch {:?}", sh, patch));
        }
    }
    None
}

#[derive(Default)]
struct ShardStats {
    batches: u64,
    requests: u64,
    steals: u64,
    /// Steals from a victim sharing this shard's home node (on a
    /// single-node machine every steal is local).
    local_steals: u64,
    /// Cross-node steals, taken only past the staleness threshold.
    remote_steals: u64,
    expired: u64,
    panics: u64,
    restarts: u64,
    metrics: Metrics,
}

struct Shard {
    queue: Mutex<VecDeque<Queued>>,
    cvar: Condvar,
    stats: Mutex<ShardStats>,
}

struct Inner {
    cfg: ServerConfig,
    pool: Arc<TaskPool>,
    /// One coordinator slot per shard. A slot's mutex is held for the
    /// duration of exactly one batch dispatch ([`Inner::run_batch`]),
    /// so [`Inner::swap_plan`] acquiring every slot serializes with
    /// in-flight batches: a cutover lands *between* batches, never
    /// under one.
    coordinators: Vec<Mutex<Coordinator>>,
    shards: Vec<Shard>,
    /// Bytes of one shard's warm worker arenas (workspace_req × workers)
    /// — the fixed term of the batch admission inequality. Atomic
    /// because a live plan swap re-derives it for the new plan.
    shard_ws_bytes: AtomicU64,
    /// The served network spec, kept so a live replan can recompile a
    /// new plan against the same architecture (and the same weights).
    net: NetSpec,
    /// Home NUMA node per shard: `None` everywhere unless
    /// `ZNNI_NUMA=auto` found a multi-node machine. Drives the locality
    /// tiers of [`Inner::try_steal`].
    home_nodes: Vec<Option<usize>>,
    /// Home-node CPU set per shard — handed to each coordinator's serve
    /// workers, and re-applied to replacement coordinators on a swap.
    home_sets: Vec<Option<Arc<Vec<usize>>>>,
    /// Name of the served network — the tenant id carried by
    /// [`RejectReason::WrongTenantShape`] (a single-model server is one
    /// tenant owning the whole budget).
    name: String,
    f_in: usize,
    f_out: usize,
    fov: Vec3,
    /// Patch extent of the *current* plan (swapped with it); submits
    /// validate against this.
    patch: Mutex<Vec3>,
    /// Plan cutovers committed by [`Inner::swap_plan`].
    plan_swaps: AtomicU64,
    shutdown: AtomicBool,
    next_id: AtomicU64,
    rr: AtomicUsize,
    submitted: AtomicU64,
    rejected: AtomicU64,
    expired: AtomicU64,
    completed_late: AtomicU64,
    completed: AtomicU64,
    batches: AtomicU64,
    batch_requests: AtomicU64,
    queue_depth_hwm: AtomicUsize,
    latencies: Mutex<LatencyRing>,
    /// Effective micro-batch request cap: halved under memory pressure,
    /// doubled back toward `cfg.max_batch_requests` as pressure clears.
    batch_limit: AtomicUsize,
    /// Whether admission currently runs at reduced depth.
    pressured: AtomicBool,
    /// Consecutive pressure-free batches observed while `pressured`.
    clear_streak: AtomicUsize,
    panics: AtomicU64,
    restarts: AtomicU64,
    mem_pressure_events: AtomicU64,
    shed_cache_bytes: AtomicU64,
}

#[derive(Default)]
struct LatencyRing {
    samples_us: Vec<u64>,
    next: usize,
}

impl LatencyRing {
    fn record(&mut self, us: u64) {
        if self.samples_us.len() < LATENCY_CAP {
            self.samples_us.push(us);
        } else {
            self.samples_us[self.next % LATENCY_CAP] = us;
        }
        self.next = (self.next + 1) % LATENCY_CAP;
    }

    /// Percentiles from one sorted pass. Callers snapshot the samples
    /// under the lock and sort outside it (see [`Server::metrics`]) so
    /// the response path never waits on a 16K-element sort.
    fn percentiles(samples: &mut [u64], qs: [f64; 2]) -> [Duration; 2] {
        if samples.is_empty() {
            return [Duration::ZERO; 2];
        }
        samples.sort_unstable();
        qs.map(|q| {
            let idx = ((samples.len() - 1) as f64 * q).round() as usize;
            Duration::from_micros(samples[idx.min(samples.len() - 1)])
        })
    }
}

/// Per-shard observability snapshot.
#[derive(Clone, Debug, Default)]
pub struct ShardSnapshot {
    /// Batches this shard dispatched.
    pub batches: u64,
    /// Requests this shard served.
    pub requests: u64,
    /// Requests stolen from siblings' queue tails.
    pub steals: u64,
    /// Steals whose victim shared this shard's home NUMA node (every
    /// steal, on a single-node machine).
    pub local_steals: u64,
    /// Cross-node steals — taken only once the victim's queue tail had
    /// waited past the staleness threshold.
    pub remote_steals: u64,
    /// Requests this shard dropped at dispatch because their deadline
    /// had already passed in the queue.
    pub expired: u64,
    /// Batch panics isolated on this shard (each answered its batch
    /// members with [`ServeError::Internal`]).
    pub panics: u64,
    /// Times the supervisor restarted this shard's serving loop with
    /// fresh warm arenas.
    pub restarts: u64,
    /// Current admission-queue length.
    pub queue_len: usize,
    /// Patches executed (coordinator metric).
    pub patches: usize,
    /// Dense output voxels produced.
    pub voxels: u64,
    /// Summed worker compute seconds.
    pub busy_secs: f64,
    /// Max arena footprint across the shard's workers.
    pub arena_hwm_bytes: u64,
    /// Arena takes that needed fresh memory (0 once warm).
    pub arena_fresh_allocs: u64,
    /// Seconds spent waiting on output-assembly band locks.
    pub assembly_lock_wait_secs: f64,
    /// Resident bytes of the shared kernel-spectra caches as seen by
    /// this shard (one `Arc` per layer, shared across shards — every
    /// shard reports the same allocation).
    pub kernel_cache_bytes: u64,
}

/// Aggregate server metrics: admission counters, latency percentiles,
/// batch occupancy and per-shard arena gauges.
#[derive(Clone, Debug, Default)]
pub struct ServerMetrics {
    /// Requests admitted past the door.
    pub submitted: u64,
    /// Submits turned away (backpressure, size or shape).
    pub rejected: u64,
    /// Requests dropped at dispatch because their deadline passed in queue.
    pub expired: u64,
    /// Requests that were dispatched in time but whose response was
    /// only produced after the deadline had passed — the batch-level
    /// deadline misses EDF ordering works to minimize.
    pub completed_late: u64,
    /// Requests answered with an output.
    pub completed: u64,
    /// Coordinator batches dispatched.
    pub batches: u64,
    /// Total requests across all dispatched batches.
    pub batch_requests: u64,
    /// Deepest any shard queue has been since start.
    pub queue_depth_hwm: usize,
    /// Current total queued requests across shards.
    pub queued_now: usize,
    /// Median submit-to-response latency over the sample ring.
    pub p50_latency: Duration,
    /// 99th-percentile submit-to-response latency.
    pub p99_latency: Duration,
    /// Dense output voxels produced by all shards.
    pub voxels: u64,
    /// Resident bytes of the plan's precomputed kernel-spectra caches —
    /// shared across every shard via `Arc`, so this is the max (not the
    /// sum) of the per-shard reports: the RAM the weight-spectrum cache
    /// is buying throughput with.
    pub kernel_cache_bytes: u64,
    /// Batch panics isolated by `catch_unwind` across all shards: every
    /// affected request was answered [`ServeError::Internal`] instead
    /// of hanging its ticket.
    pub panics: u64,
    /// Shard serving loops restarted by their supervisor after a panic
    /// (with fresh warm arenas; queued requests survive).
    pub restarts: u64,
    /// Times a shard observed memory pressure at batch dispatch (ledger
    /// over budget, or an injected reserve failure).
    pub mem_pressure_events: u64,
    /// Kernel-spectra cache bytes shed (largest row first, mirroring
    /// the optimizer's fallback order) to relieve memory pressure;
    /// caches rebuild lazily once pressure clears.
    pub shed_kernel_cache_bytes: u64,
    /// Current effective micro-batch request cap — halved under memory
    /// pressure, restored to [`ServerConfig::max_batch_requests`] after
    /// a streak of pressure-free batches.
    pub current_max_batch: usize,
    /// Live plan cutovers committed by [`Server::swap_plan`] (directly
    /// or via the replanner) since start.
    pub plan_swaps: u64,
    /// Per-shard observability snapshots.
    pub per_shard: Vec<ShardSnapshot>,
}

impl ServerMetrics {
    /// Mean requests per dispatched batch — the request-level analogue
    /// of the paper's "bigger image" amortization.
    pub fn batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_requests as f64 / self.batches as f64
        }
    }

    /// Total deadline misses: requests expired in the queue (dropped at
    /// dispatch) plus requests completed past their deadline.
    pub fn deadline_misses(&self) -> u64 {
        self.expired + self.completed_late
    }

    /// One-line human-readable summary of the counters.
    pub fn report(&self) -> String {
        let fresh: u64 = self.per_shard.iter().map(|s| s.arena_fresh_allocs).sum();
        let hwm = self.per_shard.iter().map(|s| s.arena_hwm_bytes).max().unwrap_or(0);
        let steals: u64 = self.per_shard.iter().map(|s| s.steals).sum();
        let local: u64 = self.per_shard.iter().map(|s| s.local_steals).sum();
        let remote: u64 = self.per_shard.iter().map(|s| s.remote_steals).sum();
        format!(
            "submitted={} completed={} rejected={} expired={} late={} batches={} occupancy={:.2} \
             queue_hwm={} queued={} p50={:.3}ms p99={:.3}ms steals={} (local={} remote={}) \
             arena_hwm={} arena_fresh_allocs={} kernel_cache={} \
             panics={} restarts={} mem_pressure={} shed_cache={} max_batch={} plan_swaps={}",
            self.submitted,
            self.completed,
            self.rejected,
            self.expired,
            self.completed_late,
            self.batches,
            self.batch_occupancy(),
            self.queue_depth_hwm,
            self.queued_now,
            self.p50_latency.as_secs_f64() * 1e3,
            self.p99_latency.as_secs_f64() * 1e3,
            steals,
            local,
            remote,
            crate::util::human_bytes(hwm),
            fresh,
            crate::util::human_bytes(self.kernel_cache_bytes),
            self.panics,
            self.restarts,
            self.mem_pressure_events,
            crate::util::human_bytes(self.shed_kernel_cache_bytes),
            self.current_max_batch,
            self.plan_swaps,
        )
    }
}

/// The serving frontend. Construct with [`Server::start`]; dropping it
/// drains the queues gracefully (every queued request is served) and
/// joins the shard threads.
pub struct Server {
    inner: Arc<Inner>,
    handles: Vec<JoinHandle<()>>,
    /// Stop flag + thread of the metrics-driven replanner, when
    /// [`Server::start_replanner`] armed one. Joined before the shards
    /// on drop.
    replanner: Option<(Arc<AtomicBool>, JoinHandle<()>)>,
}

impl Server {
    /// Start `cfg.shards` shard threads over replicas of one compiled
    /// plan. Fails at start (plan time) if the memory budget cannot
    /// hold even one shard's warm arenas — never mid-serve.
    pub fn start(
        net: NetSpec,
        plan: CompiledPlan,
        cfg: ServerConfig,
        pool: Arc<TaskPool>,
    ) -> Result<Server> {
        if cfg.shards == 0 || cfg.queue_depth == 0 || cfg.max_batch_requests == 0 {
            bail!("server config must have at least one shard, queue slot and batch slot");
        }
        let plan = Arc::new(plan);
        let shard_workers = (pool.workers() / cfg.shards).max(1);
        // Warm arenas multiply per worker; the resident kernel-spectra
        // row is one shared Arc and is charged once per shard (see
        // `WorkspaceReq::times`). Building the spectra happens below,
        // at start — never on a request's critical path.
        let shard_ws_bytes = plan.workspace_req(shard_workers).times(shard_workers).total();
        if shard_ws_bytes >= cfg.memory_budget {
            bail!(
                "server memory budget {} cannot hold one shard's warm arenas {} — \
                 no request is admissible",
                cfg.memory_budget,
                shard_ws_bytes
            );
        }
        plan.warm_kernel_caches(&pool);
        let fov = net.field_of_view();
        let f_out = net.f_out();
        // Home-node assignment: only on a genuinely multi-node machine
        // under ZNNI_NUMA=auto do shards get CPU sets (round-robin over
        // nodes). Everywhere else every entry stays None and no
        // affinity syscall is ever issued — the provable no-op path.
        let numa = crate::util::numa::topology();
        let active = crate::util::numa::placement_active(numa);
        let mut home_nodes = Vec::with_capacity(cfg.shards);
        let mut home_sets: Vec<Option<Arc<Vec<usize>>>> = Vec::with_capacity(cfg.shards);
        for si in 0..cfg.shards {
            if active {
                let node = crate::util::numa::home_node_for_shard(numa, si);
                home_nodes.push(Some(node));
                home_sets.push(Some(Arc::new(numa.nodes[node].cpus.clone())));
            } else {
                home_nodes.push(None);
                home_sets.push(None);
            }
        }
        let mut coordinators = Vec::with_capacity(cfg.shards);
        for si in 0..cfg.shards {
            let mut c = Coordinator::with_shared_plan(net.clone(), plan.clone())?;
            c.workers = shard_workers;
            c.home_cpus = home_sets[si].clone();
            coordinators.push(c);
        }
        let patch = coordinators[0].patch();
        let coordinators: Vec<Mutex<Coordinator>> =
            coordinators.into_iter().map(Mutex::new).collect();
        let shards = (0..cfg.shards)
            .map(|_| Shard {
                queue: Mutex::new(VecDeque::new()),
                cvar: Condvar::new(),
                stats: Mutex::new(ShardStats::default()),
            })
            .collect();
        let max_batch_requests = cfg.max_batch_requests;
        let inner = Arc::new(Inner {
            cfg,
            pool,
            coordinators,
            shards,
            shard_ws_bytes: AtomicU64::new(shard_ws_bytes),
            home_nodes,
            home_sets,
            name: net.name.clone(),
            f_in: net.f_in,
            f_out,
            net,
            fov,
            patch: Mutex::new(patch),
            plan_swaps: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            completed_late: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_requests: AtomicU64::new(0),
            queue_depth_hwm: AtomicUsize::new(0),
            latencies: Mutex::new(LatencyRing::default()),
            batch_limit: AtomicUsize::new(max_batch_requests),
            pressured: AtomicBool::new(false),
            clear_streak: AtomicUsize::new(0),
            panics: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            mem_pressure_events: AtomicU64::new(0),
            shed_cache_bytes: AtomicU64::new(0),
        });
        let handles = (0..inner.cfg.shards)
            .map(|si| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("znni-shard{si}"))
                    .spawn(move || inner.supervise(si))
                    .expect("spawn shard thread")
            })
            .collect();
        Ok(Server { inner, handles, replanner: None })
    }

    /// The serving configuration.
    pub fn config(&self) -> &ServerConfig {
        &self.inner.cfg
    }

    /// Patch extent the shards execute (the *current* plan's input
    /// extent — a live plan swap updates it).
    pub fn patch(&self) -> Vec3 {
        *recover_lock(&self.inner.patch)
    }

    /// Install a new compiled plan on every shard without stopping the
    /// server: kernel-spectra caches are warmed first (off every
    /// request's critical path), then each shard's coordinator slot is
    /// replaced under its mutex — a shard mid-batch finishes that batch
    /// on the old plan and dispatches its next one on the new plan, so
    /// every in-flight request is answered by the plan that dispatched
    /// it. Fails (leaving the current plan serving untouched) if the
    /// new plan's warm arenas cannot fit the shard batch budget or the
    /// plan is not all-MPF.
    pub fn swap_plan(&self, plan: CompiledPlan) -> Result<()> {
        self.inner.swap_plan(Arc::new(plan))
    }

    /// Arm the metrics-driven replanner: a background thread samples
    /// this server's own metrics (p99 latency, deadline misses, batch
    /// occupancy) every [`replan::ReplanConfig::sample_every`] and
    /// feeds them to a [`replan::ReplanController`]. On a sustained
    /// shift (hysteresis + cooldown in the controller keep noise from
    /// ever thrashing plans) it re-runs
    /// [`crate::optimizer::search_serving`] against `space`/`cost`/
    /// `load` and, when the winner differs from the serving plan, swaps
    /// it in via [`Server::swap_plan`] — reusing the serving weights,
    /// so outputs are unchanged across the cutover. The thread stops
    /// when the server drops.
    pub fn start_replanner(
        &mut self,
        space: SearchSpace,
        cost: CostModel,
        load: ServingLoad,
        rcfg: replan::ReplanConfig,
    ) {
        let inner = self.inner.clone();
        let stop = Arc::new(AtomicBool::new(false));
        let stop_t = stop.clone();
        let handle = std::thread::Builder::new()
            .name("znni-replan".into())
            .spawn(move || {
                let mut ctl = replan::ReplanController::new(rcfg.clone());
                while !stop_t.load(Ordering::SeqCst) {
                    // Sleep in short slices so a server drop never
                    // waits a full sample interval on the join.
                    let mut left = rcfg.sample_every;
                    while left > Duration::ZERO && !stop_t.load(Ordering::SeqCst) {
                        let step = left.min(Duration::from_millis(5));
                        std::thread::sleep(step);
                        left = left.saturating_sub(step);
                    }
                    if stop_t.load(Ordering::SeqCst) {
                        break;
                    }
                    let m = inner.snapshot_metrics();
                    let sample = replan::ReplanSample {
                        p99_us: m.p99_latency.as_micros() as u64,
                        deadline_misses: m.deadline_misses(),
                        batch_occupancy: m.batch_occupancy(),
                    };
                    if ctl.observe(sample).is_some() {
                        inner.replan(&space, &cost, &load);
                    }
                }
            })
            .expect("spawn replanner thread");
        self.replanner = Some((stop, handle));
    }

    /// Submit with the config's default deadline. Never blocks: a full
    /// server answers [`RejectReason::QueueFull`] immediately.
    pub fn submit(&self, volume: Tensor5) -> Result<Ticket, Rejected> {
        self.submit_with_deadline(volume, self.inner.cfg.default_deadline)
    }

    /// Submit with an explicit deadline (measured from now).
    pub fn submit_with_deadline(
        &self,
        volume: Tensor5,
        deadline: Option<Duration>,
    ) -> Result<Ticket, Rejected> {
        let inner = &*self.inner;
        if inner.shutdown.load(Ordering::SeqCst) {
            return Err(Rejected { volume, reason: RejectReason::ShuttingDown });
        }
        let sh = volume.shape();
        if sh.s != 1 {
            let detail = format!("expected a single volume (s = 1), got {}", sh);
            return Err(Rejected { volume, reason: RejectReason::BadShape { detail } });
        }
        let patch = *recover_lock(&inner.patch);
        if let Some(detail) = tenant_shape_error(sh, inner.f_in, patch) {
            let reason = RejectReason::WrongTenantShape {
                tenant: inner.name.clone(),
                f_in: inner.f_in,
                min_extent: patch,
                detail,
            };
            return Err(Rejected { volume, reason });
        }
        let bytes = request_memory_bytes(inner.f_in, inner.f_out, [sh.x, sh.y, sh.z], inner.fov);
        let ws = inner.shard_ws_bytes.load(Ordering::SeqCst);
        if bytes.saturating_add(ws) > inner.cfg.memory_budget {
            return Err(Rejected {
                volume,
                reason: RejectReason::TooLarge { bytes, budget: inner.cfg.memory_budget },
            });
        }
        let id = inner.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        let now = Instant::now();
        let mut item = Some(Queued {
            id,
            volume,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            bytes,
            tx,
        });
        // Round-robin admission with fallback scan: the request lands
        // on the first shard with a free slot (inserted in EDF order,
        // so the shard's head is always its most urgent request); all
        // full ⇒ reject. Under memory pressure the effective depth is
        // halved — the admission half of graceful degradation.
        let pressured = inner.pressured.load(Ordering::SeqCst);
        let eff_depth = if pressured {
            (inner.cfg.queue_depth / 2).max(1)
        } else {
            inner.cfg.queue_depth
        };
        let start = inner.rr.fetch_add(1, Ordering::SeqCst);
        for k in 0..inner.shards.len() {
            let si = (start + k) % inner.shards.len();
            let shard = &inner.shards[si];
            let mut q = recover_lock(&shard.queue);
            if q.len() < eff_depth {
                edf_insert(&mut q, item.take().unwrap());
                let depth = q.len();
                drop(q);
                inner.queue_depth_hwm.fetch_max(depth, Ordering::SeqCst);
                inner.submitted.fetch_add(1, Ordering::SeqCst);
                shard.cvar.notify_one();
                // A queue deeper than one request is stealable work:
                // nudge an idle sibling so its tail does not wait for
                // the IDLE_WAIT backstop to re-poll.
                if depth > 1 && inner.shards.len() > 1 {
                    inner.shards[(si + 1) % inner.shards.len()].cvar.notify_one();
                }
                return Ok(Ticket { id, rx });
            }
        }
        inner.rejected.fetch_add(1, Ordering::SeqCst);
        let volume = item.take().unwrap().volume;
        let reason = if pressured {
            RejectReason::MemoryPressure { depth: eff_depth }
        } else {
            RejectReason::QueueFull { depth: inner.cfg.queue_depth }
        };
        Err(Rejected { volume, reason })
    }

    /// Snapshot the serving metrics.
    pub fn metrics(&self) -> ServerMetrics {
        self.inner.snapshot_metrics()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // The replanner goes first: it must not race a plan swap
        // against the shard shutdown below.
        if let Some((stop, h)) = self.replanner.take() {
            stop.store(true, Ordering::SeqCst);
            let _ = h.join();
        }
        self.inner.shutdown.store(true, Ordering::SeqCst);
        for sh in &self.inner.shards {
            sh.cvar.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Why a shard's serving loop returned to its supervisor.
enum ShardExit {
    /// Graceful shutdown: the server is dropping and every queue this
    /// shard can reach is drained.
    Shutdown,
    /// A batch panicked (isolated in [`Inner::run_batch`]); the
    /// supervisor should reset the shard's arenas and re-enter.
    Restart,
}

/// What happened to one dispatched batch.
enum BatchOutcome {
    /// Every member was answered with a response or a typed error.
    Served,
    /// The batch panicked; members were answered
    /// [`ServeError::Internal`] and the shard needs a restart.
    Panicked,
}

impl Inner {
    /// Shard supervisor: runs the serving loop and, whenever a batch
    /// panic (or a panic escaping the loop itself) kills it, resets the
    /// shard's worker arenas and restarts the loop on the same thread.
    /// Undispatched requests survive untouched in the shard's EDF
    /// queue; the panicked batch's requests were already answered with
    /// [`ServeError::Internal`].
    fn supervise(&self, si: usize) {
        loop {
            match catch_unwind(AssertUnwindSafe(|| self.shard_loop(si))) {
                Ok(ShardExit::Shutdown) => return,
                Ok(ShardExit::Restart) => {}
                Err(_) => {
                    // A panic escaped run_batch's isolation (injected
                    // into the dispatch loop itself, or a bug). Any
                    // Queued senders it held were dropped, so their
                    // tickets resolve `Disconnected` — typed, never a
                    // hang.
                    self.panics.fetch_add(1, Ordering::SeqCst);
                    recover_lock(&self.shards[si].stats).panics += 1;
                }
            }
            self.restarts.fetch_add(1, Ordering::SeqCst);
            recover_lock(&self.shards[si].stats).restarts += 1;
            // A panicked worker's arena was lost mid-flight; drop the
            // survivors too so the restarted shard re-warms a
            // consistent set (steady-state fresh allocs return to zero
            // after the first post-restart batch).
            recover_lock(&self.coordinators[si]).reset_arenas();
        }
    }

    /// Snapshot the serving metrics (shared by [`Server::metrics`] and
    /// the replanner thread, which holds only the `Inner`).
    fn snapshot_metrics(&self) -> ServerMetrics {
        let per_shard: Vec<ShardSnapshot> = self
            .shards
            .iter()
            .map(|sh| {
                let st = recover_lock(&sh.stats);
                ShardSnapshot {
                    batches: st.batches,
                    requests: st.requests,
                    steals: st.steals,
                    local_steals: st.local_steals,
                    remote_steals: st.remote_steals,
                    expired: st.expired,
                    panics: st.panics,
                    restarts: st.restarts,
                    queue_len: recover_lock(&sh.queue).len(),
                    patches: st.metrics.patches,
                    voxels: st.metrics.voxels,
                    busy_secs: st.metrics.busy_secs,
                    arena_hwm_bytes: st.metrics.arena_hwm_bytes,
                    arena_fresh_allocs: st.metrics.arena_fresh_allocs,
                    assembly_lock_wait_secs: st.metrics.assembly_lock_wait_secs,
                    kernel_cache_bytes: st.metrics.kernel_cache_bytes,
                }
            })
            .collect();
        let mut samples = recover_lock(&self.latencies).samples_us.clone();
        let [p50, p99] = LatencyRing::percentiles(&mut samples, [0.50, 0.99]);
        ServerMetrics {
            submitted: self.submitted.load(Ordering::SeqCst),
            rejected: self.rejected.load(Ordering::SeqCst),
            expired: self.expired.load(Ordering::SeqCst),
            completed_late: self.completed_late.load(Ordering::SeqCst),
            completed: self.completed.load(Ordering::SeqCst),
            batches: self.batches.load(Ordering::SeqCst),
            batch_requests: self.batch_requests.load(Ordering::SeqCst),
            queue_depth_hwm: self.queue_depth_hwm.load(Ordering::SeqCst),
            queued_now: per_shard.iter().map(|s| s.queue_len).sum(),
            p50_latency: p50,
            p99_latency: p99,
            voxels: per_shard.iter().map(|s| s.voxels).sum(),
            kernel_cache_bytes: per_shard.iter().map(|s| s.kernel_cache_bytes).max().unwrap_or(0),
            panics: self.panics.load(Ordering::SeqCst),
            restarts: self.restarts.load(Ordering::SeqCst),
            mem_pressure_events: self.mem_pressure_events.load(Ordering::SeqCst),
            shed_kernel_cache_bytes: self.shed_cache_bytes.load(Ordering::SeqCst),
            current_max_batch: self.batch_limit.load(Ordering::SeqCst),
            plan_swaps: self.plan_swaps.load(Ordering::SeqCst),
            per_shard,
        }
    }

    /// Swap every shard's coordinator onto `plan`. Preconditions are
    /// checked before any slot is touched (all-or-nothing): the plan
    /// must be all-MPF and its warm arenas must leave batch headroom.
    /// Kernel-spectra caches are warmed here — off every request's
    /// critical path — and each slot's mutex is then taken in turn, so
    /// a shard mid-batch finishes that batch on the old plan and picks
    /// up the new plan for its next dispatch. Admission geometry (the
    /// patch extent and the warm-arena term) updates last; requests
    /// already queued are served by whichever plan dispatches them —
    /// same net, same weights, so the function they compute is the
    /// same.
    fn swap_plan(&self, plan: Arc<CompiledPlan>) -> Result<()> {
        let shard_workers = (self.pool.workers() / self.cfg.shards).max(1);
        let ws = plan.workspace_req(shard_workers).times(shard_workers).total();
        if ws >= self.cfg.memory_budget {
            bail!(
                "plan swap rejected: new plan's warm arenas {} exceed the shard budget {}",
                ws,
                self.cfg.memory_budget
            );
        }
        plan.warm_kernel_caches(&self.pool);
        let mut fresh = Vec::with_capacity(self.coordinators.len());
        for si in 0..self.coordinators.len() {
            let mut c = Coordinator::with_shared_plan(self.net.clone(), plan.clone())?;
            c.workers = shard_workers;
            c.home_cpus = self.home_sets[si].clone();
            fresh.push(c);
        }
        let new_patch = fresh[0].patch();
        for (slot, c) in self.coordinators.iter().zip(fresh) {
            *recover_lock(slot) = c;
        }
        *recover_lock(&self.patch) = new_patch;
        self.shard_ws_bytes.store(ws, Ordering::SeqCst);
        self.plan_swaps.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// Search + compile + swap, for the replanner thread. Returns
    /// whether a cutover happened: an infeasible search, a winner
    /// identical to the serving plan, or a failed budget check all
    /// leave the current plan serving. The new plan is compiled against
    /// the *serving weights*, so a swap never changes the function the
    /// server computes.
    fn replan(&self, space: &SearchSpace, cost: &CostModel, load: &ServingLoad) -> bool {
        let Some((plan, _)) = crate::optimizer::search_serving(&self.net, space, cost, load)
        else {
            return false;
        };
        let (weights, same) = {
            let cur = recover_lock(&self.coordinators[0]);
            let cp = cur.plan();
            let same = cp.plan.input == plan.input && cp.plan.layers == plan.layers;
            (cp.weights.clone(), same)
        };
        if same {
            return false;
        }
        match crate::optimizer::compile(&self.net, &plan, &weights) {
            Ok(cp) => self.swap_plan(Arc::new(cp)).is_ok(),
            Err(_) => false,
        }
    }

    /// Pop from the shard's own queue head — the earliest deadline,
    /// since [`edf_insert`] keeps the queue EDF-ordered.
    fn try_pop_local(&self, si: usize) -> Option<Queued> {
        recover_lock(&self.shards[si].queue).pop_front()
    }

    /// How stale a *cross-node* victim's queue tail must be before an
    /// idle shard reaches across the interconnect for it. Same-node
    /// steals keep first-touch traffic on one node and happen
    /// immediately; a remote steal drags the request's pages (and its
    /// output's) across nodes, so it only pays once the victim has
    /// demonstrably fallen behind — its tail has waited longer than two
    /// batch windows.
    fn steal_staleness(&self) -> Duration {
        self.cfg.max_batch_wait.max(Duration::from_micros(500)) * 2
    }

    /// Steal one request from the tail of a sibling's queue — the
    /// victim's *least* urgent work, so stealing never takes a request
    /// the victim was about to dispatch against a deadline. Victims are
    /// tried in two locality tiers: same-home-node shards first
    /// (unconditionally), then cross-node shards, but only for work
    /// staler than [`Inner::steal_staleness`].
    fn try_steal(&self, si: usize) -> Option<Queued> {
        let n = self.shards.len();
        let my_node = self.home_nodes[si];
        // Tier 1: same home node. On a single-node machine every shard
        // shares the `None` home, so this tier is the whole ring and
        // stealing behaves exactly as it did before NUMA placement.
        for k in 1..n {
            let vi = (si + k) % n;
            if self.home_nodes[vi] != my_node {
                continue;
            }
            let stolen = recover_lock(&self.shards[vi].queue).pop_back();
            if let Some(q) = stolen {
                let mut st = recover_lock(&self.shards[si].stats);
                st.steals += 1;
                st.local_steals += 1;
                return Some(q);
            }
        }
        // Tier 2: cross-node victims, only for stale tails — locality
        // is worth less than a request visibly rotting in a queue.
        let threshold = self.steal_staleness();
        for k in 1..n {
            let vi = (si + k) % n;
            if self.home_nodes[vi] == my_node {
                continue;
            }
            let mut q = recover_lock(&self.shards[vi].queue);
            let stale = q.back().map(|x| x.enqueued.elapsed() >= threshold).unwrap_or(false);
            let stolen = if stale { q.pop_back() } else { None };
            drop(q);
            if let Some(item) = stolen {
                let mut st = recover_lock(&self.shards[si].stats);
                st.steals += 1;
                st.remote_steals += 1;
                return Some(item);
            }
        }
        None
    }

    /// Block until a request is available (own queue, then steal).
    /// Returns `None` on shutdown once every queue this shard can reach
    /// is drained. Sleeps on the shard condvar — submits and shutdown
    /// notify it, so the [`IDLE_WAIT`] backstop only bounds how long a
    /// steal opportunity on a sibling can go unnoticed (and guards
    /// against a missed wakeup).
    fn next_request(&self, si: usize) -> Option<Queued> {
        loop {
            if let Some(q) = self.try_pop_local(si) {
                return Some(q);
            }
            if let Some(q) = self.try_steal(si) {
                return Some(q);
            }
            let shard = &self.shards[si];
            let guard = recover_lock(&shard.queue);
            if !guard.is_empty() {
                continue;
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            let (g, _) = recover_wait_timeout(&shard.cvar, guard, IDLE_WAIT);
            drop(g);
        }
    }

    fn shard_loop(&self, si: usize) -> ShardExit {
        loop {
            let Some(first) = self.next_request(si) else { return ShardExit::Shutdown };
            let mut batch_bytes = first.bytes;
            let mut batch = vec![first];
            let wait_until = Instant::now() + self.cfg.max_batch_wait;
            // Coalesce from the local queue while the Table II budget,
            // the (pressure-adjusted) batch cap and the wait window
            // allow.
            let limit =
                self.batch_limit.load(Ordering::SeqCst).clamp(1, self.cfg.max_batch_requests);
            let ws = self.shard_ws_bytes.load(Ordering::SeqCst);
            while batch.len() < limit {
                match self.try_pop_local(si) {
                    Some(q) => {
                        if batch_bytes.saturating_add(q.bytes).saturating_add(ws)
                            > self.cfg.memory_budget
                        {
                            // Does not fit this batch — put it back. A
                            // concurrent submit may have inserted an
                            // earlier deadline since the pop, so the
                            // position is recomputed under the lock
                            // (push_front could break the EDF order).
                            edf_insert(&mut recover_lock(&self.shards[si].queue), q);
                            break;
                        }
                        batch_bytes += q.bytes;
                        batch.push(q);
                    }
                    None => {
                        let now = Instant::now();
                        if now >= wait_until || self.shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        let shard = &self.shards[si];
                        let guard = recover_lock(&shard.queue);
                        if guard.is_empty() {
                            let (g, _) =
                                recover_wait_timeout(&shard.cvar, guard, wait_until - now);
                            drop(g);
                        }
                    }
                }
            }
            if let BatchOutcome::Panicked = self.run_batch(si, batch) {
                return ShardExit::Restart;
            }
        }
    }

    /// Per-batch memory-pressure probe: pressure is the process-wide
    /// allocation ledger exceeding the total serving budget, or an
    /// injected `arena_take:reserve_fail` failpoint. Under pressure the
    /// micro-batch cap halves and the largest resident kernel-spectra
    /// cache row is shed (recompute beats an OOM — the same largest-
    /// first order the optimizer's fallback uses); after
    /// [`PRESSURE_CLEAR_STREAK`] pressure-free batches the cap doubles
    /// one step back, and at full cap the caches may rebuild.
    fn check_pressure(&self, si: usize) {
        let injected = faults::fire_reserve(FaultSite::ArenaTake);
        let budget = self.cfg.memory_budget.saturating_mul(self.cfg.shards as u64);
        let over = budget < u64::MAX && crate::memory::current() > budget;
        if injected || over {
            self.mem_pressure_events.fetch_add(1, Ordering::SeqCst);
            self.pressured.store(true, Ordering::SeqCst);
            self.clear_streak.store(0, Ordering::SeqCst);
            let cur = self.batch_limit.load(Ordering::SeqCst);
            self.batch_limit.store((cur / 2).max(1), Ordering::SeqCst);
            let shed = recover_lock(&self.coordinators[si]).plan().shed_largest_kernel_cache();
            if shed > 0 {
                self.shed_cache_bytes.fetch_add(shed, Ordering::SeqCst);
            }
        } else if self.pressured.load(Ordering::SeqCst) {
            let streak = self.clear_streak.fetch_add(1, Ordering::SeqCst) + 1;
            if streak >= PRESSURE_CLEAR_STREAK {
                self.clear_streak.store(0, Ordering::SeqCst);
                let cur = self.batch_limit.load(Ordering::SeqCst);
                let next = (cur.saturating_mul(2)).clamp(1, self.cfg.max_batch_requests);
                self.batch_limit.store(next, Ordering::SeqCst);
                if next >= self.cfg.max_batch_requests {
                    self.pressured.store(false, Ordering::SeqCst);
                    recover_lock(&self.coordinators[si]).plan().restore_kernel_caches();
                }
            }
        }
    }

    fn run_batch(&self, si: usize, batch: Vec<Queued>) -> BatchOutcome {
        self.check_pressure(si);
        // Expire requests whose deadline passed while queued.
        let now = Instant::now();
        let mut reqs = Vec::with_capacity(batch.len());
        let mut metas = Vec::with_capacity(batch.len());
        let mut expired_here = 0u64;
        for q in batch {
            if let Some(d) = q.deadline {
                if now > d {
                    expired_here += 1;
                    self.expired.fetch_add(1, Ordering::SeqCst);
                    let waited = q.enqueued.elapsed();
                    let _ = q.tx.send(Err(ServeError::DeadlineExceeded { waited }));
                    continue;
                }
            }
            reqs.push(InferenceRequest { id: q.id, volume: q.volume });
            metas.push((q.tx, q.enqueued, q.deadline));
        }
        if expired_here > 0 {
            recover_lock(&self.shards[si].stats).expired += expired_here;
        }
        if reqs.is_empty() {
            return BatchOutcome::Served;
        }
        let n = reqs.len();
        // Panic isolation: whatever dies inside the coordinator (a
        // primitive, an arena take, a kernel-cache build, an injected
        // fault) is caught here so every ticket is answered before the
        // supervisor restarts the shard.
        let served = catch_unwind(AssertUnwindSafe(|| {
            faults::fire(FaultSite::ShardDispatch);
            // The slot lock is held for exactly this batch: a
            // concurrent swap_plan waits here, and once it lands the
            // next batch dispatches on the new plan.
            recover_lock(&self.coordinators[si]).serve(reqs, &self.pool)
        }));
        match served {
            Ok(Ok((resps, m))) => {
                self.batches.fetch_add(1, Ordering::SeqCst);
                self.batch_requests.fetch_add(n as u64, Ordering::SeqCst);
                {
                    let mut st = recover_lock(&self.shards[si].stats);
                    st.batches += 1;
                    st.requests += n as u64;
                    st.metrics.merge(&m);
                }
                let done = Instant::now();
                for (mut resp, (tx, enqueued, deadline)) in resps.into_iter().zip(metas) {
                    let lat = done.duration_since(enqueued);
                    resp.latency = lat;
                    if deadline.map(|d| done > d).unwrap_or(false) {
                        // Dispatched in time but finished late — the
                        // response still goes out (the work is done),
                        // and the miss is recorded.
                        self.completed_late.fetch_add(1, Ordering::SeqCst);
                    }
                    recover_lock(&self.latencies).record(lat.as_micros() as u64);
                    self.completed.fetch_add(1, Ordering::SeqCst);
                    let _ = tx.send(Ok(resp));
                }
                BatchOutcome::Served
            }
            Ok(Err(e)) => {
                // Submit-time validation makes per-request failures
                // unreachable; a batch error here is systemic and is
                // reported to every member.
                let msg = e.to_string();
                for (tx, _, _) in metas {
                    let _ = tx.send(Err(ServeError::Failed(msg.clone())));
                }
                BatchOutcome::Served
            }
            Err(payload) => {
                let msg = faults::panic_message(payload.as_ref()).unwrap_or("panic");
                let site = faults::site_of_panic(msg)
                    .map(|s| s.name().to_string())
                    .unwrap_or_else(|| msg.to_string());
                self.panics.fetch_add(1, Ordering::SeqCst);
                recover_lock(&self.shards[si].stats).panics += 1;
                for (tx, _, _) in metas {
                    let _ = tx.send(Err(ServeError::Internal { site: site.clone() }));
                }
                BatchOutcome::Panicked
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::optimizer::{compile, make_weights, search, CostModel, SearchSpace};
    use crate::tensor::Shape5;
    use crate::util::pool::ChipTopology;

    fn setup() -> (NetSpec, CompiledPlan, Arc<TaskPool>) {
        let net = crate::net::zoo::tiny_net(2);
        let cm = CostModel::default_rates(2);
        let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
        space.max_candidates = 2;
        let plan = search(&net, &space, &cm).unwrap();
        let weights = make_weights(&net, 3);
        let cp = compile(&net, &plan, &weights).unwrap();
        let pool = Arc::new(TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 }));
        (net, cp, pool)
    }

    #[test]
    fn serves_one_request_end_to_end() {
        let (net, cp, pool) = setup();
        let fov = net.field_of_view();
        let server = Server::start(net, cp, ServerConfig::default(), pool).unwrap();
        let vol = Tensor5::random(Shape5::new(1, 1, 18, 18, 18), 5);
        let resp = server.submit(vol).unwrap().wait().unwrap();
        let osh = resp.output.shape();
        assert_eq!((osh.x, osh.y, osh.z), (18 - fov[0] + 1, 18 - fov[1] + 1, 18 - fov[2] + 1));
        assert!(resp.latency > Duration::ZERO);
        let m = server.metrics();
        assert_eq!(m.completed, 1);
        assert_eq!(m.rejected, 0);
        assert!(m.batch_occupancy() >= 1.0);
    }

    #[test]
    fn bad_shape_rejected_at_submit() {
        let (net, cp, pool) = setup();
        let name = net.name.clone();
        let server = Server::start(net, cp, ServerConfig::default(), pool).unwrap();
        // Wrong feature count: the typed rejection names the tenant and
        // the shapes it accepts.
        let bad = Tensor5::random(Shape5::new(1, 3, 18, 18, 18), 5);
        let r = server.submit(bad).unwrap_err();
        match &r.reason {
            RejectReason::WrongTenantShape { tenant, f_in, min_extent, .. } => {
                assert_eq!(tenant, &name);
                assert_eq!(*f_in, 1);
                assert_eq!(*min_extent, server.patch());
            }
            other => panic!("expected WrongTenantShape, got {other:?}"),
        }
        assert_eq!(r.volume.shape().f, 3, "volume must come back intact");
        // Smaller than the patch.
        let tiny = Tensor5::random(Shape5::new(1, 1, 4, 4, 4), 5);
        let r = server.submit(tiny).unwrap_err();
        assert!(matches!(r.reason, RejectReason::WrongTenantShape { .. }));
        // A batched (s > 1) submit is malformed for any tenant.
        let batched = Tensor5::random(Shape5::new(2, 1, 18, 18, 18), 5);
        let r = server.submit(batched).unwrap_err();
        assert!(matches!(r.reason, RejectReason::BadShape { .. }));
    }

    #[test]
    fn oversized_request_rejected_up_front() {
        let (net, cp, pool) = setup();
        let ws = cp.workspace_req(pool.workers()).times(pool.workers()).total();
        let cfg = ServerConfig { memory_budget: ws + 1024, ..ServerConfig::default() };
        let server = Server::start(net, cp, cfg, pool).unwrap();
        // 18³ input + dense output is far beyond 1 KiB of batch room.
        let vol = Tensor5::random(Shape5::new(1, 1, 18, 18, 18), 5);
        let r = server.submit(vol).unwrap_err();
        assert!(matches!(r.reason, RejectReason::TooLarge { .. }));
    }

    #[test]
    fn undersized_budget_fails_at_start() {
        let (net, cp, pool) = setup();
        let cfg = ServerConfig { memory_budget: 16, ..ServerConfig::default() };
        assert!(Server::start(net, cp, cfg, pool).is_err());
    }

    #[test]
    fn deadline_already_expired_is_reported() {
        let (net, cp, pool) = setup();
        let server = Server::start(net, cp, ServerConfig::default(), pool).unwrap();
        let vol = Tensor5::random(Shape5::new(1, 1, 18, 18, 18), 5);
        let t = server.submit_with_deadline(vol, Some(Duration::ZERO)).unwrap();
        match t.wait() {
            Err(ServeError::DeadlineExceeded { .. }) => {}
            Err(other) => panic!("expected deadline error, got {other}"),
            Ok(_) => panic!("expected deadline error, got a response"),
        }
        assert_eq!(server.metrics().expired, 1);
    }

    #[test]
    fn sharded_server_answers_many_clients() {
        let (net, cp, pool) = setup();
        let cfg = ServerConfig { shards: 2, queue_depth: 16, ..ServerConfig::default() };
        let server = Server::start(net, cp, cfg, pool).unwrap();
        let tickets: Vec<Ticket> = (0..6)
            .map(|i| server.submit(Tensor5::random(Shape5::new(1, 1, 18, 18, 18), i)).unwrap())
            .collect();
        for t in tickets {
            let resp = t.wait().unwrap();
            assert!(resp.output.data().iter().any(|&v| v != 0.0));
        }
        let m = server.metrics();
        assert_eq!(m.completed, 6);
        assert!(m.batches >= 1);
        assert_eq!(m.per_shard.len(), 2);
        assert!(m.p99_latency >= m.p50_latency);
        // The locality split always accounts for every steal.
        for s in &m.per_shard {
            assert_eq!(s.local_steals + s.remote_steals, s.steals);
        }
    }

    #[test]
    fn swap_plan_cuts_over_and_preserves_the_function() {
        let (net, cp, pool) = setup();
        let weights = cp.weights.clone();
        let server = Server::start(net.clone(), cp, ServerConfig::default(), pool).unwrap();
        let vol = || Tensor5::random(Shape5::new(1, 1, 18, 18, 18), 21);
        let before = server.submit(vol()).unwrap().wait().unwrap();
        // A genuinely different plan over the same weights: force the
        // FFT family.
        let cm = CostModel::default_rates(2);
        let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
        space.algos = vec![crate::memory::model::ConvAlgo::FftTaskParallel];
        space.max_candidates = 2;
        let plan_b = search(&net, &space, &cm).unwrap();
        let cp_b = compile(&net, &plan_b, &weights).unwrap();
        server.swap_plan(cp_b).unwrap();
        let after = server.submit(vol()).unwrap().wait().unwrap();
        let m = server.metrics();
        assert_eq!(m.plan_swaps, 1);
        assert_eq!(m.completed, 2);
        // Same weights, same input ⇒ the same function across the
        // algorithm change (bit-identity against a cold start on the
        // new plan is the integration test's job).
        crate::util::quick::assert_allclose(
            before.output.data(),
            after.output.data(),
            1e-4,
            1e-3,
            "swap preserves the served function",
        );
    }

    #[test]
    fn edf_insert_orders_queue() {
        let now = Instant::now();
        let mk = |id: u64, deadline: Option<Duration>| {
            let (tx, _rx) = channel();
            Queued {
                id,
                volume: Tensor5::zeros(Shape5::new(1, 1, 1, 1, 1)),
                enqueued: now,
                deadline: deadline.map(|d| now + d),
                bytes: 0,
                tx,
            }
        };
        let mut q = VecDeque::new();
        edf_insert(&mut q, mk(0, Some(Duration::from_secs(10)))); // far
        edf_insert(&mut q, mk(1, None)); // no deadline: last
        edf_insert(&mut q, mk(2, Some(Duration::from_secs(1)))); // near
        edf_insert(&mut q, mk(3, Some(Duration::from_secs(5)))); // mid
        edf_insert(&mut q, mk(4, None)); // FIFO among deadline-free
        edf_insert(&mut q, mk(5, Some(Duration::from_secs(1)))); // FIFO tie after id 2
        let order: Vec<u64> = q.iter().map(|x| x.id).collect();
        assert_eq!(order, vec![2, 5, 3, 0, 1, 4]);
        // Head = most urgent (what the shard dispatches), tail = least
        // urgent (what a sibling steals).
        assert_eq!(q.pop_front().unwrap().id, 2);
        assert_eq!(q.pop_back().unwrap().id, 4);
    }

    #[test]
    fn kernel_cache_bytes_surface_in_metrics() {
        // Force the FFT family so the searched plan caches its kernel
        // spectra; the resident bytes must be visible in the aggregate
        // and per-shard metrics (same shared Arc, so max == per-shard).
        let net = crate::net::zoo::tiny_net(2);
        let cm = CostModel::default_rates(2);
        let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
        space.algos = vec![crate::memory::model::ConvAlgo::FftTaskParallel];
        space.max_candidates = 2;
        let plan = search(&net, &space, &cm).unwrap();
        let cached_planned = plan.kernel_cache_bytes;
        let weights = make_weights(&net, 3);
        let cp = compile(&net, &plan, &weights).unwrap();
        let pool = Arc::new(TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 }));
        let cfg = ServerConfig { shards: 2, queue_depth: 8, ..ServerConfig::default() };
        let server = Server::start(net, cp, cfg, pool).unwrap();
        let vol = Tensor5::random(Shape5::new(1, 1, 18, 18, 18), 5);
        server.submit(vol).unwrap().wait().unwrap();
        let m = server.metrics();
        // The kill switch (ZNNI_KERNEL_CACHE=off) zeroes both sides;
        // either way the gauge must agree with the plan's decision.
        use crate::conv::precomp::{cache_mode, CacheMode};
        if cached_planned > 0 && cache_mode() != CacheMode::Off {
            assert_eq!(m.kernel_cache_bytes, cached_planned);
        } else {
            assert_eq!(m.kernel_cache_bytes, 0);
        }
    }

    #[test]
    fn expired_requests_count_as_deadline_misses() {
        let (net, cp, pool) = setup();
        let server = Server::start(net, cp, ServerConfig::default(), pool).unwrap();
        let vol = Tensor5::random(Shape5::new(1, 1, 18, 18, 18), 5);
        let t = server.submit_with_deadline(vol, Some(Duration::ZERO)).unwrap();
        assert!(t.wait().is_err());
        let m = server.metrics();
        assert_eq!(m.deadline_misses(), 1);
        assert_eq!(m.per_shard.iter().map(|s| s.expired).sum::<u64>(), 1);
    }

    #[test]
    fn latency_ring_percentiles() {
        let mut r = LatencyRing::default();
        for us in [1000u64, 30, 10, 40, 20] {
            r.record(us);
        }
        let mut s = r.samples_us.clone();
        let [p50, p99] = LatencyRing::percentiles(&mut s, [0.50, 0.99]);
        assert_eq!(p50, Duration::from_micros(30));
        assert_eq!(p99, Duration::from_micros(1000));
        let [z50, _] = LatencyRing::percentiles(&mut [], [0.50, 0.99]);
        assert_eq!(z50, Duration::ZERO);
    }
}
