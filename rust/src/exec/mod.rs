//! Execution contexts: plan memory once, allocate never on the hot path.
//!
//! The paper's central claim is that memory overhead caps throughput —
//! the winning primitive is the one whose working set fits the biggest
//! patch (§II, Table II). That only pays off in steady-state serving if
//! execution *stays inside* that working set instead of churning the
//! allocator on every patch. This module brings the statically planned
//! buffer-reuse discipline of PZnet (Popovych et al. 2019) and the ZNN
//! training pipelines (Zlateski et al. 2015) to the whole stack:
//!
//! * [`Arena`] — a slab of reusable `f32` / [`Complex32`] buffers keyed
//!   by exact length. Buffers cycle take → use → put/retire; after a
//!   one-patch warmup a fixed workload allocates nothing.
//! * [`ExecCtx`] — what every [`crate::layers::LayerPrimitive`] executes
//!   against: the [`TaskPool`] plus an arena. Output tensors, FFT
//!   spectra and workspaces are all drawn from it.
//! * [`WorkspaceReq`] — the plan-time contract: `optimizer::compile`
//!   sizes the arena up front from the same Table II model the search
//!   ranks plans with ([`crate::optimizer::CompiledPlan::workspace_req`]).
//!   An undersized budget fails loudly at [`ExecCtx::reserve`] time —
//!   never mid-execution.
//! * a process-wide FFT plan cache keyed by (padded shape, algorithm
//!   family) — twiddle tables are built once per shape, not per call.
//!
//! Ledger contract (see [`crate::memory`]): bytes handed out by an arena
//! are registered with the process ledger exactly like direct
//! allocations, so Table II peak measurements are unchanged; bytes
//! *idle* in an arena free list are tracked by the arena gauges instead
//! (`memory::arena_hwm`, `memory::arena_fresh_allocs`). Reused buffers
//! register via `memory::alloc_recycled`, which does not count an
//! allocation event — `memory::alloc_events()` therefore counts exactly
//! the transient (fresh) allocations a workload performs.
//!
//! ```
//! use znni::exec::ExecCtx;
//! use znni::tensor::Shape5;
//! use znni::util::pool::{ChipTopology, TaskPool};
//!
//! let pool = TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 });
//! let mut ctx = ExecCtx::new(&pool);
//! let t = ctx.tensor5(Shape5::new(1, 1, 4, 4, 4)); // drawn from the arena
//! ctx.retire(t); // recycle the backing store
//! assert_eq!(ctx.arena.stats().fresh_allocs, 1);
//! let _warm = ctx.tensor5(Shape5::new(1, 1, 4, 4, 4));
//! assert_eq!(ctx.arena.stats().fresh_allocs, 1); // same length: reused, not allocated
//! ```

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Result};

use crate::fft::batched::BatchedFft3;
use crate::fft::Fft3;
use crate::memory;
use crate::tensor::{Complex32, Shape5, Tensor5, Vec3};
use crate::util::faults::{self, FaultSite};
use crate::util::pool::TaskPool;
use crate::util::sync::recover_lock;

/// Bytes an execution needs from the arena, computed at plan time from
/// the Table II model (input + output + transients of the worst layer),
/// plus the resident kernel-spectra row the weight-spectrum cache adds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkspaceReq {
    /// Arena bytes of the working set (cycled per patch).
    pub bytes: u64,
    /// Long-lived precomputed kernel-spectra bytes
    /// ([`crate::conv::precomp::PrecomputedKernels`]) resident beside
    /// the arena for the plan's lifetime. Never drawn from the arena —
    /// excluded from [`Arena::reserve`]'s budget check — and shared via
    /// `Arc` across workers and shards, so [`WorkspaceReq::times`] does
    /// not multiply it.
    pub resident_bytes: u64,
}

impl WorkspaceReq {
    /// The empty requirement.
    pub const ZERO: WorkspaceReq = WorkspaceReq { bytes: 0, resident_bytes: 0 };

    /// Pointwise maximum of both rows.
    pub fn max(self, other: WorkspaceReq) -> WorkspaceReq {
        WorkspaceReq {
            bytes: self.bytes.max(other.bytes),
            resident_bytes: self.resident_bytes.max(other.resident_bytes),
        }
    }

    /// Combine the requirements of two layers of one plan: arena bytes
    /// take the max (layers share the arena), resident kernel-spectra
    /// bytes sum (every cached layer's spectra stay live for the whole
    /// run).
    pub fn stack(self, other: WorkspaceReq) -> WorkspaceReq {
        WorkspaceReq {
            bytes: self.bytes.max(other.bytes),
            resident_bytes: self.resident_bytes.saturating_add(other.resident_bytes),
        }
    }

    /// Requirement of `n` independent copies of this working set —
    /// e.g. the warm per-worker arenas of one coordinator shard, which
    /// do *not* share buffers and therefore sum, not max. The resident
    /// kernel-spectra row is one shared allocation and stays unscaled.
    pub fn times(self, n: usize) -> WorkspaceReq {
        WorkspaceReq {
            bytes: self.bytes.saturating_mul(n as u64),
            resident_bytes: self.resident_bytes,
        }
    }

    /// Everything this requirement pins in RAM: arena working set plus
    /// the resident kernel-spectra row.
    pub fn total(self) -> u64 {
        self.bytes.saturating_add(self.resident_bytes)
    }
}

/// Snapshot of an arena's accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct ArenaStats {
    /// Takes that had to allocate fresh backing store.
    pub fresh_allocs: u64,
    /// Takes served from the free lists.
    pub reuses: u64,
    /// Bytes idle in the free lists right now.
    pub held_bytes: u64,
    /// Raw workspace bytes handed out and not yet `put_*` back.
    /// Tensor-backing bytes are transferred out of this count at
    /// creation (a tensor may outlive the arena or be retired into a
    /// different one), so the gauge stays drift-free on long streams.
    pub outstanding_bytes: u64,
    /// High-water mark of `held + outstanding`.
    pub hwm_bytes: u64,
    /// The plan-time budget, if one was set.
    pub planned_bytes: Option<u64>,
}

impl ArenaStats {
    /// Bytes by which the arena's cache footprint exceeded the
    /// plan-time budget (0 when unbudgeted or within plan).
    /// Informational: the budget is the per-layer Table II max that
    /// gates plan admission, while the footprint is the union of layer
    /// working sets cached across a plan — some overshoot on
    /// multi-layer plans is expected and bounded by the bucket cap.
    pub fn over_budget_bytes(&self) -> u64 {
        match self.planned_bytes {
            Some(b) => self.hwm_bytes.saturating_sub(b),
            None => 0,
        }
    }
}

/// Default per-length free-list cap; raised to the worker count by
/// [`ExecCtx`] so per-worker buffer sets (e.g. the direct conv's T
/// temporaries) survive a full put/take cycle on any pool size.
const DEFAULT_BUCKET_CAP: usize = 8;

/// A slab arena of reusable buffers, keyed by exact element count.
///
/// Exact-length bucketing keeps the ledger arithmetic precise and fits
/// the steady-state serving shape: every patch of a compiled plan takes
/// the same sequence of buffer sizes, so after one warm patch every
/// take hits. Free lists keep at most `bucket_cap` buffers per length;
/// beyond that a returned buffer is genuinely dropped, bounding idle
/// memory.
pub struct Arena {
    f32_free: HashMap<usize, Vec<Vec<f32>>>,
    c32_free: HashMap<usize, Vec<Vec<Complex32>>>,
    u16_free: HashMap<usize, Vec<Vec<u16>>>,
    budget: Option<u64>,
    bucket_cap: usize,
    held: u64,
    outstanding: u64,
    hwm: u64,
    fresh: u64,
    reuses: u64,
}

impl Default for Arena {
    fn default() -> Self {
        Arena {
            f32_free: HashMap::new(),
            c32_free: HashMap::new(),
            u16_free: HashMap::new(),
            budget: None,
            bucket_cap: DEFAULT_BUCKET_CAP,
            held: 0,
            outstanding: 0,
            hwm: 0,
            fresh: 0,
            reuses: 0,
        }
    }
}

impl Arena {
    /// Empty, unbudgeted arena.
    pub fn new() -> Self {
        Arena::default()
    }

    /// Ensure per-length free lists can hold at least `n` buffers.
    /// Contexts raise this to the pool's worker count so per-worker
    /// buffer sets (one temp image per worker, one spectrum per chip)
    /// are fully retained across the put/take cycle.
    pub fn set_bucket_cap_at_least(&mut self, n: usize) {
        if n > self.bucket_cap {
            self.bucket_cap = n;
        }
    }

    /// Arena with a plan-time byte budget. The budget is enforced by
    /// [`Arena::reserve`] *at plan time*; execution never panics on it —
    /// overshoot is recorded in [`ArenaStats::over_budget_bytes`].
    pub fn with_budget(bytes: u64) -> Self {
        Arena { budget: Some(bytes), ..Arena::default() }
    }

    /// Plan-time admission check: fail loudly *before* execution if the
    /// planned working set cannot fit the budget.
    pub fn reserve(&mut self, req: &WorkspaceReq) -> Result<()> {
        if let Some(budget) = self.budget {
            if req.bytes > budget {
                bail!(
                    "arena undersized at plan time: workspace requires {} bytes, budget is {} bytes",
                    req.bytes,
                    budget
                );
            }
        }
        Ok(())
    }

    /// Snapshot the accounting counters.
    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            fresh_allocs: self.fresh,
            reuses: self.reuses,
            held_bytes: self.held,
            outstanding_bytes: self.outstanding,
            hwm_bytes: self.hwm,
            planned_bytes: self.budget,
        }
    }

    fn note_hwm(&mut self) {
        let footprint = self.held + self.outstanding;
        if footprint > self.hwm {
            self.hwm = footprint;
        }
    }

    /// Owner-touch warmup: rewrite one element per 4 KiB page of every
    /// buffer idle in the free lists, from the *calling* thread.
    ///
    /// Linux commits anonymous pages on first touch, on the node of the
    /// thread that touches them — so an arena whose buffers were
    /// allocated (or migrated) on the wrong node serves remote-DRAM
    /// reads forever after. A pinned shard worker calls this after
    /// binding to its home node: already-local pages are a cheap
    /// read+write, while pages still untouched (fresh `vec![0; n]`
    /// allocations are copy-on-write mappings of the zero page) get
    /// committed node-local. Contents are preserved (each page's first
    /// element is rewritten with its own value, via volatile accesses
    /// the compiler cannot elide). Returns the bytes walked.
    pub fn touch_pages(&mut self) -> u64 {
        const PAGE: usize = 4096;
        fn touch<T>(bufs: &mut HashMap<usize, Vec<Vec<T>>>, elem_bytes: usize) -> u64 {
            let stride = PAGE / elem_bytes;
            let mut bytes = 0u64;
            for bucket in bufs.values_mut() {
                for buf in bucket.iter_mut() {
                    let p = buf.as_mut_ptr();
                    let mut i = 0;
                    while i < buf.len() {
                        // SAFETY: i < len; volatile keeps the dead
                        // store from being optimised away.
                        unsafe {
                            let v = std::ptr::read_volatile(p.add(i));
                            std::ptr::write_volatile(p.add(i), v);
                        }
                        i += stride;
                    }
                    bytes += (buf.len() * elem_bytes) as u64;
                }
            }
            bytes
        }
        touch(&mut self.f32_free, 4) + touch(&mut self.c32_free, 8) + touch(&mut self.u16_free, 2)
    }

    /// f32 buffer of exactly `len` elements with **unspecified**
    /// contents (recycled data). For workspaces the caller fully
    /// overwrites before reading — skips a working-set-sized memset on
    /// the hot path. Use [`Arena::take_f32`] when zeroing matters.
    pub fn take_f32_raw(&mut self, len: usize) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        faults::fire(FaultSite::ArenaTake);
        let bytes = (len * 4) as u64;
        if let Some(v) = self.f32_free.get_mut(&len).and_then(Vec::pop) {
            self.held -= bytes;
            self.outstanding += bytes;
            self.reuses += 1;
            memory::alloc_recycled(bytes);
            memory::arena_gauge(-(bytes as i64), bytes as i64);
            self.note_hwm();
            return v;
        }
        self.outstanding += bytes;
        self.fresh += 1;
        memory::alloc(bytes);
        memory::arena_fresh_event();
        memory::arena_gauge(0, bytes as i64);
        self.note_hwm();
        vec![0.0; len]
    }

    /// Zeroed f32 buffer of exactly `len` elements.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_f32_raw(len);
        v.fill(0.0);
        v
    }

    /// Return an f32 buffer to the free list.
    pub fn put_f32(&mut self, v: Vec<f32>) {
        let len = v.len();
        if len == 0 {
            return;
        }
        let bytes = (len * 4) as u64;
        memory::free(bytes);
        let dec = bytes.min(self.outstanding);
        self.outstanding -= dec;
        let bucket = self.f32_free.entry(len).or_default();
        if bucket.len() < self.bucket_cap {
            bucket.push(v);
            self.held += bytes;
            memory::arena_gauge(bytes as i64, -(dec as i64));
        } else {
            memory::arena_gauge(0, -(dec as i64));
        }
        self.note_hwm();
    }

    /// Complex buffer of exactly `len` elements with **unspecified**
    /// contents — see [`Arena::take_f32_raw`].
    pub fn take_c32_raw(&mut self, len: usize) -> Vec<Complex32> {
        if len == 0 {
            return Vec::new();
        }
        faults::fire(FaultSite::ArenaTake);
        let bytes = (len * 8) as u64;
        if let Some(v) = self.c32_free.get_mut(&len).and_then(Vec::pop) {
            self.held -= bytes;
            self.outstanding += bytes;
            self.reuses += 1;
            memory::alloc_recycled(bytes);
            memory::arena_gauge(-(bytes as i64), bytes as i64);
            self.note_hwm();
            return v;
        }
        self.outstanding += bytes;
        self.fresh += 1;
        memory::alloc(bytes);
        memory::arena_fresh_event();
        memory::arena_gauge(0, bytes as i64);
        self.note_hwm();
        vec![Complex32::ZERO; len]
    }

    /// Zeroed complex buffer of exactly `len` elements.
    pub fn take_c32(&mut self, len: usize) -> Vec<Complex32> {
        let mut v = self.take_c32_raw(len);
        v.fill(Complex32::ZERO);
        v
    }

    /// Return a complex buffer to the free list.
    pub fn put_c32(&mut self, v: Vec<Complex32>) {
        let len = v.len();
        if len == 0 {
            return;
        }
        let bytes = (len * 8) as u64;
        memory::free(bytes);
        let dec = bytes.min(self.outstanding);
        self.outstanding -= dec;
        let bucket = self.c32_free.entry(len).or_default();
        if bucket.len() < self.bucket_cap {
            bucket.push(v);
            self.held += bytes;
            memory::arena_gauge(bytes as i64, -(dec as i64));
        } else {
            memory::arena_gauge(0, -(dec as i64));
        }
        self.note_hwm();
    }

    /// Half-width storage buffer (f16/bf16 bit patterns, 2 bytes per
    /// element) with **unspecified** contents — the narrow kernels
    /// fully overwrite before anything reads. Used by
    /// [`crate::layers::ConvLayer`] to stage reduced-precision
    /// activations between layers; accounted in the ledger and gauges
    /// exactly like the f32/c32 families, at the 2-byte width.
    pub fn take_u16_raw(&mut self, len: usize) -> Vec<u16> {
        if len == 0 {
            return Vec::new();
        }
        faults::fire(FaultSite::ArenaTake);
        let bytes = (len * 2) as u64;
        if let Some(v) = self.u16_free.get_mut(&len).and_then(Vec::pop) {
            self.held -= bytes;
            self.outstanding += bytes;
            self.reuses += 1;
            memory::alloc_recycled(bytes);
            memory::arena_gauge(-(bytes as i64), bytes as i64);
            self.note_hwm();
            return v;
        }
        self.outstanding += bytes;
        self.fresh += 1;
        memory::alloc(bytes);
        memory::arena_fresh_event();
        memory::arena_gauge(0, bytes as i64);
        self.note_hwm();
        vec![0; len]
    }

    /// Return a half-width storage buffer to the free list.
    pub fn put_u16(&mut self, v: Vec<u16>) {
        let len = v.len();
        if len == 0 {
            return;
        }
        let bytes = (len * 2) as u64;
        memory::free(bytes);
        let dec = bytes.min(self.outstanding);
        self.outstanding -= dec;
        let bucket = self.u16_free.entry(len).or_default();
        if bucket.len() < self.bucket_cap {
            bucket.push(v);
            self.held += bytes;
            memory::arena_gauge(bytes as i64, -(dec as i64));
        } else {
            memory::arena_gauge(0, -(dec as i64));
        }
        self.note_hwm();
    }

    /// Mark `bytes` of a just-taken buffer as transferred out of this
    /// arena's custody (ownership moves to a tensor that may outlive
    /// the arena). Keeps `outstanding` balanced by raw workspace
    /// takes/puts alone, so long streams whose output tensors migrate
    /// to other owners do not drift the footprint gauges.
    fn note_transfer(&mut self, bytes: u64) {
        let dec = bytes.min(self.outstanding);
        self.outstanding -= dec;
        memory::arena_gauge(0, -(dec as i64));
    }

    /// Zeroed tensor-backing buffer: a take whose bytes immediately
    /// leave the arena's outstanding count (see [`Arena::note_transfer`]).
    pub(crate) fn take_tensor_f32(&mut self, len: usize) -> Vec<f32> {
        let v = self.take_f32(len);
        self.note_transfer((len * 4) as u64);
        v
    }

    /// Retire a tensor's backing store into the free list (the arena
    /// analogue of dropping it — the ledger sees the same `free`).
    /// Tensors were transferred out of `outstanding` at creation (and
    /// may come from *another* context entirely), so this only stashes
    /// the buffer.
    pub fn retire_tensor(&mut self, t: Tensor5) {
        let (_, data) = t.into_raw();
        let len = data.len();
        if len == 0 {
            return;
        }
        let bytes = (len * 4) as u64;
        memory::free(bytes);
        let bucket = self.f32_free.entry(len).or_default();
        if bucket.len() < self.bucket_cap {
            bucket.push(data);
            self.held += bytes;
            memory::arena_gauge(bytes as i64, 0);
        }
        self.note_hwm();
    }
}

impl Drop for Arena {
    fn drop(&mut self) {
        // Idle buffers are dropped with the arena; outstanding buffers
        // now live entirely outside any arena — stop gauging both.
        memory::arena_gauge(-(self.held as i64), -(self.outstanding as i64));
    }
}

/// Everything a primitive needs to execute: the worker pool plus an
/// arena of reusable buffers. One per pipeline stage / coordinator
/// worker; reused across patches so steady state allocates nothing.
pub struct ExecCtx<'p> {
    pool: &'p TaskPool,
    /// The buffer arena (public so callers can snapshot its stats).
    pub arena: Arena,
}

impl<'p> ExecCtx<'p> {
    /// Context over a fresh, unbudgeted arena.
    pub fn new(pool: &'p TaskPool) -> ExecCtx<'p> {
        Self::from_arena(pool, Arena::new())
    }

    /// Context with a plan-time byte budget (see [`Arena::with_budget`]).
    pub fn with_budget(pool: &'p TaskPool, bytes: u64) -> ExecCtx<'p> {
        Self::from_arena(pool, Arena::with_budget(bytes))
    }

    /// Rehydrate a context from a warm arena (coordinator workers keep
    /// arenas across `serve` calls). The arena's per-length cap is
    /// raised to the pool's worker count so per-worker buffer sets
    /// survive the put/take cycle on any topology.
    pub fn from_arena(pool: &'p TaskPool, mut arena: Arena) -> ExecCtx<'p> {
        arena.set_bucket_cap_at_least(pool.workers());
        ExecCtx { pool, arena }
    }

    /// Take the arena back out (to persist it past this context).
    pub fn into_arena(self) -> Arena {
        self.arena
    }

    /// The worker pool. The returned reference carries the pool's own
    /// lifetime, so holding it does not borrow the context.
    pub fn pool(&self) -> &'p TaskPool {
        self.pool
    }

    /// Plan-time admission check — see [`Arena::reserve`].
    pub fn reserve(&mut self, req: &WorkspaceReq) -> Result<()> {
        self.arena.reserve(req)
    }

    /// Zeroed tensor whose backing store comes from the arena.
    pub fn tensor5(&mut self, shape: Shape5) -> Tensor5 {
        let data = self.arena.take_tensor_f32(shape.len());
        Tensor5::from_arena(shape, data)
    }

    /// Recycle a tensor's backing store into the arena.
    pub fn retire(&mut self, t: Tensor5) {
        self.arena.retire_tensor(t);
    }

    /// Zeroed f32 buffer from the arena.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        self.arena.take_f32(len)
    }

    /// Unzeroed workspace take — caller fully overwrites before reading.
    pub fn take_f32_raw(&mut self, len: usize) -> Vec<f32> {
        self.arena.take_f32_raw(len)
    }

    /// Recycle an f32 buffer into the arena.
    pub fn put_f32(&mut self, v: Vec<f32>) {
        self.arena.put_f32(v)
    }

    /// Zeroed complex buffer from the arena.
    pub fn take_c32(&mut self, len: usize) -> Vec<Complex32> {
        self.arena.take_c32(len)
    }

    /// Unzeroed workspace take — caller fully overwrites before reading.
    pub fn take_c32_raw(&mut self, len: usize) -> Vec<Complex32> {
        self.arena.take_c32_raw(len)
    }

    /// Recycle a complex buffer into the arena.
    pub fn put_c32(&mut self, v: Vec<Complex32>) {
        self.arena.put_c32(v)
    }

    /// Unzeroed half-width storage buffer (f16/bf16 bits) — see
    /// [`Arena::take_u16_raw`].
    pub fn take_u16_raw(&mut self, len: usize) -> Vec<u16> {
        self.arena.take_u16_raw(len)
    }

    /// Recycle a half-width storage buffer into the arena.
    pub fn put_u16(&mut self, v: Vec<u16>) {
        self.arena.put_u16(v)
    }

    /// Cached serial/parallel 3D FFT plan for the given padded extent.
    pub fn fft3(&mut self, padded: Vec3) -> Arc<Fft3> {
        fft3_plan(padded)
    }

    /// Cached batched (GPU-scheme) 3D FFT plan.
    pub fn batched_fft3(&mut self, dims: Vec3, padded: Vec3) -> Arc<BatchedFft3> {
        batched_fft3_plan(dims, padded)
    }
}

// ---------------------------------------------------------------------
// Process-wide FFT plan cache. Plans are immutable (twiddle tables +
// factorisations), so sharing one Arc per key across all contexts and
// workers is sound; building them per call was pure waste.
// ---------------------------------------------------------------------

fn fft3_cache() -> &'static Mutex<HashMap<Vec3, Arc<Fft3>>> {
    static CACHE: OnceLock<Mutex<HashMap<Vec3, Arc<Fft3>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

fn batched_cache() -> &'static Mutex<HashMap<(Vec3, Vec3), Arc<BatchedFft3>>> {
    static CACHE: OnceLock<Mutex<HashMap<(Vec3, Vec3), Arc<BatchedFft3>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Shared plan for serial/data-parallel 3D FFTs padded to `padded`.
pub fn fft3_plan(padded: Vec3) -> Arc<Fft3> {
    let mut c = recover_lock(fft3_cache());
    c.entry(padded).or_insert_with(|| Arc::new(Fft3::new(padded))).clone()
}

/// Shared plan for the batched GPU-scheme FFT of `dims` padded to
/// `padded` (the kernel and image transforms of one layer are distinct
/// keys because their pruning differs).
pub fn batched_fft3_plan(dims: Vec3, padded: Vec3) -> Arc<BatchedFft3> {
    let mut c = recover_lock(batched_cache());
    c.entry((dims, padded)).or_insert_with(|| Arc::new(BatchedFft3::new(dims, padded))).clone()
}

/// Number of cached plans (both families) — observability for tests.
pub fn plan_cache_len() -> usize {
    recover_lock(fft3_cache()).len() + recover_lock(batched_cache()).len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::ChipTopology;

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    #[test]
    fn take_put_reuses_backing_store() {
        let mut a = Arena::new();
        let v = a.take_f32(100);
        assert_eq!(v.len(), 100);
        assert_eq!(a.stats().fresh_allocs, 1);
        a.put_f32(v);
        assert_eq!(a.stats().held_bytes, 400);
        let v2 = a.take_f32(100);
        assert_eq!(a.stats().fresh_allocs, 1, "second take must reuse");
        assert_eq!(a.stats().reuses, 1);
        assert!(v2.iter().all(|&x| x == 0.0), "reused buffers are zeroed");
        a.put_f32(v2);
    }

    #[test]
    fn distinct_lengths_do_not_alias() {
        let mut a = Arena::new();
        let v = a.take_f32(10);
        a.put_f32(v);
        let _w = a.take_f32(11);
        assert_eq!(a.stats().fresh_allocs, 2);
        assert_eq!(a.stats().held_bytes, 40);
    }

    #[test]
    fn tensor_retire_cycle_is_allocation_free() {
        let pool = tpool();
        let mut ctx = ExecCtx::new(&pool);
        let sh = Shape5::new(1, 2, 3, 3, 3);
        let t = ctx.tensor5(sh);
        assert_eq!(t.shape(), sh);
        ctx.retire(t);
        let base_fresh = ctx.arena.stats().fresh_allocs;
        for _ in 0..5 {
            let t = ctx.tensor5(sh);
            ctx.retire(t);
        }
        assert_eq!(ctx.arena.stats().fresh_allocs, base_fresh);
    }

    #[test]
    fn arena_accounting_is_balanced() {
        // The global ledger is shared with concurrently running tests,
        // so this asserts on the arena's own (race-free) accounting:
        // take/put cycles must leave outstanding at zero and held equal
        // to the cached bytes.
        let mut a = Arena::new();
        let v = a.take_f32(50);
        assert_eq!(a.stats().outstanding_bytes, 200);
        a.put_f32(v);
        assert_eq!(a.stats().outstanding_bytes, 0);
        assert_eq!(a.stats().held_bytes, 200);
        let v = a.take_c32(50);
        assert_eq!(a.stats().outstanding_bytes, 400);
        a.put_c32(v);
        assert_eq!(a.stats().outstanding_bytes, 0);
        assert_eq!(a.stats().held_bytes, 600);
        assert_eq!(a.stats().hwm_bytes, 600);
    }

    #[test]
    fn undersized_budget_fails_at_plan_time() {
        let pool = tpool();
        let mut ctx = ExecCtx::with_budget(&pool, 1024);
        let err =
            ctx.reserve(&WorkspaceReq { bytes: 1 << 20, resident_bytes: 0 }).unwrap_err();
        assert!(err.to_string().contains("undersized"), "{err}");
        // Within budget is fine; resident (kernel-spectra) bytes live
        // outside the arena and do not count against its budget.
        assert!(ctx.reserve(&WorkspaceReq { bytes: 512, resident_bytes: 1 << 30 }).is_ok());
    }

    #[test]
    fn over_budget_is_recorded_not_fatal() {
        let mut a = Arena::with_budget(100);
        let v = a.take_f32(1000); // 4000 bytes > 100-byte budget
        assert_eq!(a.stats().over_budget_bytes(), 3900);
        a.put_f32(v);
    }

    #[test]
    fn bucket_cap_bounds_idle_memory() {
        let mut a = Arena::new();
        let bufs: Vec<_> = (0..2 * DEFAULT_BUCKET_CAP).map(|_| a.take_f32(8)).collect();
        for b in bufs {
            a.put_f32(b);
        }
        assert_eq!(a.stats().held_bytes, (DEFAULT_BUCKET_CAP * 8 * 4) as u64);
    }

    #[test]
    fn tensor_bytes_transfer_out_of_outstanding() {
        // Tensors leave `outstanding` at creation, so streams whose
        // outputs migrate to another owner do not drift the gauges —
        // and retiring a foreign tensor just stashes its buffer.
        let pool = tpool();
        let mut producer = ExecCtx::new(&pool);
        let t = producer.tensor5(Shape5::new(1, 1, 3, 3, 3));
        assert_eq!(producer.arena.stats().outstanding_bytes, 0);
        let mut consumer = ExecCtx::new(&pool);
        consumer.retire(t);
        assert_eq!(consumer.arena.stats().held_bytes, 108);
        assert_eq!(consumer.arena.stats().outstanding_bytes, 0);
        // The consumer now serves that size from its free list.
        let _v = consumer.take_f32(27);
        assert_eq!(consumer.arena.stats().reuses, 1);
    }

    #[test]
    fn bucket_cap_rises_with_pool_workers() {
        // A context built on an n-worker pool must retain n same-length
        // buffers (the direct conv's per-worker temporaries).
        let pool = TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 6 });
        let mut ctx = ExecCtx::new(&pool);
        let bufs: Vec<_> = (0..12).map(|_| ctx.take_f32(8)).collect();
        for b in bufs {
            ctx.put_f32(b);
        }
        assert_eq!(ctx.arena.stats().held_bytes, 12 * 8 * 4);
        let fresh = ctx.arena.stats().fresh_allocs;
        let bufs: Vec<_> = (0..12).map(|_| ctx.take_f32(8)).collect();
        assert_eq!(ctx.arena.stats().fresh_allocs, fresh, "all 12 must reuse");
        for b in bufs {
            ctx.put_f32(b);
        }
    }

    #[test]
    fn u16_buckets_account_at_two_bytes() {
        let mut a = Arena::new();
        let mut v = a.take_u16_raw(100);
        assert_eq!(v.len(), 100);
        assert_eq!(a.stats().outstanding_bytes, 200, "2 bytes per element");
        assert_eq!(a.stats().fresh_allocs, 1);
        v[0] = 0x3C00;
        a.put_u16(v);
        assert_eq!(a.stats().outstanding_bytes, 0);
        assert_eq!(a.stats().held_bytes, 200);
        let v2 = a.take_u16_raw(100);
        assert_eq!(a.stats().fresh_allocs, 1, "second take must reuse");
        assert_eq!(a.stats().reuses, 1);
        assert_eq!(v2[0], 0x3C00, "raw take keeps recycled contents");
        a.put_u16(v2);
        // Distinct widths never alias: an f32 take of the same element
        // count is a separate bucket family.
        let f = a.take_f32(100);
        assert_eq!(a.stats().fresh_allocs, 2);
        a.put_f32(f);
    }

    #[test]
    fn touch_pages_walks_free_lists_and_preserves_contents() {
        let mut a = Arena::new();
        assert_eq!(a.touch_pages(), 0, "empty arena touches nothing");
        let mut f = a.take_f32_raw(3000); // > 2 pages
        f[0] = 1.5;
        f[1024] = 2.5; // the second page's first element
        a.put_f32(f);
        let c = a.take_c32(600);
        a.put_c32(c);
        let u = a.take_u16_raw(100);
        a.put_u16(u);
        let walked = a.touch_pages();
        assert_eq!(walked, 3000 * 4 + 600 * 8 + 100 * 2);
        // Touching never moves buffers out of the free lists or changes
        // their contents.
        let f = a.take_f32_raw(3000);
        assert_eq!(a.stats().reuses, 4);
        assert_eq!(f[0], 1.5);
        assert_eq!(f[1024], 2.5);
        a.put_f32(f);
        // Outstanding buffers are not walked — only idle ones.
        let held = a.take_f32_raw(3000);
        assert_eq!(a.touch_pages(), 600 * 8 + 100 * 2);
        a.put_f32(held);
    }

    #[test]
    fn raw_take_skips_zeroing_on_reuse() {
        let mut a = Arena::new();
        let mut v = a.take_f32_raw(4);
        v.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        a.put_f32(v);
        let raw = a.take_f32_raw(4);
        assert_eq!(raw, vec![1.0, 2.0, 3.0, 4.0], "raw take keeps recycled contents");
        a.put_f32(raw);
        let zeroed = a.take_f32(4);
        assert_eq!(zeroed, vec![0.0; 4]);
        a.put_f32(zeroed);
    }

    #[test]
    fn plan_cache_shares_arcs() {
        let a = fft3_plan([6, 6, 6]);
        let b = fft3_plan([6, 6, 6]);
        assert!(Arc::ptr_eq(&a, &b));
        let c = batched_fft3_plan([3, 3, 3], [6, 6, 6]);
        let d = batched_fft3_plan([3, 3, 3], [6, 6, 6]);
        assert!(Arc::ptr_eq(&c, &d));
        let e = batched_fft3_plan([2, 3, 3], [6, 6, 6]);
        assert!(!Arc::ptr_eq(&c, &e));
        assert!(plan_cache_len() >= 3);
    }

    #[test]
    fn workspace_req_max() {
        let a = WorkspaceReq { bytes: 10, resident_bytes: 0 };
        let b = WorkspaceReq { bytes: 20, resident_bytes: 0 };
        assert_eq!(a.max(b).bytes, 20);
        assert_eq!(WorkspaceReq::ZERO.max(a).bytes, 10);
        assert_eq!(a.times(3).bytes, 30);
        let huge = WorkspaceReq { bytes: u64::MAX, resident_bytes: 0 };
        assert_eq!(huge.times(2).bytes, u64::MAX);
    }

    #[test]
    fn workspace_req_stacks_resident_and_shares_it_across_copies() {
        // Two layers: arena bytes take the max, kernel-spectra rows sum.
        let a = WorkspaceReq { bytes: 100, resident_bytes: 40 };
        let b = WorkspaceReq { bytes: 60, resident_bytes: 25 };
        let plan = a.stack(b);
        assert_eq!(plan.bytes, 100);
        assert_eq!(plan.resident_bytes, 65);
        assert_eq!(plan.total(), 165);
        // N warm worker arenas multiply the working set but share the
        // one Arc'd spectra cache.
        let fleet = plan.times(4);
        assert_eq!(fleet.bytes, 400);
        assert_eq!(fleet.resident_bytes, 65);
        assert_eq!(fleet.total(), 465);
    }

    #[test]
    fn ctx_into_arena_keeps_warmth() {
        let pool = tpool();
        let mut ctx = ExecCtx::new(&pool);
        let v = ctx.take_f32(64);
        ctx.put_f32(v);
        let arena = ctx.into_arena();
        let mut ctx2 = ExecCtx::from_arena(&pool, arena);
        let _v = ctx2.take_f32(64);
        assert_eq!(ctx2.arena.stats().reuses, 1);
    }
}
