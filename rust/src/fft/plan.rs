//! FFT size planning: factorisation and "FFT-optimal" padded sizes.
//!
//! The paper pads images/kernels to sizes of the form
//! `2^a·3^b·5^c·7^d` (cuFFT-friendly; §III.D) — optionally allowing one
//! factor of 11 or 13 in fftw mode. Sizes outside this set still work
//! (generic prime butterfly) but are slower; the planner never chooses
//! them.

/// Radices our butterflies specialise; the generic O(p²) butterfly
/// handles any other prime as a fallback.
pub const FAST_RADICES: [usize; 4] = [2, 3, 5, 7];

/// Factorise `n` into prime factors, smallest first, preferring to emit
/// 4s (pairs of 2s) since the radix-4 butterfly saves multiplies.
pub fn factorize(mut n: usize) -> Vec<usize> {
    assert!(n > 0);
    let mut fs = Vec::new();
    // Pull out 4s first, then a leftover 2.
    while n % 4 == 0 {
        fs.push(4);
        n /= 4;
    }
    if n % 2 == 0 {
        fs.push(2);
        n /= 2;
    }
    let mut p = 3;
    while p * p <= n {
        while n % p == 0 {
            fs.push(p);
            n /= p;
        }
        p += 2;
    }
    if n > 1 {
        fs.push(n);
    }
    fs
}

/// Is `n` a product of 2, 3, 5, 7 only (cuFFT/MKL-fast, §III.D)?
/// `allow_11_13` additionally permits a *single* factor of 11 or 13
/// (the fftw constraint e+f ≤ 1 from the paper).
pub fn is_fft_fast_size_ext(n: usize, allow_11_13: bool) -> bool {
    if n == 0 {
        return false;
    }
    let mut n = n;
    for p in [2usize, 3, 5, 7] {
        while n % p == 0 {
            n /= p;
        }
    }
    if allow_11_13 {
        if n % 11 == 0 {
            n /= 11;
        } else if n % 13 == 0 {
            n /= 13;
        }
    }
    n == 1
}

/// Is `n` a product of 2, 3, 5, 7 only?
pub fn is_fft_fast_size(n: usize) -> bool {
    is_fft_fast_size_ext(n, false)
}

/// Smallest fast size ≥ `n` (FFT-OPTIMAL-SIZE in Algorithm 2).
pub fn fft_optimal_size(n: usize) -> usize {
    let mut m = n.max(1);
    while !is_fft_fast_size(m) {
        m += 1;
    }
    m
}

/// Per-dimension optimal padded extent.
pub fn fft_optimal_vec3(n: [usize; 3]) -> [usize; 3] {
    [fft_optimal_size(n[0]), fft_optimal_size(n[1]), fft_optimal_size(n[2])]
}

/// Analytic op count of a length-`n` 1D FFT: `C · n · log2 n` with the
/// conventional C = 5 for real-world mixed radix (used only for cost
/// *models*, never for timing).
pub fn fft_1d_flops(n: usize) -> f64 {
    let n = n as f64;
    5.0 * n * n.log2().max(1.0)
}

/// Table I cost of a full (unpruned) 3D FFT of extent `n³`-like volume.
pub fn fft_3d_flops_naive(n: [usize; 3]) -> f64 {
    let [x, y, z] = n;
    // y·z lines along x + x·z lines along y + x·y lines along z
    (y * z) as f64 * fft_1d_flops(x)
        + (x * z) as f64 * fft_1d_flops(y)
        + (x * y) as f64 * fft_1d_flops(z)
}

/// §III.A pruned cost of transforming a `k`-extent image zero-padded to
/// `n` extent: only `k²` lines along the first dimension, `k·n` along
/// the second, `n²` along the last.
pub fn fft_3d_flops_pruned(k: [usize; 3], n: [usize; 3]) -> f64 {
    let [kx, ky, _kz] = k;
    let [x, y, z] = n;
    // Transform along z first (k_x·k_y lines), then y (k_x·z lines),
    // then x (y·z lines) — mirrors Fft3::forward.
    (kx * ky) as f64 * fft_1d_flops(z)
        + (kx * z) as f64 * fft_1d_flops(y)
        + (y * z) as f64 * fft_1d_flops(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factorize_roundtrip() {
        for n in 1..500usize {
            let fs = factorize(n);
            assert_eq!(fs.iter().product::<usize>(), n, "n={n} fs={fs:?}");
        }
    }

    #[test]
    fn factorize_prefers_radix4() {
        assert_eq!(factorize(16), vec![4, 4]);
        assert_eq!(factorize(8), vec![4, 2]);
        assert_eq!(factorize(12), vec![4, 3]);
    }

    #[test]
    fn fast_sizes() {
        for n in [1, 2, 8, 27, 35, 48, 70, 105, 128, 210, 243, 245] {
            assert!(is_fft_fast_size(n), "n={n}");
        }
        for n in [11, 13, 22, 121, 97, 101] {
            assert!(!is_fft_fast_size(n), "n={n}");
        }
    }

    #[test]
    fn fftw_mode_allows_one_11_or_13() {
        assert!(is_fft_fast_size_ext(11, true));
        assert!(is_fft_fast_size_ext(13 * 48, true));
        assert!(!is_fft_fast_size_ext(11 * 13, true));
        assert!(!is_fft_fast_size_ext(11 * 11, true));
    }

    #[test]
    fn optimal_size_is_minimal_fast() {
        for n in 1..300usize {
            let m = fft_optimal_size(n);
            assert!(m >= n);
            assert!(is_fft_fast_size(m));
            for c in n..m {
                assert!(!is_fft_fast_size(c));
            }
        }
    }

    #[test]
    fn pruned_flops_below_naive_for_kernels() {
        // A 5³ kernel padded to 64³: pruning must save roughly 2/3.
        let pruned = fft_3d_flops_pruned([5, 5, 5], [64, 64, 64]);
        let naive = fft_3d_flops_naive([64, 64, 64]);
        assert!(pruned < naive / 2.0, "pruned={pruned} naive={naive}");
    }
}
