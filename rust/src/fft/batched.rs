//! Batched pruned 3D FFT — the GPU scheme of §III.C.
//!
//! Transforms `b` 3D images at once. Each 3D FFT is decomposed into
//! batches of **contiguous** 1D transforms along the least-significant
//! dimension, interleaved with out-of-place 4D tensor permutes whose
//! flat-index arithmetic uses magic-number division instead of hardware
//! div/mod (§III.D — on the GPU those divisions can cost more than the
//! FFTs; we keep the same structure so the primitive is a faithful
//! stand-in on the simulated device).
//!
//! Pruning falls out of the representation: the z-pass only transforms
//! the `b·x·y` lines of the (unpadded) input, the y-pass only `b·x·z''`
//! lines, and only the final x-pass runs at full `b·z''·y'` width.
//!
//! The "transformed representation" is `b × z'' × y' × x'` (x
//! contiguous); point-wise products and accumulation happen directly in
//! it, and the inverse undoes the permutes while pruning against the
//! crop window.

use crate::memory::TrackedVec;
use crate::tensor::{Complex32, Vec3};
use crate::util::pool::TaskPool;
use crate::util::sendptr::SendPtr;
use crate::util::MagicU64;

use super::dft::{FftPlan, FftScratch};

/// Per-worker scratch tuple: FFT scratch, spectrum line, two real
/// lines, two complex lines.
type TlBufs = (FftScratch, Vec<Complex32>, Vec<f32>, Vec<f32>, Vec<Complex32>, Vec<Complex32>);

thread_local! {
    /// Per-worker line buffers for the batched passes — the per-line
    /// `vec![...]` allocations dominated pass time on profile (perf
    /// pass, EXPERIMENTS.md §Perf).
    static TL: std::cell::RefCell<TlBufs> = std::cell::RefCell::new((
        FftScratch::new(),
        Vec::new(),
        Vec::new(),
        Vec::new(),
        Vec::new(),
        Vec::new(),
    ));
}

/// Plan for batched transforms of images with extent `dims`, padded to
/// `padded` (both z-contiguous `[x][y][z]`).
pub struct BatchedFft3 {
    dims: Vec3,
    padded: Vec3,
    zc: usize,
    px: FftPlan,
    py: FftPlan,
    pz: FftPlan,
}

impl BatchedFft3 {
    /// Plan for images of extent `dims`, padded to `padded`.
    pub fn new(dims: Vec3, padded: Vec3) -> Self {
        assert!(dims[0] <= padded[0] && dims[1] <= padded[1] && dims[2] <= padded[2]);
        BatchedFft3 {
            dims,
            padded,
            zc: padded[2] / 2 + 1,
            px: FftPlan::new(padded[0]),
            py: FftPlan::new(padded[1]),
            pz: FftPlan::new(padded[2]),
        }
    }

    /// Unpadded image extent.
    pub fn dims(&self) -> Vec3 {
        self.dims
    }

    /// Padded transform extent.
    pub fn padded(&self) -> Vec3 {
        self.padded
    }

    /// Complex elements of one transformed image (z'' · y' · x').
    pub fn spectrum_len(&self) -> usize {
        self.zc * self.padded[1] * self.padded[0]
    }

    /// Scratch (peak extra complex elements) the forward transform of a
    /// batch of `b` images allocates internally — the `b·x·y'·z''` of
    /// §III.D.
    pub fn forward_scratch_elems(&self, b: usize) -> usize {
        let [x, y, _] = self.dims;
        let [_, py, _] = self.padded;
        // Ĩ¹ (b·x·y·z'') live while Ĩ² (b·x·z''·y') is built.
        b * x * y * self.zc + b * x * self.zc * py
    }

    /// Complex elements of the pass-1 scratch (Ĩ¹) for a batch of `b`.
    pub fn forward_scratch1_len(&self, b: usize) -> usize {
        b * self.dims[0] * self.dims[1] * self.zc
    }

    /// Complex elements of the pass-2 scratch (Ĩ²) for a batch of `b`.
    pub fn forward_scratch2_len(&self, b: usize) -> usize {
        b * self.dims[0] * self.zc * self.padded[1]
    }

    /// Complex elements of the inverse pass-2 scratch for crop `cx`.
    pub fn inverse_scratch2_len(&self, b: usize, cx: usize) -> usize {
        b * cx * self.zc * self.padded[1]
    }

    /// Complex elements of the inverse pass-1 scratch for crop `(cx, cy)`.
    pub fn inverse_scratch1_len(&self, b: usize, cx: usize, cy: usize) -> usize {
        b * cx * cy * self.zc
    }

    /// Forward transform of `b` images (`input` is `b·x·y·z` reals) into
    /// `out` (`b` spectra of [`Self::spectrum_len`] each). Allocates its
    /// two permute scratches internally; hot paths pass arena buffers to
    /// [`Self::forward_scratch`] instead.
    pub fn forward(&self, b: usize, input: &[f32], out: &mut [Complex32], pool: &TaskPool) {
        let mut i1: TrackedVec<Complex32> =
            TrackedVec::zeroed(self.forward_scratch1_len(b), "batched-fft I1");
        let mut i2: TrackedVec<Complex32> =
            TrackedVec::zeroed(self.forward_scratch2_len(b), "batched-fft I2");
        self.forward_scratch(b, input, out, i1.as_mut_slice(), i2.as_mut_slice(), pool);
    }

    /// [`Self::forward`] with caller-provided permute scratches: `s1` of
    /// [`Self::forward_scratch1_len`] and `s2` of
    /// [`Self::forward_scratch2_len`] elements (contents ignored).
    pub fn forward_scratch(
        &self,
        b: usize,
        input: &[f32],
        out: &mut [Complex32],
        s1: &mut [Complex32],
        s2: &mut [Complex32],
        pool: &TaskPool,
    ) {
        let [x, y, z] = self.dims;
        let [px, py, _pz] = self.padded;
        let zc = self.zc;
        assert_eq!(input.len(), b * x * y * z);
        assert_eq!(out.len(), b * self.spectrum_len());
        assert_eq!(s1.len(), self.forward_scratch1_len(b));
        assert_eq!(s2.len(), self.forward_scratch2_len(b));
        // The final permute writes only source elements; the zero-fill
        // provides the x-extension (callers may reuse `out`).
        out.fill(Complex32::ZERO);

        // Pass 1 — r2c along z: b·x·y contiguous lines → Ĩ¹ b×x×y×z''.
        let i1 = s1;
        {
            let lines = b * x * y;
            let i1s = SendPtr(i1.as_mut_ptr());
            pool.parallel_for(lines.div_ceil(2), |pair| {
                TL.with(|tl| {
                    let tlr = &mut *tl.borrow_mut();
                    let (sc, ra, rb, la, lb) =
                        (&mut tlr.0, &mut tlr.2, &mut tlr.3, &mut tlr.4, &mut tlr.5);
                    ra.resize(self.padded[2], 0.0);
                    rb.resize(self.padded[2], 0.0);
                    la.resize(zc, Complex32::ZERO);
                    lb.resize(zc, Complex32::ZERO);
                    let l0 = pair * 2;
                    let l1 = l0 + 1;
                    ra[..z].copy_from_slice(&input[l0 * z..(l0 + 1) * z]);
                    ra[z..].fill(0.0);
                    let dst = i1s.get();
                    if l1 < lines {
                        rb[..z].copy_from_slice(&input[l1 * z..(l1 + 1) * z]);
                        rb[z..].fill(0.0);
                        self.pz.r2c_pair(ra, rb, la, lb, sc);
                        unsafe {
                            std::ptr::copy_nonoverlapping(la.as_ptr(), dst.add(l0 * zc), zc);
                            std::ptr::copy_nonoverlapping(lb.as_ptr(), dst.add(l1 * zc), zc);
                        }
                    } else {
                        self.pz.r2c(ra, la, sc);
                        unsafe {
                            std::ptr::copy_nonoverlapping(la.as_ptr(), dst.add(l0 * zc), zc);
                        }
                    }
                });
            });
        }

        // Pass 2 — permute Ĩ¹[i,j,k,l] → Ĩ²[i,j,l,k] (b×x×z''×y',
        // zero-extended in y), then c2c along y'. The permute writes
        // only source elements, so the scratch must be pre-zeroed.
        let i2 = s2;
        i2.fill(Complex32::ZERO);
        permute_magic(i1, i2, [b, x, y, zc], PermuteMap::SwapLast(py), pool);
        self.c2c_pass(i2, b * x * zc, &self.py, pool);

        // Pass 3 — permute Ĩ²[i,j,k,l] → Ĩ³[i,k,l,j] (b×z''×y'×x',
        // zero-extended in x), then c2c along x'.
        permute_magic(
            i2,
            out,
            [b, x, zc, py],
            PermuteMap::RotateLeft3(px),
            pool,
        );
        self.c2c_pass(out, b * zc * py, &self.px, pool);
    }

    /// Inverse of [`Self::forward`] with crop: recover, for each of the
    /// `b` images, the window `offset..offset+crop` of the padded
    /// volume. `freq` is consumed. Allocates its permute scratches
    /// internally; hot paths use [`Self::inverse_crop_scratch`].
    pub fn inverse_crop(
        &self,
        b: usize,
        freq: &mut [Complex32],
        offset: Vec3,
        crop: Vec3,
        out: &mut [f32],
        pool: &TaskPool,
    ) {
        let mut i2: TrackedVec<Complex32> =
            TrackedVec::zeroed(self.inverse_scratch2_len(b, crop[0]), "batched-ifft I2");
        let mut i1: TrackedVec<Complex32> =
            TrackedVec::zeroed(self.inverse_scratch1_len(b, crop[0], crop[1]), "batched-ifft I1");
        self.inverse_crop_scratch(
            b,
            freq,
            offset,
            crop,
            out,
            i1.as_mut_slice(),
            i2.as_mut_slice(),
            pool,
        );
    }

    /// [`Self::inverse_crop`] with caller-provided permute scratches:
    /// `s1` of [`Self::inverse_scratch1_len`] and `s2` of
    /// [`Self::inverse_scratch2_len`] elements (contents ignored).
    pub fn inverse_crop_scratch(
        &self,
        b: usize,
        freq: &mut [Complex32],
        offset: Vec3,
        crop: Vec3,
        out: &mut [f32],
        s1: &mut [Complex32],
        s2: &mut [Complex32],
        pool: &TaskPool,
    ) {
        let [px, py, pz] = self.padded;
        let zc = self.zc;
        let [ox, oy, oz] = offset;
        let [cx, cy, cz] = crop;
        assert!(ox + cx <= px && oy + cy <= py && oz + cz <= pz);
        assert_eq!(freq.len(), b * self.spectrum_len());
        assert_eq!(out.len(), b * cx * cy * cz);
        assert_eq!(s1.len(), self.inverse_scratch1_len(b, cx, cy));
        assert_eq!(s2.len(), self.inverse_scratch2_len(b, cx));

        // Inverse along x (contiguous in the transformed representation).
        self.c2c_pass_inv(freq, b * zc * py, &self.px, pool);

        // Permute Ĩ³[i,k,l,j] → Ĩ²[i,j,k,l], keeping only x within the
        // crop: b×cx×z''×y'.
        let i2 = s2;
        i2.fill(Complex32::ZERO);
        {
            let src = freq;
            let dst = &mut *i2;
            // src layout [i,k,l,j] = b×zc×py×px ; dst [i,j',k,l] with
            // j' = j - ox over cx values.
            let m_j = MagicU64::new(px as u64);
            let m_l = MagicU64::new(py as u64);
            let m_k = MagicU64::new(zc as u64);
            let n = src.len() as u64;
            let dsts = SendPtr(dst.as_mut_ptr());
            pool.parallel_for(b, |i| {
                let base = (i * zc * py * px) as u64;
                let mut flat = base;
                while flat < base + (zc * py * px) as u64 {
                    let (r1, j) = m_j.divrem(flat);
                    let (r2, l) = m_l.divrem(r1);
                    let (_i, k) = m_k.divrem(r2);
                    debug_assert_eq!(_i as usize, i);
                    let _ = n;
                    if (j as usize) >= ox && (j as usize) < ox + cx {
                        let jj = j as usize - ox;
                        let didx = ((i * cx + jj) * zc + k as usize) * py + l as usize;
                        unsafe {
                            *dsts.get().add(didx) = *src.as_ptr().add(flat as usize);
                        }
                    }
                    flat += 1;
                }
            });
        }
        // Inverse along y.
        self.c2c_pass_inv(i2, b * cx * zc, &self.py, pool);

        // Permute Ĩ²[i,j,k,l] → Ĩ¹[i,j,l,k], keeping only y in crop:
        // b×cx×cy×z''.
        let i1 = s1;
        i1.fill(Complex32::ZERO);
        {
            let src = &*i2;
            let dst = &mut *i1;
            let m_l = MagicU64::new(py as u64);
            let m_k = MagicU64::new(zc as u64);
            let dsts = SendPtr(dst.as_mut_ptr());
            pool.parallel_for(b * cx, |ij| {
                let base = (ij * zc * py) as u64;
                let mut flat = base;
                while flat < base + (zc * py) as u64 {
                    let (r1, l) = m_l.divrem(flat);
                    let (_ij, k) = m_k.divrem(r1);
                    if (l as usize) >= oy && (l as usize) < oy + cy {
                        let ll = l as usize - oy;
                        let didx = (ij * cy + ll) * zc + k as usize;
                        unsafe {
                            *dsts.get().add(didx) = *src.as_ptr().add(flat as usize);
                        }
                    }
                    flat += 1;
                }
            });
        }
        // c2r along z, cropping [oz, oz+cz).
        {
            let lines = b * cx * cy;
            let src = &*i1;
            let outp = SendPtr(out.as_mut_ptr());
            pool.parallel_for(lines.div_ceil(2), |pair| {
                TL.with(|tl| {
                let tlr = &mut *tl.borrow_mut();
                let (sc, ra, rb) = (&mut tlr.0, &mut tlr.2, &mut tlr.3);
                ra.resize(pz, 0.0);
                rb.resize(pz, 0.0);
                let l0 = pair * 2;
                let l1 = l0 + 1;
                let sa = &src[l0 * zc..(l0 + 1) * zc];
                if l1 < lines {
                    let sb = &src[l1 * zc..(l1 + 1) * zc];
                    self.pz.c2r_pair(sa, sb, ra, rb, sc);
                    unsafe {
                        let (pa, pb) = (ra.as_ptr().add(oz), rb.as_ptr().add(oz));
                        std::ptr::copy_nonoverlapping(pa, outp.get().add(l0 * cz), cz);
                        std::ptr::copy_nonoverlapping(pb, outp.get().add(l1 * cz), cz);
                    }
                } else {
                    self.pz.c2r(sa, ra, sc);
                    unsafe {
                        let pa = ra.as_ptr().add(oz);
                        std::ptr::copy_nonoverlapping(pa, outp.get().add(l0 * cz), cz);
                    }
                }
                });
            });
        }
    }

    /// Forward c2c over `lines` contiguous lines of `plan.len()`.
    fn c2c_pass(&self, buf: &mut [Complex32], lines: usize, plan: &FftPlan, pool: &TaskPool) {
        let n = plan.len();
        assert_eq!(buf.len(), lines * n);
        let bufp = SendPtr(buf.as_mut_ptr());
        pool.parallel_for(lines, |l| {
            TL.with(|tl| {
                let tlr = &mut *tl.borrow_mut();
                let tmp = &mut tlr.1;
                tmp.resize(n, Complex32::ZERO);
                unsafe {
                    let line = std::slice::from_raw_parts_mut(bufp.get().add(l * n), n);
                    plan.forward(line, tmp);
                    line.copy_from_slice(&tmp[..n]);
                }
            });
        });
    }

    fn c2c_pass_inv(&self, buf: &mut [Complex32], lines: usize, plan: &FftPlan, pool: &TaskPool) {
        let n = plan.len();
        assert_eq!(buf.len(), lines * n);
        let bufp = SendPtr(buf.as_mut_ptr());
        pool.parallel_for(lines, |l| {
            TL.with(|tl| {
                let tlr = &mut *tl.borrow_mut();
                let (sc, tmp) = (&mut tlr.0, &mut tlr.1);
                tmp.resize(n, Complex32::ZERO);
                unsafe {
                    let line = std::slice::from_raw_parts_mut(bufp.get().add(l * n), n);
                    plan.inverse(line, tmp, sc);
                    line.copy_from_slice(&tmp[..n]);
                }
            });
        });
    }
}

/// The two permute shapes §III.C needs.
enum PermuteMap {
    /// `[i,j,k,l] → [i,j,l,k]`, last output dim zero-extended to the
    /// given length (y-extension).
    SwapLast(usize),
    /// `[i,j,k,l] → [i,k,l,j]`, last output dim zero-extended (x-ext).
    RotateLeft3(usize),
}

/// Out-of-place 4D permute with magic-number flat-index decomposition.
/// `dst` must be pre-zeroed (it is larger than `src` when extending).
fn permute_magic(
    src: &[Complex32],
    dst: &mut [Complex32],
    src_dims: [usize; 4],
    map: PermuteMap,
    pool: &TaskPool,
) {
    let [b, d1, d2, d3] = src_dims;
    assert_eq!(src.len(), b * d1 * d2 * d3);
    let m3 = MagicU64::new(d3 as u64);
    let m2 = MagicU64::new(d2 as u64);
    let m1 = MagicU64::new(d1 as u64);
    let dsts = SendPtr(dst.as_mut_ptr());
    let per_img = d1 * d2 * d3;
    pool.parallel_for(b, |i| {
        let base = (i * per_img) as u64;
        for flat in base..base + per_img as u64 {
            let (r1, l) = m3.divrem(flat);
            let (r2, k) = m2.divrem(r1);
            let (_i, j) = m1.divrem(r2);
            let (j, k, l) = (j as usize, k as usize, l as usize);
            let didx = match map {
                // [i,j,k,l] → [i,j,l,k] with k-dim over d2 values and
                // output dims (d1, d3, ext)
                PermuteMap::SwapLast(ext) => ((i * d1 + j) * d3 + l) * ext + k,
                // [i,j,k,l] → [i,k,l,j] output dims (d2, d3, ext)
                PermuteMap::RotateLeft3(ext) => ((i * d2 + k) * d3 + l) * ext + j,
            };
            unsafe {
                *dsts.get().add(didx) = src[flat as usize];
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::fft3d::{Fft3, Fft3Scratch};
    use crate::util::pool::ChipTopology;
    use crate::util::prng::Rng;
    use crate::util::quick::assert_allclose;

    fn pool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    fn rand_imgs(b: usize, dims: Vec3, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..b * dims[0] * dims[1] * dims[2]).map(|_| r.f32_range(-1.0, 1.0)).collect()
    }

    /// The batched (GPU-scheme) spectrum is a permutation of the CPU
    /// scheme's: compare element-by-element through the index maps.
    #[test]
    fn batched_matches_cpu_scheme() {
        let dims = [3, 4, 5];
        let padded = [6, 7, 8];
        let b = 2;
        let p = pool();
        let bf = BatchedFft3::new(dims, padded);
        let cf = Fft3::new(padded);
        let imgs = rand_imgs(b, dims, 5);
        let mut out = vec![Complex32::ZERO; b * bf.spectrum_len()];
        bf.forward(b, &imgs, &mut out, &p);

        let mut sc = Fft3Scratch::new();
        let zc = padded[2] / 2 + 1;
        for i in 0..b {
            let img = &imgs[i * dims[0] * dims[1] * dims[2]..(i + 1) * dims[0] * dims[1] * dims[2]];
            let mut cpu = vec![Complex32::ZERO; cf.complex_len()];
            cf.forward(img, dims, &mut cpu, &mut sc);
            // cpu layout [x][y][zc]; batched layout [zc][y'][x'].
            for x in 0..padded[0] {
                for y in 0..padded[1] {
                    for k in 0..zc {
                        let a = cpu[(x * padded[1] + y) * zc + k];
                        let bb = out[i * bf.spectrum_len() + (k * padded[1] + y) * padded[0] + x];
                        assert!(
                            (a.re - bb.re).abs() < 2e-3 && (a.im - bb.im).abs() < 2e-3,
                            "mismatch at i={i} x={x} y={y} k={k}: {a:?} vs {bb:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn forward_inverse_roundtrip_batched() {
        let dims = [4, 5, 6];
        let padded = [6, 6, 8];
        let b = 3;
        let p = pool();
        let bf = BatchedFft3::new(dims, padded);
        let imgs = rand_imgs(b, dims, 9);
        let mut freq = vec![Complex32::ZERO; b * bf.spectrum_len()];
        bf.forward(b, &imgs, &mut freq, &p);
        let mut back = vec![0.0f32; b * dims[0] * dims[1] * dims[2]];
        bf.inverse_crop(b, &mut freq, [0, 0, 0], dims, &mut back, &p);
        assert_allclose(&back, &imgs, 1e-3, 1e-2, "batched roundtrip");
    }

    #[test]
    fn inverse_crop_window_batched() {
        let dims = [5, 5, 5];
        let padded = [5, 5, 5];
        let b = 2;
        let p = pool();
        let bf = BatchedFft3::new(dims, padded);
        let imgs = rand_imgs(b, dims, 21);
        let mut freq = vec![Complex32::ZERO; b * bf.spectrum_len()];
        bf.forward(b, &imgs, &mut freq, &p);
        let off = [2, 1, 0];
        let crop = [3, 2, 4];
        let mut out = vec![0.0f32; b * crop[0] * crop[1] * crop[2]];
        bf.inverse_crop(b, &mut freq, off, crop, &mut out, &p);
        // Roundtrip of the identity transform = crop of the original.
        let mut expect = Vec::new();
        for i in 0..b {
            for x in 0..crop[0] {
                for y in 0..crop[1] {
                    for z in 0..crop[2] {
                        expect.push(
                            imgs[((i * dims[0] + off[0] + x) * dims[1] + off[1] + y) * dims[2]
                                + off[2]
                                + z],
                        );
                    }
                }
            }
        }
        assert_allclose(&out, &expect, 1e-3, 1e-2, "batched crop");
    }
}
