//! Pruned 3D FFT — the CPU scheme of §III.B.
//!
//! A 3D transform is three passes of 1D transforms. When the input is a
//! small image (e.g. a k³ kernel) zero-padded to the FFT size, most 1D
//! lines are all-zero and their transforms are skipped:
//!
//! * along z: only the `nx·ny` lines inside the image are transformed;
//! * along y: only lines at `x < nx` can be non-zero — `nx·z̃` lines;
//! * along x: every `ỹ·z̃` line may be non-zero — full pass.
//!
//! The inverse prunes symmetrically against the *crop window* (the
//! "valid" region of the convolution): full pass along x, then only
//! cropped-x lines along y, then only cropped-(x,y) lines along z.
//!
//! Layout: real volumes are `[x][y][z]` row-major (z contiguous);
//! spectra are `[x][y][zc]` with `zc = Z/2+1` complex bins from the
//! real-to-complex transform along z.

use crate::tensor::{Complex32, Vec3};
use crate::util::pool::TaskPool;
use crate::util::sendptr::SendPtr;

use super::dft::{FftPlan, FftScratch};

thread_local! {
    /// Per-worker scratch for the parallel (data-parallel primitive)
    /// variants — avoids per-line allocation in the hot loops.
    static TL_SCRATCH: std::cell::RefCell<Fft3Scratch> =
        std::cell::RefCell::new(Fft3Scratch::new());
}

/// Scratch for one in-flight 3D transform. One per worker thread.
pub struct Fft3Scratch {
    /// 1D scratch shared by the line transforms.
    pub fft: FftScratch,
    line_a: Vec<Complex32>,
    line_b: Vec<Complex32>,
    real_a: Vec<f32>,
    real_b: Vec<f32>,
}

impl Fft3Scratch {
    /// Empty scratch.
    pub fn new() -> Self {
        Fft3Scratch {
            fft: FftScratch::new(),
            line_a: Vec::new(),
            line_b: Vec::new(),
            real_a: Vec::new(),
            real_b: Vec::new(),
        }
    }

    fn ensure(&mut self, n: usize) {
        if self.line_a.len() < n {
            self.line_a.resize(n, Complex32::ZERO);
            self.line_b.resize(n, Complex32::ZERO);
            self.real_a.resize(n, 0.0);
            self.real_b.resize(n, 0.0);
        }
    }
}

impl Default for Fft3Scratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Plan for 3D transforms padded to `padded = [X, Y, Z]`.
pub struct Fft3 {
    padded: Vec3,
    zc: usize,
    px: FftPlan,
    py: FftPlan,
    pz: FftPlan,
}

impl Fft3 {
    /// Plan 3D transforms padded to `padded`.
    pub fn new(padded: Vec3) -> Self {
        let [x, y, z] = padded;
        Fft3 {
            padded,
            zc: z / 2 + 1,
            px: FftPlan::new(x),
            py: FftPlan::new(y),
            pz: FftPlan::new(z),
        }
    }

    /// Padded transform extent.
    pub fn padded(&self) -> Vec3 {
        self.padded
    }

    /// Complex bins along z after r2c.
    pub fn zc(&self) -> usize {
        self.zc
    }

    /// Elements of a spectrum buffer: X · Y · zc.
    pub fn complex_len(&self) -> usize {
        self.padded[0] * self.padded[1] * self.zc
    }

    /// Pruned forward transform: `img` has extent `dims ≤ padded`
    /// (z-contiguous), `out` is the X·Y·zc spectrum (fully overwritten).
    pub fn forward(&self, img: &[f32], dims: Vec3, out: &mut [Complex32], sc: &mut Fft3Scratch) {
        let [nx, ny, nz] = dims;
        let [px, py, _pz] = self.padded;
        let zc = self.zc;
        assert!(nx <= px && ny <= py && nz <= self.padded[2], "image exceeds padded size");
        assert_eq!(img.len(), nx * ny * nz);
        assert_eq!(out.len(), self.complex_len());
        sc.ensure(self.max_len());
        out.fill(Complex32::ZERO);

        // Pass 1 — along z (real→complex), pruned to the nx·ny image
        // lines, two lines per complex FFT.
        let z = self.padded[2];
        let total = nx * ny;
        let mut li = 0usize;
        while li < total {
            let (x0, y0) = (li / ny, li % ny);
            let src0 = &img[(x0 * ny + y0) * nz..(x0 * ny + y0) * nz + nz];
            sc.real_a[..nz].copy_from_slice(src0);
            sc.real_a[nz..z].fill(0.0);
            if li + 1 < total {
                let (x1, y1) = ((li + 1) / ny, (li + 1) % ny);
                let src1 = &img[(x1 * ny + y1) * nz..(x1 * ny + y1) * nz + nz];
                sc.real_b[..nz].copy_from_slice(src1);
                sc.real_b[nz..z].fill(0.0);
                // Split scratch: write into line buffers, then copy out.
                let (ra, rb, la, lb, fft) = (
                    &sc.real_a[..z],
                    &sc.real_b[..z],
                    &mut sc.line_a[..zc],
                    &mut sc.line_b[..zc],
                    &mut sc.fft,
                );
                self.pz.r2c_pair(ra, rb, la, lb, fft);
                out[(x0 * py + y0) * zc..(x0 * py + y0) * zc + zc].copy_from_slice(la);
                out[(x1 * py + y1) * zc..(x1 * py + y1) * zc + zc].copy_from_slice(lb);
                li += 2;
            } else {
                let (ra, la, fft) = (&sc.real_a[..z], &mut sc.line_a[..zc], &mut sc.fft);
                self.pz.r2c(ra, la, fft);
                out[(x0 * py + y0) * zc..(x0 * py + y0) * zc + zc].copy_from_slice(la);
                li += 1;
            }
        }

        // Pass 2 — along y, pruned to x < nx: nx·zc lines.
        for x in 0..nx {
            for k in 0..zc {
                self.c2c_line(out, (x * py) * zc + k, zc, &self.py, sc);
            }
        }

        // Pass 3 — along x: full ỹ·zc lines.
        for y in 0..py {
            for k in 0..zc {
                self.c2c_line(out, y * zc + k, py * zc, &self.px, sc);
            }
        }
    }

    /// Unpruned forward (reference / baseline): transforms every line.
    pub fn forward_naive(
        &self,
        img: &[f32],
        dims: Vec3,
        out: &mut [Complex32],
        sc: &mut Fft3Scratch,
    ) {
        let [nx, ny, nz] = dims;
        let [px, py, pz] = self.padded;
        let zc = self.zc;
        assert_eq!(out.len(), self.complex_len());
        sc.ensure(self.max_len());
        out.fill(Complex32::ZERO);
        let z = pz;
        // Along z: all px·py lines (zero lines transformed too).
        for x in 0..px {
            for y in 0..py {
                if x < nx && y < ny {
                    let src = &img[(x * ny + y) * nz..(x * ny + y) * nz + nz];
                    sc.real_a[..nz].copy_from_slice(src);
                    sc.real_a[nz..z].fill(0.0);
                } else {
                    sc.real_a[..z].fill(0.0);
                }
                let (ra, la, fft) = (&sc.real_a[..z], &mut sc.line_a[..zc], &mut sc.fft);
                self.pz.r2c(ra, la, fft);
                out[(x * py + y) * zc..(x * py + y) * zc + zc].copy_from_slice(la);
            }
        }
        for x in 0..px {
            for k in 0..zc {
                self.c2c_line(out, (x * py) * zc + k, zc, &self.py, sc);
            }
        }
        for y in 0..py {
            for k in 0..zc {
                self.c2c_line(out, y * zc + k, py * zc, &self.px, sc);
            }
        }
    }

    /// Pruned inverse: recover only the crop window `offset..offset+dims`
    /// of the padded real volume. `freq` is consumed (overwritten).
    pub fn inverse_crop(
        &self,
        freq: &mut [Complex32],
        offset: Vec3,
        dims: Vec3,
        out_img: &mut [f32],
        sc: &mut Fft3Scratch,
    ) {
        let [ox, oy, oz] = offset;
        let [cx, cy, cz] = dims;
        let [px, py, pz] = self.padded;
        let zc = self.zc;
        assert!(ox + cx <= px && oy + cy <= py && oz + cz <= pz, "crop exceeds padded size");
        assert_eq!(freq.len(), self.complex_len());
        assert_eq!(out_img.len(), cx * cy * cz);
        sc.ensure(self.max_len());

        // Pass 1 — inverse along x: all ỹ·zc lines are needed.
        for y in 0..py {
            for k in 0..zc {
                self.c2c_line_inv(freq, y * zc + k, py * zc, &self.px, sc);
            }
        }
        // Pass 2 — inverse along y, pruned to x within the crop.
        for x in ox..ox + cx {
            for k in 0..zc {
                self.c2c_line_inv(freq, (x * py) * zc + k, zc, &self.py, sc);
            }
        }
        // Pass 3 — complex→real along z, pruned to (x, y) within the
        // crop, two lines per complex FFT.
        let total = cx * cy;
        let mut li = 0usize;
        while li < total {
            let (ix0, iy0) = (li / cy, li % cy);
            let (x0, y0) = (ox + ix0, oy + iy0);
            let o0 = (x0 * py + y0) * zc;
            if li + 1 < total {
                let (ix1, iy1) = ((li + 1) / cy, (li + 1) % cy);
                let (x1, y1) = (ox + ix1, oy + iy1);
                let o1 = (x1 * py + y1) * zc;
                // Copy spectra lines into scratch to avoid aliasing.
                sc.line_a[..zc].copy_from_slice(&freq[o0..o0 + zc]);
                sc.line_b[..zc].copy_from_slice(&freq[o1..o1 + zc]);
                let (la, lb, ra, rb, fft) = (
                    &sc.line_a[..zc],
                    &sc.line_b[..zc],
                    &mut sc.real_a[..pz],
                    &mut sc.real_b[..pz],
                    &mut sc.fft,
                );
                self.pz.c2r_pair(la, lb, ra, rb, fft);
                out_img[(ix0 * cy + iy0) * cz..(ix0 * cy + iy0) * cz + cz]
                    .copy_from_slice(&ra[oz..oz + cz]);
                out_img[(ix1 * cy + iy1) * cz..(ix1 * cy + iy1) * cz + cz]
                    .copy_from_slice(&rb[oz..oz + cz]);
                li += 2;
            } else {
                sc.line_a[..zc].copy_from_slice(&freq[o0..o0 + zc]);
                let (la, ra, fft) = (&sc.line_a[..zc], &mut sc.real_a[..pz], &mut sc.fft);
                self.pz.c2r(la, ra, fft);
                out_img[(ix0 * cy + iy0) * cz..(ix0 * cy + iy0) * cz + cz]
                    .copy_from_slice(&ra[oz..oz + cz]);
                li += 1;
            }
        }
    }

    /// Parallel pruned forward: same result as [`Self::forward`], with
    /// each pass's independent 1D lines fanned out over the pool. This
    /// is the "PARALLEL-FFT" of Algorithm 2 (the data-parallel CPU
    /// primitive parallelises *within* one transform).
    pub fn forward_par(&self, img: &[f32], dims: Vec3, out: &mut [Complex32], pool: &TaskPool) {
        let [nx, ny, nz] = dims;
        let [px, py, pz] = self.padded;
        let zc = self.zc;
        assert!(nx <= px && ny <= py && nz <= pz, "image exceeds padded size");
        assert_eq!(img.len(), nx * ny * nz);
        assert_eq!(out.len(), self.complex_len());
        out.fill(Complex32::ZERO);
        let outp = SendPtr(out.as_mut_ptr());

        // Pass 1 — r2c along z over nx·ny image lines (paired).
        let total = nx * ny;
        pool.parallel_for(total.div_ceil(2), |pair| {
            TL_SCRATCH.with(|c| {
                let sc = &mut *c.borrow_mut();
                sc.ensure(self.max_len());
                let l0 = pair * 2;
                let (x0, y0) = (l0 / ny, l0 % ny);
                sc.real_a[..nz].copy_from_slice(&img[l0 * nz..(l0 + 1) * nz]);
                sc.real_a[nz..pz].fill(0.0);
                if l0 + 1 < total {
                    let (x1, y1) = ((l0 + 1) / ny, (l0 + 1) % ny);
                    sc.real_b[..nz].copy_from_slice(&img[(l0 + 1) * nz..(l0 + 2) * nz]);
                    sc.real_b[nz..pz].fill(0.0);
                    let (ra, rb, la, lb, fft) = (
                        &sc.real_a[..pz],
                        &sc.real_b[..pz],
                        &mut sc.line_a[..zc],
                        &mut sc.line_b[..zc],
                        &mut sc.fft,
                    );
                    self.pz.r2c_pair(ra, rb, la, lb, fft);
                    unsafe {
                        outp.slice_mut((x0 * py + y0) * zc, zc).copy_from_slice(la);
                        outp.slice_mut((x1 * py + y1) * zc, zc).copy_from_slice(lb);
                    }
                } else {
                    let (ra, la, fft) = (&sc.real_a[..pz], &mut sc.line_a[..zc], &mut sc.fft);
                    self.pz.r2c(ra, la, fft);
                    unsafe {
                        outp.slice_mut((x0 * py + y0) * zc, zc).copy_from_slice(la);
                    }
                }
            });
        });

        // Pass 2 — along y, pruned to x < nx.
        pool.parallel_for(nx * zc, |i| {
            let (x, k) = (i / zc, i % zc);
            TL_SCRATCH.with(|c| {
                let sc = &mut *c.borrow_mut();
                sc.ensure(self.max_len());
                unsafe {
                    c2c_line_raw(outp, (x * py) * zc + k, zc, &self.py, sc, false);
                }
            });
        });

        // Pass 3 — along x, full width.
        pool.parallel_for(py * zc, |i| {
            let (y, k) = (i / zc, i % zc);
            TL_SCRATCH.with(|c| {
                let sc = &mut *c.borrow_mut();
                sc.ensure(self.max_len());
                unsafe {
                    c2c_line_raw(outp, y * zc + k, py * zc, &self.px, sc, false);
                }
            });
        });
    }

    /// Parallel pruned inverse-with-crop — the data-parallel
    /// counterpart of [`Self::inverse_crop`].
    pub fn inverse_crop_par(
        &self,
        freq: &mut [Complex32],
        offset: Vec3,
        dims: Vec3,
        out_img: &mut [f32],
        pool: &TaskPool,
    ) {
        let [ox, oy, oz] = offset;
        let [cx, cy, cz] = dims;
        let [px, py, pz] = self.padded;
        let zc = self.zc;
        assert!(ox + cx <= px && oy + cy <= py && oz + cz <= pz);
        assert_eq!(freq.len(), self.complex_len());
        assert_eq!(out_img.len(), cx * cy * cz);
        let freqp = SendPtr(freq.as_mut_ptr());
        let outp = SendPtr(out_img.as_mut_ptr());

        // Inverse along x — all lines.
        pool.parallel_for(py * zc, |i| {
            let (y, k) = (i / zc, i % zc);
            TL_SCRATCH.with(|c| {
                let sc = &mut *c.borrow_mut();
                sc.ensure(self.max_len());
                unsafe {
                    c2c_line_raw(freqp, y * zc + k, py * zc, &self.px, sc, true);
                }
            });
        });
        // Inverse along y — x within crop only.
        pool.parallel_for(cx * zc, |i| {
            let (xi, k) = (i / zc, i % zc);
            let x = ox + xi;
            TL_SCRATCH.with(|c| {
                let sc = &mut *c.borrow_mut();
                sc.ensure(self.max_len());
                unsafe {
                    c2c_line_raw(freqp, (x * py) * zc + k, zc, &self.py, sc, true);
                }
            });
        });
        // c2r along z — (x, y) within crop, paired.
        let total = cx * cy;
        pool.parallel_for(total.div_ceil(2), |pair| {
            TL_SCRATCH.with(|c| {
                let sc = &mut *c.borrow_mut();
                sc.ensure(self.max_len());
                let l0 = pair * 2;
                let (ix0, iy0) = (l0 / cy, l0 % cy);
                let o0 = ((ox + ix0) * py + oy + iy0) * zc;
                unsafe {
                    sc.line_a[..zc].copy_from_slice(outp_freq(freqp, o0, zc));
                    if l0 + 1 < total {
                        let (ix1, iy1) = ((l0 + 1) / cy, (l0 + 1) % cy);
                        let o1 = ((ox + ix1) * py + oy + iy1) * zc;
                        sc.line_b[..zc].copy_from_slice(outp_freq(freqp, o1, zc));
                        let (la, lb, ra, rb, fft) = (
                            &sc.line_a[..zc],
                            &sc.line_b[..zc],
                            &mut sc.real_a[..pz],
                            &mut sc.real_b[..pz],
                            &mut sc.fft,
                        );
                        self.pz.c2r_pair(la, lb, ra, rb, fft);
                        outp.slice_mut((ix0 * cy + iy0) * cz, cz)
                            .copy_from_slice(&ra[oz..oz + cz]);
                        outp.slice_mut((ix1 * cy + iy1) * cz, cz)
                            .copy_from_slice(&rb[oz..oz + cz]);
                    } else {
                        let (la, ra, fft) =
                            (&sc.line_a[..zc], &mut sc.real_a[..pz], &mut sc.fft);
                        self.pz.c2r(la, ra, fft);
                        outp.slice_mut((ix0 * cy + iy0) * cz, cz)
                            .copy_from_slice(&ra[oz..oz + cz]);
                    }
                }
            });
        });
    }

    fn max_len(&self) -> usize {
        self.padded[0].max(self.padded[1]).max(self.padded[2]).max(self.zc)
    }

    /// Gather a strided complex line, forward-transform, scatter back.
    fn c2c_line(
        &self,
        buf: &mut [Complex32],
        start: usize,
        stride: usize,
        plan: &FftPlan,
        sc: &mut Fft3Scratch,
    ) {
        let n = plan.len();
        for i in 0..n {
            sc.line_a[i] = buf[start + i * stride];
        }
        {
            let (la, lb) = (&sc.line_a[..n], &mut sc.line_b[..n]);
            plan.forward(la, lb);
        }
        for i in 0..n {
            buf[start + i * stride] = sc.line_b[i];
        }
    }

    fn c2c_line_inv(
        &self,
        buf: &mut [Complex32],
        start: usize,
        stride: usize,
        plan: &FftPlan,
        sc: &mut Fft3Scratch,
    ) {
        let n = plan.len();
        for i in 0..n {
            sc.line_a[i] = buf[start + i * stride];
        }
        {
            let (la, lb, fft) = (&sc.line_a[..n], &mut sc.line_b[..n], &mut sc.fft);
            plan.inverse(la, lb, fft);
        }
        for i in 0..n {
            buf[start + i * stride] = sc.line_b[i];
        }
    }

    /// Point-wise multiply-accumulate of two spectra: `acc += a · b`,
    /// parallelised over chunks (PARALLEL-MAD of Algorithm 2).
    pub fn mad_spectra_par(
        acc: &mut [Complex32],
        a: &[Complex32],
        b: &[Complex32],
        pool: &TaskPool,
    ) {
        assert_eq!(acc.len(), a.len());
        assert_eq!(acc.len(), b.len());
        let n = acc.len();
        let chunks = (pool.workers() * 2).min(n.max(1));
        let per = n.div_ceil(chunks);
        let accp = SendPtr(acc.as_mut_ptr());
        pool.parallel_for(chunks, |c| {
            let lo = c * per;
            let hi = ((c + 1) * per).min(n);
            if lo >= hi {
                return;
            }
            let acc = unsafe { accp.slice_mut(lo, hi - lo) };
            crate::simd::mad_spectra(acc, &a[lo..hi], &b[lo..hi]);
        });
    }

    /// Point-wise multiply-accumulate of two spectra: `acc += a · b`.
    /// This is PARALLEL-MAD's inner kernel (Algorithm 2), dispatched to
    /// the best SIMD tier at runtime (AVX2+FMA runs it as split-complex
    /// pure-FMA tiles; see [`crate::simd`]).
    pub fn mad_spectra(acc: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
        debug_assert_eq!(acc.len(), a.len());
        debug_assert_eq!(acc.len(), b.len());
        crate::simd::mad_spectra(acc, a, b);
    }
}

/// Run `f` with this worker thread's reusable 3D-FFT scratch. Task
/// bodies of the task-parallel primitive use this so per-task transforms
/// do not re-allocate.
pub fn with_tl_scratch<R>(f: impl FnOnce(&mut Fft3Scratch) -> R) -> R {
    TL_SCRATCH.with(|c| f(&mut c.borrow_mut()))
}

/// Gather a strided line through a raw pointer, transform (forward or
/// inverse), scatter back.
///
/// # Safety
/// Caller guarantees the strided line indices are in bounds and no two
/// concurrent calls touch the same line.
unsafe fn c2c_line_raw(
    buf: SendPtr<Complex32>,
    start: usize,
    stride: usize,
    plan: &FftPlan,
    sc: &mut Fft3Scratch,
    inverse: bool,
) {
    let n = plan.len();
    let p = buf.get();
    for i in 0..n {
        sc.line_a[i] = *p.add(start + i * stride);
    }
    {
        let (la, lb, fft) = (&sc.line_a[..n], &mut sc.line_b[..n], &mut sc.fft);
        if inverse {
            plan.inverse(la, lb, fft);
        } else {
            plan.forward(la, lb);
        }
    }
    for i in 0..n {
        *p.add(start + i * stride) = sc.line_b[i];
    }
}

/// View a spectrum range through the raw pointer (read side of the
/// paired c2r pass).
unsafe fn outp_freq(p: SendPtr<Complex32>, off: usize, len: usize) -> &'static [Complex32] {
    std::slice::from_raw_parts(p.get().add(off), len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::quick::assert_allclose;

    fn rand_img(dims: Vec3, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..dims[0] * dims[1] * dims[2]).map(|_| r.f32_range(-1.0, 1.0)).collect()
    }

    /// O(n⁶) 3D DFT magnitude reference via direct convolution theorem
    /// check instead: pruned forward must equal naive forward.
    #[test]
    fn pruned_equals_naive_forward() {
        for (dims, padded) in [
            ([3, 3, 3], [8, 8, 8]),
            ([2, 3, 4], [6, 7, 8]),
            ([5, 5, 5], [5, 5, 5]),
            ([1, 1, 1], [4, 4, 4]),
            ([4, 2, 6], [9, 10, 12]),
        ] {
            let plan = Fft3::new(padded);
            let img = rand_img(dims, 42);
            let mut sc = Fft3Scratch::new();
            let mut a = vec![Complex32::ZERO; plan.complex_len()];
            let mut b = vec![Complex32::ZERO; plan.complex_len()];
            plan.forward(&img, dims, &mut a, &mut sc);
            plan.forward_naive(&img, dims, &mut b, &mut sc);
            let fa: Vec<f32> = a.iter().flat_map(|c| [c.re, c.im]).collect();
            let fb: Vec<f32> = b.iter().flat_map(|c| [c.re, c.im]).collect();
            assert_allclose(&fa, &fb, 1e-3, 1e-3, &format!("pruned vs naive {dims:?}->{padded:?}"));
        }
    }

    #[test]
    fn forward_inverse_roundtrip_full() {
        let dims = [4, 5, 6];
        let padded = [4, 5, 6];
        let plan = Fft3::new(padded);
        let img = rand_img(dims, 7);
        let mut sc = Fft3Scratch::new();
        let mut freq = vec![Complex32::ZERO; plan.complex_len()];
        plan.forward(&img, dims, &mut freq, &mut sc);
        let mut back = vec![0.0f32; dims[0] * dims[1] * dims[2]];
        plan.inverse_crop(&mut freq, [0, 0, 0], dims, &mut back, &mut sc);
        assert_allclose(&back, &img, 1e-4, 1e-3, "3d roundtrip");
    }

    #[test]
    fn inverse_crop_extracts_window() {
        let dims = [6, 6, 6];
        let padded = [8, 9, 10];
        let plan = Fft3::new(padded);
        let img = rand_img(dims, 9);
        let mut sc = Fft3Scratch::new();
        let mut freq = vec![Complex32::ZERO; plan.complex_len()];
        plan.forward(&img, dims, &mut freq, &mut sc);

        // Full inverse for reference.
        let mut freq2 = freq.clone();
        let mut full = vec![0.0f32; padded[0] * padded[1] * padded[2]];
        plan.inverse_crop(&mut freq2, [0, 0, 0], padded, &mut full, &mut sc);

        let off = [2, 1, 3];
        let cdims = [3, 4, 5];
        let mut crop = vec![0.0f32; cdims[0] * cdims[1] * cdims[2]];
        plan.inverse_crop(&mut freq, off, cdims, &mut crop, &mut sc);

        let mut expect = Vec::new();
        for x in 0..cdims[0] {
            for y in 0..cdims[1] {
                for z in 0..cdims[2] {
                    expect.push(
                        full[((off[0] + x) * padded[1] + (off[1] + y)) * padded[2] + off[2] + z],
                    );
                }
            }
        }
        assert_allclose(&crop, &expect, 1e-4, 1e-3, "crop window");
    }

    #[test]
    fn parallel_variants_match_serial() {
        let pool = crate::util::pool::TaskPool::with_topology(
            crate::util::pool::ChipTopology { chips: 2, cores_per_chip: 2 },
        );
        let dims = [5, 6, 7];
        let padded = [8, 8, 9];
        let plan = Fft3::new(padded);
        let img = rand_img(dims, 33);
        let mut sc = Fft3Scratch::new();

        let mut a = vec![Complex32::ZERO; plan.complex_len()];
        let mut b = vec![Complex32::ZERO; plan.complex_len()];
        plan.forward(&img, dims, &mut a, &mut sc);
        plan.forward_par(&img, dims, &mut b, &pool);
        let fa: Vec<f32> = a.iter().flat_map(|c| [c.re, c.im]).collect();
        let fb: Vec<f32> = b.iter().flat_map(|c| [c.re, c.im]).collect();
        assert_allclose(&fb, &fa, 1e-4, 1e-3, "forward_par");

        let off = [1, 2, 0];
        let crop = [4, 3, 5];
        let mut out_s = vec![0.0f32; crop.volume_()];
        let mut out_p = vec![0.0f32; crop.volume_()];
        plan.inverse_crop(&mut a, off, crop, &mut out_s, &mut sc);
        plan.inverse_crop_par(&mut b, off, crop, &mut out_p, &pool);
        assert_allclose(&out_p, &out_s, 1e-4, 1e-3, "inverse_crop_par");
    }

    trait Volume_ {
        fn volume_(&self) -> usize;
    }
    impl Volume_ for Vec3 {
        fn volume_(&self) -> usize {
            self[0] * self[1] * self[2]
        }
    }

    #[test]
    fn mad_par_matches_serial() {
        let pool = crate::util::pool::TaskPool::with_topology(
            crate::util::pool::ChipTopology { chips: 1, cores_per_chip: 3 },
        );
        let mut r = Rng::new(77);
        let n = 1000;
        let rand_c32 = |r: &mut Rng| Complex32::new(r.f32_range(-1.0, 1.0), r.f32_range(-1.0, 1.0));
        let a: Vec<Complex32> = (0..n).map(|_| rand_c32(&mut r)).collect();
        let b: Vec<Complex32> = (0..n).map(|_| rand_c32(&mut r)).collect();
        let mut acc1 = vec![Complex32::new(0.1, 0.2); n];
        let mut acc2 = acc1.clone();
        Fft3::mad_spectra(&mut acc1, &a, &b);
        Fft3::mad_spectra_par(&mut acc2, &a, &b, &pool);
        let f1: Vec<f32> = acc1.iter().flat_map(|c| [c.re, c.im]).collect();
        let f2: Vec<f32> = acc2.iter().flat_map(|c| [c.re, c.im]).collect();
        assert_allclose(&f2, &f1, 1e-6, 1e-6, "mad par");
    }

    /// Convolution theorem end-to-end: FFT-multiply-IFFT must equal a
    /// direct "valid" 3D convolution.
    #[test]
    fn convolution_theorem_valid_region() {
        let n = [7, 6, 8];
        let k = [3, 2, 4];
        let padded = n; // overlap-save: pad only to image size
        let plan = Fft3::new(padded);
        let img = rand_img(n, 11);
        let ker = rand_img(k, 13);
        let mut sc = Fft3Scratch::new();

        let mut fi = vec![Complex32::ZERO; plan.complex_len()];
        let mut fk = vec![Complex32::ZERO; plan.complex_len()];
        plan.forward(&img, n, &mut fi, &mut sc);
        plan.forward(&ker, k, &mut fk, &mut sc);
        for (a, b) in fi.iter_mut().zip(fk.iter()) {
            *a = *a * *b;
        }
        let out_dims = [n[0] - k[0] + 1, n[1] - k[1] + 1, n[2] - k[2] + 1];
        let off = [k[0] - 1, k[1] - 1, k[2] - 1];
        let mut out = vec![0.0f32; out_dims[0] * out_dims[1] * out_dims[2]];
        plan.inverse_crop(&mut fi, off, out_dims, &mut out, &mut sc);

        // Direct valid *convolution* (flipped kernel).
        let mut expect = vec![0.0f32; out.len()];
        for x in 0..out_dims[0] {
            for y in 0..out_dims[1] {
                for z in 0..out_dims[2] {
                    let mut acc = 0.0f32;
                    for a in 0..k[0] {
                        for b in 0..k[1] {
                            for c in 0..k[2] {
                                let iv = img[((x + a) * n[1] + (y + b)) * n[2] + (z + c)];
                                let kv = ker[((k[0] - 1 - a) * k[1] + (k[1] - 1 - b)) * k[2]
                                    + (k[2] - 1 - c)];
                                acc += iv * kv;
                            }
                        }
                    }
                    expect[(x * out_dims[1] + y) * out_dims[2] + z] = acc;
                }
            }
        }
        assert_allclose(&out, &expect, 1e-3, 1e-2, "conv theorem");
    }
}
