//! 1D complex FFT: recursive mixed-radix Cooley–Tukey with specialised
//! radix-2/3/4 butterflies, plus real↔complex wrappers including the
//! two-for-one packed transform (two real lines per complex FFT) used by
//! the 3D schemes for batched line transforms.

use crate::tensor::Complex32;

use super::plan::factorize;

/// Reusable scratch for the real/inverse wrappers. One per thread;
/// grows to the largest plan it has served.
#[derive(Default)]
pub struct FftScratch {
    a: Vec<Complex32>,
    b: Vec<Complex32>,
}

impl FftScratch {
    /// Empty scratch.
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, n: usize) {
        if self.a.len() < n {
            self.a.resize(n, Complex32::ZERO);
            self.b.resize(n, Complex32::ZERO);
        }
    }
}

/// Precomputed plan for length-`n` transforms.
pub struct FftPlan {
    n: usize,
    /// tw[j] = e^{-2πi j / n}
    tw: Vec<Complex32>,
    factors: Vec<usize>,
}

impl FftPlan {
    /// Plan a length-`n` transform (twiddle table + factorization).
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "FFT length must be positive");
        let tw = (0..n)
            .map(|j| Complex32::cis(-2.0 * std::f64::consts::PI * j as f64 / n as f64))
            .collect();
        FftPlan { n, tw, factors: factorize(n) }
    }

    /// Transform length n.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Always false - plans have positive length.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Number of complex outputs of a real transform: n/2 + 1.
    pub fn half_len(&self) -> usize {
        self.n / 2 + 1
    }

    /// Forward complex DFT, out of place. `src` and `dst` have length n.
    pub fn forward(&self, src: &[Complex32], dst: &mut [Complex32]) {
        debug_assert_eq!(src.len(), self.n);
        debug_assert_eq!(dst.len(), self.n);
        self.rec(src, 1, dst, self.n, 0);
    }

    /// Inverse complex DFT (normalised by 1/n), out of place.
    pub fn inverse(&self, src: &[Complex32], dst: &mut [Complex32], scratch: &mut FftScratch) {
        scratch.ensure(self.n);
        for (s, d) in src.iter().zip(scratch.a.iter_mut()) {
            *d = s.conj();
        }
        self.rec(&scratch.a[..self.n], 1, dst, self.n, 0);
        let inv = 1.0 / self.n as f32;
        for d in dst.iter_mut() {
            *d = d.conj().scale(inv);
        }
    }

    /// Real → complex transform: `dst` receives the n/2+1 non-redundant
    /// bins.
    pub fn r2c(&self, src: &[f32], dst: &mut [Complex32], scratch: &mut FftScratch) {
        debug_assert_eq!(src.len(), self.n);
        debug_assert!(dst.len() >= self.half_len());
        scratch.ensure(self.n);
        for (i, s) in src.iter().enumerate() {
            scratch.a[i] = Complex32::new(*s, 0.0);
        }
        let (a, b) = {
            let FftScratch { a, b } = scratch;
            (&a[..self.n], &mut b[..self.n])
        };
        self.rec(a, 1, b, self.n, 0);
        dst[..self.half_len()].copy_from_slice(&b[..self.half_len()]);
    }

    /// Two-for-one: real transforms of two lines `pa`, `pb` for the cost
    /// of one complex FFT (pack z = a + i·b, then unpack by Hermitian
    /// symmetry). This is the work-horse of the batched 3D schemes.
    pub fn r2c_pair(
        &self,
        pa: &[f32],
        pb: &[f32],
        da: &mut [Complex32],
        db: &mut [Complex32],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        debug_assert_eq!(pa.len(), n);
        debug_assert_eq!(pb.len(), n);
        scratch.ensure(n);
        for i in 0..n {
            scratch.a[i] = Complex32::new(pa[i], pb[i]);
        }
        let (a, b) = {
            let FftScratch { a, b } = scratch;
            (&a[..n], &mut b[..n])
        };
        self.rec(a, 1, b, n, 0);
        let h = self.half_len();
        for k in 0..h {
            let u = b[k];
            let v = b[(n - k) % n].conj();
            da[k] = (u + v).scale(0.5);
            db[k] = (u - v).mul_neg_i().scale(0.5);
        }
    }

    /// Complex (half-spectrum) → real inverse transform.
    pub fn c2r(&self, src: &[Complex32], dst: &mut [f32], scratch: &mut FftScratch) {
        let n = self.n;
        let h = self.half_len();
        debug_assert!(src.len() >= h);
        debug_assert_eq!(dst.len(), n);
        scratch.ensure(n);
        // Build the conjugated full spectrum; then Re(FFT(conj X)) / n
        // is the inverse real signal.
        for k in 0..h {
            scratch.a[k] = src[k].conj();
        }
        for k in h..n {
            scratch.a[k] = src[n - k];
        }
        let (a, b) = {
            let FftScratch { a, b } = scratch;
            (&a[..n], &mut b[..n])
        };
        self.rec(a, 1, b, n, 0);
        let inv = 1.0 / n as f32;
        for i in 0..n {
            dst[i] = b[i].re * inv;
        }
    }

    /// Two-for-one inverse: recover two real lines from their half
    /// spectra with one complex FFT.
    pub fn c2r_pair(
        &self,
        sa: &[Complex32],
        sb: &[Complex32],
        da: &mut [f32],
        db: &mut [f32],
        scratch: &mut FftScratch,
    ) {
        let n = self.n;
        let h = self.half_len();
        scratch.ensure(n);
        // Z = A + i·B has IFFT z = a + i·b. Build conj(Z) and forward it:
        // z = conj(FFT(conj Z)) / n, so a = Re/n, b = -Im/n.
        for k in 0..h {
            scratch.a[k] = (sa[k] + sb[k].mul_i()).conj();
        }
        for k in h..n {
            scratch.a[k] = (sa[n - k].conj() + sb[n - k].conj().mul_i()).conj();
        }
        let (a, b) = {
            let FftScratch { a, b } = scratch;
            (&a[..n], &mut b[..n])
        };
        self.rec(a, 1, b, n, 0);
        let inv = 1.0 / n as f32;
        for i in 0..n {
            da[i] = b[i].re * inv;
            db[i] = -b[i].im * inv;
        }
    }

    /// Recursive decimation-in-time step: FFT of `src` (strided) into
    /// contiguous `dst[0..sub_n]`. `fi` indexes the factor used at this
    /// level; twiddle stride is `self.n / sub_n`.
    fn rec(
        &self,
        src: &[Complex32],
        stride: usize,
        dst: &mut [Complex32],
        sub_n: usize,
        fi: usize,
    ) {
        if sub_n == 1 {
            dst[0] = src[0];
            return;
        }
        let r = self.factors[fi];
        if sub_n == r {
            // Leaf: small strided DFT straight out of src.
            self.small_dft_strided(src, stride, dst, r);
            return;
        }
        let m = sub_n / r;
        for q in 0..r {
            self.rec(&src[q * stride..], stride * r, &mut dst[q * m..(q + 1) * m], m, fi + 1);
        }
        // Combine r sub-transforms of length m. The radix-2/4 combines
        // — the planned path's hot butterflies — go through the SIMD
        // kernel layer (twiddle-multiply + butterfly vectorised over
        // consecutive k2, with the scalar loop as remainder tail and
        // fallback); odd radices keep the scalar gather loop.
        let tw_step = self.n / sub_n;
        match r {
            2 => crate::simd::radix2_combine(&mut dst[..2 * m], m, &self.tw, tw_step, self.n),
            4 => crate::simd::radix4_combine(&mut dst[..4 * m], m, &self.tw, tw_step, self.n),
            _ => {
                let mut t = [Complex32::ZERO; 8];
                let mut tv: Vec<Complex32> =
                    if r > 8 { vec![Complex32::ZERO; r] } else { Vec::new() };
                for k2 in 0..m {
                    let t = if r <= 8 { &mut t[..r] } else { &mut tv[..] };
                    // Twiddle index q·k2·tw_step mod n by accumulation — no
                    // multiply/modulo in the gather loop (perf pass, see
                    // EXPERIMENTS.md §Perf), and the w = 1 case skipped.
                    let step = (k2 * tw_step) % self.n;
                    let mut w_idx = 0usize;
                    for q in 0..r {
                        let v = dst[q * m + k2];
                        t[q] = if w_idx == 0 { v } else { v * self.tw[w_idx] };
                        w_idx += step;
                        if w_idx >= self.n {
                            w_idx -= self.n;
                        }
                    }
                    match r {
                        3 => {
                            let (x0, x1, x2) = bf3(t[0], t[1], t[2]);
                            dst[k2] = x0;
                            dst[m + k2] = x1;
                            dst[2 * m + k2] = x2;
                        }
                        _ => {
                            // Generic radix: r-point naive DFT of t.
                            let wr = self.n / r;
                            for k3 in 0..r {
                                let mut acc = t[0];
                                for q in 1..r {
                                    acc.mad(t[q], self.tw[(q * k3 % r) * wr]);
                                }
                                dst[k3 * m + k2] = acc;
                            }
                        }
                    }
                }
            }
        }
    }

    /// Naive strided small DFT (leaf case, r ≤ 7 on the planned path).
    fn small_dft_strided(&self, src: &[Complex32], stride: usize, dst: &mut [Complex32], r: usize) {
        match r {
            2 => {
                let (a, b) = (src[0], src[stride]);
                dst[0] = a + b;
                dst[1] = a - b;
            }
            3 => {
                let (x0, x1, x2) = bf3(src[0], src[stride], src[2 * stride]);
                dst[0] = x0;
                dst[1] = x1;
                dst[2] = x2;
            }
            4 => {
                let (x0, x1, x2, x3) = bf4(src[0], src[stride], src[2 * stride], src[3 * stride]);
                dst[0] = x0;
                dst[1] = x1;
                dst[2] = x2;
                dst[3] = x3;
            }
            _ => {
                let wr = self.n / r;
                for k in 0..r {
                    let mut acc = src[0];
                    for q in 1..r {
                        acc.mad(src[q * stride], self.tw[(q * k % r) * wr]);
                    }
                    dst[k] = acc;
                }
            }
        }
    }
}

/// Radix-3 butterfly (forward), 2 real-mult form.
#[inline(always)]
fn bf3(t0: Complex32, t1: Complex32, t2: Complex32) -> (Complex32, Complex32, Complex32) {
    const S60: f32 = 0.866_025_4; // sin(2π/3)
    let s = t1 + t2;
    let d = t1 - t2;
    let x0 = t0 + s;
    let m = t0 - s.scale(0.5);
    let e = Complex32::new(S60 * d.im, -S60 * d.re); // -i·sin60·d
    (x0, m + e, m - e)
}

/// Radix-4 butterfly (forward): multiplies by ±i only.
#[inline(always)]
fn bf4(
    t0: Complex32,
    t1: Complex32,
    t2: Complex32,
    t3: Complex32,
) -> (Complex32, Complex32, Complex32, Complex32) {
    let a = t0 + t2;
    let b = t0 - t2;
    let c = t1 + t3;
    let d = (t1 - t3).mul_neg_i();
    (a + c, b + d, a - c, b - d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick::assert_allclose;

    /// O(n²) reference DFT.
    fn naive_dft(src: &[Complex32], sign: f64) -> Vec<Complex32> {
        let n = src.len();
        (0..n)
            .map(|k| {
                let mut acc = Complex32::ZERO;
                for (j, s) in src.iter().enumerate() {
                    let theta = sign * 2.0 * std::f64::consts::PI * (j * k % n) as f64;
                    let w = Complex32::cis(theta / n as f64);
                    acc.mad(*s, w);
                }
                acc
            })
            .collect()
    }

    fn flat(v: &[Complex32]) -> Vec<f32> {
        v.iter().flat_map(|c| [c.re, c.im]).collect()
    }

    fn rand_complex(n: usize, seed: u64) -> Vec<Complex32> {
        let mut r = crate::util::prng::Rng::new(seed);
        (0..n).map(|_| Complex32::new(r.f32_range(-1.0, 1.0), r.f32_range(-1.0, 1.0))).collect()
    }

    #[test]
    fn forward_matches_naive_many_sizes() {
        let sizes = [
            1usize, 2, 3, 4, 5, 6, 7, 8, 9, 10, 12, 15, 16, 20, 21, 24, 25, 27, 30, 32, 35, 36,
            48, 49, 60, 64, 11, 13, 22, 26, 33,
        ];
        for n in sizes {
            let plan = FftPlan::new(n);
            let src = rand_complex(n, n as u64);
            let mut dst = vec![Complex32::ZERO; n];
            plan.forward(&src, &mut dst);
            let expect = naive_dft(&src, -1.0);
            assert_allclose(&flat(&dst), &flat(&expect), 1e-3, 1e-3, &format!("fft n={n}"));
        }
    }

    #[test]
    fn inverse_roundtrip() {
        let mut scratch = FftScratch::new();
        for n in [4usize, 12, 30, 49, 64, 105] {
            let plan = FftPlan::new(n);
            let src = rand_complex(n, 7 + n as u64);
            let mut freq = vec![Complex32::ZERO; n];
            let mut back = vec![Complex32::ZERO; n];
            plan.forward(&src, &mut freq);
            plan.inverse(&freq, &mut back, &mut scratch);
            assert_allclose(&flat(&back), &flat(&src), 1e-4, 1e-3, &format!("ifft n={n}"));
        }
    }

    #[test]
    fn r2c_matches_complex_fft() {
        let mut scratch = FftScratch::new();
        for n in [4usize, 10, 24, 35, 64] {
            let plan = FftPlan::new(n);
            let mut r = crate::util::prng::Rng::new(n as u64);
            let real: Vec<f32> = (0..n).map(|_| r.f32_range(-1.0, 1.0)).collect();
            let mut half = vec![Complex32::ZERO; plan.half_len()];
            plan.r2c(&real, &mut half, &mut scratch);
            let src: Vec<Complex32> = real.iter().map(|&v| Complex32::new(v, 0.0)).collect();
            let full = naive_dft(&src, -1.0);
            assert_allclose(&flat(&half), &flat(&full[..plan.half_len()]), 1e-3, 1e-3, "r2c");
        }
    }

    #[test]
    fn r2c_c2r_roundtrip() {
        let mut scratch = FftScratch::new();
        for n in [4usize, 9, 20, 48, 70] {
            let plan = FftPlan::new(n);
            let mut r = crate::util::prng::Rng::new(n as u64 * 3);
            let real: Vec<f32> = (0..n).map(|_| r.f32_range(-1.0, 1.0)).collect();
            let mut half = vec![Complex32::ZERO; plan.half_len()];
            let mut back = vec![0.0f32; n];
            plan.r2c(&real, &mut half, &mut scratch);
            plan.c2r(&half, &mut back, &mut scratch);
            assert_allclose(&back, &real, 1e-4, 1e-3, &format!("r2c/c2r n={n}"));
        }
    }

    #[test]
    fn two_for_one_pair_matches_single() {
        let mut scratch = FftScratch::new();
        for n in [6usize, 16, 30, 63] {
            let plan = FftPlan::new(n);
            let mut r = crate::util::prng::Rng::new(n as u64 * 5);
            let a: Vec<f32> = (0..n).map(|_| r.f32_range(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..n).map(|_| r.f32_range(-1.0, 1.0)).collect();
            let h = plan.half_len();
            let (mut da, mut db) = (vec![Complex32::ZERO; h], vec![Complex32::ZERO; h]);
            let (mut ea, mut eb) = (vec![Complex32::ZERO; h], vec![Complex32::ZERO; h]);
            plan.r2c_pair(&a, &b, &mut da, &mut db, &mut scratch);
            plan.r2c(&a, &mut ea, &mut scratch);
            plan.r2c(&b, &mut eb, &mut scratch);
            assert_allclose(&flat(&da), &flat(&ea), 1e-3, 1e-3, "pair A");
            assert_allclose(&flat(&db), &flat(&eb), 1e-3, 1e-3, "pair B");
            // And the inverse pair.
            let (mut ra, mut rb) = (vec![0.0f32; n], vec![0.0f32; n]);
            plan.c2r_pair(&da, &db, &mut ra, &mut rb, &mut scratch);
            assert_allclose(&ra, &a, 1e-4, 1e-3, "pair inv A");
            assert_allclose(&rb, &b, 1e-4, 1e-3, "pair inv B");
        }
    }

    #[test]
    fn linearity_property() {
        crate::util::quick::check("fft linearity", |g| {
            let n = *g.choose(&[8usize, 12, 20, 36]);
            let plan = FftPlan::new(n);
            let a = rand_complex(n, g.case as u64);
            let b = rand_complex(n, g.case as u64 + 999);
            let alpha = g.f32(-2.0, 2.0);
            let sum: Vec<Complex32> =
                a.iter().zip(&b).map(|(x, y)| *x + y.scale(alpha)).collect();
            let mut fa = vec![Complex32::ZERO; n];
            let mut fb = vec![Complex32::ZERO; n];
            let mut fs = vec![Complex32::ZERO; n];
            plan.forward(&a, &mut fa);
            plan.forward(&b, &mut fb);
            plan.forward(&sum, &mut fs);
            let expect: Vec<Complex32> =
                fa.iter().zip(&fb).map(|(x, y)| *x + y.scale(alpha)).collect();
            assert_allclose(&flat(&fs), &flat(&expect), 1e-3, 1e-2, "linearity");
        });
    }

    #[test]
    fn impulse_gives_flat_spectrum() {
        let n = 24;
        let plan = FftPlan::new(n);
        let mut src = vec![Complex32::ZERO; n];
        src[0] = Complex32::ONE;
        let mut dst = vec![Complex32::ZERO; n];
        plan.forward(&src, &mut dst);
        for d in &dst {
            assert!((d.re - 1.0).abs() < 1e-5 && d.im.abs() < 1e-5);
        }
    }
}
