//! From-scratch FFT machinery, including the paper's **pruned FFT**
//! (§III) — the key primitive behind ZNNi's FFT-based convolution.
//!
//! * [`plan`] — mixed-radix planning, twiddle tables, FFT-optimal sizes
//!   (`2^a·3^b·5^c·7^d`, §III.D).
//! * [`dft`] — 1D complex FFT (recursive Cooley–Tukey with specialised
//!   radix-2/3/4/5 butterflies), real↔complex wrappers including the
//!   two-for-one packed real transform used for batched lines.
//! * [`fft3d`] — the CPU pruned 3D scheme of §III.B: per-dimension 1D
//!   passes that skip all-zero lines of the zero-padded input, cutting
//!   kernel-transform cost from `C·n³·log n³` to
//!   `C·n·log n·(k² + k·n + n²)`.
//! * [`batched`] — the GPU scheme of §III.C: batched contiguous 1D
//!   transforms interleaved with out-of-place 4D tensor permutes whose
//!   index arithmetic uses magic-number division (§III.D).

pub mod batched;
pub mod dft;
pub mod fft3d;
pub mod plan;

pub use dft::FftPlan;
pub use fft3d::Fft3;
pub use plan::{fft_optimal_size, fft_optimal_vec3, is_fft_fast_size};
