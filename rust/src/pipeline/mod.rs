//! CPU–GPU producer–consumer pipeline — §VII.C.
//!
//! The first θ layers of the network run on the CPU; the remaining
//! layers run on the (simulated) GPU. The CPU produces intermediate
//! tensors onto a depth-1 queue; the GPU consumes them. The depth-1
//! bound is the paper's backpressure rule: the CPU may not start the
//! next input until the GPU has picked up the previous one, keeping the
//! host-RAM overhead to a single in-flight intermediate.

use std::sync::mpsc::sync_channel;

use crate::exec::ExecCtx;
use crate::layers::LayerPrimitive;
use crate::tensor::Tensor5;
use crate::util::pool::TaskPool;

/// A two-stage pipeline over layer primitives.
pub struct Pipeline {
    /// Layers executed by the producer (CPU part, θ layers).
    pub head: Vec<Box<dyn LayerPrimitive>>,
    /// Layers executed by the consumer (GPU part).
    pub tail: Vec<Box<dyn LayerPrimitive>>,
}

impl Pipeline {
    /// Split point θ of a compiled layer stack.
    pub fn split(mut layers: Vec<Box<dyn LayerPrimitive>>, theta: usize) -> Self {
        assert!(theta <= layers.len());
        let tail = layers.split_off(theta);
        Pipeline { head: layers, tail }
    }

    /// Run a stream of inputs through the pipeline. The queue between
    /// the stages holds at most one tensor. Each stage owns a private
    /// [`ExecCtx`], reused across the whole stream, so head and tail
    /// never contend on one arena. The tail's working set is fully
    /// recycled after its first item; the head re-takes its egress
    /// tensor per item (ownership crosses the stage boundary and the
    /// buffer is retired into the *tail's* arena, which caps what it
    /// keeps), so the steady per-item cost of the head is one buffer
    /// allocation — bounded by the depth-1 queue, not accumulating.
    pub fn run_stream(&self, inputs: Vec<Tensor5>, pool: &TaskPool) -> Vec<Tensor5> {
        let n = inputs.len();
        let (tx, rx) = sync_channel::<Tensor5>(1);
        let mut outputs = Vec::with_capacity(n);
        std::thread::scope(|s| {
            // Producer: CPU part, with its own context.
            s.spawn(move || {
                let mut ctx = ExecCtx::new(pool);
                for input in inputs {
                    let mut cur = input;
                    for l in &self.head {
                        cur = l.execute(cur, &mut ctx);
                    }
                    // Blocks while the queue is full — the paper's
                    // "CPU waits until the GPU picked up the data".
                    tx.send(cur).expect("consumer alive");
                }
                drop(tx);
            });
            // Consumer: GPU part (this thread), its own context.
            let mut ctx = ExecCtx::new(pool);
            while let Ok(mid) = rx.recv() {
                let mut cur = mid;
                for l in &self.tail {
                    cur = l.execute(cur, &mut ctx);
                }
                outputs.push(cur);
            }
        });
        outputs
    }

    /// Sequential reference (no overlap) for testing and speedup
    /// accounting.
    pub fn run_sequential(&self, inputs: Vec<Tensor5>, pool: &TaskPool) -> Vec<Tensor5> {
        let mut ctx = ExecCtx::new(pool);
        inputs
            .into_iter()
            .map(|input| {
                let mut cur = input;
                for l in self.head.iter().chain(self.tail.iter()) {
                    cur = l.execute(cur, &mut ctx);
                }
                cur
            })
            .collect()
    }
}

/// Choose θ by cost model: minimise max(head-time, tail-time) — the
/// pipeline's steady-state period is the slower stage (§VII.C).
pub fn best_theta(layer_secs_cpu: &[f64], layer_secs_gpu: &[f64]) -> usize {
    assert_eq!(layer_secs_cpu.len(), layer_secs_gpu.len());
    let n = layer_secs_cpu.len();
    let mut best = (0usize, f64::INFINITY);
    for theta in 0..=n {
        let head: f64 = layer_secs_cpu[..theta].iter().sum();
        let tail: f64 = layer_secs_gpu[theta..].iter().sum();
        let period = head.max(tail);
        if period < best.1 {
            best = (theta, period);
        }
    }
    best.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{Activation, Weights};
    use crate::layers::{ConvLayer, MpfLayer, Placement};
    use crate::memory::model::ConvAlgo;
    use crate::tensor::Shape5;
    use crate::util::pool::ChipTopology;
    use crate::util::quick::assert_allclose;
    use std::sync::Arc;

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    fn layers() -> Vec<Box<dyn LayerPrimitive>> {
        vec![
            Box::new(ConvLayer::new(
                Arc::new(Weights::random(2, 1, [3, 3, 3], 1)),
                ConvAlgo::DirectMkl,
                Activation::Relu,
            )),
            Box::new(MpfLayer { window: [2, 2, 2], placement: Placement::Cpu }),
            Box::new(ConvLayer::new(
                Arc::new(Weights::random(1, 2, [3, 3, 3], 2)),
                ConvAlgo::GpuFft,
                Activation::Relu,
            )),
        ]
    }

    #[test]
    fn pipeline_matches_sequential() {
        let pool = tpool();
        let pipe = Pipeline::split(layers(), 2);
        let pipe2 = Pipeline::split(layers(), 2);
        let inputs: Vec<Tensor5> =
            (0..4).map(|i| Tensor5::random(Shape5::new(1, 1, 13, 13, 13), i)).collect();
        let inputs2: Vec<Tensor5> =
            (0..4).map(|i| Tensor5::random(Shape5::new(1, 1, 13, 13, 13), i)).collect();
        let a = pipe.run_stream(inputs, &pool);
        let b = pipe2.run_sequential(inputs2, &pool);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_allclose(x.data(), y.data(), 1e-5, 1e-5, "pipeline vs sequential");
        }
    }

    #[test]
    fn outputs_preserve_order() {
        let pool = tpool();
        let pipe = Pipeline::split(layers(), 1);
        // Distinct inputs → distinct outputs; order must match.
        let inputs: Vec<Tensor5> =
            (0..3).map(|i| Tensor5::random(Shape5::new(1, 1, 13, 13, 13), 100 + i)).collect();
        let seq_in: Vec<Tensor5> =
            (0..3).map(|i| Tensor5::random(Shape5::new(1, 1, 13, 13, 13), 100 + i)).collect();
        let a = pipe.run_stream(inputs, &pool);
        let b = pipe.run_sequential(seq_in, &pool);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.data(), y.data());
        }
    }

    #[test]
    fn theta_zero_and_full() {
        let pool = tpool();
        for theta in [0, 3] {
            let pipe = Pipeline::split(layers(), theta);
            let out = pipe.run_stream(
                vec![Tensor5::random(Shape5::new(1, 1, 13, 13, 13), 7)],
                &pool,
            );
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].shape().f, 1);
        }
    }

    #[test]
    fn best_theta_balances_stages() {
        // CPU times all 1.0; GPU times all 0.5: putting everything on
        // the GPU (θ=0) gives period 1.5... θ=0 → tail 1.5, θ=3 → head 3.
        let cpu = [1.0, 1.0, 1.0];
        let gpu = [0.5, 0.5, 0.5];
        let t = best_theta(&cpu, &gpu);
        // θ=0: max(0, 1.5)=1.5 ; θ=1: max(1, 1)=1 ; θ=2: max(2, .5)=2.
        assert_eq!(t, 1);
    }

    #[test]
    fn best_theta_degenerate() {
        assert_eq!(best_theta(&[], &[]), 0);
        // GPU dominates: keep everything on GPU.
        assert_eq!(best_theta(&[10.0], &[0.1]), 0);
        // CPU dominates: everything on CPU.
        assert_eq!(best_theta(&[0.1], &[10.0]), 1);
    }
}
