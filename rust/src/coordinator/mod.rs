//! Serving coordinator: the L3 front that turns whole-volume inference
//! requests into patch work, dispatches patches to workers, and
//! reassembles + reports.
//!
//! Architecture (vLLM-router-like, adapted to throughput-oriented 3D
//! inference):
//!
//! ```text
//!  requests ──► job list ──► worker(s) ─────────► per-request outputs
//!               (start        crop patch from      (mutex-guarded;
//!                coords        volume, compiled     workers write their
//!                only)         plan, MPF            cover region, then
//!                              recombine)           retire the buffer)
//! ```
//!
//! Memory discipline: each worker keeps one long-lived [`Arena`]
//! (persisted across `serve` calls). Patch inputs, every intermediate
//! tensor, FFT spectrum/workspace, and the recombined dense output are
//! all drawn from it; the dense buffer is retired right back after its
//! cover region is copied into the request output. The whole buffer
//! cycle therefore stays inside one worker — after a one-patch warmup
//! a serve loop performs **zero transient allocations**, and at most
//! `workers` patches of data are in flight (a tighter bound than the
//! old pre-cropped patch queue).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::exec::{Arena, ExecCtx};
use crate::inference::{dense_output_shape, fragment_map, recombine, FragmentMap};
use crate::net::{NetSpec, PoolingMode};
use crate::optimizer::CompiledPlan;
use crate::tensor::{Shape5, Tensor5, Vec3};
use crate::util::faults::{self, FaultSite};
use crate::util::pool::TaskPool;
use crate::util::sync::recover_lock;

/// A whole-volume inference request.
pub struct InferenceRequest {
    /// Caller-chosen request id (echoed in the response).
    pub id: u64,
    /// The whole input volume (1 x f_in x X x Y x Z).
    pub volume: Tensor5,
}

/// The served result.
pub struct InferenceResponse {
    /// Id of the request this answers.
    pub id: u64,
    /// Dense sliding-window output.
    pub output: Tensor5,
    /// Serve latency (batch-level on this testbed).
    pub latency: Duration,
    /// Patches executed for this request (0 = batch-level accounting).
    pub patches: usize,
    /// Output voxels produced.
    pub voxels: u64,
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Requests served.
    pub requests: usize,
    /// Patches executed.
    pub patches: usize,
    /// Dense output voxels produced.
    pub voxels: u64,
    /// Summed worker compute seconds.
    pub busy_secs: f64,
    /// Wall-clock seconds of the serve call.
    pub wall_secs: f64,
    /// Max arena footprint (held + outstanding bytes) across the
    /// workers of this serve call.
    pub arena_hwm_bytes: u64,
    /// Arena takes this serve call served with *fresh* allocations —
    /// zero on a warm coordinator means the steady state ran
    /// allocation-free.
    pub arena_fresh_allocs: u64,
    /// Seconds workers spent *waiting* to acquire output-assembly band
    /// locks (summed across workers). Assembly is banded per output
    /// region, so this should stay near zero even at high shard/worker
    /// counts; a large value flags contention worth re-banding.
    pub assembly_lock_wait_secs: f64,
    /// Resident bytes of the plan's precomputed kernel-spectra caches —
    /// the RAM the weight-spectrum cache is buying throughput with. One
    /// shared `Arc` per layer (not per worker), so merge takes the max.
    pub kernel_cache_bytes: u64,
}

impl Metrics {
    /// Voxels per wall second.
    pub fn throughput(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.voxels as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "requests={} patches={} voxels={} wall={:.3}s busy={:.3}s throughput={} arena_hwm={} arena_fresh_allocs={} assembly_lock_wait={:.6}s kernel_cache={}",
            self.requests,
            self.patches,
            self.voxels,
            self.wall_secs,
            self.busy_secs,
            crate::util::human_throughput(self.throughput()),
            crate::util::human_bytes(self.arena_hwm_bytes),
            self.arena_fresh_allocs,
            self.assembly_lock_wait_secs,
            crate::util::human_bytes(self.kernel_cache_bytes),
        )
    }

    /// Fold another serve call's metrics into this one. Aggregation is
    /// over *sequential* serve calls (one shard's batches run one after
    /// another), so wall seconds sum like the counters do and
    /// `throughput()` on the merged value stays honest; only the arena
    /// high-water mark takes the max.
    pub fn merge(&mut self, other: &Metrics) {
        self.requests += other.requests;
        self.patches += other.patches;
        self.voxels += other.voxels;
        self.busy_secs += other.busy_secs;
        self.wall_secs += other.wall_secs;
        self.arena_hwm_bytes = self.arena_hwm_bytes.max(other.arena_hwm_bytes);
        self.arena_fresh_allocs += other.arena_fresh_allocs;
        self.assembly_lock_wait_secs += other.assembly_lock_wait_secs;
        // One shared cache, reported by every serve call: max, not sum.
        self.kernel_cache_bytes = self.kernel_cache_bytes.max(other.kernel_cache_bytes);
    }
}

/// The coordinator: a compiled plan + patch geometry + worker loop.
pub struct Coordinator {
    /// The served network architecture.
    pub net: NetSpec,
    plan: Arc<CompiledPlan>,
    fmap: FragmentMap,
    fov: Vec3,
    patch: Vec3,
    /// Retained for API compatibility; patch results are written in
    /// place by workers, so in-flight data is bounded by `workers`.
    pub queue_depth: usize,
    /// Number of worker threads pulling patches.
    pub workers: usize,
    /// Home NUMA node CPU set for this coordinator's serve workers.
    /// `None` (the default, and always the case on single-node hosts)
    /// means workers float and no affinity syscalls are issued. When
    /// set by [`crate::server::Server`] under `ZNNI_NUMA=auto` on a
    /// multi-node machine, each scoped serve worker pins itself to
    /// these CPUs and owner-touches its warm arena before executing,
    /// so first-touched pages land on the shard's home node.
    pub home_cpus: Option<Arc<Vec<usize>>>,
    /// Warm per-worker arenas, persisted across `serve` calls so the
    /// second and later calls run allocation-free from the first patch.
    arenas: Mutex<Vec<Arena>>,
}

impl Coordinator {
    /// Build a coordinator for an all-MPF compiled plan. The patch
    /// extent is the plan's input extent.
    pub fn new(net: NetSpec, plan: CompiledPlan) -> Result<Coordinator> {
        Self::with_shared_plan(net, Arc::new(plan))
    }

    /// Build a coordinator over an already-shared compiled plan.
    /// [`crate::server::Server`] replicates one plan across N shards —
    /// each shard gets its own warm arena set while the primitives,
    /// weights and the process-wide FFT plan cache stay shared.
    pub fn with_shared_plan(net: NetSpec, plan: Arc<CompiledPlan>) -> Result<Coordinator> {
        let modes = plan.plan.modes();
        if modes.iter().any(|m| *m != PoolingMode::Mpf) {
            bail!("coordinator requires an all-MPF plan");
        }
        let fmap = fragment_map(&net, &modes)?;
        let fov = net.field_of_view();
        let patch = [plan.plan.input.x, plan.plan.input.y, plan.plan.input.z];
        Ok(Coordinator {
            net,
            plan,
            fmap,
            fov,
            patch,
            queue_depth: 2,
            workers: 1,
            home_cpus: None,
            arenas: Mutex::new(Vec::new()),
        })
    }

    /// Patch extent per dimension (the plan's input extent).
    pub fn patch(&self) -> Vec3 {
        self.patch
    }

    /// The compiled plan this coordinator executes.
    pub fn plan(&self) -> &Arc<CompiledPlan> {
        &self.plan
    }

    /// Drop every warm per-worker arena. The shard supervisor calls
    /// this after a panic: an unwinding worker loses its checked-out
    /// arena mid-flight, so the survivors are dropped too and the next
    /// serve call re-warms a consistent set (their backing memory is
    /// released through the global ledger as usual).
    pub fn reset_arenas(&self) {
        recover_lock(&self.arenas).clear();
    }

    /// The compiled plan's arena requirement per worker (Table II max
    /// across layers) — what each worker's warm arena converges to.
    pub fn workspace_req(&self, threads: usize) -> crate::exec::WorkspaceReq {
        self.plan.workspace_req(threads)
    }

    /// Patch cover extent (dense output voxels per patch per dim).
    pub fn cover(&self) -> Vec3 {
        [
            self.patch[0] - self.fov[0] + 1,
            self.patch[1] - self.fov[1] + 1,
            self.patch[2] - self.fov[2] + 1,
        ]
    }

    fn patch_starts(&self, vdims: Vec3) -> Vec<Vec3> {
        let cover = self.cover();
        let per_dim = |d: usize| -> Vec<usize> {
            let mut v = Vec::new();
            let mut s = 0;
            loop {
                if s + self.patch[d] >= vdims[d] {
                    v.push(vdims[d] - self.patch[d]);
                    break;
                }
                v.push(s);
                s += cover[d];
            }
            v
        };
        let (xs, ys, zs) = (per_dim(0), per_dim(1), per_dim(2));
        let mut out = Vec::new();
        for &x in &xs {
            for &y in &ys {
                for &z in &zs {
                    out.push([x, y, z]);
                }
            }
        }
        out
    }

    /// Serve a batch of requests: split → dispatch → recombine →
    /// assemble. Returns responses in request order plus metrics.
    pub fn serve(
        &self,
        requests: Vec<InferenceRequest>,
        pool: &TaskPool,
    ) -> Result<(Vec<InferenceResponse>, Metrics)> {
        // Build any planned kernel-spectra caches before the clock
        // starts and the workers spawn (idempotent — a no-op once
        // built), so the one-off transforms land in neither a worker's
        // patch loop nor this serve call's wall-clock/throughput
        // metrics.
        let kernel_cache_bytes = self.plan.warm_kernel_caches(pool);
        let t_wall = Instant::now();
        let fov = self.fov;
        let cover = self.cover();
        let f_out = self.net.f_out();

        // Pre-validate and allocate outputs (one per request; these are
        // the only per-request allocations of the serve loop).
        let mut outputs: Vec<Tensor5> = Vec::new();
        let mut out_shapes: Vec<Shape5> = Vec::new();
        for r in &requests {
            let sh = r.volume.shape();
            if sh.s != 1 || sh.f != self.net.f_in {
                bail!("request {}: expected shape (1, {}, ...)", r.id, self.net.f_in);
            }
            for d in 0..3 {
                if self.patch[d] > [sh.x, sh.y, sh.z][d] {
                    bail!("request {}: volume smaller than patch {:?}", r.id, self.patch);
                }
            }
            let osh = dense_output_shape(sh, fov, f_out);
            out_shapes.push(osh);
            outputs.push(Tensor5::zeros(osh));
        }

        // Assembly bands: each dense output is split into contiguous
        // chunks of whole (f, x) planes with one lock per chunk, so
        // concurrent workers serialize only on the region they actually
        // write instead of contending on one per-request mutex. A row
        // always lies inside one plane, hence inside one chunk.
        let chunk_lens: Vec<usize> = out_shapes
            .iter()
            .map(|osh| {
                let plane = osh.y * osh.z;
                let planes = osh.f * osh.x;
                crate::util::ceil_div(planes, self.workers.max(1) * 8).max(1) * plane
            })
            .collect();
        let bands: Vec<Vec<Mutex<&mut [f32]>>> = outputs
            .iter_mut()
            .zip(&chunk_lens)
            .map(|(t, &cl)| t.data_mut().chunks_mut(cl).map(Mutex::new).collect())
            .collect();

        // The job list is start coordinates only — workers crop from
        // the request volumes on demand, into arena buffers.
        let mut jobs: Vec<(usize, Vec3)> = Vec::new();
        for (ri, r) in requests.iter().enumerate() {
            let vsh = r.volume.shape();
            for start in self.patch_starts([vsh.x, vsh.y, vsh.z]) {
                jobs.push((ri, start));
            }
        }
        let next = AtomicUsize::new(0);

        let arena_hwm = AtomicU64::new(0);
        let arena_fresh = AtomicU64::new(0);
        let patches = AtomicUsize::new(0);
        let voxels = AtomicU64::new(0);
        // busy / lock-wait seconds in micro/nanoseconds (atomics carry
        // no f64).
        let busy_us = AtomicU64::new(0);
        let assembly_ns = AtomicU64::new(0);
        std::thread::scope(|s| {
            // Workers: crop patch → compiled plan → recombination →
            // in-place assembly, all against a long-lived per-worker
            // context whose buffers cycle locally.
            let mut handles = Vec::with_capacity(self.workers.max(1));
            for _ in 0..self.workers.max(1) {
                let plan = self.plan.clone();
                let fmap = &self.fmap;
                let reqs = &requests;
                let jobs = &jobs;
                let next = &next;
                let bands = &bands;
                let chunk_lens = &chunk_lens;
                let out_shapes = &out_shapes;
                let patch = self.patch;
                let arena_hwm = &arena_hwm;
                let arena_fresh = &arena_fresh;
                let patches = &patches;
                let voxels = &voxels;
                let busy_us = &busy_us;
                let assembly_ns = &assembly_ns;
                handles.push(s.spawn(move || {
                    // Home-node placement: pin this worker to the
                    // shard's CPU set *before* taking the arena, then
                    // owner-touch the warm buffers so any page not yet
                    // committed (or migrated by a prior floating run)
                    // is first-touched node-local. Both are no-ops when
                    // no home node was assigned (single-node hosts,
                    // `ZNNI_NUMA=off`).
                    let mut arena = recover_lock(&self.arenas).pop().unwrap_or_default();
                    if let Some(cpus) = &self.home_cpus {
                        crate::util::numa::pin_current_thread(cpus);
                        arena.touch_pages();
                    }
                    let fresh_before = arena.stats().fresh_allocs;
                    let mut ctx = ExecCtx::from_arena(pool, arena);
                    let mut lock_ns = 0u64;
                    loop {
                        let idx = next.fetch_add(1, Ordering::SeqCst);
                        let Some(&(ri, start)) = jobs.get(idx) else { break };
                        // Failpoint: a panic here unwinds this worker
                        // (losing its arena), propagates through the
                        // scope, and must surface as a typed error —
                        // never a hung ticket.
                        faults::fire(FaultSite::WorkerPatch);
                        let r = &reqs[ri];
                        let vsh = r.volume.shape();
                        let mut pin = ctx.tensor5(Shape5::from_spatial(1, vsh.f, patch));
                        for f in 0..vsh.f {
                            for x in 0..patch[0] {
                                for y in 0..patch[1] {
                                    let src = (f * vsh.x + start[0] + x) * vsh.y * vsh.z
                                        + (start[1] + y) * vsh.z
                                        + start[2];
                                    let dst = (f * patch[0] + x) * patch[1] * patch[2]
                                        + y * patch[2];
                                    pin.data_mut()[dst..dst + patch[2]]
                                        .copy_from_slice(&r.volume.data()[src..src + patch[2]]);
                                }
                            }
                        }
                        let t0 = Instant::now();
                        let raw = plan.run(pin, &mut ctx);
                        let dense = recombine(&raw, 1, fmap, &mut ctx);
                        ctx.retire(raw);
                        busy_us.fetch_add(t0.elapsed().as_micros() as u64, Ordering::SeqCst);
                        // Assemble in place: this patch's cover region.
                        // Overlapping regions (clamped final patches)
                        // receive identical values; the per-chunk band
                        // locks keep concurrent writers exclusive while
                        // letting patches of disjoint regions proceed
                        // in parallel.
                        {
                            let osh = out_shapes[ri];
                            let chunk_len = chunk_lens[ri];
                            let bands_r = &bands[ri];
                            for f in 0..f_out {
                                for x in 0..cover[0] {
                                    let drow0 = ((f * osh.x + start[0] + x) * osh.y + start[1])
                                        * osh.z
                                        + start[2];
                                    let chunk = drow0 / chunk_len;
                                    let base = chunk * chunk_len;
                                    let t_lock = Instant::now();
                                    let mut band = recover_lock(&bands_r[chunk]);
                                    lock_ns += t_lock.elapsed().as_nanos() as u64;
                                    let buf: &mut [f32] = &mut band;
                                    for y in 0..cover[1] {
                                        let srow = ((f * cover[0] + x) * cover[1] + y) * cover[2];
                                        let drow = drow0 + y * osh.z;
                                        buf[drow - base..drow - base + cover[2]].copy_from_slice(
                                            &dense.data()[srow..srow + cover[2]],
                                        );
                                    }
                                }
                            }
                        }
                        ctx.retire(dense);
                        patches.fetch_add(1, Ordering::SeqCst);
                        voxels.fetch_add((cover[0] * cover[1] * cover[2]) as u64, Ordering::SeqCst);
                    }
                    assembly_ns.fetch_add(lock_ns, Ordering::SeqCst);
                    let st = ctx.arena.stats();
                    arena_hwm.fetch_max(st.hwm_bytes, Ordering::SeqCst);
                    arena_fresh.fetch_add(st.fresh_allocs - fresh_before, Ordering::SeqCst);
                    recover_lock(&self.arenas).push(ctx.into_arena());
                }));
            }
            // Join explicitly and re-raise the first panic with its
            // original payload: `std::thread::scope` alone would replace
            // it with a generic "a scoped thread panicked" message,
            // losing the failpoint site the server's supervisor reports
            // in `ServeError::Internal`.
            for h in handles {
                if let Err(payload) = h.join() {
                    std::panic::resume_unwind(payload);
                }
            }
        });

        let wall = t_wall.elapsed();
        drop(bands);
        let mut responses = Vec::new();
        for (ri, output) in outputs.into_iter().enumerate() {
            let osh = output.shape();
            responses.push(InferenceResponse {
                id: requests[ri].id,
                output,
                latency: wall, // batch-level latency on this testbed
                patches: 0,
                voxels: (osh.x * osh.y * osh.z) as u64,
            });
        }
        let metrics = Metrics {
            requests: responses.len(),
            patches: patches.load(Ordering::SeqCst),
            voxels: voxels.load(Ordering::SeqCst),
            busy_secs: busy_us.load(Ordering::SeqCst) as f64 / 1e6,
            wall_secs: wall.as_secs_f64(),
            arena_hwm_bytes: arena_hwm.load(Ordering::SeqCst),
            arena_fresh_allocs: arena_fresh.load(Ordering::SeqCst),
            assembly_lock_wait_secs: assembly_ns.load(Ordering::SeqCst) as f64 / 1e9,
            kernel_cache_bytes,
        };
        Ok((responses, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo::tiny_net;
    use crate::optimizer::{compile, make_weights, search, CostModel, SearchSpace};
    use crate::device::Device;
    use crate::util::pool::ChipTopology;
    use crate::util::quick::assert_allclose;

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    fn make_coordinator(seed: u64) -> (Coordinator, TaskPool) {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
        space.max_candidates = 2;
        let plan = search(&net, &space, &cm).unwrap();
        let weights = make_weights(&net, seed);
        let cp = compile(&net, &plan, &weights).unwrap();
        (Coordinator::new(net, cp).unwrap(), tpool())
    }

    #[test]
    fn serves_single_request() {
        let (c, pool) = make_coordinator(1);
        let fov = c.net.field_of_view();
        let vol = Tensor5::random(Shape5::new(1, 1, 20, 20, 20), 2);
        let (resp, metrics) = c
            .serve(vec![InferenceRequest { id: 7, volume: vol }], &pool)
            .unwrap();
        assert_eq!(resp.len(), 1);
        assert_eq!(resp[0].id, 7);
        let osh = resp[0].output.shape();
        assert_eq!((osh.x, osh.y, osh.z), (20 - fov[0] + 1, 20 - fov[1] + 1, 20 - fov[2] + 1));
        assert!(metrics.patches >= 1);
        assert!(metrics.throughput() > 0.0);
        assert!(metrics.arena_hwm_bytes > 0);
    }

    #[test]
    fn serve_matches_direct_infer_volume() {
        let (c, pool) = make_coordinator(3);
        let vol = Tensor5::random(Shape5::new(1, 1, 19, 19, 19), 9);
        let vol2 = vol.clone_tensor();
        let (resp, _) = c.serve(vec![InferenceRequest { id: 0, volume: vol }], &pool).unwrap();

        // Reference through inference::infer_volume with the same plan.
        let fmap = fragment_map(&c.net, &c.plan.plan.modes()).unwrap();
        let mut ctx = ExecCtx::new(&pool);
        let mut runner = |t: Tensor5| {
            let raw = c.plan.run(t, &mut ctx);
            let dense = recombine(&raw, 1, &fmap, &mut ctx);
            ctx.retire(raw);
            dense
        };
        let expect = crate::inference::infer_volume(
            &vol2,
            c.net.field_of_view(),
            c.patch,
            c.net.f_out(),
            &mut runner,
        )
        .unwrap();
        assert_allclose(resp[0].output.data(), expect.data(), 1e-5, 1e-5, "serve == infer");
    }

    #[test]
    fn serves_multiple_requests_in_order() {
        let (c, pool) = make_coordinator(5);
        let reqs = (0..3)
            .map(|i| InferenceRequest {
                id: 100 + i,
                volume: Tensor5::random(Shape5::new(1, 1, 16, 16, 16), i),
            })
            .collect();
        let (resp, metrics) = c.serve(reqs, &pool).unwrap();
        assert_eq!(resp.len(), 3);
        assert_eq!(resp.iter().map(|r| r.id).collect::<Vec<_>>(), vec![100, 101, 102]);
        assert_eq!(metrics.requests, 3);
    }

    #[test]
    fn rejects_undersized_volume() {
        let (c, pool) = make_coordinator(7);
        let vol = Tensor5::random(Shape5::new(1, 1, 5, 5, 5), 2);
        assert!(c.serve(vec![InferenceRequest { id: 0, volume: vol }], &pool).is_err());
    }

    #[test]
    fn multi_worker_serve_matches_single_worker() {
        let (mut c, pool) = make_coordinator(13);
        let vol = Tensor5::random(Shape5::new(1, 1, 22, 22, 22), 4);
        let vol2 = vol.clone_tensor();
        let (single, _) = c.serve(vec![InferenceRequest { id: 0, volume: vol }], &pool).unwrap();
        c.workers = 3;
        let (multi, m) = c.serve(vec![InferenceRequest { id: 0, volume: vol2 }], &pool).unwrap();
        assert!(m.patches >= 2);
        assert_eq!(single[0].output.data(), multi[0].output.data());
    }

    #[test]
    fn concurrent_banded_assembly_bit_identical() {
        // Regression for the per-request assembly mutex split: many
        // workers racing to assemble several requests through the
        // banded region locks must produce outputs bit-identical to a
        // single worker, and the lock-wait gauge must be reported.
        let (mut c, pool) = make_coordinator(17);
        let mk = |seed: u64| Tensor5::random(Shape5::new(1, 1, 24, 24, 24), seed);
        let reqs = |base: u64| {
            (0..3)
                .map(|i| InferenceRequest { id: base + i, volume: mk(i + 40) })
                .collect::<Vec<_>>()
        };
        c.workers = 1;
        let (serial, _) = c.serve(reqs(0), &pool).unwrap();
        c.workers = 4;
        let (concurrent, m) = c.serve(reqs(100), &pool).unwrap();
        assert!(m.patches >= 8, "want several patches in flight, got {}", m.patches);
        assert!(m.assembly_lock_wait_secs >= 0.0);
        for (a, b) in serial.iter().zip(&concurrent) {
            assert_eq!(a.output.data(), b.output.data(), "banded assembly diverged");
        }
    }

    #[test]
    fn warm_serve_is_allocation_free() {
        // THE steady-state assertion: after the first serve call warms
        // the per-worker arena, a second serve over the same shapes
        // performs zero transient allocations per patch — every take
        // hits a recycled buffer.
        let (c, pool) = make_coordinator(11);
        let mk = |seed| Tensor5::random(Shape5::new(1, 1, 20, 20, 20), seed);
        let (_, warmup) = c
            .serve(vec![InferenceRequest { id: 0, volume: mk(1) }], &pool)
            .unwrap();
        assert!(warmup.arena_fresh_allocs > 0, "cold serve must allocate");
        let (resp, steady) = c
            .serve(vec![InferenceRequest { id: 1, volume: mk(2) }], &pool)
            .unwrap();
        assert!(steady.patches >= 1);
        assert_eq!(
            steady.arena_fresh_allocs, 0,
            "warm serve must run allocation-free (hwm={} patches={})",
            steady.arena_hwm_bytes, steady.patches
        );
        assert!(resp[0].output.data().iter().any(|&v| v != 0.0));
    }
}
