//! Serving coordinator: the L3 front that turns whole-volume inference
//! requests into patch work, dispatches patches to workers, and
//! reassembles + reports.
//!
//! Architecture (vLLM-router-like, adapted to throughput-oriented 3D
//! inference):
//!
//! ```text
//!  requests ──► patcher ──► patch queue ──► worker(s) ──► assembler
//!               (overlap-save split)        (compiled      (writes into
//!                                            plan + MPF     per-request
//!                                            recombine)     output volume)
//! ```
//!
//! Workers share the process [`TaskPool`]; the queue applies
//! backpressure (bounded channel) so host memory holds a bounded number
//! of in-flight patches — the same memory discipline as §VII.C.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::inference::{fragment_map, recombine, FragmentMap};
use crate::net::{NetSpec, PoolingMode};
use crate::optimizer::CompiledPlan;
use crate::tensor::{Shape5, Tensor5, Vec3};
use crate::util::pool::TaskPool;

/// A whole-volume inference request.
pub struct InferenceRequest {
    pub id: u64,
    pub volume: Tensor5,
}

/// The served result.
pub struct InferenceResponse {
    pub id: u64,
    pub output: Tensor5,
    pub latency: Duration,
    pub patches: usize,
    pub voxels: u64,
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: usize,
    pub patches: usize,
    pub voxels: u64,
    pub busy_secs: f64,
    pub wall_secs: f64,
}

impl Metrics {
    pub fn throughput(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.voxels as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} patches={} voxels={} wall={:.3}s busy={:.3}s throughput={}",
            self.requests,
            self.patches,
            self.voxels,
            self.wall_secs,
            self.busy_secs,
            crate::util::human_throughput(self.throughput()),
        )
    }
}

struct PatchJob {
    req: usize,
    start: Vec3,
    input: Tensor5,
}

struct PatchResult {
    req: usize,
    start: Vec3,
    output: Tensor5,
    secs: f64,
}

/// The coordinator: a compiled plan + patch geometry + worker loop.
pub struct Coordinator {
    pub net: NetSpec,
    plan: Arc<CompiledPlan>,
    fmap: FragmentMap,
    fov: Vec3,
    patch: Vec3,
    /// Bound on in-flight patches (queue depth).
    pub queue_depth: usize,
    /// Number of worker threads pulling patches.
    pub workers: usize,
}

impl Coordinator {
    /// Build a coordinator for an all-MPF compiled plan. The patch
    /// extent is the plan's input extent.
    pub fn new(net: NetSpec, plan: CompiledPlan) -> Result<Coordinator> {
        let modes = plan.plan.modes();
        if modes.iter().any(|m| *m != PoolingMode::Mpf) {
            bail!("coordinator requires an all-MPF plan");
        }
        let fmap = fragment_map(&net, &modes)?;
        let fov = net.field_of_view();
        let patch = [plan.plan.input.x, plan.plan.input.y, plan.plan.input.z];
        Ok(Coordinator { net, plan: Arc::new(plan), fmap, fov, patch, queue_depth: 2, workers: 1 })
    }

    /// Patch cover extent (dense output voxels per patch per dim).
    pub fn cover(&self) -> Vec3 {
        [
            self.patch[0] - self.fov[0] + 1,
            self.patch[1] - self.fov[1] + 1,
            self.patch[2] - self.fov[2] + 1,
        ]
    }

    fn patch_starts(&self, vdims: Vec3) -> Vec<Vec3> {
        let cover = self.cover();
        let per_dim = |d: usize| -> Vec<usize> {
            let mut v = Vec::new();
            let mut s = 0;
            loop {
                if s + self.patch[d] >= vdims[d] {
                    v.push(vdims[d] - self.patch[d]);
                    break;
                }
                v.push(s);
                s += cover[d];
            }
            v
        };
        let (xs, ys, zs) = (per_dim(0), per_dim(1), per_dim(2));
        let mut out = Vec::new();
        for &x in &xs {
            for &y in &ys {
                for &z in &zs {
                    out.push([x, y, z]);
                }
            }
        }
        out
    }

    /// Serve a batch of requests: split → dispatch → recombine →
    /// assemble. Returns responses in request order plus metrics.
    pub fn serve(
        &self,
        requests: Vec<InferenceRequest>,
        pool: &TaskPool,
    ) -> Result<(Vec<InferenceResponse>, Metrics)> {
        let t_wall = Instant::now();
        let fov = self.fov;
        let cover = self.cover();
        let f_out = self.net.f_out();

        // Pre-validate and allocate outputs.
        let mut outputs = Vec::new();
        let mut req_meta = Vec::new();
        for r in &requests {
            let sh = r.volume.shape();
            if sh.s != 1 || sh.f != self.net.f_in {
                bail!("request {}: expected shape (1, {}, ...)", r.id, self.net.f_in);
            }
            for d in 0..3 {
                if self.patch[d] > [sh.x, sh.y, sh.z][d] {
                    bail!("request {}: volume smaller than patch {:?}", r.id, self.patch);
                }
            }
            let odims = [sh.x - fov[0] + 1, sh.y - fov[1] + 1, sh.z - fov[2] + 1];
            outputs.push(Mutex::new(Tensor5::zeros(Shape5::from_spatial(1, f_out, odims))));
            req_meta.push((r.id, Instant::now()));
        }

        let (jtx, jrx): (SyncSender<PatchJob>, Receiver<PatchJob>) =
            sync_channel(self.queue_depth.max(1));
        let (rtx, rrx) = sync_channel::<PatchResult>(self.queue_depth.max(1));
        let jrx = Arc::new(Mutex::new(jrx));

        let mut total_patches = 0usize;
        let mut busy = 0.0f64;
        let mut voxels = 0u64;
        std::thread::scope(|s| -> Result<()> {
            // Patcher thread: crop patches and feed the queue.
            let reqs = &requests;
            let patch = self.patch;
            s.spawn(move || {
                for (ri, r) in reqs.iter().enumerate() {
                    let vsh = r.volume.shape();
                    for start in self.patch_starts([vsh.x, vsh.y, vsh.z]) {
                        let mut pin = Tensor5::zeros(Shape5::from_spatial(1, vsh.f, patch));
                        for f in 0..vsh.f {
                            for x in 0..patch[0] {
                                for y in 0..patch[1] {
                                    let src = ((f) * vsh.x + start[0] + x) * vsh.y * vsh.z
                                        + (start[1] + y) * vsh.z
                                        + start[2];
                                    let dst = (f * patch[0] + x) * patch[1] * patch[2]
                                        + y * patch[2];
                                    pin.data_mut()[dst..dst + patch[2]]
                                        .copy_from_slice(&r.volume.data()[src..src + patch[2]]);
                                }
                            }
                        }
                        if jtx.send(PatchJob { req: ri, start, input: pin }).is_err() {
                            return;
                        }
                    }
                }
                drop(jtx);
            });
            // Workers: run the compiled plan + recombination.
            for _ in 0..self.workers.max(1) {
                let jrx = jrx.clone();
                let rtx = rtx.clone();
                let plan = self.plan.clone();
                let fmap = &self.fmap;
                s.spawn(move || loop {
                    let job = {
                        let g = jrx.lock().unwrap();
                        g.recv()
                    };
                    let Ok(job) = job else { break };
                    let t0 = Instant::now();
                    let raw = plan.run(job.input, pool);
                    let dense = recombine(&raw, 1, fmap);
                    let secs = t0.elapsed().as_secs_f64();
                    if rtx
                        .send(PatchResult { req: job.req, start: job.start, output: dense, secs })
                        .is_err()
                    {
                        break;
                    }
                });
            }
            drop(rtx);
            // Assembler (this thread): write patch outputs into volumes.
            while let Ok(res) = rrx.recv() {
                total_patches += 1;
                busy += res.secs;
                let osh = res.output.shape();
                voxels += (osh.x * osh.y * osh.z) as u64;
                let mut out = outputs[res.req].lock().unwrap();
                let vsh = out.shape();
                for f in 0..f_out {
                    for x in 0..cover[0] {
                        for y in 0..cover[1] {
                            for z in 0..cover[2] {
                                out.set(
                                    0,
                                    f,
                                    res.start[0] + x,
                                    res.start[1] + y,
                                    res.start[2] + z,
                                    res.output.at(0, f, x, y, z),
                                );
                            }
                        }
                    }
                }
                let _ = vsh;
            }
            Ok(())
        })?;

        let wall = t_wall.elapsed();
        let mut responses = Vec::new();
        for (ri, out) in outputs.into_iter().enumerate() {
            let output = out.into_inner().unwrap();
            let osh = output.shape();
            responses.push(InferenceResponse {
                id: req_meta[ri].0,
                output,
                latency: wall, // batch-level latency on this testbed
                patches: 0,
                voxels: (osh.x * osh.y * osh.z) as u64,
            });
        }
        let metrics = Metrics {
            requests: responses.len(),
            patches: total_patches,
            voxels,
            busy_secs: busy,
            wall_secs: wall.as_secs_f64(),
        };
        Ok((responses, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo::tiny_net;
    use crate::optimizer::{compile, make_weights, search, CostModel, SearchSpace};
    use crate::device::Device;
    use crate::util::pool::ChipTopology;
    use crate::util::quick::assert_allclose;

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    fn make_coordinator(seed: u64) -> (Coordinator, TaskPool) {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
        space.max_candidates = 2;
        let plan = search(&net, &space, &cm).unwrap();
        let weights = make_weights(&net, seed);
        let cp = compile(&net, &plan, &weights).unwrap();
        (Coordinator::new(net, cp).unwrap(), tpool())
    }

    #[test]
    fn serves_single_request() {
        let (c, pool) = make_coordinator(1);
        let fov = c.net.field_of_view();
        let vol = Tensor5::random(Shape5::new(1, 1, 20, 20, 20), 2);
        let (resp, metrics) = c
            .serve(vec![InferenceRequest { id: 7, volume: vol }], &pool)
            .unwrap();
        assert_eq!(resp.len(), 1);
        assert_eq!(resp[0].id, 7);
        let osh = resp[0].output.shape();
        assert_eq!((osh.x, osh.y, osh.z), (20 - fov[0] + 1, 20 - fov[1] + 1, 20 - fov[2] + 1));
        assert!(metrics.patches >= 1);
        assert!(metrics.throughput() > 0.0);
    }

    #[test]
    fn serve_matches_direct_infer_volume() {
        let (c, pool) = make_coordinator(3);
        let vol = Tensor5::random(Shape5::new(1, 1, 19, 19, 19), 9);
        let vol2 = vol.clone_tensor();
        let (resp, _) = c.serve(vec![InferenceRequest { id: 0, volume: vol }], &pool).unwrap();

        // Reference through inference::infer_volume with the same plan.
        let fmap = fragment_map(&c.net, &c.plan.plan.modes()).unwrap();
        let runner = |t: Tensor5| {
            let raw = c.plan.run(t, &pool);
            recombine(&raw, 1, &fmap)
        };
        let expect = crate::inference::infer_volume(
            &vol2,
            c.net.field_of_view(),
            c.patch,
            c.net.f_out(),
            &runner,
        )
        .unwrap();
        assert_allclose(resp[0].output.data(), expect.data(), 1e-5, 1e-5, "serve == infer");
    }

    #[test]
    fn serves_multiple_requests_in_order() {
        let (c, pool) = make_coordinator(5);
        let reqs = (0..3)
            .map(|i| InferenceRequest {
                id: 100 + i,
                volume: Tensor5::random(Shape5::new(1, 1, 16, 16, 16), i),
            })
            .collect();
        let (resp, metrics) = c.serve(reqs, &pool).unwrap();
        assert_eq!(resp.len(), 3);
        assert_eq!(resp.iter().map(|r| r.id).collect::<Vec<_>>(), vec![100, 101, 102]);
        assert_eq!(metrics.requests, 3);
    }

    #[test]
    fn rejects_undersized_volume() {
        let (c, pool) = make_coordinator(7);
        let vol = Tensor5::random(Shape5::new(1, 1, 5, 5, 5), 2);
        assert!(c.serve(vec![InferenceRequest { id: 0, volume: vol }], &pool).is_err());
    }
}
