//! GPU + host RAM convolutional layers — §VII.A.
//!
//! A conv layer whose working set exceeds device RAM is decomposed into
//! sub-layers over (batch × input-map × output-map) ranges; each
//! sub-layer is a smaller conv layer run by a GPU-only primitive, with
//! inputs streamed up from host RAM and results streamed back. The
//! search over decompositions uses the paper's two pruning heuristics:
//!
//! 1. kernels ≤ 5³ consider only the dense (cuDNN) primitives, larger
//!    kernels only the FFT primitive;
//! 2. prefer sub-batch splits (`fᵢ = f`, `f'ᵢ = f'`, `Sᵢ ≤ S`) — each
//!    input then moves to the device exactly once; only if no
//!    sub-batch fits, fall back to `Sᵢ = 1` channel-block splits
//!    (`fᵢ = f_α ≤ f`, `f'ᵢ = f'_α ≤ f'`), estimating time from the
//!    distinct sub-shapes only.

use crate::conv::{Activation, Weights};
use crate::device::Device;
use crate::exec::ExecCtx;
use crate::layers::{ConvLayer, LayerPrimitive};
use crate::memory::model::{conv_memory_bytes, ConvAlgo, ConvDims};
use crate::optimizer::CostModel;
use crate::tensor::{Shape5, Tensor5};
use crate::util::ceil_div;

/// One sub-layer: ranges into the batch and channel dimensions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SubPiece {
    /// Batch range start.
    pub s0: usize,
    /// Batch range end (exclusive).
    pub s1: usize,
    /// Input-map range start.
    pub i0: usize,
    /// Input-map range end (exclusive).
    pub i1: usize,
    /// Output-map range start.
    pub j0: usize,
    /// Output-map range end (exclusive).
    pub j1: usize,
}

/// A decomposition of a conv layer into device-sized sub-layers.
#[derive(Clone, Debug)]
pub struct SubLayerPlan {
    /// GPU algorithm every piece runs.
    pub algo: ConvAlgo,
    /// Sub-layer pieces covering the full layer.
    pub pieces: Vec<SubPiece>,
    /// Estimated compute seconds (cost model, all pieces).
    pub est_compute_secs: f64,
    /// Modelled host↔device traffic for the whole layer.
    pub transfer_bytes: u64,
    /// Peak device memory of the largest piece.
    pub gpu_mem: u64,
}

impl SubLayerPlan {
    /// Estimated total seconds including modelled transfer time.
    pub fn est_secs(&self, gpu: &Device) -> f64 {
        self.est_compute_secs + gpu.transfer_secs(self.transfer_bytes)
    }
}

/// Candidate GPU algorithms per the kernel-size heuristic.
fn algo_candidates(k: [usize; 3]) -> Vec<ConvAlgo> {
    if k[0] * k[1] * k[2] <= 125 {
        vec![ConvAlgo::GpuDenseNoWorkspace, ConvAlgo::GpuDensePrecomp]
    } else {
        vec![ConvAlgo::GpuFft]
    }
}

/// Transfer bytes of a piece: input slice up + output slice down (+
/// kernels, negligible but counted).
fn piece_transfer_bytes(d: &ConvDims, piece: &SubPiece) -> u64 {
    let s = (piece.s1 - piece.s0) as u64;
    let fi = (piece.i1 - piece.i0) as u64;
    let fo = (piece.j1 - piece.j0) as u64;
    let up = s * fi * d.n_elems() * 4 + fi * fo * (d.k[0] * d.k[1] * d.k[2]) as u64 * 4;
    let down = s * fo * d.n_out_elems() * 4;
    up + down
}

/// Find the best decomposition of layer `d` for device `gpu`, or None
/// if even a 1×1×1-channel piece does not fit.
pub fn decompose(d: &ConvDims, gpu: &Device, cost: &CostModel) -> Option<SubLayerPlan> {
    let mut best: Option<SubLayerPlan> = None;
    for algo in algo_candidates(d.k) {
        // Heuristic 2a: largest sub-batch with full channels.
        let mut chosen: Option<Vec<SubPiece>> = None;
        for si in (1..=d.s).rev() {
            let sub = ConvDims { s: si, ..*d };
            if gpu.fits(conv_memory_bytes(algo, &sub, 1)) {
                let mut pieces = Vec::new();
                let mut s0 = 0;
                while s0 < d.s {
                    let s1 = (s0 + si).min(d.s);
                    pieces.push(SubPiece { s0, s1, i0: 0, i1: d.f_in, j0: 0, j1: d.f_out });
                    s0 = s1;
                }
                chosen = Some(pieces);
                break;
            }
        }
        // Heuristic 2b: S_i = 1 with channel blocks f_α × f'_α.
        if chosen.is_none() {
            let mut best_blocks: Option<(usize, usize, f64)> = None;
            for fa in (1..=d.f_in).rev() {
                for fpa in (1..=d.f_out).rev() {
                    let sub = ConvDims { s: 1, f_in: fa, f_out: fpa, ..*d };
                    if !gpu.fits(conv_memory_bytes(algo, &sub, 1)) {
                        continue;
                    }
                    // #pieces × (compute + transfer) estimate; distinct
                    // shapes only is implicit — all pieces share `sub`'s
                    // shape modulo remainders.
                    let npieces =
                        (d.s * ceil_div(d.f_in, fa) * ceil_div(d.f_out, fpa)) as f64;
                    let t = npieces
                        * (cost.conv_secs(algo, &sub, gpu)
                            + gpu.transfer_secs(piece_transfer_bytes(
                                d,
                                &SubPiece { s0: 0, s1: 1, i0: 0, i1: fa, j0: 0, j1: fpa },
                            )));
                    if best_blocks.map(|(_, _, bt)| t < bt).unwrap_or(true) {
                        best_blocks = Some((fa, fpa, t));
                    }
                }
            }
            if let Some((fa, fpa, _)) = best_blocks {
                let mut pieces = Vec::new();
                for s in 0..d.s {
                    let mut j0 = 0;
                    while j0 < d.f_out {
                        let j1 = (j0 + fpa).min(d.f_out);
                        let mut i0 = 0;
                        while i0 < d.f_in {
                            let i1 = (i0 + fa).min(d.f_in);
                            pieces.push(SubPiece { s0: s, s1: s + 1, i0, i1, j0, j1 });
                            i0 = i1;
                        }
                        j0 = j1;
                    }
                }
                chosen = Some(pieces);
            }
        }
        let Some(pieces) = chosen else { continue };
        // Cost the plan.
        let mut compute = 0.0;
        let mut transfer = 0u64;
        let mut gpu_mem = 0u64;
        for p in &pieces {
            let sub = ConvDims {
                s: p.s1 - p.s0,
                f_in: p.i1 - p.i0,
                f_out: p.j1 - p.j0,
                n: d.n,
                k: d.k,
            };
            compute += cost.conv_secs(algo, &sub, gpu);
            transfer += piece_transfer_bytes(d, p);
            gpu_mem = gpu_mem.max(conv_memory_bytes(algo, &sub, 1));
        }
        let plan = SubLayerPlan {
            algo,
            pieces,
            est_compute_secs: compute,
            transfer_bytes: transfer,
            gpu_mem,
        };
        if best
            .as_ref()
            .map(|b| plan.est_secs(gpu) < b.est_secs(gpu))
            .unwrap_or(true)
        {
            best = Some(plan);
        }
    }
    best
}

/// Execute a decomposed layer: pieces run on the (simulated) device,
/// partial sums accumulate on the host, bias + activation applied once
/// at the end. Returns the output and the bytes moved.
pub fn execute(
    input: &Tensor5,
    w: &Weights,
    plan: &SubLayerPlan,
    act: Activation,
    ctx: &mut ExecCtx<'_>,
) -> (Tensor5, u64) {
    let ish = input.shape();
    assert_eq!(ish.f, w.f_in);
    let osh = crate::conv::conv_out_shape(ish, w.f_out, w.k);
    let mut out = ctx.tensor5(osh);
    let mut moved = 0u64;
    let d = ConvDims { s: ish.s, f_in: w.f_in, f_out: w.f_out, n: ish.spatial(), k: w.k };
    for p in &plan.pieces {
        // Host→device: copy the input slice (the upload of Fig. 6).
        let sub_ish = Shape5::from_spatial(p.s1 - p.s0, p.i1 - p.i0, ish.spatial());
        let mut sub_in = ctx.tensor5(sub_ish);
        for (ss, s) in (p.s0..p.s1).enumerate() {
            for (ii, i) in (p.i0..p.i1).enumerate() {
                sub_in.image_mut(ss, ii).copy_from_slice(input.image(s, i));
            }
        }
        // Sub-weights with zero bias — bias belongs to the final sum.
        let mut sub_w = w.window(p.j0, p.j1 - p.j0, p.i0, p.i1 - p.i0);
        for j in 0..sub_w.f_out {
            sub_w.set_bias(j, 0.0);
        }
        let layer = ConvLayer::new(std::sync::Arc::new(sub_w), plan.algo, Activation::None);
        let sub_out = layer.execute(sub_in, ctx);
        // Device→host: accumulate the partial result.
        for (ss, s) in (p.s0..p.s1).enumerate() {
            for (jj, j) in (p.j0..p.j1).enumerate() {
                for (dst, src) in out.image_mut(s, j).iter_mut().zip(sub_out.image(ss, jj)) {
                    *dst += *src;
                }
            }
        }
        ctx.retire(sub_out);
        moved += piece_transfer_bytes(&d, p);
    }
    for s in 0..osh.s {
        for j in 0..w.f_out {
            let b = w.bias(j);
            for v in out.image_mut(s, j).iter_mut() {
                *v = act.apply(*v + b);
            }
        }
    }
    (out, moved)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_layer_reference;
    use crate::util::pool::{ChipTopology, TaskPool};
    use crate::util::quick::assert_allclose;

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    fn dims() -> ConvDims {
        ConvDims { s: 2, f_in: 4, f_out: 6, n: [8, 8, 8], k: [3, 3, 3] }
    }

    #[test]
    fn whole_layer_fits_single_piece() {
        let cm = CostModel::default_rates(2);
        let plan = decompose(&dims(), &Device::titan_x(), &cm).unwrap();
        assert_eq!(plan.pieces.len(), 1);
        assert_eq!(plan.pieces[0], SubPiece { s0: 0, s1: 2, i0: 0, i1: 4, j0: 0, j1: 6 });
    }

    #[test]
    fn tight_device_splits_batch_then_channels() {
        let cm = CostModel::default_rates(2);
        let d = dims();
        // Budget that fits one batch entry but not two.
        let one = conv_memory_bytes(ConvAlgo::GpuDensePrecomp, &ConvDims { s: 1, ..d }, 1);
        let plan = decompose(&d, &Device::gpu_with_ram(one + 1024), &cm).unwrap();
        assert!(plan.pieces.len() >= 2);
        for p in &plan.pieces {
            assert!(p.s1 - p.s0 <= 1 || (p.i1 - p.i0 == d.f_in && p.j1 - p.j0 == d.f_out));
        }
        // Channel-split fallback.
        let tiny = conv_memory_bytes(
            ConvAlgo::GpuDenseNoWorkspace,
            &ConvDims { s: 1, f_in: 2, f_out: 2, ..d },
            1,
        );
        let plan2 = decompose(&d, &Device::gpu_with_ram(tiny + 1024), &cm).unwrap();
        assert!(plan2.pieces.len() > plan.pieces.len());
        assert!(plan2.gpu_mem <= tiny + 1024);
    }

    #[test]
    fn impossible_budget_returns_none() {
        let cm = CostModel::default_rates(2);
        assert!(decompose(&dims(), &Device::gpu_with_ram(1024), &cm).is_none());
    }

    #[test]
    fn large_kernels_use_fft() {
        let cm = CostModel::default_rates(2);
        let d = ConvDims { k: [7, 7, 7], n: [12, 12, 12], ..dims() };
        let plan = decompose(&d, &Device::titan_x(), &cm).unwrap();
        assert_eq!(plan.algo, ConvAlgo::GpuFft);
        let d_small = dims();
        let plan2 = decompose(&d_small, &Device::titan_x(), &cm).unwrap();
        assert!(matches!(
            plan2.algo,
            ConvAlgo::GpuDenseNoWorkspace | ConvAlgo::GpuDensePrecomp
        ));
    }

    #[test]
    fn execute_matches_reference_across_splits() {
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        let cm = CostModel::default_rates(2);
        let d = dims();
        let input = Tensor5::random(Shape5::from_spatial(d.s, d.f_in, d.n), 51);
        let w = Weights::random(d.f_out, d.f_in, d.k, 52);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        for ram in [
            Device::titan_x().ram_bytes,
            conv_memory_bytes(ConvAlgo::GpuDensePrecomp, &ConvDims { s: 1, ..d }, 1) + 1024,
            conv_memory_bytes(
                ConvAlgo::GpuDenseNoWorkspace,
                &ConvDims { s: 1, f_in: 2, f_out: 2, ..d },
                1,
            ) + 1024,
        ] {
            let gpu = Device::gpu_with_ram(ram);
            let plan = decompose(&d, &gpu, &cm).unwrap();
            let (out, moved) = execute(&input, &w, &plan, Activation::Relu, &mut ctx);
            assert_allclose(out.data(), expect.data(), 1e-3, 1e-2, "sublayer exec");
            assert_eq!(moved, plan.transfer_bytes);
        }
    }

    #[test]
    fn transfer_grows_with_splitting() {
        let cm = CostModel::default_rates(2);
        let d = dims();
        let whole = decompose(&d, &Device::titan_x(), &cm).unwrap();
        let tiny = conv_memory_bytes(
            ConvAlgo::GpuDenseNoWorkspace,
            &ConvDims { s: 1, f_in: 1, f_out: 1, ..d },
            1,
        );
        let split = decompose(&d, &Device::gpu_with_ram(tiny + 1024), &cm).unwrap();
        assert!(split.transfer_bytes > whole.transfer_bytes);
    }
}
