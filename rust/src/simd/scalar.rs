//! Scalar reference implementations of every SIMD kernel.
//!
//! These are the dispatch fallback for unknown ISAs *and* the oracle the
//! property tests compare every vector tier against. Keep them boring:
//! straight loops, no manual unrolling, semantics identical to the code
//! they replaced in `fft`, `conv` and `pool`.

use crate::tensor::Complex32;

/// `dst[i] += k · src[i]` — the z-contiguous direct-convolution axpy.
pub fn axpy(dst: &mut [f32], src: &[f32], k: f32) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += k * *s;
    }
}

/// `dst0[i] += k0 · src[i]; dst1[i] += k1 · src[i]` — the fused direct
/// conv's register tile: one input load feeds two output channels.
///
/// Deliberately multiply-then-add (no FMA) so every vector tier can
/// reproduce the exact same IEEE operation sequence — the fused family
/// promises *bit* identity with its scalar oracle on finite inputs,
/// not just tolerance parity.
pub fn axpy2(dst0: &mut [f32], dst1: &mut [f32], src: &[f32], k0: f32, k1: f32) {
    debug_assert_eq!(dst0.len(), src.len());
    debug_assert_eq!(dst1.len(), src.len());
    for ((d0, d1), s) in dst0.iter_mut().zip(dst1.iter_mut()).zip(src) {
        *d0 += k0 * *s;
        *d1 += k1 * *s;
    }
}

/// `dst[i] = act(src[i] + bias)` — the fused direct conv's single
/// store: bias and (optional) ReLU applied as the accumulator row
/// leaves the register tile. ReLU is `max(v, 0)`, matching
/// [`crate::conv::Activation::apply`] bit-for-bit on finite inputs.
pub fn store_bias_act(dst: &mut [f32], src: &[f32], bias: f32, relu: bool) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        let v = *s + bias;
        *d = if relu { v.max(0.0) } else { v };
    }
}

/// `dst[i] += src[i]` — per-channel accumulation of temp images.
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

/// `dst[i] = max(dst[i], src[i])` — the pooling comparison sweep.
///
/// NaN handling mirrors x86 `maxps(dst, src)`: when either operand is
/// NaN the *src* operand is taken (`!(d > s) → s`), so the scalar and
/// SSE2/AVX2 tiers agree bit-for-bit even on NaN inputs. NEON `vmax`
/// instead propagates NaN from either side — NaN inputs are outside
/// the cross-tier parity contract (pooling a NaN image is ill-defined
/// anyway; all finite inputs agree exactly on every tier).
pub fn max_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        if !(*d > *s) {
            *d = *s;
        }
    }
}

/// `acc[i] += a[i] · b[i]` over complex spectra — PARALLEL-MAD's inner
/// kernel (Algorithm 2), the hot loop of every FFT-conv primitive.
pub fn mad_spectra(acc: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    debug_assert_eq!(acc.len(), a.len());
    debug_assert_eq!(acc.len(), b.len());
    for ((d, x), y) in acc.iter_mut().zip(a).zip(b) {
        d.mad(*x, *y);
    }
}

/// `dst[i] = a[i] · b[i]` over complex spectra — the GPU scheme's
/// PARALLEL-MULT stage.
pub fn cmul(dst: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    for ((d, x), y) in dst.iter_mut().zip(a).zip(b) {
        *d = *x * *y;
    }
}

/// Radix-2 DIT combine over `m` butterflies: for each `k2 < m`
///
/// ```text
/// t0 = dst[k2];  t1 = dst[m + k2] · tw[(k2·step) mod n]
/// dst[k2] = t0 + t1;  dst[m + k2] = t0 - t1
/// ```
///
/// Twiddle indices are accumulated rather than multiplied, mirroring the
/// loop this replaced in `fft::dft`.
pub fn radix2_combine(dst: &mut [Complex32], m: usize, tw: &[Complex32], step: usize, n: usize) {
    radix2_combine_from(dst, m, tw, step, n, 0);
}

/// [`radix2_combine`] restricted to `k2 ∈ [k0, m)` — the remainder-tail
/// entry point shared with the vector tiers.
pub fn radix2_combine_from(
    dst: &mut [Complex32],
    m: usize,
    tw: &[Complex32],
    step: usize,
    n: usize,
    k0: usize,
) {
    debug_assert!(dst.len() >= 2 * m);
    let step = step % n;
    let (lo, hi) = dst.split_at_mut(m);
    let mut w_idx = (k0 * step) % n;
    for k2 in k0..m {
        let t0 = lo[k2];
        let t1 = if w_idx == 0 { hi[k2] } else { hi[k2] * tw[w_idx] };
        lo[k2] = t0 + t1;
        hi[k2] = t0 - t1;
        w_idx += step;
        if w_idx >= n {
            w_idx -= n;
        }
    }
}

// ------------------------------------------------- precision storage

/// Convert one `f32` to IEEE 754 binary16 bits with round-to-nearest-
/// even — the scalar oracle for the f16 storage tier. Exact for every
/// finite input (normals, subnormals, overflow to ±inf); NaNs map to a
/// quiet NaN carrying the top ten payload bits.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7fff_ffff;
    if abs >= 0x7f80_0000 {
        // Inf stays inf; NaN becomes a quiet NaN (payload truncated).
        let mant = if abs > 0x7f80_0000 { 0x0200 | ((abs >> 13) & 0x03ff) as u16 } else { 0 };
        return sign | 0x7c00 | mant;
    }
    let exp = (abs >> 23) as i32 - 127;
    let mant = abs & 0x007f_ffff;
    if exp >= 16 {
        return sign | 0x7c00; // ≥ 2^16: overflows half even before rounding
    }
    if exp >= -14 {
        // Normal half range. Round the 13 dropped mantissa bits to
        // nearest-even; a mantissa carry correctly bumps the exponent
        // (and a carry out of exp=30 correctly lands on inf).
        let mut h = (((exp + 15) as u32) << 10) | (mant >> 13);
        let rest = mant & 0x1fff;
        if rest > 0x1000 || (rest == 0x1000 && (h & 1) != 0) {
            h += 1;
        }
        return sign | h as u16;
    }
    if exp < -25 {
        return sign; // below half the smallest subnormal: rounds to ±0
    }
    // Subnormal half: value = m · 2^(exp−23) with the implicit bit made
    // explicit, target unit 2^−24. A carry out of the 10 mantissa bits
    // lands on the smallest normal — the bit pattern is already right.
    let m = mant | 0x0080_0000;
    let shift = (13 + (-14 - exp)) as u32;
    let mut h = m >> shift;
    let rest = m & ((1u32 << shift) - 1);
    let halfway = 1u32 << (shift - 1);
    if rest > halfway || (rest == halfway && (h & 1) != 0) {
        h += 1;
    }
    sign | h as u16
}

/// Convert IEEE 754 binary16 bits back to `f32` — exact (every half
/// value, including subnormals, is representable in `f32`).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1f;
    let mant = (h & 0x03ff) as u32;
    let bits = match exp {
        0 => {
            if mant == 0 {
                sign
            } else {
                // Subnormal: value = mant · 2^−24. Normalize to f32.
                let p = 31 - mant.leading_zeros(); // MSB position, 0..=9
                let exp32 = p + 127 - 24;
                let mant32 = (mant << (23 - p)) & 0x007f_ffff;
                sign | (exp32 << 23) | mant32
            }
        }
        31 => sign | 0x7f80_0000 | (mant << 13),
        _ => sign | ((exp as u32 + 112) << 23) | (mant << 13),
    };
    f32::from_bits(bits)
}

/// Convert one `f32` to bfloat16 bits: truncate to the top 16 bits with
/// round-to-nearest-even. Exact RNE for every finite input; NaNs map to
/// a quiet NaN (the rounding add must not carry a NaN into the exponent
/// field). Every vector tier runs this exact integer sequence, so the
/// conversion is bit-identical across tiers for *all* inputs.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let u = x.to_bits();
    if (u & 0x7fff_ffff) > 0x7f80_0000 {
        return ((u >> 16) as u16) | 0x0040;
    }
    let rounded = u.wrapping_add(0x7fff + ((u >> 16) & 1));
    (rounded >> 16) as u16
}

/// Convert bfloat16 bits back to `f32` — exact (bf16 is a prefix of the
/// f32 encoding).
#[inline]
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// `dst[i] = f16(src[i])` — narrow an f32 row into half storage.
pub fn narrow_f16(dst: &mut [u16], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d = f32_to_f16_bits(*s);
    }
}

/// `dst[i] = f32(src[i])` — widen half storage back to f32 (exact).
pub fn widen_f16(dst: &mut [f32], src: &[u16]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d = f16_bits_to_f32(*s);
    }
}

/// `dst[i] = bf16(src[i])` — narrow an f32 row into bfloat16 storage.
pub fn narrow_bf16(dst: &mut [u16], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d = f32_to_bf16_bits(*s);
    }
}

/// `dst[i] = f32(src[i])` — widen bfloat16 storage back to f32 (exact).
pub fn widen_bf16(dst: &mut [f32], src: &[u16]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d = bf16_bits_to_f32(*s);
    }
}

/// `dst[i] = f16(act(src[i] + bias))` — the fused narrow-on-store:
/// bias + activation + narrowing in one sweep, so a half-precision
/// layer's output never round-trips through an extra f32 store pass.
pub fn store_bias_act_narrow_f16(dst: &mut [u16], src: &[f32], bias: f32, relu: bool) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        let v = *s + bias;
        *d = f32_to_f16_bits(if relu { v.max(0.0) } else { v });
    }
}

/// `dst[i] = bf16(act(src[i] + bias))` — fused narrow-on-store, bf16.
pub fn store_bias_act_narrow_bf16(dst: &mut [u16], src: &[f32], bias: f32, relu: bool) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        let v = *s + bias;
        *d = f32_to_bf16_bits(if relu { v.max(0.0) } else { v });
    }
}

/// Radix-4 DIT combine over `m` butterflies (twiddles `w^q` for rows
/// `q = 1, 2, 3`, then the ±1/±i butterfly).
pub fn radix4_combine(dst: &mut [Complex32], m: usize, tw: &[Complex32], step: usize, n: usize) {
    radix4_combine_from(dst, m, tw, step, n, 0);
}

/// [`radix4_combine`] restricted to `k2 ∈ [k0, m)`.
pub fn radix4_combine_from(
    dst: &mut [Complex32],
    m: usize,
    tw: &[Complex32],
    step: usize,
    n: usize,
    k0: usize,
) {
    debug_assert!(dst.len() >= 4 * m);
    let step = step % n;
    let mut w1 = (k0 * step) % n;
    for k2 in k0..m {
        let t0 = dst[k2];
        let (t1, t2, t3) = if w1 == 0 {
            (dst[m + k2], dst[2 * m + k2], dst[3 * m + k2])
        } else {
            let mut w2 = w1 + w1;
            if w2 >= n {
                w2 -= n;
            }
            let mut w3 = w2 + w1;
            if w3 >= n {
                w3 -= n;
            }
            (
                dst[m + k2] * tw[w1],
                dst[2 * m + k2] * tw[w2],
                dst[3 * m + k2] * tw[w3],
            )
        };
        let a = t0 + t2;
        let b = t0 - t2;
        let c = t1 + t3;
        let d = (t1 - t3).mul_neg_i();
        dst[k2] = a + c;
        dst[m + k2] = b + d;
        dst[2 * m + k2] = a - c;
        dst[3 * m + k2] = b - d;
        w1 += step;
        if w1 >= n {
            w1 -= n;
        }
    }
}
