//! AVX2+FMA and SSE2 kernel implementations.
//!
//! Every function here is `unsafe` because it is compiled with a
//! `#[target_feature]` the caller must have verified at runtime
//! (`simd::detect` / `simd::supported`); all memory access is
//! bounds-derived from the slice arguments with unaligned loads, so
//! there are no alignment preconditions.
//!
//! Complex layout note: `Complex32` is `#[repr(C)] { re, im }`, so a
//! `&[Complex32]` reinterprets as interleaved `[re, im]` f32 pairs. The
//! AVX2 `mad_spectra` deinterleaves 8-complex tiles into split-complex
//! (SoA) registers — the complex multiply-accumulate then runs as four
//! pure FMAs — and reinterleaves on store. The butterfly/multiply
//! kernels stay interleaved and use `fmaddsub`-style sign tricks.

#![allow(clippy::missing_safety_doc)]

use crate::tensor::Complex32;

use super::scalar;

#[cfg(target_arch = "x86")]
use core::arch::x86::*;
#[cfg(target_arch = "x86_64")]
use core::arch::x86_64::*;

// ---------------------------------------------------------------- f32

#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
/// AVX2+FMA `dst[i] += k * src[i]`.
pub unsafe fn axpy_avx2(dst: &mut [f32], src: &[f32], k: f32) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let kv = _mm256_set1_ps(k);
    let mut i = 0usize;
    while i + 16 <= n {
        let r0 = _mm256_fmadd_ps(kv, _mm256_loadu_ps(s.add(i)), _mm256_loadu_ps(d.add(i)));
        let r1 = _mm256_fmadd_ps(
            kv,
            _mm256_loadu_ps(s.add(i + 8)),
            _mm256_loadu_ps(d.add(i + 8)),
        );
        _mm256_storeu_ps(d.add(i), r0);
        _mm256_storeu_ps(d.add(i + 8), r1);
        i += 16;
    }
    if i + 8 <= n {
        let r = _mm256_fmadd_ps(kv, _mm256_loadu_ps(s.add(i)), _mm256_loadu_ps(d.add(i)));
        _mm256_storeu_ps(d.add(i), r);
        i += 8;
    }
    scalar::axpy(&mut dst[i..], &src[i..], k);
}

#[target_feature(enable = "sse2")]
/// SSE2 `dst[i] += k * src[i]`.
pub unsafe fn axpy_sse2(dst: &mut [f32], src: &[f32], k: f32) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let kv = _mm_set1_ps(k);
    let mut i = 0usize;
    while i + 4 <= n {
        let r = _mm_add_ps(_mm_loadu_ps(d.add(i)), _mm_mul_ps(kv, _mm_loadu_ps(s.add(i))));
        _mm_storeu_ps(d.add(i), r);
        i += 4;
    }
    scalar::axpy(&mut dst[i..], &src[i..], k);
}

#[target_feature(enable = "avx2")]
/// AVX2 `dst[i] += src[i]`.
pub unsafe fn add_assign_avx2(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 8 <= n {
        let r = _mm256_add_ps(_mm256_loadu_ps(d.add(i)), _mm256_loadu_ps(s.add(i)));
        _mm256_storeu_ps(d.add(i), r);
        i += 8;
    }
    scalar::add_assign(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "sse2")]
/// SSE2 `dst[i] += src[i]`.
pub unsafe fn add_assign_sse2(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        let r = _mm_add_ps(_mm_loadu_ps(d.add(i)), _mm_loadu_ps(s.add(i)));
        _mm_storeu_ps(d.add(i), r);
        i += 4;
    }
    scalar::add_assign(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "avx2")]
/// AVX2 `dst[i] = max(dst[i], src[i])`.
pub unsafe fn max_assign_avx2(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 8 <= n {
        let r = _mm256_max_ps(_mm256_loadu_ps(d.add(i)), _mm256_loadu_ps(s.add(i)));
        _mm256_storeu_ps(d.add(i), r);
        i += 8;
    }
    scalar::max_assign(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "sse2")]
/// SSE2 `dst[i] = max(dst[i], src[i])`.
pub unsafe fn max_assign_sse2(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        let r = _mm_max_ps(_mm_loadu_ps(d.add(i)), _mm_loadu_ps(s.add(i)));
        _mm_storeu_ps(d.add(i), r);
        i += 4;
    }
    scalar::max_assign(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "avx2")]
/// AVX2 `dst0[i] += k0 * src[i]; dst1[i] += k1 * src[i]`.
///
/// Deliberately multiply-then-add (no FMA, despite the tier having it):
/// the fused direct-conv family promises bit identity with its scalar
/// oracle, so every tier must run the same IEEE operation sequence.
pub unsafe fn axpy2_avx2(dst0: &mut [f32], dst1: &mut [f32], src: &[f32], k0: f32, k1: f32) {
    let n = src.len();
    let d0 = dst0.as_mut_ptr();
    let d1 = dst1.as_mut_ptr();
    let s = src.as_ptr();
    let kv0 = _mm256_set1_ps(k0);
    let kv1 = _mm256_set1_ps(k1);
    let mut i = 0usize;
    while i + 8 <= n {
        let sv = _mm256_loadu_ps(s.add(i));
        let r0 = _mm256_add_ps(_mm256_loadu_ps(d0.add(i)), _mm256_mul_ps(kv0, sv));
        let r1 = _mm256_add_ps(_mm256_loadu_ps(d1.add(i)), _mm256_mul_ps(kv1, sv));
        _mm256_storeu_ps(d0.add(i), r0);
        _mm256_storeu_ps(d1.add(i), r1);
        i += 8;
    }
    scalar::axpy2(&mut dst0[i..], &mut dst1[i..], &src[i..], k0, k1);
}

#[target_feature(enable = "sse2")]
/// SSE2 `dst0[i] += k0 * src[i]; dst1[i] += k1 * src[i]`.
pub unsafe fn axpy2_sse2(dst0: &mut [f32], dst1: &mut [f32], src: &[f32], k0: f32, k1: f32) {
    let n = src.len();
    let d0 = dst0.as_mut_ptr();
    let d1 = dst1.as_mut_ptr();
    let s = src.as_ptr();
    let kv0 = _mm_set1_ps(k0);
    let kv1 = _mm_set1_ps(k1);
    let mut i = 0usize;
    while i + 4 <= n {
        let sv = _mm_loadu_ps(s.add(i));
        let r0 = _mm_add_ps(_mm_loadu_ps(d0.add(i)), _mm_mul_ps(kv0, sv));
        let r1 = _mm_add_ps(_mm_loadu_ps(d1.add(i)), _mm_mul_ps(kv1, sv));
        _mm_storeu_ps(d0.add(i), r0);
        _mm_storeu_ps(d1.add(i), r1);
        i += 4;
    }
    scalar::axpy2(&mut dst0[i..], &mut dst1[i..], &src[i..], k0, k1);
}

#[target_feature(enable = "avx2")]
/// AVX2 `dst[i] = act(src[i] + bias)`. `maxps(sum, 0)` takes the second
/// operand when the sum is NaN, matching scalar `f32::max(0.0)`.
pub unsafe fn store_bias_act_avx2(dst: &mut [f32], src: &[f32], bias: f32, relu: bool) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let bv = _mm256_set1_ps(bias);
    let zero = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        let mut v = _mm256_add_ps(_mm256_loadu_ps(s.add(i)), bv);
        if relu {
            v = _mm256_max_ps(v, zero);
        }
        _mm256_storeu_ps(d.add(i), v);
        i += 8;
    }
    scalar::store_bias_act(&mut dst[i..], &src[i..], bias, relu);
}

#[target_feature(enable = "sse2")]
/// SSE2 `dst[i] = act(src[i] + bias)`.
pub unsafe fn store_bias_act_sse2(dst: &mut [f32], src: &[f32], bias: f32, relu: bool) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let bv = _mm_set1_ps(bias);
    let zero = _mm_setzero_ps();
    let mut i = 0usize;
    while i + 4 <= n {
        let mut v = _mm_add_ps(_mm_loadu_ps(s.add(i)), bv);
        if relu {
            v = _mm_max_ps(v, zero);
        }
        _mm_storeu_ps(d.add(i), v);
        i += 4;
    }
    scalar::store_bias_act(&mut dst[i..], &src[i..], bias, relu);
}

// ----------------------------------------------------------- complex

/// Deinterleave two 4-complex vectors into (re, im) SoA registers.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn deinterleave(v0: __m256, v1: __m256) -> (__m256, __m256) {
    // v0 = [r0 i0 r1 i1 | r2 i2 r3 i3], v1 = [r4 i4 r5 i5 | r6 i6 r7 i7]
    let p0 = _mm256_permute2f128_ps::<0x20>(v0, v1); // [r0 i0 r1 i1 | r4 i4 r5 i5]
    let p1 = _mm256_permute2f128_ps::<0x31>(v0, v1); // [r2 i2 r3 i3 | r6 i6 r7 i7]
    (
        _mm256_shuffle_ps::<0b10_00_10_00>(p0, p1), // [r0..r3 | r4..r7]
        _mm256_shuffle_ps::<0b11_01_11_01>(p0, p1), // [i0..i3 | i4..i7]
    )
}

/// Inverse of [`deinterleave`].
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn interleave(re: __m256, im: __m256) -> (__m256, __m256) {
    let lo = _mm256_unpacklo_ps(re, im); // [r0 i0 r1 i1 | r4 i4 r5 i5]
    let hi = _mm256_unpackhi_ps(re, im); // [r2 i2 r3 i3 | r6 i6 r7 i7]
    (
        _mm256_permute2f128_ps::<0x20>(lo, hi),
        _mm256_permute2f128_ps::<0x31>(lo, hi),
    )
}

/// Interleaved complex multiply of 4 pairs: `a · b` per complex lane.
#[inline]
#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
unsafe fn cmul4(a: __m256, b: __m256) -> __m256 {
    let ar = _mm256_moveldup_ps(a); // [a.re a.re ...]
    let ai = _mm256_movehdup_ps(a); // [a.im a.im ...]
    let bs = _mm256_permute_ps::<0xB1>(b); // [b.im b.re ...]
    // even lanes: ar·br − ai·bi ; odd lanes: ar·bi + ai·br
    _mm256_fmaddsub_ps(ar, b, _mm256_mul_ps(ai, bs))
}

/// `v · (−i)` per complex lane: (re, im) → (im, −re).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn mul_neg_i4(v: __m256) -> __m256 {
    let sw = _mm256_permute_ps::<0xB1>(v); // (im, re)
    // Flip the sign of the odd (imaginary) lanes.
    const S: i32 = i32::MIN;
    let neg_odd = _mm256_castsi256_ps(_mm256_set_epi32(S, 0, S, 0, S, 0, S, 0));
    _mm256_xor_ps(sw, neg_odd)
}

#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
/// AVX2+FMA complex `acc[i] += a[i] * b[i]` (split-complex tiles).
pub unsafe fn mad_spectra_avx2(acc: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    let n = acc.len();
    let ap = a.as_ptr() as *const f32;
    let bp = b.as_ptr() as *const f32;
    let cp = acc.as_mut_ptr() as *mut f32;
    let mut i = 0usize; // complex index
    while i + 8 <= n {
        let f = 2 * i;
        let (ar, ai) = deinterleave(_mm256_loadu_ps(ap.add(f)), _mm256_loadu_ps(ap.add(f + 8)));
        let (br, bi) = deinterleave(_mm256_loadu_ps(bp.add(f)), _mm256_loadu_ps(bp.add(f + 8)));
        let (mut cr, mut ci) =
            deinterleave(_mm256_loadu_ps(cp.add(f)), _mm256_loadu_ps(cp.add(f + 8)));
        cr = _mm256_fmadd_ps(ar, br, cr);
        cr = _mm256_fnmadd_ps(ai, bi, cr);
        ci = _mm256_fmadd_ps(ar, bi, ci);
        ci = _mm256_fmadd_ps(ai, br, ci);
        let (o0, o1) = interleave(cr, ci);
        _mm256_storeu_ps(cp.add(f), o0);
        _mm256_storeu_ps(cp.add(f + 8), o1);
        i += 8;
    }
    scalar::mad_spectra(&mut acc[i..], &a[i..], &b[i..]);
}

#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
/// AVX2+FMA complex `dst[i] = a[i] * b[i]`.
pub unsafe fn cmul_avx2_slices(dst: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    let n = dst.len();
    let ap = a.as_ptr() as *const f32;
    let bp = b.as_ptr() as *const f32;
    let dp = dst.as_mut_ptr() as *mut f32;
    let mut i = 0usize;
    while i + 4 <= n {
        let f = 2 * i;
        let r = cmul4(_mm256_loadu_ps(ap.add(f)), _mm256_loadu_ps(bp.add(f)));
        _mm256_storeu_ps(dp.add(f), r);
        i += 4;
    }
    scalar::cmul(&mut dst[i..], &a[i..], &b[i..]);
}

/// Sign mask flipping the even (real) lanes — emulates `addsub` on SSE2.
#[inline]
#[target_feature(enable = "sse2")]
unsafe fn sign_even_sse2() -> __m128 {
    const S: i32 = i32::MIN;
    _mm_castsi128_ps(_mm_set_epi32(0, S, 0, S))
}

/// Interleaved complex multiply of 2 pairs (SSE2, no FMA/addsub).
#[inline]
#[target_feature(enable = "sse2")]
unsafe fn cmul2(a: __m128, b: __m128) -> __m128 {
    let ar = _mm_shuffle_ps::<0xA0>(a, a); // [a0.re a0.re a1.re a1.re]
    let ai = _mm_shuffle_ps::<0xF5>(a, a); // [a0.im a0.im a1.im a1.im]
    let bs = _mm_shuffle_ps::<0xB1>(b, b); // [b0.im b0.re b1.im b1.re]
    let t = _mm_xor_ps(_mm_mul_ps(ai, bs), sign_even_sse2()); // [−ai·bi, ai·br, ...]
    _mm_add_ps(_mm_mul_ps(ar, b), t)
}

/// `v · (−i)` per complex lane (SSE2).
#[inline]
#[target_feature(enable = "sse2")]
unsafe fn mul_neg_i2(v: __m128) -> __m128 {
    let sw = _mm_shuffle_ps::<0xB1>(v, v);
    const S: i32 = i32::MIN;
    let neg_odd = _mm_castsi128_ps(_mm_set_epi32(S, 0, S, 0));
    _mm_xor_ps(sw, neg_odd)
}

#[target_feature(enable = "sse2")]
/// SSE2 complex `acc[i] += a[i] * b[i]`.
pub unsafe fn mad_spectra_sse2(acc: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    let n = acc.len();
    let ap = a.as_ptr() as *const f32;
    let bp = b.as_ptr() as *const f32;
    let cp = acc.as_mut_ptr() as *mut f32;
    let mut i = 0usize;
    while i + 2 <= n {
        let f = 2 * i;
        let prod = cmul2(_mm_loadu_ps(ap.add(f)), _mm_loadu_ps(bp.add(f)));
        _mm_storeu_ps(cp.add(f), _mm_add_ps(_mm_loadu_ps(cp.add(f)), prod));
        i += 2;
    }
    scalar::mad_spectra(&mut acc[i..], &a[i..], &b[i..]);
}

#[target_feature(enable = "sse2")]
/// SSE2 complex `dst[i] = a[i] * b[i]`.
pub unsafe fn cmul_sse2_slices(dst: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    let n = dst.len();
    let ap = a.as_ptr() as *const f32;
    let bp = b.as_ptr() as *const f32;
    let dp = dst.as_mut_ptr() as *mut f32;
    let mut i = 0usize;
    while i + 2 <= n {
        let f = 2 * i;
        let r = cmul2(_mm_loadu_ps(ap.add(f)), _mm_loadu_ps(bp.add(f)));
        _mm_storeu_ps(dp.add(f), r);
        i += 2;
    }
    scalar::cmul(&mut dst[i..], &a[i..], &b[i..]);
}

// ------------------------------------------------- precision storage

/// RNE-truncate four f32 bit patterns to bf16 values in the low 16 bits
/// of each u32 lane — the exact integer sequence of
/// [`scalar::f32_to_bf16_bits`], so all tiers agree bit-for-bit.
#[inline]
#[target_feature(enable = "sse2")]
unsafe fn bf16_round_sse2(u: __m128i) -> __m128i {
    let abs = _mm_and_si128(u, _mm_set1_epi32(0x7fff_ffff));
    // abs ≤ i32::MAX, so the signed compare is exact.
    let is_nan = _mm_cmpgt_epi32(abs, _mm_set1_epi32(0x7f80_0000));
    let lsb = _mm_and_si128(_mm_srli_epi32::<16>(u), _mm_set1_epi32(1));
    let rounded = _mm_add_epi32(u, _mm_add_epi32(_mm_set1_epi32(0x7fff), lsb));
    let r = _mm_srli_epi32::<16>(rounded);
    let nan_r = _mm_or_si128(_mm_srli_epi32::<16>(u), _mm_set1_epi32(0x0040));
    _mm_or_si128(_mm_and_si128(is_nan, nan_r), _mm_andnot_si128(is_nan, r))
}

/// Pack two vectors of u32 lanes (each ≤ 0xFFFF) into eight u16s. SSE2
/// has no unsigned pack, so bias into i16 range, saturating-pack, and
/// flip the sign bit back.
#[inline]
#[target_feature(enable = "sse2")]
unsafe fn pack_u32x8_to_u16_sse2(lo: __m128i, hi: __m128i) -> __m128i {
    let bias = _mm_set1_epi32(0x8000);
    let p = _mm_packs_epi32(_mm_sub_epi32(lo, bias), _mm_sub_epi32(hi, bias));
    _mm_xor_si128(p, _mm_set1_epi16(i16::MIN))
}

#[target_feature(enable = "sse2")]
/// SSE2 `dst[i] = bf16(src[i])` — bit-identical to the scalar oracle.
pub unsafe fn narrow_bf16_sse2(dst: &mut [u16], src: &[f32]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 8 <= n {
        let u0 = _mm_castps_si128(_mm_loadu_ps(s.add(i)));
        let u1 = _mm_castps_si128(_mm_loadu_ps(s.add(i + 4)));
        let h = pack_u32x8_to_u16_sse2(bf16_round_sse2(u0), bf16_round_sse2(u1));
        _mm_storeu_si128(d.add(i) as *mut __m128i, h);
        i += 8;
    }
    scalar::narrow_bf16(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "sse2")]
/// SSE2 `dst[i] = f32(src[i])` for bf16 storage (exact widening).
pub unsafe fn widen_bf16_sse2(dst: &mut [f32], src: &[u16]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let zero = _mm_setzero_si128();
    let mut i = 0usize;
    while i + 8 <= n {
        let h = _mm_loadu_si128(s.add(i) as *const __m128i);
        let lo = _mm_slli_epi32::<16>(_mm_unpacklo_epi16(h, zero));
        let hi = _mm_slli_epi32::<16>(_mm_unpackhi_epi16(h, zero));
        _mm_storeu_ps(d.add(i), _mm_castsi128_ps(lo));
        _mm_storeu_ps(d.add(i + 4), _mm_castsi128_ps(hi));
        i += 8;
    }
    scalar::widen_bf16(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "sse2")]
/// SSE2 `dst[i] = bf16(act(src[i] + bias))` — fused narrow-on-store.
pub unsafe fn store_bias_act_narrow_bf16_sse2(dst: &mut [u16], src: &[f32], bias: f32, relu: bool) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let bv = _mm_set1_ps(bias);
    let zero = _mm_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        let mut v0 = _mm_add_ps(_mm_loadu_ps(s.add(i)), bv);
        let mut v1 = _mm_add_ps(_mm_loadu_ps(s.add(i + 4)), bv);
        if relu {
            v0 = _mm_max_ps(v0, zero);
            v1 = _mm_max_ps(v1, zero);
        }
        let h = pack_u32x8_to_u16_sse2(
            bf16_round_sse2(_mm_castps_si128(v0)),
            bf16_round_sse2(_mm_castps_si128(v1)),
        );
        _mm_storeu_si128(d.add(i) as *mut __m128i, h);
        i += 8;
    }
    scalar::store_bias_act_narrow_bf16(&mut dst[i..], &src[i..], bias, relu);
}

/// AVX2 lane-wise bf16 RNE truncation (see [`bf16_round_sse2`]).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn bf16_round_avx2(u: __m256i) -> __m256i {
    let abs = _mm256_and_si256(u, _mm256_set1_epi32(0x7fff_ffff));
    let is_nan = _mm256_cmpgt_epi32(abs, _mm256_set1_epi32(0x7f80_0000));
    let lsb = _mm256_and_si256(_mm256_srli_epi32::<16>(u), _mm256_set1_epi32(1));
    let rounded = _mm256_add_epi32(u, _mm256_add_epi32(_mm256_set1_epi32(0x7fff), lsb));
    let r = _mm256_srli_epi32::<16>(rounded);
    let nan_r = _mm256_or_si256(_mm256_srli_epi32::<16>(u), _mm256_set1_epi32(0x0040));
    _mm256_blendv_epi8(r, nan_r, is_nan)
}

/// Pack two 256-bit vectors of u32 lanes (each ≤ 0xFFFF) into sixteen
/// u16s in order (`packs` interleaves 128-bit lanes; the permute
/// restores them).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn pack_u32x16_to_u16_avx2(lo: __m256i, hi: __m256i) -> __m256i {
    let bias = _mm256_set1_epi32(0x8000);
    let p = _mm256_packs_epi32(_mm256_sub_epi32(lo, bias), _mm256_sub_epi32(hi, bias));
    let p = _mm256_permute4x64_epi64::<0b11_01_10_00>(p);
    _mm256_xor_si256(p, _mm256_set1_epi16(i16::MIN))
}

#[target_feature(enable = "avx2")]
/// AVX2 `dst[i] = bf16(src[i])` — bit-identical to the scalar oracle.
pub unsafe fn narrow_bf16_avx2(dst: &mut [u16], src: &[f32]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 16 <= n {
        let u0 = _mm256_castps_si256(_mm256_loadu_ps(s.add(i)));
        let u1 = _mm256_castps_si256(_mm256_loadu_ps(s.add(i + 8)));
        let h = pack_u32x16_to_u16_avx2(bf16_round_avx2(u0), bf16_round_avx2(u1));
        _mm256_storeu_si256(d.add(i) as *mut __m256i, h);
        i += 16;
    }
    scalar::narrow_bf16(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "avx2")]
/// AVX2 `dst[i] = f32(src[i])` for bf16 storage (exact widening).
pub unsafe fn widen_bf16_avx2(dst: &mut [f32], src: &[u16]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 8 <= n {
        let h = _mm_loadu_si128(s.add(i) as *const __m128i);
        let w = _mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h));
        _mm256_storeu_ps(d.add(i), _mm256_castsi256_ps(w));
        i += 8;
    }
    scalar::widen_bf16(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "avx2")]
/// AVX2 `dst[i] = bf16(act(src[i] + bias))` — fused narrow-on-store.
pub unsafe fn store_bias_act_narrow_bf16_avx2(dst: &mut [u16], src: &[f32], bias: f32, relu: bool) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let bv = _mm256_set1_ps(bias);
    let zero = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        let mut v0 = _mm256_add_ps(_mm256_loadu_ps(s.add(i)), bv);
        let mut v1 = _mm256_add_ps(_mm256_loadu_ps(s.add(i + 8)), bv);
        if relu {
            v0 = _mm256_max_ps(v0, zero);
            v1 = _mm256_max_ps(v1, zero);
        }
        let h = pack_u32x16_to_u16_avx2(
            bf16_round_avx2(_mm256_castps_si256(v0)),
            bf16_round_avx2(_mm256_castps_si256(v1)),
        );
        _mm256_storeu_si256(d.add(i) as *mut __m256i, h);
        i += 16;
    }
    scalar::store_bias_act_narrow_bf16(&mut dst[i..], &src[i..], bias, relu);
}

#[target_feature(enable = "avx2")]
/// AVX2 `dst[i] = f16(src[i])`: hardware F16C (`vcvtps2ph`, RNE) when
/// the CPU has it — IEEE-identical to [`scalar::f32_to_f16_bits`] on
/// finite inputs — else the scalar oracle. The check is a runtime
/// branch because AVX2 does not imply F16C.
pub unsafe fn narrow_f16_avx2(dst: &mut [u16], src: &[f32]) {
    if std::arch::is_x86_feature_detected!("f16c") {
        narrow_f16_f16c(dst, src);
    } else {
        scalar::narrow_f16(dst, src);
    }
}

#[target_feature(enable = "avx2")]
#[target_feature(enable = "f16c")]
unsafe fn narrow_f16_f16c(dst: &mut [u16], src: &[f32]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 8 <= n {
        let h = _mm256_cvtps_ph::<_MM_FROUND_TO_NEAREST_INT>(_mm256_loadu_ps(s.add(i)));
        _mm_storeu_si128(d.add(i) as *mut __m128i, h);
        i += 8;
    }
    scalar::narrow_f16(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "avx2")]
/// AVX2 `dst[i] = f32(src[i])` for f16 storage: F16C `vcvtph2ps` when
/// available (widening is exact on every path), else scalar.
pub unsafe fn widen_f16_avx2(dst: &mut [f32], src: &[u16]) {
    if std::arch::is_x86_feature_detected!("f16c") {
        widen_f16_f16c(dst, src);
    } else {
        scalar::widen_f16(dst, src);
    }
}

#[target_feature(enable = "avx2")]
#[target_feature(enable = "f16c")]
unsafe fn widen_f16_f16c(dst: &mut [f32], src: &[u16]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 8 <= n {
        let h = _mm_loadu_si128(s.add(i) as *const __m128i);
        _mm256_storeu_ps(d.add(i), _mm256_cvtph_ps(h));
        i += 8;
    }
    scalar::widen_f16(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "avx2")]
/// AVX2 `dst[i] = f16(act(src[i] + bias))` — fused narrow-on-store
/// (F16C when available, scalar otherwise).
pub unsafe fn store_bias_act_narrow_f16_avx2(dst: &mut [u16], src: &[f32], bias: f32, relu: bool) {
    if std::arch::is_x86_feature_detected!("f16c") {
        store_bias_act_narrow_f16_f16c(dst, src, bias, relu);
    } else {
        scalar::store_bias_act_narrow_f16(dst, src, bias, relu);
    }
}

#[target_feature(enable = "avx2")]
#[target_feature(enable = "f16c")]
unsafe fn store_bias_act_narrow_f16_f16c(dst: &mut [u16], src: &[f32], bias: f32, relu: bool) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let bv = _mm256_set1_ps(bias);
    let zero = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        let mut v = _mm256_add_ps(_mm256_loadu_ps(s.add(i)), bv);
        if relu {
            v = _mm256_max_ps(v, zero);
        }
        let h = _mm256_cvtps_ph::<_MM_FROUND_TO_NEAREST_INT>(v);
        _mm_storeu_si128(d.add(i) as *mut __m128i, h);
        i += 8;
    }
    scalar::store_bias_act_narrow_f16(&mut dst[i..], &src[i..], bias, relu);
}

// -------------------------------------------------------- butterflies

#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
/// AVX2 radix-2 butterfly combine.
pub unsafe fn radix2_combine_avx2(
    dst: &mut [Complex32],
    m: usize,
    tw: &[Complex32],
    step: usize,
    n: usize,
) {
    let base = dst.as_mut_ptr() as *mut f32;
    let lo = base;
    let hi = base.add(2 * m);
    let mut wbuf = [Complex32::ZERO; 4];
    // Twiddle index (k2·step) mod n by accumulation — no per-butterfly
    // multiply/modulo in the gather (mirrors the scalar path).
    let step = step % n;
    let mut w = 0usize;
    let mut k2 = 0usize;
    while k2 + 4 <= m {
        for slot in wbuf.iter_mut() {
            *slot = tw[w];
            w += step;
            if w >= n {
                w -= n;
            }
        }
        let wv = _mm256_loadu_ps(wbuf.as_ptr() as *const f32);
        let t0 = _mm256_loadu_ps(lo.add(2 * k2));
        let t1 = cmul4(_mm256_loadu_ps(hi.add(2 * k2)), wv);
        _mm256_storeu_ps(lo.add(2 * k2), _mm256_add_ps(t0, t1));
        _mm256_storeu_ps(hi.add(2 * k2), _mm256_sub_ps(t0, t1));
        k2 += 4;
    }
    scalar::radix2_combine_from(dst, m, tw, step, n, k2);
}

#[target_feature(enable = "sse2")]
/// SSE2 radix-2 butterfly combine.
pub unsafe fn radix2_combine_sse2(
    dst: &mut [Complex32],
    m: usize,
    tw: &[Complex32],
    step: usize,
    n: usize,
) {
    let base = dst.as_mut_ptr() as *mut f32;
    let lo = base;
    let hi = base.add(2 * m);
    let mut wbuf = [Complex32::ZERO; 2];
    let step = step % n;
    let mut w = 0usize;
    let mut k2 = 0usize;
    while k2 + 2 <= m {
        for slot in wbuf.iter_mut() {
            *slot = tw[w];
            w += step;
            if w >= n {
                w -= n;
            }
        }
        let wv = _mm_loadu_ps(wbuf.as_ptr() as *const f32);
        let t0 = _mm_loadu_ps(lo.add(2 * k2));
        let t1 = cmul2(_mm_loadu_ps(hi.add(2 * k2)), wv);
        _mm_storeu_ps(lo.add(2 * k2), _mm_add_ps(t0, t1));
        _mm_storeu_ps(hi.add(2 * k2), _mm_sub_ps(t0, t1));
        k2 += 2;
    }
    scalar::radix2_combine_from(dst, m, tw, step, n, k2);
}

#[target_feature(enable = "avx2")]
#[target_feature(enable = "fma")]
/// AVX2 radix-4 butterfly combine.
pub unsafe fn radix4_combine_avx2(
    dst: &mut [Complex32],
    m: usize,
    tw: &[Complex32],
    step: usize,
    n: usize,
) {
    let base = dst.as_mut_ptr() as *mut f32;
    let d0 = base;
    let d1 = base.add(2 * m);
    let d2 = base.add(4 * m);
    let d3 = base.add(6 * m);
    // Gathered twiddles: w¹[4], w²[4], w³[4]. The w¹ index accumulates
    // (no per-butterfly multiply/modulo); w² and w³ are additions with
    // a conditional wrap.
    let mut wbuf = [Complex32::ZERO; 12];
    let step = step % n;
    let mut w1 = 0usize;
    let mut k2 = 0usize;
    while k2 + 4 <= m {
        for j in 0..4 {
            let mut w2 = w1 + w1;
            if w2 >= n {
                w2 -= n;
            }
            let mut w3 = w2 + w1;
            if w3 >= n {
                w3 -= n;
            }
            wbuf[j] = tw[w1];
            wbuf[4 + j] = tw[w2];
            wbuf[8 + j] = tw[w3];
            w1 += step;
            if w1 >= n {
                w1 -= n;
            }
        }
        let wp = wbuf.as_ptr() as *const f32;
        let t0 = _mm256_loadu_ps(d0.add(2 * k2));
        let t1 = cmul4(_mm256_loadu_ps(d1.add(2 * k2)), _mm256_loadu_ps(wp));
        let t2 = cmul4(_mm256_loadu_ps(d2.add(2 * k2)), _mm256_loadu_ps(wp.add(8)));
        let t3 = cmul4(_mm256_loadu_ps(d3.add(2 * k2)), _mm256_loadu_ps(wp.add(16)));
        let a = _mm256_add_ps(t0, t2);
        let b = _mm256_sub_ps(t0, t2);
        let c = _mm256_add_ps(t1, t3);
        let d = mul_neg_i4(_mm256_sub_ps(t1, t3));
        _mm256_storeu_ps(d0.add(2 * k2), _mm256_add_ps(a, c));
        _mm256_storeu_ps(d1.add(2 * k2), _mm256_add_ps(b, d));
        _mm256_storeu_ps(d2.add(2 * k2), _mm256_sub_ps(a, c));
        _mm256_storeu_ps(d3.add(2 * k2), _mm256_sub_ps(b, d));
        k2 += 4;
    }
    scalar::radix4_combine_from(dst, m, tw, step, n, k2);
}

#[target_feature(enable = "sse2")]
/// SSE2 radix-4 butterfly combine.
pub unsafe fn radix4_combine_sse2(
    dst: &mut [Complex32],
    m: usize,
    tw: &[Complex32],
    step: usize,
    n: usize,
) {
    let base = dst.as_mut_ptr() as *mut f32;
    let d0 = base;
    let d1 = base.add(2 * m);
    let d2 = base.add(4 * m);
    let d3 = base.add(6 * m);
    let mut wbuf = [Complex32::ZERO; 6];
    let step = step % n;
    let mut w1 = 0usize;
    let mut k2 = 0usize;
    while k2 + 2 <= m {
        for j in 0..2 {
            let mut w2 = w1 + w1;
            if w2 >= n {
                w2 -= n;
            }
            let mut w3 = w2 + w1;
            if w3 >= n {
                w3 -= n;
            }
            wbuf[j] = tw[w1];
            wbuf[2 + j] = tw[w2];
            wbuf[4 + j] = tw[w3];
            w1 += step;
            if w1 >= n {
                w1 -= n;
            }
        }
        let wp = wbuf.as_ptr() as *const f32;
        let t0 = _mm_loadu_ps(d0.add(2 * k2));
        let t1 = cmul2(_mm_loadu_ps(d1.add(2 * k2)), _mm_loadu_ps(wp));
        let t2 = cmul2(_mm_loadu_ps(d2.add(2 * k2)), _mm_loadu_ps(wp.add(4)));
        let t3 = cmul2(_mm_loadu_ps(d3.add(2 * k2)), _mm_loadu_ps(wp.add(8)));
        let a = _mm_add_ps(t0, t2);
        let b = _mm_sub_ps(t0, t2);
        let c = _mm_add_ps(t1, t3);
        let d = mul_neg_i2(_mm_sub_ps(t1, t3));
        _mm_storeu_ps(d0.add(2 * k2), _mm_add_ps(a, c));
        _mm_storeu_ps(d1.add(2 * k2), _mm_add_ps(b, d));
        _mm_storeu_ps(d2.add(2 * k2), _mm_sub_ps(a, c));
        _mm_storeu_ps(d3.add(2 * k2), _mm_sub_ps(b, d));
        k2 += 2;
    }
    scalar::radix4_combine_from(dst, m, tw, step, n, k2);
}
