//! NEON kernel implementations (aarch64).
//!
//! NEON is baseline on aarch64, so these are always dispatchable there.
//! The complex kernels use `vld2q`/`vst2q` structured loads, which
//! deinterleave to split-complex (SoA) registers for free — the complex
//! multiply-accumulate is then four fused multiply-adds, the same shape
//! as the AVX2 tile. The radix-2/4 butterfly combines run four
//! butterflies per iteration on the same split-complex representation
//! (twiddles gathered scalar-side exactly like the x86 tiers, so the
//! accumulated-index arithmetic matches the scalar oracle bit for bit);
//! remainder tails fall through to `scalar::radix*_combine_from`.

#![allow(clippy::missing_safety_doc)]

use crate::tensor::Complex32;

use super::scalar;

use core::arch::aarch64::*;

#[target_feature(enable = "neon")]
/// NEON `dst[i] += k * src[i]`.
pub unsafe fn axpy_neon(dst: &mut [f32], src: &[f32], k: f32) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let kv = vdupq_n_f32(k);
    let mut i = 0usize;
    while i + 4 <= n {
        let r = vfmaq_f32(vld1q_f32(d.add(i)), vld1q_f32(s.add(i)), kv);
        vst1q_f32(d.add(i), r);
        i += 4;
    }
    scalar::axpy(&mut dst[i..], &src[i..], k);
}

#[target_feature(enable = "neon")]
/// NEON `dst0[i] += k0 * src[i]; dst1[i] += k1 * src[i]`.
///
/// Deliberately multiply-then-add (`vmul` + `vadd`, not `vfma`): the
/// fused direct-conv family promises bit identity with its scalar
/// oracle, so every tier must run the same IEEE operation sequence.
pub unsafe fn axpy2_neon(dst0: &mut [f32], dst1: &mut [f32], src: &[f32], k0: f32, k1: f32) {
    let n = src.len();
    let d0 = dst0.as_mut_ptr();
    let d1 = dst1.as_mut_ptr();
    let s = src.as_ptr();
    let kv0 = vdupq_n_f32(k0);
    let kv1 = vdupq_n_f32(k1);
    let mut i = 0usize;
    while i + 4 <= n {
        let sv = vld1q_f32(s.add(i));
        let r0 = vaddq_f32(vld1q_f32(d0.add(i)), vmulq_f32(kv0, sv));
        let r1 = vaddq_f32(vld1q_f32(d1.add(i)), vmulq_f32(kv1, sv));
        vst1q_f32(d0.add(i), r0);
        vst1q_f32(d1.add(i), r1);
        i += 4;
    }
    scalar::axpy2(&mut dst0[i..], &mut dst1[i..], &src[i..], k0, k1);
}

#[target_feature(enable = "neon")]
/// NEON `dst[i] = act(src[i] + bias)`.
pub unsafe fn store_bias_act_neon(dst: &mut [f32], src: &[f32], bias: f32, relu: bool) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let bv = vdupq_n_f32(bias);
    let zero = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 4 <= n {
        let mut v = vaddq_f32(vld1q_f32(s.add(i)), bv);
        if relu {
            v = vmaxq_f32(v, zero);
        }
        vst1q_f32(d.add(i), v);
        i += 4;
    }
    scalar::store_bias_act(&mut dst[i..], &src[i..], bias, relu);
}

#[target_feature(enable = "neon")]
/// NEON `dst[i] += src[i]`.
pub unsafe fn add_assign_neon(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        vst1q_f32(d.add(i), vaddq_f32(vld1q_f32(d.add(i)), vld1q_f32(s.add(i))));
        i += 4;
    }
    scalar::add_assign(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "neon")]
/// NEON `dst[i] = max(dst[i], src[i])`.
pub unsafe fn max_assign_neon(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        vst1q_f32(d.add(i), vmaxq_f32(vld1q_f32(d.add(i)), vld1q_f32(s.add(i))));
        i += 4;
    }
    scalar::max_assign(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "neon")]
/// NEON complex `acc[i] += a[i] * b[i]` (vld2q split-complex).
pub unsafe fn mad_spectra_neon(acc: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    let n = acc.len();
    let ap = a.as_ptr() as *const f32;
    let bp = b.as_ptr() as *const f32;
    let cp = acc.as_mut_ptr() as *mut f32;
    let mut i = 0usize;
    while i + 4 <= n {
        let f = 2 * i;
        let av = vld2q_f32(ap.add(f)); // .0 = re lanes, .1 = im lanes
        let bv = vld2q_f32(bp.add(f));
        let mut cv = vld2q_f32(cp.add(f));
        cv.0 = vfmaq_f32(cv.0, av.0, bv.0);
        cv.0 = vfmsq_f32(cv.0, av.1, bv.1);
        cv.1 = vfmaq_f32(cv.1, av.0, bv.1);
        cv.1 = vfmaq_f32(cv.1, av.1, bv.0);
        vst2q_f32(cp.add(f), cv);
        i += 4;
    }
    scalar::mad_spectra(&mut acc[i..], &a[i..], &b[i..]);
}

/// Split-complex multiply of four packed complexes: `a · b` with re/im
/// in separate lanes (the `vld2q` representation).
#[inline]
#[target_feature(enable = "neon")]
unsafe fn cmul4(a: float32x4x2_t, b: float32x4x2_t) -> float32x4x2_t {
    let re = vfmsq_f32(vmulq_f32(a.0, b.0), a.1, b.1);
    let im = vfmaq_f32(vmulq_f32(a.0, b.1), a.1, b.0);
    float32x4x2_t(re, im)
}

/// Split-complex add of four packed complexes.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn cadd4(a: float32x4x2_t, b: float32x4x2_t) -> float32x4x2_t {
    float32x4x2_t(vaddq_f32(a.0, b.0), vaddq_f32(a.1, b.1))
}

/// Split-complex subtract of four packed complexes.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn csub4(a: float32x4x2_t, b: float32x4x2_t) -> float32x4x2_t {
    float32x4x2_t(vsubq_f32(a.0, b.0), vsubq_f32(a.1, b.1))
}

/// Split-complex multiply by `-i`: `(re, im) → (im, -re)` — mirrors
/// `Complex32::mul_neg_i`.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn cmul_neg_i4(a: float32x4x2_t) -> float32x4x2_t {
    float32x4x2_t(a.1, vnegq_f32(a.0))
}

#[target_feature(enable = "neon")]
/// NEON radix-2 DIT combine: four butterflies per iteration, scalar
/// remainder tail (see `scalar::radix2_combine` for semantics).
pub unsafe fn radix2_combine_neon(
    dst: &mut [Complex32],
    m: usize,
    tw: &[Complex32],
    step: usize,
    n: usize,
) {
    let base = dst.as_mut_ptr() as *mut f32;
    let lo = base;
    let hi = base.add(2 * m);
    let mut wbuf = [Complex32::ZERO; 4];
    // Twiddle index (k2·step) mod n by accumulation — no per-butterfly
    // multiply/modulo in the gather (mirrors the scalar path).
    let step = step % n;
    let mut w = 0usize;
    let mut k2 = 0usize;
    while k2 + 4 <= m {
        for slot in wbuf.iter_mut() {
            *slot = tw[w];
            w += step;
            if w >= n {
                w -= n;
            }
        }
        let wv = vld2q_f32(wbuf.as_ptr() as *const f32);
        let t0 = vld2q_f32(lo.add(2 * k2));
        let t1 = cmul4(vld2q_f32(hi.add(2 * k2)), wv);
        vst2q_f32(lo.add(2 * k2), cadd4(t0, t1));
        vst2q_f32(hi.add(2 * k2), csub4(t0, t1));
        k2 += 4;
    }
    scalar::radix2_combine_from(dst, m, tw, step, n, k2);
}

#[target_feature(enable = "neon")]
/// NEON radix-4 DIT combine: four butterflies per iteration, scalar
/// remainder tail (see `scalar::radix4_combine` for semantics).
pub unsafe fn radix4_combine_neon(
    dst: &mut [Complex32],
    m: usize,
    tw: &[Complex32],
    step: usize,
    n: usize,
) {
    let base = dst.as_mut_ptr() as *mut f32;
    let d0 = base;
    let d1 = base.add(2 * m);
    let d2 = base.add(4 * m);
    let d3 = base.add(6 * m);
    // Gathered twiddles: w¹[4], w²[4], w³[4]. The w¹ index accumulates;
    // w² and w³ are additions with a conditional wrap (same arithmetic
    // as the scalar oracle, so indices agree exactly).
    let mut wbuf = [Complex32::ZERO; 12];
    let step = step % n;
    let mut w1 = 0usize;
    let mut k2 = 0usize;
    while k2 + 4 <= m {
        for j in 0..4 {
            let mut w2 = w1 + w1;
            if w2 >= n {
                w2 -= n;
            }
            let mut w3 = w2 + w1;
            if w3 >= n {
                w3 -= n;
            }
            wbuf[j] = tw[w1];
            wbuf[4 + j] = tw[w2];
            wbuf[8 + j] = tw[w3];
            w1 += step;
            if w1 >= n {
                w1 -= n;
            }
        }
        let wp = wbuf.as_ptr() as *const f32;
        let t0 = vld2q_f32(d0.add(2 * k2));
        let t1 = cmul4(vld2q_f32(d1.add(2 * k2)), vld2q_f32(wp));
        let t2 = cmul4(vld2q_f32(d2.add(2 * k2)), vld2q_f32(wp.add(8)));
        let t3 = cmul4(vld2q_f32(d3.add(2 * k2)), vld2q_f32(wp.add(16)));
        let a = cadd4(t0, t2);
        let b = csub4(t0, t2);
        let c = cadd4(t1, t3);
        let d = cmul_neg_i4(csub4(t1, t3));
        vst2q_f32(d0.add(2 * k2), cadd4(a, c));
        vst2q_f32(d1.add(2 * k2), cadd4(b, d));
        vst2q_f32(d2.add(2 * k2), csub4(a, c));
        vst2q_f32(d3.add(2 * k2), csub4(b, d));
        k2 += 4;
    }
    scalar::radix4_combine_from(dst, m, tw, step, n, k2);
}

// ------------------------------------------------- precision storage
//
// bf16 runs as real integer vectors (the same RNE arithmetic as the
// scalar oracle, hence bit-identical for all inputs). The f16 kernels
// are dispatched to the scalar oracle on this tier: stdarch's NEON
// f16 conversion intrinsics (`vcvt_f16_f32`) are not stable at the
// crate's MSRV, and conversions sit outside the per-voxel hot loops.

/// RNE-truncate four f32 bit patterns to bf16 values in the low 16 bits
/// of each u32 lane — the exact integer sequence of
/// `scalar::f32_to_bf16_bits`.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn bf16_round_neon(u: uint32x4_t) -> uint32x4_t {
    let abs = vandq_u32(u, vdupq_n_u32(0x7fff_ffff));
    let is_nan = vcgtq_u32(abs, vdupq_n_u32(0x7f80_0000));
    let lsb = vandq_u32(vshrq_n_u32::<16>(u), vdupq_n_u32(1));
    let rounded = vaddq_u32(u, vaddq_u32(vdupq_n_u32(0x7fff), lsb));
    let r = vshrq_n_u32::<16>(rounded);
    let nan_r = vorrq_u32(vshrq_n_u32::<16>(u), vdupq_n_u32(0x0040));
    vbslq_u32(is_nan, nan_r, r)
}

#[target_feature(enable = "neon")]
/// NEON `dst[i] = bf16(src[i])` — bit-identical to the scalar oracle.
pub unsafe fn narrow_bf16_neon(dst: &mut [u16], src: &[f32]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        let u = vreinterpretq_u32_f32(vld1q_f32(s.add(i)));
        vst1_u16(d.add(i), vmovn_u32(bf16_round_neon(u)));
        i += 4;
    }
    scalar::narrow_bf16(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "neon")]
/// NEON `dst[i] = f32(src[i])` for bf16 storage (exact widening).
pub unsafe fn widen_bf16_neon(dst: &mut [f32], src: &[u16]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        let w = vshlq_n_u32::<16>(vmovl_u16(vld1_u16(s.add(i))));
        vst1q_f32(d.add(i), vreinterpretq_f32_u32(w));
        i += 4;
    }
    scalar::widen_bf16(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "neon")]
/// NEON `dst[i] = bf16(act(src[i] + bias))` — fused narrow-on-store.
pub unsafe fn store_bias_act_narrow_bf16_neon(
    dst: &mut [u16],
    src: &[f32],
    bias: f32,
    relu: bool,
) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let bv = vdupq_n_f32(bias);
    let zero = vdupq_n_f32(0.0);
    let mut i = 0usize;
    while i + 4 <= n {
        let mut v = vaddq_f32(vld1q_f32(s.add(i)), bv);
        if relu {
            v = vmaxq_f32(v, zero);
        }
        let u = vreinterpretq_u32_f32(v);
        vst1_u16(d.add(i), vmovn_u32(bf16_round_neon(u)));
        i += 4;
    }
    scalar::store_bias_act_narrow_bf16(&mut dst[i..], &src[i..], bias, relu);
}

#[target_feature(enable = "neon")]
/// NEON complex `dst[i] = a[i] * b[i]`.
pub unsafe fn cmul_neon(dst: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    let n = dst.len();
    let ap = a.as_ptr() as *const f32;
    let bp = b.as_ptr() as *const f32;
    let dp = dst.as_mut_ptr() as *mut f32;
    let mut i = 0usize;
    while i + 4 <= n {
        let f = 2 * i;
        let av = vld2q_f32(ap.add(f));
        let bv = vld2q_f32(bp.add(f));
        let re = vfmsq_f32(vmulq_f32(av.0, bv.0), av.1, bv.1);
        let im = vfmaq_f32(vmulq_f32(av.0, bv.1), av.1, bv.0);
        vst2q_f32(dp.add(f), float32x4x2_t(re, im));
        i += 4;
    }
    scalar::cmul(&mut dst[i..], &a[i..], &b[i..]);
}
