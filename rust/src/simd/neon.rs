//! NEON kernel implementations (aarch64).
//!
//! NEON is baseline on aarch64, so these are always dispatchable there.
//! The complex kernels use `vld2q`/`vst2q` structured loads, which
//! deinterleave to split-complex (SoA) registers for free — the complex
//! multiply-accumulate is then four fused multiply-adds, the same shape
//! as the AVX2 tile. The radix butterflies currently fall back to
//! scalar (see `simd::radix2_combine_with`).

#![allow(clippy::missing_safety_doc)]

use crate::tensor::Complex32;

use super::scalar;

use core::arch::aarch64::*;

#[target_feature(enable = "neon")]
/// NEON `dst[i] += k * src[i]`.
pub unsafe fn axpy_neon(dst: &mut [f32], src: &[f32], k: f32) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let kv = vdupq_n_f32(k);
    let mut i = 0usize;
    while i + 4 <= n {
        let r = vfmaq_f32(vld1q_f32(d.add(i)), vld1q_f32(s.add(i)), kv);
        vst1q_f32(d.add(i), r);
        i += 4;
    }
    scalar::axpy(&mut dst[i..], &src[i..], k);
}

#[target_feature(enable = "neon")]
/// NEON `dst[i] += src[i]`.
pub unsafe fn add_assign_neon(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        vst1q_f32(d.add(i), vaddq_f32(vld1q_f32(d.add(i)), vld1q_f32(s.add(i))));
        i += 4;
    }
    scalar::add_assign(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "neon")]
/// NEON `dst[i] = max(dst[i], src[i])`.
pub unsafe fn max_assign_neon(dst: &mut [f32], src: &[f32]) {
    let n = dst.len();
    let d = dst.as_mut_ptr();
    let s = src.as_ptr();
    let mut i = 0usize;
    while i + 4 <= n {
        vst1q_f32(d.add(i), vmaxq_f32(vld1q_f32(d.add(i)), vld1q_f32(s.add(i))));
        i += 4;
    }
    scalar::max_assign(&mut dst[i..], &src[i..]);
}

#[target_feature(enable = "neon")]
/// NEON complex `acc[i] += a[i] * b[i]` (vld2q split-complex).
pub unsafe fn mad_spectra_neon(acc: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    let n = acc.len();
    let ap = a.as_ptr() as *const f32;
    let bp = b.as_ptr() as *const f32;
    let cp = acc.as_mut_ptr() as *mut f32;
    let mut i = 0usize;
    while i + 4 <= n {
        let f = 2 * i;
        let av = vld2q_f32(ap.add(f)); // .0 = re lanes, .1 = im lanes
        let bv = vld2q_f32(bp.add(f));
        let mut cv = vld2q_f32(cp.add(f));
        cv.0 = vfmaq_f32(cv.0, av.0, bv.0);
        cv.0 = vfmsq_f32(cv.0, av.1, bv.1);
        cv.1 = vfmaq_f32(cv.1, av.0, bv.1);
        cv.1 = vfmaq_f32(cv.1, av.1, bv.0);
        vst2q_f32(cp.add(f), cv);
        i += 4;
    }
    scalar::mad_spectra(&mut acc[i..], &a[i..], &b[i..]);
}

#[target_feature(enable = "neon")]
/// NEON complex `dst[i] = a[i] * b[i]`.
pub unsafe fn cmul_neon(dst: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    let n = dst.len();
    let ap = a.as_ptr() as *const f32;
    let bp = b.as_ptr() as *const f32;
    let dp = dst.as_mut_ptr() as *mut f32;
    let mut i = 0usize;
    while i + 4 <= n {
        let f = 2 * i;
        let av = vld2q_f32(ap.add(f));
        let bv = vld2q_f32(bp.add(f));
        let re = vfmsq_f32(vmulq_f32(av.0, bv.0), av.1, bv.1);
        let im = vfmaq_f32(vmulq_f32(av.0, bv.1), av.1, bv.0);
        vst2q_f32(dp.add(f), float32x4x2_t(re, im));
        i += 4;
    }
    scalar::cmul(&mut dst[i..], &a[i..], &b[i..]);
}
