//! Portable SIMD kernel layer with runtime dispatch.
//!
//! ZNNi's CPU throughput rests on four hot loops — the FFT-conv
//! point-wise multiply-accumulate, the direct-conv z-contiguous FMA,
//! the radix-2/4 FFT butterflies, and the pooling comparisons. This
//! module provides each of them as a *kernel* with one scalar reference
//! implementation ([`scalar`], also the property-test oracle) and
//! vector implementations selected at runtime:
//!
//! | tier       | arch      | requirement                |
//! |------------|-----------|----------------------------|
//! | `avx2+fma` | x86/x86_64| AVX2 and FMA detected      |
//! | `sse2`     | x86/x86_64| SSE2 detected (baseline)   |
//! | `neon`     | aarch64   | always (NEON is baseline)  |
//! | `scalar`   | any       | —                          |
//!
//! Dispatch resolves once (CPUID + the `ZNNI_SIMD` environment
//! variable, values `scalar | sse2 | avx2 | neon | auto`) and can be
//! overridden programmatically with [`force`] — benches use that to
//! measure scalar-vs-vector on the same machine, tests to prove parity
//! on every supported tier. Each kernel also has an explicit-tier
//! `*_with` variant that bypasses the global state entirely.
//!
//! Building with `RUSTFLAGS="-C target-cpu=native"` additionally lets
//! the compiler use the same ISA in the surrounding scalar code; the
//! kernels here do not require it.
//!
//! ```
//! use znni::simd::{self, Tier};
//!
//! let mut dst = vec![1.0f32; 9]; // odd length: exercises the tail loop
//! let src = vec![2.0f32; 9];
//! simd::axpy(&mut dst, &src, 0.5); // best tier for this CPU
//! simd::axpy_with(Tier::Scalar, &mut dst, &src, 0.5); // forced tier
//! assert!(dst.iter().all(|&v| (v - 3.0).abs() < 1e-6));
//! ```

pub mod scalar;

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
mod x86;

#[cfg(target_arch = "aarch64")]
mod neon;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use crate::tensor::Complex32;

/// An instruction-set tier a kernel can be dispatched to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Tier {
    /// Plain Rust loops — always available, and the parity oracle.
    Scalar = 1,
    /// 128-bit SSE2 (x86 baseline): no FMA, add/mul/max only.
    Sse2 = 2,
    /// 256-bit AVX2 with fused multiply-add.
    Avx2Fma = 3,
    /// 128-bit NEON with fused multiply-add (aarch64 baseline).
    Neon = 4,
}

impl Tier {
    /// Lower-case tier name (the `ZNNI_SIMD` values).
    pub fn name(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Sse2 => "sse2",
            Tier::Avx2Fma => "avx2+fma",
            Tier::Neon => "neon",
        }
    }

    fn from_u8(v: u8) -> Option<Tier> {
        match v {
            1 => Some(Tier::Scalar),
            2 => Some(Tier::Sse2),
            3 => Some(Tier::Avx2Fma),
            4 => Some(Tier::Neon),
            _ => None,
        }
    }

    /// Parse a `ZNNI_SIMD` value.
    pub fn parse(s: &str) -> Option<Tier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Tier::Scalar),
            "sse2" | "sse" => Some(Tier::Sse2),
            "avx2" | "avx2+fma" | "fma" => Some(Tier::Avx2Fma),
            "neon" => Some(Tier::Neon),
            _ => None,
        }
    }
}

/// Highest tier this CPU supports.
pub fn detect() -> Tier {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
        {
            Tier::Avx2Fma
        } else if std::arch::is_x86_feature_detected!("sse2") {
            Tier::Sse2
        } else {
            Tier::Scalar
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        Tier::Neon
    }
    #[cfg(not(any(target_arch = "x86", target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Tier::Scalar
    }
}

/// Is `t` runnable on this CPU?
pub fn supported(t: Tier) -> bool {
    match t {
        Tier::Scalar => true,
        Tier::Sse2 | Tier::Avx2Fma => {
            cfg!(any(target_arch = "x86", target_arch = "x86_64")) && t <= detect()
        }
        Tier::Neon => cfg!(target_arch = "aarch64"),
    }
}

/// All tiers runnable on this CPU, scalar first.
pub fn supported_tiers() -> Vec<Tier> {
    [Tier::Scalar, Tier::Sse2, Tier::Avx2Fma, Tier::Neon]
        .into_iter()
        .filter(|&t| supported(t))
        .collect()
}

const TIER_UNSET: u8 = 0;
static FORCED: AtomicU8 = AtomicU8::new(TIER_UNSET);
static RESOLVED: OnceLock<Tier> = OnceLock::new();

/// The tier dispatching kernels currently use: the [`force`]d tier if
/// set, else `ZNNI_SIMD` (read once), else the detected maximum.
pub fn active() -> Tier {
    match Tier::from_u8(FORCED.load(Ordering::Relaxed)) {
        Some(t) => t,
        None => *RESOLVED.get_or_init(|| {
            let hw = detect();
            match std::env::var("ZNNI_SIMD") {
                Ok(v) if !v.trim().is_empty() && v.trim() != "auto" => match Tier::parse(&v) {
                    Some(t) if supported(t) => t,
                    Some(t) => {
                        eprintln!(
                            "znni: ZNNI_SIMD={} not supported on this CPU, using {}",
                            t.name(),
                            hw.name()
                        );
                        hw
                    }
                    None => {
                        eprintln!("znni: unknown ZNNI_SIMD value {v:?}, using {}", hw.name());
                        hw
                    }
                },
                _ => hw,
            }
        }),
    }
}

/// Force every subsequent dispatch to `t` (must be [`supported`]), or
/// restore auto-detection with `None`. Used by the parity tests and the
/// scalar-vs-vector microbenches.
pub fn force(t: Option<Tier>) {
    match t {
        Some(t) => {
            assert!(supported(t), "tier {} not supported on this CPU", t.name());
            FORCED.store(t as u8, Ordering::Relaxed);
        }
        None => FORCED.store(TIER_UNSET, Ordering::Relaxed),
    }
}

// ---------------------------------------------------------------------
// Kernel entry points. Each `foo` dispatches on `active()`; each
// `foo_with` takes the tier explicitly (asserting it is supported) so
// tests can exercise every tier without touching global state.
// ---------------------------------------------------------------------

/// `dst[i] += k · src[i]`.
#[inline]
pub fn axpy(dst: &mut [f32], src: &[f32], k: f32) {
    axpy_tier(active(), dst, src, k);
}

/// [`axpy`] on an explicit tier (asserts it is supported).
pub fn axpy_with(tier: Tier, dst: &mut [f32], src: &[f32], k: f32) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    axpy_tier(tier, dst, src, k);
}

/// Crate-internal dispatch: `tier` must be supported (hot loops hoist
/// `active()` once and call this per row).
#[inline]
pub(crate) fn axpy_tier(tier: Tier, dst: &mut [f32], src: &[f32], k: f32) {
    debug_assert!(supported(tier));
    assert_eq!(dst.len(), src.len());
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::axpy_avx2(dst, src, k) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Sse2 => unsafe { x86::axpy_sse2(dst, src, k) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::axpy_neon(dst, src, k) },
        _ => scalar::axpy(dst, src, k),
    }
}

/// `dst0[i] += k0 · src[i]; dst1[i] += k1 · src[i]` — the fused
/// direct-conv register tile: one input load feeds two output-channel
/// accumulators. Multiply-then-add on every tier (no FMA), so all tiers
/// are bit-identical to [`scalar::axpy2`] on finite inputs.
#[inline]
pub fn axpy2(dst0: &mut [f32], dst1: &mut [f32], src: &[f32], k0: f32, k1: f32) {
    axpy2_tier(active(), dst0, dst1, src, k0, k1);
}

/// [`axpy2`] on an explicit tier (asserts it is supported).
pub fn axpy2_with(tier: Tier, dst0: &mut [f32], dst1: &mut [f32], src: &[f32], k0: f32, k1: f32) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    axpy2_tier(tier, dst0, dst1, src, k0, k1);
}

/// Crate-internal dispatch: `tier` must be supported (hot loops hoist
/// `active()` once and call this per row).
#[inline]
pub(crate) fn axpy2_tier(
    tier: Tier,
    dst0: &mut [f32],
    dst1: &mut [f32],
    src: &[f32],
    k0: f32,
    k1: f32,
) {
    debug_assert!(supported(tier));
    assert_eq!(dst0.len(), src.len());
    assert_eq!(dst1.len(), src.len());
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::axpy2_avx2(dst0, dst1, src, k0, k1) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Sse2 => unsafe { x86::axpy2_sse2(dst0, dst1, src, k0, k1) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::axpy2_neon(dst0, dst1, src, k0, k1) },
        _ => scalar::axpy2(dst0, dst1, src, k0, k1),
    }
}

/// `dst[i] = act(src[i] + bias)` — the fused direct conv's single
/// store: bias plus optional ReLU applied as an accumulator row leaves
/// the register tile. Bit-identical to [`scalar::store_bias_act`] on
/// every tier for finite inputs.
#[inline]
pub fn store_bias_act(dst: &mut [f32], src: &[f32], bias: f32, relu: bool) {
    store_bias_act_tier(active(), dst, src, bias, relu);
}

/// [`store_bias_act`] on an explicit tier (asserts it is supported).
pub fn store_bias_act_with(tier: Tier, dst: &mut [f32], src: &[f32], bias: f32, relu: bool) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    store_bias_act_tier(tier, dst, src, bias, relu);
}

/// Crate-internal dispatch: `tier` must be supported (hot loops hoist
/// `active()` once and call this per row).
#[inline]
pub(crate) fn store_bias_act_tier(tier: Tier, dst: &mut [f32], src: &[f32], bias: f32, relu: bool) {
    debug_assert!(supported(tier));
    assert_eq!(dst.len(), src.len());
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::store_bias_act_avx2(dst, src, bias, relu) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Sse2 => unsafe { x86::store_bias_act_sse2(dst, src, bias, relu) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::store_bias_act_neon(dst, src, bias, relu) },
        _ => scalar::store_bias_act(dst, src, bias, relu),
    }
}

/// `dst[i] += src[i]`.
#[inline]
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    add_assign_tier(active(), dst, src);
}

/// [`add_assign`] on an explicit tier (asserts it is supported).
pub fn add_assign_with(tier: Tier, dst: &mut [f32], src: &[f32]) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    add_assign_tier(tier, dst, src);
}

/// Crate-internal dispatch: `tier` must be supported (hot loops hoist
/// `active()` once and call this per row).
#[inline]
pub(crate) fn add_assign_tier(tier: Tier, dst: &mut [f32], src: &[f32]) {
    debug_assert!(supported(tier));
    assert_eq!(dst.len(), src.len());
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::add_assign_avx2(dst, src) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Sse2 => unsafe { x86::add_assign_sse2(dst, src) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::add_assign_neon(dst, src) },
        _ => scalar::add_assign(dst, src),
    }
}

/// `dst[i] = max(dst[i], src[i])`.
#[inline]
pub fn max_assign(dst: &mut [f32], src: &[f32]) {
    max_assign_tier(active(), dst, src);
}

/// [`max_assign`] on an explicit tier (asserts it is supported).
pub fn max_assign_with(tier: Tier, dst: &mut [f32], src: &[f32]) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    max_assign_tier(tier, dst, src);
}

/// Crate-internal dispatch: `tier` must be supported (hot loops hoist
/// `active()` once and call this per row).
#[inline]
pub(crate) fn max_assign_tier(tier: Tier, dst: &mut [f32], src: &[f32]) {
    debug_assert!(supported(tier));
    assert_eq!(dst.len(), src.len());
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::max_assign_avx2(dst, src) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Sse2 => unsafe { x86::max_assign_sse2(dst, src) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::max_assign_neon(dst, src) },
        _ => scalar::max_assign(dst, src),
    }
}

/// `acc[i] += a[i] · b[i]` (complex) — the FFT-conv Stage-2 kernel. The
/// AVX2 tier deinterleaves 8-complex tiles to split-complex (SoA)
/// registers so the complex MAD becomes four pure FMAs.
#[inline]
pub fn mad_spectra(acc: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    mad_spectra_tier(active(), acc, a, b);
}

/// [`mad_spectra`] on an explicit tier (asserts it is supported).
pub fn mad_spectra_with(tier: Tier, acc: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    mad_spectra_tier(tier, acc, a, b);
}

/// Crate-internal dispatch: `tier` must be supported (hot loops hoist
/// `active()` once and call this per row).
#[inline]
pub(crate) fn mad_spectra_tier(
    tier: Tier,
    acc: &mut [Complex32],
    a: &[Complex32],
    b: &[Complex32],
) {
    debug_assert!(supported(tier));
    assert_eq!(acc.len(), a.len());
    assert_eq!(acc.len(), b.len());
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::mad_spectra_avx2(acc, a, b) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Sse2 => unsafe { x86::mad_spectra_sse2(acc, a, b) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::mad_spectra_neon(acc, a, b) },
        _ => scalar::mad_spectra(acc, a, b),
    }
}

/// `dst[i] = a[i] · b[i]` (complex) — the GPU scheme's PARALLEL-MULT.
#[inline]
pub fn cmul(dst: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    cmul_tier(active(), dst, a, b);
}

/// [`cmul`] on an explicit tier (asserts it is supported).
pub fn cmul_with(tier: Tier, dst: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    cmul_tier(tier, dst, a, b);
}

/// Crate-internal dispatch: `tier` must be supported (hot loops hoist
/// `active()` once and call this per row).
#[inline]
pub(crate) fn cmul_tier(tier: Tier, dst: &mut [Complex32], a: &[Complex32], b: &[Complex32]) {
    debug_assert!(supported(tier));
    assert_eq!(dst.len(), a.len());
    assert_eq!(dst.len(), b.len());
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::cmul_avx2_slices(dst, a, b) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Sse2 => unsafe { x86::cmul_sse2_slices(dst, a, b) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::cmul_neon(dst, a, b) },
        _ => scalar::cmul(dst, a, b),
    }
}

/// `dst[i] = f16(src[i])` — narrow an f32 row into IEEE binary16
/// storage bits (round-to-nearest-even). The AVX2 tier uses hardware
/// F16C when the CPU has it (IEEE-identical on finite inputs); SSE2 and
/// NEON dispatch to the scalar oracle (no stable f16 hardware path at
/// those tiers), so every tier is bit-identical on finite inputs.
#[inline]
pub fn narrow_f16(dst: &mut [u16], src: &[f32]) {
    narrow_f16_tier(active(), dst, src);
}

/// [`narrow_f16`] on an explicit tier (asserts it is supported).
pub fn narrow_f16_with(tier: Tier, dst: &mut [u16], src: &[f32]) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    narrow_f16_tier(tier, dst, src);
}

/// Crate-internal dispatch: `tier` must be supported.
#[inline]
pub(crate) fn narrow_f16_tier(tier: Tier, dst: &mut [u16], src: &[f32]) {
    debug_assert!(supported(tier));
    assert_eq!(dst.len(), src.len());
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::narrow_f16_avx2(dst, src) },
        _ => scalar::narrow_f16(dst, src),
    }
}

/// `dst[i] = f32(src[i])` — widen f16 storage bits back to f32. Exact
/// on every tier (each half value is representable in f32).
#[inline]
pub fn widen_f16(dst: &mut [f32], src: &[u16]) {
    widen_f16_tier(active(), dst, src);
}

/// [`widen_f16`] on an explicit tier (asserts it is supported).
pub fn widen_f16_with(tier: Tier, dst: &mut [f32], src: &[u16]) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    widen_f16_tier(tier, dst, src);
}

/// Crate-internal dispatch: `tier` must be supported.
#[inline]
pub(crate) fn widen_f16_tier(tier: Tier, dst: &mut [f32], src: &[u16]) {
    debug_assert!(supported(tier));
    assert_eq!(dst.len(), src.len());
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::widen_f16_avx2(dst, src) },
        _ => scalar::widen_f16(dst, src),
    }
}

/// `dst[i] = bf16(src[i])` — narrow an f32 row into bfloat16 storage
/// bits (round-to-nearest-even truncation). Every vector tier runs the
/// same integer sequence as [`scalar::f32_to_bf16_bits`], so all tiers
/// are bit-identical for all inputs.
#[inline]
pub fn narrow_bf16(dst: &mut [u16], src: &[f32]) {
    narrow_bf16_tier(active(), dst, src);
}

/// [`narrow_bf16`] on an explicit tier (asserts it is supported).
pub fn narrow_bf16_with(tier: Tier, dst: &mut [u16], src: &[f32]) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    narrow_bf16_tier(tier, dst, src);
}

/// Crate-internal dispatch: `tier` must be supported.
#[inline]
pub(crate) fn narrow_bf16_tier(tier: Tier, dst: &mut [u16], src: &[f32]) {
    debug_assert!(supported(tier));
    assert_eq!(dst.len(), src.len());
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::narrow_bf16_avx2(dst, src) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Sse2 => unsafe { x86::narrow_bf16_sse2(dst, src) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::narrow_bf16_neon(dst, src) },
        _ => scalar::narrow_bf16(dst, src),
    }
}

/// `dst[i] = f32(src[i])` — widen bf16 storage bits back to f32. Exact
/// on every tier (bf16 is a prefix of the f32 encoding).
#[inline]
pub fn widen_bf16(dst: &mut [f32], src: &[u16]) {
    widen_bf16_tier(active(), dst, src);
}

/// [`widen_bf16`] on an explicit tier (asserts it is supported).
pub fn widen_bf16_with(tier: Tier, dst: &mut [f32], src: &[u16]) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    widen_bf16_tier(tier, dst, src);
}

/// Crate-internal dispatch: `tier` must be supported.
#[inline]
pub(crate) fn widen_bf16_tier(tier: Tier, dst: &mut [f32], src: &[u16]) {
    debug_assert!(supported(tier));
    assert_eq!(dst.len(), src.len());
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::widen_bf16_avx2(dst, src) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Sse2 => unsafe { x86::widen_bf16_sse2(dst, src) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::widen_bf16_neon(dst, src) },
        _ => scalar::widen_bf16(dst, src),
    }
}

/// `dst[i] = f16(act(src[i] + bias))` — fused narrow-on-store: the
/// [`store_bias_act`] sweep narrowing directly into half storage, so a
/// reduced-precision layer's output skips the extra f32 store pass.
/// Bit-identical across tiers on finite inputs.
#[inline]
pub fn store_bias_act_narrow_f16(dst: &mut [u16], src: &[f32], bias: f32, relu: bool) {
    store_bias_act_narrow_f16_tier(active(), dst, src, bias, relu);
}

/// [`store_bias_act_narrow_f16`] on an explicit tier (asserts support).
pub fn store_bias_act_narrow_f16_with(
    tier: Tier,
    dst: &mut [u16],
    src: &[f32],
    bias: f32,
    relu: bool,
) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    store_bias_act_narrow_f16_tier(tier, dst, src, bias, relu);
}

/// Crate-internal dispatch: `tier` must be supported.
#[inline]
pub(crate) fn store_bias_act_narrow_f16_tier(
    tier: Tier,
    dst: &mut [u16],
    src: &[f32],
    bias: f32,
    relu: bool,
) {
    debug_assert!(supported(tier));
    assert_eq!(dst.len(), src.len());
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::store_bias_act_narrow_f16_avx2(dst, src, bias, relu) },
        _ => scalar::store_bias_act_narrow_f16(dst, src, bias, relu),
    }
}

/// `dst[i] = bf16(act(src[i] + bias))` — fused narrow-on-store, bf16.
/// Bit-identical across tiers on finite inputs.
#[inline]
pub fn store_bias_act_narrow_bf16(dst: &mut [u16], src: &[f32], bias: f32, relu: bool) {
    store_bias_act_narrow_bf16_tier(active(), dst, src, bias, relu);
}

/// [`store_bias_act_narrow_bf16`] on an explicit tier (asserts support).
pub fn store_bias_act_narrow_bf16_with(
    tier: Tier,
    dst: &mut [u16],
    src: &[f32],
    bias: f32,
    relu: bool,
) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    store_bias_act_narrow_bf16_tier(tier, dst, src, bias, relu);
}

/// Crate-internal dispatch: `tier` must be supported.
#[inline]
pub(crate) fn store_bias_act_narrow_bf16_tier(
    tier: Tier,
    dst: &mut [u16],
    src: &[f32],
    bias: f32,
    relu: bool,
) {
    debug_assert!(supported(tier));
    assert_eq!(dst.len(), src.len());
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::store_bias_act_narrow_bf16_avx2(dst, src, bias, relu) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Sse2 => unsafe { x86::store_bias_act_narrow_bf16_sse2(dst, src, bias, relu) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::store_bias_act_narrow_bf16_neon(dst, src, bias, relu) },
        _ => scalar::store_bias_act_narrow_bf16(dst, src, bias, relu),
    }
}

/// Radix-2 DIT combine (see [`scalar::radix2_combine`] for semantics).
#[inline]
pub fn radix2_combine(dst: &mut [Complex32], m: usize, tw: &[Complex32], step: usize, n: usize) {
    radix2_combine_tier(active(), dst, m, tw, step, n);
}

/// [`radix2_combine`] on an explicit tier (asserts it is supported).
pub fn radix2_combine_with(
    tier: Tier,
    dst: &mut [Complex32],
    m: usize,
    tw: &[Complex32],
    step: usize,
    n: usize,
) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    radix2_combine_tier(tier, dst, m, tw, step, n);
}

#[inline]
pub(crate) fn radix2_combine_tier(
    tier: Tier,
    dst: &mut [Complex32],
    m: usize,
    tw: &[Complex32],
    step: usize,
    n: usize,
) {
    debug_assert!(supported(tier));
    assert!(dst.len() >= 2 * m);
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::radix2_combine_avx2(dst, m, tw, step, n) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Sse2 => unsafe { x86::radix2_combine_sse2(dst, m, tw, step, n) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::radix2_combine_neon(dst, m, tw, step, n) },
        _ => scalar::radix2_combine(dst, m, tw, step, n),
    }
}

/// Radix-4 DIT combine (see [`scalar::radix4_combine`] for semantics).
#[inline]
pub fn radix4_combine(dst: &mut [Complex32], m: usize, tw: &[Complex32], step: usize, n: usize) {
    radix4_combine_tier(active(), dst, m, tw, step, n);
}

/// [`radix4_combine`] on an explicit tier (asserts it is supported).
pub fn radix4_combine_with(
    tier: Tier,
    dst: &mut [Complex32],
    m: usize,
    tw: &[Complex32],
    step: usize,
    n: usize,
) {
    assert!(supported(tier), "tier {} not supported on this CPU", tier.name());
    radix4_combine_tier(tier, dst, m, tw, step, n);
}

#[inline]
pub(crate) fn radix4_combine_tier(
    tier: Tier,
    dst: &mut [Complex32],
    m: usize,
    tw: &[Complex32],
    step: usize,
    n: usize,
) {
    debug_assert!(supported(tier));
    assert!(dst.len() >= 4 * m);
    match tier {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Avx2Fma => unsafe { x86::radix4_combine_avx2(dst, m, tw, step, n) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Tier::Sse2 => unsafe { x86::radix4_combine_sse2(dst, m, tw, step, n) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::radix4_combine_neon(dst, m, tw, step, n) },
        _ => scalar::radix4_combine(dst, m, tw, step, n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::quick::assert_allclose;

    fn rand_f32(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.f32_range(-1.0, 1.0)).collect()
    }

    fn rand_c32(n: usize, seed: u64) -> Vec<Complex32> {
        let mut r = Rng::new(seed);
        (0..n)
            .map(|_| Complex32::new(r.f32_range(-1.0, 1.0), r.f32_range(-1.0, 1.0)))
            .collect()
    }

    fn flat(v: &[Complex32]) -> Vec<f32> {
        v.iter().flat_map(|c| [c.re, c.im]).collect()
    }

    fn twiddles(n: usize) -> Vec<Complex32> {
        (0..n)
            .map(|j| Complex32::cis(-2.0 * std::f64::consts::PI * j as f64 / n as f64))
            .collect()
    }

    #[test]
    fn detection_is_consistent() {
        let hw = detect();
        assert!(supported(hw));
        assert!(supported(Tier::Scalar));
        assert!(supported_tiers().contains(&Tier::Scalar));
        assert!(supported_tiers().contains(&hw));
        // active() resolves to something supported.
        assert!(supported(active()));
    }

    #[test]
    fn parse_accepts_aliases() {
        assert_eq!(Tier::parse("scalar"), Some(Tier::Scalar));
        assert_eq!(Tier::parse(" SSE2 "), Some(Tier::Sse2));
        assert_eq!(Tier::parse("avx2"), Some(Tier::Avx2Fma));
        assert_eq!(Tier::parse("avx2+fma"), Some(Tier::Avx2Fma));
        assert_eq!(Tier::parse("neon"), Some(Tier::Neon));
        assert_eq!(Tier::parse("mmx"), None);
    }

    #[test]
    fn f32_kernels_match_scalar_on_every_tier() {
        // Odd lengths on purpose: exercise the remainder tails.
        for n in [0usize, 1, 3, 7, 8, 15, 16, 17, 31, 33, 64, 100, 129] {
            let src = rand_f32(n, n as u64);
            let base = rand_f32(n, n as u64 + 500);
            for tier in supported_tiers() {
                let mut want = base.clone();
                scalar::axpy(&mut want, &src, 0.37);
                let mut got = base.clone();
                axpy_with(tier, &mut got, &src, 0.37);
                assert_allclose(&got, &want, 1e-6, 1e-5, &format!("axpy {tier:?} n={n}"));

                let mut want = base.clone();
                scalar::add_assign(&mut want, &src);
                let mut got = base.clone();
                add_assign_with(tier, &mut got, &src);
                assert_allclose(&got, &want, 0.0, 0.0, &format!("add {tier:?} n={n}"));

                let mut want = base.clone();
                scalar::max_assign(&mut want, &src);
                let mut got = base.clone();
                max_assign_with(tier, &mut got, &src);
                assert_allclose(&got, &want, 0.0, 0.0, &format!("max {tier:?} n={n}"));

                // The fused-conv kernels promise *bit* identity (no FMA
                // on any tier), hence zero tolerance even for axpy2.
                let base1 = rand_f32(n, n as u64 + 900);
                let mut want0 = base.clone();
                let mut want1 = base1.clone();
                scalar::axpy2(&mut want0, &mut want1, &src, 0.37, -0.61);
                let mut got0 = base.clone();
                let mut got1 = base1.clone();
                axpy2_with(tier, &mut got0, &mut got1, &src, 0.37, -0.61);
                assert_allclose(&got0, &want0, 0.0, 0.0, &format!("axpy2.0 {tier:?} n={n}"));
                assert_allclose(&got1, &want1, 0.0, 0.0, &format!("axpy2.1 {tier:?} n={n}"));

                for relu in [false, true] {
                    let mut want = vec![0.0f32; n];
                    scalar::store_bias_act(&mut want, &src, -0.25, relu);
                    let mut got = vec![0.0f32; n];
                    store_bias_act_with(tier, &mut got, &src, -0.25, relu);
                    assert_allclose(
                        &got,
                        &want,
                        0.0,
                        0.0,
                        &format!("store_bias_act {tier:?} n={n} relu={relu}"),
                    );
                }
            }
        }
    }

    #[test]
    fn complex_kernels_match_scalar_on_every_tier() {
        for n in [0usize, 1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 40, 65] {
            let a = rand_c32(n, n as u64);
            let b = rand_c32(n, n as u64 + 90);
            let acc0 = rand_c32(n, n as u64 + 180);
            for tier in supported_tiers() {
                let mut want = acc0.clone();
                scalar::mad_spectra(&mut want, &a, &b);
                let mut got = acc0.clone();
                mad_spectra_with(tier, &mut got, &a, &b);
                assert_allclose(
                    &flat(&got),
                    &flat(&want),
                    1e-6,
                    1e-4,
                    &format!("mad {tier:?} n={n}"),
                );

                let mut want = acc0.clone();
                scalar::cmul(&mut want, &a, &b);
                let mut got = acc0.clone();
                cmul_with(tier, &mut got, &a, &b);
                assert_allclose(
                    &flat(&got),
                    &flat(&want),
                    1e-6,
                    1e-4,
                    &format!("cmul {tier:?} n={n}"),
                );
            }
        }
    }

    #[test]
    fn radix_combines_match_scalar_on_every_tier() {
        for (m, fft_n, step) in [
            (1usize, 8usize, 1usize),
            (2, 8, 2),
            (3, 12, 1),
            (4, 16, 1),
            (5, 40, 2),
            (8, 32, 1),
            (13, 104, 2),
            (16, 64, 1),
            (30, 240, 2),
        ] {
            let tw = twiddles(fft_n);
            let d2 = rand_c32(2 * m, (m + fft_n) as u64);
            let d4 = rand_c32(4 * m, (m * fft_n) as u64);
            for tier in supported_tiers() {
                let mut want = d2.clone();
                scalar::radix2_combine(&mut want, m, &tw, step, fft_n);
                let mut got = d2.clone();
                radix2_combine_with(tier, &mut got, m, &tw, step, fft_n);
                assert_allclose(
                    &flat(&got),
                    &flat(&want),
                    1e-6,
                    1e-4,
                    &format!("radix2 {tier:?} m={m}"),
                );

                let mut want = d4.clone();
                scalar::radix4_combine(&mut want, m, &tw, step, fft_n);
                let mut got = d4.clone();
                radix4_combine_with(tier, &mut got, m, &tw, step, fft_n);
                assert_allclose(
                    &flat(&got),
                    &flat(&want),
                    1e-6,
                    1e-4,
                    &format!("radix4 {tier:?} m={m}"),
                );
            }
        }
    }

    #[test]
    fn f16_scalar_oracle_known_values() {
        use scalar::{f16_bits_to_f32, f32_to_f16_bits};
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // half::MAX
        assert_eq!(f32_to_f16_bits(65519.0), 0x7BFF); // < 65520: rounds down
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00); // ties up to inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xFC00);
        // Smallest subnormal half is 2^-24; half of it ties to even (0).
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-24)), 0x0001);
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-25)), 0x0000);
        assert_eq!(f32_to_f16_bits(1.5 * 2.0f32.powi(-25)), 0x0001);
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-14)), 0x0400); // min normal
        // RNE on the mantissa: 1 + 2^-11 ties back to even (1.0); one
        // more ulp rounds up.
        assert_eq!(f32_to_f16_bits(1.0 + 2.0f32.powi(-11)), 0x3C00);
        assert_eq!(f32_to_f16_bits(1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-20)), 0x3C01);
        // NaN narrows to a quiet NaN.
        let h = f32_to_f16_bits(f32::NAN);
        assert_eq!(h & 0x7C00, 0x7C00);
        assert_ne!(h & 0x03FF, 0);
        // Widening is exact on a few anchors.
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7BFF), 65504.0);
        assert_eq!(f16_bits_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f16_bits_to_f32(0x8000), -0.0);
        assert!(f16_bits_to_f32(0x8000).is_sign_negative());
    }

    #[test]
    fn half_round_trips_are_exact_for_representable_values() {
        // Every non-NaN f16 bit pattern must survive widen→narrow
        // unchanged (that's 63489 exhaustive cases), and likewise a
        // sweep of bf16 patterns. This is the "exactly representable
        // values round-trip exactly" leg of the accuracy gate.
        for h in 0..=u16::MAX {
            if h & 0x7C00 == 0x7C00 && h & 0x03FF != 0 {
                continue; // NaN payloads may be quieted
            }
            let w = scalar::f16_bits_to_f32(h);
            assert_eq!(scalar::f32_to_f16_bits(w), h, "f16 bits {h:#06x}");
        }
        for h in 0..=u16::MAX {
            if h & 0x7F80 == 0x7F80 && h & 0x007F != 0 {
                continue; // NaN payloads may be quieted
            }
            let w = scalar::bf16_bits_to_f32(h);
            assert_eq!(scalar::f32_to_bf16_bits(w), h, "bf16 bits {h:#06x}");
        }
    }

    #[test]
    fn bf16_scalar_oracle_rounds_to_nearest_even() {
        use scalar::{bf16_bits_to_f32, f32_to_bf16_bits};
        assert_eq!(f32_to_bf16_bits(1.0), 0x3F80);
        assert_eq!(f32_to_bf16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7F80);
        // 1 + 2^-8 ties to even (1.0); one more f32 ulp rounds up.
        assert_eq!(f32_to_bf16_bits(1.0 + 2.0f32.powi(-8)), 0x3F80);
        assert_eq!(f32_to_bf16_bits(f32::from_bits((1.0f32 + 2.0f32.powi(-8)).to_bits() + 1)), 0x3F81);
        // Max finite bf16; the next f32 above the rounding boundary
        // overflows to inf.
        assert_eq!(bf16_bits_to_f32(0x7F7F), f32::from_bits(0x7F7F_0000));
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x7F7F_0000)), 0x7F7F);
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x7F7F_8001)), 0x7F80);
        // NaN narrows to a quiet NaN, never to inf.
        let h = f32_to_bf16_bits(f32::NAN);
        assert_eq!(h & 0x7F80, 0x7F80);
        assert_ne!(h & 0x007F, 0);
    }

    #[test]
    fn precision_kernels_match_scalar_on_every_tier() {
        // Odd lengths exercise the remainder tails; values span the
        // full finite range including subnormal-half territory, exact
        // halves, ties and negatives.
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 33, 64, 100, 129] {
            let mut r = Rng::new(n as u64 + 7000);
            let src: Vec<f32> = (0..n)
                .map(|i| match i % 7 {
                    0 => r.f32_range(-1.0, 1.0),
                    1 => r.f32_range(-70000.0, 70000.0), // overflows f16
                    2 => r.f32_range(-1e-6, 1e-6),       // subnormal halves
                    3 => (i as f32) * 0.25,              // exactly representable
                    4 => -0.0,
                    5 => r.f32_range(-1e30, 1e30), // tests bf16 range
                    _ => 1.0 + 2.0f32.powi(-11),   // f16 tie case
                })
                .collect();
            let mut want16 = vec![0u16; n];
            scalar::narrow_f16(&mut want16, &src);
            let mut wantb = vec![0u16; n];
            scalar::narrow_bf16(&mut wantb, &src);
            let mut want_w16 = vec![0.0f32; n];
            scalar::widen_f16(&mut want_w16, &want16);
            let mut want_wb = vec![0.0f32; n];
            scalar::widen_bf16(&mut want_wb, &wantb);
            for tier in supported_tiers() {
                let mut got = vec![0u16; n];
                narrow_f16_with(tier, &mut got, &src);
                assert_eq!(got, want16, "narrow_f16 {tier:?} n={n}");

                let mut got = vec![0u16; n];
                narrow_bf16_with(tier, &mut got, &src);
                assert_eq!(got, wantb, "narrow_bf16 {tier:?} n={n}");

                let mut got = vec![0.0f32; n];
                widen_f16_with(tier, &mut got, &want16);
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want_w16.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "widen_f16 {tier:?} n={n}"
                );

                let mut got = vec![0.0f32; n];
                widen_bf16_with(tier, &mut got, &wantb);
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want_wb.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "widen_bf16 {tier:?} n={n}"
                );

                for relu in [false, true] {
                    let mut want = vec![0u16; n];
                    scalar::store_bias_act_narrow_f16(&mut want, &src, -0.25, relu);
                    let mut got = vec![0u16; n];
                    store_bias_act_narrow_f16_with(tier, &mut got, &src, -0.25, relu);
                    assert_eq!(got, want, "sban_f16 {tier:?} n={n} relu={relu}");

                    let mut want = vec![0u16; n];
                    scalar::store_bias_act_narrow_bf16(&mut want, &src, -0.25, relu);
                    let mut got = vec![0u16; n];
                    store_bias_act_narrow_bf16_with(tier, &mut got, &src, -0.25, relu);
                    assert_eq!(got, want, "sban_bf16 {tier:?} n={n} relu={relu}");
                }
            }
        }
    }

    #[test]
    fn narrow_error_stays_within_documented_ulp_bounds() {
        // The accuracy-gate contract documented in ARCHITECTURE.md:
        // narrowing a finite in-range value loses at most half an ulp of
        // the storage format — relative error ≤ 2^-11 for f16 and
        // ≤ 2^-8 for bf16.
        let mut r = Rng::new(41);
        for _ in 0..4096 {
            let x = r.f32_range(-1000.0, 1000.0);
            let f16 = scalar::f16_bits_to_f32(scalar::f32_to_f16_bits(x));
            let bf = scalar::bf16_bits_to_f32(scalar::f32_to_bf16_bits(x));
            let ax = x.abs().max(2.0f32.powi(-14)); // below: absolute regime
            assert!(
                (f16 - x).abs() <= ax * 2.0f32.powi(-11),
                "f16 ulp bound: {x} -> {f16}"
            );
            assert!(
                (bf - x).abs() <= ax * 2.0f32.powi(-8),
                "bf16 ulp bound: {x} -> {bf}"
            );
        }
    }
}
