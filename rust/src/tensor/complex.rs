//! Minimal complex-f32 value type (no external num-complex dependency).

use std::ops::{Add, AddAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// A complex number with f32 parts. `#[repr(C)]` so slices of it can be
/// reinterpreted as interleaved `[re, im]` f32 pairs for FFT I/O.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
#[repr(C)]
pub struct Complex32 {
    /// Real part.
    pub re: f32,
    /// Imaginary part.
    pub im: f32,
}

impl Complex32 {
    /// 0 + 0i.
    pub const ZERO: Complex32 = Complex32 { re: 0.0, im: 0.0 };
    /// 1 + 0i.
    pub const ONE: Complex32 = Complex32 { re: 1.0, im: 0.0 };

    #[inline(always)]
    /// Complex number from parts.
    pub fn new(re: f32, im: f32) -> Self {
        Complex32 { re, im }
    }

    /// e^{iθ}.
    #[inline]
    pub fn cis(theta: f64) -> Self {
        Complex32 { re: theta.cos() as f32, im: theta.sin() as f32 }
    }

    #[inline(always)]
    /// Complex conjugate.
    pub fn conj(self) -> Self {
        Complex32 { re: self.re, im: -self.im }
    }

    #[inline(always)]
    /// Squared magnitude.
    pub fn norm_sqr(self) -> f32 {
        self.re * self.re + self.im * self.im
    }

    #[inline(always)]
    /// Magnitude.
    pub fn abs(self) -> f32 {
        self.norm_sqr().sqrt()
    }

    #[inline(always)]
    /// Multiply both parts by a real scalar.
    pub fn scale(self, s: f32) -> Self {
        Complex32 { re: self.re * s, im: self.im * s }
    }

    /// Fused multiply-accumulate: `self += a * b`. The hot op of the
    /// FFT-conv point-wise stage (PARALLEL-MAD in Algorithm 2).
    #[inline(always)]
    pub fn mad(&mut self, a: Complex32, b: Complex32) {
        self.re += a.re * b.re - a.im * b.im;
        self.im += a.re * b.im + a.im * b.re;
    }

    /// Multiply by ±i without a full complex multiply.
    #[inline(always)]
    pub fn mul_i(self) -> Self {
        Complex32 { re: -self.im, im: self.re }
    }

    #[inline(always)]
    /// Multiply by -i without a full complex multiply.
    pub fn mul_neg_i(self) -> Self {
        Complex32 { re: self.im, im: -self.re }
    }
}

impl Add for Complex32 {
    type Output = Complex32;
    #[inline(always)]
    fn add(self, o: Complex32) -> Complex32 {
        Complex32 { re: self.re + o.re, im: self.im + o.im }
    }
}

impl Sub for Complex32 {
    type Output = Complex32;
    #[inline(always)]
    fn sub(self, o: Complex32) -> Complex32 {
        Complex32 { re: self.re - o.re, im: self.im - o.im }
    }
}

impl Mul for Complex32 {
    type Output = Complex32;
    #[inline(always)]
    fn mul(self, o: Complex32) -> Complex32 {
        Complex32 {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl Neg for Complex32 {
    type Output = Complex32;
    #[inline(always)]
    fn neg(self) -> Complex32 {
        Complex32 { re: -self.re, im: -self.im }
    }
}

impl AddAssign for Complex32 {
    #[inline(always)]
    fn add_assign(&mut self, o: Complex32) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl SubAssign for Complex32 {
    #[inline(always)]
    fn sub_assign(&mut self, o: Complex32) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl MulAssign for Complex32 {
    #[inline(always)]
    fn mul_assign(&mut self, o: Complex32) {
        *self = *self * o;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: Complex32, b: Complex32) -> bool {
        (a.re - b.re).abs() < 1e-6 && (a.im - b.im).abs() < 1e-6
    }

    #[test]
    fn arithmetic() {
        let a = Complex32::new(1.0, 2.0);
        let b = Complex32::new(3.0, -1.0);
        assert!(close(a + b, Complex32::new(4.0, 1.0)));
        assert!(close(a - b, Complex32::new(-2.0, 3.0)));
        // (1+2i)(3-i) = 3 - i + 6i - 2i² = 5 + 5i
        assert!(close(a * b, Complex32::new(5.0, 5.0)));
    }

    #[test]
    fn mad_matches_mul_add() {
        let mut acc = Complex32::new(0.5, -0.5);
        let a = Complex32::new(1.5, 2.5);
        let b = Complex32::new(-0.5, 1.0);
        let expect = acc + a * b;
        acc.mad(a, b);
        assert!(close(acc, expect));
    }

    #[test]
    fn cis_unit_circle() {
        let z = Complex32::cis(std::f64::consts::FRAC_PI_2);
        assert!(close(z, Complex32::new(0.0, 1.0)));
        assert!((Complex32::cis(1.234).abs() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn mul_i_identities() {
        let a = Complex32::new(2.0, 3.0);
        assert!(close(a.mul_i(), a * Complex32::new(0.0, 1.0)));
        assert!(close(a.mul_neg_i(), a * Complex32::new(0.0, -1.0)));
    }

    #[test]
    fn conj_and_norm() {
        let a = Complex32::new(3.0, 4.0);
        assert_eq!(a.abs(), 5.0);
        assert!(close(a * a.conj(), Complex32::new(25.0, 0.0)));
    }
}
