//! 5D tensors (batch × feature-maps × x × y × z) in f32 and complex-f32.
//!
//! The paper treats a convolutional layer's input as a 5D tensor of size
//! `S × f × n_x × n_y × n_z` (§IV); all layer primitives here operate on
//! these types. Layout is row-major with **z contiguous** (the least
//! significant dimension), matching the batched-FFT scheme of §III.C.
//!
//! Every allocation is registered with [`crate::memory`] so the Table II
//! memory model can be validated against measured peaks.

mod complex;
mod shape;
mod tensor5;

pub use complex::Complex32;
pub use shape::{Shape5, Vec3};
pub use tensor5::{CTensor5, Tensor5};
