//! Shape types: 3D extents and the 5D `S × f × x × y × z` tensor shape.

/// 3D extent (x, y, z).
pub type Vec3 = [usize; 3];

/// Element-wise ops on [`Vec3`] used by shape propagation (Table I).
#[allow(dead_code)]
pub trait Vec3Ext {
    /// Product of the three extents.
    fn volume(&self) -> usize;
    /// Element-wise sum.
    fn add(&self, o: Vec3) -> Vec3;
    /// Element-wise difference.
    fn sub(&self, o: Vec3) -> Vec3;
    /// Element-wise integer division.
    fn div(&self, o: Vec3) -> Vec3;
    /// Element-wise product.
    fn mul(&self, o: Vec3) -> Vec3;
    /// `[1, 1, 1]`.
    fn one() -> Vec3 {
        [1, 1, 1]
    }
    fn splat(v: usize) -> Vec3 {
        [v, v, v]
    }
    fn divisible_by(&self, o: Vec3) -> bool;
}

impl Vec3Ext for Vec3 {
    fn volume(&self) -> usize {
        self[0] * self[1] * self[2]
    }
    fn add(&self, o: Vec3) -> Vec3 {
        [self[0] + o[0], self[1] + o[1], self[2] + o[2]]
    }
    fn sub(&self, o: Vec3) -> Vec3 {
        [self[0] - o[0], self[1] - o[1], self[2] - o[2]]
    }
    fn div(&self, o: Vec3) -> Vec3 {
        [self[0] / o[0], self[1] / o[1], self[2] / o[2]]
    }
    fn mul(&self, o: Vec3) -> Vec3 {
        [self[0] * o[0], self[1] * o[1], self[2] * o[2]]
    }
    fn divisible_by(&self, o: Vec3) -> bool {
        self[0] % o[0] == 0 && self[1] % o[1] == 0 && self[2] % o[2] == 0
    }
}

/// Shape of a 5D tensor: batch `s`, feature maps `f`, spatial `x,y,z`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Shape5 {
    /// Batch (S).
    pub s: usize,
    /// Feature maps (f).
    pub f: usize,
    /// Spatial extent x.
    pub x: usize,
    /// Spatial extent y.
    pub y: usize,
    /// Spatial extent z.
    pub z: usize,
}

impl Shape5 {
    /// Shape from the five extents.
    pub fn new(s: usize, f: usize, x: usize, y: usize, z: usize) -> Self {
        Shape5 { s, f, x, y, z }
    }

    /// Shape from batch, maps and a spatial [`Vec3`].
    pub fn from_spatial(s: usize, f: usize, n: Vec3) -> Self {
        Shape5 { s, f, x: n[0], y: n[1], z: n[2] }
    }

    /// Spatial extent as a [`Vec3`].
    pub fn spatial(&self) -> Vec3 {
        [self.x, self.y, self.z]
    }

    /// Voxels in one image.
    pub fn image_len(&self) -> usize {
        self.x * self.y * self.z
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.s * self.f * self.image_len()
    }

    /// Whether any extent is zero.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flat offset of element (s, f, x, y, z).
    #[inline(always)]
    pub fn idx(&self, s: usize, f: usize, x: usize, y: usize, z: usize) -> usize {
        debug_assert!(s < self.s && f < self.f && x < self.x && y < self.y && z < self.z);
        (((s * self.f + f) * self.x + x) * self.y + y) * self.z + z
    }

    /// Flat offset of the start of image (s, f).
    #[inline(always)]
    pub fn image_offset(&self, s: usize, f: usize) -> usize {
        (s * self.f + f) * self.image_len()
    }

    /// Bytes for f32 storage.
    pub fn bytes_f32(&self) -> u64 {
        self.len() as u64 * 4
    }

    /// Bytes for complex-f32 storage.
    pub fn bytes_c32(&self) -> u64 {
        self.len() as u64 * 8
    }
}

impl std::fmt::Display for Shape5 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}x{}x{}", self.s, self.f, self.x, self.y, self.z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idx_is_row_major_z_contiguous() {
        let sh = Shape5::new(2, 3, 4, 5, 6);
        assert_eq!(sh.idx(0, 0, 0, 0, 0), 0);
        assert_eq!(sh.idx(0, 0, 0, 0, 1), 1);
        assert_eq!(sh.idx(0, 0, 0, 1, 0), 6);
        assert_eq!(sh.idx(0, 0, 1, 0, 0), 30);
        assert_eq!(sh.idx(0, 1, 0, 0, 0), 120);
        assert_eq!(sh.idx(1, 0, 0, 0, 0), 360);
        assert_eq!(sh.len(), 720);
    }

    #[test]
    fn idx_covers_all_without_collision() {
        let sh = Shape5::new(2, 2, 3, 3, 3);
        let mut seen = vec![false; sh.len()];
        for s in 0..sh.s {
            for f in 0..sh.f {
                for x in 0..sh.x {
                    for y in 0..sh.y {
                        for z in 0..sh.z {
                            let i = sh.idx(s, f, x, y, z);
                            assert!(!seen[i]);
                            seen[i] = true;
                        }
                    }
                }
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn vec3_ops() {
        let a: Vec3 = [6, 8, 10];
        let b: Vec3 = [2, 4, 5];
        assert_eq!(a.volume(), 480);
        assert_eq!(a.add(b), [8, 12, 15]);
        assert_eq!(a.sub(b), [4, 4, 5]);
        assert_eq!(a.div(b), [3, 2, 2]);
        assert_eq!(a.mul(b), [12, 32, 50]);
        assert!(a.divisible_by(b));
        assert!(!a.divisible_by([4, 4, 4]));
    }

    #[test]
    fn image_offset_matches_idx() {
        let sh = Shape5::new(3, 4, 2, 2, 2);
        for s in 0..3 {
            for f in 0..4 {
                assert_eq!(sh.image_offset(s, f), sh.idx(s, f, 0, 0, 0));
            }
        }
    }
}
