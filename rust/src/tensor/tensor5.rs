//! Owned 5D tensors with memory-ledger registration.

use super::complex::Complex32;
use super::shape::Shape5;
use crate::memory;
use crate::util::prng::Rng;

/// Real f32 5D tensor. Allocation/deallocation is registered with the
/// process memory ledger so Table II peaks can be measured.
pub struct Tensor5 {
    shape: Shape5,
    data: Vec<f32>,
}

impl Tensor5 {
    /// Zero-initialised tensor.
    pub fn zeros(shape: Shape5) -> Self {
        memory::alloc(shape.bytes_f32());
        Tensor5 { shape, data: vec![0.0; shape.len()] }
    }

    /// Tensor filled with uniform random values in [-1, 1).
    pub fn random(shape: Shape5, seed: u64) -> Self {
        let mut t = Self::zeros(shape);
        let mut rng = Rng::new(seed);
        rng.fill_uniform(&mut t.data);
        t
    }

    /// Build from existing data (length must match the shape).
    pub fn from_vec(shape: Shape5, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), shape.len(), "data length mismatch for {shape}");
        memory::alloc(shape.bytes_f32());
        Tensor5 { shape, data }
    }

    /// Build from a buffer drawn from an [`crate::exec::Arena`]. The
    /// arena already registered the bytes with the ledger when it handed
    /// the buffer out, so this does *not* call `memory::alloc`; `Drop`
    /// still frees, which matches the arena's accounting (a dropped
    /// arena tensor genuinely releases its memory, a retired one hands
    /// the registered bytes back through `Arena::put_f32`).
    pub(crate) fn from_arena(shape: Shape5, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), shape.len(), "arena buffer length mismatch for {shape}");
        Tensor5 { shape, data }
    }

    /// Decompose into shape + backing store without running `Drop` (the
    /// ledger keeps the bytes registered; the arena's `put` releases
    /// them). Crate-internal: only `exec::Arena` retires tensors.
    pub(crate) fn into_raw(self) -> (Shape5, Vec<f32>) {
        let mut me = std::mem::ManuallyDrop::new(self);
        (me.shape, std::mem::take(&mut me.data))
    }

    /// The tensor's shape.
    pub fn shape(&self) -> Shape5 {
        self.shape
    }

    /// Flat element slice (s-major, z-minor).
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat element slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// One image (s, f) as a contiguous slice of `x*y*z` voxels.
    pub fn image(&self, s: usize, f: usize) -> &[f32] {
        let o = self.shape.image_offset(s, f);
        &self.data[o..o + self.shape.image_len()]
    }

    /// Mutable image (s, f) as a contiguous slice.
    pub fn image_mut(&mut self, s: usize, f: usize) -> &mut [f32] {
        let o = self.shape.image_offset(s, f);
        let l = self.shape.image_len();
        &mut self.data[o..o + l]
    }

    #[inline(always)]
    /// Element at (s, f, x, y, z).
    pub fn at(&self, s: usize, f: usize, x: usize, y: usize, z: usize) -> f32 {
        self.data[self.shape.idx(s, f, x, y, z)]
    }

    #[inline(always)]
    /// Set the element at (s, f, x, y, z).
    pub fn set(&mut self, s: usize, f: usize, x: usize, y: usize, z: usize, v: f32) {
        let i = self.shape.idx(s, f, x, y, z);
        self.data[i] = v;
    }

    /// Reinterpret the batch/feature dims: same data, new (s, f) split.
    /// Used by MPF layers, which multiply the batch dimension (§V) — the
    /// storage is identical, only the bookkeeping changes.
    pub fn reshape_batch(mut self, s: usize, f: usize) -> Tensor5 {
        assert_eq!(
            s * f,
            self.shape.s * self.shape.f,
            "reshape_batch must preserve s*f ({}*{} -> {s}*{f})",
            self.shape.s,
            self.shape.f
        );
        self.shape = Shape5 { s, f, ..self.shape };
        self
    }

    /// Max |a - b| against another tensor of identical shape.
    pub fn max_abs_diff(&self, other: &Tensor5) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Apply ReLU in place (the paper's transfer function).
    pub fn relu_inplace(&mut self) {
        for v in self.data.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Deep copy.
    pub fn clone_tensor(&self) -> Tensor5 {
        Tensor5::from_vec(self.shape, self.data.clone())
    }
}

impl Drop for Tensor5 {
    fn drop(&mut self) {
        memory::free(self.shape.bytes_f32());
    }
}

impl std::fmt::Debug for Tensor5 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Tensor5[{}]", self.shape)
    }
}

/// Complex f32 5D tensor (FFT-domain images). The spatial shape is the
/// *transformed* extent — e.g. `(x, y, z/2+1)` after a real-to-complex
/// transform along z.
pub struct CTensor5 {
    shape: Shape5,
    data: Vec<Complex32>,
}

impl CTensor5 {
    /// Zeroed complex tensor (ledger-registered).
    pub fn zeros(shape: Shape5) -> Self {
        memory::alloc(shape.bytes_c32());
        CTensor5 { shape, data: vec![Complex32::ZERO; shape.len()] }
    }

    /// The tensor's shape.
    pub fn shape(&self) -> Shape5 {
        self.shape
    }

    /// Flat element slice (s-major, z-minor).
    pub fn data(&self) -> &[Complex32] {
        &self.data
    }

    /// Mutable flat element slice.
    pub fn data_mut(&mut self) -> &mut [Complex32] {
        &mut self.data
    }

    /// Image (s, f) as a contiguous slice.
    pub fn image(&self, s: usize, f: usize) -> &[Complex32] {
        let o = self.shape.image_offset(s, f);
        &self.data[o..o + self.shape.image_len()]
    }

    /// Mutable image (s, f) as a contiguous slice.
    pub fn image_mut(&mut self, s: usize, f: usize) -> &mut [Complex32] {
        let o = self.shape.image_offset(s, f);
        let l = self.shape.image_len();
        &mut self.data[o..o + l]
    }

    /// Zero all elements (reuse without realloc).
    pub fn clear(&mut self) {
        self.data.fill(Complex32::ZERO);
    }
}

impl Drop for CTensor5 {
    fn drop(&mut self) {
        memory::free(self.shape.bytes_c32());
    }
}

impl std::fmt::Debug for CTensor5 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "CTensor5[{}]", self.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_set_get() {
        let mut t = Tensor5::zeros(Shape5::new(1, 2, 3, 3, 3));
        assert_eq!(t.at(0, 1, 2, 2, 2), 0.0);
        t.set(0, 1, 2, 2, 2, 7.5);
        assert_eq!(t.at(0, 1, 2, 2, 2), 7.5);
    }

    #[test]
    fn memory_ledger_tracks_tensors() {
        let base = memory::current();
        {
            let _t = Tensor5::zeros(Shape5::new(1, 1, 10, 10, 10));
            assert_eq!(memory::current(), base + 4000);
        }
        assert_eq!(memory::current(), base);
    }

    #[test]
    fn image_slice_is_contiguous() {
        let sh = Shape5::new(2, 2, 2, 2, 2);
        let mut t = Tensor5::zeros(sh);
        t.set(1, 0, 0, 0, 0, 1.0);
        t.set(1, 0, 1, 1, 1, 2.0);
        let img = t.image(1, 0);
        assert_eq!(img.len(), 8);
        assert_eq!(img[0], 1.0);
        assert_eq!(img[7], 2.0);
    }

    #[test]
    fn reshape_batch_preserves_data() {
        let sh = Shape5::new(1, 4, 2, 2, 2);
        let t = Tensor5::random(sh, 1);
        let before = t.data().to_vec();
        let t = t.reshape_batch(2, 2);
        assert_eq!(t.shape(), Shape5::new(2, 2, 2, 2, 2));
        assert_eq!(t.data(), &before[..]);
    }

    #[test]
    #[should_panic(expected = "reshape_batch")]
    fn reshape_batch_rejects_bad_split() {
        let t = Tensor5::zeros(Shape5::new(1, 4, 2, 2, 2));
        let _ = t.reshape_batch(3, 2);
    }

    #[test]
    fn relu_clamps_negatives() {
        let mut t = Tensor5::from_vec(
            Shape5::new(1, 1, 1, 1, 4),
            vec![-1.0, 2.0, -3.0, 0.5],
        );
        t.relu_inplace();
        assert_eq!(t.data(), &[0.0, 2.0, 0.0, 0.5]);
    }

    #[test]
    fn random_is_deterministic() {
        let a = Tensor5::random(Shape5::new(1, 1, 4, 4, 4), 42);
        let b = Tensor5::random(Shape5::new(1, 1, 4, 4, 4), 42);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn ctensor_roundtrip() {
        let mut c = CTensor5::zeros(Shape5::new(1, 1, 2, 2, 2));
        c.data_mut()[3] = Complex32::new(1.0, -1.0);
        assert_eq!(c.image(0, 0)[3], Complex32::new(1.0, -1.0));
        c.clear();
        assert_eq!(c.data()[3], Complex32::ZERO);
    }
}
