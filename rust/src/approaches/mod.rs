//! The four ZNNi execution approaches compared in Figs 5/7 and Table V:
//! CPU-only, GPU-only, GPU + host RAM, and the CPU–GPU pipeline.
//!
//! Each function plans under the appropriate memory constraint, runs
//! real patches, and reports measured compute seconds plus *modelled*
//! host↔device transfer seconds (the simulated device's PCIe cost —
//! kept separate so reports stay honest about what is measured vs
//! modelled).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::conv::{Activation, Weights};
use crate::coordinator::{Coordinator, InferenceRequest};
use crate::device::Device;
use crate::exec::ExecCtx;
use crate::layers::{ConvLayer, LayerPrimitive, MpfLayer, Placement};
use crate::memory::model::{ConvAlgo, ConvDims};
use crate::net::{LayerSpec, NetSpec, PoolingMode};
use crate::optimizer::{
    compile, make_weights, search, search_serving, search_serving_multi, CostModel, PlanLayer,
    SearchSpace,
};
use crate::pipeline::{best_theta, Pipeline};
use crate::server::tenants::{Tenant, TenantServer};
use crate::server::{RejectReason, Server, ServerConfig, ServingLoad};
use crate::tensor::{Shape5, Tensor5};
use crate::util::pool::TaskPool;

/// Which §VI–VII execution mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Approach {
    /// §VI CPU-only: CPU primitives within host RAM.
    CpuOnly,
    /// §VI GPU-only: GPU primitives within device RAM.
    GpuOnly,
    /// §VII.A-B GPU + host RAM via sub-layer decomposition.
    GpuHostRam,
    /// §VII.C CPU-GPU pipeline.
    CpuGpu,
}

impl Approach {
    /// All four approaches, in Table V order.
    pub const ALL: [Approach; 4] =
        [Approach::CpuOnly, Approach::GpuOnly, Approach::GpuHostRam, Approach::CpuGpu];

    /// Display name (Table V row).
    pub fn name(&self) -> &'static str {
        match self {
            Approach::CpuOnly => "CPU-Only",
            Approach::GpuOnly => "GPU-Only",
            Approach::GpuHostRam => "GPU + host RAM",
            Approach::CpuGpu => "CPU-GPU",
        }
    }
}

/// Outcome of running one approach on one net.
#[derive(Clone, Debug)]
pub struct ApproachResult {
    /// Which approach produced this result.
    pub approach: Approach,
    /// Chosen cubic input extent.
    pub input_extent: usize,
    /// Output voxels produced per patch (α·S·x'·y'·z').
    pub out_voxels: u64,
    /// Measured compute seconds per patch.
    pub compute_secs: f64,
    /// Modelled transfer seconds per patch (simulated PCIe).
    pub transfer_secs: f64,
    /// Peak Table II memory of the plan.
    pub memory_bytes: u64,
}

impl ApproachResult {
    /// Measured throughput: output voxels per (compute + transfer) second.
    pub fn throughput(&self) -> f64 {
        self.out_voxels as f64 / (self.compute_secs + self.transfer_secs)
    }
}

fn out_voxels(sh: &Shape5) -> u64 {
    (sh.s * sh.x * sh.y * sh.z) as u64
}

/// §VI CPU-only: optimizer plan over CPU primitives within host RAM.
pub fn run_cpu_only(
    net: &NetSpec,
    weights: &[Arc<Weights>],
    host: &Device,
    cm: &CostModel,
    pool: &TaskPool,
    max_extent: usize,
) -> Result<ApproachResult> {
    let mut space = SearchSpace::cpu_only(host.clone(), max_extent);
    space.max_candidates = 6;
    let plan = search(net, &space, cm).ok_or_else(|| anyhow!("no feasible CPU plan"))?;
    let cp = compile(net, &plan, weights)?;
    let mut ctx = cp.make_ctx(pool)?;
    let input = Tensor5::random(plan.input, 1);
    let t0 = Instant::now();
    let out = cp.run(input, &mut ctx);
    Ok(ApproachResult {
        approach: Approach::CpuOnly,
        input_extent: plan.input.x,
        out_voxels: out_voxels(&out.shape()),
        compute_secs: t0.elapsed().as_secs_f64(),
        transfer_secs: 0.0,
        memory_bytes: plan.est_memory,
    })
}

/// §VI GPU-only: GPU primitives within device RAM; input uploaded and
/// output downloaded once (modelled).
pub fn run_gpu_only(
    net: &NetSpec,
    weights: &[Arc<Weights>],
    gpu: &Device,
    cm: &CostModel,
    pool: &TaskPool,
    max_extent: usize,
) -> Result<ApproachResult> {
    let mut space = SearchSpace::gpu_only(gpu.clone(), max_extent);
    space.max_candidates = 6;
    let plan = search(net, &space, cm).ok_or_else(|| anyhow!("no feasible GPU plan"))?;
    let cp = compile(net, &plan, weights)?;
    let mut ctx = cp.make_ctx(pool)?;
    let input = Tensor5::random(plan.input, 1);
    let in_bytes = input.shape().bytes_f32();
    let t0 = Instant::now();
    let out = cp.run(input, &mut ctx);
    let compute = t0.elapsed().as_secs_f64();
    let transfer = gpu.transfer_secs(in_bytes + out.shape().bytes_f32());
    Ok(ApproachResult {
        approach: Approach::GpuOnly,
        input_extent: plan.input.x,
        out_voxels: out_voxels(&out.shape()),
        compute_secs: compute,
        transfer_secs: transfer,
        memory_bytes: plan.est_memory,
    })
}

/// §VII.A–B GPU + host RAM: tensors live in host RAM; each conv layer
/// is decomposed into device-sized sub-layers; MPF runs on the CPU
/// (the paper found device MPF not worth the transfers).
pub fn run_gpu_host_ram(
    net: &NetSpec,
    weights: &[Arc<Weights>],
    host: &Device,
    gpu: &Device,
    cm: &CostModel,
    pool: &TaskPool,
    max_extent: usize,
) -> Result<ApproachResult> {
    // Plan sizes against HOST ram (that is the point of the approach),
    // with per-layer feasibility = decomposable onto the device.
    let modes = vec![PoolingMode::Mpf; net.pool_count()];
    let mut chosen: Option<usize> = None;
    let mut extents = net.valid_extents(1, max_extent, &modes);
    extents.reverse();
    'outer: for n in extents {
        let input = Shape5::new(1, net.f_in, n, n, n);
        let Ok(shapes) = net.shapes(input, &modes) else { continue };
        // Host must hold input+output of the biggest layer; every conv
        // must decompose onto the device.
        let mut cur = input;
        for (li, l) in net.layers.iter().enumerate() {
            if cur.bytes_f32() + shapes[li].bytes_f32() > host.ram_bytes {
                continue 'outer;
            }
            if let LayerSpec::Conv { f_out, k } = l {
                let d = ConvDims {
                    s: cur.s,
                    f_in: net.f_in_at(li),
                    f_out: *f_out,
                    n: cur.spatial(),
                    k: *k,
                };
                if crate::sublayer::decompose(&d, gpu, cm).is_none() {
                    continue 'outer;
                }
            }
            cur = shapes[li];
        }
        chosen = Some(n);
        break;
    }
    let n = chosen.ok_or_else(|| anyhow!("no feasible GPU+host plan"))?;
    let input_sh = Shape5::new(1, net.f_in, n, n, n);
    let mut ctx = ExecCtx::new(pool);
    let mut cur = Tensor5::random(input_sh, 1);
    let mut wi = 0;
    let mut compute = 0.0f64;
    let mut transfer_bytes = 0u64;
    let mut peak_mem = 0u64;
    for l in &net.layers {
        match l {
            LayerSpec::Conv { f_out, k } => {
                let ish = cur.shape();
                let d = ConvDims {
                    s: ish.s,
                    f_in: ish.f,
                    f_out: *f_out,
                    n: ish.spatial(),
                    k: *k,
                };
                let plan = crate::sublayer::decompose(&d, gpu, cm).unwrap();
                peak_mem = peak_mem.max(ish.bytes_f32() * 2);
                let t0 = Instant::now();
                let (out, moved) =
                    crate::sublayer::execute(&cur, &weights[wi], &plan, Activation::Relu, &mut ctx);
                compute += t0.elapsed().as_secs_f64();
                transfer_bytes += moved;
                ctx.retire(cur);
                cur = out;
                wi += 1;
            }
            LayerSpec::Pool { p } => {
                let t0 = Instant::now();
                let out = crate::pool::mpf_forward(&cur, *p, &mut ctx);
                compute += t0.elapsed().as_secs_f64();
                ctx.retire(cur);
                cur = out;
            }
        }
    }
    Ok(ApproachResult {
        approach: Approach::GpuHostRam,
        input_extent: n,
        out_voxels: out_voxels(&cur.shape()),
        compute_secs: compute,
        transfer_secs: gpu.transfer_secs(transfer_bytes),
        memory_bytes: peak_mem,
    })
}

/// §VII.C CPU–GPU pipeline: first θ layers on CPU primitives, rest on
/// GPU primitives, θ chosen by the cost model, measured over a stream
/// of patches so the overlap shows up in wall-clock.
pub fn run_cpu_gpu(
    net: &NetSpec,
    weights: &[Arc<Weights>],
    host: &Device,
    gpu: &Device,
    cm: &CostModel,
    pool: &TaskPool,
    max_extent: usize,
    stream_len: usize,
) -> Result<ApproachResult> {
    // Plan the CPU side (for sizes) and the GPU side per layer.
    let mut cpu_space = SearchSpace::cpu_only(host.clone(), max_extent);
    cpu_space.max_candidates = 4;
    let cpu_plan = search(net, &cpu_space, cm).ok_or_else(|| anyhow!("no CPU plan"))?;
    let mut gpu_space = SearchSpace::gpu_only(gpu.clone(), max_extent);
    gpu_space.min_extent = cpu_plan.input.x;
    gpu_space.max_extent = cpu_plan.input.x;
    let gpu_plan = search(net, &gpu_space, cm);

    // Per-layer estimated times on each device at this input size.
    let modes = cpu_plan.modes();
    let shapes = net.shapes(cpu_plan.input, &modes)?;
    let mut cpu_secs = Vec::new();
    let mut gpu_secs = Vec::new();
    let mut cur = cpu_plan.input;
    let mut pool_i = 0;
    for (li, l) in net.layers.iter().enumerate() {
        match l {
            LayerSpec::Conv { f_out, k } => {
                let d = ConvDims {
                    s: cur.s,
                    f_in: net.f_in_at(li),
                    f_out: *f_out,
                    n: cur.spatial(),
                    k: *k,
                };
                let cpu_algos =
                    [ConvAlgo::DirectMkl, ConvAlgo::FftDataParallel, ConvAlgo::FftTaskParallel];
                let best_cpu = cpu_algos
                    .iter()
                    .map(|&a| cm.conv_secs(a, &d, host))
                    .fold(f64::INFINITY, f64::min);
                let best_gpu = [ConvAlgo::GpuDensePrecomp, ConvAlgo::GpuFft]
                    .iter()
                    .map(|&a| cm.conv_secs(a, &d, gpu))
                    .fold(f64::INFINITY, f64::min);
                cpu_secs.push(best_cpu);
                gpu_secs.push(best_gpu);
            }
            LayerSpec::Pool { p } => {
                let mpf = modes[pool_i] == PoolingMode::Mpf;
                let t = cm.pool_secs(cur.s, cur.f, cur.spatial(), *p, mpf);
                pool_i += 1;
                cpu_secs.push(t);
                gpu_secs.push(t); // MPF stays on CPU either way (§VII.B)
            }
        }
        cur = shapes[li];
    }
    let theta = best_theta(&cpu_secs, &gpu_secs).clamp(1, net.layers.len());

    // Build the stack: head = CPU plan primitives, tail = GPU.
    let mut prims: Vec<Box<dyn LayerPrimitive>> = Vec::new();
    let mut wi = 0;
    for (li, l) in net.layers.iter().enumerate() {
        match l {
            LayerSpec::Conv { .. } => {
                let algo = if li < theta {
                    match cpu_plan.layers[li] {
                        PlanLayer::Conv { algo, .. } => algo,
                        _ => ConvAlgo::FftTaskParallel,
                    }
                } else {
                    match gpu_plan.as_ref().map(|p| &p.layers[li]) {
                        Some(PlanLayer::Conv { algo, .. }) => *algo,
                        _ => ConvAlgo::GpuFft,
                    }
                };
                prims.push(Box::new(ConvLayer::new(weights[wi].clone(), algo, Activation::Relu)));
                wi += 1;
            }
            LayerSpec::Pool { p } => {
                prims.push(Box::new(MpfLayer { window: *p, placement: Placement::Cpu }));
            }
        }
    }
    let pipe = Pipeline::split(prims, theta);

    // Stream patches; modelled transfer = the θ-boundary tensor + final
    // output per patch.
    let boundary_bytes = if theta == 0 {
        cpu_plan.input.bytes_f32()
    } else {
        shapes[theta - 1].bytes_f32()
    };
    let out_bytes = shapes.last().unwrap().bytes_f32();
    let inputs: Vec<Tensor5> =
        (0..stream_len.max(1)).map(|i| Tensor5::random(cpu_plan.input, i as u64)).collect();
    let t0 = Instant::now();
    let outs = pipe.run_stream(inputs, pool);
    let wall = t0.elapsed().as_secs_f64();
    let per_patch = wall / outs.len() as f64;
    let vox = out_voxels(&outs[0].shape());
    Ok(ApproachResult {
        approach: Approach::CpuGpu,
        input_extent: cpu_plan.input.x,
        out_voxels: vox,
        compute_secs: per_patch,
        transfer_secs: gpu.transfer_secs(boundary_bytes + out_bytes),
        memory_bytes: cpu_plan.est_memory,
    })
}

/// Outcome of the closed-loop serving harness ([`run_server`]).
#[derive(Clone, Debug)]
pub struct ServerRunResult {
    /// The serving config the optimizer chose.
    pub config: ServerConfig,
    /// Per-batch dispatch overhead (seconds) the serving-config search
    /// charged — measured when the cost model came from
    /// [`CostModel::calibrate_full`], otherwise the default constant.
    pub dispatch_overhead_secs: f64,
    /// Requests completed through the batched server.
    pub requests: u64,
    /// Dense output voxels produced by the batched server.
    pub voxels: u64,
    /// Wall seconds of the batched measurement window.
    pub wall_secs: f64,
    /// Submits rejected by backpressure during the window.
    pub rejected: u64,
    /// Requests whose deadline expired in the queue.
    pub expired: u64,
    /// Closed-loop requests that ended in a non-backpressure rejection
    /// or a serve error — nonzero means the throughput numbers cover
    /// fewer requests than offered.
    pub failed: u64,
    /// Median request latency.
    pub p50_latency: Duration,
    /// 99th-percentile request latency.
    pub p99_latency: Duration,
    /// Mean requests per dispatched batch.
    pub batch_occupancy: f64,
    /// Serial reference: one request per `Coordinator::serve` call.
    pub serial_voxels: u64,
    /// Wall seconds of the serial reference window.
    pub serial_wall_secs: f64,
}

impl ServerRunResult {
    /// Batched-server throughput (voxels/s).
    pub fn throughput(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.voxels as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Serial-coordinator throughput on the same request stream.
    pub fn serial_throughput(&self) -> f64 {
        if self.serial_wall_secs > 0.0 {
            self.serial_voxels as f64 / self.serial_wall_secs
        } else {
            0.0
        }
    }
}

/// Serving throughput harness: search plan + [`ServerConfig`] in one
/// call ([`search_serving`]), measure a **serial** coordinator on the
/// request stream (one request per serve call, warm arenas), then start
/// the sharded batched [`Server`] and drive it with `load.clients`
/// closed-loop load-generator threads (submit → wait → repeat,
/// retrying briefly on backpressure) over the same stream. Both sides
/// are warmed before their measurement window.
///
/// Pass a [`CostModel::calibrate_full`]-calibrated (or
/// [`CostModel::load_profile`]-loaded) cost model to make the config
/// search use this machine's measured rates and dispatch overhead; an
/// uncalibrated model falls back to the static defaults.
pub fn run_server(
    net: &NetSpec,
    weights: &[Arc<Weights>],
    host: &Device,
    cm: &CostModel,
    pool: Arc<TaskPool>,
    max_extent: usize,
    load: &ServingLoad,
    rounds: usize,
) -> Result<ServerRunResult> {
    let mut space = SearchSpace::cpu_only(host.clone(), max_extent);
    space.max_candidates = 4;
    let (plan, cfg) =
        search_serving(net, &space, cm, load).ok_or_else(|| anyhow!("no feasible serving plan"))?;
    let n = load.volume_extent;
    let rounds = rounds.max(1);
    let total = load.clients.max(1) * rounds;
    let mk = |seed: u64| Tensor5::random(Shape5::new(1, net.f_in, n, n, n), seed);

    // --- serial reference: same stream, one request per serve call,
    // with the whole machine's workers (fair comparison) ---
    let mut serial = Coordinator::new(net.clone(), compile(net, &plan, weights)?)?;
    serial.workers = pool.workers();
    serial.serve(vec![InferenceRequest { id: u64::MAX, volume: mk(9000) }], &pool)?;
    let t0 = Instant::now();
    let mut serial_voxels = 0u64;
    for i in 0..total {
        let (r, _) =
            serial.serve(vec![InferenceRequest { id: i as u64, volume: mk(i as u64) }], &pool)?;
        serial_voxels += r[0].voxels;
    }
    let serial_wall_secs = t0.elapsed().as_secs_f64();

    // --- batched server on the same stream ---
    let server = Server::start(net.clone(), compile(net, &plan, weights)?, cfg.clone(), pool)?;
    // Warm every shard's arenas (spread by round-robin + stealing).
    for i in 0..cfg.shards {
        let t = server
            .submit(mk(9100 + i as u64))
            .map_err(|r| anyhow!("warmup rejected: {:?}", r.reason))?;
        t.wait().map_err(|e| anyhow!("warmup failed: {e}"))?;
    }
    let voxels = AtomicU64::new(0);
    let served = AtomicU64::new(0);
    let failed = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..load.clients.max(1) {
            let server = &server;
            let voxels = &voxels;
            let served = &served;
            let failed = &failed;
            let mk = &mk;
            s.spawn(move || {
                for r in 0..rounds {
                    let mut vol = mk((c * rounds + r) as u64);
                    loop {
                        match server.submit(vol) {
                            Ok(t) => {
                                match t.wait() {
                                    Ok(resp) => {
                                        voxels.fetch_add(resp.voxels, Ordering::SeqCst);
                                        served.fetch_add(1, Ordering::SeqCst);
                                    }
                                    Err(_) => {
                                        failed.fetch_add(1, Ordering::SeqCst);
                                    }
                                }
                                break;
                            }
                            Err(rej) => match rej.reason {
                                RejectReason::QueueFull { .. } => {
                                    // Backpressure: brief pause, retry.
                                    vol = rej.volume;
                                    std::thread::sleep(Duration::from_micros(200));
                                }
                                _ => {
                                    failed.fetch_add(1, Ordering::SeqCst);
                                    break;
                                }
                            },
                        }
                    }
                }
            });
        }
    });
    let wall_secs = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    Ok(ServerRunResult {
        config: cfg,
        dispatch_overhead_secs: cm.dispatch_overhead_secs,
        requests: served.load(Ordering::SeqCst),
        voxels: voxels.load(Ordering::SeqCst),
        wall_secs,
        rejected: m.rejected,
        expired: m.expired,
        failed: failed.load(Ordering::SeqCst),
        p50_latency: m.p50_latency,
        p99_latency: m.p99_latency,
        batch_occupancy: m.batch_occupancy(),
        serial_voxels,
        serial_wall_secs,
    })
}

/// Per-tenant slice of a [`run_server_multi`] measurement window.
#[derive(Clone, Debug)]
pub struct TenantRunResult {
    /// Tenant id (the net name).
    pub name: String,
    /// SWRR dispatch weight the multi-tenant search assigned.
    pub weight: u32,
    /// Admission quota (bytes of queued + in-flight requests).
    pub quota_bytes: u64,
    /// Closed-loop requests this tenant completed.
    pub requests: u64,
    /// Dense output voxels this tenant produced.
    pub voxels: u64,
    /// Submits the server rejected for this tenant (all reasons,
    /// including backpressure retries the closed loop absorbed).
    pub rejected: u64,
    /// Requests whose deadline expired in this tenant's queues.
    pub expired: u64,
    /// Non-backpressure failures in this tenant's closed loop.
    pub failed: u64,
    /// Median request latency for this tenant.
    pub p50_latency: Duration,
    /// 99th-percentile request latency for this tenant.
    pub p99_latency: Duration,
}

/// Outcome of the multi-tenant closed-loop harness
/// ([`run_server_multi`]). All tenants share one measurement window,
/// so per-tenant throughput is `tenants[i].voxels / wall_secs`.
#[derive(Clone, Debug)]
pub struct MultiServerRunResult {
    /// The shared serving config the multi-tenant search chose.
    pub config: ServerConfig,
    /// Wall seconds of the measurement window (all tenants together).
    pub wall_secs: f64,
    /// Mean requests per dispatched batch, across all tenants.
    pub batch_occupancy: f64,
    /// Per-tenant outcomes, in the same order as the input tenant set.
    pub tenants: Vec<TenantRunResult>,
}

impl MultiServerRunResult {
    /// Aggregate throughput (voxels/s) across all tenants.
    pub fn throughput(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.tenants.iter().map(|t| t.voxels).sum::<u64>() as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// One tenant's share of the window's throughput (voxels/s).
    pub fn tenant_throughput(&self, name: &str) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.tenants
            .iter()
            .find(|t| t.name == name)
            .map(|t| t.voxels as f64 / self.wall_secs)
            .unwrap_or(0.0)
    }
}

/// Multi-tenant serving harness: search per-tenant plans, weights, and
/// quotas in one call ([`search_serving_multi`]), compile each tenant
/// with deterministic weights, start one [`TenantServer`], and drive
/// every tenant with its own `load.clients` closed-loop threads over a
/// shared measurement window. Backpressure rejections (queue-full,
/// over-quota, memory-pressure) are retried; anything else counts as a
/// failure for that tenant.
pub fn run_server_multi(
    tenants: &[(NetSpec, ServingLoad, u32)],
    host: &Device,
    cm: &CostModel,
    pool: Arc<TaskPool>,
    max_extent: usize,
    rounds: usize,
) -> Result<MultiServerRunResult> {
    let mut space = SearchSpace::cpu_only(host.clone(), max_extent);
    space.max_candidates = 4;
    let (tplans, cfg) = search_serving_multi(tenants, &space, cm)
        .ok_or_else(|| anyhow!("no feasible multi-tenant serving plan"))?;
    let rounds = rounds.max(1);
    let mut built = Vec::with_capacity(tplans.len());
    for (i, tp) in tplans.iter().enumerate() {
        let net = tenants[i].0.clone();
        let weights = make_weights(&net, 40 + i as u64);
        let plan = compile(&net, &tp.plan, &weights)?;
        built.push(Tenant { net, plan, weight: tp.weight, quota_bytes: tp.quota_bytes });
    }
    let server = TenantServer::start(built, cfg.clone(), pool)?;
    // Warm every shard's arenas for every tenant. Sequential submits
    // keep at most one request in flight per tenant, so the quota
    // floor (one request) always admits them.
    for (i, (net, load, _)) in tenants.iter().enumerate() {
        let n = load.volume_extent;
        for s in 0..cfg.shards {
            let seed = 9100 + (i * 31 + s) as u64;
            let vol = Tensor5::random(Shape5::new(1, net.f_in, n, n, n), seed);
            let t = server
                .submit(&net.name, vol)
                .map_err(|r| anyhow!("warmup rejected for {}: {:?}", net.name, r.reason))?;
            t.wait().map_err(|e| anyhow!("warmup failed for {}: {e}", net.name))?;
        }
    }
    // (voxels, served, failed) per tenant.
    let per: Vec<[AtomicU64; 3]> =
        tenants.iter().map(|_| [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)]).collect();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for (ti, (net, load, _)) in tenants.iter().enumerate() {
            for c in 0..load.clients.max(1) {
                let server = &server;
                let per = &per;
                s.spawn(move || {
                    let n = load.volume_extent;
                    for r in 0..rounds {
                        let seed = (ti * 7919 + c * rounds + r) as u64;
                        let mut vol = Tensor5::random(Shape5::new(1, net.f_in, n, n, n), seed);
                        loop {
                            match server.submit(&net.name, vol) {
                                Ok(t) => {
                                    match t.wait() {
                                        Ok(resp) => {
                                            per[ti][0].fetch_add(resp.voxels, Ordering::SeqCst);
                                            per[ti][1].fetch_add(1, Ordering::SeqCst);
                                        }
                                        Err(_) => {
                                            per[ti][2].fetch_add(1, Ordering::SeqCst);
                                        }
                                    }
                                    break;
                                }
                                Err(rej) => match rej.reason {
                                    RejectReason::QueueFull { .. }
                                    | RejectReason::OverQuota { .. }
                                    | RejectReason::MemoryPressure { .. } => {
                                        // Backpressure: brief pause, retry.
                                        vol = rej.volume;
                                        std::thread::sleep(Duration::from_micros(200));
                                    }
                                    _ => {
                                        per[ti][2].fetch_add(1, Ordering::SeqCst);
                                        break;
                                    }
                                },
                            }
                        }
                    }
                });
            }
        }
    });
    let wall_secs = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    let out = m
        .tenants
        .iter()
        .enumerate()
        .map(|(ti, tm)| TenantRunResult {
            name: tm.name.clone(),
            weight: tm.weight,
            quota_bytes: tm.quota_bytes,
            requests: per[ti][1].load(Ordering::SeqCst),
            voxels: per[ti][0].load(Ordering::SeqCst),
            rejected: tm.metrics.rejected,
            expired: tm.metrics.expired,
            failed: per[ti][2].load(Ordering::SeqCst),
            p50_latency: tm.metrics.p50_latency,
            p99_latency: tm.metrics.p99_latency,
        })
        .collect();
    Ok(MultiServerRunResult {
        config: cfg,
        wall_secs,
        batch_occupancy: m.merged.batch_occupancy(),
        tenants: out,
    })
}

/// Run one approach (dispatch helper for the benches).
#[allow(clippy::too_many_arguments)]
pub fn run_approach(
    a: Approach,
    net: &NetSpec,
    weights: &[Arc<Weights>],
    host: &Device,
    gpu: &Device,
    cm: &CostModel,
    pool: &TaskPool,
    max_extent: usize,
) -> Result<ApproachResult> {
    match a {
        Approach::CpuOnly => run_cpu_only(net, weights, host, cm, pool, max_extent),
        Approach::GpuOnly => run_gpu_only(net, weights, gpu, cm, pool, max_extent),
        Approach::GpuHostRam => run_gpu_host_ram(net, weights, host, gpu, cm, pool, max_extent),
        Approach::CpuGpu => run_cpu_gpu(net, weights, host, gpu, cm, pool, max_extent, 3),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo::tiny_net;
    use crate::optimizer::make_weights;
    use crate::util::pool::ChipTopology;

    fn setup() -> (NetSpec, Vec<Arc<Weights>>, Device, Device, CostModel, TaskPool) {
        let net = tiny_net(2);
        let weights = make_weights(&net, 5);
        let host = Device::host_with_ram(4 << 30);
        let gpu = Device::gpu_with_ram(1 << 30);
        let cm = CostModel::default_rates(2);
        let pool = TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 });
        (net, weights, host, gpu, cm, pool)
    }

    #[test]
    fn all_approaches_run_and_report() {
        let (net, weights, host, gpu, cm, pool) = setup();
        for a in Approach::ALL {
            let r = run_approach(a, &net, &weights, &host, &gpu, &cm, &pool, 17)
                .unwrap_or_else(|e| panic!("{}: {e}", a.name()));
            assert!(r.out_voxels > 0, "{}", a.name());
            assert!(r.compute_secs > 0.0, "{}", a.name());
            assert!(r.throughput() > 0.0, "{}", a.name());
        }
    }

    #[test]
    fn theta_split_matches_layerwise_execution() {
        // §VII.B: the θ-split strategy must compute the same function as
        // layer-at-a-time execution (it only reorders sub-batches), and
        // report less transfer than the layerwise GPU+host mode at the
        // same extent.
        let (net, weights, host, _gpu, cm, pool) = setup();
        let gpu = Device::gpu_with_ram(512 << 20);
        let extent = 13;
        let split = run_gpu_host_theta(&net, &weights, &host, &gpu, &cm, &pool, extent, 2)
            .expect("theta split runs");
        assert!(split.out_voxels > 0);
        assert!(split.transfer_secs > 0.0);
        // Compare transfers against the layer-at-a-time variant on the
        // same extent (force via max_extent = extent).
        let layerwise =
            run_gpu_host_ram(&net, &weights, &host, &gpu, &cm, &pool, extent).unwrap();
        if layerwise.input_extent == extent {
            assert!(
                split.transfer_secs <= layerwise.transfer_secs + 1e-9,
                "theta-split moved more data: {} vs {}",
                split.transfer_secs,
                layerwise.transfer_secs
            );
        }
    }

    #[test]
    fn server_harness_runs_and_reports() {
        let (net, weights, host, _gpu, cm, pool) = setup();
        let pool = Arc::new(pool);
        let load = ServingLoad { clients: 2, volume_extent: 18 };
        let r = run_server(&net, &weights, &host, &cm, pool, 15, &load, 2).unwrap();
        assert_eq!(r.requests, 4, "every closed-loop request must complete");
        assert!(r.voxels > 0);
        assert!(r.throughput() > 0.0);
        assert!(r.serial_throughput() > 0.0);
        assert!(r.batch_occupancy >= 1.0);
        assert_eq!(r.expired, 0);
        assert_eq!(r.failed, 0);
    }

    #[test]
    fn multi_tenant_harness_runs_and_reports() {
        let (_, _, host, _gpu, cm, pool) = setup();
        let pool = Arc::new(pool);
        let minis = crate::net::zoo::bench_miniatures();
        let tenants = vec![
            (minis[0].clone(), ServingLoad { clients: 2, volume_extent: 19 }, 2),
            (minis[1].clone(), ServingLoad { clients: 1, volume_extent: 19 }, 1),
        ];
        let r = run_server_multi(&tenants, &host, &cm, pool, 19, 2).unwrap();
        assert_eq!(r.tenants.len(), 2);
        assert!(r.throughput() > 0.0);
        assert!(r.batch_occupancy >= 1.0);
        for (t, (net, load, _)) in r.tenants.iter().zip(&tenants) {
            assert_eq!(t.name, net.name);
            let offered = (load.clients * 2) as u64;
            assert_eq!(t.requests, offered, "{}: every closed-loop request completes", t.name);
            assert!(t.voxels > 0, "{}", t.name);
            assert_eq!(t.failed, 0, "{}", t.name);
            assert_eq!(t.expired, 0, "{}", t.name);
            assert!(r.tenant_throughput(&t.name) > 0.0, "{}", t.name);
        }
    }

    #[test]
    fn gpu_host_ram_can_exceed_gpu_only_input() {
        // With a tiny device, GPU-only is capped hard; GPU+host RAM can
        // still take bigger inputs (the point of §VII.A).
        let (net, weights, host, _gpu, cm, pool) = setup();
        let tiny_gpu = Device::gpu_with_ram(24 << 20);
        let gonly = run_gpu_only(&net, &weights, &tiny_gpu, &cm, &pool, 29);
        let ghost = run_gpu_host_ram(&net, &weights, &host, &tiny_gpu, &cm, &pool, 29).unwrap();
        if let Ok(g) = gonly {
            assert!(ghost.input_extent >= g.input_extent);
        }
        assert!(ghost.transfer_secs > 0.0);
    }
}

/// §VII.B refinement (Fig 8): execute the first θ layers one *layer*
/// at a time (GPU + host RAM conv, CPU MPF), then the remaining layers
/// one *sub-batch* at a time as a GPU-only network — fragment groups
/// after the MPF layers are independent (the batch-concatenation
/// property), so each group stays on the device end-to-end and no
/// intermediate returns to host RAM.
pub fn run_gpu_host_theta(
    net: &NetSpec,
    weights: &[Arc<Weights>],
    host: &Device,
    gpu: &Device,
    cm: &CostModel,
    pool: &TaskPool,
    extent: usize,
    theta: usize,
) -> Result<ApproachResult> {
    let modes = vec![PoolingMode::Mpf; net.pool_count()];
    let input_sh = Shape5::new(1, net.f_in, extent, extent, extent);
    let shapes = net.shapes(input_sh, &modes)?;
    let theta = theta.clamp(1, net.layers.len());

    // --- head: θ layers, one at a time (as run_gpu_host_ram) ---
    let mut ctx = ExecCtx::new(pool);
    let mut cur = Tensor5::random(input_sh, 1);
    let mut wi = 0;
    let mut compute = 0.0f64;
    let mut transfer_bytes = 0u64;
    for l in &net.layers[..theta] {
        match l {
            LayerSpec::Conv { f_out, k } => {
                let ish = cur.shape();
                let d = ConvDims {
                    s: ish.s,
                    f_in: ish.f,
                    f_out: *f_out,
                    n: ish.spatial(),
                    k: *k,
                };
                let plan = crate::sublayer::decompose(&d, gpu, cm)
                    .ok_or_else(|| anyhow!("layer does not fit the device"))?;
                let t0 = Instant::now();
                let (out, moved) =
                    crate::sublayer::execute(&cur, &weights[wi], &plan, Activation::Relu, &mut ctx);
                compute += t0.elapsed().as_secs_f64();
                transfer_bytes += moved;
                ctx.retire(cur);
                cur = out;
                wi += 1;
            }
            LayerSpec::Pool { p } => {
                let t0 = Instant::now();
                let out = crate::pool::mpf_forward(&cur, *p, &mut ctx);
                compute += t0.elapsed().as_secs_f64();
                ctx.retire(cur);
                cur = out;
            }
        }
    }

    // --- tail: one fragment sub-batch at a time, GPU-only, entirely on
    // the device (upload once, download once per sub-batch) ---
    let mid_sh = cur.shape();
    // Verify the single-batch tail fits the device; grow the sub-batch
    // while it still fits.
    let tail_mem = |s: usize| -> Option<u64> {
        let mut sh = Shape5 { s, ..mid_sh };
        let mut peak = 0u64;
        for l in net.layers.iter().skip(theta) {
            match l {
                LayerSpec::Conv { f_out, k } => {
                    let d = ConvDims {
                        s: sh.s,
                        f_in: sh.f,
                        f_out: *f_out,
                        n: sh.spatial(),
                        k: *k,
                    };
                    let algo_mem = [ConvAlgo::GpuDensePrecomp, ConvAlgo::GpuFft]
                        .iter()
                        .map(|&a| crate::memory::model::conv_memory_bytes(a, &d, 1))
                        .min()
                        .unwrap();
                    peak = peak.max(algo_mem);
                }
                LayerSpec::Pool { p } => {
                    peak = peak.max(crate::memory::model::mpf_memory_bytes(
                        sh.s,
                        sh.f,
                        sh.spatial(),
                        *p,
                    ));
                }
            }
            sh = propagate_one(l, sh, PoolingMode::Mpf)?;
        }
        Some(peak)
    };
    let mut sub = 1usize;
    while sub * 2 <= mid_sh.s
        && mid_sh.s % (sub * 2) == 0
        && tail_mem(sub * 2).map(|m| gpu.fits(m)).unwrap_or(false)
    {
        sub *= 2;
    }
    if tail_mem(sub).map(|m| !gpu.fits(m)).unwrap_or(true) {
        bail!("tail does not fit the device even at sub-batch 1");
    }

    // Execute each sub-batch through GPU primitives.
    let frag_groups = mid_sh.s / sub;
    let mut outputs: Vec<Tensor5> = Vec::with_capacity(frag_groups);
    for g in 0..frag_groups {
        // Slice the sub-batch out of the θ-boundary tensor.
        let gsh = Shape5 { s: sub, ..mid_sh };
        let mut part = Tensor5::zeros(gsh);
        for s in 0..sub {
            for f in 0..mid_sh.f {
                part.image_mut(s, f).copy_from_slice(cur.image(g * sub + s, f));
            }
        }
        transfer_bytes += gsh.bytes_f32();
        let t0 = Instant::now();
        let mut x = part;
        let mut twi = wi;
        for l in &net.layers[theta..] {
            x = match l {
                LayerSpec::Conv { f_out, k } => {
                    let ish = x.shape();
                    let d = ConvDims {
                        s: ish.s,
                        f_in: ish.f,
                        f_out: *f_out,
                        n: ish.spatial(),
                        k: *k,
                    };
                    let _ = d;
                    let algo = if k[0] * k[1] * k[2] <= 125 {
                        ConvAlgo::GpuDensePrecomp
                    } else {
                        ConvAlgo::GpuFft
                    };
                    let layer = ConvLayer::new(weights[twi].clone(), algo, Activation::Relu);
                    twi += 1;
                    layer.execute(x, &mut ctx)
                }
                LayerSpec::Pool { p } => {
                    let out = crate::pool::mpf_forward(&x, *p, &mut ctx);
                    ctx.retire(x);
                    out
                }
            };
        }
        compute += t0.elapsed().as_secs_f64();
        transfer_bytes += x.shape().bytes_f32();
        outputs.push(x);
    }

    // Concatenate sub-batch outputs (batch-concatenation property).
    let osh0 = outputs[0].shape();
    let full = Shape5 { s: osh0.s * frag_groups, ..osh0 };
    let mut out = Tensor5::zeros(full);
    for (g, o) in outputs.iter().enumerate() {
        let len = o.data().len();
        out.data_mut()[g * len..(g + 1) * len].copy_from_slice(o.data());
    }

    Ok(ApproachResult {
        approach: Approach::GpuHostRam,
        input_extent: extent,
        out_voxels: out_voxels(&out.shape()),
        compute_secs: compute,
        transfer_secs: gpu.transfer_secs(transfer_bytes),
        memory_bytes: mid_sh.bytes_f32() * 2,
    })
}

/// Shape propagation for one layer (helper for the θ-split planner).
fn propagate_one(l: &LayerSpec, sh: Shape5, mode: PoolingMode) -> Option<Shape5> {
    match l {
        LayerSpec::Conv { f_out, k } => {
            if sh.x < k[0] || sh.y < k[1] || sh.z < k[2] {
                return None;
            }
            Some(Shape5 {
                s: sh.s,
                f: *f_out,
                x: sh.x - k[0] + 1,
                y: sh.y - k[1] + 1,
                z: sh.z - k[2] + 1,
            })
        }
        LayerSpec::Pool { p } => match mode {
            PoolingMode::Mpf => {
                if (sh.x + 1) % p[0] != 0 || (sh.y + 1) % p[1] != 0 || (sh.z + 1) % p[2] != 0 {
                    return None;
                }
                Some(Shape5 {
                    s: sh.s * p[0] * p[1] * p[2],
                    f: sh.f,
                    x: sh.x / p[0],
                    y: sh.y / p[1],
                    z: sh.z / p[2],
                })
            }
            PoolingMode::MaxPool => {
                if sh.x % p[0] != 0 || sh.y % p[1] != 0 || sh.z % p[2] != 0 {
                    return None;
                }
                Some(Shape5 { x: sh.x / p[0], y: sh.y / p[1], z: sh.z / p[2], ..sh })
            }
        },
    }
}
