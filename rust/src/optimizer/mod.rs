//! Throughput optimizer — the exhaustive search of §VI.A.
//!
//! For a fixed choice of max-pool vs MPF per pooling layer and a fixed
//! input shape, the time and memory of every candidate primitive per
//! layer are uniquely determined — so the search:
//!
//! 1. loops over pooling-mode assignments,
//! 2. loops over allowed input shapes (and batch sizes),
//! 3. picks, per convolutional layer, the fastest primitive whose
//!    Table II memory fits the device,
//!
//! and keeps the plan with the highest estimated throughput
//! (`Size(I′) / Σ Time(primitiveᵢ, Iᵢ)`). Plans can then be *executed*
//! to measure real throughput.
//!
//! ```
//! use znni::device::Device;
//! use znni::net::zoo::tiny_net;
//! use znni::optimizer::{search, CostModel, SearchSpace};
//!
//! let net = tiny_net(2);
//! let space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
//! let plan = search(&net, &space, &CostModel::default_rates(2)).expect("feasible");
//! assert_eq!(plan.layers.len(), net.layers.len());
//! assert!(plan.est_throughput() > 0.0);
//! ```

pub mod cost;
pub mod theory;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::conv::{Activation, Weights};
use crate::device::Device;
use crate::exec::{ExecCtx, WorkspaceReq};
use crate::layers::{
    ConvLayer, FusedConvPoolLayer, LayerPrimitive, MaxPoolLayer, MpfLayer, Placement,
    PoolFusedLayer,
};
use crate::memory::model::{
    conv_memory_bytes, conv_pool_fused_memory_bytes, mpf_memory_bytes, pool_memory_bytes,
    ConvAlgo, ConvDims,
};
use crate::net::{LayerSpec, NetSpec, PoolingMode};
use crate::precision::Precision;
use crate::tensor::{Shape5, Tensor5};
use crate::util::pool::TaskPool;

pub use cost::CostModel;

/// Per-layer decision of a plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanLayer {
    /// A convolutional layer executed with the chosen algorithm.
    Conv {
        /// The algorithm the search picked for this layer.
        algo: ConvAlgo,
        /// Whether this layer precomputes its kernel spectra
        /// ([`crate::conv::precomp::PrecomputedKernels`]) — a decision
        /// the search makes per layer under the memory budget: spending
        /// RAM on resident spectra competes directly with spending it
        /// on a larger input image. Always `false` for non-FFT
        /// algorithms.
        cache_kernels: bool,
        /// Storage precision of this layer's cached kernel spectra and
        /// output activations ([`crate::precision::Precision`]).
        /// Compute stays f32; a half-width choice halves the resident
        /// spectra row (and stages activations through a 2-byte arena
        /// buffer) at the cost of the narrow/widen conversions —
        /// another budgeted trade the search makes per layer. Always
        /// [`Precision::F32`] unless `ZNNI_PRECISION`
        /// ([`crate::precision::precision_mode`]) admits the half
        /// formats.
        precision: Precision,
    },
    /// A pooling layer realised in the chosen mode.
    Pool {
        /// Max-pool or MPF.
        mode: PoolingMode,
    },
    /// A max-pool layer whose reduce was folded into the preceding
    /// conv layer ([`ConvAlgo::DirectFusedPool`]): the fused primitive
    /// already produced the pooled tensor, so this slot compiles to a
    /// pass-through ([`PoolFusedLayer`]) and plans stay 1:1 with the
    /// network spec. Counts as [`PoolingMode::MaxPool`] in
    /// [`Plan::modes`].
    PoolFused,
}

impl PlanLayer {
    /// Short Table IV tag of this decision.
    pub fn tag(&self) -> &'static str {
        match self {
            PlanLayer::Conv { algo, .. } => algo.tag(),
            PlanLayer::Pool { mode } => match mode {
                PoolingMode::Mpf => "MPF",
                PoolingMode::MaxPool => "Pool",
            },
            PlanLayer::PoolFused => "(fused)",
        }
    }
}

/// A fully determined execution plan for one input patch.
#[derive(Clone, Debug)]
pub struct Plan {
    /// Name of the planned network.
    pub net_name: String,
    /// Chosen input patch shape.
    pub input: Shape5,
    /// Per-layer decisions, in layer order.
    pub layers: Vec<PlanLayer>,
    /// Shape after each layer.
    pub shapes: Vec<Shape5>,
    /// Estimated seconds per patch (cost model).
    pub est_secs: f64,
    /// Peak Table II memory across layers (bytes), including the
    /// resident kernel-spectra row ([`Plan::kernel_cache_bytes`]).
    pub est_memory: u64,
    /// Resident precomputed kernel-spectra bytes summed over the layers
    /// the search chose to cache (0 when nothing is cached). A shared
    /// allocation: counted once per plan, not per worker.
    pub kernel_cache_bytes: u64,
    /// Output voxels per patch: S′ · x′·y′·z′ (spatial positions of the
    /// sliding-window output covered by one patch).
    pub out_voxels: u64,
}

impl Plan {
    /// Estimated throughput: output voxels per estimated second.
    pub fn est_throughput(&self) -> f64 {
        self.out_voxels as f64 / self.est_secs
    }

    /// Pooling modes of this plan in pool-layer order.
    pub fn modes(&self) -> Vec<PoolingMode> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                PlanLayer::Pool { mode } => Some(*mode),
                // The fused reduce realises max-pool semantics.
                PlanLayer::PoolFused => Some(PoolingMode::MaxPool),
                _ => None,
            })
            .collect()
    }
}

/// Search constraints: which algorithms may be used and on what device.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// Device whose RAM constrains every candidate.
    pub device: Device,
    /// Conv algorithms the search may choose from.
    pub algos: Vec<ConvAlgo>,
    /// Allow max-pool (in addition to MPF) in the pooling assignment
    /// loop. The paper's result is that MPF always wins; keeping both
    /// lets the benches demonstrate that.
    pub allow_maxpool: bool,
    /// Candidate batch sizes (the paper finds S = 1 optimal for ≥2-pool
    /// nets; Fig 4 sweeps this).
    pub batch_sizes: Vec<usize>,
    /// Inclusive range of cubic input extents to consider.
    pub min_extent: usize,
    /// Largest cubic input extent to consider.
    pub max_extent: usize,
    /// Cap on candidate extents actually evaluated (largest kept).
    pub max_candidates: usize,
    /// Per-search storage-precision override. `None` (the default)
    /// defers to the process-wide `ZNNI_PRECISION`
    /// ([`crate::precision::precision_mode`]); `Some(mode)` pins this
    /// search to that mode regardless of the environment — the hook
    /// [`search_serving_multi_spec`] uses to give each tenant its own
    /// precision policy on one box.
    pub precision: Option<crate::precision::PrecisionMode>,
}

impl SearchSpace {
    /// CPU-only search (§VI): CPU primitives against host RAM.
    pub fn cpu_only(device: Device, max_extent: usize) -> Self {
        SearchSpace {
            device,
            algos: vec![
                ConvAlgo::DirectNaive,
                ConvAlgo::DirectMkl,
                ConvAlgo::DirectFused,
                ConvAlgo::DirectFusedPool,
                ConvAlgo::FftDataParallel,
                ConvAlgo::FftTaskParallel,
            ],
            allow_maxpool: false,
            batch_sizes: vec![1],
            min_extent: 1,
            max_extent,
            max_candidates: 12,
            precision: None,
        }
    }

    /// GPU-only search (§VI): GPU primitives against device RAM.
    pub fn gpu_only(device: Device, max_extent: usize) -> Self {
        SearchSpace {
            device,
            algos: vec![
                ConvAlgo::GpuDenseNoWorkspace,
                ConvAlgo::GpuDensePrecomp,
                ConvAlgo::GpuFft,
            ],
            allow_maxpool: false,
            batch_sizes: vec![1],
            min_extent: 1,
            max_extent,
            max_candidates: 12,
            precision: None,
        }
    }
}

/// All pooling-mode assignments (2^pools, or MPF-only).
fn mode_assignments(pools: usize, allow_maxpool: bool) -> Vec<Vec<PoolingMode>> {
    if !allow_maxpool {
        return vec![vec![PoolingMode::Mpf; pools]];
    }
    (0..(1usize << pools))
        .map(|mask| {
            (0..pools)
                .map(|i| {
                    if mask & (1 << i) != 0 {
                        PoolingMode::MaxPool
                    } else {
                        PoolingMode::Mpf
                    }
                })
                .collect()
        })
        .collect()
}

/// One conv-layer candidate during [`evaluate`]: algorithm, whether the
/// kernel spectra are precomputed, the storage precision, and the
/// modelled cost of each choice.
#[derive(Clone, Copy)]
struct ConvChoice {
    algo: ConvAlgo,
    cached: bool,
    /// Storage precision of the spectra row and output activations.
    precision: Precision,
    secs: f64,
    mem: u64,
    /// Resident spectra bytes when `cached` (0 otherwise), at
    /// `precision`'s element width.
    cache_bytes: u64,
    /// Seconds added back if the cache is later dropped (the per-call
    /// kernel-transform time, net of any conversion tax the cached
    /// choice was paying).
    drop_penalty: f64,
}

/// Evaluate one (modes, input) candidate: per-layer fastest primitive
/// under the memory constraint, with kernel-spectra caching searched
/// per FFT layer. Returns None if any layer has no feasible primitive.
///
/// Conv→pool pairs get an extra candidate spanning both spec layers:
/// when the next layer is a max-pool whose window tiles the conv
/// output and [`ConvAlgo::DirectFusedPool`] is in the space, the fused
/// primitive competes against the best conv choice *plus* the separate
/// pool pass — on time when both fit, and by default when only the
/// fused working set (which drops the inter-layer tensor) fits the
/// device. A fused pair emits `Conv { DirectFusedPool }` followed by
/// [`PlanLayer::PoolFused`].
///
/// Caching discipline: cached spectra are resident for the whole run,
/// so a plan's peak is `max(layer working sets) + Σ cached spectra`.
/// Layers are chosen greedily in order (each candidate checked against
/// the spectra already committed); a final pass re-verifies the true
/// peak and drops caches — largest row first, adding the kernel
/// transform time back — until the plan fits (the per-layer fallback
/// to recomputation). `ZNNI_KERNEL_CACHE` (see
/// [`crate::conv::precomp::cache_mode`]) gates the whole axis: `off`
/// never caches, `on` caches every FFT layer the budget admits without
/// consulting the cost model, `auto` (default) lets the cost model
/// decide — which, under the analytic model, also caches wherever the
/// budget admits (cached layers are strictly cheaper), so `auto` and
/// `on` only diverge if a future measured model charges the cache.
///
/// Storage precision is a second per-layer axis, gated the same way by
/// `ZNNI_PRECISION` ([`crate::precision::precision_mode`]): under
/// `auto`, every cached candidate is probed at f32 first and then at
/// each half format — a half row costs exactly half the resident bytes
/// ([`crate::memory::model::kernel_spectra_bytes_p`]) plus an
/// activation-staging row, against the narrow/widen tax
/// ([`CostModel::convert_secs`]) — so half-width spectra win exactly
/// where the f32 row no longer fits. Candidates are ranked purely on
/// modelled time; pin a fixed mode (`f16`/`bf16`) to choose a format
/// for accuracy reasons. Fixed modes pin *every* conv layer (cached or
/// not); under `auto` uncached layers stay f32, where half storage
/// only costs. The fused conv→pool pair has no spectra row or
/// inter-layer hand-off and always stays f32.
fn evaluate(
    net: &NetSpec,
    input: Shape5,
    modes: &[PoolingMode],
    space: &SearchSpace,
    cost: &CostModel,
) -> Option<Plan> {
    use crate::conv::precomp::{cache_mode, CacheMode};
    use crate::memory::model::kernel_spectra_bytes_p;
    use crate::precision::precision_mode;

    let mode = cache_mode();
    let pmode = space.precision.unwrap_or_else(precision_mode);
    // The precision every *uncached* conv layer gets: a fixed
    // ZNNI_PRECISION pins it, `auto` keeps f32 (without a resident row
    // to halve, half storage only adds conversion time and staging).
    let un_prec = pmode.fixed().unwrap_or(Precision::F32);
    let shapes = net.shapes(input, modes).ok()?;
    let mut cur = input;
    let mut layers = Vec::with_capacity(net.layers.len());
    let mut est_secs = 0.0;
    let mut max_mem = 0u64;
    let mut cache_total = 0u64;
    // (index into `layers`, the choice) for every cached conv layer —
    // the candidates of the final drop-to-fit pass.
    let mut cached_layers: Vec<(usize, ConvChoice)> = Vec::new();
    let mut pool_i = 0;
    let mut li = 0;
    while li < net.layers.len() {
        let l = &net.layers[li];
        match l {
            LayerSpec::Conv { f_out, k } => {
                let d = ConvDims {
                    s: cur.s,
                    f_in: net.f_in_at(li),
                    f_out: *f_out,
                    n: cur.spatial(),
                    k: *k,
                };
                let mut best: Option<ConvChoice> = None;
                let consider = |c: ConvChoice, best: &mut Option<ConvChoice>| {
                    if best.map(|b| c.secs < b.secs).unwrap_or(true) {
                        *best = Some(c);
                    }
                };
                for &algo in &space.algos {
                    // The conv→pool fused algorithm is not a per-layer
                    // candidate: it spans two spec layers, so the
                    // lookahead below owns it.
                    if algo == ConvAlgo::DirectFusedPool {
                        continue;
                    }
                    let mem = conv_memory_bytes(algo, &d, cost.threads);
                    let secs = cost.conv_secs(algo, &d, &space.device);
                    // Per-patch element counts a half format converts:
                    // output activations are narrowed then widened (two
                    // passes over S'·f'·n'³), a cached spectra row is
                    // widened once (f'·f·ñ float-equivalents).
                    let act_elems = 2 * (d.s * d.f_out) as u64 * d.n_out_elems();
                    let spectra_elems = (d.f_in * d.f_out) as u64 * d.n_tilde_elems();
                    // Table II surcharge of the half formats: the 2-byte
                    // arena staging buffer the activation hand-off
                    // narrows into (ConvLayer::memory_bytes adds the
                    // same row).
                    let staging = |p: Precision| {
                        if p.is_half() {
                            2 * (d.s * d.f_out) as u64 * d.n_out_elems()
                        } else {
                            0
                        }
                    };
                    let un_secs = secs + cost.convert_secs(un_prec, act_elems);
                    let un_mem = mem.saturating_add(staging(un_prec));
                    let mut cached_feasible = false;
                    if algo.uses_kernel_cache() && mode != CacheMode::Off {
                        for &prec in pmode.candidates() {
                            let cb = kernel_spectra_bytes_p(algo, &d, prec);
                            let cmem = mem.saturating_add(staging(prec));
                            // A cached candidate must afford its own row
                            // on top of the spectra already committed.
                            if space
                                .device
                                .fits(cmem.saturating_add(cache_total).saturating_add(cb))
                            {
                                cached_feasible = true;
                                let cached_secs = cost.conv_secs_cached(algo, &d, &space.device)
                                    + cost.convert_secs(prec, spectra_elems + act_elems);
                                consider(
                                    ConvChoice {
                                        algo,
                                        cached: true,
                                        precision: prec,
                                        secs: cached_secs,
                                        mem: cmem,
                                        cache_bytes: cb,
                                        drop_penalty: un_secs - cached_secs,
                                    },
                                    &mut best,
                                );
                            }
                        }
                    }
                    // The recompute candidate — checked against the
                    // device alone (the final drop-to-fit pass owns the
                    // cache/working-set interaction, so caching can
                    // never make a previously feasible plan infeasible);
                    // suppressed in `on` (force) mode when a cached
                    // variant of the same algorithm is admissible.
                    if space.device.fits(un_mem) && !(mode == CacheMode::Force && cached_feasible) {
                        consider(
                            ConvChoice {
                                algo,
                                cached: false,
                                precision: un_prec,
                                secs: un_secs,
                                mem: un_mem,
                                cache_bytes: 0,
                                drop_penalty: 0.0,
                            },
                            &mut best,
                        );
                    }
                }
                // Fusion lookahead: when the next spec layer is a
                // max-pool whose window tiles this conv's output, a
                // single fused conv→pool primitive is a candidate for
                // the *pair*. Its Table II row drops the inter-layer
                // tensor, so it can be feasible where conv-then-pool is
                // not; otherwise it wins on time alone.
                if space.algos.contains(&ConvAlgo::DirectFusedPool) {
                    if let Some(LayerSpec::Pool { p }) = net.layers.get(li + 1) {
                        let csh = shapes[li];
                        let divisible = csh.x % p[0] == 0
                            && csh.y % p[1] == 0
                            && csh.z % p[2] == 0;
                        if modes[pool_i] == PoolingMode::MaxPool && divisible {
                            let fmem = conv_pool_fused_memory_bytes(&d, *p, cost.threads);
                            if space.device.fits(fmem) {
                                let fsecs =
                                    cost.conv_secs(ConvAlgo::DirectFusedPool, &d, &space.device);
                                let pool_mem =
                                    pool_memory_bytes(csh.s, csh.f, csh.spatial(), *p);
                                let pool_secs =
                                    cost.pool_secs(csh.s, csh.f, csh.spatial(), *p, false);
                                let take_fused = match &best {
                                    Some(b) if space.device.fits(pool_mem) => {
                                        fsecs < b.secs + pool_secs
                                    }
                                    // No feasible unfused pair at all —
                                    // fusion is the only way through.
                                    _ => true,
                                };
                                if take_fused {
                                    layers.push(PlanLayer::Conv {
                                        algo: ConvAlgo::DirectFusedPool,
                                        cache_kernels: false,
                                        // The fused pair streams into
                                        // the pooled output — no spectra
                                        // row, no inter-layer hand-off —
                                        // so it stays f32 in every mode.
                                        precision: Precision::F32,
                                    });
                                    layers.push(PlanLayer::PoolFused);
                                    est_secs += fsecs;
                                    max_mem = max_mem.max(fmem);
                                    pool_i += 1;
                                    cur = shapes[li + 1];
                                    li += 2;
                                    continue;
                                }
                            }
                        }
                    }
                }
                let c = best?;
                if c.cached {
                    cache_total += c.cache_bytes;
                    cached_layers.push((layers.len(), c));
                }
                layers.push(PlanLayer::Conv {
                    algo: c.algo,
                    cache_kernels: c.cached,
                    precision: c.precision,
                });
                est_secs += c.secs;
                max_mem = max_mem.max(c.mem);
            }
            LayerSpec::Pool { p } => {
                let mode_p = modes[pool_i];
                pool_i += 1;
                let mem = match mode_p {
                    PoolingMode::Mpf => mpf_memory_bytes(cur.s, cur.f, cur.spatial(), *p),
                    PoolingMode::MaxPool => pool_memory_bytes(cur.s, cur.f, cur.spatial(), *p),
                };
                if !space.device.fits(mem) {
                    return None;
                }
                layers.push(PlanLayer::Pool { mode: mode_p });
                est_secs +=
                    cost.pool_secs(cur.s, cur.f, cur.spatial(), *p, mode_p == PoolingMode::Mpf);
                max_mem = max_mem.max(mem);
            }
        }
        cur = shapes[li];
        li += 1;
    }
    // Per-layer fallback: caches committed early may no longer fit once
    // later layers raised the peak or added their own spectra. Drop the
    // largest rows first until the true peak fits, paying each layer's
    // kernel-transform time back.
    cached_layers.sort_by(|a, b| a.1.cache_bytes.cmp(&b.1.cache_bytes));
    while !space.device.fits(max_mem.saturating_add(cache_total)) {
        let Some((idx, c)) = cached_layers.pop() else {
            return None; // infeasible even with every cache dropped
        };
        cache_total -= c.cache_bytes;
        est_secs += c.drop_penalty;
        // A dropped cache reverts the layer to the uncached precision
        // (f32 under `auto` — without the row there is nothing for half
        // storage to buy); `drop_penalty` was priced against exactly
        // that fallback.
        layers[idx] = PlanLayer::Conv { algo: c.algo, cache_kernels: false, precision: un_prec };
    }
    let out = *shapes.last().unwrap();
    Some(Plan {
        net_name: net.name.clone(),
        input,
        layers,
        shapes,
        est_secs,
        est_memory: max_mem.saturating_add(cache_total),
        kernel_cache_bytes: cache_total,
        out_voxels: (out.s * out.x * out.y * out.z) as u64,
    })
}

/// Exhaustive search per §VI.A. Returns the best plan (highest
/// estimated throughput) if any candidate is feasible.
pub fn search(net: &NetSpec, space: &SearchSpace, cost: &CostModel) -> Option<Plan> {
    let mut best: Option<Plan> = None;
    for modes in mode_assignments(net.pool_count(), space.allow_maxpool) {
        let mut extents = net.valid_extents(space.min_extent, space.max_extent, &modes);
        // Keep only the largest few candidates — throughput grows with
        // input size until memory runs out (§II), so the optimum is at
        // the memory frontier.
        if extents.len() > space.max_candidates {
            extents = extents.split_off(extents.len() - space.max_candidates);
        }
        for &s in &space.batch_sizes {
            for &n in &extents {
                let input = Shape5::new(s, net.f_in, n, n, n);
                if let Some(p) = evaluate(net, input, &modes, space, cost) {
                    let cur_best = best.as_ref().map(|b| b.est_throughput());
                    if cur_best.map(|b| p.est_throughput() > b).unwrap_or(true) {
                        best = Some(p);
                    }
                }
            }
        }
    }
    best
}

/// Search the plan **and** the serving configuration in one call.
///
/// The serving layer obeys the same law the plan search does: amortize
/// fixed overheads over the largest workload the memory budget admits
/// (§III, Fig. 5) — at the request level that means picking how many
/// coordinator shards run, how deep the admission queues are and how
/// long the micro-batcher waits. This coarse search models, per shard
/// count `c` (powers of two up to the cost model's threads):
///
/// * **memory** — every worker keeps one warm Table II arena
///   (`plan.est_memory`), plus one in-flight request (input + dense
///   output, [`crate::memory::model::request_memory_bytes`]) per busy
///   shard; candidates that do not fit the device are discarded;
/// * **time** — per-patch seconds scale with the thread share a shard
///   gets, plus the per-batch dispatch overhead
///   ([`CostModel::dispatch_overhead_secs`]) that more shards amortize
///   across concurrent clients. The overhead is a *measured* quantity:
///   [`cost::measure_dispatch_overhead`] (run by
///   [`CostModel::calibrate_full`]) times the worker spawn + hand-off
///   this machine actually pays, replacing the old fixed 200 µs
///   assumption; uncalibrated models fall back to
///   [`cost::DEFAULT_DISPATCH_OVERHEAD_SECS`].
///
/// Queue depth (Little's-law-style: two outstanding requests per
/// client, split across shards, capped by spare RAM), the batch cap and
/// the batch wait are then derived from the winning shard count.
pub fn search_serving(
    net: &NetSpec,
    space: &SearchSpace,
    cost: &CostModel,
    load: &crate::server::ServingLoad,
) -> Option<(Plan, crate::server::ServerConfig)> {
    use std::time::Duration;

    let plan = search(net, space, cost)?;
    let fov = net.field_of_view();
    let vd = [load.volume_extent; 3];
    let req_bytes =
        crate::memory::model::request_memory_bytes(net.f_in, net.f_out(), vd, fov).max(1);
    let threads = cost.threads.max(1);
    // `est_memory` includes the plan's resident kernel-spectra row.
    // That row is one shared Arc (not per worker), so charging it per
    // worker here over-reserves slightly — a deliberately conservative
    // admission model (the Server::start gate uses the exact split via
    // `WorkspaceReq::times`, which leaves resident bytes unscaled).
    let per_worker_ws = plan.est_memory.max(1);
    let clients = load.clients.max(1);
    // Fixed per-batch dispatch cost (worker spawn + assembly) — the
    // request-level analogue of the per-patch fixed overheads the paper
    // amortizes with bigger images. Measured by the calibration harness
    // (`CostModel::calibrate_full`) for the *full* pool; a shard's
    // batch only spawns its own worker share, and thread spawn/join
    // dominates the measurement, so the charge scales linearly with the
    // shard's worker count (floored at one thread's worth).
    let measured_overhead = cost.dispatch_overhead_secs.max(0.0);
    let overhead_for = |shard_workers: usize| {
        (measured_overhead * shard_workers as f64 / threads as f64)
            .max(measured_overhead / threads as f64)
    };

    let mut best: Option<(usize, f64)> = None;
    let mut shards = 1usize;
    while shards <= threads {
        let shard_workers = (threads / shards).max(1);
        let arenas = per_worker_ws.saturating_mul((shard_workers * shards) as u64);
        let concurrency = shards.min(clients);
        let inflight = req_bytes.saturating_mul(concurrency as u64);
        if space.device.fits(arenas.saturating_add(inflight)) {
            let patch_secs = plan.est_secs * threads as f64 / shard_workers as f64;
            let tp = concurrency as f64 * plan.out_voxels as f64
                / (patch_secs + overhead_for(shard_workers));
            if best.map(|(_, b)| tp > b).unwrap_or(true) {
                best = Some((shards, tp));
            }
        }
        shards *= 2;
    }
    let (shards, _) = best?;
    let shard_workers = (threads / shards).max(1);
    let shard_arena = per_worker_ws.saturating_mul(shard_workers as u64);
    let arenas = shard_arena.saturating_mul(shards as u64);
    let spare = space.device.ram_bytes.saturating_sub(arenas);
    let depth_by_mem = ((spare / req_bytes).max(1) as usize).min(1 << 16);
    let queue_depth = crate::util::ceil_div(2 * clients, shards).clamp(1, depth_by_mem);
    let max_batch_requests = depth_by_mem.min(clients).clamp(1, 8);
    let patch_secs = plan.est_secs * threads as f64 / shard_workers as f64;
    // Waiting less than one dispatch overhead for co-batchable requests
    // cannot pay for itself, so the winning shard size's measured
    // overhead floors the wait.
    let wait_floor = overhead_for(shard_workers).clamp(50e-6, 5e-3);
    let max_batch_wait = Duration::from_secs_f64((patch_secs / 8.0).clamp(wait_floor, 10e-3));
    // Per-shard batch budget: an even share of device RAM, but always
    // enough for the shard's warm arenas plus one typical request (the
    // start-time admission gate requires strict headroom).
    let memory_budget = (space.device.ram_bytes / shards as u64)
        .max(shard_arena.saturating_add(req_bytes).saturating_add(1));
    let cfg = crate::server::ServerConfig {
        shards,
        queue_depth,
        max_batch_requests,
        max_batch_wait,
        memory_budget,
        default_deadline: None,
    };
    Some((plan, cfg))
}

/// One tenant's slice of a multi-tenant serving search: its plan, its
/// dispatch weight, and its admission quota — ready to compile and
/// hand to [`crate::server::tenants::TenantServer::start`].
#[derive(Clone, Debug)]
pub struct TenantPlan {
    /// Tenant id (the network name).
    pub name: String,
    /// The tenant's searched execution plan.
    pub plan: Plan,
    /// Dispatch weight (passed through from the search input).
    pub weight: u32,
    /// Admission quota in bytes: the tenant's slice of the device
    /// budget, split in proportion to its offered load's Table II
    /// request footprint (`request_memory_bytes × clients`).
    pub quota_bytes: u64,
    /// The offered load the quota was derived for.
    pub load: crate::server::ServingLoad,
}

/// One tenant's input to [`search_serving_multi_spec`]: its network,
/// offered load, dispatch weight, and (optionally) its own storage
/// precision policy.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// The tenant's network; `net.name` becomes the tenant id.
    pub net: NetSpec,
    /// The offered load to size shards and quotas for.
    pub load: crate::server::ServingLoad,
    /// Dispatch weight (see [`crate::server::tenants::Tenant::weight`]).
    pub weight: u32,
    /// Per-tenant storage-precision override for this tenant's plan
    /// search: `Some(mode)` pins the tenant to that mode (e.g. a
    /// latency-insensitive tenant opting into f16 spectra while an
    /// accuracy-critical sibling stays f32 on the same box); `None`
    /// inherits the search space's [`SearchSpace::precision`], which in
    /// turn defaults to the process-wide `ZNNI_PRECISION`.
    pub precision: Option<crate::precision::PrecisionMode>,
}

/// Multi-tenant serving search over `(net, load, weight)` tuples — the
/// original interface, kept for callers without per-tenant precision
/// policies. Equivalent to [`search_serving_multi_spec`] with every
/// [`TenantSpec::precision`] set to `None`.
pub fn search_serving_multi(
    tenants: &[(NetSpec, crate::server::ServingLoad, u32)],
    space: &SearchSpace,
    cost: &CostModel,
) -> Option<(Vec<TenantPlan>, crate::server::ServerConfig)> {
    let specs: Vec<TenantSpec> = tenants
        .iter()
        .map(|(net, load, weight)| TenantSpec {
            net: net.clone(),
            load: *load,
            weight: *weight,
            precision: None,
        })
        .collect();
    search_serving_multi_spec(&specs, space, cost)
}

/// Multi-tenant serving search: size the shard set and split the device
/// budget across a tenant set in one call.
///
/// Input is one [`TenantSpec`] per tenant. The search runs
/// in three steps, all in the paper's memory currency:
///
/// 1. **Per-tenant plan search** under a weight-proportional RAM share
///    (`ram × weight / Σ weights`) — a heavy tenant may buy a larger
///    patch, a light one gets a leaner plan — and under the tenant's
///    own precision policy ([`TenantSpec::precision`]). Any tenant
///    with no feasible plan fails the whole search (`None`).
/// 2. **Aggregate shard sizing**, mirroring [`search_serving`] but with
///    every tenant's warm arenas resident on every shard and one
///    in-flight request per tenant per busy shard; the shard count
///    maximizing summed tenant throughput wins.
/// 3. **Quota split**: the RAM left after all warm arenas is divided in
///    proportion to each tenant's `request_memory_bytes × clients`
///    (its share of the offered byte load), floored at one request so
///    every tenant can always admit something.
///
/// The returned [`crate::server::ServerConfig`] bounds each *per-tenant*
/// per-shard queue with the deepest per-tenant demand, and budgets one
/// shard's batch against all tenants' resident arenas.
pub fn search_serving_multi_spec(
    tenants: &[TenantSpec],
    space: &SearchSpace,
    cost: &CostModel,
) -> Option<(Vec<TenantPlan>, crate::server::ServerConfig)> {
    use std::time::Duration;

    if tenants.is_empty() {
        return None;
    }
    let total_weight: u64 = tenants.iter().map(|t| u64::from(t.weight.max(1))).sum();
    let threads = cost.threads.max(1);

    // Step 1: per-tenant plans under weight-proportional RAM shares,
    // each under the tenant's own precision policy.
    let mut plans = Vec::with_capacity(tenants.len());
    let mut req_bytes = Vec::with_capacity(tenants.len());
    for t in tenants {
        let mut share = space.clone();
        let w = u64::from(t.weight.max(1));
        share.device.ram_bytes = (space.device.ram_bytes / total_weight).saturating_mul(w);
        share.precision = t.precision.or(space.precision);
        let plan = search(&t.net, &share, cost)?;
        let fov = t.net.field_of_view();
        let vd = [t.load.volume_extent; 3];
        req_bytes.push(
            crate::memory::model::request_memory_bytes(t.net.f_in, t.net.f_out(), vd, fov)
                .max(1),
        );
        plans.push(plan);
    }

    // Step 2: aggregate shard sizing (same currency as search_serving,
    // summed over tenants).
    let measured_overhead = cost.dispatch_overhead_secs.max(0.0);
    let overhead_for = |shard_workers: usize| {
        (measured_overhead * shard_workers as f64 / threads as f64)
            .max(measured_overhead / threads as f64)
    };
    let per_worker_ws: u64 = plans.iter().map(|p| p.est_memory.max(1)).sum();
    let mut best: Option<(usize, f64)> = None;
    let mut shards = 1usize;
    while shards <= threads {
        let shard_workers = (threads / shards).max(1);
        let arenas = per_worker_ws.saturating_mul((shard_workers * shards) as u64);
        let mut inflight = 0u64;
        let mut tp = 0.0f64;
        for (t, (plan, rb)) in tenants.iter().zip(plans.iter().zip(&req_bytes)) {
            let concurrency = shards.min(t.load.clients.max(1));
            inflight = inflight.saturating_add(rb.saturating_mul(concurrency as u64));
            let patch_secs = plan.est_secs * threads as f64 / shard_workers as f64;
            tp += concurrency as f64 * plan.out_voxels as f64
                / (patch_secs + overhead_for(shard_workers));
        }
        let feasible = space.device.fits(arenas.saturating_add(inflight));
        if feasible && best.map(|(_, b)| tp > b).unwrap_or(true) {
            best = Some((shards, tp));
        }
        shards *= 2;
    }
    let (shards, _) = best?;
    let shard_workers = (threads / shards).max(1);
    let shard_arena = per_worker_ws.saturating_mul(shard_workers as u64);
    let arenas = shard_arena.saturating_mul(shards as u64);
    let spare = space.device.ram_bytes.saturating_sub(arenas);

    // Step 3: quota split over the spare RAM, proportional to each
    // tenant's offered byte load, floored at one request each.
    let demand: Vec<u64> = tenants
        .iter()
        .zip(&req_bytes)
        .map(|(t, rb)| rb.saturating_mul(t.load.clients.max(1) as u64))
        .collect();
    let total_demand: u64 = demand.iter().sum::<u64>().max(1);
    let quotas: Vec<u64> = demand
        .iter()
        .zip(&req_bytes)
        .map(|(d, rb)| {
            let share = ((spare as u128 * *d as u128) / total_demand as u128) as u64;
            share.max(*rb)
        })
        .collect();

    // Derived serving config, per-tenant-queue flavoured: queue depth
    // covers the most demanding tenant (the bound applies per tenant),
    // the batch wait follows the slowest tenant's patch time.
    let max_req = req_bytes.iter().copied().max().unwrap_or(1);
    let depth_by_mem = ((spare / max_req).max(1) as usize).min(1 << 16);
    let max_clients = tenants.iter().map(|t| t.load.clients.max(1)).max().unwrap_or(1);
    let queue_depth = crate::util::ceil_div(2 * max_clients, shards).clamp(1, depth_by_mem);
    let max_batch_requests = depth_by_mem.min(max_clients).clamp(1, 8);
    let patch_secs = plans
        .iter()
        .map(|p| p.est_secs * threads as f64 / shard_workers as f64)
        .fold(0.0f64, f64::max);
    let wait_floor = overhead_for(shard_workers).clamp(50e-6, 5e-3);
    let max_batch_wait = Duration::from_secs_f64((patch_secs / 8.0).clamp(wait_floor, 10e-3));
    let memory_budget = (space.device.ram_bytes / shards as u64)
        .max(shard_arena.saturating_add(max_req).saturating_add(1));
    let cfg = crate::server::ServerConfig {
        shards,
        queue_depth,
        max_batch_requests,
        max_batch_wait,
        memory_budget,
        default_deadline: None,
    };
    let tenant_plans = tenants
        .iter()
        .zip(plans)
        .zip(quotas)
        .map(|((t, plan), quota_bytes)| TenantPlan {
            name: t.net.name.clone(),
            plan,
            weight: t.weight.max(1),
            quota_bytes,
            load: t.load,
        })
        .collect();
    Some((tenant_plans, cfg))
}

/// Materialised, executable plan: primitives + weights.
pub struct CompiledPlan {
    /// The plan this was compiled from.
    pub plan: Plan,
    /// Executable primitive per layer, in order.
    pub primitives: Vec<Box<dyn LayerPrimitive>>,
    /// Weights per conv layer, in order.
    pub weights: Vec<Arc<Weights>>,
}

/// Build random (fixed-seed) weights for every conv layer of a net.
pub fn make_weights(net: &NetSpec, seed: u64) -> Vec<Arc<Weights>> {
    let mut out = Vec::new();
    for (li, l) in net.layers.iter().enumerate() {
        if let LayerSpec::Conv { f_out, k } = l {
            out.push(Arc::new(Weights::random(
                *f_out,
                net.f_in_at(li),
                *k,
                seed.wrapping_add(li as u64),
            )));
        }
    }
    out
}

/// Compile a plan into executable primitives with the given weights
/// (one entry per conv layer, in order).
pub fn compile(net: &NetSpec, plan: &Plan, weights: &[Arc<Weights>]) -> Result<CompiledPlan> {
    if weights.len() != net.conv_count() {
        bail!("expected {} weight sets, got {}", net.conv_count(), weights.len());
    }
    let mut prims: Vec<Box<dyn LayerPrimitive>> = Vec::new();
    let mut wi = 0;
    for (li, (l, pl)) in net.layers.iter().zip(&plan.layers).enumerate() {
        match (l, pl) {
            // A fused conv→pool pair: the conv slot becomes the fused
            // primitive (it needs the pool window from the *next* spec
            // layer); the pool slot is matched below as a pass-through.
            (
                LayerSpec::Conv { .. },
                PlanLayer::Conv { algo: ConvAlgo::DirectFusedPool, .. },
            ) => {
                let Some(LayerSpec::Pool { p }) = net.layers.get(li + 1) else {
                    bail!("DirectFusedPool at layer {li} has no following pool layer");
                };
                prims.push(Box::new(FusedConvPoolLayer {
                    weights: weights[wi].clone(),
                    window: *p,
                    act: Activation::Relu,
                }));
                wi += 1;
            }
            (LayerSpec::Pool { .. }, PlanLayer::PoolFused) => {
                prims.push(Box::new(PoolFusedLayer));
            }
            (LayerSpec::Conv { .. }, PlanLayer::Conv { algo, cache_kernels, precision }) => {
                prims.push(Box::new(
                    ConvLayer::new(weights[wi].clone(), *algo, Activation::Relu)
                        .with_kernel_cache(*cache_kernels)
                        .with_precision(*precision),
                ));
                wi += 1;
            }
            (LayerSpec::Pool { p }, PlanLayer::Pool { mode }) => {
                let placement = Placement::Cpu;
                match mode {
                    PoolingMode::Mpf => prims.push(Box::new(MpfLayer { window: *p, placement })),
                    PoolingMode::MaxPool => {
                        prims.push(Box::new(MaxPoolLayer { window: *p, placement }))
                    }
                }
            }
            _ => bail!("plan does not match net layer kinds"),
        }
    }
    Ok(CompiledPlan { plan: plan.clone(), primitives: prims, weights: weights.to_vec() })
}

impl CompiledPlan {
    /// Execute the plan on one input patch against an execution
    /// context. Every intermediate tensor cycles through the context's
    /// arena, so a warm context re-executes without allocating.
    pub fn run(&self, input: Tensor5, ctx: &mut ExecCtx<'_>) -> Tensor5 {
        let mut cur = input;
        for p in &self.primitives {
            debug_assert!(p.accepts(cur.shape()), "{} rejects {}", p.name(), cur.shape());
            cur = p.execute(cur, ctx);
        }
        cur
    }

    /// Arena bytes this plan needs — the max of every layer's Table II
    /// working set at its planned input shape, stacked with the sum of
    /// the resident kernel-spectra rows of every cached layer
    /// ([`WorkspaceReq::stack`]). This is the same model `search` ranked
    /// the plan with, so the arena is sized from the numbers the
    /// optimizer already trusts (planned arena size ≤ `plan.est_memory`
    /// whenever `threads` matches the cost model's).
    pub fn workspace_req(&self, threads: usize) -> WorkspaceReq {
        let mut req = WorkspaceReq::ZERO;
        let mut cur = self.plan.input;
        for (li, p) in self.primitives.iter().enumerate() {
            req = req.stack(p.plan_workspace(cur, threads));
            cur = self.plan.shapes[li];
        }
        req
    }

    /// Build every layer's precomputed kernel spectra now (idempotent —
    /// each layer's cache is built at most once and shared via `Arc`
    /// from then on). Called by [`CompiledPlan::make_ctx`],
    /// [`crate::coordinator::Coordinator::serve`] and
    /// [`crate::server::Server::start`], so the one-off transform cost
    /// lands at plan-build time, never on a request's critical path.
    /// Returns [`CompiledPlan::kernel_cache_bytes`] after warming.
    pub fn warm_kernel_caches(&self, pool: &TaskPool) -> u64 {
        let mut cur = self.plan.input;
        for (li, p) in self.primitives.iter().enumerate() {
            p.warm(cur, pool);
            cur = self.plan.shapes[li];
        }
        self.kernel_cache_bytes()
    }

    /// Resident bytes of the kernel-spectra caches built so far across
    /// this plan's layers (0 before warming / when nothing caches).
    pub fn kernel_cache_bytes(&self) -> u64 {
        self.primitives.iter().map(|p| p.kernel_cache_bytes()).sum()
    }

    /// Shed the single largest resident kernel-spectra cache row to
    /// relieve memory pressure, returning the bytes released (0 when
    /// nothing is resident). Largest-first mirrors the order `search`'s
    /// evaluate fallback drops over-budget cache rows in: the rows
    /// buying the least throughput per byte go first, and the layer
    /// falls back to on-the-fly kernel transforms without affecting
    /// outputs. The shed layer does not rebuild until
    /// [`CompiledPlan::restore_kernel_caches`].
    pub fn shed_largest_kernel_cache(&self) -> u64 {
        let largest = self
            .primitives
            .iter()
            .max_by_key(|p| p.kernel_cache_bytes())
            .filter(|p| p.kernel_cache_bytes() > 0);
        largest.map(|p| p.shed_kernel_cache()).unwrap_or(0)
    }

    /// Re-admit lazy rebuilds of every shed kernel-spectra cache — the
    /// next [`CompiledPlan::warm_kernel_caches`] (every serve call runs
    /// one) builds them back. Called once memory pressure clears.
    pub fn restore_kernel_caches(&self) {
        for p in &self.primitives {
            p.restore_kernel_cache();
        }
    }

    /// Build an execution context whose arena budget is this plan's
    /// [`CompiledPlan::workspace_req`]. The reserve check runs at plan
    /// time — an infeasible budget errors here, never mid-execution.
    /// Kernel-spectra caches are warmed here too (they live beside the
    /// arena, not in it), so execution starts with both the buffers
    /// planned and the spectra resident.
    pub fn make_ctx<'p>(&self, pool: &'p TaskPool) -> Result<ExecCtx<'p>> {
        let req = self.workspace_req(pool.workers());
        self.warm_kernel_caches(pool);
        let mut ctx = ExecCtx::with_budget(pool, req.bytes);
        ctx.reserve(&req)?;
        Ok(ctx)
    }

    /// Device placement check: whether all conv layers are GPU
    /// primitives (GPU-only plan).
    pub fn is_gpu_plan(&self) -> bool {
        self.primitives.iter().all(|p| {
            p.placement() == Placement::Gpu || p.name() == "MPF" || p.name() == "Pool"
        })
    }
}

/// Format a plan as the Table IV rows (layer → primitive tag).
pub fn plan_table(plan: &Plan) -> Vec<(String, String)> {
    let input_row = format!("{}^3 (S={})", plan.input.x, plan.input.s);
    let mut rows = vec![("Input size".to_string(), input_row)];
    for (i, l) in plan.layers.iter().enumerate() {
        rows.push((format!("Layer {}", i + 1), l.tag().to_string()));
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo::tiny_net;
    use crate::util::pool::ChipTopology;

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    fn host(gb: u64) -> Device {
        Device::host_with_ram(gb << 30)
    }

    #[test]
    fn search_finds_feasible_plan() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let space = SearchSpace::cpu_only(host(4), 21);
        let plan = search(&net, &space, &cm).expect("feasible plan");
        assert_eq!(plan.layers.len(), net.layers.len());
        assert!(plan.est_secs > 0.0);
        assert!(plan.out_voxels > 0);
        // MPF-only space ⇒ pool layer must be MPF.
        assert!(matches!(plan.layers[1], PlanLayer::Pool { mode: PoolingMode::Mpf }));
    }

    #[test]
    fn bigger_memory_bigger_input() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let small = search(&net, &SearchSpace::cpu_only(host(1), 41), &cm).unwrap();
        let mut tight_space = SearchSpace::cpu_only(Device::host_with_ram(16 << 20), 41);
        tight_space.max_candidates = 40;
        let tight = search(&net, &tight_space, &cm).unwrap();
        assert!(small.input.x >= tight.input.x, "{} vs {}", small.input.x, tight.input.x);
        assert!(tight.est_memory <= 16 << 20);
    }

    #[test]
    fn memory_constraint_respected() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        for gb in [1u64, 4] {
            if let Some(p) = search(&net, &SearchSpace::cpu_only(host(gb), 41), &cm) {
                assert!(p.est_memory <= gb << 30);
            }
        }
    }

    #[test]
    fn infeasible_space_returns_none() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        // 1 KiB of RAM fits nothing.
        let space = SearchSpace::cpu_only(Device::host_with_ram(1024), 41);
        assert!(search(&net, &space, &cm).is_none());
    }

    #[test]
    fn compile_and_run_plan() {
        let pool = tpool();
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let mut space = SearchSpace::cpu_only(host(4), 13);
        space.max_candidates = 2;
        let plan = search(&net, &space, &cm).unwrap();
        let weights = make_weights(&net, 1);
        let cp = compile(&net, &plan, &weights).unwrap();
        let mut ctx = cp.make_ctx(&pool).unwrap();
        let input = Tensor5::random(plan.input, 2);
        let out = cp.run(input, &mut ctx);
        assert_eq!(out.shape(), *plan.shapes.last().unwrap());
    }

    #[test]
    fn workspace_req_within_table2_estimate() {
        // The arena's planned size must stay within the optimizer's own
        // Table II estimate when computed with the same thread count.
        let net = tiny_net(2);
        let threads = 2;
        let cm = CostModel::default_rates(threads);
        let mut space = SearchSpace::cpu_only(host(4), 15);
        space.max_candidates = 2;
        let plan = search(&net, &space, &cm).unwrap();
        let weights = make_weights(&net, 1);
        let cp = compile(&net, &plan, &weights).unwrap();
        let req = cp.workspace_req(threads);
        assert!(req.bytes > 0);
        assert!(
            req.bytes <= plan.est_memory,
            "planned arena {} exceeds Table II estimate {}",
            req.bytes,
            plan.est_memory
        );
    }

    #[test]
    fn search_serving_returns_plan_and_config() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(4);
        let space = SearchSpace::cpu_only(host(4), 15);
        let load = crate::server::ServingLoad { clients: 4, volume_extent: 20 };
        let (plan, cfg) = search_serving(&net, &space, &cm, &load).expect("feasible");
        assert!(plan.est_secs > 0.0);
        assert!(cfg.shards >= 1 && cfg.shards <= 4);
        assert!(cfg.queue_depth >= 1);
        assert!(cfg.max_batch_requests >= 1);
        assert!(cfg.max_batch_wait > std::time::Duration::ZERO);
        // The budget must admit the shard's arenas plus one request —
        // the Server::start gate relies on this.
        let shard_workers = (cm.threads / cfg.shards).max(1);
        assert!(cfg.memory_budget > plan.est_memory * shard_workers as u64);
    }

    #[test]
    fn search_serving_multi_splits_budget_across_tenants() {
        let minis = crate::net::zoo::bench_miniatures();
        let cm = CostModel::default_rates(4);
        // mini537's field of view is 18³: the search space must admit
        // at least that extent for a feasible plan.
        let space = SearchSpace::cpu_only(host(4), 19);
        let tenants = vec![
            (minis[0].clone(), crate::server::ServingLoad { clients: 4, volume_extent: 19 }, 2),
            (minis[1].clone(), crate::server::ServingLoad { clients: 2, volume_extent: 19 }, 1),
        ];
        let (plans, cfg) = search_serving_multi(&tenants, &space, &cm).expect("feasible");
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].name, "mini337");
        assert_eq!(plans[1].name, "mini537");
        assert_eq!(plans[0].weight, 2);
        let mut quota_sum = 0u64;
        for (tp, (net, load, _)) in plans.iter().zip(&tenants) {
            let vd = [load.volume_extent; 3];
            let rb = crate::memory::model::request_memory_bytes(
                net.f_in,
                net.f_out(),
                vd,
                net.field_of_view(),
            );
            assert!(tp.quota_bytes >= rb, "{}: quota admits at least one request", tp.name);
            quota_sum += tp.quota_bytes;
        }
        assert!(quota_sum <= space.device.ram_bytes, "quotas never exceed the device");
        // mini337 offers 2× the clients at equal extent: its quota
        // share must not be smaller than mini537's.
        assert!(plans[0].quota_bytes >= plans[1].quota_bytes);
        assert!(cfg.shards >= 1 && cfg.queue_depth >= 1 && cfg.max_batch_requests >= 1);
        // The budget gate TenantServer::start applies: both tenants'
        // shard arenas plus one request must fit.
        let shard_workers = (cm.threads / cfg.shards).max(1);
        let arenas: u64 =
            plans.iter().map(|t| t.plan.est_memory * shard_workers as u64).sum();
        assert!(cfg.memory_budget > arenas);
    }

    #[test]
    fn search_serving_multi_rejects_empty_tenant_set() {
        let cm = CostModel::default_rates(2);
        let space = SearchSpace::cpu_only(host(4), 15);
        assert!(search_serving_multi(&[], &space, &cm).is_none());
    }

    #[test]
    fn tenant_precision_override_is_per_tenant() {
        // A tenant pinned to f16 gets half-width conv layers while its
        // unpinned sibling on the same box inherits the process default
        // (f32 — ZNNI_PRECISION is unset under test), in one search.
        let minis = crate::net::zoo::bench_miniatures();
        let cm = CostModel::default_rates(4);
        let mut space = SearchSpace::cpu_only(host(4), 19);
        space.algos = vec![ConvAlgo::FftTaskParallel];
        let load = crate::server::ServingLoad { clients: 2, volume_extent: 19 };
        let tenants = vec![
            TenantSpec {
                net: minis[0].clone(),
                load,
                weight: 1,
                precision: Some(crate::precision::PrecisionMode::F16),
            },
            TenantSpec { net: minis[1].clone(), load, weight: 1, precision: None },
        ];
        let (plans, _) = search_serving_multi_spec(&tenants, &space, &cm).expect("feasible");
        for l in &plans[0].plan.layers {
            if let PlanLayer::Conv { precision, .. } = l {
                assert_eq!(*precision, crate::precision::Precision::F16, "pinned tenant");
            }
        }
        for l in &plans[1].plan.layers {
            if let PlanLayer::Conv { precision, .. } = l {
                assert_eq!(*precision, crate::precision::Precision::F32, "unpinned tenant");
            }
        }
    }

    #[test]
    fn search_serving_scales_shards_with_clients() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(8);
        let space = SearchSpace::cpu_only(host(8), 15);
        let one = crate::server::ServingLoad { clients: 1, volume_extent: 20 };
        let many = crate::server::ServingLoad { clients: 16, volume_extent: 20 };
        let (_, c1) = search_serving(&net, &space, &cm, &one).unwrap();
        let (_, c16) = search_serving(&net, &space, &cm, &many).unwrap();
        assert!(
            c16.shards >= c1.shards,
            "more clients must not shrink the shard count ({} vs {})",
            c16.shards,
            c1.shards
        );
        assert!(c16.shards * c16.queue_depth >= c1.shards * c1.queue_depth);
    }

    #[test]
    fn gpu_space_uses_gpu_algos() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let space = SearchSpace::gpu_only(Device::titan_x(), 21);
        let plan = search(&net, &space, &cm).unwrap();
        for l in &plan.layers {
            if let PlanLayer::Conv { algo, .. } = l {
                assert!(algo.is_gpu());
            }
        }
    }

    #[test]
    fn search_accounts_kernel_cache_in_memory() {
        // Force the FFT family so the cache axis is exercised: with
        // ample RAM the searched plan caches its kernel spectra, the
        // spectra bytes land in est_memory, and workspace_req carries
        // them as the resident row.
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let mut space = SearchSpace::cpu_only(host(4), 15);
        space.algos = vec![ConvAlgo::FftTaskParallel];
        space.max_candidates = 2;
        let plan = search(&net, &space, &cm).expect("feasible");
        assert!(plan.kernel_cache_bytes > 0, "ample RAM must admit the spectra cache");
        assert!(plan.est_memory > plan.kernel_cache_bytes);
        let conv_cached: Vec<bool> = plan
            .layers
            .iter()
            .filter_map(|l| match l {
                PlanLayer::Conv { cache_kernels, .. } => Some(*cache_kernels),
                _ => None,
            })
            .collect();
        assert!(conv_cached.iter().all(|&c| c), "every FFT layer should cache under 4 GiB");
        let weights = make_weights(&net, 1);
        let cp = compile(&net, &plan, &weights).unwrap();
        let req = cp.workspace_req(cm.threads);
        assert_eq!(req.resident_bytes, plan.kernel_cache_bytes);
        assert!(req.total() <= plan.est_memory);
    }

    #[test]
    fn default_precision_mode_keeps_plans_f32() {
        // Reduced precision is opt-in: with ZNNI_PRECISION unset (the
        // default f32 mode) every searched conv layer must come out at
        // full width, with the full-size spectra row. The half-width
        // selection path is exercised (serialized) in
        // tests/integration_precision.rs.
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let mut space = SearchSpace::cpu_only(host(4), 15);
        space.algos = vec![ConvAlgo::FftTaskParallel];
        space.max_candidates = 2;
        let plan = search(&net, &space, &cm).expect("feasible");
        for l in &plan.layers {
            if let PlanLayer::Conv { precision, .. } = l {
                assert_eq!(*precision, crate::precision::Precision::F32);
            }
        }
        assert!(plan.kernel_cache_bytes > 0, "f32 caching itself must still engage");
    }

    #[test]
    fn over_budget_cache_falls_back_to_recompute() {
        // Pin the candidate to one extent, find the uncached footprint,
        // then offer exactly that much RAM: the cached variant no longer
        // fits, so the search must return the same plan with
        // cache_kernels = false instead of failing.
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let mut space = SearchSpace::cpu_only(host(4), 15);
        space.algos = vec![ConvAlgo::FftTaskParallel];
        space.max_candidates = 1;
        let roomy = search(&net, &space, &cm).expect("feasible");
        assert!(roomy.kernel_cache_bytes > 0);
        let uncached_peak = roomy.est_memory - roomy.kernel_cache_bytes;
        let mut tight = space.clone();
        tight.device = Device::host_with_ram(uncached_peak);
        tight.min_extent = roomy.input.x;
        tight.max_extent = roomy.input.x;
        let fallback = search(&net, &tight, &cm).expect("recompute fallback must be feasible");
        assert_eq!(fallback.input, roomy.input);
        assert_eq!(fallback.kernel_cache_bytes, 0, "over-budget cache must be rejected");
        assert!(fallback.est_memory <= uncached_peak);
        assert!(
            fallback.est_secs > roomy.est_secs,
            "dropping the cache pays the kernel transforms back"
        );
        for l in &fallback.layers {
            if let PlanLayer::Conv { cache_kernels, .. } = l {
                assert!(!cache_kernels);
            }
        }
    }

    #[test]
    fn search_selects_fused_direct_for_small_kernel_layers() {
        // Acceptance: under default calibration the register-tiled
        // fused family must win at least one small-kernel (k = 3) conv
        // layer of a zoo net in the default CPU space.
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let plan = search(&net, &SearchSpace::cpu_only(host(4), 21), &cm).expect("feasible");
        let fused_layers = plan
            .layers
            .iter()
            .filter(|l| {
                matches!(
                    l,
                    PlanLayer::Conv {
                        algo: ConvAlgo::DirectFused | ConvAlgo::DirectFusedPool,
                        ..
                    }
                )
            })
            .count();
        assert!(fused_layers > 0, "no fused layer in {:?}", plan.layers);
    }

    #[test]
    fn fusion_lookahead_drops_inter_layer_tensor() {
        // Under max-pool modes the fused pair must be chosen, its plan
        // must carry the (fused) pass-through slot, and est_memory must
        // drop relative to the same space without the fused algorithm —
        // the eliminated inter-layer tensor.
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let space = SearchSpace::cpu_only(host(4), 21);
        let input = Shape5::new(1, net.f_in, 14, 14, 14);
        let modes = [PoolingMode::MaxPool];
        let with = evaluate(&net, input, &modes, &space, &cm).expect("fused feasible");
        assert!(
            matches!(with.layers[0], PlanLayer::Conv { algo: ConvAlgo::DirectFusedPool, .. }),
            "{:?}",
            with.layers
        );
        assert_eq!(with.layers[1], PlanLayer::PoolFused);
        assert_eq!(with.modes(), vec![PoolingMode::MaxPool], "fused slot counts as max-pool");
        let mut no_fuse = space.clone();
        no_fuse.algos.retain(|a| *a != ConvAlgo::DirectFusedPool);
        let without = evaluate(&net, input, &modes, &no_fuse, &cm).expect("unfused feasible");
        assert!(
            with.est_memory < without.est_memory,
            "fusion must shrink the peak: {} vs {}",
            with.est_memory,
            without.est_memory
        );
        assert!(with.est_secs < without.est_secs, "fused pair saves the separate pool pass");
    }

    #[test]
    fn fused_plan_compiles_and_runs() {
        let pool = tpool();
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let space = SearchSpace::cpu_only(host(4), 21);
        let input_sh = Shape5::new(1, net.f_in, 14, 14, 14);
        let modes = [PoolingMode::MaxPool];
        let with = evaluate(&net, input_sh, &modes, &space, &cm).unwrap();
        let mut no_fuse = space;
        no_fuse.algos.retain(|a| *a != ConvAlgo::DirectFusedPool);
        let without = evaluate(&net, input_sh, &modes, &no_fuse, &cm).unwrap();
        let weights = make_weights(&net, 5);
        let cp_with = compile(&net, &with, &weights).unwrap();
        let cp_without = compile(&net, &without, &weights).unwrap();
        let input = Tensor5::random(input_sh, 6);
        let mut ctx = cp_with.make_ctx(&pool).unwrap();
        let a = cp_with.run(input.clone_tensor(), &mut ctx);
        assert_eq!(a.shape(), *with.shapes.last().unwrap());
        let mut ctx2 = cp_without.make_ctx(&pool).unwrap();
        let b = cp_without.run(input, &mut ctx2);
        crate::util::quick::assert_allclose(a.data(), b.data(), 1e-4, 1e-3, "fused plan");
    }

    #[test]
    fn mode_assignment_enumeration() {
        assert_eq!(mode_assignments(2, false).len(), 1);
        assert_eq!(mode_assignments(2, true).len(), 4);
        assert_eq!(mode_assignments(0, true).len(), 1);
    }

    #[test]
    fn plan_table_has_row_per_layer() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let plan = search(&net, &SearchSpace::cpu_only(host(4), 21), &cm).unwrap();
        let rows = plan_table(&plan);
        assert_eq!(rows.len(), net.layers.len() + 1);
    }
}
