//! Throughput optimizer — the exhaustive search of §VI.A.
//!
//! For a fixed choice of max-pool vs MPF per pooling layer and a fixed
//! input shape, the time and memory of every candidate primitive per
//! layer are uniquely determined — so the search:
//!
//! 1. loops over pooling-mode assignments,
//! 2. loops over allowed input shapes (and batch sizes),
//! 3. picks, per convolutional layer, the fastest primitive whose
//!    Table II memory fits the device,
//!
//! and keeps the plan with the highest estimated throughput
//! (`Size(I′) / Σ Time(primitiveᵢ, Iᵢ)`). Plans can then be *executed*
//! to measure real throughput.
//!
//! ```
//! use znni::device::Device;
//! use znni::net::zoo::tiny_net;
//! use znni::optimizer::{search, CostModel, SearchSpace};
//!
//! let net = tiny_net(2);
//! let space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
//! let plan = search(&net, &space, &CostModel::default_rates(2)).expect("feasible");
//! assert_eq!(plan.layers.len(), net.layers.len());
//! assert!(plan.est_throughput() > 0.0);
//! ```

pub mod cost;
pub mod theory;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::conv::{Activation, Weights};
use crate::device::Device;
use crate::exec::{ExecCtx, WorkspaceReq};
use crate::layers::{ConvLayer, LayerPrimitive, MaxPoolLayer, MpfLayer, Placement};
use crate::memory::model::{
    conv_memory_bytes, mpf_memory_bytes, pool_memory_bytes, ConvAlgo, ConvDims,
};
use crate::net::{LayerSpec, NetSpec, PoolingMode};
use crate::tensor::{Shape5, Tensor5};
use crate::util::pool::TaskPool;

pub use cost::CostModel;

/// Per-layer decision of a plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanLayer {
    /// A convolutional layer executed with the chosen algorithm.
    Conv {
        /// The algorithm the search picked for this layer.
        algo: ConvAlgo,
    },
    /// A pooling layer realised in the chosen mode.
    Pool {
        /// Max-pool or MPF.
        mode: PoolingMode,
    },
}

impl PlanLayer {
    /// Short Table IV tag of this decision.
    pub fn tag(&self) -> &'static str {
        match self {
            PlanLayer::Conv { algo } => algo.tag(),
            PlanLayer::Pool { mode } => match mode {
                PoolingMode::Mpf => "MPF",
                PoolingMode::MaxPool => "Pool",
            },
        }
    }
}

/// A fully determined execution plan for one input patch.
#[derive(Clone, Debug)]
pub struct Plan {
    /// Name of the planned network.
    pub net_name: String,
    /// Chosen input patch shape.
    pub input: Shape5,
    /// Per-layer decisions, in layer order.
    pub layers: Vec<PlanLayer>,
    /// Shape after each layer.
    pub shapes: Vec<Shape5>,
    /// Estimated seconds per patch (cost model).
    pub est_secs: f64,
    /// Peak Table II memory across layers (bytes).
    pub est_memory: u64,
    /// Output voxels per patch: S′ · x′·y′·z′ (spatial positions of the
    /// sliding-window output covered by one patch).
    pub out_voxels: u64,
}

impl Plan {
    /// Estimated throughput: output voxels per estimated second.
    pub fn est_throughput(&self) -> f64 {
        self.out_voxels as f64 / self.est_secs
    }

    /// Pooling modes of this plan in pool-layer order.
    pub fn modes(&self) -> Vec<PoolingMode> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                PlanLayer::Pool { mode } => Some(*mode),
                _ => None,
            })
            .collect()
    }
}

/// Search constraints: which algorithms may be used and on what device.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// Device whose RAM constrains every candidate.
    pub device: Device,
    /// Conv algorithms the search may choose from.
    pub algos: Vec<ConvAlgo>,
    /// Allow max-pool (in addition to MPF) in the pooling assignment
    /// loop. The paper's result is that MPF always wins; keeping both
    /// lets the benches demonstrate that.
    pub allow_maxpool: bool,
    /// Candidate batch sizes (the paper finds S = 1 optimal for ≥2-pool
    /// nets; Fig 4 sweeps this).
    pub batch_sizes: Vec<usize>,
    /// Inclusive range of cubic input extents to consider.
    pub min_extent: usize,
    /// Largest cubic input extent to consider.
    pub max_extent: usize,
    /// Cap on candidate extents actually evaluated (largest kept).
    pub max_candidates: usize,
}

impl SearchSpace {
    /// CPU-only search (§VI): CPU primitives against host RAM.
    pub fn cpu_only(device: Device, max_extent: usize) -> Self {
        SearchSpace {
            device,
            algos: vec![
                ConvAlgo::DirectNaive,
                ConvAlgo::DirectMkl,
                ConvAlgo::FftDataParallel,
                ConvAlgo::FftTaskParallel,
            ],
            allow_maxpool: false,
            batch_sizes: vec![1],
            min_extent: 1,
            max_extent,
            max_candidates: 12,
        }
    }

    /// GPU-only search (§VI): GPU primitives against device RAM.
    pub fn gpu_only(device: Device, max_extent: usize) -> Self {
        SearchSpace {
            device,
            algos: vec![
                ConvAlgo::GpuDenseNoWorkspace,
                ConvAlgo::GpuDensePrecomp,
                ConvAlgo::GpuFft,
            ],
            allow_maxpool: false,
            batch_sizes: vec![1],
            min_extent: 1,
            max_extent,
            max_candidates: 12,
        }
    }
}

/// All pooling-mode assignments (2^pools, or MPF-only).
fn mode_assignments(pools: usize, allow_maxpool: bool) -> Vec<Vec<PoolingMode>> {
    if !allow_maxpool {
        return vec![vec![PoolingMode::Mpf; pools]];
    }
    (0..(1usize << pools))
        .map(|mask| {
            (0..pools)
                .map(|i| {
                    if mask & (1 << i) != 0 {
                        PoolingMode::MaxPool
                    } else {
                        PoolingMode::Mpf
                    }
                })
                .collect()
        })
        .collect()
}

/// Evaluate one (modes, input) candidate: per-layer fastest primitive
/// under the memory constraint. Returns None if any layer has no
/// feasible primitive.
fn evaluate(
    net: &NetSpec,
    input: Shape5,
    modes: &[PoolingMode],
    space: &SearchSpace,
    cost: &CostModel,
) -> Option<Plan> {
    let shapes = net.shapes(input, modes).ok()?;
    let mut cur = input;
    let mut layers = Vec::with_capacity(net.layers.len());
    let mut est_secs = 0.0;
    let mut est_memory = 0u64;
    let mut pool_i = 0;
    for (li, l) in net.layers.iter().enumerate() {
        match l {
            LayerSpec::Conv { f_out, k } => {
                let d = ConvDims {
                    s: cur.s,
                    f_in: net.f_in_at(li),
                    f_out: *f_out,
                    n: cur.spatial(),
                    k: *k,
                };
                let mut best: Option<(ConvAlgo, f64, u64)> = None;
                for &algo in &space.algos {
                    let mem = conv_memory_bytes(algo, &d, cost.threads);
                    if !space.device.fits(mem) {
                        continue;
                    }
                    let t = cost.conv_secs(algo, &d, &space.device);
                    if best.map(|(_, bt, _)| t < bt).unwrap_or(true) {
                        best = Some((algo, t, mem));
                    }
                }
                let (algo, t, mem) = best?;
                layers.push(PlanLayer::Conv { algo });
                est_secs += t;
                est_memory = est_memory.max(mem);
            }
            LayerSpec::Pool { p } => {
                let mode = modes[pool_i];
                pool_i += 1;
                let mem = match mode {
                    PoolingMode::Mpf => mpf_memory_bytes(cur.s, cur.f, cur.spatial(), *p),
                    PoolingMode::MaxPool => pool_memory_bytes(cur.s, cur.f, cur.spatial(), *p),
                };
                if !space.device.fits(mem) {
                    return None;
                }
                layers.push(PlanLayer::Pool { mode });
                est_secs +=
                    cost.pool_secs(cur.s, cur.f, cur.spatial(), *p, mode == PoolingMode::Mpf);
                est_memory = est_memory.max(mem);
            }
        }
        cur = shapes[li];
    }
    let out = *shapes.last().unwrap();
    Some(Plan {
        net_name: net.name.clone(),
        input,
        layers,
        shapes,
        est_secs,
        est_memory,
        out_voxels: (out.s * out.x * out.y * out.z) as u64,
    })
}

/// Exhaustive search per §VI.A. Returns the best plan (highest
/// estimated throughput) if any candidate is feasible.
pub fn search(net: &NetSpec, space: &SearchSpace, cost: &CostModel) -> Option<Plan> {
    let mut best: Option<Plan> = None;
    for modes in mode_assignments(net.pool_count(), space.allow_maxpool) {
        let mut extents = net.valid_extents(space.min_extent, space.max_extent, &modes);
        // Keep only the largest few candidates — throughput grows with
        // input size until memory runs out (§II), so the optimum is at
        // the memory frontier.
        if extents.len() > space.max_candidates {
            extents = extents.split_off(extents.len() - space.max_candidates);
        }
        for &s in &space.batch_sizes {
            for &n in &extents {
                let input = Shape5::new(s, net.f_in, n, n, n);
                if let Some(p) = evaluate(net, input, &modes, space, cost) {
                    let cur_best = best.as_ref().map(|b| b.est_throughput());
                    if cur_best.map(|b| p.est_throughput() > b).unwrap_or(true) {
                        best = Some(p);
                    }
                }
            }
        }
    }
    best
}

/// Search the plan **and** the serving configuration in one call.
///
/// The serving layer obeys the same law the plan search does: amortize
/// fixed overheads over the largest workload the memory budget admits
/// (§III, Fig. 5) — at the request level that means picking how many
/// coordinator shards run, how deep the admission queues are and how
/// long the micro-batcher waits. This coarse search models, per shard
/// count `c` (powers of two up to the cost model's threads):
///
/// * **memory** — every worker keeps one warm Table II arena
///   (`plan.est_memory`), plus one in-flight request (input + dense
///   output, [`crate::memory::model::request_memory_bytes`]) per busy
///   shard; candidates that do not fit the device are discarded;
/// * **time** — per-patch seconds scale with the thread share a shard
///   gets, plus the per-batch dispatch overhead
///   ([`CostModel::dispatch_overhead_secs`]) that more shards amortize
///   across concurrent clients. The overhead is a *measured* quantity:
///   [`cost::measure_dispatch_overhead`] (run by
///   [`CostModel::calibrate_full`]) times the worker spawn + hand-off
///   this machine actually pays, replacing the old fixed 200 µs
///   assumption; uncalibrated models fall back to
///   [`cost::DEFAULT_DISPATCH_OVERHEAD_SECS`].
///
/// Queue depth (Little's-law-style: two outstanding requests per
/// client, split across shards, capped by spare RAM), the batch cap and
/// the batch wait are then derived from the winning shard count.
pub fn search_serving(
    net: &NetSpec,
    space: &SearchSpace,
    cost: &CostModel,
    load: &crate::server::ServingLoad,
) -> Option<(Plan, crate::server::ServerConfig)> {
    use std::time::Duration;

    let plan = search(net, space, cost)?;
    let fov = net.field_of_view();
    let vd = [load.volume_extent; 3];
    let req_bytes =
        crate::memory::model::request_memory_bytes(net.f_in, net.f_out(), vd, fov).max(1);
    let threads = cost.threads.max(1);
    let per_worker_ws = plan.est_memory.max(1);
    let clients = load.clients.max(1);
    // Fixed per-batch dispatch cost (worker spawn + assembly) — the
    // request-level analogue of the per-patch fixed overheads the paper
    // amortizes with bigger images. Measured by the calibration harness
    // (`CostModel::calibrate_full`) for the *full* pool; a shard's
    // batch only spawns its own worker share, and thread spawn/join
    // dominates the measurement, so the charge scales linearly with the
    // shard's worker count (floored at one thread's worth).
    let measured_overhead = cost.dispatch_overhead_secs.max(0.0);
    let overhead_for = |shard_workers: usize| {
        (measured_overhead * shard_workers as f64 / threads as f64)
            .max(measured_overhead / threads as f64)
    };

    let mut best: Option<(usize, f64)> = None;
    let mut shards = 1usize;
    while shards <= threads {
        let shard_workers = (threads / shards).max(1);
        let arenas = per_worker_ws.saturating_mul((shard_workers * shards) as u64);
        let concurrency = shards.min(clients);
        let inflight = req_bytes.saturating_mul(concurrency as u64);
        if space.device.fits(arenas.saturating_add(inflight)) {
            let patch_secs = plan.est_secs * threads as f64 / shard_workers as f64;
            let tp = concurrency as f64 * plan.out_voxels as f64
                / (patch_secs + overhead_for(shard_workers));
            if best.map(|(_, b)| tp > b).unwrap_or(true) {
                best = Some((shards, tp));
            }
        }
        shards *= 2;
    }
    let (shards, _) = best?;
    let shard_workers = (threads / shards).max(1);
    let shard_arena = per_worker_ws.saturating_mul(shard_workers as u64);
    let arenas = shard_arena.saturating_mul(shards as u64);
    let spare = space.device.ram_bytes.saturating_sub(arenas);
    let depth_by_mem = ((spare / req_bytes).max(1) as usize).min(1 << 16);
    let queue_depth = crate::util::ceil_div(2 * clients, shards).clamp(1, depth_by_mem);
    let max_batch_requests = depth_by_mem.min(clients).clamp(1, 8);
    let patch_secs = plan.est_secs * threads as f64 / shard_workers as f64;
    // Waiting less than one dispatch overhead for co-batchable requests
    // cannot pay for itself, so the winning shard size's measured
    // overhead floors the wait.
    let wait_floor = overhead_for(shard_workers).clamp(50e-6, 5e-3);
    let max_batch_wait = Duration::from_secs_f64((patch_secs / 8.0).clamp(wait_floor, 10e-3));
    // Per-shard batch budget: an even share of device RAM, but always
    // enough for the shard's warm arenas plus one typical request (the
    // start-time admission gate requires strict headroom).
    let memory_budget = (space.device.ram_bytes / shards as u64)
        .max(shard_arena.saturating_add(req_bytes).saturating_add(1));
    let cfg = crate::server::ServerConfig {
        shards,
        queue_depth,
        max_batch_requests,
        max_batch_wait,
        memory_budget,
        default_deadline: None,
    };
    Some((plan, cfg))
}

/// Materialised, executable plan: primitives + weights.
pub struct CompiledPlan {
    /// The plan this was compiled from.
    pub plan: Plan,
    /// Executable primitive per layer, in order.
    pub primitives: Vec<Box<dyn LayerPrimitive>>,
    /// Weights per conv layer, in order.
    pub weights: Vec<Arc<Weights>>,
}

/// Build random (fixed-seed) weights for every conv layer of a net.
pub fn make_weights(net: &NetSpec, seed: u64) -> Vec<Arc<Weights>> {
    let mut out = Vec::new();
    for (li, l) in net.layers.iter().enumerate() {
        if let LayerSpec::Conv { f_out, k } = l {
            out.push(Arc::new(Weights::random(
                *f_out,
                net.f_in_at(li),
                *k,
                seed.wrapping_add(li as u64),
            )));
        }
    }
    out
}

/// Compile a plan into executable primitives with the given weights
/// (one entry per conv layer, in order).
pub fn compile(net: &NetSpec, plan: &Plan, weights: &[Arc<Weights>]) -> Result<CompiledPlan> {
    if weights.len() != net.conv_count() {
        bail!("expected {} weight sets, got {}", net.conv_count(), weights.len());
    }
    let mut prims: Vec<Box<dyn LayerPrimitive>> = Vec::new();
    let mut wi = 0;
    for (l, pl) in net.layers.iter().zip(&plan.layers) {
        match (l, pl) {
            (LayerSpec::Conv { .. }, PlanLayer::Conv { algo }) => {
                prims.push(Box::new(ConvLayer::new(
                    weights[wi].clone(),
                    *algo,
                    Activation::Relu,
                )));
                wi += 1;
            }
            (LayerSpec::Pool { p }, PlanLayer::Pool { mode }) => {
                let placement = Placement::Cpu;
                match mode {
                    PoolingMode::Mpf => prims.push(Box::new(MpfLayer { window: *p, placement })),
                    PoolingMode::MaxPool => {
                        prims.push(Box::new(MaxPoolLayer { window: *p, placement }))
                    }
                }
            }
            _ => bail!("plan does not match net layer kinds"),
        }
    }
    Ok(CompiledPlan { plan: plan.clone(), primitives: prims, weights: weights.to_vec() })
}

impl CompiledPlan {
    /// Execute the plan on one input patch against an execution
    /// context. Every intermediate tensor cycles through the context's
    /// arena, so a warm context re-executes without allocating.
    pub fn run(&self, input: Tensor5, ctx: &mut ExecCtx<'_>) -> Tensor5 {
        let mut cur = input;
        for p in &self.primitives {
            debug_assert!(p.accepts(cur.shape()), "{} rejects {}", p.name(), cur.shape());
            cur = p.execute(cur, ctx);
        }
        cur
    }

    /// Arena bytes this plan needs — the max of every layer's Table II
    /// working set at its planned input shape. This is the same model
    /// `search` ranked the plan with, so the arena is sized from the
    /// numbers the optimizer already trusts (planned size ≤
    /// `plan.est_memory` whenever `threads` matches the cost model's).
    pub fn workspace_req(&self, threads: usize) -> WorkspaceReq {
        let mut req = WorkspaceReq::ZERO;
        let mut cur = self.plan.input;
        for (li, p) in self.primitives.iter().enumerate() {
            req = req.max(p.plan_workspace(cur, threads));
            cur = self.plan.shapes[li];
        }
        req
    }

    /// Build an execution context whose arena budget is this plan's
    /// [`CompiledPlan::workspace_req`]. The reserve check runs at plan
    /// time — an infeasible budget errors here, never mid-execution.
    pub fn make_ctx<'p>(&self, pool: &'p TaskPool) -> Result<ExecCtx<'p>> {
        let req = self.workspace_req(pool.workers());
        let mut ctx = ExecCtx::with_budget(pool, req.bytes);
        ctx.reserve(&req)?;
        Ok(ctx)
    }

    /// Device placement check: whether all conv layers are GPU
    /// primitives (GPU-only plan).
    pub fn is_gpu_plan(&self) -> bool {
        self.primitives.iter().all(|p| {
            p.placement() == Placement::Gpu || p.name() == "MPF" || p.name() == "Pool"
        })
    }
}

/// Format a plan as the Table IV rows (layer → primitive tag).
pub fn plan_table(plan: &Plan) -> Vec<(String, String)> {
    let input_row = format!("{}^3 (S={})", plan.input.x, plan.input.s);
    let mut rows = vec![("Input size".to_string(), input_row)];
    for (i, l) in plan.layers.iter().enumerate() {
        rows.push((format!("Layer {}", i + 1), l.tag().to_string()));
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo::tiny_net;
    use crate::util::pool::ChipTopology;

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    fn host(gb: u64) -> Device {
        Device::host_with_ram(gb << 30)
    }

    #[test]
    fn search_finds_feasible_plan() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let space = SearchSpace::cpu_only(host(4), 21);
        let plan = search(&net, &space, &cm).expect("feasible plan");
        assert_eq!(plan.layers.len(), net.layers.len());
        assert!(plan.est_secs > 0.0);
        assert!(plan.out_voxels > 0);
        // MPF-only space ⇒ pool layer must be MPF.
        assert!(matches!(plan.layers[1], PlanLayer::Pool { mode: PoolingMode::Mpf }));
    }

    #[test]
    fn bigger_memory_bigger_input() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let small = search(&net, &SearchSpace::cpu_only(host(1), 41), &cm).unwrap();
        let mut tight_space = SearchSpace::cpu_only(Device::host_with_ram(16 << 20), 41);
        tight_space.max_candidates = 40;
        let tight = search(&net, &tight_space, &cm).unwrap();
        assert!(small.input.x >= tight.input.x, "{} vs {}", small.input.x, tight.input.x);
        assert!(tight.est_memory <= 16 << 20);
    }

    #[test]
    fn memory_constraint_respected() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        for gb in [1u64, 4] {
            if let Some(p) = search(&net, &SearchSpace::cpu_only(host(gb), 41), &cm) {
                assert!(p.est_memory <= gb << 30);
            }
        }
    }

    #[test]
    fn infeasible_space_returns_none() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        // 1 KiB of RAM fits nothing.
        let space = SearchSpace::cpu_only(Device::host_with_ram(1024), 41);
        assert!(search(&net, &space, &cm).is_none());
    }

    #[test]
    fn compile_and_run_plan() {
        let pool = tpool();
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let mut space = SearchSpace::cpu_only(host(4), 13);
        space.max_candidates = 2;
        let plan = search(&net, &space, &cm).unwrap();
        let weights = make_weights(&net, 1);
        let cp = compile(&net, &plan, &weights).unwrap();
        let mut ctx = cp.make_ctx(&pool).unwrap();
        let input = Tensor5::random(plan.input, 2);
        let out = cp.run(input, &mut ctx);
        assert_eq!(out.shape(), *plan.shapes.last().unwrap());
    }

    #[test]
    fn workspace_req_within_table2_estimate() {
        // The arena's planned size must stay within the optimizer's own
        // Table II estimate when computed with the same thread count.
        let net = tiny_net(2);
        let threads = 2;
        let cm = CostModel::default_rates(threads);
        let mut space = SearchSpace::cpu_only(host(4), 15);
        space.max_candidates = 2;
        let plan = search(&net, &space, &cm).unwrap();
        let weights = make_weights(&net, 1);
        let cp = compile(&net, &plan, &weights).unwrap();
        let req = cp.workspace_req(threads);
        assert!(req.bytes > 0);
        assert!(
            req.bytes <= plan.est_memory,
            "planned arena {} exceeds Table II estimate {}",
            req.bytes,
            plan.est_memory
        );
    }

    #[test]
    fn search_serving_returns_plan_and_config() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(4);
        let space = SearchSpace::cpu_only(host(4), 15);
        let load = crate::server::ServingLoad { clients: 4, volume_extent: 20 };
        let (plan, cfg) = search_serving(&net, &space, &cm, &load).expect("feasible");
        assert!(plan.est_secs > 0.0);
        assert!(cfg.shards >= 1 && cfg.shards <= 4);
        assert!(cfg.queue_depth >= 1);
        assert!(cfg.max_batch_requests >= 1);
        assert!(cfg.max_batch_wait > std::time::Duration::ZERO);
        // The budget must admit the shard's arenas plus one request —
        // the Server::start gate relies on this.
        let shard_workers = (cm.threads / cfg.shards).max(1);
        assert!(cfg.memory_budget > plan.est_memory * shard_workers as u64);
    }

    #[test]
    fn search_serving_scales_shards_with_clients() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(8);
        let space = SearchSpace::cpu_only(host(8), 15);
        let one = crate::server::ServingLoad { clients: 1, volume_extent: 20 };
        let many = crate::server::ServingLoad { clients: 16, volume_extent: 20 };
        let (_, c1) = search_serving(&net, &space, &cm, &one).unwrap();
        let (_, c16) = search_serving(&net, &space, &cm, &many).unwrap();
        assert!(
            c16.shards >= c1.shards,
            "more clients must not shrink the shard count ({} vs {})",
            c16.shards,
            c1.shards
        );
        assert!(c16.shards * c16.queue_depth >= c1.shards * c1.queue_depth);
    }

    #[test]
    fn gpu_space_uses_gpu_algos() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let space = SearchSpace::gpu_only(Device::titan_x(), 21);
        let plan = search(&net, &space, &cm).unwrap();
        for l in &plan.layers {
            if let PlanLayer::Conv { algo } = l {
                assert!(algo.is_gpu());
            }
        }
    }

    #[test]
    fn mode_assignment_enumeration() {
        assert_eq!(mode_assignments(2, false).len(), 1);
        assert_eq!(mode_assignments(2, true).len(), 4);
        assert_eq!(mode_assignments(0, true).len(), 1);
    }

    #[test]
    fn plan_table_has_row_per_layer() {
        let net = tiny_net(2);
        let cm = CostModel::default_rates(2);
        let plan = search(&net, &SearchSpace::cpu_only(host(4), 21), &cm).unwrap();
        let rows = plan_table(&plan);
        assert_eq!(rows.len(), net.layers.len() + 1);
    }
}
