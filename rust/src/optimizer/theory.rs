//! Theoretical speedup model — Fig. 4 (§VI.A).
//!
//! The paper defines theoretical speedup as the ratio of operations
//! needed per output voxel by the naive approach (input = field of
//! view, a single output voxel, max-pooling) to those needed by an MPF
//! network at a given input size and batch size, using the FFT-based
//! layer costs of Table I. Plotted against the memory the configuration
//! requires, this shows why batch size 1 wins for ≥2-pool networks
//! while 1-pool networks prefer larger batches.

use crate::memory::model::{
    conv_memory_bytes, conv_pool_fused_memory_bytes, mpf_memory_bytes, pool_memory_bytes,
    ConvAlgo, ConvDims,
};
use crate::net::{LayerSpec, NetSpec, PoolingMode};
use crate::tensor::Shape5;

/// FFT-based op count of the whole net for one input (Table I rows 2/4).
pub fn fft_ops(net: &NetSpec, input: Shape5, modes: &[PoolingMode]) -> Option<f64> {
    let shapes = net.shapes(input, modes).ok()?;
    let mut cur = input;
    let mut ops = 0.0;
    let mut pool_i = 0;
    for (li, l) in net.layers.iter().enumerate() {
        match l {
            LayerSpec::Conv { f_out, k } => {
                let d = ConvDims {
                    s: cur.s,
                    f_in: net.f_in_at(li),
                    f_out: *f_out,
                    n: cur.spatial(),
                    k: *k,
                };
                ops += d.fft_flops();
            }
            LayerSpec::Pool { p } => {
                let mult = if modes[pool_i] == PoolingMode::Mpf {
                    (p[0] * p[1] * p[2]) as f64
                } else {
                    1.0
                };
                ops += cur.len() as f64 * mult;
                pool_i += 1;
            }
        }
        cur = shapes[li];
    }
    Some(ops)
}

/// Peak Table II memory of the net using the task-parallel FFT
/// primitive everywhere (the Fig. 4 x-axis).
pub fn fft_memory(
    net: &NetSpec,
    input: Shape5,
    modes: &[PoolingMode],
    threads: usize,
) -> Option<u64> {
    let shapes = net.shapes(input, modes).ok()?;
    let mut cur = input;
    let mut mem = 0u64;
    let mut pool_i = 0;
    for (li, l) in net.layers.iter().enumerate() {
        match l {
            LayerSpec::Conv { f_out, k } => {
                let d = ConvDims {
                    s: cur.s,
                    f_in: net.f_in_at(li),
                    f_out: *f_out,
                    n: cur.spatial(),
                    k: *k,
                };
                mem = mem.max(conv_memory_bytes(ConvAlgo::FftTaskParallel, &d, threads));
            }
            LayerSpec::Pool { p } => {
                let m = if modes[pool_i] == PoolingMode::Mpf {
                    mpf_memory_bytes(cur.s, cur.f, cur.spatial(), *p)
                } else {
                    pool_memory_bytes(cur.s, cur.f, cur.spatial(), *p)
                };
                mem = mem.max(m);
                pool_i += 1;
            }
        }
        cur = shapes[li];
    }
    Some(mem)
}

/// Analytic Table II memory saving of conv→pool fusion: for every
/// `Conv` spec layer immediately followed by a `Pool` whose window
/// tiles the conv output (max-pooling modes everywhere), compare the
/// unfused peak — the larger of the DirectMkl conv row and the pool
/// row, since the plan's working set is the max over layers — with the
/// fused row (`conv_pool_fused_memory_bytes`, which drops the
/// inter-layer `S·f'·n'` tensor). Returns one
/// `(conv layer index, unfused bytes, fused bytes)` triple per fusable
/// pair, or `None` when the net rejects `input` under max-pooling.
pub fn fused_pair_memory(
    net: &NetSpec,
    input: Shape5,
    threads: usize,
) -> Option<Vec<(usize, u64, u64)>> {
    let modes = vec![PoolingMode::MaxPool; net.pool_count()];
    let shapes = net.shapes(input, &modes).ok()?;
    let mut cur = input;
    let mut pairs = Vec::new();
    for (li, l) in net.layers.iter().enumerate() {
        if let LayerSpec::Conv { f_out, k } = l {
            if let Some(LayerSpec::Pool { p }) = net.layers.get(li + 1) {
                let c = shapes[li];
                if c.x % p[0] == 0 && c.y % p[1] == 0 && c.z % p[2] == 0 {
                    let d = ConvDims {
                        s: cur.s,
                        f_in: net.f_in_at(li),
                        f_out: *f_out,
                        n: cur.spatial(),
                        k: *k,
                    };
                    let unfused = conv_memory_bytes(ConvAlgo::DirectMkl, &d, threads)
                        .max(pool_memory_bytes(c.s, c.f, c.spatial(), *p));
                    let fused = conv_pool_fused_memory_bytes(&d, *p, threads);
                    pairs.push((li, unfused, fused));
                }
            }
        }
        cur = shapes[li];
    }
    Some(pairs)
}

/// Ops per output voxel of the naive approach: input = field of view,
/// max-pooling everywhere, one output voxel.
pub fn naive_ops_per_voxel(net: &NetSpec) -> f64 {
    let fov = net.field_of_view();
    let modes = vec![PoolingMode::MaxPool; net.pool_count()];
    let input = Shape5::new(1, net.f_in, fov[0], fov[1], fov[2]);
    fft_ops(net, input, &modes).expect("FoV input must be valid for max-pooling")
}

/// One Fig. 4 series: a batch size and its (memory, speedup) curve.
#[derive(Clone, Debug)]
pub struct SpeedupSeries {
    /// Batch size (S) of this series.
    pub batch: usize,
    /// (memory bytes, theoretical speedup) per valid input extent.
    pub points: Vec<(u64, f64)>,
}

/// Fig. 4: theoretical speedup vs memory for several batch sizes.
pub fn speedup_series(
    net: &NetSpec,
    batch_sizes: &[usize],
    max_extent: usize,
    threads: usize,
) -> Vec<SpeedupSeries> {
    let naive = naive_ops_per_voxel(net);
    let modes = vec![PoolingMode::Mpf; net.pool_count()];
    batch_sizes
        .iter()
        .map(|&s| {
            let mut points = Vec::new();
            for n in net.valid_extents(1, max_extent, &modes) {
                let input = Shape5::new(s, net.f_in, n, n, n);
                let (Some(ops), Some(mem), Ok(shapes)) = (
                    fft_ops(net, input, &modes),
                    fft_memory(net, input, &modes, threads),
                    net.shapes(input, &modes),
                ) else {
                    continue;
                };
                let out = shapes.last().unwrap();
                let vox = (out.s * out.x * out.y * out.z) as f64;
                points.push((mem, naive * vox / ops));
            }
            SpeedupSeries { batch: s, points }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo::tiny_net;
    use crate::net::spec::LayerSpec;

    /// A 1-pool and a 2-pool net as in Fig. 4.
    fn one_pool() -> NetSpec {
        NetSpec {
            name: "p1".into(),
            f_in: 1,
            layers: vec![
                LayerSpec::Conv { f_out: 4, k: [3; 3] },
                LayerSpec::Pool { p: [2; 3] },
                LayerSpec::Conv { f_out: 4, k: [3; 3] },
                LayerSpec::Conv { f_out: 2, k: [3; 3] },
            ],
        }
    }

    fn two_pool() -> NetSpec {
        NetSpec {
            name: "p2".into(),
            f_in: 1,
            layers: vec![
                LayerSpec::Conv { f_out: 4, k: [3; 3] },
                LayerSpec::Pool { p: [2; 3] },
                LayerSpec::Conv { f_out: 4, k: [3; 3] },
                LayerSpec::Pool { p: [2; 3] },
                LayerSpec::Conv { f_out: 2, k: [3; 3] },
            ],
        }
    }

    #[test]
    fn speedup_grows_with_input_size() {
        let s = speedup_series(&tiny_net(2), &[1], 41, 4);
        let pts = &s[0].points;
        assert!(pts.len() >= 3);
        // Larger inputs (more memory) → more reuse → higher speedup.
        assert!(pts.last().unwrap().1 > pts.first().unwrap().1);
    }

    #[test]
    fn speedup_exceeds_one_for_reasonable_inputs() {
        let s = speedup_series(&two_pool(), &[1], 60, 4);
        assert!(s[0].points.last().unwrap().1 > 1.0);
    }

    #[test]
    fn two_pool_prefers_batch_one_at_fixed_memory() {
        // The paper's Fig. 4b finding: for 2-pool nets, at equal memory,
        // S=1 achieves at least the speedup of larger batches.
        let series = speedup_series(&two_pool(), &[1, 4], 80, 4);
        let s1 = &series[0];
        let s4 = &series[1];
        // Compare at s4's top memory point against s1 interpolated at
        // ≤ that memory.
        let (m4, v4) = *s4.points.last().unwrap();
        let v1 = s1
            .points
            .iter()
            .filter(|(m, _)| *m <= m4)
            .map(|(_, v)| *v)
            .fold(0.0, f64::max);
        assert!(v1 >= v4 * 0.95, "s1 best {v1} vs s4 {v4} at mem {m4}");
    }

    #[test]
    fn one_pool_nets_have_series_too() {
        let series = speedup_series(&one_pool(), &[1, 2, 4], 40, 4);
        assert_eq!(series.len(), 3);
        for s in &series {
            assert!(!s.points.is_empty(), "batch {} empty", s.batch);
        }
    }

    #[test]
    fn naive_ops_positive() {
        assert!(naive_ops_per_voxel(&tiny_net(2)) > 0.0);
    }

    #[test]
    fn fused_pairs_save_memory_on_every_cp_pair() {
        // tiny_net is C P C C: one fusable pair at conv index 0. A
        // 10³ input gives an 8³ conv output the 2³ window tiles.
        let net = tiny_net(2);
        let input = Shape5::new(1, net.f_in, 10, 10, 10);
        let pairs = fused_pair_memory(&net, input, 4).unwrap();
        assert_eq!(pairs.len(), 1);
        let (li, unfused, fused) = pairs[0];
        assert_eq!(li, 0);
        assert!(
            fused < unfused,
            "fusion must beat the unfused peak: {fused} vs {unfused}"
        );
        // An input whose conv output the pool window cannot tile is
        // rejected outright under max-pooling modes.
        assert!(fused_pair_memory(&net, Shape5::new(1, net.f_in, 9, 9, 9), 4).is_none());
    }
}
