//! Cost model: estimated execution time per primitive, with a measured
//! calibration harness.
//!
//! The optimizer (§VI.A) ranks thousands of candidate plans; it cannot
//! execute them all. Times are estimated as `FLOPs / effective-rate`,
//! with per-algorithm effective rates that fold in each algorithm's
//! constants, cache behaviour and parallel efficiency. The paper's
//! central empirical lesson (§V) is that these rates **cannot be
//! derived from FLOP counts** — direct, FFT and pruned-FFT primitives
//! reach wildly different fractions of peak — so the rates here come in
//! three tiers of fidelity:
//!
//! 1. [`CostModel::default_rates`] — static plausible rates; ordering
//!    stays sane when nothing has been measured.
//! 2. [`CostModel::calibrate`] — one quick probe per primitive.
//! 3. [`CostModel::calibrate_full`] — the measured autotuner: every
//!    primitive is micro-benchmarked through a **warm** [`ExecCtx`] at a
//!    ladder of extents, an effective rate is fitted per algorithm
//!    (work-weighted across the ladder), and the real per-batch
//!    dispatch overhead is measured ([`measure_dispatch_overhead`]) to
//!    replace the default constant the serving-config search would
//!    otherwise assume.
//!
//! Calibration is machine-specific and costs seconds, so profiles
//! persist as JSON: [`CostModel::save_profile`] /
//! [`CostModel::load_profile`] let serving startup reuse a prior run.
//!
//! ```no_run
//! use znni::optimizer::CostModel;
//! use znni::util::pool::TaskPool;
//!
//! let pool = TaskPool::global();
//! let cm = CostModel::calibrate_full(pool, &[8, 12, 16]);
//! cm.save_profile("znni-profile.json").unwrap();
//! // ...next startup:
//! let cm = CostModel::load_profile("znni-profile.json").unwrap();
//! assert!(cm.dispatch_overhead_secs > 0.0);
//! ```

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::conv::{Activation, Weights};
use crate::device::Device;
use crate::exec::ExecCtx;
use crate::layers::{ConvLayer, FusedConvPoolLayer, LayerPrimitive};
use crate::memory::model::{ConvAlgo, ConvDims};
use crate::precision::Precision;
use crate::tensor::{Shape5, Tensor5, Vec3};
use crate::util::json::Json;
use crate::util::pool::TaskPool;

/// Dispatch overhead assumed when no measurement has been taken: the
/// serving-config search's fixed per-batch cost (worker spawn +
/// assembly). [`measure_dispatch_overhead`] replaces it with the real
/// number for this machine.
pub const DEFAULT_DISPATCH_OVERHEAD_SECS: f64 = 200e-6;

/// Profile format version written by [`CostModel::save_profile`].
const PROFILE_VERSION: u64 = 1;

/// Effective throughput (FLOP/s) per algorithm plus pooling rates.
#[derive(Clone, Debug)]
pub struct CostModel {
    rates: [(ConvAlgo, f64); 9],
    /// voxels/s for pooling layers (comparisons are cheap; memory-bound)
    pub pool_rate: f64,
    /// Worker threads the rates were taken with.
    pub threads: usize,
    /// Fixed per-batch dispatch cost (seconds) the serving-config
    /// search charges each coordinator batch — worker spawn, queue
    /// hand-off and output assembly. Defaults to
    /// [`DEFAULT_DISPATCH_OVERHEAD_SECS`]; [`CostModel::calibrate_full`]
    /// replaces it with a measurement.
    pub dispatch_overhead_secs: f64,
    /// Elements/second of the f16 narrow/widen conversion kernels
    /// ([`crate::simd::narrow_f16`] / [`crate::simd::widen_f16`]) — the
    /// per-patch tax a reduced-precision layer pays to stage its cached
    /// spectra and activations through half-width storage.
    /// [`CostModel::calibrate_full`] measures it.
    pub convert_rate_f16: f64,
    /// Elements/second of the bf16 narrow/widen conversion kernels
    /// (integer shift/round — typically faster than f16).
    pub convert_rate_bf16: f64,
}

/// One timed probe of the calibration ladder.
#[derive(Clone, Copy, Debug)]
pub struct CalSample {
    /// Cubic input extent of the probe.
    pub extent: usize,
    /// Work performed: effective FLOPs (conv) or voxels (pooling).
    pub work: f64,
    /// Best measured seconds of the warm (steady-state) runs.
    pub secs: f64,
}

impl CalSample {
    /// The probe's effective rate (work per second).
    pub fn rate(&self) -> f64 {
        self.work / self.secs.max(1e-9)
    }
}

/// The measured evidence behind a calibrated [`CostModel`], returned by
/// [`CostModel::calibrate_full_report`] so benches and examples can show
/// per-extent numbers instead of just the fitted aggregate.
#[derive(Clone, Debug, Default)]
pub struct CalibrationReport {
    /// Convolution probes: one ladder of samples per algorithm.
    pub conv: Vec<(ConvAlgo, Vec<CalSample>)>,
    /// MPF pooling probes.
    pub pool: Vec<CalSample>,
    /// Measured per-batch dispatch overhead (seconds).
    pub dispatch_overhead_secs: f64,
    /// Measured f16 narrow+widen throughput (elements/s).
    pub convert_f16: f64,
    /// Measured bf16 narrow+widen throughput (elements/s).
    pub convert_bf16: f64,
}

/// Measure the narrow+widen throughput (elements/second) of one half
/// format's conversion kernels on this machine — a single-threaded
/// streaming pass over a cache-spilling buffer, best of several trials
/// (conversions run inside already-parallel primitive sections, so the
/// per-element rate is what the cost model scales).
pub fn measure_convert_rate(precision: Precision) -> f64 {
    assert!(precision.is_half(), "only half formats convert");
    let len = 1 << 20;
    let src: Vec<f32> = (0..len).map(|i| (i as f32).sin()).collect();
    let mut bits = vec![0u16; len];
    let mut back = vec![0.0f32; len];
    let mut best = f64::INFINITY;
    for _ in 0..5 {
        let t0 = Instant::now();
        precision.narrow(&mut bits, &src);
        precision.widen(&mut back, &bits);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    std::hint::black_box(&back);
    // Two passes over `len` elements each.
    2.0 * len as f64 / best.max(1e-9)
}

/// Measure the fixed per-batch dispatch overhead on this machine: the
/// time to spawn and join `workers` scoped OS threads plus one channel
/// round-trip — exactly the fixed costs a
/// [`crate::coordinator::Coordinator::serve`] batch pays before and
/// after its compute, and what a [`crate::server::Server`] shard adds
/// per dispatched batch. Returns the median of repeated trials.
pub fn measure_dispatch_overhead(workers: usize) -> f64 {
    let workers = workers.max(1);
    let trial = || {
        let (tx, rx) = std::sync::mpsc::channel::<u64>();
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| std::hint::black_box(0u64));
            }
        });
        tx.send(1).ok();
        let _ = rx.recv();
        t0.elapsed().as_secs_f64()
    };
    for _ in 0..4 {
        trial(); // warmup: first spawns page in thread stacks
    }
    let mut samples: Vec<f64> = (0..24).map(|_| trial()).collect();
    samples.sort_by(f64::total_cmp);
    samples[samples.len() / 2].max(1e-7)
}

impl CostModel {
    /// Static defaults: plausible single-machine rates (FLOP/s). These
    /// keep ordering sane when calibration is skipped; benches always
    /// calibrate.
    pub fn default_rates(threads: usize) -> Self {
        let t = threads as f64;
        CostModel {
            rates: [
                (ConvAlgo::DirectNaive, 0.4e9 * t),
                (ConvAlgo::DirectMkl, 0.8e9 * t),
                // The register-tiled family streams each input row once
                // per output-channel *pair* and skips the temp-image
                // add-assign pass, so it clears ~2× the MKL-style rate
                // on the small-kernel layers it targets.
                (ConvAlgo::DirectFused, 1.6e9 * t),
                (ConvAlgo::DirectFusedPool, 1.6e9 * t),
                (ConvAlgo::FftDataParallel, 0.5e9 * t),
                (ConvAlgo::FftTaskParallel, 0.7e9 * t),
                (ConvAlgo::GpuDenseNoWorkspace, 0.4e9 * t),
                (ConvAlgo::GpuDensePrecomp, 0.9e9 * t),
                (ConvAlgo::GpuFft, 0.6e9 * t),
            ],
            pool_rate: 200e6 * t,
            threads,
            dispatch_overhead_secs: DEFAULT_DISPATCH_OVERHEAD_SECS,
            // Conversions are memory-bound streaming passes; bf16 is a
            // pure integer shift/round while f16 re-biases the
            // exponent, so its default is a little slower.
            convert_rate_f16: 2.0e9 * t,
            convert_rate_bf16: 3.0e9 * t,
        }
    }

    /// Builder-style override of the dispatch overhead (seconds) — for
    /// replaying a measurement taken elsewhere.
    pub fn with_dispatch_overhead(mut self, secs: f64) -> Self {
        self.dispatch_overhead_secs = secs.max(0.0);
        self
    }

    /// Calibrate by timing each primitive once on a probe problem.
    /// Rates are effective-FLOPs/s so they fold in each algorithm's
    /// constants, cache behaviour and parallel efficiency. For the full
    /// ladder + dispatch-overhead measurement use
    /// [`CostModel::calibrate_full`].
    pub fn calibrate(pool: &TaskPool, probe_extent: usize) -> Self {
        let mut cm = Self::default_rates(pool.workers());
        let n = [probe_extent; 3];
        let k = [3usize, 3, 3];
        let (f_in, f_out) = (4usize, 4usize);
        let dims = ConvDims { s: 1, f_in, f_out, n, k };
        let w = std::sync::Arc::new(Weights::random(f_out, f_in, k, 0xCA11));
        // One context for all probes: the warmup run also warms the
        // arena, so the timed run measures steady-state (allocation-
        // free) execution — the regime the optimizer plans for.
        let mut ctx = ExecCtx::new(pool);
        for (algo, rate) in cm.rates.iter_mut() {
            let layer = ConvLayer::new(w.clone(), *algo, Activation::Relu);
            let flops = layer.flops(Shape5::from_spatial(1, f_in, n));
            // One warmup + one timed run.
            let mk = || Tensor5::random(Shape5::from_spatial(1, f_in, n), 7);
            layer.execute(mk(), &mut ctx);
            let t0 = Instant::now();
            layer.execute(mk(), &mut ctx);
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            *rate = flops / secs;
            let _ = dims;
        }
        // Pooling rate: voxels/s of an MPF probe.
        {
            let sh = Shape5::new(1, f_in, probe_extent | 1, probe_extent | 1, probe_extent | 1);
            let t = Tensor5::random(sh, 9);
            crate::pool::mpf_forward(&t, [2, 2, 2], &mut ctx);
            let t0 = Instant::now();
            let t2 = Tensor5::random(sh, 9);
            crate::pool::mpf_forward(&t2, [2, 2, 2], &mut ctx);
            cm.pool_rate = sh.len() as f64 / t0.elapsed().as_secs_f64().max(1e-9);
        }
        cm
    }

    /// The measured autotuner: micro-benchmark every primitive through
    /// a warm [`ExecCtx`] at a ladder of cubic `extents`, fit one
    /// effective rate per algorithm family, and measure the real
    /// per-batch dispatch overhead. Equivalent to
    /// [`CostModel::calibrate_full_report`] without the evidence.
    pub fn calibrate_full(pool: &TaskPool, extents: &[usize]) -> Self {
        Self::calibrate_full_report(pool, extents).0
    }

    /// [`CostModel::calibrate_full`], additionally returning the raw
    /// per-extent measurements ([`CalibrationReport`]).
    ///
    /// Method: for each algorithm and extent, one cold run warms the
    /// arena and the FFT plan cache, then the best of three warm runs is
    /// kept (the steady-state regime the optimizer plans for — the same
    /// argument the paper makes for per-primitive timing runs, §V). The
    /// fitted rate is work-weighted across the ladder,
    /// `Σ work / Σ secs`, so large probes — where the optimum lives —
    /// dominate the fit.
    pub fn calibrate_full_report(pool: &TaskPool, extents: &[usize]) -> (Self, CalibrationReport) {
        let mut cm = Self::default_rates(pool.workers());
        let mut report = CalibrationReport::default();
        let extents: Vec<usize> = if extents.is_empty() { vec![8, 12] } else { extents.to_vec() };
        let k = [3usize, 3, 3];
        let (f_in, f_out) = (4usize, 4usize);
        let w = std::sync::Arc::new(Weights::random(f_out, f_in, k, 0xCA11));
        let mut ctx = ExecCtx::new(pool);
        for (algo, rate) in cm.rates.iter_mut() {
            // `DirectFusedPool` is probed through the primitive the
            // optimizer actually emits for it — the fused conv→pool
            // layer — so its fitted rate includes the max-reduce.
            let layer: Box<dyn LayerPrimitive> = if *algo == ConvAlgo::DirectFusedPool {
                Box::new(FusedConvPoolLayer {
                    weights: w.clone(),
                    window: [2, 2, 2],
                    act: Activation::Relu,
                })
            } else {
                Box::new(ConvLayer::new(w.clone(), *algo, Activation::Relu))
            };
            let mut ladder = Vec::with_capacity(extents.len());
            for &e in &extents {
                let mut e = e.max(k[0]);
                // The fused-pool probe needs a conv output the 2³
                // window tiles.
                if *algo == ConvAlgo::DirectFusedPool && (e - k[0] + 1) % 2 != 0 {
                    e += 1;
                }
                let sh = Shape5::from_spatial(1, f_in, [e; 3]);
                let work = layer.flops(sh);
                let mut best = f64::INFINITY;
                // Cold run (warms arena + plan cache), then 3 warm runs.
                for i in 0..4 {
                    let input = Tensor5::random(sh, 7 + i);
                    let t0 = Instant::now();
                    let out = layer.execute(input, &mut ctx);
                    let secs = t0.elapsed().as_secs_f64();
                    ctx.retire(out);
                    if i > 0 {
                        best = best.min(secs);
                    }
                }
                ladder.push(CalSample { extent: e, work, secs: best.max(1e-9) });
            }
            let (tw, ts): (f64, f64) =
                ladder.iter().fold((0.0, 0.0), |(w, s), p| (w + p.work, s + p.secs));
            *rate = tw / ts.max(1e-9);
            report.conv.push((*algo, ladder));
        }
        // Pooling rate: voxels/s of MPF probes over the same ladder
        // (extents forced odd so the 2³ fragment windows tile).
        {
            let mut ladder = Vec::with_capacity(extents.len());
            for &e in &extents {
                let e = (e | 1).max(3);
                let sh = Shape5::new(1, f_in, e, e, e);
                let mut best = f64::INFINITY;
                for i in 0..4 {
                    let input = Tensor5::random(sh, 9 + i);
                    let t0 = Instant::now();
                    let out = crate::pool::mpf_forward(&input, [2, 2, 2], &mut ctx);
                    let secs = t0.elapsed().as_secs_f64();
                    ctx.retire(out);
                    if i > 0 {
                        best = best.min(secs);
                    }
                }
                ladder.push(CalSample { extent: e, work: sh.len() as f64, secs: best.max(1e-9) });
            }
            let (tw, ts): (f64, f64) =
                ladder.iter().fold((0.0, 0.0), |(w, s), p| (w + p.work, s + p.secs));
            cm.pool_rate = tw / ts.max(1e-9);
            report.pool = ladder;
        }
        cm.dispatch_overhead_secs = measure_dispatch_overhead(pool.workers());
        report.dispatch_overhead_secs = cm.dispatch_overhead_secs;
        cm.convert_rate_f16 = measure_convert_rate(Precision::F16);
        cm.convert_rate_bf16 = measure_convert_rate(Precision::Bf16);
        report.convert_f16 = cm.convert_rate_f16;
        report.convert_bf16 = cm.convert_rate_bf16;
        (cm, report)
    }

    /// Serialize this model as a calibration-profile JSON document.
    pub fn to_profile_json(&self) -> String {
        let rates: Vec<(String, Json)> =
            self.rates.iter().map(|(a, r)| (a.tag().to_string(), Json::Num(*r))).collect();
        Json::Object(vec![
            ("version".into(), Json::Num(PROFILE_VERSION as f64)),
            ("threads".into(), Json::Num(self.threads as f64)),
            ("pool_rate".into(), Json::Num(self.pool_rate)),
            ("dispatch_overhead_secs".into(), Json::Num(self.dispatch_overhead_secs)),
            ("convert_rate_f16".into(), Json::Num(self.convert_rate_f16)),
            ("convert_rate_bf16".into(), Json::Num(self.convert_rate_bf16)),
            ("rates".into(), Json::Object(rates)),
        ])
        .to_pretty_string()
    }

    /// Parse a calibration profile produced by
    /// [`CostModel::to_profile_json`]. Strict: the version must match
    /// and every algorithm must carry a positive finite rate.
    pub fn from_profile_json(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let version = v
            .get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("profile missing 'version'"))?;
        if version != PROFILE_VERSION {
            bail!("unsupported profile version {} (expected {})", version, PROFILE_VERSION);
        }
        let threads = v
            .get("threads")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("profile missing 'threads'"))? as usize;
        if threads == 0 {
            bail!("profile 'threads' must be positive");
        }
        let field = |key: &str| -> Result<f64> {
            let x = v
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("profile missing '{key}'"))?;
            if !x.is_finite() || x <= 0.0 {
                bail!("profile '{key}' must be a positive finite number, got {x}");
            }
            Ok(x)
        };
        let mut cm = Self::default_rates(threads);
        cm.pool_rate = field("pool_rate")?;
        // Zero is a legal overhead ([`CostModel::with_dispatch_overhead`]
        // clamps to it), so unlike the rates this field only needs to be
        // finite and non-negative to round-trip.
        let overhead = v
            .get("dispatch_overhead_secs")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("profile missing 'dispatch_overhead_secs'"))?;
        if !overhead.is_finite() || overhead < 0.0 {
            bail!("profile 'dispatch_overhead_secs' must be finite and >= 0, got {overhead}");
        }
        cm.dispatch_overhead_secs = overhead;
        // Profiles written before the reduced-precision axis carry no
        // conversion rates; keep the defaults so old profiles stay
        // loadable (the same forward-compat contract as the fused
        // direct rates below). Present keys are validated as strictly
        // as the rest.
        for (key, dst) in [
            ("convert_rate_f16", &mut cm.convert_rate_f16),
            ("convert_rate_bf16", &mut cm.convert_rate_bf16),
        ] {
            if let Some(val) = v.get(key) {
                let x = val
                    .as_f64()
                    .ok_or_else(|| anyhow!("profile '{key}' must be a number"))?;
                if !x.is_finite() || x <= 0.0 {
                    bail!("profile '{key}' must be a positive finite number, got {x}");
                }
                *dst = x;
            }
        }
        let rates = v
            .get("rates")
            .and_then(Json::as_object)
            .ok_or_else(|| anyhow!("profile missing 'rates' object"))?;
        for (algo, rate) in cm.rates.iter_mut() {
            let tag = algo.tag();
            let entry = rates.iter().find(|(k, _)| k == tag);
            let Some((_, val)) = entry else {
                // Profiles written before the fused direct family
                // existed carry no rate for it; keep the defaults so
                // old profiles stay loadable. Every other tag is as
                // strict as ever.
                if matches!(algo, ConvAlgo::DirectFused | ConvAlgo::DirectFusedPool) {
                    continue;
                }
                bail!("profile missing rate for '{tag}'");
            };
            let x = val
                .as_f64()
                .ok_or_else(|| anyhow!("profile rate for '{tag}' must be a number"))?;
            if !x.is_finite() || x <= 0.0 {
                bail!("profile rate for '{tag}' must be positive finite, got {x}");
            }
            *rate = x;
        }
        for (key, _) in rates {
            if ConvAlgo::from_tag(key).is_none() {
                bail!("profile has rate for unknown algorithm '{key}'");
            }
        }
        Ok(cm)
    }

    /// Persist this model's calibration as JSON at `path`, so a later
    /// serving startup can [`CostModel::load_profile`] instead of
    /// re-measuring.
    pub fn save_profile(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_profile_json())
            .map_err(|e| anyhow!("writing profile {}: {e}", path.display()))
    }

    /// Load a calibration profile saved by [`CostModel::save_profile`].
    pub fn load_profile(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading profile {}: {e}", path.display()))?;
        Self::from_profile_json(&text)
    }

    /// Effective rate for an algorithm (scaled by the device's modelled
    /// speed factor for GPU placements).
    pub fn rate(&self, algo: ConvAlgo, device: &Device) -> f64 {
        let base = self
            .rates
            .iter()
            .find(|(a, _)| *a == algo)
            .map(|(_, r)| *r)
            .unwrap_or(1e9);
        if algo.is_gpu() {
            base * device.speed_factor
        } else {
            base
        }
    }

    /// Estimated seconds for a conv layer.
    pub fn conv_secs(&self, algo: ConvAlgo, d: &ConvDims, device: &Device) -> f64 {
        let flops = match algo {
            ConvAlgo::DirectNaive
            | ConvAlgo::DirectMkl
            | ConvAlgo::DirectFused
            | ConvAlgo::DirectFusedPool
            | ConvAlgo::GpuDenseNoWorkspace
            | ConvAlgo::GpuDensePrecomp => d.direct_flops(),
            _ => d.fft_flops(),
        };
        flops / self.rate(algo, device)
    }

    /// Estimated seconds for a conv layer executing against a
    /// precomputed weight-spectrum cache: the FFT families drop their
    /// per-call kernel-transform FLOPs
    /// ([`ConvDims::fft_kernel_flops`] — amortized to zero once the
    /// spectra are resident); algorithms that transform no kernels cost
    /// the same as [`CostModel::conv_secs`].
    pub fn conv_secs_cached(&self, algo: ConvAlgo, d: &ConvDims, device: &Device) -> f64 {
        let full = self.conv_secs(algo, d, device);
        if algo.uses_kernel_cache() {
            (full - d.fft_kernel_flops() / self.rate(algo, device)).max(0.0)
        } else {
            full
        }
    }

    /// Estimated seconds to convert `elems` stored elements through a
    /// half format's narrow/widen kernels (0 for f32 — nothing
    /// converts). The reduced-precision search charges this against the
    /// halved resident row a half-width layer buys.
    pub fn convert_secs(&self, precision: Precision, elems: u64) -> f64 {
        match precision {
            Precision::F32 => 0.0,
            Precision::F16 => elems as f64 / self.convert_rate_f16.max(1.0),
            Precision::Bf16 => elems as f64 / self.convert_rate_bf16.max(1.0),
        }
    }

    /// Estimated seconds for a pooling/MPF layer.
    pub fn pool_secs(&self, s: usize, f: usize, n: Vec3, p: Vec3, mpf: bool) -> f64 {
        let vox = (s * f * n[0] * n[1] * n[2]) as f64;
        let mult = if mpf { (p[0] * p[1] * p[2]) as f64 } else { 1.0 };
        vox * mult / self.pool_rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::ChipTopology;

    #[test]
    fn default_rates_positive() {
        let cm = CostModel::default_rates(4);
        let host = Device::host_with_ram(1 << 30);
        for algo in ConvAlgo::ALL {
            assert!(cm.rate(algo, &host) > 0.0);
        }
        assert_eq!(cm.dispatch_overhead_secs, DEFAULT_DISPATCH_OVERHEAD_SECS);
    }

    #[test]
    fn conv_secs_scale_with_work() {
        let cm = CostModel::default_rates(4);
        let host = Device::host_with_ram(1 << 30);
        let small = ConvDims { s: 1, f_in: 2, f_out: 2, n: [10; 3], k: [3; 3] };
        let big = ConvDims { s: 1, f_in: 2, f_out: 2, n: [20; 3], k: [3; 3] };
        assert!(
            cm.conv_secs(ConvAlgo::DirectNaive, &big, &host)
                > cm.conv_secs(ConvAlgo::DirectNaive, &small, &host)
        );
    }

    #[test]
    fn cached_kernels_strictly_cheaper_for_fft_families() {
        let cm = CostModel::default_rates(4);
        let host = Device::host_with_ram(1 << 30);
        let d = ConvDims { s: 1, f_in: 4, f_out: 4, n: [16; 3], k: [3; 3] };
        for algo in ConvAlgo::ALL {
            let full = cm.conv_secs(algo, &d, &host);
            let cached = cm.conv_secs_cached(algo, &d, &host);
            if algo.uses_kernel_cache() {
                assert!(cached < full, "{algo:?}: cache must drop kernel-transform time");
                assert!(cached >= 0.0);
            } else {
                assert_eq!(cached, full, "{algo:?}: no kernel transforms to drop");
            }
        }
    }

    #[test]
    fn gpu_speed_factor_applies() {
        let cm = CostModel::default_rates(4);
        let d = ConvDims { s: 1, f_in: 2, f_out: 2, n: [12; 3], k: [3; 3] };
        let slow = Device { speed_factor: 1.0, ..Device::titan_x() };
        let fast = Device { speed_factor: 4.0, ..Device::titan_x() };
        let t_slow = cm.conv_secs(ConvAlgo::GpuFft, &d, &slow);
        let t_fast = cm.conv_secs(ConvAlgo::GpuFft, &d, &fast);
        assert!((t_slow / t_fast - 4.0).abs() < 1e-6);
    }

    #[test]
    fn calibration_produces_finite_rates() {
        let pool = TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 });
        let cm = CostModel::calibrate(&pool, 8);
        let host = Device::host_with_ram(1 << 30);
        for algo in ConvAlgo::ALL {
            let r = cm.rate(algo, &host);
            assert!(r.is_finite() && r > 0.0, "{algo:?}: {r}");
        }
        assert!(cm.pool_rate > 0.0);
    }

    #[test]
    fn full_calibration_fits_rates_and_measures_dispatch() {
        let pool = TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 });
        let (cm, report) = CostModel::calibrate_full_report(&pool, &[6, 8]);
        let host = Device::host_with_ram(1 << 30);
        for algo in ConvAlgo::ALL {
            let r = cm.rate(algo, &host);
            assert!(r.is_finite() && r > 0.0, "{algo:?}: {r}");
        }
        assert!(cm.pool_rate > 0.0);
        assert!(cm.dispatch_overhead_secs > 0.0 && cm.dispatch_overhead_secs < 1.0);
        assert!(cm.convert_rate_f16 > 0.0 && cm.convert_rate_f16.is_finite());
        assert!(cm.convert_rate_bf16 > 0.0 && cm.convert_rate_bf16.is_finite());
        assert_eq!(report.convert_f16, cm.convert_rate_f16);
        assert_eq!(report.convert_bf16, cm.convert_rate_bf16);
        // The report carries one ladder per algorithm, each probe timed.
        assert_eq!(report.conv.len(), ConvAlgo::ALL.len());
        for (algo, ladder) in &report.conv {
            assert_eq!(ladder.len(), 2, "{algo:?}");
            for s in ladder {
                assert!(s.secs > 0.0 && s.work > 0.0 && s.rate() > 0.0, "{algo:?}");
            }
        }
        assert_eq!(report.pool.len(), 2);
        assert_eq!(report.dispatch_overhead_secs, cm.dispatch_overhead_secs);
    }

    #[test]
    fn dispatch_overhead_is_sane() {
        let d = measure_dispatch_overhead(2);
        assert!(d > 0.0 && d < 0.5, "dispatch overhead {d}s out of range");
    }

    #[test]
    fn profile_json_round_trips() {
        let mut cm = CostModel::default_rates(3);
        cm.pool_rate = 123.5e6;
        cm.dispatch_overhead_secs = 321e-6;
        cm.convert_rate_f16 = 1.25e9;
        cm.convert_rate_bf16 = 4.5e9;
        let text = cm.to_profile_json();
        let back = CostModel::from_profile_json(&text).unwrap();
        assert_eq!(back.threads, cm.threads);
        assert_eq!(back.pool_rate, cm.pool_rate);
        assert_eq!(back.dispatch_overhead_secs, cm.dispatch_overhead_secs);
        assert_eq!(back.convert_rate_f16, cm.convert_rate_f16);
        assert_eq!(back.convert_rate_bf16, cm.convert_rate_bf16);
        let host = Device::host_with_ram(1 << 30);
        for algo in ConvAlgo::ALL {
            assert_eq!(back.rate(algo, &host), cm.rate(algo, &host), "{algo:?}");
        }
        // Zero overhead is legal (with_dispatch_overhead clamps to it)
        // and must survive the round-trip too.
        let zero = CostModel::default_rates(2).with_dispatch_overhead(0.0);
        let back = CostModel::from_profile_json(&zero.to_profile_json()).unwrap();
        assert_eq!(back.dispatch_overhead_secs, 0.0);
    }

    #[test]
    fn profile_without_fused_rates_falls_back_to_defaults() {
        // A legacy profile written before the fused direct family: its
        // "rates" object carries only the original seven tags. It must
        // still load, with the fused algorithms keeping their defaults.
        let legacy = r#"{
            "version": 1,
            "threads": 3,
            "pool_rate": 150000000.0,
            "dispatch_overhead_secs": 0.0002,
            "rates": {
                "DirectN": 1000000000.0,
                "DirectM": 2000000000.0,
                "FFT-DP": 1500000000.0,
                "FFT-TP": 1700000000.0,
                "CuDNN1": 1100000000.0,
                "CuDNN2": 2100000000.0,
                "FFT": 1600000000.0
            }
        }"#;
        let cm = CostModel::from_profile_json(legacy).unwrap();
        let defaults = CostModel::default_rates(3);
        let host = Device::host_with_ram(1 << 30);
        assert_eq!(cm.rate(ConvAlgo::DirectMkl, &host), 2000000000.0);
        for algo in [ConvAlgo::DirectFused, ConvAlgo::DirectFusedPool] {
            assert_eq!(cm.rate(algo, &host), defaults.rate(algo, &host), "{algo:?}");
        }
        // A fused rate that IS present must be honoured — and still
        // validated.
        let cm = CostModel::default_rates(2);
        let text = cm.to_profile_json();
        assert!(text.contains("\"DirectFused\""), "new profiles persist fused rates");
        let back = CostModel::from_profile_json(&text).unwrap();
        assert_eq!(back.rate(ConvAlgo::DirectFused, &host), cm.rate(ConvAlgo::DirectFused, &host));
        let bad = text.replace(
            &format!("\"DirectFusedPool\": {:?}", cm.rate(ConvAlgo::DirectFusedPool, &host)),
            "\"DirectFusedPool\": -5.0",
        );
        assert_ne!(bad, text, "replacement must have matched the profile text");
        assert!(CostModel::from_profile_json(&bad).is_err(), "present-but-invalid still errors");
    }

    #[test]
    fn profile_without_convert_rates_falls_back_to_defaults() {
        // A profile saved before the reduced-precision axis existed:
        // no convert_rate_* keys anywhere. It must load with the
        // default conversion rates, and re-saving it must persist the
        // new keys.
        let legacy = r#"{
            "version": 1,
            "threads": 3,
            "pool_rate": 150000000.0,
            "dispatch_overhead_secs": 0.0002,
            "rates": {
                "DirectN": 1000000000.0,
                "DirectM": 2000000000.0,
                "DirectFused": 2500000000.0,
                "DirectFusedPool": 2500000000.0,
                "FFT-DP": 1500000000.0,
                "FFT-TP": 1700000000.0,
                "CuDNN1": 1100000000.0,
                "CuDNN2": 2100000000.0,
                "FFT": 1600000000.0
            }
        }"#;
        let cm = CostModel::from_profile_json(legacy).unwrap();
        let defaults = CostModel::default_rates(3);
        assert_eq!(cm.convert_rate_f16, defaults.convert_rate_f16);
        assert_eq!(cm.convert_rate_bf16, defaults.convert_rate_bf16);
        let resaved = cm.to_profile_json();
        assert!(resaved.contains("\"convert_rate_f16\""));
        assert!(resaved.contains("\"convert_rate_bf16\""));
        let back = CostModel::from_profile_json(&resaved).unwrap();
        assert_eq!(back.convert_rate_f16, defaults.convert_rate_f16);
        // Present-but-invalid still errors.
        let cm2 = CostModel::default_rates(2);
        let bad = cm2.to_profile_json().replace(
            &format!("\"convert_rate_f16\": {:?}", cm2.convert_rate_f16),
            "\"convert_rate_f16\": -1.0",
        );
        assert_ne!(bad, cm2.to_profile_json(), "replacement must have matched");
        assert!(CostModel::from_profile_json(&bad).is_err());
    }

    #[test]
    fn convert_secs_zero_for_f32_and_positive_for_half() {
        let cm = CostModel::default_rates(4);
        assert_eq!(cm.convert_secs(Precision::F32, 1 << 20), 0.0);
        let f16 = cm.convert_secs(Precision::F16, 1 << 20);
        let bf16 = cm.convert_secs(Precision::Bf16, 1 << 20);
        assert!(f16 > 0.0 && bf16 > 0.0);
        // Linear in the element count.
        assert!((cm.convert_secs(Precision::F16, 2 << 20) / f16 - 2.0).abs() < 1e-9);
        // The measured rates are finite and positive on this machine.
        for p in Precision::HALF {
            let r = measure_convert_rate(p);
            assert!(r.is_finite() && r > 0.0, "{p:?}: {r}");
        }
    }

    #[test]
    fn profile_json_rejects_bad_documents() {
        assert!(CostModel::from_profile_json("{}").is_err());
        assert!(CostModel::from_profile_json("not json").is_err());
        // Wrong version.
        let bad = CostModel::default_rates(2).to_profile_json().replace(
            "\"version\": 1",
            "\"version\": 99",
        );
        assert!(CostModel::from_profile_json(&bad).is_err());
        // A missing rate.
        let bad = CostModel::default_rates(2).to_profile_json().replace("\"FFT-DP\"", "\"nope\"");
        assert!(CostModel::from_profile_json(&bad).is_err());
        // A non-positive rate.
        let cm = CostModel::default_rates(2);
        let bad = cm.to_profile_json().replace(&format!("{:?}", cm.pool_rate), "-1.0");
        assert!(CostModel::from_profile_json(&bad).is_err());
    }
}
