//! Cost model: estimated execution time per primitive.
//!
//! The optimizer (§VI.A) ranks thousands of candidate plans; it cannot
//! execute them all. Times are estimated as `FLOPs / effective-rate`,
//! with per-algorithm effective rates that can be **calibrated** on the
//! machine by running each primitive once at a probe size (the paper's
//! search equally relies on per-primitive timing runs). GPU rates are
//! additionally scaled by the device speed factor.

use std::time::Instant;

use crate::conv::{Activation, Weights};
use crate::device::Device;
use crate::exec::ExecCtx;
use crate::layers::{ConvLayer, LayerPrimitive};
use crate::memory::model::{ConvAlgo, ConvDims};
use crate::tensor::{Shape5, Tensor5, Vec3};
use crate::util::pool::TaskPool;

/// Effective throughput (FLOP/s) per algorithm plus pooling rates.
#[derive(Clone, Debug)]
pub struct CostModel {
    rates: [(ConvAlgo, f64); 7],
    /// voxels/s for pooling layers (comparisons are cheap; memory-bound)
    pub pool_rate: f64,
    pub threads: usize,
}

impl CostModel {
    /// Static defaults: plausible single-machine rates (FLOP/s). These
    /// keep ordering sane when calibration is skipped; benches always
    /// calibrate.
    pub fn default_rates(threads: usize) -> Self {
        let t = threads as f64;
        CostModel {
            rates: [
                (ConvAlgo::DirectNaive, 0.4e9 * t),
                (ConvAlgo::DirectMkl, 0.8e9 * t),
                (ConvAlgo::FftDataParallel, 0.5e9 * t),
                (ConvAlgo::FftTaskParallel, 0.7e9 * t),
                (ConvAlgo::GpuDenseNoWorkspace, 0.4e9 * t),
                (ConvAlgo::GpuDensePrecomp, 0.9e9 * t),
                (ConvAlgo::GpuFft, 0.6e9 * t),
            ],
            pool_rate: 200e6 * t,
            threads,
        }
    }

    /// Calibrate by timing each primitive once on a probe problem.
    /// Rates are effective-FLOPs/s so they fold in each algorithm's
    /// constants, cache behaviour and parallel efficiency.
    pub fn calibrate(pool: &TaskPool, probe_extent: usize) -> Self {
        let mut cm = Self::default_rates(pool.workers());
        let n = [probe_extent; 3];
        let k = [3usize, 3, 3];
        let (f_in, f_out) = (4usize, 4usize);
        let dims = ConvDims { s: 1, f_in, f_out, n, k };
        let w = std::sync::Arc::new(Weights::random(f_out, f_in, k, 0xCA11));
        // One context for all probes: the warmup run also warms the
        // arena, so the timed run measures steady-state (allocation-
        // free) execution — the regime the optimizer plans for.
        let mut ctx = ExecCtx::new(pool);
        for (algo, rate) in cm.rates.iter_mut() {
            let layer = ConvLayer::new(w.clone(), *algo, Activation::Relu);
            let flops = layer.flops(Shape5::from_spatial(1, f_in, n));
            // One warmup + one timed run.
            let mk = || Tensor5::random(Shape5::from_spatial(1, f_in, n), 7);
            layer.execute(mk(), &mut ctx);
            let t0 = Instant::now();
            layer.execute(mk(), &mut ctx);
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            *rate = flops / secs;
            let _ = dims;
        }
        // Pooling rate: voxels/s of an MPF probe.
        {
            let sh = Shape5::new(1, f_in, probe_extent | 1, probe_extent | 1, probe_extent | 1);
            let t = Tensor5::random(sh, 9);
            crate::pool::mpf_forward(&t, [2, 2, 2], &mut ctx);
            let t0 = Instant::now();
            let t2 = Tensor5::random(sh, 9);
            crate::pool::mpf_forward(&t2, [2, 2, 2], &mut ctx);
            cm.pool_rate = sh.len() as f64 / t0.elapsed().as_secs_f64().max(1e-9);
        }
        cm
    }

    /// Effective rate for an algorithm (scaled by the device's modelled
    /// speed factor for GPU placements).
    pub fn rate(&self, algo: ConvAlgo, device: &Device) -> f64 {
        let base = self
            .rates
            .iter()
            .find(|(a, _)| *a == algo)
            .map(|(_, r)| *r)
            .unwrap_or(1e9);
        if algo.is_gpu() {
            base * device.speed_factor
        } else {
            base
        }
    }

    /// Estimated seconds for a conv layer.
    pub fn conv_secs(&self, algo: ConvAlgo, d: &ConvDims, device: &Device) -> f64 {
        let flops = match algo {
            ConvAlgo::DirectNaive
            | ConvAlgo::DirectMkl
            | ConvAlgo::GpuDenseNoWorkspace
            | ConvAlgo::GpuDensePrecomp => d.direct_flops(),
            _ => d.fft_flops(),
        };
        flops / self.rate(algo, device)
    }

    /// Estimated seconds for a pooling/MPF layer.
    pub fn pool_secs(&self, s: usize, f: usize, n: Vec3, p: Vec3, mpf: bool) -> f64 {
        let vox = (s * f * n[0] * n[1] * n[2]) as f64;
        let mult = if mpf { (p[0] * p[1] * p[2]) as f64 } else { 1.0 };
        vox * mult / self.pool_rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::ChipTopology;

    #[test]
    fn default_rates_positive() {
        let cm = CostModel::default_rates(4);
        let host = Device::host_with_ram(1 << 30);
        for algo in ConvAlgo::ALL {
            assert!(cm.rate(algo, &host) > 0.0);
        }
    }

    #[test]
    fn conv_secs_scale_with_work() {
        let cm = CostModel::default_rates(4);
        let host = Device::host_with_ram(1 << 30);
        let small = ConvDims { s: 1, f_in: 2, f_out: 2, n: [10; 3], k: [3; 3] };
        let big = ConvDims { s: 1, f_in: 2, f_out: 2, n: [20; 3], k: [3; 3] };
        assert!(
            cm.conv_secs(ConvAlgo::DirectNaive, &big, &host)
                > cm.conv_secs(ConvAlgo::DirectNaive, &small, &host)
        );
    }

    #[test]
    fn gpu_speed_factor_applies() {
        let cm = CostModel::default_rates(4);
        let d = ConvDims { s: 1, f_in: 2, f_out: 2, n: [12; 3], k: [3; 3] };
        let slow = Device { speed_factor: 1.0, ..Device::titan_x() };
        let fast = Device { speed_factor: 4.0, ..Device::titan_x() };
        let t_slow = cm.conv_secs(ConvAlgo::GpuFft, &d, &slow);
        let t_fast = cm.conv_secs(ConvAlgo::GpuFft, &d, &fast);
        assert!((t_slow / t_fast - 4.0).abs() < 1e-6);
    }

    #[test]
    fn calibration_produces_finite_rates() {
        let pool = TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 });
        let cm = CostModel::calibrate(&pool, 8);
        let host = Device::host_with_ram(1 << 30);
        for algo in ConvAlgo::ALL {
            let r = cm.rate(algo, &host);
            assert!(r.is_finite() && r > 0.0, "{algo:?}: {r}");
        }
        assert!(cm.pool_rate > 0.0);
    }
}
