//! Layer primitives — the composable units of Fig. 1.
//!
//! A ConvNet implementation is a choice of one primitive per layer
//! (§VI). Every primitive knows its output shape (Table I), its peak
//! memory (Table II) and its analytic FLOPs, so the optimizer can search
//! plans without executing them; `execute` then runs the chosen plan.

use std::sync::Arc;

use crate::conv::{self, Activation, Weights};
use crate::memory::model::{conv_memory_bytes, mpf_memory_bytes, pool_memory_bytes, ConvAlgo, ConvDims};
use crate::pool::{max_pool, max_pool_out_shape, mpf_forward, mpf_out_shape};
use crate::tensor::{Shape5, Tensor5, Vec3};
use crate::util::pool::TaskPool;

/// Which device a primitive is meant for (§IV.A vs §IV.B). On this
/// testbed the GPU is simulated — see `crate::device`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    Cpu,
    Gpu,
}

/// A layer primitive: shape/cost metadata + execution.
pub trait LayerPrimitive: Send + Sync {
    /// Short display name (Table IV uses these tags).
    fn name(&self) -> String;

    /// Output shape for a given input shape (panics on invalid input —
    /// use [`LayerPrimitive::accepts`] to probe).
    fn out_shape(&self, input: Shape5) -> Shape5;

    /// Whether this primitive can process the given input shape.
    fn accepts(&self, input: Shape5) -> bool;

    /// Peak memory (bytes) per Table II.
    fn memory_bytes(&self, input: Shape5, threads: usize) -> u64;

    /// Analytic FLOPs per Table I.
    fn flops(&self, input: Shape5) -> f64;

    /// CPU or GPU primitive.
    fn placement(&self) -> Placement;

    /// Run the layer.
    fn execute(&self, input: Tensor5, pool: &TaskPool) -> Tensor5;
}

/// Convolutional layer with a fixed algorithm choice.
pub struct ConvLayer {
    pub weights: Arc<Weights>,
    pub algo: ConvAlgo,
    pub act: Activation,
}

impl ConvLayer {
    pub fn new(weights: Arc<Weights>, algo: ConvAlgo, act: Activation) -> Self {
        ConvLayer { weights, algo, act }
    }

    fn dims(&self, input: Shape5) -> ConvDims {
        ConvDims {
            s: input.s,
            f_in: self.weights.f_in,
            f_out: self.weights.f_out,
            n: input.spatial(),
            k: self.weights.k,
        }
    }
}

impl LayerPrimitive for ConvLayer {
    fn name(&self) -> String {
        self.algo.tag().to_string()
    }

    fn out_shape(&self, input: Shape5) -> Shape5 {
        conv::conv_out_shape(input, self.weights.f_out, self.weights.k)
    }

    fn accepts(&self, input: Shape5) -> bool {
        input.f == self.weights.f_in
            && input.x >= self.weights.k[0]
            && input.y >= self.weights.k[1]
            && input.z >= self.weights.k[2]
    }

    fn memory_bytes(&self, input: Shape5, threads: usize) -> u64 {
        conv_memory_bytes(self.algo, &self.dims(input), threads)
    }

    fn flops(&self, input: Shape5) -> f64 {
        let d = self.dims(input);
        match self.algo {
            ConvAlgo::DirectNaive
            | ConvAlgo::DirectMkl
            | ConvAlgo::GpuDenseNoWorkspace
            | ConvAlgo::GpuDensePrecomp => d.direct_flops(),
            ConvAlgo::FftDataParallel | ConvAlgo::FftTaskParallel | ConvAlgo::GpuFft => {
                d.fft_flops()
            }
        }
    }

    fn placement(&self) -> Placement {
        if self.algo.is_gpu() {
            Placement::Gpu
        } else {
            Placement::Cpu
        }
    }

    fn execute(&self, input: Tensor5, pool: &TaskPool) -> Tensor5 {
        let w = &self.weights;
        match self.algo {
            ConvAlgo::DirectNaive => conv::direct::conv_direct_naive(&input, w, self.act, pool),
            ConvAlgo::DirectMkl => conv::direct::conv_direct_mkl(&input, w, self.act, pool),
            ConvAlgo::FftDataParallel => conv::fft_dp::conv_fft_dp(input, w, self.act, pool),
            ConvAlgo::FftTaskParallel => conv::fft_tp::conv_fft_tp(input, w, self.act, pool),
            // Dense-conv stand-ins for the two cuDNN primitives: the
            // no-workspace variant is the slow/lean one, the precomp
            // variant trades workspace memory for speed (§IV.B.1). The
            // workspace registration makes the Table II difference
            // observable to the ledger.
            ConvAlgo::GpuDenseNoWorkspace => {
                conv::direct::conv_direct_naive(&input, w, self.act, pool)
            }
            ConvAlgo::GpuDensePrecomp => {
                let ish = input.shape();
                let _workspace = crate::memory::TrackedVec::<f32>::zeroed(
                    ish.len(),
                    "cudnn-precomp workspace",
                );
                conv::direct::conv_direct_mkl(&input, w, self.act, pool)
            }
            ConvAlgo::GpuFft => conv::fft_gpu::conv_fft_gpu(input, w, self.act, pool),
        }
    }
}

/// Plain max-pooling layer.
pub struct MaxPoolLayer {
    pub window: Vec3,
    pub placement: Placement,
}

impl LayerPrimitive for MaxPoolLayer {
    fn name(&self) -> String {
        "Pool".into()
    }

    fn out_shape(&self, input: Shape5) -> Shape5 {
        max_pool_out_shape(input, self.window)
    }

    fn accepts(&self, input: Shape5) -> bool {
        input.x % self.window[0] == 0
            && input.y % self.window[1] == 0
            && input.z % self.window[2] == 0
            && input.x > 0
    }

    fn memory_bytes(&self, input: Shape5, _threads: usize) -> u64 {
        pool_memory_bytes(input.s, input.f, input.spatial(), self.window)
    }

    fn flops(&self, input: Shape5) -> f64 {
        // Table I: S·f·n³ comparisons.
        input.len() as f64
    }

    fn placement(&self) -> Placement {
        self.placement
    }

    fn execute(&self, input: Tensor5, pool: &TaskPool) -> Tensor5 {
        max_pool(&input, self.window, pool)
    }
}

/// Max-pooling-fragments layer.
pub struct MpfLayer {
    pub window: Vec3,
    pub placement: Placement,
}

impl LayerPrimitive for MpfLayer {
    fn name(&self) -> String {
        "MPF".into()
    }

    fn out_shape(&self, input: Shape5) -> Shape5 {
        mpf_out_shape(input, self.window)
    }

    fn accepts(&self, input: Shape5) -> bool {
        (input.x + 1) % self.window[0] == 0
            && (input.y + 1) % self.window[1] == 0
            && (input.z + 1) % self.window[2] == 0
    }

    fn memory_bytes(&self, input: Shape5, _threads: usize) -> u64 {
        mpf_memory_bytes(input.s, input.f, input.spatial(), self.window)
    }

    fn flops(&self, input: Shape5) -> f64 {
        // Table I: S·f·n³·p³.
        input.len() as f64 * (self.window[0] * self.window[1] * self.window[2]) as f64
    }

    fn placement(&self) -> Placement {
        self.placement
    }

    fn execute(&self, input: Tensor5, pool: &TaskPool) -> Tensor5 {
        mpf_forward(&input, self.window, pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::ChipTopology;
    use crate::util::quick::assert_allclose;

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    fn conv_layer(algo: ConvAlgo) -> ConvLayer {
        ConvLayer::new(Arc::new(Weights::random(3, 2, [3, 3, 3], 1)), algo, Activation::Relu)
    }

    #[test]
    fn all_conv_algos_agree() {
        let p = tpool();
        let input = Tensor5::random(Shape5::new(1, 2, 7, 7, 7), 2);
        let reference =
            conv::conv_layer_reference(&input, &conv_layer(ConvAlgo::DirectNaive).weights, Activation::Relu);
        for algo in ConvAlgo::ALL {
            let l = conv_layer(algo);
            assert!(l.accepts(input.shape()));
            assert_eq!(l.out_shape(input.shape()), reference.shape());
            let out = l.execute(input.clone_tensor(), &p);
            assert_allclose(out.data(), reference.data(), 1e-3, 1e-2, l.name().as_str());
        }
    }

    #[test]
    fn conv_rejects_wrong_channels() {
        let l = conv_layer(ConvAlgo::DirectNaive);
        assert!(!l.accepts(Shape5::new(1, 3, 7, 7, 7)));
        assert!(!l.accepts(Shape5::new(1, 2, 2, 7, 7)));
    }

    #[test]
    fn memory_model_monotone_in_batch() {
        let l = conv_layer(ConvAlgo::FftTaskParallel);
        let m1 = l.memory_bytes(Shape5::new(1, 2, 9, 9, 9), 4);
        let m2 = l.memory_bytes(Shape5::new(2, 2, 9, 9, 9), 4);
        assert!(m2 > m1);
    }

    #[test]
    fn pool_and_mpf_layer_shapes() {
        let pl = MaxPoolLayer { window: [2, 2, 2], placement: Placement::Cpu };
        assert!(pl.accepts(Shape5::new(1, 1, 4, 4, 4)));
        assert!(!pl.accepts(Shape5::new(1, 1, 5, 4, 4)));
        let ml = MpfLayer { window: [2, 2, 2], placement: Placement::Cpu };
        assert!(ml.accepts(Shape5::new(1, 1, 5, 5, 5)));
        assert!(!ml.accepts(Shape5::new(1, 1, 4, 5, 5)));
        assert_eq!(ml.out_shape(Shape5::new(1, 1, 5, 5, 5)).s, 8);
    }

    #[test]
    fn measured_memory_within_model() {
        // The Table II model must upper-bound (within slack for
        // planner pessimism) what the primitives actually allocate.
        let p = tpool();
        let sh = Shape5::new(1, 2, 9, 9, 9);
        for algo in [
            ConvAlgo::DirectNaive,
            ConvAlgo::DirectMkl,
            ConvAlgo::FftDataParallel,
            ConvAlgo::FftTaskParallel,
            ConvAlgo::GpuFft,
        ] {
            let l = conv_layer(algo);
            let model = l.memory_bytes(sh, p.workers()) as i64;
            let input = Tensor5::random(sh, 3);
            let (_out, peak) = crate::memory::measure(|| l.execute(input, &p));
            // `measure` reports extra bytes beyond entry; the input was
            // allocated before, so add it back for the comparison.
            let measured = peak as i64 + sh.bytes_f32() as i64;
            assert!(
                measured <= model + (crate::memory::model::GPU_FFT_K_BYTES as i64),
                "{algo:?}: measured {measured} > model {model}"
            );
        }
    }
}
