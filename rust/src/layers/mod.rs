//! Layer primitives — the composable units of Fig. 1.
//!
//! A ConvNet implementation is a choice of one primitive per layer
//! (§VI). Every primitive knows its output shape (Table I), its peak
//! memory (Table II) and its analytic FLOPs, so the optimizer can search
//! plans without executing them; `execute` then runs the chosen plan
//! against an [`ExecCtx`], drawing every output tensor and workspace
//! from the context's arena. [`LayerPrimitive::plan_workspace`] reports
//! the same Table II working set as bytes so `optimizer::compile` can
//! size the arena up front from the model the search already ranked
//! plans with.

use std::sync::{Arc, Mutex};

use crate::conv::precomp::{cache_mode, CacheMode, PrecomputedKernels, SpectraLayout, SpectraMap};
use crate::conv::{self, Activation, Weights};
use crate::exec::{ExecCtx, WorkspaceReq};
use crate::fft::fft_optimal_vec3;
use crate::memory::model::{
    conv_memory_bytes, conv_pool_fused_memory_bytes, kernel_spectra_bytes_p, mpf_memory_bytes,
    pool_memory_bytes, ConvAlgo, ConvDims,
};
use crate::pool::{max_pool, max_pool_out_shape, mpf_forward, mpf_out_shape};
use crate::precision::Precision;
use crate::tensor::{Shape5, Tensor5, Vec3};
use crate::util::faults::{self, FaultSite};
use crate::util::pool::TaskPool;
use crate::util::sync::recover_lock;

/// Which device a primitive is meant for (§IV.A vs §IV.B). On this
/// testbed the GPU is simulated — see `crate::device`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// CPU primitive (§IV.A).
    Cpu,
    /// (Simulated) GPU primitive (§IV.B).
    Gpu,
}

/// A layer primitive: shape/cost metadata + execution.
pub trait LayerPrimitive: Send + Sync {
    /// Short display name (Table IV uses these tags).
    fn name(&self) -> String;

    /// Output shape for a given input shape (panics on invalid input —
    /// use [`LayerPrimitive::accepts`] to probe).
    fn out_shape(&self, input: Shape5) -> Shape5;

    /// Whether this primitive can process the given input shape.
    fn accepts(&self, input: Shape5) -> bool;

    /// Peak memory (bytes) per Table II.
    fn memory_bytes(&self, input: Shape5, threads: usize) -> u64;

    /// Arena bytes this layer draws while executing on `input` — the
    /// Table II working set (input + output + transients) — plus any
    /// resident kernel-spectra row. Plans take the max of the arena
    /// bytes and the sum of the resident rows across layers
    /// ([`WorkspaceReq::stack`]); see
    /// [`crate::optimizer::CompiledPlan::workspace_req`].
    fn plan_workspace(&self, input: Shape5, threads: usize) -> WorkspaceReq {
        WorkspaceReq { bytes: self.memory_bytes(input, threads), resident_bytes: 0 }
    }

    /// Analytic FLOPs per Table I.
    fn flops(&self, input: Shape5) -> f64;

    /// CPU or GPU primitive.
    fn placement(&self) -> Placement;

    /// Run the layer. Consumes `input` (its backing store is retired
    /// into the context's arena) and draws the output from the arena.
    fn execute(&self, input: Tensor5, ctx: &mut ExecCtx<'_>) -> Tensor5;

    /// Precompute any weight-derived resident state for the given input
    /// shape (idempotent). [`ConvLayer`] builds its kernel-spectra
    /// cache here; everything else is a no-op. Called by
    /// [`crate::optimizer::CompiledPlan::warm_kernel_caches`] so the
    /// one-off cost lands at plan-build time.
    fn warm(&self, _input: Shape5, _pool: &TaskPool) {}

    /// Resident bytes of precomputed kernel spectra this layer has
    /// built (0 for layers without a cache, or before warming).
    fn kernel_cache_bytes(&self) -> u64 {
        0
    }

    /// Drop any resident kernel-spectra cache to relieve memory
    /// pressure, returning the bytes released (0 when nothing is
    /// resident). A shed layer falls back to on-the-fly kernel
    /// transforms and must *not* rebuild the cache until
    /// [`LayerPrimitive::restore_kernel_cache`] — otherwise the next
    /// warm call would immediately re-allocate under the same pressure.
    fn shed_kernel_cache(&self) -> u64 {
        0
    }

    /// Allow a shed kernel-spectra cache to rebuild lazily on next use
    /// (called once memory pressure has cleared).
    fn restore_kernel_cache(&self) {}
}

/// Shed-aware kernel-spectra cache state: the per-padded-shape spectra
/// map plus a pressure flag blocking *new builds* while shed (shapes
/// still resident stay servable — reads cost nothing).
struct KernelCacheState {
    map: SpectraMap,
    shed: bool,
}

/// Convolutional layer with a fixed algorithm choice.
pub struct ConvLayer {
    /// Shared layer weights.
    pub weights: Arc<Weights>,
    /// Algorithm choice (fixed per plan).
    pub algo: ConvAlgo,
    /// Post-convolution activation.
    pub act: Activation,
    /// Whether this layer precomputes its kernel spectra (the plan's
    /// per-layer cache decision; see [`ConvLayer::with_kernel_cache`]).
    cache_enabled: bool,
    /// Storage precision of this layer's cached spectra and output
    /// activations (the plan's per-layer precision decision; see
    /// [`ConvLayer::with_precision`]). Compute stays f32.
    precision: Precision,
    /// Per-padded-shape spectra map, built on first use (or
    /// [`LayerPrimitive::warm`]) and shared via `Arc` across every
    /// worker and shard; shed largest-shape-first under memory
    /// pressure (see [`LayerPrimitive::shed_kernel_cache`]).
    kernel_cache: Mutex<KernelCacheState>,
}

impl ConvLayer {
    /// Layer from weights + algorithm + activation (kernel-spectra
    /// caching off — the searched plan enables it via
    /// [`ConvLayer::with_kernel_cache`]).
    pub fn new(weights: Arc<Weights>, algo: ConvAlgo, act: Activation) -> Self {
        ConvLayer {
            weights,
            algo,
            act,
            cache_enabled: false,
            precision: Precision::F32,
            kernel_cache: Mutex::new(KernelCacheState { map: SpectraMap::new(), shed: false }),
        }
    }

    /// Enable (or disable) the precomputed kernel-spectra cache for
    /// this layer. Only meaningful for the FFT families; ignored by
    /// algorithms that transform no kernels. The runtime kill switch
    /// `ZNNI_KERNEL_CACHE=off` overrides an enabled cache.
    pub fn with_kernel_cache(mut self, enabled: bool) -> Self {
        self.cache_enabled = enabled && self.algo.uses_kernel_cache();
        self
    }

    /// Whether the plan enabled kernel-spectra caching for this layer.
    pub fn kernel_cache_enabled(&self) -> bool {
        self.cache_enabled
    }

    /// Set the storage precision of this layer's cached kernel spectra
    /// and output activations (the searched per-layer axis — see
    /// [`crate::precision`]). The plan's decision is authoritative at
    /// execute time: the `ZNNI_PRECISION` mode gates which candidates
    /// the *optimizer* may pick, so a layer only ever receives a
    /// half-width precision when the mode admitted it at plan time.
    /// Compute stays f32; a half precision narrows the resident spectra
    /// (half the bytes) and quantizes the layer's output through an
    /// arena half-buffer exactly as a stored-half activation would be.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// The storage precision the plan assigned this layer.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// The cache to execute against for `input`, building it on first
    /// use. The layer keeps a [`SpectraMap`] — one spectra row per
    /// distinct padded FFT shape — so mixed patch sizes (several
    /// tenants routed through one shared plan, or shape-heterogeneous
    /// traffic) each hit precomputed spectra after their first warm.
    /// Returns `None` when caching is off (plan decision or the
    /// `ZNNI_KERNEL_CACHE=off` kill switch), or when the shape is not
    /// yet resident and builds are blocked because the layer is shed
    /// under memory pressure — the primitive then falls back to
    /// on-the-fly transforms. Shapes still resident while shed remain
    /// servable: a cache hit costs no new bytes.
    fn kernels_for(&self, input: Shape5, pool: &TaskPool) -> Option<Arc<PrecomputedKernels>> {
        if !self.cache_enabled || cache_mode() == CacheMode::Off {
            return None;
        }
        let layout = SpectraLayout::for_algo(self.algo)?;
        let padded = fft_optimal_vec3(input.spatial());
        let (f_out, f_in) = (self.weights.f_out, self.weights.f_in);
        let mut st = recover_lock(&self.kernel_cache);
        if let Some(hit) = st.map.get(layout, padded, f_out, f_in, self.precision) {
            return Some(hit);
        }
        if st.shed {
            return None;
        }
        faults::fire(FaultSite::KernelCacheWarm);
        let built = Arc::new(PrecomputedKernels::build_p(
            &self.weights,
            layout,
            padded,
            pool,
            self.precision,
        ));
        st.map.insert(built.clone());
        Some(built)
    }

    /// Stage `out` through half-width storage when the plan assigned
    /// this layer a reduced precision: narrow the activations into an
    /// arena u16 buffer (the stored form), then widen them back —
    /// exactly the quantization a consumer of stored-half activations
    /// would observe. No-op at [`Precision::F32`]. The staging buffer
    /// is charged in [`LayerPrimitive::memory_bytes`] so ledger peaks
    /// stay within the planned workspace.
    fn store_activations(&self, mut out: Tensor5, ctx: &mut ExecCtx<'_>) -> Tensor5 {
        if !self.precision.is_half() {
            return out;
        }
        let len = out.data().len();
        let mut bits = ctx.take_u16_raw(len);
        self.precision.narrow(&mut bits, out.data());
        self.precision.widen(out.data_mut(), &bits);
        ctx.put_u16(bits);
        out
    }

    fn dims(&self, input: Shape5) -> ConvDims {
        ConvDims {
            s: input.s,
            f_in: self.weights.f_in,
            f_out: self.weights.f_out,
            n: input.spatial(),
            k: self.weights.k,
        }
    }
}

impl LayerPrimitive for ConvLayer {
    fn name(&self) -> String {
        self.algo.tag().to_string()
    }

    fn out_shape(&self, input: Shape5) -> Shape5 {
        conv::conv_out_shape(input, self.weights.f_out, self.weights.k)
    }

    fn accepts(&self, input: Shape5) -> bool {
        input.f == self.weights.f_in
            && input.x >= self.weights.k[0]
            && input.y >= self.weights.k[1]
            && input.z >= self.weights.k[2]
    }

    fn memory_bytes(&self, input: Shape5, threads: usize) -> u64 {
        let d = self.dims(input);
        let base = conv_memory_bytes(self.algo, &d, threads);
        // Half-precision activation staging: the u16 buffer the output
        // is narrowed through (2 bytes per output element), live beside
        // the output during the hand-off.
        if self.precision.is_half() {
            base + self.precision.elem_bytes() * (d.s as u64 * d.f_out as u64) * d.n_out_elems()
        } else {
            base
        }
    }

    fn plan_workspace(&self, input: Shape5, threads: usize) -> WorkspaceReq {
        WorkspaceReq {
            bytes: self.memory_bytes(input, threads),
            // The spectra row is resident beside the arena when the
            // plan enabled caching — the analytic size at the plan's
            // storage precision (half-width rows cost exactly half), so
            // the requirement is known before anything is built.
            resident_bytes: if self.cache_enabled {
                kernel_spectra_bytes_p(self.algo, &self.dims(input), self.precision)
            } else {
                0
            },
        }
    }

    fn flops(&self, input: Shape5) -> f64 {
        let d = self.dims(input);
        match self.algo {
            ConvAlgo::DirectNaive
            | ConvAlgo::DirectMkl
            | ConvAlgo::DirectFused
            | ConvAlgo::DirectFusedPool
            | ConvAlgo::GpuDenseNoWorkspace
            | ConvAlgo::GpuDensePrecomp => d.direct_flops(),
            ConvAlgo::FftDataParallel | ConvAlgo::FftTaskParallel | ConvAlgo::GpuFft => {
                d.fft_flops()
            }
        }
    }

    fn placement(&self) -> Placement {
        if self.algo.is_gpu() {
            Placement::Gpu
        } else {
            Placement::Cpu
        }
    }

    fn warm(&self, input: Shape5, pool: &TaskPool) {
        let _ = self.kernels_for(input, pool);
    }

    fn kernel_cache_bytes(&self) -> u64 {
        recover_lock(&self.kernel_cache).map.bytes()
    }

    fn shed_kernel_cache(&self) -> u64 {
        let mut st = recover_lock(&self.kernel_cache);
        // Drop our Arc to the largest cached shape (workers mid-execute
        // keep theirs alive until their batch finishes) and block new
        // builds until restored; repeated shed calls drain the map one
        // shape at a time, largest-first.
        let bytes = st.map.evict_largest();
        if bytes > 0 {
            st.shed = true;
        }
        bytes
    }

    fn restore_kernel_cache(&self) {
        recover_lock(&self.kernel_cache).shed = false;
    }

    fn execute(&self, input: Tensor5, ctx: &mut ExecCtx<'_>) -> Tensor5 {
        let w = &self.weights;
        let out = match self.algo {
            ConvAlgo::DirectNaive => {
                let out = conv::direct::conv_direct_naive(&input, w, self.act, ctx);
                ctx.retire(input);
                out
            }
            ConvAlgo::DirectMkl => {
                let out = conv::direct::conv_direct_mkl(&input, w, self.act, ctx);
                ctx.retire(input);
                out
            }
            // A bare `ConvLayer` has no pooling window, so both fused
            // variants run the register-tiled fused conv; the optimizer
            // instantiates `FusedConvPoolLayer` (not this) for
            // `DirectFusedPool` plans, where the pool window is known.
            ConvAlgo::DirectFused | ConvAlgo::DirectFusedPool => {
                let out = conv::direct_fused::conv_direct_fused(&input, w, self.act, ctx);
                ctx.retire(input);
                out
            }
            ConvAlgo::FftDataParallel => {
                let kern = self.kernels_for(input.shape(), ctx.pool());
                conv::fft_dp::conv_fft_dp_with(input, w, self.act, ctx, kern.as_deref())
            }
            ConvAlgo::FftTaskParallel => {
                let kern = self.kernels_for(input.shape(), ctx.pool());
                conv::fft_tp::conv_fft_tp_with(input, w, self.act, ctx, kern.as_deref())
            }
            // Dense-conv stand-ins for the two cuDNN primitives: the
            // no-workspace variant is the slow/lean one, the precomp
            // variant trades workspace memory for speed (§IV.B.1). The
            // workspace is drawn from the arena so the Table II
            // difference stays observable to the ledger.
            ConvAlgo::GpuDenseNoWorkspace => {
                let out = conv::direct::conv_direct_naive(&input, w, self.act, ctx);
                ctx.retire(input);
                out
            }
            ConvAlgo::GpuDensePrecomp => {
                let ish = input.shape();
                // Stand-in workspace: sized like the input, never read.
                let workspace = ctx.take_f32_raw(ish.len());
                let out = conv::direct::conv_direct_mkl(&input, w, self.act, ctx);
                ctx.put_f32(workspace);
                ctx.retire(input);
                out
            }
            ConvAlgo::GpuFft => {
                let kern = self.kernels_for(input.shape(), ctx.pool());
                conv::fft_gpu::conv_fft_gpu_with(input, w, self.act, ctx, kern.as_deref())
            }
        };
        self.store_activations(out, ctx)
    }
}

/// Plain max-pooling layer.
pub struct MaxPoolLayer {
    /// Pooling window p.
    pub window: Vec3,
    /// Device placement.
    pub placement: Placement,
}

impl LayerPrimitive for MaxPoolLayer {
    fn name(&self) -> String {
        "Pool".into()
    }

    fn out_shape(&self, input: Shape5) -> Shape5 {
        max_pool_out_shape(input, self.window)
    }

    fn accepts(&self, input: Shape5) -> bool {
        // All three spatial extents must be non-zero: a zero extent
        // passes the divisibility test (0 % p == 0) but has no voxels
        // to pool.
        input.x > 0
            && input.y > 0
            && input.z > 0
            && input.x % self.window[0] == 0
            && input.y % self.window[1] == 0
            && input.z % self.window[2] == 0
    }

    fn memory_bytes(&self, input: Shape5, _threads: usize) -> u64 {
        pool_memory_bytes(input.s, input.f, input.spatial(), self.window)
    }

    fn flops(&self, input: Shape5) -> f64 {
        // Table I: S·f·n³ comparisons.
        input.len() as f64
    }

    fn placement(&self) -> Placement {
        self.placement
    }

    fn execute(&self, input: Tensor5, ctx: &mut ExecCtx<'_>) -> Tensor5 {
        let out = max_pool(&input, self.window, ctx);
        ctx.retire(input);
        out
    }
}

/// Max-pooling-fragments layer.
pub struct MpfLayer {
    /// Pooling window p.
    pub window: Vec3,
    /// Device placement.
    pub placement: Placement,
}

impl LayerPrimitive for MpfLayer {
    fn name(&self) -> String {
        "MPF".into()
    }

    fn out_shape(&self, input: Shape5) -> Shape5 {
        mpf_out_shape(input, self.window)
    }

    fn accepts(&self, input: Shape5) -> bool {
        (input.x + 1) % self.window[0] == 0
            && (input.y + 1) % self.window[1] == 0
            && (input.z + 1) % self.window[2] == 0
    }

    fn memory_bytes(&self, input: Shape5, _threads: usize) -> u64 {
        mpf_memory_bytes(input.s, input.f, input.spatial(), self.window)
    }

    fn flops(&self, input: Shape5) -> f64 {
        // Table I: S·f·n³·p³.
        input.len() as f64 * (self.window[0] * self.window[1] * self.window[2]) as f64
    }

    fn placement(&self) -> Placement {
        self.placement
    }

    fn execute(&self, input: Tensor5, ctx: &mut ExecCtx<'_>) -> Tensor5 {
        let out = mpf_forward(&input, self.window, ctx);
        ctx.retire(input);
        out
    }
}

/// Fused convolution + max-pool layer ([`ConvAlgo::DirectFusedPool`]):
/// one primitive spanning a conv→pool pair of the network spec. The
/// pre-pool tensor is never materialized — each worker convolves a
/// `p₀`-row tile into per-worker scratch and max-reduces it straight
/// into the pooled output, so the Table II row drops the inter-layer
/// `S·f'·n'` tensor (see
/// [`crate::memory::model::conv_pool_fused_memory_bytes`]).
///
/// The optimizer emits this for a `Conv` spec layer whose plan chose
/// `DirectFusedPool`; the following `Pool` spec layer compiles to
/// [`PoolFusedLayer`], a pass-through, so plan layers stay 1:1 with
/// the network spec.
pub struct FusedConvPoolLayer {
    /// Shared layer weights of the convolution half.
    pub weights: Arc<Weights>,
    /// Pooling window p of the fused max-pool half.
    pub window: Vec3,
    /// Activation applied between the conv accumulate and the pool.
    pub act: Activation,
}

impl FusedConvPoolLayer {
    fn dims(&self, input: Shape5) -> ConvDims {
        ConvDims {
            s: input.s,
            f_in: self.weights.f_in,
            f_out: self.weights.f_out,
            n: input.spatial(),
            k: self.weights.k,
        }
    }
}

impl LayerPrimitive for FusedConvPoolLayer {
    fn name(&self) -> String {
        "DirectFP".into()
    }

    fn out_shape(&self, input: Shape5) -> Shape5 {
        let csh = conv::conv_out_shape(input, self.weights.f_out, self.weights.k);
        max_pool_out_shape(csh, self.window)
    }

    fn accepts(&self, input: Shape5) -> bool {
        if input.f != self.weights.f_in
            || input.x < self.weights.k[0]
            || input.y < self.weights.k[1]
            || input.z < self.weights.k[2]
        {
            return false;
        }
        let csh = conv::conv_out_shape(input, self.weights.f_out, self.weights.k);
        csh.x > 0
            && csh.y > 0
            && csh.z > 0
            && csh.x % self.window[0] == 0
            && csh.y % self.window[1] == 0
            && csh.z % self.window[2] == 0
    }

    fn memory_bytes(&self, input: Shape5, threads: usize) -> u64 {
        conv_pool_fused_memory_bytes(&self.dims(input), self.window, threads)
    }

    fn flops(&self, input: Shape5) -> f64 {
        // Convolution FLOPs only; the pool's comparisons ride along in
        // the fitted per-algorithm rate (`CostModel::conv_secs` divides
        // these FLOPs by the measured fused throughput, which already
        // includes the max-reduce).
        self.dims(input).direct_flops()
    }

    fn placement(&self) -> Placement {
        Placement::Cpu
    }

    fn execute(&self, input: Tensor5, ctx: &mut ExecCtx<'_>) -> Tensor5 {
        let out = conv::direct_fused::conv_direct_fused_pool(
            &input,
            &self.weights,
            self.act,
            self.window,
            ctx,
        );
        ctx.retire(input);
        out
    }
}

/// Pass-through primitive standing in for a `Pool` spec layer whose
/// max-reduce was folded into the preceding [`FusedConvPoolLayer`]. It
/// keeps compiled plans 1:1 with the network spec: the fused conv
/// already produced the pooled tensor, so this layer is the identity —
/// zero FLOPs, zero extra memory.
pub struct PoolFusedLayer;

impl LayerPrimitive for PoolFusedLayer {
    fn name(&self) -> String {
        "PoolFused".into()
    }

    fn out_shape(&self, input: Shape5) -> Shape5 {
        input
    }

    fn accepts(&self, _input: Shape5) -> bool {
        true
    }

    fn memory_bytes(&self, _input: Shape5, _threads: usize) -> u64 {
        0
    }

    fn flops(&self, _input: Shape5) -> f64 {
        0.0
    }

    fn placement(&self) -> Placement {
        Placement::Cpu
    }

    fn execute(&self, input: Tensor5, _ctx: &mut ExecCtx<'_>) -> Tensor5 {
        input
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::{ChipTopology, TaskPool};
    use crate::util::quick::assert_allclose;

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    fn conv_layer(algo: ConvAlgo) -> ConvLayer {
        ConvLayer::new(Arc::new(Weights::random(3, 2, [3, 3, 3], 1)), algo, Activation::Relu)
    }

    #[test]
    fn all_conv_algos_agree() {
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(1, 2, 7, 7, 7), 2);
        let w = &conv_layer(ConvAlgo::DirectNaive).weights;
        let reference = conv::conv_layer_reference(&input, w, Activation::Relu);
        for algo in ConvAlgo::ALL {
            let l = conv_layer(algo);
            assert!(l.accepts(input.shape()));
            assert_eq!(l.out_shape(input.shape()), reference.shape());
            let out = l.execute(input.clone_tensor(), &mut ctx);
            assert_allclose(out.data(), reference.data(), 1e-3, 1e-2, l.name().as_str());
        }
    }

    #[test]
    fn conv_rejects_wrong_channels() {
        let l = conv_layer(ConvAlgo::DirectNaive);
        assert!(!l.accepts(Shape5::new(1, 3, 7, 7, 7)));
        assert!(!l.accepts(Shape5::new(1, 2, 2, 7, 7)));
    }

    #[test]
    fn memory_model_monotone_in_batch() {
        let l = conv_layer(ConvAlgo::FftTaskParallel);
        let m1 = l.memory_bytes(Shape5::new(1, 2, 9, 9, 9), 4);
        let m2 = l.memory_bytes(Shape5::new(2, 2, 9, 9, 9), 4);
        assert!(m2 > m1);
    }

    #[test]
    fn plan_workspace_matches_table2_model() {
        for algo in ConvAlgo::ALL {
            let l = conv_layer(algo);
            let sh = Shape5::new(1, 2, 9, 9, 9);
            assert_eq!(l.plan_workspace(sh, 4).bytes, l.memory_bytes(sh, 4));
            assert_eq!(l.plan_workspace(sh, 4).resident_bytes, 0, "cache off by default");
        }
    }

    #[test]
    fn plan_workspace_adds_resident_spectra_row_when_cached() {
        let sh = Shape5::new(1, 2, 9, 9, 9);
        for algo in ConvAlgo::ALL {
            let l = conv_layer(algo).with_kernel_cache(true);
            let req = l.plan_workspace(sh, 4);
            assert_eq!(req.bytes, l.memory_bytes(sh, 4), "{algo:?}: arena row unchanged");
            let expect = kernel_spectra_bytes_p(algo, &l.dims(sh), Precision::F32);
            assert_eq!(req.resident_bytes, expect, "{algo:?}");
            if algo.uses_kernel_cache() {
                assert!(req.resident_bytes > 0, "{algo:?}");
            } else {
                assert_eq!(req.resident_bytes, 0, "{algo:?}: nothing to cache");
            }
        }
    }

    #[test]
    fn half_precision_plan_workspace_halves_resident_and_adds_staging() {
        let sh = Shape5::new(1, 2, 9, 9, 9);
        for algo in [ConvAlgo::FftDataParallel, ConvAlgo::FftTaskParallel, ConvAlgo::GpuFft] {
            let full = conv_layer(algo).with_kernel_cache(true);
            let fr = full.plan_workspace(sh, 4);
            for p in Precision::HALF {
                let half = conv_layer(algo).with_kernel_cache(true).with_precision(p);
                let hr = half.plan_workspace(sh, 4);
                assert_eq!(hr.resident_bytes * 2, fr.resident_bytes, "{algo:?} {}", p.name());
                // Arena row grows by exactly the u16 staging buffer:
                // 2 bytes per output element.
                let d = half.dims(sh);
                let staging = 2 * (d.s as u64 * d.f_out as u64) * d.n_out_elems();
                assert_eq!(hr.bytes, fr.bytes + staging, "{algo:?} {}", p.name());
            }
        }
    }

    #[test]
    fn half_precision_layer_stays_within_error_bound_of_f32() {
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(1, 2, 7, 7, 7), 51);
        for algo in [ConvAlgo::DirectMkl, ConvAlgo::FftDataParallel, ConvAlgo::FftTaskParallel] {
            let w = Arc::new(Weights::random(3, 2, [3, 3, 3], 52));
            let oracle = ConvLayer::new(w.clone(), algo, Activation::Relu)
                .execute(input.clone_tensor(), &mut ctx);
            for prec in Precision::HALF {
                // The documented plan-output bounds (ARCHITECTURE.md):
                // one narrowing of activations (+ narrowed spectra when
                // cached) stays well inside these.
                let rtol = match prec {
                    Precision::F16 => 2e-2f32,
                    Precision::Bf16 => 1e-1,
                    Precision::F32 => unreachable!(),
                };
                for cache in [false, true] {
                    let l = ConvLayer::new(w.clone(), algo, Activation::Relu)
                        .with_kernel_cache(cache)
                        .with_precision(prec);
                    let got = l.execute(input.clone_tensor(), &mut ctx);
                    for (g, e) in got.data().iter().zip(oracle.data()) {
                        // Relative above |e| = 1, absolute below: FFT-
                        // domain quantization error scales with the
                        // signal norm, not the (possibly cancelled or
                        // relu-clamped) output value.
                        let tol = rtol * e.abs().max(1.0);
                        assert!(
                            (g - e).abs() <= tol,
                            "{algo:?} {} cache={cache}: {g} vs {e}",
                            prec.name()
                        );
                    }
                    ctx.retire(got);
                }
            }
            ctx.retire(oracle);
        }
    }

    #[test]
    fn half_precision_layer_is_deterministic_warm_and_cold() {
        let p = tpool();
        let input = Tensor5::random(Shape5::new(1, 2, 7, 7, 7), 53);
        let w = Arc::new(Weights::random(3, 2, [3, 3, 3], 54));
        for prec in Precision::HALF {
            let l = ConvLayer::new(w.clone(), ConvAlgo::FftTaskParallel, Activation::Relu)
                .with_kernel_cache(true)
                .with_precision(prec);
            // Cold context, then the same warm context twice: all three
            // runs must agree bit for bit (narrow is RNE, widen exact,
            // and the accumulation order is fixed).
            let mut cold = ExecCtx::new(&p);
            let a = l.execute(input.clone_tensor(), &mut cold);
            let mut warm = ExecCtx::new(&p);
            let b = l.execute(input.clone_tensor(), &mut warm);
            let c = l.execute(input.clone_tensor(), &mut warm);
            for ((x, y), z) in a.data().iter().zip(b.data()).zip(c.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{}", prec.name());
                assert_eq!(y.to_bits(), z.to_bits(), "{}", prec.name());
            }
        }
    }

    #[test]
    fn cached_layer_matches_uncached_and_reports_bytes() {
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(1, 2, 7, 7, 7), 6);
        for algo in [ConvAlgo::FftDataParallel, ConvAlgo::FftTaskParallel, ConvAlgo::GpuFft] {
            let w = Arc::new(Weights::random(3, 2, [3, 3, 3], 2));
            let plain = ConvLayer::new(w.clone(), algo, Activation::Relu);
            let cached = ConvLayer::new(w, algo, Activation::Relu).with_kernel_cache(true);
            assert!(cached.kernel_cache_enabled());
            assert_eq!(cached.kernel_cache_bytes(), 0, "nothing built before warm");
            cached.warm(input.shape(), &p);
            // The kill switch may disable the cache in this process
            // (ZNNI_KERNEL_CACHE=off); outputs must agree either way.
            let a = plain.execute(input.clone_tensor(), &mut ctx);
            let b = cached.execute(input.clone_tensor(), &mut ctx);
            assert_eq!(a.data(), b.data(), "{algo:?}: cached path must be bit-identical");
            ctx.retire(a);
            ctx.retire(b);
        }
    }

    #[test]
    fn shed_blocks_rebuild_until_restore() {
        let p = tpool();
        let input = Tensor5::random(Shape5::new(1, 2, 7, 7, 7), 6);
        let w = Arc::new(Weights::random(3, 2, [3, 3, 3], 2));
        let cached =
            ConvLayer::new(w, ConvAlgo::FftTaskParallel, Activation::Relu).with_kernel_cache(true);
        cached.warm(input.shape(), &p);
        let bytes = cached.kernel_cache_bytes();
        // (Under ZNNI_KERNEL_CACHE=off nothing is resident and shed is
        // a no-op returning 0 — every assertion below still holds.)
        assert_eq!(cached.shed_kernel_cache(), bytes);
        assert_eq!(cached.kernel_cache_bytes(), 0, "shed must release the row");
        cached.warm(input.shape(), &p);
        assert_eq!(cached.kernel_cache_bytes(), 0, "warm must not rebuild while shed");
        let mut ctx = ExecCtx::new(&p);
        let a = cached.execute(input.clone_tensor(), &mut ctx);
        cached.restore_kernel_cache();
        cached.warm(input.shape(), &p);
        assert_eq!(cached.kernel_cache_bytes(), bytes, "restore re-admits the rebuild");
        let b = cached.execute(input.clone_tensor(), &mut ctx);
        assert_eq!(a.data(), b.data(), "shed fallback must be bit-identical");
        ctx.retire(a);
        ctx.retire(b);
    }

    #[test]
    fn per_shape_spectra_map_serves_mixed_patch_shapes() {
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        let w = Arc::new(Weights::random(3, 2, [3, 3, 3], 31));
        let plain = ConvLayer::new(w.clone(), ConvAlgo::FftTaskParallel, Activation::Relu);
        let cached =
            ConvLayer::new(w, ConvAlgo::FftTaskParallel, Activation::Relu).with_kernel_cache(true);
        let small = Tensor5::random(Shape5::new(1, 2, 7, 7, 7), 32);
        let big = Tensor5::random(Shape5::new(1, 2, 11, 11, 11), 33);
        cached.warm(small.shape(), &p);
        let small_bytes = cached.kernel_cache_bytes();
        cached.warm(big.shape(), &p);
        let both = cached.kernel_cache_bytes();
        // (Under ZNNI_KERNEL_CACHE=off nothing is resident; the
        // identity assertions below still hold via the fallback path.)
        if small_bytes > 0 {
            assert!(both > small_bytes, "second shape must add its own spectra row");
        }
        for t in [&small, &big] {
            let a = plain.execute(t.clone_tensor(), &mut ctx);
            let b = cached.execute(t.clone_tensor(), &mut ctx);
            assert_eq!(a.data(), b.data(), "cached path bit-identical at {:?}", t.shape());
            ctx.retire(a);
            ctx.retire(b);
        }
        assert_eq!(cached.kernel_cache_bytes(), both, "execute must not grow the map");
    }

    #[test]
    fn shed_evicts_largest_shape_first_with_byte_accounting() {
        let p = tpool();
        let w = Arc::new(Weights::random(3, 2, [3, 3, 3], 41));
        let cached =
            ConvLayer::new(w, ConvAlgo::FftTaskParallel, Activation::Relu).with_kernel_cache(true);
        let small = Shape5::new(1, 2, 7, 7, 7);
        let big = Shape5::new(1, 2, 11, 11, 11);
        cached.warm(small, &p);
        let small_bytes = cached.kernel_cache_bytes();
        cached.warm(big, &p);
        let big_bytes = cached.kernel_cache_bytes() - small_bytes;
        // (Under ZNNI_KERNEL_CACHE=off every figure here is 0 and the
        // assertions degenerate but still hold.)
        assert!(big_bytes >= small_bytes, "bigger padded shape must cost more");
        assert_eq!(cached.shed_kernel_cache(), big_bytes, "largest shape goes first");
        assert_eq!(cached.kernel_cache_bytes(), small_bytes, "small shape stays resident");
        // While shed, the evicted shape must not rebuild, but the
        // still-resident shape keeps serving from cache.
        cached.warm(big, &p);
        assert_eq!(cached.kernel_cache_bytes(), small_bytes, "no rebuild while shed");
        let input = Tensor5::random(small, 42);
        let mut ctx = ExecCtx::new(&p);
        let out = cached.execute(input.clone_tensor(), &mut ctx);
        assert_eq!(cached.kernel_cache_bytes(), small_bytes);
        ctx.retire(out);
        assert_eq!(cached.shed_kernel_cache(), small_bytes, "second shed drains the map");
        assert_eq!(cached.kernel_cache_bytes(), 0);
        cached.restore_kernel_cache();
        cached.warm(big, &p);
        assert_eq!(cached.kernel_cache_bytes(), big_bytes, "restore re-admits builds");
    }

    #[test]
    fn with_kernel_cache_ignored_for_non_fft_algos() {
        let l = conv_layer(ConvAlgo::DirectMkl).with_kernel_cache(true);
        assert!(!l.kernel_cache_enabled());
        let p = tpool();
        l.warm(Shape5::new(1, 2, 7, 7, 7), &p);
        assert_eq!(l.kernel_cache_bytes(), 0);
    }

    #[test]
    fn pool_and_mpf_layer_shapes() {
        let pl = MaxPoolLayer { window: [2, 2, 2], placement: Placement::Cpu };
        assert!(pl.accepts(Shape5::new(1, 1, 4, 4, 4)));
        assert!(!pl.accepts(Shape5::new(1, 1, 5, 4, 4)));
        let ml = MpfLayer { window: [2, 2, 2], placement: Placement::Cpu };
        assert!(ml.accepts(Shape5::new(1, 1, 5, 5, 5)));
        assert!(!ml.accepts(Shape5::new(1, 1, 4, 5, 5)));
        assert_eq!(ml.out_shape(Shape5::new(1, 1, 5, 5, 5)).s, 8);
    }

    #[test]
    fn fused_conv_pool_layer_matches_separate_primitives() {
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        let w = Arc::new(Weights::random(3, 2, [3, 3, 3], 21));
        let fused =
            FusedConvPoolLayer { weights: w.clone(), window: [2, 2, 2], act: Activation::Relu };
        let conv = ConvLayer::new(w, ConvAlgo::DirectFused, Activation::Relu);
        let pool_l = MaxPoolLayer { window: [2, 2, 2], placement: Placement::Cpu };
        // conv-out 4³ divides the 2³ window.
        let sh = Shape5::new(1, 2, 6, 6, 6);
        assert!(fused.accepts(sh));
        let input = Tensor5::random(sh, 22);
        let mid = conv.execute(input.clone_tensor(), &mut ctx);
        let expect = pool_l.execute(mid, &mut ctx);
        assert_eq!(fused.out_shape(sh), expect.shape());
        let got = fused.execute(input, &mut ctx);
        // Same tap order and pool-reduce order — bit-identical.
        assert_eq!(got.data(), expect.data(), "fused layer vs conv-then-pool");
        // The fused Table II row must undercut conv + pool: it drops
        // the full-size inter-layer tensor.
        let separate = conv.memory_bytes(sh, p.workers());
        assert!(fused.memory_bytes(sh, p.workers()) < separate);
        assert_eq!(fused.flops(sh), conv.flops(sh), "pool comparisons fold into the rate");
    }

    #[test]
    fn fused_conv_pool_layer_rejects_indivisible_conv_out() {
        let w = Arc::new(Weights::random(3, 2, [3, 3, 3], 23));
        let fused = FusedConvPoolLayer { weights: w, window: [2, 2, 2], act: Activation::Relu };
        // conv-out 5³ does not divide 2.
        assert!(!fused.accepts(Shape5::new(1, 2, 7, 7, 7)));
        // wrong channel count.
        assert!(!fused.accepts(Shape5::new(1, 3, 6, 6, 6)));
        // kernel does not fit.
        assert!(!fused.accepts(Shape5::new(1, 2, 2, 6, 6)));
    }

    #[test]
    fn pool_fused_layer_is_identity() {
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        let l = PoolFusedLayer;
        let sh = Shape5::new(1, 3, 4, 4, 4);
        assert!(l.accepts(sh));
        assert_eq!(l.out_shape(sh), sh);
        assert_eq!(l.memory_bytes(sh, 8), 0);
        assert_eq!(l.flops(sh), 0.0);
        let input = Tensor5::random(sh, 24);
        let before = input.data().to_vec();
        let out = l.execute(input, &mut ctx);
        assert_eq!(out.data(), &before[..], "pass-through must not touch data");
        ctx.retire(out);
    }

    #[test]
    fn maxpool_rejects_zero_extent_on_every_axis() {
        // Regression: `accepts` used to check > 0 only on x, so a zero
        // y or z extent (which trivially divides any window) slipped
        // through to a panicking execute.
        let pl = MaxPoolLayer { window: [2, 2, 2], placement: Placement::Cpu };
        assert!(!pl.accepts(Shape5::new(1, 1, 0, 4, 4)));
        assert!(!pl.accepts(Shape5::new(1, 1, 4, 0, 4)));
        assert!(!pl.accepts(Shape5::new(1, 1, 4, 4, 0)));
        assert!(pl.accepts(Shape5::new(1, 1, 2, 2, 2)));
    }

    #[test]
    fn measured_memory_within_model() {
        // The Table II model must upper-bound (within slack for
        // planner pessimism) what the primitives actually allocate. A
        // cold context is created inside the measured section so arena
        // takes register exactly like the direct allocations they
        // replaced.
        let p = tpool();
        let sh = Shape5::new(1, 2, 9, 9, 9);
        for algo in [
            ConvAlgo::DirectNaive,
            ConvAlgo::DirectMkl,
            ConvAlgo::FftDataParallel,
            ConvAlgo::FftTaskParallel,
            ConvAlgo::GpuFft,
        ] {
            let l = conv_layer(algo);
            let model = l.memory_bytes(sh, p.workers()) as i64;
            let input = Tensor5::random(sh, 3);
            let (_out, peak) = crate::memory::measure(|| {
                let mut ctx = ExecCtx::new(&p);
                l.execute(input, &mut ctx)
            });
            // `measure` reports extra bytes beyond entry; the input was
            // allocated before, so add it back for the comparison.
            let measured = peak as i64 + sh.bytes_f32() as i64;
            assert!(
                measured <= model + (crate::memory::model::GPU_FFT_K_BYTES as i64),
                "{algo:?}: measured {measured} > model {model}"
            );
        }
    }
}
