//! # ZNNi — throughput-maximised 3D ConvNet inference
//!
//! Rust + JAX/Pallas reproduction of *"ZNNi – Maximizing the Inference
//! Throughput of 3D Convolutional Networks on Multi-Core CPUs and GPUs"*
//! (Zlateski, Lee, Seung; 2016).
//!
//! The crate provides:
//!
//! * pruned-FFT machinery ([`fft`], paper §III);
//! * CPU and (simulated-)GPU layer primitives for convolution and
//!   (fragment) pooling ([`conv`], [`pool`], [`layers`], §IV–V);
//! * the Table II memory model and a peak-tracking ledger ([`memory`]);
//! * the four benchmark networks and shape propagation ([`net`],
//!   Tables I & III);
//! * the throughput optimizer ([`optimizer`], §VI), GPU + host RAM
//!   sub-layer execution ([`sublayer`], §VII.A–B) and the CPU–GPU
//!   pipeline ([`pipeline`], §VII.C);
//! * sliding-window patch inference with MPF fragment recombination
//!   ([`inference`], §II);
//! * baseline comparators ([`baselines`], §VIII) and a serving
//!   coordinator ([`coordinator`]);
//! * a PJRT runtime that loads the AOT-compiled JAX/Pallas artifacts
//!   ([`runtime`]);
//! * a SIMD kernel layer with runtime dispatch for the four CPU hot
//!   loops ([`simd`]): AVX2+FMA → SSE2 → scalar on x86, NEON on
//!   aarch64, forced via `ZNNI_SIMD` or [`simd::force`];
//! * arena-backed execution contexts ([`exec`]): primitives draw output
//!   tensors, FFT spectra and workspaces from a reusable [`exec::Arena`]
//!   sized at plan time from the Table II model, so steady-state serving
//!   performs zero transient allocations after a one-patch warmup;
//! * an asynchronous batched serving frontend ([`server`]): sharded
//!   coordinators with bounded admission queues (reject, never block),
//!   per-request deadlines, Table II-budgeted micro-batching and
//!   work-stealing between shards; [`optimizer::search_serving`]
//!   derives the plan and the [`server::ServerConfig`] in one call.

// Style lints this from-scratch codebase deliberately trades away for
// explicit index arithmetic in the kernel code (CI runs clippy with
// `-D warnings`).
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::manual_div_ceil,
    clippy::new_without_default,
    clippy::type_complexity,
    clippy::uninlined_format_args
)]

pub mod approaches;
pub mod baselines;
pub mod conv;
pub mod coordinator;
pub mod device;
pub mod exec;
pub mod fft;
pub mod layers;
pub mod memory;
pub mod inference;
pub mod net;
pub mod optimizer;
pub mod pipeline;
pub mod runtime;
pub mod pool;
pub mod server;
pub mod simd;
pub mod sublayer;
pub mod tensor;
pub mod util;

/// Library version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
