//! # ZNNi — throughput-maximised 3D ConvNet inference
//!
//! Rust + JAX/Pallas reproduction of *"ZNNi – Maximizing the Inference
//! Throughput of 3D Convolutional Networks on Multi-Core CPUs and GPUs"*
//! (Zlateski, Lee, Seung; 2016).
//!
//! The crate provides:
//!
//! * pruned-FFT machinery ([`fft`], paper §III);
//! * CPU and (simulated-)GPU layer primitives for convolution and
//!   (fragment) pooling ([`conv`], [`pool`], [`layers`], §IV–V);
//! * the Table II memory model and a peak-tracking ledger ([`memory`]);
//! * the four benchmark networks and shape propagation ([`net`],
//!   Tables I & III);
//! * the throughput optimizer ([`optimizer`], §VI), GPU + host RAM
//!   sub-layer execution ([`sublayer`], §VII.A–B) and the CPU–GPU
//!   pipeline ([`pipeline`], §VII.C);
//! * sliding-window patch inference with MPF fragment recombination
//!   ([`inference`], §II);
//! * baseline comparators ([`baselines`], §VIII) and a serving
//!   coordinator ([`coordinator`]);
//! * a PJRT runtime that loads the AOT-compiled JAX/Pallas artifacts
//!   ([`runtime`]);
//! * a SIMD kernel layer with runtime dispatch for the four CPU hot
//!   loops ([`simd`]): AVX2+FMA → SSE2 → scalar on x86, NEON on
//!   aarch64, forced via `ZNNI_SIMD` or [`simd::force`];
//! * arena-backed execution contexts ([`exec`]): primitives draw output
//!   tensors, FFT spectra and workspaces from a reusable [`exec::Arena`]
//!   sized at plan time from the Table II model, so steady-state serving
//!   performs zero transient allocations after a one-patch warmup;
//! * an asynchronous batched serving frontend ([`server`]): sharded
//!   coordinators with bounded admission queues (reject, never block),
//!   earliest-deadline-first queue ordering with deadline-miss
//!   counters, Table II-budgeted micro-batching and work-stealing
//!   between shards; [`optimizer::search_serving`] derives the plan and
//!   the [`server::ServerConfig`] in one call;
//! * a measured autotuner ([`optimizer::cost`]):
//!   [`optimizer::CostModel::calibrate_full`] micro-benchmarks every
//!   primitive through a warm execution context at a ladder of sizes,
//!   measures the real batch-dispatch overhead, and persists the result
//!   as a JSON profile so serving startup can reuse a prior run;
//! * a weight-spectrum cache ([`conv::precomp`]): kernel FFTs are
//!   precomputed once per layer and shared via `Arc` across every
//!   worker and shard (bit-identical to on-the-fly transforms), with
//!   caching a per-layer decision the optimizer searches under the
//!   memory budget — resident spectra compete with larger input images
//!   for the same RAM (`ZNNI_KERNEL_CACHE` gates it at runtime);
//! * a reduced-precision storage tier ([`precision`]): cached kernel
//!   spectra and inter-layer activations can be stored as f16 or bf16
//!   bit patterns while all compute stays f32 — a per-layer axis the
//!   optimizer searches exactly like `cache_kernels`, trading halved
//!   resident bytes against the measured widen/narrow cost
//!   (`ZNNI_PRECISION=f32|f16|bf16|auto` gates it end to end);
//! * NUMA-aware placement and live replanning ([`util::numa`],
//!   [`server::replan`]): on a multi-node host each shard gets a home
//!   node — workers pin there and first-touch their arenas so pages
//!   commit node-locally, and stealing prefers same-node victims
//!   (`ZNNI_NUMA` gates it; single-node hosts are a provable no-op) —
//!   while a metrics-driven controller ([`server::Server::start_replanner`])
//!   re-searches the serving plan on sustained load shifts and swaps it
//!   in between batches without dropping a request (`ZNNI_REPLAN`
//!   tunes the hysteresis).
//!
//! The one-minute tour — search a plan, compile it, run a patch:
//!
//! ```
//! use znni::device::Device;
//! use znni::net::zoo::tiny_net;
//! use znni::optimizer::{compile, make_weights, search, CostModel, SearchSpace};
//! use znni::tensor::Tensor5;
//! use znni::util::pool::{ChipTopology, TaskPool};
//!
//! let net = tiny_net(2);
//! let cm = CostModel::default_rates(2);
//! let space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
//! let plan = search(&net, &space, &cm).expect("a feasible plan");
//! let cp = compile(&net, &plan, &make_weights(&net, 1)).unwrap();
//! let pool = TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 });
//! let mut ctx = cp.make_ctx(&pool).unwrap();
//! let out = cp.run(Tensor5::random(plan.input, 7), &mut ctx);
//! assert_eq!(out.shape(), *plan.shapes.last().unwrap());
//! ```

// Style lints this from-scratch codebase deliberately trades away for
// explicit index arithmetic in the kernel code (CI runs clippy with
// `-D warnings`).
#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::manual_div_ceil,
    clippy::new_without_default,
    clippy::type_complexity,
    clippy::uninlined_format_args
)]
// Every public item carries documentation; `cargo doc` is kept
// warning-free by the CI docs job (RUSTDOCFLAGS="-D warnings").
#![warn(missing_docs)]

pub mod approaches;
pub mod baselines;
pub mod conv;
pub mod coordinator;
pub mod device;
pub mod exec;
pub mod fft;
pub mod layers;
pub mod memory;
pub mod inference;
pub mod net;
pub mod optimizer;
pub mod pipeline;
pub mod precision;
pub mod runtime;
pub mod pool;
pub mod server;
pub mod simd;
pub mod sublayer;
pub mod tensor;
pub mod util;

/// Library version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
