//! Max-pooling fragments (MPF) — §V.
//!
//! For window `p`, MPF performs max-pooling at every offset
//! `(ox, oy, oz) ∈ [0,p)³`, producing `p³` fragments per input image.
//! Fragments become extra entries in the *batch* dimension: an input of
//! shape `(S, f, n³)` yields `(S·p³, f, ⌊n/p⌋³)` (Table I row 4). The
//! fragment index is the least-significant part of the output batch
//! index, so downstream layers see a contiguous per-input group —
//! the recombination in `crate::inference` relies on this ordering.

use crate::exec::ExecCtx;
use crate::tensor::{Shape5, Tensor5, Vec3};
use crate::util::sendptr::SendPtr;

use super::maxpool::pool_one;

/// Output shape of an MPF layer. Requires `n + 1 ≡ 0 (mod p)` per
/// dimension so every fragment has the same extent `⌊n/p⌋`.
pub fn mpf_out_shape(input: Shape5, p: Vec3) -> Shape5 {
    assert!(
        (input.x + 1) % p[0] == 0 && (input.y + 1) % p[1] == 0 && (input.z + 1) % p[2] == 0,
        "MPF requires n+1 divisible by p ({input} by {p:?})"
    );
    Shape5 {
        s: input.s * p[0] * p[1] * p[2],
        f: input.f,
        x: input.x / p[0],
        y: input.y / p[1],
        z: input.z / p[2],
    }
}

/// Enumerate fragment offsets in their batch order.
pub fn mpf_fragment_order(p: Vec3) -> Vec<Vec3> {
    let mut v = Vec::with_capacity(p[0] * p[1] * p[2]);
    for ox in 0..p[0] {
        for oy in 0..p[1] {
            for oz in 0..p[2] {
                v.push([ox, oy, oz]);
            }
        }
    }
    v
}

/// MPF layer: batch entry `s` of the input becomes entries
/// `s·p³ .. (s+1)·p³` of the output, one per offset (in
/// [`mpf_fragment_order`]).
pub fn mpf_forward(input: &Tensor5, p: Vec3, ctx: &mut ExecCtx<'_>) -> Tensor5 {
    let pool = ctx.pool();
    let ish = input.shape();
    let osh = mpf_out_shape(ish, p);
    let frags = mpf_fragment_order(p);
    let nf = frags.len();
    let mut out = ctx.tensor5(osh);
    let outp = SendPtr(out.data_mut().as_mut_ptr());
    let ol = osh.image_len();
    let odims = osh.spatial();
    // Parallel over (s, f, fragment): each job writes one output image.
    pool.parallel_for(ish.s * ish.f * nf, |idx| {
        let s = idx / (ish.f * nf);
        let rest = idx % (ish.f * nf);
        let f = rest / nf;
        let fi = rest % nf;
        let off = frags[fi];
        let os = s * nf + fi; // output batch index
        let o = unsafe { outp.slice_mut(osh.image_offset(os, f), ol) };
        pool_one(input.image(s, f), ish.spatial(), p, off, odims, o);
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::{ChipTopology, TaskPool};

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    #[test]
    fn shape_multiplies_batch() {
        let sh = mpf_out_shape(Shape5::new(2, 3, 7, 7, 7), [2, 2, 2]);
        assert_eq!(sh, Shape5::new(16, 3, 3, 3, 3));
    }

    #[test]
    #[should_panic(expected = "n+1 divisible")]
    fn shape_rejects_bad_extent() {
        mpf_out_shape(Shape5::new(1, 1, 8, 7, 7), [2, 2, 2]);
    }

    #[test]
    fn fragment_order_is_row_major() {
        let o = mpf_fragment_order([2, 1, 2]);
        assert_eq!(o, vec![[0, 0, 0], [0, 0, 1], [1, 0, 0], [1, 0, 1]]);
    }

    #[test]
    fn fragment_zero_equals_plain_pooling_region() {
        // Fragment (0,0,0) of MPF on an n=7 image equals max-pooling the
        // leading 6³ sub-volume.
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        let t = Tensor5::random(Shape5::new(1, 1, 7, 7, 7), 3);
        let m = mpf_forward(&t, [2, 2, 2], &mut ctx);
        for x in 0..3 {
            for y in 0..3 {
                for z in 0..3 {
                    let mut expect = f32::NEG_INFINITY;
                    for a in 0..2 {
                        for b in 0..2 {
                            for c in 0..2 {
                                expect = expect.max(t.at(0, 0, 2 * x + a, 2 * y + b, 2 * z + c));
                            }
                        }
                    }
                    assert_eq!(m.at(0, 0, x, y, z), expect);
                }
            }
        }
    }

    #[test]
    fn each_fragment_is_offset_pooling() {
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        let t = Tensor5::random(Shape5::new(2, 2, 5, 5, 5), 5);
        let m = mpf_forward(&t, [2, 2, 2], &mut ctx);
        let order = mpf_fragment_order([2, 2, 2]);
        for s in 0..2 {
            for (fi, off) in order.iter().enumerate() {
                for f in 0..2 {
                    for x in 0..2 {
                        for y in 0..2 {
                            for z in 0..2 {
                                let mut expect = f32::NEG_INFINITY;
                                for a in 0..2 {
                                    for b in 0..2 {
                                        for c in 0..2 {
                                            expect = expect.max(t.at(
                                                s,
                                                f,
                                                off[0] + 2 * x + a,
                                                off[1] + 2 * y + b,
                                                off[2] + 2 * z + c,
                                            ));
                                        }
                                    }
                                }
                                assert_eq!(m.at(s * 8 + fi, f, x, y, z), expect, "s={s} fi={fi}");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn anisotropic_window_2x1x1() {
        // The paper's illustration network uses 2×1×1 MPF windows.
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        let t = Tensor5::random(Shape5::new(1, 1, 5, 4, 4), 9);
        let m = mpf_forward(&t, [2, 1, 1], &mut ctx);
        assert_eq!(m.shape(), Shape5::new(2, 1, 2, 4, 4));
        // Fragment 0: rows 0..2, 2..4 pooled along x; fragment 1: 1..3, 3..5.
        for (fi, off) in [(0usize, 0usize), (1, 1)] {
            for x in 0..2 {
                for y in 0..4 {
                    for z in 0..4 {
                        let lo = t.at(0, 0, off + 2 * x, y, z);
                        let hi = t.at(0, 0, off + 2 * x + 1, y, z);
                        let expect = lo.max(hi);
                        assert_eq!(m.at(fi, 0, x, y, z), expect);
                    }
                }
            }
        }
    }
}
