//! Max-pooling and max-pooling fragments (MPF) — §V.
//!
//! Plain max-pooling subsamples: an `n` image with window `p` yields an
//! `n/p` image (n must be divisible by p). **MPF** instead produces all
//! `p³` pooled *fragments* (one per offset), multiplying the batch
//! dimension of the downstream layers by `p³` — this is what lets a
//! sliding-window ConvNet reuse computation across window positions
//! (equivalent to dilated convolution / strided kernels / max
//! filtering). Fragments are uniform when `n + 1 ≡ 0 (mod p)`.

mod maxpool;
mod mpf;

pub use maxpool::{max_pool, max_pool_out_shape, pool_one, pool_one_scalar};
pub use mpf::{mpf_forward, mpf_fragment_order, mpf_out_shape};
