//! Plain max-pooling layer (§V): each image pooled independently in a
//! parallel-for, window `p`, stride `p`. The output tensor is drawn
//! from the [`ExecCtx`] arena.

use crate::exec::ExecCtx;
use crate::tensor::{Shape5, Tensor5, Vec3};
use crate::util::sendptr::SendPtr;

/// Output shape of max-pooling (Table I row 3). Panics unless the
/// spatial extent is divisible by the window.
pub fn max_pool_out_shape(input: Shape5, p: Vec3) -> Shape5 {
    assert!(
        input.x % p[0] == 0 && input.y % p[1] == 0 && input.z % p[2] == 0,
        "max-pool requires divisible extent ({input} by {p:?})"
    );
    Shape5 { x: input.x / p[0], y: input.y / p[1], z: input.z / p[2], ..input }
}

/// Max-pooling layer.
pub fn max_pool(input: &Tensor5, p: Vec3, ctx: &mut ExecCtx<'_>) -> Tensor5 {
    let pool = ctx.pool();
    let ish = input.shape();
    let osh = max_pool_out_shape(ish, p);
    let mut out = ctx.tensor5(osh);
    let outp = SendPtr(out.data_mut().as_mut_ptr());
    let ol = osh.image_len();
    pool.parallel_for(ish.s * ish.f, |sf| {
        let (s, f) = (sf / ish.f, sf % ish.f);
        let img = input.image(s, f);
        let o = unsafe { outp.slice_mut(osh.image_offset(s, f), ol) };
        pool_one(img, ish.spatial(), p, [0, 0, 0], osh.spatial(), o);
    });
    out
}

thread_local! {
    /// Per-worker z-row scratch for the vectorised pooling path.
    static ROW_MAX: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Max-pool a single image at a given offset with window/stride `p`,
/// writing `odims` pooled voxels. Shared by max-pool (offset 0) and MPF
/// (every offset).
///
/// Restructured for SIMD: for each output (x, y) row the `p₀·p₁` window
/// rows are reduced element-wise along contiguous z
/// ([`crate::simd::max_assign`]), then each output voxel takes the max
/// of its `p₂` strided survivors. Identical results to
/// [`pool_one_scalar`] for non-NaN inputs (NaN ordering is
/// tier-defined; see [`crate::simd::scalar::max_assign`]), which the
/// property tests compare against.
pub fn pool_one(img: &[f32], n: Vec3, p: Vec3, off: Vec3, odims: Vec3, out: &mut [f32]) {
    debug_assert_eq!(out.len(), odims[0] * odims[1] * odims[2]);
    // Resolve the dispatch tier once per image, not once per window row.
    let tier = crate::simd::active();
    ROW_MAX.with(|c| {
        let tmp = &mut *c.borrow_mut();
        if tmp.len() < n[2] {
            tmp.resize(n[2], 0.0);
        }
        let tmp = &mut tmp[..n[2]];
        for x in 0..odims[0] {
            let bx = off[0] + x * p[0];
            for y in 0..odims[1] {
                let by = off[1] + y * p[1];
                let r0 = (bx * n[1] + by) * n[2];
                tmp.copy_from_slice(&img[r0..r0 + n[2]]);
                for a in 0..p[0] {
                    for b in 0..p[1] {
                        if a == 0 && b == 0 {
                            continue;
                        }
                        let rb = ((bx + a) * n[1] + (by + b)) * n[2];
                        crate::simd::max_assign_tier(tier, tmp, &img[rb..rb + n[2]]);
                    }
                }
                let orow = (x * odims[1] + y) * odims[2];
                for z in 0..odims[2] {
                    let bz = off[2] + z * p[2];
                    let mut m = tmp[bz];
                    for c in 1..p[2] {
                        let v = tmp[bz + c];
                        if v > m {
                            m = v;
                        }
                    }
                    out[orow + z] = m;
                }
            }
        }
    });
}

/// Scalar six-loop pooling oracle (the original inner loop): max over
/// the full `p³` window per output voxel.
pub fn pool_one_scalar(img: &[f32], n: Vec3, p: Vec3, off: Vec3, odims: Vec3, out: &mut [f32]) {
    debug_assert_eq!(out.len(), odims[0] * odims[1] * odims[2]);
    for x in 0..odims[0] {
        let bx = off[0] + x * p[0];
        for y in 0..odims[1] {
            let by = off[1] + y * p[1];
            for z in 0..odims[2] {
                let bz = off[2] + z * p[2];
                let mut m = f32::NEG_INFINITY;
                for a in 0..p[0] {
                    for b in 0..p[1] {
                        let row = ((bx + a) * n[1] + (by + b)) * n[2] + bz;
                        for c in 0..p[2] {
                            let v = img[row + c];
                            if v > m {
                                m = v;
                            }
                        }
                    }
                }
                out[(x * odims[1] + y) * odims[2] + z] = m;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::{ChipTopology, TaskPool};

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    #[test]
    fn shape_divides() {
        let sh = max_pool_out_shape(Shape5::new(1, 2, 4, 6, 8), [2, 2, 2]);
        assert_eq!(sh, Shape5::new(1, 2, 2, 3, 4));
    }

    #[test]
    #[should_panic(expected = "divisible")]
    fn shape_rejects_indivisible() {
        max_pool_out_shape(Shape5::new(1, 1, 5, 4, 4), [2, 2, 2]);
    }

    #[test]
    fn pools_max_of_each_block() {
        let mut t = Tensor5::zeros(Shape5::new(1, 1, 2, 2, 2));
        for (i, v) in [1.0, 8.0, 3.0, 4.0, 5.0, 6.0, 7.0, 2.0].iter().enumerate() {
            t.data_mut()[i] = *v;
        }
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        let out = max_pool(&t, [2, 2, 2], &mut ctx);
        assert_eq!(out.shape(), Shape5::new(1, 1, 1, 1, 1));
        assert_eq!(out.data(), &[8.0]);
    }

    #[test]
    fn anisotropic_window() {
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        let t = Tensor5::random(Shape5::new(2, 2, 4, 2, 6), 7);
        let out = max_pool(&t, [2, 1, 3], &mut ctx);
        assert_eq!(out.shape(), Shape5::new(2, 2, 2, 2, 2));
        // Check one block by hand.
        let mut m = f32::NEG_INFINITY;
        for a in 0..2 {
            for c in 0..3 {
                m = m.max(t.at(1, 1, 2 + a, 1, 3 + c));
            }
        }
        assert_eq!(out.at(1, 1, 1, 1, 1), m);
    }

    #[test]
    fn vectorised_pool_matches_scalar_oracle() {
        crate::util::quick::check("pool_one == pool_one_scalar", |g| {
            let p = [g.usize(1, 3), g.usize(1, 3), g.usize(1, 3)];
            let odims = [g.usize(1, 4), g.usize(1, 4), g.usize(1, 4)];
            let off = [g.usize(0, 2), g.usize(0, 2), g.usize(0, 2)];
            let n = [
                off[0] + odims[0] * p[0] + g.usize(0, 2),
                off[1] + odims[1] * p[1] + g.usize(0, 2),
                off[2] + odims[2] * p[2] + g.usize(0, 2),
            ];
            let img = g.vec_f32(n[0] * n[1] * n[2]);
            let mut a = vec![0.0f32; odims[0] * odims[1] * odims[2]];
            let mut b = a.clone();
            pool_one(&img, n, p, off, odims, &mut a);
            pool_one_scalar(&img, n, p, off, odims, &mut b);
            crate::util::quick::assert_allclose(&a, &b, 0.0, 0.0, "pool parity");
        });
    }

    #[test]
    fn pooling_is_monotone_property() {
        let p = tpool();
        let mut ctx = ExecCtx::new(&p);
        crate::util::quick::check("maxpool ≥ any element", |g| {
            let n = [g.usize(1, 3) * 2, g.usize(1, 3) * 2, g.usize(1, 3) * 2];
            let t = Tensor5::random(Shape5::from_spatial(1, 1, n), g.case as u64);
            let out = max_pool(&t, [2, 2, 2], &mut ctx);
            // Every output must be ≥ all 8 inputs of its block and equal
            // to one of them.
            let osh = out.shape();
            for x in 0..osh.x {
                for y in 0..osh.y {
                    for z in 0..osh.z {
                        let o = out.at(0, 0, x, y, z);
                        let mut found = false;
                        for a in 0..2 {
                            for b in 0..2 {
                                for c in 0..2 {
                                    let v = t.at(0, 0, 2 * x + a, 2 * y + b, 2 * z + c);
                                    assert!(o >= v);
                                    found |= o == v;
                                }
                            }
                        }
                        assert!(found);
                    }
                }
            }
        });
    }
}
