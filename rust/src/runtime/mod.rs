//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts and execute
//! them from the Rust hot path.
//!
//! `make artifacts` (build-time Python) lowers the Layer-2 graphs to
//! HLO **text** (`artifacts/*.hlo.txt`) plus a line-oriented manifest;
//! this module compiles them on the PJRT CPU client and executes them
//! with tensors produced by the coordinator. Python never runs at
//! request time. On this testbed the PJRT executables stand in for the
//! GPU device's compiled kernels (see `crate::device`).

//! The PJRT client itself needs the offline `xla` crate, which is not
//! present on every testbed: it is gated behind the `pjrt` cargo
//! feature. Without it, [`Runtime::open`] returns an error and every
//! caller (CLI `info`, quickstart, integration tests) degrades
//! gracefully; the [`Manifest`] parser is always available.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

#[cfg(feature = "pjrt")]
use crate::tensor::Shape5;
use crate::tensor::Tensor5;

/// One artifact: name, file, argument and output shapes.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Artifact name (layer id).
    pub name: String,
    /// File name inside the artifact directory.
    pub file: String,
    /// Argument shapes, in call order.
    pub arg_shapes: Vec<Vec<usize>>,
    /// Output shape.
    pub output_shape: Vec<usize>,
}

/// Parsed `manifest.txt`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// All artifacts, in manifest order.
    pub entries: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Parse the line format emitted by `python/compile/aot.py`.
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut entries: Vec<ArtifactSpec> = Vec::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            match toks[0] {
                "artifact" => {
                    if toks.len() != 3 {
                        bail!("manifest line {}: artifact NAME FILE", ln + 1);
                    }
                    entries.push(ArtifactSpec {
                        name: toks[1].into(),
                        file: toks[2].into(),
                        arg_shapes: Vec::new(),
                        output_shape: Vec::new(),
                    });
                }
                "arg" | "out" => {
                    let cur = entries
                        .last_mut()
                        .ok_or_else(|| anyhow!("manifest line {}: shape before artifact", ln + 1))?;
                    let dims: Vec<usize> = toks[1..]
                        .iter()
                        .map(|t| t.parse())
                        .collect::<std::result::Result<_, _>>()
                        .with_context(|| format!("manifest line {}", ln + 1))?;
                    if toks[0] == "arg" {
                        cur.arg_shapes.push(dims);
                    } else {
                        cur.output_shape = dims;
                    }
                }
                other => bail!("manifest line {}: unknown directive {other}", ln + 1),
            }
        }
        Ok(Manifest { entries })
    }

    /// Load `manifest.txt` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    /// Find an artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.entries.iter().find(|e| e.name == name)
    }
}

/// PJRT runtime: lazily compiles artifacts on first use and caches the
/// loaded executables.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    dir: PathBuf,
    /// Parsed artifact manifest.
    pub manifest: Manifest,
    client: xla::PjRtClient,
    loaded: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open the artifact directory (default `artifacts/`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime { dir, manifest, client, loaded: Mutex::new(HashMap::new()) })
    }

    /// Platform string of the PJRT client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn ensure_loaded(&self, name: &str) -> Result<()> {
        let mut loaded = crate::util::sync::recover_lock(&self.loaded);
        if loaded.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling '{name}': {e:?}"))?;
        loaded.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with flat f32 argument buffers (shapes per
    /// the manifest). Returns the flat output buffer.
    pub fn execute(&self, name: &str, args: &[&[f32]]) -> Result<Vec<f32>> {
        self.ensure_loaded(name)?;
        let spec = self.manifest.get(name).unwrap().clone();
        if args.len() != spec.arg_shapes.len() {
            bail!(
                "artifact '{name}' expects {} args, got {}",
                spec.arg_shapes.len(),
                args.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, (buf, shape)) in args.iter().zip(&spec.arg_shapes).enumerate() {
            let want: usize = shape.iter().product();
            if buf.len() != want {
                bail!("artifact '{name}' arg {i}: {} elems, want {want} ({shape:?})", buf.len());
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape arg {i}: {e:?}"))?;
            literals.push(lit);
        }
        let loaded = crate::util::sync::recover_lock(&self.loaded);
        let exe = loaded.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing '{name}': {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // Lowered with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Execute an artifact whose first arg is a 5D tensor and whose
    /// output is 5D, with weight buffers appended.
    pub fn execute_tensor(
        &self,
        name: &str,
        input: &Tensor5,
        weight_bufs: &[&[f32]],
    ) -> Result<Tensor5> {
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let mut args: Vec<&[f32]> = vec![input.data()];
        args.extend_from_slice(weight_bufs);
        let flat = self.execute(name, &args)?;
        if spec.output_shape.len() != 5 {
            bail!("artifact '{name}' output is not 5D");
        }
        let sh = Shape5::new(
            spec.output_shape[0],
            spec.output_shape[1],
            spec.output_shape[2],
            spec.output_shape[3],
            spec.output_shape[4],
        );
        Ok(Tensor5::from_vec(sh, flat))
    }
}

/// Stub runtime when built without the `pjrt` feature: `open` always
/// fails with a descriptive error, so callers fall back to the CPU
/// primitives (every call site already handles the error path).
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    /// Parsed artifact manifest.
    pub manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    const UNAVAILABLE: &'static str =
        "PJRT runtime unavailable: znni was built without the `pjrt` cargo feature \
         (requires the offline `xla` crate)";

    /// Always fails: the PJRT client is not compiled in.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let _ = dir;
        bail!("{}", Self::UNAVAILABLE)
    }

    /// Platform string of the PJRT client.
    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    /// Execute an artifact with flat f32 argument buffers.
    pub fn execute(&self, _name: &str, _args: &[&[f32]]) -> Result<Vec<f32>> {
        bail!("{}", Self::UNAVAILABLE)
    }

    /// Execute an artifact on a 5D tensor plus weight buffers.
    pub fn execute_tensor(
        &self,
        _name: &str,
        _input: &Tensor5,
        _weight_bufs: &[&[f32]],
    ) -> Result<Tensor5> {
        bail!("{}", Self::UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_line_format() {
        let text = "artifact foo foo.hlo.txt\narg 1 1 4 4 4\narg 2 1 3 3 3\nout 1 2 2 2 2\n";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.get("foo").unwrap();
        assert_eq!(e.arg_shapes.len(), 2);
        assert_eq!(e.output_shape, vec![1, 2, 2, 2, 2]);
        assert!(m.get("bar").is_none());
    }

    #[test]
    fn manifest_rejects_bad_lines() {
        assert!(Manifest::parse("arg 1 2 3\n").is_err());
        assert!(Manifest::parse("frob x y\n").is_err());
        assert!(Manifest::parse("artifact a\n").is_err());
    }

    // Execution against real artifacts lives in
    // rust/tests/integration_runtime.rs (requires `make artifacts`).
}
