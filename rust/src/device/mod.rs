//! Device models: the host CPU and the **simulated GPU**.
//!
//! This testbed has no CUDA device, so the GPU of the paper (a 12 GB
//! Titan X) is modelled as a *constraint + cost structure* — which is
//! exactly the role it plays in the paper's arguments:
//!
//! * a hard on-board RAM budget (the reason GPU-only loses to CPU-only
//!   for large kernels, §VI.B);
//! * a host↔device transfer cost per byte (the reason GPU + host RAM
//!   layers are pipelined per sub-layer, §VII.A, and MPF layers moved to
//!   the CPU, §VII.B);
//! * a relative speed factor applied to *modelled* compute time, used
//!   by the optimizer's cost model when ranking GPU primitives against
//!   CPU ones (calibratable; default from `ZNNI_GPU_SPEEDUP`).
//!
//! GPU-placed primitives execute on the host cores through the same
//! code paths (or through the PJRT runtime for AOT-compiled layers);
//! the device ledger enforces the memory budget the real card would.

use crate::tensor::Shape5;

/// Kind of execution resource.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceKind {
    /// Host cores.
    Cpu,
    /// (Simulated) accelerator.
    Gpu,
}

/// A device with a memory budget and a transfer cost model.
#[derive(Clone, Debug)]
pub struct Device {
    /// Kind of execution resource.
    pub kind: DeviceKind,
    /// Display name.
    pub name: String,
    /// RAM available to primitives on this device.
    pub ram_bytes: u64,
    /// Host↔device bandwidth (bytes/s). Zero ⇒ no transfer cost (host).
    pub transfer_bytes_per_sec: f64,
    /// Modelled speed multiplier relative to host compute for the same
    /// primitive (>1 ⇒ device is faster). Only used in *cost models*;
    /// measured wall-clock numbers are always reported as measured.
    pub speed_factor: f64,
}

impl Device {
    /// The host machine: all visible RAM (or `ZNNI_HOST_RAM` bytes).
    pub fn host() -> Device {
        let ram = std::env::var("ZNNI_HOST_RAM")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(detect_host_ram);
        Device {
            kind: DeviceKind::Cpu,
            name: "host-cpu".into(),
            ram_bytes: ram,
            transfer_bytes_per_sec: 0.0,
            speed_factor: 1.0,
        }
    }

    /// Host device with an explicit RAM budget (Fig 7 sweeps this).
    pub fn host_with_ram(ram_bytes: u64) -> Device {
        Device { ram_bytes, ..Device::host() }
    }

    /// The simulated Titan X: 12 GB on-board, ~8 GB/s effective PCIe
    /// bandwidth, speed factor from `ZNNI_GPU_SPEEDUP` (default 1.0 —
    /// honest wall-clock on this testbed).
    pub fn titan_x() -> Device {
        let speed = std::env::var("ZNNI_GPU_SPEEDUP")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1.0);
        Device {
            kind: DeviceKind::Gpu,
            name: "sim-titan-x".into(),
            ram_bytes: 12 << 30,
            transfer_bytes_per_sec: 8e9,
            speed_factor: speed,
        }
    }

    /// Simulated GPU with an explicit RAM budget.
    pub fn gpu_with_ram(ram_bytes: u64) -> Device {
        Device { ram_bytes, ..Device::titan_x() }
    }

    /// Does a primitive needing `bytes` fit on this device?
    pub fn fits(&self, bytes: u64) -> bool {
        bytes <= self.ram_bytes
    }

    /// Modelled seconds to move `bytes` between host and this device
    /// (0 for the host itself).
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        if self.transfer_bytes_per_sec <= 0.0 {
            0.0
        } else {
            bytes as f64 / self.transfer_bytes_per_sec
        }
    }

    /// Modelled seconds to upload a tensor of this shape.
    pub fn upload_secs(&self, shape: Shape5) -> f64 {
        self.transfer_secs(shape.bytes_f32())
    }
}

/// Read total system RAM from /proc/meminfo (fallback 16 GiB).
pub fn detect_host_ram() -> u64 {
    if let Ok(s) = std::fs::read_to_string("/proc/meminfo") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("MemTotal:") {
                let kb = rest.trim().split_whitespace().next().and_then(|v| v.parse::<u64>().ok());
                if let Some(kb) = kb {
                    return kb * 1024;
                }
            }
        }
    }
    16 << 30
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn titan_x_budget() {
        let g = Device::titan_x();
        assert_eq!(g.ram_bytes, 12 << 30);
        assert!(g.fits(1 << 30));
        assert!(!g.fits(13 << 30));
    }

    #[test]
    fn transfer_model() {
        let g = Device::titan_x();
        let t = g.transfer_secs(8_000_000_000);
        assert!((t - 1.0).abs() < 1e-9);
        let h = Device::host();
        assert_eq!(h.transfer_secs(1 << 30), 0.0);
    }

    #[test]
    fn host_ram_detected() {
        assert!(detect_host_ram() > 1 << 28, "host has at least 256 MiB");
    }

    #[test]
    fn explicit_budgets() {
        assert_eq!(Device::host_with_ram(1024).ram_bytes, 1024);
        assert_eq!(Device::gpu_with_ram(2048).ram_bytes, 2048);
        assert_eq!(Device::gpu_with_ram(2048).kind, DeviceKind::Gpu);
    }
}
