//! Sliding-window inference: fragment recombination and patch-based
//! whole-volume execution (§II, §VI.A).
//!
//! An MPF network's output is `α` fragments per input; recombination
//! interleaves them at the total pooling stride to reconstruct the
//! dense sliding-window output. For volumes too large for one patch,
//! the volume is divided into overlapping input patches (overlap-save:
//! overlap = FoV − 1) whose recombined outputs tile the dense output
//! exactly.

use anyhow::{bail, Result};

use crate::exec::ExecCtx;
use crate::net::{LayerSpec, NetSpec, PoolingMode};
use crate::tensor::{Shape5, Tensor5, Vec3};

/// Fragment geometry of an all-MPF network: per-fragment offsets (in
/// output-batch order) and the total stride.
#[derive(Clone, Debug)]
pub struct FragmentMap {
    /// Per-fragment output offsets, in output-batch order.
    pub offsets: Vec<Vec3>,
    /// Total fragment stride (product of the MPF windows).
    pub stride: Vec3,
}

/// Compute the fragment offsets produced by the net's MPF layers, in
/// the batch order the layers emit them (earlier layers are more
/// significant). Requires every pooling layer to be MPF.
pub fn fragment_map(net: &NetSpec, modes: &[PoolingMode]) -> Result<FragmentMap> {
    let mut offsets: Vec<Vec3> = vec![[0, 0, 0]];
    let mut stride: Vec3 = [1, 1, 1];
    let mut pool_i = 0;
    for l in &net.layers {
        if let LayerSpec::Pool { p } = l {
            if modes[pool_i] != PoolingMode::Mpf {
                bail!("fragment recombination requires all pooling layers to be MPF");
            }
            pool_i += 1;
            let mut next = Vec::with_capacity(offsets.len() * p[0] * p[1] * p[2]);
            for base in &offsets {
                for frag in crate::pool::mpf_fragment_order(*p) {
                    next.push([
                        base[0] + stride[0] * frag[0],
                        base[1] + stride[1] * frag[1],
                        base[2] + stride[2] * frag[2],
                    ]);
                }
            }
            offsets = next;
            for d in 0..3 {
                stride[d] *= p[d];
            }
        }
    }
    Ok(FragmentMap { offsets, stride })
}

/// Recombine an MPF net output (`α·S` fragments) into the dense
/// sliding-window output: for each original input `s`, fragment values
/// land at `offset + stride · t`. Output spatial extent is
/// `stride · fragment_extent` per dimension (= n − FoV + 1).
///
/// Each fragment z-row is contiguous in the fragment; at z-stride 1 it
/// is also contiguous in the dense output, so whole rows move as one
/// `copy_from_slice` (a vectorised memcpy). At larger strides the row
/// base is still computed once and the scatter walks a precomputed
/// stride — the old voxel-by-voxel `out.set(..)` recomputed the full
/// 5-D index per element. The dense tensor comes from the context's
/// arena.
pub fn recombine(
    output: &Tensor5,
    s_orig: usize,
    map: &FragmentMap,
    ctx: &mut ExecCtx<'_>,
) -> Tensor5 {
    let osh = output.shape();
    let alpha = map.offsets.len();
    assert_eq!(osh.s, s_orig * alpha, "batch {} != {}·{}", osh.s, s_orig, alpha);
    let dense = Shape5 {
        s: s_orig,
        f: osh.f,
        x: osh.x * map.stride[0],
        y: osh.y * map.stride[1],
        z: osh.z * map.stride[2],
    };
    let mut out = ctx.tensor5(dense);
    if osh.image_len() == 0 {
        return out;
    }
    let [sx, sy, sz] = map.stride;
    let (dy, dz) = (dense.y, dense.z);
    for s in 0..s_orig {
        for (fi, off) in map.offsets.iter().enumerate() {
            for f in 0..osh.f {
                let frag = output.image(s * alpha + fi, f);
                let oimg = out.image_mut(s, f);
                for x in 0..osh.x {
                    let ox = off[0] + sx * x;
                    for y in 0..osh.y {
                        let oy = off[1] + sy * y;
                        let frow = &frag[(x * osh.y + y) * osh.z..(x * osh.y + y) * osh.z + osh.z];
                        let obase = (ox * dy + oy) * dz + off[2];
                        if sz == 1 {
                            oimg[obase..obase + osh.z].copy_from_slice(frow);
                        } else {
                            let orow = &mut oimg[obase..obase + (osh.z - 1) * sz + 1];
                            for (zi, &v) in frow.iter().enumerate() {
                                orow[zi * sz] = v;
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Shape of the dense sliding-window output for a whole-volume request:
/// one value per valid FoV placement, `f_out` images, batch 1. Shared by
/// the coordinator (output allocation), the serving frontend (admission
/// sizing) and the Table II request model so they can never disagree.
pub fn dense_output_shape(vshape: Shape5, fov: Vec3, f_out: usize) -> Shape5 {
    Shape5::from_spatial(
        vshape.s,
        f_out,
        [vshape.x - fov[0] + 1, vshape.y - fov[1] + 1, vshape.z - fov[2] + 1],
    )
}

/// Dense sliding-window reference: run the net (max-pool modes, batch 1)
/// independently on every FoV-sized window. O(positions × net) — only
/// for validating recombination on tiny problems. The runner owns its
/// execution context (capture an `&mut ExecCtx` in the closure).
pub fn dense_reference(
    net: &NetSpec,
    runner: &mut dyn FnMut(Tensor5) -> Tensor5,
    volume: &Tensor5,
) -> Tensor5 {
    let vsh = volume.shape();
    assert_eq!(vsh.s, 1);
    let fov = net.field_of_view();
    let on = [vsh.x - fov[0] + 1, vsh.y - fov[1] + 1, vsh.z - fov[2] + 1];
    let f_out = net.f_out();
    let mut out = Tensor5::zeros(Shape5::from_spatial(1, f_out, on));
    for ux in 0..on[0] {
        for uy in 0..on[1] {
            for uz in 0..on[2] {
                let mut win = Tensor5::zeros(Shape5::from_spatial(1, vsh.f, fov));
                for f in 0..vsh.f {
                    for x in 0..fov[0] {
                        for y in 0..fov[1] {
                            for z in 0..fov[2] {
                                win.set(0, f, x, y, z, volume.at(0, f, ux + x, uy + y, uz + z));
                            }
                        }
                    }
                }
                let r = runner(win);
                let rsh = r.shape();
                assert_eq!((rsh.x, rsh.y, rsh.z), (1, 1, 1), "window must give one voxel");
                for f in 0..f_out {
                    out.set(0, f, ux, uy, uz, r.at(0, f, 0, 0, 0));
                }
            }
        }
    }
    out
}

/// Patch-based whole-volume inference. `runner` maps one input patch
/// (shape `1 × f × patch³`) to its recombined dense output patch
/// (`1 × f' × (patch − fov + 1)³`) and owns its execution context.
/// Patches overlap by `fov − 1` (overlap-save), the final patch is
/// shifted inward so the output tiles exactly.
pub fn infer_volume(
    volume: &Tensor5,
    fov: Vec3,
    patch: Vec3,
    f_out: usize,
    runner: &mut dyn FnMut(Tensor5) -> Tensor5,
) -> Result<Tensor5> {
    let vsh = volume.shape();
    if vsh.s != 1 {
        bail!("volume batch must be 1");
    }
    for d in 0..3 {
        if patch[d] > [vsh.x, vsh.y, vsh.z][d] {
            bail!("patch {patch:?} larger than volume");
        }
        if patch[d] < fov[d] {
            bail!("patch {patch:?} smaller than FoV {fov:?}");
        }
    }
    let vdims = [vsh.x, vsh.y, vsh.z];
    let cover = [patch[0] - fov[0] + 1, patch[1] - fov[1] + 1, patch[2] - fov[2] + 1];
    let odims = [vdims[0] - fov[0] + 1, vdims[1] - fov[1] + 1, vdims[2] - fov[2] + 1];
    let mut out = Tensor5::zeros(Shape5::from_spatial(1, f_out, odims));

    // Patch start positions per dim: multiples of `cover`, with the
    // final start clamped so the patch stays in bounds.
    let starts = |d: usize| -> Vec<usize> {
        let mut v = Vec::new();
        let mut s = 0;
        loop {
            if s + patch[d] >= vdims[d] {
                v.push(vdims[d] - patch[d]);
                break;
            }
            v.push(s);
            s += cover[d];
        }
        v
    };
    for &sx in &starts(0) {
        for &sy in &starts(1) {
            for &sz in &starts(2) {
                // Crop the input patch.
                let mut pin = Tensor5::zeros(Shape5::from_spatial(1, vsh.f, patch));
                for f in 0..vsh.f {
                    for x in 0..patch[0] {
                        for y in 0..patch[1] {
                            let src_base = (f * vsh.x + sx + x) * vsh.y * vsh.z
                                + (sy + y) * vsh.z
                                + sz;
                            let dst_base =
                                (f * patch[0] + x) * patch[1] * patch[2] + y * patch[2];
                            pin.data_mut()[dst_base..dst_base + patch[2]]
                                .copy_from_slice(&volume.data()[src_base..src_base + patch[2]]);
                        }
                    }
                }
                let pout = runner(pin);
                let psh = pout.shape();
                assert_eq!((psh.x, psh.y, psh.z), (cover[0], cover[1], cover[2]));
                assert_eq!(psh.f, f_out);
                for f in 0..f_out {
                    for x in 0..cover[0] {
                        for y in 0..cover[1] {
                            for z in 0..cover[2] {
                                out.set(0, f, sx + x, sy + y, sz + z, pout.at(0, f, x, y, z));
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::zoo::tiny_net;
    use crate::optimizer::{compile, make_weights, Plan, PlanLayer};
    use crate::memory::model::ConvAlgo;
    use crate::util::pool::{ChipTopology, TaskPool};
    use crate::util::quick::assert_allclose;

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    /// Manual plan: direct conv everywhere with the given pool modes.
    fn manual_plan(net: &NetSpec, input: Shape5, modes: &[PoolingMode]) -> Plan {
        let shapes = net.shapes(input, modes).unwrap();
        let mut mi = 0;
        let layers = net
            .layers
            .iter()
            .map(|l| match l {
                LayerSpec::Conv { .. } => PlanLayer::Conv {
                    algo: ConvAlgo::DirectMkl,
                    cache_kernels: false,
                    precision: crate::precision::Precision::F32,
                },
                LayerSpec::Pool { .. } => {
                    let m = modes[mi];
                    mi += 1;
                    PlanLayer::Pool { mode: m }
                }
            })
            .collect();
        let out = *shapes.last().unwrap();
        Plan {
            net_name: net.name.clone(),
            input,
            layers,
            shapes,
            est_secs: 1.0,
            est_memory: 0,
            kernel_cache_bytes: 0,
            out_voxels: (out.s * out.x * out.y * out.z) as u64,
        }
    }

    #[test]
    fn fragment_map_single_layer() {
        let net = tiny_net(2);
        let m = fragment_map(&net, &[PoolingMode::Mpf]).unwrap();
        assert_eq!(m.stride, [2, 2, 2]);
        assert_eq!(m.offsets.len(), 8);
        assert_eq!(m.offsets[0], [0, 0, 0]);
        assert_eq!(m.offsets[7], [1, 1, 1]);
    }

    #[test]
    fn fragment_map_rejects_maxpool() {
        let net = tiny_net(2);
        assert!(fragment_map(&net, &[PoolingMode::MaxPool]).is_err());
    }

    /// THE golden test: MPF + recombination must equal the dense
    /// sliding-window output computed window by window.
    #[test]
    fn mpf_recombination_equals_dense_sliding_window() {
        let pool = tpool();
        let mut ctx = ExecCtx::new(&pool);
        let net = tiny_net(2);
        let weights = make_weights(&net, 77);
        let fov = net.field_of_view(); // 10³ for tiny CPCC

        // MPF path on a 13³ volume (valid: 13-2=11, (11+1)%2=0 ✓).
        let n = 13;
        let volume = Tensor5::random(Shape5::new(1, 1, n, n, n), 99);
        let mpf_modes = vec![PoolingMode::Mpf];
        let plan = manual_plan(&net, volume.shape(), &mpf_modes);
        let cp = compile(&net, &plan, &weights).unwrap();
        let raw = cp.run(volume.clone_tensor(), &mut ctx);
        let map = fragment_map(&net, &mpf_modes).unwrap();
        let dense = recombine(&raw, 1, &map, &mut ctx);
        assert_eq!(
            dense.shape(),
            Shape5::new(1, 2, n - fov[0] + 1, n - fov[1] + 1, n - fov[2] + 1)
        );

        // Dense reference: run every FoV window through the max-pool net.
        let mp_modes = vec![PoolingMode::MaxPool];
        let wplan = manual_plan(&net, Shape5::from_spatial(1, 1, fov), &mp_modes);
        let wcp = compile(&net, &wplan, &weights).unwrap();
        let mut wctx = ExecCtx::new(&pool);
        let mut runner = |t: Tensor5| wcp.run(t, &mut wctx);
        let expect = dense_reference(&net, &mut runner, &volume);

        assert_allclose(dense.data(), expect.data(), 1e-4, 1e-3, "MPF == dense");
    }

    #[test]
    fn infer_volume_tiles_patches_seamlessly() {
        let pool = tpool();
        let net = tiny_net(2);
        let weights = make_weights(&net, 31);
        let fov = net.field_of_view();
        let mpf_modes = vec![PoolingMode::Mpf];
        let map = fragment_map(&net, &mpf_modes).unwrap();

        // Whole volume in one patch vs split into smaller patches.
        let volume = Tensor5::random(Shape5::new(1, 1, 17, 17, 17), 5);
        let mut rctx = ExecCtx::new(&pool);
        let mut run_patch = |patch: Tensor5| {
            let plan = manual_plan(&net, patch.shape(), &mpf_modes);
            let cp = compile(&net, &plan, &weights).unwrap();
            let raw = cp.run(patch, &mut rctx);
            let dense = recombine(&raw, 1, &map, &mut rctx);
            rctx.retire(raw);
            dense
        };
        let whole = infer_volume(&volume, fov, [17, 17, 17], 2, &mut run_patch).unwrap();
        let tiled = infer_volume(&volume, fov, [13, 13, 13], 2, &mut run_patch).unwrap();
        assert_eq!(whole.shape(), tiled.shape());
        assert_allclose(tiled.data(), whole.data(), 1e-5, 1e-5, "patch tiling");
    }

    #[test]
    fn infer_volume_rejects_bad_patch() {
        let net = tiny_net(2);
        let fov = net.field_of_view();
        let volume = Tensor5::random(Shape5::new(1, 1, 12, 12, 12), 1);
        let mut nop = |t: Tensor5| t;
        assert!(infer_volume(&volume, fov, [20, 20, 20], 2, &mut nop).is_err());
        assert!(infer_volume(&volume, fov, [4, 4, 4], 2, &mut nop).is_err());
    }

    #[test]
    fn recombine_strided_and_contiguous_rows_agree_with_setwise() {
        // The z-row fast path must reproduce the voxel-by-voxel law:
        // out[s, f, off + stride·t] = frag[t].
        let pool = tpool();
        let mut ctx = ExecCtx::new(&pool);
        for stride in [[2usize, 2, 2], [2, 1, 1], [1, 1, 1], [1, 2, 3]] {
            let (fx, fy, fz) = (2usize, 3usize, 2usize);
            let alpha = stride[0] * stride[1] * stride[2];
            let mut offsets = Vec::new();
            for a in 0..stride[0] {
                for b in 0..stride[1] {
                    for c in 0..stride[2] {
                        offsets.push([a, b, c]);
                    }
                }
            }
            let map = FragmentMap { offsets: offsets.clone(), stride };
            let raw = Tensor5::random(Shape5::new(2 * alpha, 2, fx, fy, fz), 7);
            let dense = recombine(&raw, 2, &map, &mut ctx);
            for s in 0..2 {
                for (fi, off) in offsets.iter().enumerate() {
                    for f in 0..2 {
                        for x in 0..fx {
                            for y in 0..fy {
                                for z in 0..fz {
                                    assert_eq!(
                                        dense.at(
                                            s,
                                            f,
                                            off[0] + stride[0] * x,
                                            off[1] + stride[1] * y,
                                            off[2] + stride[2] * z,
                                        ),
                                        raw.at(s * alpha + fi, f, x, y, z),
                                        "stride {stride:?}"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}
