//! Poison-proof locking helpers.
//!
//! A panic while a `std::sync::Mutex` is held poisons it, and every
//! later `lock().unwrap()` then panics too — one fault cascades through
//! the whole process. All the state this crate guards with mutexes
//! (arena free lists, shard queues, stats counters, FFT plan caches) is
//! either value-consistent at every await point or rebuilt by the shard
//! supervisor after a panic, so the right response to poisoning is to
//! take the data and keep serving, not to amplify the failure.
//!
//! These helpers are the crate-wide replacement for `lock().unwrap()`
//! (see `docs/ARCHITECTURE.md`, "Fault tolerance & degradation").

use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// Lock a mutex, recovering the guard from a poisoned lock instead of
/// panicking. Use wherever the guarded state stays consistent across
/// panics (or is reset by a supervisor afterwards).
#[inline]
pub fn recover_lock<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// [`Condvar::wait`] that recovers from a poisoned mutex instead of
/// panicking — the condvar analogue of [`recover_lock`].
#[inline]
pub fn recover_wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

/// [`Condvar::wait_timeout`] that recovers from a poisoned mutex
/// instead of panicking.
#[inline]
pub fn recover_wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, timeout).unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn recover_lock_survives_poison() {
        let m = Mutex::new(41);
        let r = catch_unwind(AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison it");
        }));
        assert!(r.is_err());
        assert!(m.lock().is_err(), "mutex must be poisoned");
        let mut g = recover_lock(&m);
        *g += 1;
        assert_eq!(*g, 42);
    }

    #[test]
    fn recover_wait_timeout_returns_guard() {
        let m = Mutex::new(0u32);
        let cv = Condvar::new();
        let g = recover_lock(&m);
        let (g, res) = recover_wait_timeout(&cv, g, Duration::from_millis(1));
        assert!(res.timed_out());
        assert_eq!(*g, 0);
    }
}
