//! quickcheck-lite: property-based testing without external crates.
//!
//! A property is a closure over a [`Gen`]; the harness runs it for a
//! configurable number of random cases with deterministic seeds and, on
//! failure, reports the seed + case index so the exact case can be
//! replayed (`ZNNI_QC_SEED`, `ZNNI_QC_CASES` override).

use crate::util::prng::Rng;

/// Per-case generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Index of the current case (0-based).
    pub case: usize,
}

impl Gen {
    /// usize in [lo, hi] inclusive.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    /// f32 in [lo, hi).
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.f32_range(lo, hi)
    }

    /// Random vec of f32 in [-1, 1).
    pub fn vec_f32(&mut self, len: usize) -> Vec<f32> {
        let mut v = vec![0.0; len];
        self.rng.fill_uniform(&mut v);
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f32) -> bool {
        self.rng.f32() < p
    }

    /// Access the raw RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Configuration for a property run.
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case i derives its own from it.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let cases = std::env::var("ZNNI_QC_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(32);
        let seed = std::env::var("ZNNI_QC_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0x5EED_CAFE);
        Config { cases, seed }
    }
}

/// Run `prop` for `cfg.cases` random cases; panics with the seed/case on
/// the first failure (the property itself panics/asserts on violation).
pub fn check_with(cfg: Config, name: &str, mut prop: impl FnMut(&mut Gen)) {
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen { rng: Rng::new(seed), case };
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = r {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (replay: ZNNI_QC_SEED={} ZNNI_QC_CASES={}): {msg}",
                cfg.seed,
                case + 1
            );
        }
    }
}

/// Run with default config.
pub fn check(name: &str, prop: impl FnMut(&mut Gen)) {
    check_with(Config::default(), name, prop);
}

/// Assert two f32 slices are element-wise close.
#[track_caller]
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch {} vs {}", a.len(), b.len());
    let mut worst = 0.0f32;
    let mut worst_i = 0usize;
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let err = (x - y).abs();
        let bound = atol + rtol * y.abs().max(x.abs());
        let rel = if bound > 0.0 { err / bound } else { err };
        if rel > worst {
            worst = rel;
            worst_i = i;
        }
    }
    assert!(
        worst <= 1.0,
        "{what}: mismatch at index {worst_i}: {} vs {} (|d|={}, allowed atol={atol} rtol={rtol})",
        a[worst_i],
        b[worst_i],
        (a[worst_i] - b[worst_i]).abs()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", |g| {
            let a = g.f32(-10.0, 10.0);
            let b = g.f32(-10.0, 10.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check_with(Config { cases: 3, seed: 1 }, "always fails", |_| {
            panic!("nope");
        });
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6, "eq");
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn allclose_rejects_far() {
        assert_allclose(&[1.0], &[2.0], 1e-6, 1e-6, "far");
    }

    #[test]
    fn gen_ranges() {
        check("gen ranges", |g| {
            let v = g.usize(3, 9);
            assert!((3..=9).contains(&v));
            let f = g.f32(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let xs = g.vec_f32(10);
            assert_eq!(xs.len(), 10);
        });
    }
}
