//! Deterministic xorshift64* PRNG.
//!
//! Used for synthetic workloads, weight initialisation and the
//! property-testing harness. Deterministic seeding keeps every test and
//! benchmark reproducible without an external `rand` dependency.

/// xorshift64* generator (Vigna 2016). Passes BigCrush for our purposes
/// (non-cryptographic test-data generation).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. A zero seed is remapped — the
    /// xorshift state must never be zero.
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // Use the top 24 bits for a uniform mantissa.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform usize in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform usize in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Standard-normal-ish value via the sum of 4 uniforms (Irwin–Hall,
    /// close enough for synthetic image data).
    pub fn normalish(&mut self) -> f32 {
        let s = self.f32() + self.f32() + self.f32() + self.f32();
        (s - 2.0) * (3.0f32).sqrt()
    }

    /// Fill a slice with uniform values in [-1, 1).
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.f32_range(-1.0, 1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(2, 5);
            assert!((2..=5).contains(&v));
            seen_lo |= v == 2;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn roughly_uniform_mean() {
        let mut r = Rng::new(1234);
        let n = 100_000;
        let mean: f32 = (0..n).map(|_| r.f32()).sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = Rng::new(0);
        // Must not get stuck at zero.
        assert_ne!(r.next_u64(), 0);
        assert_ne!(r.next_u64(), r.next_u64());
    }
}
