//! Persistent worker-thread pool with chip-affinity scheduling.
//!
//! Reproduces the execution substrate of the paper's task-parallel
//! convolutional layer (§IV.A.3):
//!
//! * every worker is logically **pinned** to a `(chip, core)` slot — the
//!   paper pins via OS affinity on a 4-way Xeon. Pinning is expressed
//!   as strict queue affinity (a chip-affine task is only ever executed
//!   by that chip's workers), which reproduces the scheduling behaviour
//!   on any host; on genuinely multi-node machines
//!   ([`TaskPool::with_placement`], engaged by [`TaskPool::new`] under
//!   `ZNNI_NUMA=auto`) each chip's workers are *additionally* bound to
//!   a home NUMA node via [`crate::util::numa::pin_current_thread`], so
//!   queue affinity and OS affinity agree and first-touched pages land
//!   node-local;
//! * a subset of workers are **primary** threads (at most one per task
//!   that needs a private kernel-transform buffer), evenly distributed
//!   across chips;
//! * chip-affine tasks carry a **priority** (the paper uses distance to
//!   the sink of the task DAG) and are drained highest-priority-first;
//! * there is deliberately **no work stealing** between chips — the
//!   paper found affinity scheduling ~20% faster and more deterministic
//!   than TBB-style stealing on multi-chip machines.

use std::collections::{BinaryHeap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::util::sync::{recover_lock, recover_wait};

/// Logical machine topology: `chips` NUMA nodes × `cores_per_chip`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChipTopology {
    /// NUMA chips.
    pub chips: usize,
    /// Worker cores per chip.
    pub cores_per_chip: usize,
}

impl ChipTopology {
    /// Total worker count.
    pub fn cores(&self) -> usize {
        self.chips * self.cores_per_chip
    }

    /// Detect a topology for this machine. The paper's testbed is a
    /// 4-way (4-chip) Xeon; we model ≥16 cores as 4 chips, ≥8 as 2, else
    /// a single chip, overridable via `ZNNI_CHIPS` / `ZNNI_CORES`.
    pub fn detect() -> Self {
        let cores = std::env::var("ZNNI_CORES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            });
        let chips = std::env::var("ZNNI_CHIPS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(if cores >= 16 {
                4
            } else if cores >= 8 {
                2
            } else {
                1
            });
        let chips = chips.max(1).min(cores.max(1));
        ChipTopology { chips, cores_per_chip: (cores / chips).max(1) }
    }
}

type Job = Box<dyn FnOnce(&WorkerCtx) + Send + 'static>;

/// Identity handed to every job: which worker slot is running it.
#[derive(Clone, Copy, Debug)]
pub struct WorkerCtx {
    /// Pool-wide worker index.
    pub worker: usize,
    /// Chip this worker is pinned to.
    pub chip: usize,
    /// Whether this worker is a chip primary.
    pub primary: bool,
}

struct PrioJob {
    prio: i64,
    seq: u64,
    /// Recorded for debugging/assertions; routing happens at push time.
    #[allow(dead_code)]
    primary_only: bool,
    job: Job,
}

impl PartialEq for PrioJob {
    fn eq(&self, other: &Self) -> bool {
        self.prio == other.prio && self.seq == other.seq
    }
}
impl Eq for PrioJob {}
impl PartialOrd for PrioJob {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for PrioJob {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Max-heap on priority; FIFO (smaller seq first) among equals.
        self.prio.cmp(&other.prio).then(other.seq.cmp(&self.seq))
    }
}

#[derive(Default)]
struct ChipQueues {
    /// Tasks any worker on the chip may run.
    normal: BinaryHeap<PrioJob>,
    /// Tasks only a primary worker may run (kernel transforms).
    primary: BinaryHeap<PrioJob>,
}

struct State {
    global: VecDeque<Job>,
    chips: Vec<ChipQueues>,
    seq: u64,
    shutdown: bool,
}

struct PoolInner {
    state: Mutex<State>,
    cvar: Condvar,
    topo: ChipTopology,
}

/// The pool itself. One global instance serves the whole process (see
/// [`TaskPool::global`]); tests may construct private pools.
pub struct TaskPool {
    inner: Arc<PoolInner>,
    handles: Vec<JoinHandle<()>>,
}

impl TaskPool {
    /// Build a pool with an explicit topology. `primaries_per_chip`
    /// workers on each chip are marked primary (the paper picks
    /// M = max(N, f') primaries spread over chips; callers gate
    /// primary-only work via [`Scope::submit_chip_primary`]).
    pub fn with_topology(topo: ChipTopology) -> Self {
        Self::build(topo, None)
    }

    /// Build a pool whose chips are mapped onto the host's NUMA nodes:
    /// chip `c`'s workers bind themselves (OS affinity, first thing in
    /// their loop) to node `c % numa.node_count()`'s CPU set, so the
    /// pages they first-touch are node-local. Pinning only engages when
    /// [`crate::util::numa::placement_active`] holds — under
    /// `ZNNI_NUMA=off` or on a single-node machine this is exactly
    /// [`TaskPool::with_topology`]: zero affinity syscalls, identical
    /// scheduling.
    pub fn with_placement(topo: ChipTopology, numa: &crate::util::numa::NumaTopology) -> Self {
        if !crate::util::numa::placement_active(numa) {
            return Self::build(topo, None);
        }
        let sets: Vec<Arc<Vec<usize>>> =
            numa.nodes.iter().map(|n| Arc::new(n.cpus.clone())).collect();
        Self::build(topo, Some(sets))
    }

    fn build(topo: ChipTopology, pin_sets: Option<Vec<Arc<Vec<usize>>>>) -> Self {
        let inner = Arc::new(PoolInner {
            state: Mutex::new(State {
                global: VecDeque::new(),
                chips: (0..topo.chips).map(|_| ChipQueues::default()).collect(),
                seq: 0,
                shutdown: false,
            }),
            cvar: Condvar::new(),
            topo,
        });
        let mut handles = Vec::new();
        for w in 0..topo.cores() {
            let chip = w / topo.cores_per_chip;
            // First worker of each chip is primary; additional primaries
            // are the next workers round-robin — every worker knows its
            // rank within the chip, primariness is decided per-pop.
            let ctx = WorkerCtx { worker: w, chip, primary: w % topo.cores_per_chip == 0 };
            let pin = pin_sets.as_ref().map(|sets| sets[chip % sets.len()].clone());
            let inner = inner.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("znni-w{w}-c{chip}"))
                    .spawn(move || {
                        if let Some(cpus) = pin {
                            crate::util::numa::pin_current_thread(&cpus);
                        }
                        worker_loop(inner, ctx)
                    })
                    .expect("spawn worker"),
            );
        }
        TaskPool { inner, handles }
    }

    /// Pool sized to the detected machine topology, with workers pinned
    /// to home NUMA nodes when the host is multi-node and `ZNNI_NUMA`
    /// admits it (see [`TaskPool::with_placement`]).
    pub fn new() -> Self {
        Self::with_placement(ChipTopology::detect(), crate::util::numa::topology())
    }

    /// The process-wide pool (created on first use).
    pub fn global() -> &'static TaskPool {
        static POOL: OnceLock<TaskPool> = OnceLock::new();
        POOL.get_or_init(TaskPool::new)
    }

    /// Topology of this pool.
    pub fn topology(&self) -> ChipTopology {
        self.inner.topo
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.inner.topo.cores()
    }

    /// Run `body` with a [`Scope`] that may submit borrowed jobs; all
    /// jobs are completed before `scope` returns. Panics in jobs are
    /// re-raised here.
    pub fn scope<'env, R>(&self, body: impl FnOnce(&Scope<'env, '_>) -> R) -> R {
        let sync = Arc::new(ScopeSync::default());
        let scope = Scope { pool: self, sync: sync.clone(), _marker: std::marker::PhantomData };
        let r = body(&scope);
        sync.wait();
        if sync.panicked.load(Ordering::SeqCst) {
            // Re-raise with the first job's panic message preserved as
            // a suffix, so upstream isolation (the server's shard
            // supervisor) can still attribute the fault to its
            // failpoint site.
            let msg = recover_lock(&sync.panic_msg)
                .take()
                .unwrap_or_else(|| "unknown panic".to_string());
            panic!("a task submitted to the pool scope panicked: {msg}");
        }
        r
    }

    /// Parallel for over `0..n`: `f(i)` for every i, split into chunks.
    /// This is the `parallel for` of the paper's data-parallel
    /// primitives (Algorithm 1/2).
    pub fn parallel_for(&self, n: usize, f: impl Fn(usize) + Sync) {
        self.parallel_for_with_worker(n, |_w, i| f(i));
    }

    /// Like [`TaskPool::parallel_for`], but the body also receives the
    /// executing worker's pool-wide index (`0..workers()`). This lets
    /// callers maintain **per-worker** scratch buffers (e.g. the direct
    /// convolution's arena-backed temporary images) without
    /// thread-locals: a worker runs one job at a time, so two chunks
    /// never touch the same slot concurrently. The inline fast path
    /// (n == 1 or a single worker) reports worker 0.
    pub fn parallel_for_with_worker(&self, n: usize, f: impl Fn(usize, usize) + Sync) {
        if n == 0 {
            return;
        }
        let workers = self.workers();
        if n == 1 || workers <= 1 {
            for i in 0..n {
                f(0, i);
            }
            return;
        }
        let chunks = (workers * 4).min(n);
        let per = n / chunks;
        let extra = n % chunks;
        let f = &f;
        self.scope(|s| {
            let mut start = 0usize;
            for c in 0..chunks {
                let len = per + usize::from(c < extra);
                let range = start..start + len;
                start += len;
                s.submit(move |ctx| {
                    for i in range {
                        f(ctx.worker, i);
                    }
                });
            }
        });
    }

    /// Parallel for returning per-index outputs into a vec.
    pub fn parallel_map<T: Send + Default + Clone>(
        &self,
        n: usize,
        f: impl Fn(usize) -> T + Sync,
    ) -> Vec<T> {
        let mut out = vec![T::default(); n];
        {
            let cells: Vec<Mutex<&mut T>> = out.iter_mut().map(Mutex::new).collect();
            let cells = &cells;
            let f = &f;
            self.parallel_for(n, move |i| {
                **recover_lock(&cells[i]) = f(i);
            });
        }
        out
    }
}

impl Drop for TaskPool {
    fn drop(&mut self) {
        {
            let mut st = recover_lock(&self.inner.state);
            st.shutdown = true;
        }
        self.inner.cvar.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[derive(Default)]
struct ScopeSync {
    remaining: AtomicUsize,
    panicked: AtomicBool,
    /// First panicking job's message, for the scope's re-panic.
    panic_msg: Mutex<Option<String>>,
    mutex: Mutex<()>,
    cvar: Condvar,
}

impl ScopeSync {
    fn add(&self) {
        self.remaining.fetch_add(1, Ordering::SeqCst);
    }
    fn done(&self) {
        if self.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = recover_lock(&self.mutex);
            self.cvar.notify_all();
        }
    }
    fn wait(&self) {
        let mut g = recover_lock(&self.mutex);
        while self.remaining.load(Ordering::SeqCst) != 0 {
            g = recover_wait(&self.cvar, g);
        }
    }
}

/// Submission handle valid inside [`TaskPool::scope`]. Jobs may borrow
/// from the enclosing environment (`'env`); the scope guarantees all
/// jobs finish before those borrows expire.
pub struct Scope<'env, 'p> {
    pool: &'p TaskPool,
    sync: Arc<ScopeSync>,
    _marker: std::marker::PhantomData<&'env ()>,
}

impl<'env, 'p> Scope<'env, 'p> {
    fn wrap(&self, f: impl FnOnce(&WorkerCtx) + Send + 'env) -> Job {
        self.sync.add();
        let sync = self.sync.clone();
        let job: Box<dyn FnOnce(&WorkerCtx) + Send + 'env> = Box::new(move |ctx: &WorkerCtx| {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(ctx))) {
                sync.panicked.store(true, Ordering::SeqCst);
                let msg = crate::util::faults::panic_message(payload.as_ref())
                    .unwrap_or("non-string panic payload")
                    .to_string();
                let mut slot = recover_lock(&sync.panic_msg);
                if slot.is_none() {
                    *slot = Some(msg);
                }
            }
            sync.done();
        });
        // SAFETY: the scope waits for `remaining == 0` before returning,
        // so every borrow in `f` outlives the job's execution. This is
        // the standard scoped-pool lifetime erasure.
        unsafe { std::mem::transmute::<Box<dyn FnOnce(&WorkerCtx) + Send + 'env>, Job>(job) }
    }

    /// Submit to the global FIFO queue (any worker).
    pub fn submit(&self, f: impl FnOnce(&WorkerCtx) + Send + 'env) {
        let job = self.wrap(f);
        let mut st = recover_lock(&self.pool.inner.state);
        st.global.push_back(job);
        drop(st);
        self.pool.inner.cvar.notify_all();
    }

    /// Submit a chip-affine task with a scheduling priority (higher runs
    /// first; the task-parallel conv uses distance-to-sink).
    pub fn submit_chip(&self, chip: usize, prio: i64, f: impl FnOnce(&WorkerCtx) + Send + 'env) {
        self.submit_chip_inner(chip, prio, false, f);
    }

    /// Submit a chip-affine task that only the chip's *primary* worker
    /// may execute (kernel-transform tasks own a private buffer).
    pub fn submit_chip_primary(
        &self,
        chip: usize,
        prio: i64,
        f: impl FnOnce(&WorkerCtx) + Send + 'env,
    ) {
        self.submit_chip_inner(chip, prio, true, f);
    }

    fn submit_chip_inner(
        &self,
        chip: usize,
        prio: i64,
        primary_only: bool,
        f: impl FnOnce(&WorkerCtx) + Send + 'env,
    ) {
        let job = self.wrap(f);
        let mut st = recover_lock(&self.pool.inner.state);
        let chip = chip % st.chips.len();
        let seq = st.seq;
        st.seq += 1;
        let pj = PrioJob { prio, seq, primary_only, job };
        if primary_only {
            st.chips[chip].primary.push(pj);
        } else {
            st.chips[chip].normal.push(pj);
        }
        drop(st);
        self.pool.inner.cvar.notify_all();
    }
}

fn worker_loop(inner: Arc<PoolInner>, ctx: WorkerCtx) {
    loop {
        let job = {
            let mut st = recover_lock(&inner.state);
            loop {
                if st.shutdown {
                    return;
                }
                // Primary workers drain their chip's primary queue first
                // (kernel transforms gate their multiply-add dependents).
                if ctx.primary {
                    if let Some(pj) = st.chips[ctx.chip].primary.pop() {
                        break pj.job;
                    }
                }
                if let Some(pj) = st.chips[ctx.chip].normal.pop() {
                    break pj.job;
                }
                if let Some(j) = st.global.pop_front() {
                    break j;
                }
                st = recover_wait(&inner.cvar, st);
            }
        };
        // Defense in depth: Scope::wrap already isolates job panics,
        // but one slipping through the boxed-job glue must not silently
        // kill this worker for the life of the pool.
        let _ = catch_unwind(AssertUnwindSafe(|| job(&ctx)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn small_pool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 })
    }

    #[test]
    fn parallel_for_covers_all_indices() {
        let pool = small_pool();
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_empty_and_single() {
        let pool = small_pool();
        pool.parallel_for(0, |_| panic!("must not run"));
        let c = AtomicUsize::new(0);
        pool.parallel_for(1, |_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(c.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scope_waits_for_submitted_jobs() {
        let pool = small_pool();
        let sum = AtomicU64::new(0);
        pool.scope(|s| {
            for i in 0..100u64 {
                let sum = &sum;
                s.submit(move |_| {
                    sum.fetch_add(i, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(sum.load(Ordering::SeqCst), 99 * 100 / 2);
    }

    #[test]
    fn chip_affinity_is_respected() {
        let pool = small_pool();
        let wrong = AtomicUsize::new(0);
        pool.scope(|s| {
            for i in 0..200 {
                let chip = i % 2;
                let wrong = &wrong;
                s.submit_chip(chip, 0, move |ctx| {
                    if ctx.chip != chip {
                        wrong.fetch_add(1, Ordering::SeqCst);
                    }
                });
            }
        });
        assert_eq!(wrong.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn primary_only_runs_on_primary() {
        let pool = small_pool();
        let bad = AtomicUsize::new(0);
        pool.scope(|s| {
            for i in 0..50 {
                let bad = &bad;
                s.submit_chip_primary(i % 2, 0, move |ctx| {
                    if !ctx.primary {
                        bad.fetch_add(1, Ordering::SeqCst);
                    }
                });
            }
        });
        assert_eq!(bad.load(Ordering::SeqCst), 0);
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn job_panic_propagates_to_scope() {
        let pool = small_pool();
        pool.scope(|s| {
            s.submit(|_| panic!("boom"));
        });
    }

    #[test]
    fn pool_survives_job_panic() {
        let pool = small_pool();
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scope(|s| s.submit(|_| panic!("boom")));
        }));
        assert!(r.is_err());
        // Pool must still work.
        let c = AtomicUsize::new(0);
        pool.parallel_for(10, |_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn priority_orders_chip_tasks() {
        // One single-core chip: tasks must run strictly by priority.
        let pool = TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 1 });
        let order = Mutex::new(Vec::new());
        pool.scope(|s| {
            // Block the worker briefly so all tasks are queued first.
            s.submit(|_| std::thread::sleep(std::time::Duration::from_millis(50)));
            for (prio, tag) in [(1i64, "low"), (10, "high"), (5, "mid")] {
                let order = &order;
                s.submit_chip(0, prio, move |_| order.lock().unwrap().push(tag));
            }
        });
        assert_eq!(*order.lock().unwrap(), vec!["high", "mid", "low"]);
    }

    #[test]
    fn parallel_for_with_worker_covers_all_and_reports_valid_ids() {
        let pool = small_pool();
        let hits: Vec<AtomicUsize> = (0..500).map(|_| AtomicUsize::new(0)).collect();
        let bad_worker = AtomicUsize::new(0);
        let nw = pool.workers();
        pool.parallel_for_with_worker(500, |w, i| {
            if w >= nw {
                bad_worker.fetch_add(1, Ordering::SeqCst);
            }
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        assert_eq!(bad_worker.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn parallel_map_collects() {
        let pool = small_pool();
        let v = pool.parallel_map(64, |i| i * i);
        assert_eq!(v, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn topology_detection_sane() {
        let t = ChipTopology::detect();
        assert!(t.chips >= 1);
        assert!(t.cores_per_chip >= 1);
    }

    #[test]
    fn placement_is_noop_on_single_node() {
        let before = crate::util::numa::pin_calls();
        let numa = crate::util::numa::NumaTopology::single(4);
        let pool = TaskPool::with_placement(ChipTopology { chips: 2, cores_per_chip: 2 }, &numa);
        let c = AtomicUsize::new(0);
        pool.parallel_for(8, |_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(c.load(Ordering::SeqCst), 8);
        drop(pool);
        // Other tests only pin if the *host* is multi-node; on a
        // single-node host the counter must be exactly untouched.
        if !crate::util::numa::topology().is_multi() {
            assert_eq!(crate::util::numa::pin_calls(), before, "single node must never pin");
        }
    }
}
