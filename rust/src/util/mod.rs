//! Shared infrastructure: PRNG, thread pool, magic-number division,
//! a quickcheck-lite property-testing harness and a bench harness.
//!
//! The offline crate set contains only `xla` and `anyhow`, so rayon /
//! tokio / criterion / proptest equivalents are provided here from
//! scratch. This mirrors the paper's own approach: ZNNi implemented its
//! task scheduling directly rather than relying on TBB's work stealing
//! (§IV.A.3).

pub mod bench;
pub mod faults;
pub mod json;
pub mod magic;
pub mod numa;
pub mod pool;
pub mod prng;
pub mod quick;
pub mod sendptr;
pub mod sync;

pub use magic::MagicU64;
pub use pool::{ChipTopology, TaskPool};
pub use prng::Rng;

/// Round `a` up to the next multiple of `m`.
#[inline]
pub fn round_up(a: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    (a + m - 1) / m * m
}

/// Integer ceil division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Human-readable byte count (GiB/MiB/KiB).
pub fn human_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let b = b as f64;
    if b >= K * K * K {
        format!("{:.2} GiB", b / (K * K * K))
    } else if b >= K * K {
        format!("{:.2} MiB", b / (K * K))
    } else if b >= K {
        format!("{:.2} KiB", b / K)
    } else {
        format!("{b:.0} B")
    }
}

/// Human-readable voxel throughput.
pub fn human_throughput(voxels_per_sec: f64) -> String {
    if voxels_per_sec >= 1e6 {
        format!("{:.3} MVx/s", voxels_per_sec / 1e6)
    } else if voxels_per_sec >= 1e3 {
        format!("{:.2} kVx/s", voxels_per_sec / 1e3)
    } else {
        format!("{voxels_per_sec:.1} Vx/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_works() {
        assert_eq!(round_up(0, 4), 0);
        assert_eq!(round_up(1, 4), 4);
        assert_eq!(round_up(4, 4), 4);
        assert_eq!(round_up(5, 4), 8);
    }

    #[test]
    fn ceil_div_works() {
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(3, 3), 1);
        assert_eq!(ceil_div(4, 3), 2);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert!(human_bytes(3 * 1024 * 1024).contains("MiB"));
        assert!(human_bytes(5 * 1024 * 1024 * 1024).contains("GiB"));
    }
}
