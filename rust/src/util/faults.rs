//! Deterministic fault injection: a failpoint registry for chaos tests.
//!
//! Long-running serving (the regime the paper's throughput argument
//! assumes) must survive panics and transient memory pressure anywhere
//! in a shard's compute path. This module provides the *test* side of
//! that contract: named failpoints compiled into the hot paths that are
//! a no-op until armed, plus a registry of rules that inject panics,
//! delays or simulated reserve failures with a deterministic,
//! [`crate::util::prng::Rng`]-seeded probability.
//!
//! Rules come from either the `ZNNI_FAULTS` environment variable (read
//! once, like `ZNNI_KERNEL_CACHE`) or programmatic [`install`] /
//! [`install_str`] calls, which take precedence. The spec format is a
//! comma-separated list of `site:kind:prob[:seed]` rules:
//!
//! ```text
//! ZNNI_FAULTS="worker_patch:panic:0.05:7,arena_take:reserve_fail:0.2:13"
//! ```
//!
//! * `site` — one of [`FaultSite::ALL`]: `shard_dispatch`,
//!   `worker_patch`, `arena_take`, `kernel_cache_warm`;
//! * `kind` — `panic` (unwind with a recognisable message), `delay`
//!   (sleep [`DELAY_MS`] ms) or `reserve_fail` (make
//!   [`fire_reserve`] report a simulated allocation failure — the
//!   server treats it as memory pressure);
//! * `prob` — per-hit probability in `[0, 1]`;
//! * `seed` — PRNG seed (optional, defaults to a fixed constant), so a
//!   given spec fires at exactly the same hit sequence on every run.
//!
//! The fast path ([`fire`] / [`fire_reserve`] with nothing armed) is two
//! relaxed atomic loads — cheap enough to sit inside arena takes.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock};
use std::time::Duration;

use crate::util::prng::Rng;
use crate::util::sync::recover_lock;

/// Prefix of every injected-panic message; [`site_of_panic`] recognises
/// it so the server can answer a typed `Internal { site }` error.
pub const PANIC_PREFIX: &str = "znni fault injected at ";

/// Milliseconds a `delay` rule sleeps when it fires.
pub const DELAY_MS: u64 = 25;

/// Seed used when a rule omits its fourth field.
const DEFAULT_SEED: u64 = 0x5EED;

/// A named failpoint compiled into a hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// [`crate::server`] shard loop, just before a batch is served.
    ShardDispatch,
    /// [`crate::coordinator`] worker, once per patch job.
    WorkerPatch,
    /// [`crate::exec::Arena`] raw buffer takes (`panic`/`delay`), and
    /// the server's per-batch pressure probe (`reserve_fail`).
    ArenaTake,
    /// [`crate::layers::ConvLayer`] kernel-spectra cache build.
    KernelCacheWarm,
}

impl FaultSite {
    /// Every registered site, in registry order.
    pub const ALL: [FaultSite; 4] = [
        FaultSite::ShardDispatch,
        FaultSite::WorkerPatch,
        FaultSite::ArenaTake,
        FaultSite::KernelCacheWarm,
    ];

    /// The spec/display name of this site.
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::ShardDispatch => "shard_dispatch",
            FaultSite::WorkerPatch => "worker_patch",
            FaultSite::ArenaTake => "arena_take",
            FaultSite::KernelCacheWarm => "kernel_cache_warm",
        }
    }

    /// Parse a spec-format site name.
    pub fn parse(s: &str) -> Option<FaultSite> {
        Self::ALL.into_iter().find(|site| site.name() == s.trim())
    }

    fn index(self) -> usize {
        Self::ALL.iter().position(|s| *s == self).unwrap_or(0)
    }
}

/// What an armed rule does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Unwind with `PANIC_PREFIX + site name`.
    Panic,
    /// Sleep [`DELAY_MS`] milliseconds (latency chaos; never corrupts).
    Delay,
    /// Report a simulated allocation failure through [`fire_reserve`].
    ReserveFail,
}

impl FaultKind {
    /// Parse a spec-format kind name.
    pub fn parse(s: &str) -> Option<FaultKind> {
        match s.trim() {
            "panic" => Some(FaultKind::Panic),
            "delay" => Some(FaultKind::Delay),
            "reserve_fail" => Some(FaultKind::ReserveFail),
            _ => None,
        }
    }
}

/// One parsed injection rule.
#[derive(Clone, Debug)]
pub struct FaultRule {
    /// Failpoint this rule arms.
    pub site: FaultSite,
    /// Action taken when the probability draw hits.
    pub kind: FaultKind,
    /// Per-hit firing probability in `[0, 1]`.
    pub prob: f64,
    /// Seed of the rule's private deterministic PRNG.
    pub seed: u64,
}

/// A full parsed `ZNNI_FAULTS` spec.
#[derive(Clone, Debug, Default)]
pub struct FaultConfig {
    /// The rules, in spec order.
    pub rules: Vec<FaultRule>,
}

impl FaultConfig {
    /// Parse a comma-separated `site:kind:prob[:seed]` spec.
    pub fn parse(spec: &str) -> Result<FaultConfig, String> {
        let mut rules = Vec::new();
        for raw in spec.split(',') {
            let part = raw.trim();
            if part.is_empty() {
                continue;
            }
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() < 3 || fields.len() > 4 {
                return Err(format!("rule {part:?}: want site:kind:prob[:seed]"));
            }
            let site = FaultSite::parse(fields[0])
                .ok_or_else(|| format!("rule {part:?}: unknown site {:?}", fields[0]))?;
            let kind = FaultKind::parse(fields[1])
                .ok_or_else(|| format!("rule {part:?}: unknown kind {:?}", fields[1]))?;
            let prob: f64 = fields[2]
                .trim()
                .parse()
                .map_err(|_| format!("rule {part:?}: bad probability {:?}", fields[2]))?;
            if !(0.0..=1.0).contains(&prob) {
                return Err(format!("rule {part:?}: probability must be in [0, 1]"));
            }
            let seed = match fields.get(3) {
                Some(s) => s
                    .trim()
                    .parse()
                    .map_err(|_| format!("rule {part:?}: bad seed {:?}", s))?,
                None => DEFAULT_SEED,
            };
            rules.push(FaultRule { site, kind, prob, seed });
        }
        Ok(FaultConfig { rules })
    }

    /// Whether the config arms nothing.
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }
}

/// An installed rule plus its private PRNG stream.
struct Armed {
    rule: FaultRule,
    rng: Rng,
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static INJECTED: [AtomicU64; 4] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

fn registry() -> &'static Mutex<Vec<Armed>> {
    static REG: OnceLock<Mutex<Vec<Armed>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

/// Load `ZNNI_FAULTS` exactly once. Runs before any install/fire so a
/// later programmatic [`install`]/[`clear`] always takes precedence
/// over the environment instead of being clobbered by a lazy env read.
fn ensure_env() {
    static INIT: Once = Once::new();
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("ZNNI_FAULTS") {
            if !v.trim().is_empty() {
                match FaultConfig::parse(&v) {
                    Ok(cfg) => install(cfg),
                    Err(e) => eprintln!("znni: ignoring ZNNI_FAULTS: {e}"),
                }
            }
        }
    });
}

/// Arm a config, replacing whatever was installed before (including the
/// `ZNNI_FAULTS` environment config). An empty config disarms.
pub fn install(cfg: FaultConfig) {
    ensure_env();
    let armed: Vec<Armed> =
        cfg.rules.into_iter().map(|rule| Armed { rng: Rng::new(rule.seed), rule }).collect();
    let active = !armed.is_empty();
    *recover_lock(registry()) = armed;
    ACTIVE.store(active, Ordering::SeqCst);
}

/// Parse and [`install`] a spec string.
pub fn install_str(spec: &str) -> Result<(), String> {
    install(FaultConfig::parse(spec)?);
    Ok(())
}

/// Disarm every rule (also suppresses a pending `ZNNI_FAULTS` config).
pub fn clear() {
    install(FaultConfig::default());
}

/// Whether any rule is currently armed.
pub fn active() -> bool {
    ensure_env();
    ACTIVE.load(Ordering::Relaxed)
}

/// How many times a site has injected a fault (any kind) since process
/// start. Test observability; never reset.
pub fn injected(site: FaultSite) -> u64 {
    INJECTED[site.index()].load(Ordering::Relaxed)
}

/// Total injections across all sites since process start.
pub fn injected_total() -> u64 {
    INJECTED.iter().map(|c| c.load(Ordering::Relaxed)).sum()
}

/// Hit a failpoint: fires any armed `panic` / `delay` rules for `site`.
/// A no-op (two relaxed atomic loads) when nothing is armed.
/// `reserve_fail` rules are ignored here — they only answer
/// [`fire_reserve`].
#[inline]
pub fn fire(site: FaultSite) {
    ensure_env();
    if !ACTIVE.load(Ordering::Relaxed) {
        return;
    }
    fire_slow(site);
}

#[cold]
fn fire_slow(site: FaultSite) {
    let mut do_panic = false;
    let mut do_delay = false;
    {
        let mut reg = recover_lock(registry());
        for a in reg.iter_mut().filter(|a| a.rule.site == site) {
            match a.rule.kind {
                FaultKind::Panic => do_panic |= (a.rng.f32() as f64) < a.rule.prob,
                FaultKind::Delay => do_delay |= (a.rng.f32() as f64) < a.rule.prob,
                FaultKind::ReserveFail => {}
            }
        }
    }
    // Act outside the registry lock so an injected panic never poisons
    // (or deadlocks) the registry itself.
    if do_delay {
        INJECTED[site.index()].fetch_add(1, Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(DELAY_MS));
    }
    if do_panic {
        INJECTED[site.index()].fetch_add(1, Ordering::Relaxed);
        panic!("{PANIC_PREFIX}{}", site.name());
    }
}

/// Probe a failpoint for a simulated allocation failure: `true` when an
/// armed `reserve_fail` rule for `site` fires. The server's per-batch
/// pressure check treats `true` exactly like a real over-budget ledger
/// reading. A no-op returning `false` when nothing is armed.
#[inline]
pub fn fire_reserve(site: FaultSite) -> bool {
    ensure_env();
    if !ACTIVE.load(Ordering::Relaxed) {
        return false;
    }
    fire_reserve_slow(site)
}

#[cold]
fn fire_reserve_slow(site: FaultSite) -> bool {
    let mut hit = false;
    {
        let mut reg = recover_lock(registry());
        for a in reg.iter_mut().filter(|a| a.rule.site == site) {
            if a.rule.kind == FaultKind::ReserveFail {
                hit |= (a.rng.f32() as f64) < a.rule.prob;
            }
        }
    }
    if hit {
        INJECTED[site.index()].fetch_add(1, Ordering::Relaxed);
    }
    hit
}

/// Extract the printable message of a caught panic payload (`&str` and
/// `String` payloads; anything else is `None`).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> Option<&str> {
    payload
        .downcast_ref::<&'static str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()))
}

/// Recognise an injected-fault panic message and return its site. Works
/// through the pool scope's re-panic wrapper, which preserves the
/// original message as a suffix.
pub fn site_of_panic(msg: &str) -> Option<FaultSite> {
    FaultSite::ALL
        .into_iter()
        .find(|s| msg.contains(&format!("{PANIC_PREFIX}{}", s.name())))
}

#[cfg(test)]
mod tests {
    // The registry is process-global and the failpoints sit inside code
    // paths (arena takes, shard loops) that *other* concurrently
    // running unit tests exercise, so in-module tests only cover the
    // pure parsing/recognition half. Arming and firing is exercised —
    // serialized — in rust/tests/integration_faults.rs, mirroring the
    // `force_cache_mode` discipline in `conv::precomp`.
    use super::*;

    #[test]
    fn parses_full_spec() {
        let cfg =
            FaultConfig::parse("worker_patch:panic:0.05:7, arena_take:reserve_fail:0.2:13")
                .unwrap();
        assert_eq!(cfg.rules.len(), 2);
        assert_eq!(cfg.rules[0].site, FaultSite::WorkerPatch);
        assert_eq!(cfg.rules[0].kind, FaultKind::Panic);
        assert!((cfg.rules[0].prob - 0.05).abs() < 1e-12);
        assert_eq!(cfg.rules[0].seed, 7);
        assert_eq!(cfg.rules[1].site, FaultSite::ArenaTake);
        assert_eq!(cfg.rules[1].kind, FaultKind::ReserveFail);
    }

    #[test]
    fn seed_defaults_when_omitted() {
        let cfg = FaultConfig::parse("shard_dispatch:delay:1.0").unwrap();
        assert_eq!(cfg.rules[0].seed, DEFAULT_SEED);
        assert_eq!(cfg.rules[0].kind, FaultKind::Delay);
    }

    #[test]
    fn empty_spec_is_empty_config() {
        assert!(FaultConfig::parse("").unwrap().is_empty());
        assert!(FaultConfig::parse(" , ,").unwrap().is_empty());
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(FaultConfig::parse("nope:panic:1.0").is_err());
        assert!(FaultConfig::parse("arena_take:frobnicate:1.0").is_err());
        assert!(FaultConfig::parse("arena_take:panic:1.5").is_err());
        assert!(FaultConfig::parse("arena_take:panic:x").is_err());
        assert!(FaultConfig::parse("arena_take:panic:0.5:seed").is_err());
        assert!(FaultConfig::parse("arena_take:panic").is_err());
        assert!(FaultConfig::parse("arena_take:panic:0.5:1:extra").is_err());
    }

    #[test]
    fn site_names_round_trip() {
        for s in FaultSite::ALL {
            assert_eq!(FaultSite::parse(s.name()), Some(s));
        }
        assert_eq!(FaultSite::parse("bogus"), None);
    }

    #[test]
    fn panic_messages_are_recognised() {
        let msg = format!("{PANIC_PREFIX}worker_patch");
        assert_eq!(site_of_panic(&msg), Some(FaultSite::WorkerPatch));
        let wrapped = format!("a task submitted to the pool scope panicked: {msg}");
        assert_eq!(site_of_panic(&wrapped), Some(FaultSite::WorkerPatch));
        assert_eq!(site_of_panic("ordinary panic"), None);
    }

    #[test]
    fn panic_payload_message_extraction() {
        let s: Box<dyn std::any::Any + Send> = Box::new("static str panic");
        assert_eq!(panic_message(s.as_ref()), Some("static str panic"));
        let s: Box<dyn std::any::Any + Send> = Box::new(String::from("owned panic"));
        assert_eq!(panic_message(s.as_ref()), Some("owned panic"));
        let s: Box<dyn std::any::Any + Send> = Box::new(42u32);
        assert_eq!(panic_message(s.as_ref()), None);
    }
}
