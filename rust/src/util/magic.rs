//! Magic-number unsigned division (Hacker's Delight, ch. 10).
//!
//! The paper (§III.D) replaces the div/mod in 4D tensor-permute index
//! arithmetic with multiplications by precomputed magic numbers plus
//! shifts, because on the GPU those divisions cost more than the 1D FFTs
//! themselves. Our batched-FFT permutes (fft::batched) use the same
//! trick; on x86 it removes the 20–40 cycle `div` from the inner loop.

/// Precomputed magic constants for dividing a u64 by a fixed divisor.
#[derive(Clone, Copy, Debug)]
pub struct MagicU64 {
    magic: u128,
    shift: u32,
    divisor: u64,
}

impl MagicU64 {
    /// Build the magic constants for `divisor` (must be non-zero).
    ///
    /// Uses the straightforward "round up 2^(64+shift)/d" construction,
    /// with a 128-bit multiply at use-time. Correct for all u64
    /// dividends and divisors.
    pub fn new(divisor: u64) -> Self {
        assert!(divisor > 0, "divisor must be non-zero");
        // magic = ceil(2^(64+s) / d) with s = ceil(log2(d)); then
        // q = (n * magic) >> (64 + s) for every u64 dividend n.
        let s = if divisor == 1 { 0 } else { 64 - (divisor - 1).leading_zeros() };
        let magic: u128 = if divisor == 1 {
            1u128 << 64
        } else {
            ((1u128 << (64 + s)) + divisor as u128 - 1) / divisor as u128
        };
        MagicU64 { magic, shift: s, divisor }
    }

    /// `n / divisor` without a hardware divide.
    #[inline(always)]
    pub fn div(&self, n: u64) -> u64 {
        if self.magic >> 64 != 0 {
            // magic = 2^64 + lo (it never exceeds 2^65):
            // q = (n + ⌊n·lo / 2^64⌋) >> shift, evaluated in u128.
            let lo = self.magic as u64;
            let t = ((n as u128 * lo as u128) >> 64) + n as u128;
            (t >> self.shift) as u64
        } else {
            ((n as u128 * self.magic) >> (64 + self.shift)) as u64
        }
    }

    /// `n % divisor` via the magic quotient.
    #[inline(always)]
    pub fn rem(&self, n: u64) -> u64 {
        n - self.div(n) * self.divisor
    }

    /// `(n / divisor, n % divisor)` in one go.
    #[inline(always)]
    pub fn divrem(&self, n: u64) -> (u64, u64) {
        let q = self.div(n);
        (q, n - q * self.divisor)
    }

    /// The divisor these constants encode.
    #[inline]
    pub fn divisor(&self) -> u64 {
        self.divisor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn exhaustive_small() {
        for d in 1..=64u64 {
            let m = MagicU64::new(d);
            for n in 0..4096u64 {
                assert_eq!(m.div(n), n / d, "n={n} d={d}");
                assert_eq!(m.rem(n), n % d, "n={n} d={d}");
            }
        }
    }

    #[test]
    fn random_large() {
        let mut rng = Rng::new(0xC0FFEE);
        for _ in 0..2000 {
            let d = rng.next_u64() % (1 << 40) + 1;
            let n = rng.next_u64();
            let m = MagicU64::new(d);
            assert_eq!(m.div(n), n / d, "n={n} d={d}");
            let (q, r) = m.divrem(n);
            assert_eq!(q, n / d);
            assert_eq!(r, n % d);
        }
    }

    #[test]
    fn powers_of_two() {
        for p in 0..60 {
            let d = 1u64 << p;
            let m = MagicU64::new(d);
            for n in [0, 1, d - 1, d, d + 1, u64::MAX / 2, u64::MAX] {
                assert_eq!(m.div(n), n / d, "n={n} d={d}");
            }
        }
    }

    #[test]
    fn tensor_index_decomposition() {
        // The actual permute use-case: flat -> (b, x, y, z).
        let (b, x, y, z) = (3u64, 5, 7, 11);
        let mz = MagicU64::new(z);
        let my = MagicU64::new(y);
        let mx = MagicU64::new(x);
        for flat in 0..(b * x * y * z) {
            let (rest, kz) = mz.divrem(flat);
            let (rest, ky) = my.divrem(rest);
            let (kb, kx) = mx.divrem(rest);
            let expect = (
                flat / (x * y * z),
                flat / (y * z) % x,
                flat / z % y,
                flat % z,
            );
            assert_eq!((kb, kx, ky, kz), expect);
        }
    }
}
