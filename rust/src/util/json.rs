//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Supports the JSON subset the crate persists — objects, arrays,
//! strings, finite numbers, booleans and null — with order-preserving
//! objects and shortest-round-trip `f64` formatting. Used by the
//! calibration profiles ([`crate::optimizer::CostModel::save_profile`])
//! and available to the bench targets.
//!
//! ```
//! use znni::util::json::Json;
//!
//! let v = Json::parse(r#"{"rate": 1.5e9, "tags": ["a", "b"]}"#).unwrap();
//! assert_eq!(v.get("rate").and_then(Json::as_f64), Some(1.5e9));
//! let text = v.to_pretty_string();
//! assert_eq!(Json::parse(&text).unwrap().get("tags").unwrap().as_array().unwrap().len(), 2);
//! ```

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (parsed as `f64`).
    Num(f64),
    /// A string (unescaped).
    Str(String),
    /// An array.
    Array(Vec<Json>),
    /// An object; insertion order is preserved.
    Object(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing non-whitespace is an
    /// error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup (linear scan; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number, if this is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as a `u64`, if this is a non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// The string, if this is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(v) => Some(v),
            _ => None,
        }
    }

    /// The fields, if this is an object.
    pub fn as_object(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Object(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize with two-space indentation and a trailing newline.
    pub fn to_pretty_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, depth: usize) {
        let pad = |out: &mut String, d: usize| out.push_str(&"  ".repeat(d));
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => out.push_str(&format_f64(*x)),
            Json::Str(s) => {
                out.push('"');
                out.push_str(&escape(s));
                out.push('"');
            }
            Json::Array(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in items.iter().enumerate() {
                    pad(out, depth + 1);
                    v.write(out, depth + 1);
                    out.push_str(if i + 1 < items.len() { ",\n" } else { "\n" });
                }
                pad(out, depth);
                out.push(']');
            }
            Json::Object(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in fields.iter().enumerate() {
                    pad(out, depth + 1);
                    out.push('"');
                    out.push_str(&escape(k));
                    out.push_str("\": ");
                    v.write(out, depth + 1);
                    out.push_str(if i + 1 < fields.len() { ",\n" } else { "\n" });
                }
                pad(out, depth);
                out.push('}');
            }
        }
    }
}

/// Shortest-round-trip `f64` formatting; non-finite values (which JSON
/// cannot represent) serialize as `null`.
fn format_f64(x: f64) -> String {
    if !x.is_finite() {
        return "null".into();
    }
    // `{:?}` is Rust's shortest round-trip form ("1.5", "1e300") —
    // every finite output is a valid JSON number.
    format!("{:?}", x)
}

/// Escape a string for embedding between JSON quotes.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes.get(self.pos).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected '{}' at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            b => bail!("unexpected '{}' at byte {}", b as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Object(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Object(fields));
                }
                b => bail!("expected ',' or '}}', got '{}' at byte {}", b as char, self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Array(items));
                }
                b => bail!("expected ',' or ']', got '{}' at byte {}", b as char, self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape at byte {}", self.pos);
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| anyhow!("bad \\u escape at byte {}", self.pos))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow!("bad \\u escape at byte {}", self.pos))?;
                            self.pos += 4;
                            // Surrogates are not paired here; the crate's
                            // own output never emits them.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        e => bail!("bad escape '\\{}' at byte {}", e as char, self.pos),
                    }
                }
                b if b < 0x80 => out.push(b as char),
                _ => {
                    // Multi-byte UTF-8: copy the full character.
                    let start = self.pos - 1;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| anyhow!("invalid UTF-8 at byte {start}"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos = start + c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.bytes.get(self.pos) {
            if matches!(b, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let x: f64 =
            text.parse().map_err(|_| anyhow!("invalid number '{}' at byte {}", text, start))?;
        Ok(Json::Num(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[1].as_u64(), Some(2));
        assert_eq!(arr[2].get("b").and_then(Json::as_str), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn round_trips_pretty() {
        let v = Json::Object(vec![
            ("rate".into(), Json::Num(0.4e9)),
            ("name".into(), Json::Str("FFT \"pruned\"\n".into())),
            ("ladder".into(), Json::Array(vec![Json::Num(6.0), Json::Num(10.0)])),
            ("empty".into(), Json::Object(vec![])),
        ]);
        let text = v.to_pretty_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn round_trips_f64_exactly() {
        for x in [0.0, 1.0, 200e-6, 0.4e9, 1.23456789e-7, f64::MAX] {
            let text = Json::Num(x).to_pretty_string();
            assert_eq!(Json::parse(text.trim()).unwrap().as_f64(), Some(x), "{text}");
        }
    }

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_pretty_string().trim(), "null");
    }

    #[test]
    fn unicode_and_u_escapes() {
        let v = Json::parse(r#""Aß😀""#).unwrap();
        assert_eq!(v.as_str(), Some("Aß😀"));
    }
}
