//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Provides warm-up + repeated timing with median/min/mean reporting and
//! simple aligned-table printing used by every `rust/benches/*` target
//! to regenerate the paper's tables and figures.

use std::time::{Duration, Instant};

/// Result of timing one benchmark case.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    /// Median of the timed runs.
    pub median: Duration,
    /// Fastest timed run.
    pub min: Duration,
    /// Mean of the timed runs.
    pub mean: Duration,
    /// Number of timed runs.
    pub iters: usize,
}

impl Sample {
    /// Median seconds.
    pub fn secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Time `f` with `warmup` throwaway runs and `iters` measured runs.
pub fn time_n(warmup: usize, iters: usize, mut f: impl FnMut()) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters.max(1));
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    Sample { median, min, mean, iters: times.len() }
}

/// Adaptive timing: keep running until `budget` elapses (at least 3
/// iterations), then report. Good for cases whose cost varies by 1000×
/// across a parameter sweep.
pub fn time_budget(budget: Duration, mut f: impl FnMut()) -> Sample {
    // One calibration run.
    let t0 = Instant::now();
    f();
    let first = t0.elapsed();
    let mut times = vec![first];
    let deadline = Instant::now() + budget.saturating_sub(first);
    while times.len() < 3 || (Instant::now() < deadline && times.len() < 1000) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
        if times.len() >= 3 && first > budget {
            break; // huge case: 3 runs is all we afford
        }
    }
    times.sort();
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    Sample { median, min, mean, iters: times.len() }
}

/// Scale knob shared by the bench targets: `ZNNI_SCALE=paper` runs
/// closer to the paper's sizes (slow), default `small` finishes in
/// minutes on this testbed, `tiny` for CI smoke.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// CI smoke scale.
    Tiny,
    /// Minutes-scale runs on this testbed (default).
    Small,
    /// Close to the paper's sizes (slow).
    Paper,
}

impl Scale {
    /// Read `ZNNI_SCALE` (tiny|small|paper; default small).
    pub fn from_env() -> Self {
        match std::env::var("ZNNI_SCALE").as_deref() {
            Ok("paper") => Scale::Paper,
            Ok("tiny") => Scale::Tiny,
            _ => Scale::Small,
        }
    }
}

/// Fixed-width table printer for bench output (paper-style rows).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Print with aligned columns.
    pub fn print(&self) {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("| {:width$} ", c, width = w[i]));
            }
            s.push('|');
            s
        };
        println!("{}", line(&self.headers));
        let sep: Vec<String> = w.iter().map(|n| "-".repeat(*n)).collect();
        println!("{}", line(&sep));
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_n_counts_iters() {
        let s = time_n(1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median);
    }

    #[test]
    fn time_budget_runs_at_least_three() {
        let s = time_budget(Duration::from_millis(1), || {});
        assert!(s.iters >= 3);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // must not panic
    }

    #[test]
    fn scale_default_small() {
        std::env::remove_var("ZNNI_SCALE");
        assert_eq!(Scale::from_env(), Scale::Small);
    }
}
