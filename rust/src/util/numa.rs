//! NUMA topology discovery and worker pinning — zero-dependency.
//!
//! The paper's headline CPU result ("the CPU manages to achieve higher
//! throughput because of its fast access to more RAM") assumes the
//! multi-socket machines of Table III, where that RAM is only *fast*
//! when a worker touches node-local pages. This module gives the
//! serving stack the two primitives that argument needs:
//!
//! * **Topology** — [`NumaTopology::detect`] parses
//!   `/sys/devices/system/node/node*/{cpulist,meminfo}` (no libnuma,
//!   no crates) and falls back to a single all-CPU node when the
//!   hierarchy is absent (non-Linux hosts, containers without sysfs,
//!   genuinely single-socket machines).
//! * **Pinning** — [`pin_current_thread`] binds the calling thread to a
//!   node's CPU set via a direct `extern "C" sched_setaffinity`
//!   binding (the offline crate set has no `libc`). Every attempted
//!   syscall bumps [`pin_calls`], so tests can *prove* the single-node
//!   path never pins.
//!
//! The whole axis is gated by `ZNNI_NUMA` (`off | auto`, default
//! `auto`, read once; [`force_numa_mode`] overrides for tests).
//! Placement only ever engages when the mode is `auto` **and** the
//! detected topology has more than one node ([`placement_active`]) —
//! on a single-node machine the feature is a provable no-op: no
//! syscalls, no behavioural change, bit-identical outputs.

use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::OnceLock;

/// One NUMA node: its sysfs id, the online CPUs it owns, and its local
/// memory.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NumaNode {
    /// The sysfs node id (the `N` of `/sys/devices/system/node/nodeN`).
    pub id: usize,
    /// Online CPUs local to this node, ascending (parsed from
    /// `cpulist`; offline CPUs simply never appear).
    pub cpus: Vec<usize>,
    /// Node-local memory in bytes (`meminfo` `MemTotal`), or 0 when the
    /// file is absent or unparsable.
    pub mem_bytes: u64,
}

/// The machine's NUMA topology: every node that owns at least one CPU,
/// in node-id order. Memory-only nodes (CXL expanders, zero-CPU HBM
/// nodes) are excluded — nothing can be pinned to them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NumaTopology {
    /// CPU-owning nodes, ascending by id. Never empty: detection falls
    /// back to a single node covering `fallback_cores` CPUs.
    pub nodes: Vec<NumaNode>,
}

impl NumaTopology {
    /// The degenerate single-node topology: one node owning CPUs
    /// `0..cores` — the graceful fallback everywhere sysfs is absent.
    pub fn single(cores: usize) -> Self {
        NumaTopology {
            nodes: vec![NumaNode { id: 0, cpus: (0..cores.max(1)).collect(), mem_bytes: 0 }],
        }
    }

    /// Parse a sysfs-style node directory (entries `node0`, `node1`, …
    /// each holding `cpulist` and optionally `meminfo`). Falls back to
    /// [`NumaTopology::single`]`(fallback_cores)` when the directory is
    /// missing, unreadable, or contains no CPU-owning node. Exposed
    /// (rather than hard-coding `/sys`) so fixture tests can parse
    /// synthetic trees.
    pub fn from_dir(dir: &Path, fallback_cores: usize) -> Self {
        let mut nodes: Vec<NumaNode> = Vec::new();
        if let Ok(entries) = std::fs::read_dir(dir) {
            for e in entries.flatten() {
                let name = e.file_name();
                let Some(name) = name.to_str() else { continue };
                let Some(idstr) = name.strip_prefix("node") else { continue };
                let Ok(id) = idstr.parse::<usize>() else { continue };
                let cpus = std::fs::read_to_string(e.path().join("cpulist"))
                    .map(|s| parse_cpulist(&s))
                    .unwrap_or_default();
                if cpus.is_empty() {
                    continue; // memory-only node: nothing to pin to
                }
                let mem_bytes = std::fs::read_to_string(e.path().join("meminfo"))
                    .map(|s| parse_meminfo(&s))
                    .unwrap_or(0);
                nodes.push(NumaNode { id, cpus, mem_bytes });
            }
        }
        if nodes.is_empty() {
            return NumaTopology::single(fallback_cores);
        }
        nodes.sort_by_key(|n| n.id);
        NumaTopology { nodes }
    }

    /// Detect the host topology from `/sys/devices/system/node`,
    /// falling back to one node of `fallback_cores` CPUs.
    pub fn detect(fallback_cores: usize) -> Self {
        Self::from_dir(Path::new("/sys/devices/system/node"), fallback_cores)
    }

    /// Number of CPU-owning nodes (≥ 1).
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Whether more than one CPU-owning node exists — the precondition
    /// for any pinning to engage.
    pub fn is_multi(&self) -> bool {
        self.nodes.len() > 1
    }

    /// Total CPUs across all nodes.
    pub fn total_cpus(&self) -> usize {
        self.nodes.iter().map(|n| n.cpus.len()).sum()
    }

    /// Index (into [`NumaTopology::nodes`]) of the node owning `cpu`,
    /// or `None` for an unknown/offline CPU.
    pub fn node_of_cpu(&self, cpu: usize) -> Option<usize> {
        self.nodes.iter().position(|n| n.cpus.contains(&cpu))
    }
}

/// Parse a sysfs `cpulist` (`"0-3,8-11"`, `"0"`, `"0,2,4"`; ranges are
/// inclusive, whitespace tolerated, malformed fragments skipped).
/// Returns ascending, deduplicated CPU ids.
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once('-') {
            if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                if a <= b {
                    cpus.extend(a..=b);
                }
            }
        } else if let Ok(c) = part.parse::<usize>() {
            cpus.push(c);
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    cpus
}

/// Parse a per-node `meminfo` for the `MemTotal` kB value, returning
/// bytes (0 when absent). Lines look like
/// `Node 0 MemTotal:       16303680 kB`.
pub fn parse_meminfo(s: &str) -> u64 {
    for line in s.lines() {
        if let Some(rest) = line.split("MemTotal:").nth(1) {
            let kb = rest
                .split_whitespace()
                .next()
                .and_then(|v| v.parse::<u64>().ok())
                .unwrap_or(0);
            return kb.saturating_mul(1024);
        }
    }
    0
}

/// Whether NUMA placement may engage, resolved once per process from
/// `ZNNI_NUMA`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum NumaMode {
    /// Never pin, never differentiate nodes — the topology module is
    /// inert.
    Off = 1,
    /// Pin workers to home nodes **when the machine is actually
    /// multi-node** ([`placement_active`]); single-node machines stay
    /// untouched. The default.
    Auto = 2,
}

impl NumaMode {
    /// Parse a `ZNNI_NUMA` value.
    pub fn parse(s: &str) -> Option<NumaMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "0" | "false" => Some(NumaMode::Off),
            "auto" | "on" | "1" | "true" => Some(NumaMode::Auto),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Option<NumaMode> {
        match v {
            1 => Some(NumaMode::Off),
            2 => Some(NumaMode::Auto),
            _ => None,
        }
    }
}

const MODE_UNSET: u8 = 0;
static FORCED_MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);
static RESOLVED_MODE: OnceLock<NumaMode> = OnceLock::new();
static PIN_CALLS: AtomicU64 = AtomicU64::new(0);
static TOPOLOGY: OnceLock<NumaTopology> = OnceLock::new();

/// The NUMA mode in effect: the [`force_numa_mode`]d mode if set, else
/// `ZNNI_NUMA` (read once), else [`NumaMode::Auto`].
pub fn numa_mode() -> NumaMode {
    match NumaMode::from_u8(FORCED_MODE.load(Ordering::Relaxed)) {
        Some(m) => m,
        None => *RESOLVED_MODE.get_or_init(|| match std::env::var("ZNNI_NUMA") {
            Ok(v) if !v.trim().is_empty() => match NumaMode::parse(&v) {
                Some(m) => m,
                None => {
                    eprintln!("znni: unknown ZNNI_NUMA value {v:?}, using auto");
                    NumaMode::Auto
                }
            },
            _ => NumaMode::Auto,
        }),
    }
}

/// Force the NUMA mode for every subsequent decision (tests and
/// benches), or restore env/default resolution with `None`.
pub fn force_numa_mode(mode: Option<NumaMode>) {
    match mode {
        Some(m) => FORCED_MODE.store(m as u8, Ordering::Relaxed),
        None => FORCED_MODE.store(MODE_UNSET, Ordering::Relaxed),
    }
}

/// The host topology, detected once per process (fallback core count:
/// [`std::thread::available_parallelism`]).
pub fn topology() -> &'static NumaTopology {
    TOPOLOGY.get_or_init(|| {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        NumaTopology::detect(cores)
    })
}

/// Whether placement should engage for this topology: mode is
/// [`NumaMode::Auto`] **and** the topology is genuinely multi-node.
/// Everything that pins checks this first, which is what makes the
/// single-node path a provable no-op.
pub fn placement_active(topo: &NumaTopology) -> bool {
    numa_mode() == NumaMode::Auto && topo.is_multi()
}

/// Bind the calling thread to the given CPU set via `sched_setaffinity`
/// (direct syscall binding — the crate set has no `libc`). Returns
/// whether the kernel accepted the mask. Every *attempted* syscall
/// bumps [`pin_calls`] first; callers are expected to gate on
/// [`placement_active`] so single-node machines never reach the
/// syscall. No-op (returns `false`, counter untouched) off Linux and
/// for empty CPU sets. CPUs ≥ 1024 are ignored (mask width).
pub fn pin_current_thread(cpus: &[usize]) -> bool {
    if cpus.is_empty() {
        return false;
    }
    #[cfg(target_os = "linux")]
    {
        // 16 × 64 = 1024 CPUs — matches glibc's default cpu_set_t.
        const WORDS: usize = 16;
        let mut mask = [0u64; WORDS];
        let mut any = false;
        for &c in cpus {
            if c < WORDS * 64 {
                mask[c / 64] |= 1u64 << (c % 64);
                any = true;
            }
        }
        if !any {
            return false;
        }
        extern "C" {
            // int sched_setaffinity(pid_t, size_t, const cpu_set_t *);
            // pid 0 = the calling thread.
            fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
        }
        PIN_CALLS.fetch_add(1, Ordering::SeqCst);
        let rc = unsafe { sched_setaffinity(0, WORDS * 8, mask.as_ptr()) };
        rc == 0
    }
    #[cfg(not(target_os = "linux"))]
    {
        false
    }
}

/// Total `sched_setaffinity` calls attempted process-wide — the
/// single-node no-op proof reads this (it must stay 0 when
/// [`placement_active`] is false everywhere).
pub fn pin_calls() -> u64 {
    PIN_CALLS.load(Ordering::SeqCst)
}

/// The home node (index into `topo.nodes`) for shard `si` of `shards`:
/// round-robin over the nodes, so shards spread evenly and shard
/// siblings on the same node are `si ± node_count` — the locality tier
/// work stealing prefers.
pub fn home_node_for_shard(topo: &NumaTopology, si: usize) -> usize {
    si % topo.node_count().max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn cpulist_single_and_ranges() {
        assert_eq!(parse_cpulist("0\n"), vec![0]);
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0-3,8-11\n"), vec![0, 1, 2, 3, 8, 9, 10, 11]);
        assert_eq!(parse_cpulist(" 0 , 2 , 4-5 "), vec![0, 2, 4, 5]);
    }

    #[test]
    fn cpulist_offline_gaps_and_garbage() {
        // Offline CPUs simply never appear: "0-1,6-7" is a 4-CPU node
        // with CPUs 2..=5 offline.
        assert_eq!(parse_cpulist("0-1,6-7"), vec![0, 1, 6, 7]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("3-1"), Vec::<usize>::new(), "inverted range skipped");
        assert_eq!(parse_cpulist("x,2,y-3,4"), vec![2, 4], "malformed fragments skipped");
        assert_eq!(parse_cpulist("1,1,0-1"), vec![0, 1], "deduplicated");
    }

    #[test]
    fn meminfo_parses_kb_as_bytes() {
        let s = "Node 0 MemTotal:       16303680 kB\nNode 0 MemFree:  1 kB\n";
        assert_eq!(parse_meminfo(s), 16303680 * 1024);
        assert_eq!(parse_meminfo("no such key"), 0);
    }

    /// Build a synthetic `nodeN/{cpulist,meminfo}` tree under a unique
    /// temp dir.
    fn fixture(nodes: &[(usize, &str, Option<&str>)]) -> std::path::PathBuf {
        static SEQ: AtomicU32 = AtomicU32::new(0);
        let dir = std::env::temp_dir().join(format!(
            "znni-numa-fixture-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::SeqCst)
        ));
        for (id, cpulist, meminfo) in nodes {
            let nd = dir.join(format!("node{id}"));
            std::fs::create_dir_all(&nd).unwrap();
            std::fs::write(nd.join("cpulist"), cpulist).unwrap();
            if let Some(m) = meminfo {
                std::fs::write(nd.join("meminfo"), m).unwrap();
            }
        }
        if nodes.is_empty() {
            std::fs::create_dir_all(&dir).unwrap();
        }
        dir
    }

    #[test]
    fn from_dir_multi_node() {
        let dir = fixture(&[
            (0, "0-3\n", Some("Node 0 MemTotal: 1024 kB\n")),
            (1, "4-7\n", Some("Node 1 MemTotal: 2048 kB\n")),
        ]);
        let t = NumaTopology::from_dir(&dir, 8);
        assert_eq!(t.node_count(), 2);
        assert!(t.is_multi());
        assert_eq!(t.nodes[0].cpus, vec![0, 1, 2, 3]);
        assert_eq!(t.nodes[1].cpus, vec![4, 5, 6, 7]);
        assert_eq!(t.nodes[0].mem_bytes, 1024 * 1024);
        assert_eq!(t.nodes[1].mem_bytes, 2048 * 1024);
        assert_eq!(t.total_cpus(), 8);
        assert_eq!(t.node_of_cpu(5), Some(1));
        assert_eq!(t.node_of_cpu(99), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn from_dir_skips_memory_only_nodes_and_missing_meminfo() {
        let dir = fixture(&[
            (0, "0-1,6-7\n", None),
            (2, "\n", Some("Node 2 MemTotal: 4096 kB\n")), // CXL-style, no CPUs
        ]);
        let t = NumaTopology::from_dir(&dir, 4);
        assert_eq!(t.node_count(), 1, "memory-only node excluded");
        assert_eq!(t.nodes[0].id, 0);
        assert_eq!(t.nodes[0].cpus, vec![0, 1, 6, 7], "offline CPUs 2-5 absent");
        assert_eq!(t.nodes[0].mem_bytes, 0, "missing meminfo defaults to 0");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn from_dir_falls_back_to_single_node() {
        let missing = std::env::temp_dir().join("znni-numa-definitely-missing");
        let t = NumaTopology::from_dir(&missing, 6);
        assert_eq!(t.node_count(), 1);
        assert!(!t.is_multi());
        assert_eq!(t.nodes[0].cpus, (0..6).collect::<Vec<_>>());
        // An empty dir (sysfs present but no nodeN entries) also falls
        // back.
        let empty = fixture(&[]);
        let t = NumaTopology::from_dir(&empty, 2);
        assert_eq!(t.node_count(), 1);
        assert_eq!(t.nodes[0].cpus, vec![0, 1]);
        std::fs::remove_dir_all(&empty).ok();
    }

    #[test]
    fn single_never_empty() {
        assert_eq!(NumaTopology::single(0).nodes[0].cpus, vec![0]);
    }

    #[test]
    fn mode_parse() {
        assert_eq!(NumaMode::parse("off"), Some(NumaMode::Off));
        assert_eq!(NumaMode::parse(" AUTO "), Some(NumaMode::Auto));
        assert_eq!(NumaMode::parse("on"), Some(NumaMode::Auto));
        assert_eq!(NumaMode::parse("numa"), None);
    }

    #[test]
    fn placement_needs_multi_node() {
        // Whatever the mode, a single-node topology never activates
        // placement; `force_numa_mode` is process-global, so this test
        // only asserts the topology half of the conjunction.
        assert!(!placement_active(&NumaTopology::single(8)));
    }

    #[test]
    fn home_nodes_round_robin() {
        let dir = fixture(&[(0, "0-3\n", None), (1, "4-7\n", None)]);
        let t = NumaTopology::from_dir(&dir, 8);
        assert_eq!(home_node_for_shard(&t, 0), 0);
        assert_eq!(home_node_for_shard(&t, 1), 1);
        assert_eq!(home_node_for_shard(&t, 2), 0);
        assert_eq!(home_node_for_shard(&t, 3), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pin_rejects_empty_and_out_of_range() {
        let before = pin_calls();
        assert!(!pin_current_thread(&[]));
        assert_eq!(pin_calls(), before, "empty set never reaches the syscall");
        assert!(!pin_current_thread(&[100_000]));
        assert_eq!(pin_calls(), before, "out-of-mask CPUs never reach the syscall");
    }
}
