//! Raw-pointer wrapper for disjoint parallel writes.

/// Wrapper that lets a raw mutable pointer cross closure boundaries into
/// pool jobs. Safety contract: every job writes a disjoint index range.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Accessor (rather than field access) so closures capture the
    /// wrapper — which is Send/Sync — instead of the raw pointer.
    #[inline(always)]
    pub fn get(&self) -> *mut T {
        self.0
    }

    /// View `len` elements starting at `offset` as a mutable slice.
    ///
    /// # Safety
    /// The range must be in bounds and not concurrently aliased.
    #[inline(always)]
    pub unsafe fn slice_mut(&self, offset: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(offset), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_parallel_writes() {
        let pool = crate::util::pool::TaskPool::with_topology(
            crate::util::pool::ChipTopology { chips: 1, cores_per_chip: 2 },
        );
        let mut v = vec![0u32; 100];
        let p = SendPtr(v.as_mut_ptr());
        pool.parallel_for(100, |i| unsafe {
            *p.get().add(i) = i as u32;
        });
        assert!(v.iter().enumerate().all(|(i, &x)| x == i as u32));
    }
}
