//! Memory accounting: a process-wide peak-tracking allocator ledger and
//! the paper's Table II analytic memory model.
//!
//! Throughput in ZNNi is memory-bound in an unusual sense: the *winning*
//! primitive is often the one whose working set fits the biggest input
//! patch (§II). Every [`crate::tensor`] allocation is registered here, so
//! tests can verify the analytic model of Table II against measured
//! peaks, and the optimizer can trust the model when it prunes plans.

pub mod model;

use std::sync::atomic::{AtomicU64, Ordering};

static CURRENT: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);

/// Register `bytes` of live tensor memory.
pub fn alloc(bytes: u64) {
    let cur = CURRENT.fetch_add(bytes, Ordering::SeqCst) + bytes;
    PEAK.fetch_max(cur, Ordering::SeqCst);
}

/// Unregister `bytes` of live tensor memory.
pub fn free(bytes: u64) {
    CURRENT.fetch_sub(bytes, Ordering::SeqCst);
}

/// Bytes currently registered.
pub fn current() -> u64 {
    CURRENT.load(Ordering::SeqCst)
}

/// High-water mark since the last [`reset_peak`].
pub fn peak() -> u64 {
    PEAK.load(Ordering::SeqCst)
}

/// Reset the high-water mark to the current level.
pub fn reset_peak() {
    PEAK.store(CURRENT.load(Ordering::SeqCst), Ordering::SeqCst);
}

/// A `Vec` whose backing allocation is registered with the ledger.
/// Scratch buffers inside primitives use this so their contribution to
/// the Table II peak is observable.
pub struct TrackedVec<T> {
    v: Vec<T>,
    bytes: u64,
    #[allow(dead_code)]
    label: &'static str,
}

impl<T: Clone + Default> TrackedVec<T> {
    /// Allocate `len` default-initialised elements.
    pub fn zeroed(len: usize, label: &'static str) -> Self {
        let bytes = (len * std::mem::size_of::<T>()) as u64;
        alloc(bytes);
        TrackedVec { v: vec![T::default(); len], bytes, label }
    }
}

impl<T> TrackedVec<T> {
    pub fn as_slice(&self) -> &[T] {
        &self.v
    }
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.v
    }
    pub fn as_mut_ptr(&mut self) -> *mut T {
        self.v.as_mut_ptr()
    }
    pub fn len(&self) -> usize {
        self.v.len()
    }
    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }
}

impl<T> Drop for TrackedVec<T> {
    fn drop(&mut self) {
        free(self.bytes);
    }
}

impl<T> std::ops::Index<usize> for TrackedVec<T> {
    type Output = T;
    fn index(&self, i: usize) -> &T {
        &self.v[i]
    }
}

impl<T> std::ops::IndexMut<usize> for TrackedVec<T> {
    fn index_mut(&mut self, i: usize) -> &mut T {
        &mut self.v[i]
    }
}

/// Run `f` and return `(result, peak_extra_bytes)` — the high-water mark
/// of tensor memory *above* the level at entry, as observed during `f`.
///
/// The ledger is global, so concurrent measured sections interleave;
/// tests that assert tight bounds run single-measurement.
pub fn measure<R>(f: impl FnOnce() -> R) -> (R, u64) {
    let base = current();
    reset_peak();
    let r = f();
    let p = peak();
    (r, p.saturating_sub(base))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let base = current();
        alloc(1000);
        assert_eq!(current(), base + 1000);
        free(1000);
        assert_eq!(current(), base);
    }

    #[test]
    fn measure_tracks_peak() {
        let (_, peak) = measure(|| {
            alloc(5000);
            alloc(3000);
            free(5000);
            alloc(1000);
            free(3000);
            free(1000);
        });
        assert!(peak >= 8000, "peak={peak}");
    }

    #[test]
    fn measure_of_noop_is_zero() {
        let (_, peak) = measure(|| {});
        assert_eq!(peak, 0);
    }
}
