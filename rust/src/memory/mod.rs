//! Memory accounting: a process-wide peak-tracking allocator ledger and
//! the paper's Table II analytic memory model.
//!
//! Throughput in ZNNi is memory-bound in an unusual sense: the *winning*
//! primitive is often the one whose working set fits the biggest input
//! patch (§II). Every [`crate::tensor`] allocation is registered here, so
//! tests can verify the analytic model of Table II against measured
//! peaks, and the optimizer can trust the model when it prunes plans.

pub mod model;

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

static CURRENT: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);
/// Count of *fresh* allocation events (not bytes): every `alloc` call.
/// Arena reuse goes through [`alloc_recycled`] instead, so after a warm
/// patch a steady workload advances this counter by zero — the "0
/// transient allocations after warmup" assertion reads it.
static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);
/// Arena gauges: bytes idle in arena free lists + outstanding takes,
/// aggregated over every live [`crate::exec::Arena`].
static ARENA_FOOTPRINT: AtomicI64 = AtomicI64::new(0);
static ARENA_HWM: AtomicU64 = AtomicU64::new(0);
static ARENA_FRESH: AtomicU64 = AtomicU64::new(0);
/// Resident precomputed kernel-spectra bytes
/// ([`crate::conv::precomp::PrecomputedKernels`]) currently live — the
/// RAM the weight-spectrum cache is trading for throughput.
static KERNEL_CACHE: AtomicI64 = AtomicI64::new(0);

/// Register `bytes` of live tensor memory (fresh backing store).
pub fn alloc(bytes: u64) {
    ALLOC_EVENTS.fetch_add(1, Ordering::SeqCst);
    let cur = CURRENT.fetch_add(bytes, Ordering::SeqCst) + bytes;
    PEAK.fetch_max(cur, Ordering::SeqCst);
}

/// Register `bytes` of live tensor memory whose backing store was
/// recycled from an arena — counts toward the peak like [`alloc`], but
/// is *not* an allocation event.
pub fn alloc_recycled(bytes: u64) {
    let cur = CURRENT.fetch_add(bytes, Ordering::SeqCst) + bytes;
    PEAK.fetch_max(cur, Ordering::SeqCst);
}

/// Unregister `bytes` of live tensor memory.
pub fn free(bytes: u64) {
    CURRENT.fetch_sub(bytes, Ordering::SeqCst);
}

/// Fresh allocation events since process start (monotone).
pub fn alloc_events() -> u64 {
    ALLOC_EVENTS.load(Ordering::SeqCst)
}

/// Adjust the aggregate arena footprint gauge (held + outstanding
/// bytes across all arenas) and fold it into the arena high-water mark.
/// Called by [`crate::exec::Arena`] only.
pub fn arena_gauge(held_delta: i64, outstanding_delta: i64) {
    let now = ARENA_FOOTPRINT.fetch_add(held_delta + outstanding_delta, Ordering::SeqCst)
        + held_delta
        + outstanding_delta;
    if now > 0 {
        ARENA_HWM.fetch_max(now as u64, Ordering::SeqCst);
    }
}

/// Count one arena take that required fresh backing store.
pub fn arena_fresh_event() {
    ARENA_FRESH.fetch_add(1, Ordering::SeqCst);
}

/// Current aggregate arena footprint in bytes (held + outstanding).
pub fn arena_footprint() -> u64 {
    ARENA_FOOTPRINT.load(Ordering::SeqCst).max(0) as u64
}

/// High-water mark of the aggregate arena footprint (monotone).
pub fn arena_hwm() -> u64 {
    ARENA_HWM.load(Ordering::SeqCst)
}

/// Arena takes served by fresh allocations since process start
/// (monotone) — zero growth across a window means the window ran
/// entirely out of recycled buffers.
pub fn arena_fresh_allocs() -> u64 {
    ARENA_FRESH.load(Ordering::SeqCst)
}

/// Adjust the kernel-spectra cache gauge. Called by
/// [`crate::conv::precomp::PrecomputedKernels`] only (positive at
/// build, negative at drop); the bytes also register with the ledger
/// via [`alloc`]/[`free`] so Table II peak measurements see them.
pub fn kernel_cache_gauge(delta: i64) {
    KERNEL_CACHE.fetch_add(delta, Ordering::SeqCst);
}

/// Resident precomputed kernel-spectra bytes currently live across the
/// process — the planned, budgeted RAM row the weight-spectrum cache
/// occupies (see `docs/ARCHITECTURE.md`, "The weight-spectrum cache").
pub fn kernel_cache_bytes() -> u64 {
    KERNEL_CACHE.load(Ordering::SeqCst).max(0) as u64
}

/// Bytes currently registered.
pub fn current() -> u64 {
    CURRENT.load(Ordering::SeqCst)
}

/// High-water mark since the last [`reset_peak`].
pub fn peak() -> u64 {
    PEAK.load(Ordering::SeqCst)
}

/// Reset the high-water mark to the current level.
pub fn reset_peak() {
    PEAK.store(CURRENT.load(Ordering::SeqCst), Ordering::SeqCst);
}

/// A `Vec` whose backing allocation is registered with the ledger.
/// Scratch buffers inside primitives use this so their contribution to
/// the Table II peak is observable.
pub struct TrackedVec<T> {
    v: Vec<T>,
    bytes: u64,
    #[allow(dead_code)]
    label: &'static str,
}

impl<T: Clone + Default> TrackedVec<T> {
    /// Allocate `len` default-initialised elements.
    pub fn zeroed(len: usize, label: &'static str) -> Self {
        let bytes = (len * std::mem::size_of::<T>()) as u64;
        alloc(bytes);
        TrackedVec { v: vec![T::default(); len], bytes, label }
    }
}

impl<T> TrackedVec<T> {
    /// Borrow the elements.
    pub fn as_slice(&self) -> &[T] {
        &self.v
    }
    /// Mutably borrow the elements.
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        &mut self.v
    }
    /// Raw mutable pointer to the first element.
    pub fn as_mut_ptr(&mut self) -> *mut T {
        self.v.as_mut_ptr()
    }
    /// Element count.
    pub fn len(&self) -> usize {
        self.v.len()
    }
    /// Whether there are no elements.
    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }
}

impl<T> Drop for TrackedVec<T> {
    fn drop(&mut self) {
        free(self.bytes);
    }
}

impl<T> std::ops::Index<usize> for TrackedVec<T> {
    type Output = T;
    fn index(&self, i: usize) -> &T {
        &self.v[i]
    }
}

impl<T> std::ops::IndexMut<usize> for TrackedVec<T> {
    fn index_mut(&mut self, i: usize) -> &mut T {
        &mut self.v[i]
    }
}

/// Run `f` and return `(result, peak_extra_bytes)` — the high-water mark
/// of tensor memory *above* the level at entry, as observed during `f`.
///
/// The ledger is global, so concurrent measured sections interleave;
/// tests that assert tight bounds run single-measurement.
pub fn measure<R>(f: impl FnOnce() -> R) -> (R, u64) {
    let base = current();
    reset_peak();
    let r = f();
    let p = peak();
    (r, p.saturating_sub(base))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let base = current();
        alloc(1000);
        assert_eq!(current(), base + 1000);
        free(1000);
        assert_eq!(current(), base);
    }

    #[test]
    fn measure_tracks_peak() {
        let (_, peak) = measure(|| {
            alloc(5000);
            alloc(3000);
            free(5000);
            alloc(1000);
            free(3000);
            free(1000);
        });
        assert!(peak >= 8000, "peak={peak}");
    }

    #[test]
    fn measure_of_noop_is_zero() {
        let (_, peak) = measure(|| {});
        assert_eq!(peak, 0);
    }

    #[test]
    fn recycled_alloc_counts_bytes_not_events() {
        // The counters are process-global and other tests run
        // concurrently, so only monotone properties are asserted.
        let e0 = alloc_events();
        alloc_recycled(500);
        free(500);
        alloc(500);
        free(500);
        let e1 = alloc_events();
        assert!(e1 >= e0 + 1, "alloc must count an event");
    }

    #[test]
    fn arena_gauges_are_monotone_and_balanced() {
        // Gauges are global and other tests run concurrently, so only
        // monotone properties are asserted here.
        let h0 = arena_hwm();
        let f0 = arena_fresh_allocs();
        arena_gauge(1000, 0);
        arena_fresh_event();
        arena_gauge(-1000, 0);
        assert!(arena_hwm() >= h0);
        assert!(arena_fresh_allocs() >= f0 + 1);
    }
}
