//! Table II — the analytic memory model.
//!
//! Throughput optimisation (§VI.A) needs, for every candidate primitive
//! and input shape, the peak memory the primitive will use *without
//! running it*. These functions express Table II of the paper in bytes.
//!
//! Conventions (element counts, matching the paper):
//! * `S`  — batch size;
//! * `f`, `f'` — input / output images per tuple;
//! * `n`, `n'` — voxels per input / output image;
//! * `ñ`  — *float-equivalent* elements of one transformed image,
//!   i.e. `2 · x̃ · ỹ · (z̃/2 + 1)` for padded extent `(x̃, ỹ, z̃)`;
//! * `T`  — worker threads (CPU) / primary-thread buffers;
//! * `K`  — the fixed sub-batch scratch the GPU FFT reserves (the
//!   cuFFT-overhead constant of §III.D).

use crate::fft::fft_optimal_vec3;
use crate::tensor::Vec3;

/// Bytes per f32 element.
const B: u64 = 4;

/// Which convolutional algorithm a memory estimate is for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ConvAlgo {
    /// CPU direct convolution, naive accumulation.
    DirectNaive,
    /// CPU direct with per-thread temporary result image ("MKL" mode).
    DirectMkl,
    /// CPU direct, register-tiled and cache-blocked, with bias and
    /// activation fused into the accumulator store (PZnet-style). Works
    /// out of per-worker row tiles instead of per-thread result images.
    DirectFused,
    /// [`ConvAlgo::DirectFused`] with the following max-pooling layer
    /// fused into the tile loop: each completed conv tile is pooled
    /// immediately, so the pre-pool tensor is never materialized. Only
    /// applicable when the next layer is an aligned max-pool.
    DirectFusedPool,
    /// CPU FFT-based, data parallel (Algorithm 2 / "FFT algorithm 1").
    FftDataParallel,
    /// CPU FFT-based, task parallel ("FFT algorithm 2").
    FftTaskParallel,
    /// GPU dense conv without workspace (cuDNN default stand-in).
    GpuDenseNoWorkspace,
    /// GPU dense conv with precomputed-index workspace (cuDNN precomp).
    GpuDensePrecomp,
    /// GPU FFT-based (Algorithm 3).
    GpuFft,
}

impl ConvAlgo {
    /// Every algorithm, in Table II row order.
    pub const ALL: [ConvAlgo; 9] = [
        ConvAlgo::DirectNaive,
        ConvAlgo::DirectMkl,
        ConvAlgo::DirectFused,
        ConvAlgo::DirectFusedPool,
        ConvAlgo::FftDataParallel,
        ConvAlgo::FftTaskParallel,
        ConvAlgo::GpuDenseNoWorkspace,
        ConvAlgo::GpuDensePrecomp,
        ConvAlgo::GpuFft,
    ];

    /// Whether this is a GPU-placed primitive.
    pub fn is_gpu(&self) -> bool {
        matches!(
            self,
            ConvAlgo::GpuDenseNoWorkspace | ConvAlgo::GpuDensePrecomp | ConvAlgo::GpuFft
        )
    }

    /// Human-readable name (Table II row labels).
    pub fn name(&self) -> &'static str {
        match self {
            ConvAlgo::DirectNaive => "Direct (naive)",
            ConvAlgo::DirectMkl => "Direct (MKL)",
            ConvAlgo::DirectFused => "Direct (fused)",
            ConvAlgo::DirectFusedPool => "Direct (fused+pool)",
            ConvAlgo::FftDataParallel => "FFT data-parallel",
            ConvAlgo::FftTaskParallel => "FFT task-parallel",
            ConvAlgo::GpuDenseNoWorkspace => "CuDNN1 (no workspace)",
            ConvAlgo::GpuDensePrecomp => "CuDNN2 (precomp)",
            ConvAlgo::GpuFft => "GPU-FFT",
        }
    }

    /// Short tag used in Table IV-style outputs.
    pub fn tag(&self) -> &'static str {
        match self {
            ConvAlgo::DirectNaive => "DirectN",
            ConvAlgo::DirectMkl => "DirectM",
            ConvAlgo::DirectFused => "DirectFused",
            ConvAlgo::DirectFusedPool => "DirectFusedPool",
            ConvAlgo::FftDataParallel => "FFT-DP",
            ConvAlgo::FftTaskParallel => "FFT-TP",
            ConvAlgo::GpuDenseNoWorkspace => "CuDNN1",
            ConvAlgo::GpuDensePrecomp => "CuDNN2",
            ConvAlgo::GpuFft => "FFT",
        }
    }

    /// Inverse of [`ConvAlgo::tag`] — used by the calibration-profile
    /// loader ([`crate::optimizer::CostModel::load_profile`]) to map
    /// persisted keys back to algorithms.
    pub fn from_tag(tag: &str) -> Option<ConvAlgo> {
        ConvAlgo::ALL.into_iter().find(|a| a.tag() == tag)
    }

    /// Whether this algorithm transforms kernels to the frequency domain
    /// and can therefore consume a precomputed weight-spectrum cache
    /// ([`crate::conv::precomp::PrecomputedKernels`]).
    pub fn uses_kernel_cache(&self) -> bool {
        matches!(
            self,
            ConvAlgo::FftDataParallel | ConvAlgo::FftTaskParallel | ConvAlgo::GpuFft
        )
    }
}

/// Problem dimensions of one convolutional layer application.
#[derive(Clone, Copy, Debug)]
pub struct ConvDims {
    /// Batch size (S).
    pub s: usize,
    /// Input images per tuple (f).
    pub f_in: usize,
    /// Output images per tuple (f').
    pub f_out: usize,
    /// Input extent per dimension (n).
    pub n: Vec3,
    /// Kernel extent per dimension (k).
    pub k: Vec3,
}

impl ConvDims {
    /// Output extent per dimension (n - k + 1).
    pub fn out_n(&self) -> Vec3 {
        [self.n[0] - self.k[0] + 1, self.n[1] - self.k[1] + 1, self.n[2] - self.k[2] + 1]
    }

    /// Voxels per input image.
    pub fn n_elems(&self) -> u64 {
        (self.n[0] * self.n[1] * self.n[2]) as u64
    }

    /// Voxels per output image.
    pub fn n_out_elems(&self) -> u64 {
        let o = self.out_n();
        (o[0] * o[1] * o[2]) as u64
    }

    /// Float-equivalent elements of one transformed image (ñ).
    pub fn n_tilde_elems(&self) -> u64 {
        let p = fft_optimal_vec3(self.n);
        2 * (p[0] * p[1] * (p[2] / 2 + 1)) as u64
    }

    /// FLOPs of the direct algorithm (Table I):
    /// `S · f' · f · n'³ · k³` MACs, counted as 2 ops each.
    pub fn direct_flops(&self) -> f64 {
        2.0 * self.s as f64
            * self.f_out as f64
            * self.f_in as f64
            * self.n_out_elems() as f64
            * (self.k[0] * self.k[1] * self.k[2]) as f64
    }

    /// FLOPs of the FFT algorithm (Table I):
    /// image transforms + point-wise MADs + pruned kernel transforms.
    pub fn fft_flops(&self) -> f64 {
        use crate::fft::plan::fft_3d_flops_naive;
        let p = fft_optimal_vec3(self.n);
        let s = self.s as f64;
        let (f, fp) = (self.f_in as f64, self.f_out as f64);
        let image_t = s * (f + fp) * fft_3d_flops_naive(p);
        let mads = 8.0 * s * f * fp * (p[0] * p[1] * (p[2] / 2 + 1)) as f64;
        image_t + mads + self.fft_kernel_flops()
    }

    /// The kernel-transform component of [`ConvDims::fft_flops`]:
    /// `f·f'` pruned kernel FFTs. This is the work a precomputed
    /// weight-spectrum cache removes from every call — the optimizer
    /// subtracts it when ranking a cached layer
    /// ([`crate::optimizer::CostModel::conv_secs_cached`]).
    pub fn fft_kernel_flops(&self) -> f64 {
        use crate::fft::plan::fft_3d_flops_pruned;
        let p = fft_optimal_vec3(self.n);
        (self.f_in * self.f_out) as f64 * fft_3d_flops_pruned(self.k, p)
    }
}

/// Fixed scratch constant for the GPU FFT sub-batching (K in Table II).
pub const GPU_FFT_K_BYTES: u64 = 64 << 20;

/// Peak bytes the given algorithm needs for the given layer dims,
/// per Table II. `threads` is T (CPU algorithms only).
pub fn conv_memory_bytes(algo: ConvAlgo, d: &ConvDims, threads: usize) -> u64 {
    let s = d.s as u64;
    let f = d.f_in as u64;
    let fp = d.f_out as u64;
    let n = d.n_elems();
    let np = d.n_out_elems();
    let nt = d.n_tilde_elems();
    let t = threads as u64;
    match algo {
        // S·f·n + S·f'·n'
        ConvAlgo::DirectNaive => B * (s * f * n + s * fp * np),
        // + one temporary result image per thread
        ConvAlgo::DirectMkl => B * (s * f * n + s * fp * np + t * np),
        // + one pair of accumulator rows (n'_z floats each) per thread —
        // the register tile spills nothing bigger than two output rows.
        // Run as a plain conv (no pool fused), the fused-pool variant
        // has the same footprint; its pooled row lives in
        // `conv_pool_fused_memory_bytes`.
        ConvAlgo::DirectFused | ConvAlgo::DirectFusedPool => {
            let o = d.out_n();
            B * (s * f * n + s * fp * np + t * 2 * o[2] as u64)
        }
        // max over the three stages of Algorithm 2:
        //   input + input transforms;
        //   output + input transforms + output accumulator + w̃;
        //   output + output transforms (inverse stage)
        ConvAlgo::FftDataParallel => {
            let st1 = s * f * (n + nt);
            let st2 = s * fp * np + (s * f + 1) * nt + s * nt;
            let st3 = s * fp * np + s * f * nt + s * nt;
            B * st1.max(st2).max(st3)
        }
        // max over the three stages of the task DAG:
        //   input + input transforms;
        //   input transforms + output transforms + per-primary buffers;
        //   output transforms + outputs
        ConvAlgo::FftTaskParallel => {
            let st1 = s * f * (n + nt);
            let st2 = s * (f + fp) * nt + t * nt;
            let st3 = s * fp * (np + nt);
            B * st1.max(st2).max(st3)
        }
        // S·f·n + S·f'·n'
        ConvAlgo::GpuDenseNoWorkspace => B * (s * f * n + s * fp * np),
        // 2·S·f·n + S·f'·n' (workspace for precomputed indices) plus
        // the per-worker temporary the dense inner path uses
        ConvAlgo::GpuDensePrecomp => B * (2 * s * f * n + s * fp * np + t * np),
        // K + max of the three stages of Algorithm 3
        ConvAlgo::GpuFft => {
            let st1 = s * f * (n + nt) + f * nt;
            let st2 = s * (f + fp) * nt + 2 * f * nt;
            let st3 = s * fp * (np + nt) + fp * nt;
            GPU_FFT_K_BYTES + B * st1.max(st2).max(st3)
        }
    }
}

/// Table II row of a fused conv→max-pool pair executed by
/// [`ConvAlgo::DirectFusedPool`]: input + *pooled* output + per-thread
/// tiles. The `S·f'·n'` inter-layer tensor of the unfused pair is
/// replaced by `S·f'·n'/p³` (the pooled output) plus `T` working tiles
/// of `2·p₀·n'_y·n'_z + 2·n'_z` floats each — the two-channel window of
/// conv planes being pooled, plus the accumulator rows. For any
/// realistically sized layer the tiles are orders of magnitude smaller
/// than the tensor they replace, which is the fusion's memory win.
///
/// `p` is the pooling window of the following layer; the conv output
/// extents must be divisible by it for the fusion to apply.
pub fn conv_pool_fused_memory_bytes(d: &ConvDims, p: Vec3, threads: usize) -> u64 {
    let s = d.s as u64;
    let f = d.f_in as u64;
    let fp = d.f_out as u64;
    let n = d.n_elems();
    let np = d.n_out_elems();
    let o = d.out_n();
    let t = threads as u64;
    let pooled = np / (p[0] * p[1] * p[2]) as u64;
    let tile = 2 * (p[0] * o[1] * o[2] + o[2]) as u64;
    B * (s * f * n + s * fp * pooled + t * tile)
}

/// Resident bytes of one layer's precomputed kernel-spectra row — the
/// Table II extension the weight-spectrum cache adds: `f'·f` transformed
/// kernels of `ñ` float-equivalent elements each (both the CPU and the
/// batched GPU layout store `x̃·ỹ·(z̃/2+1)` complex bins per kernel).
/// Zero for algorithms that do no kernel transforms. Unlike every other
/// Table II row this one is *resident for the plan's lifetime* and
/// *shared* across workers and shards (one `Arc`), so the optimizer
/// sums it across layers and adds it once — never per worker — when
/// checking a candidate against the device
/// ([`crate::exec::WorkspaceReq::resident_bytes`] carries it through
/// plan compilation).
pub fn kernel_spectra_bytes(algo: ConvAlgo, d: &ConvDims) -> u64 {
    kernel_spectra_bytes_p(algo, d, crate::precision::Precision::F32)
}

/// [`kernel_spectra_bytes`] at an explicit storage precision: the same
/// `f'·f·ñ` float-equivalents at that precision's element width, so a
/// half-width row ([`crate::precision::Precision::F16`] /
/// [`crate::precision::Precision::Bf16`]) costs exactly half the f32
/// row. This is the memory side of the reduced-precision trade the
/// optimizer searches — the time side is
/// [`crate::optimizer::CostModel::convert_secs`].
pub fn kernel_spectra_bytes_p(
    algo: ConvAlgo,
    d: &ConvDims,
    precision: crate::precision::Precision,
) -> u64 {
    if !algo.uses_kernel_cache() {
        return 0;
    }
    precision.elem_bytes() * (d.f_in * d.f_out) as u64 * d.n_tilde_elems()
}

/// Memory of a max-pooling layer: input + output (n/p³ per image).
pub fn pool_memory_bytes(s: usize, f: usize, n: Vec3, p: Vec3) -> u64 {
    let inp = (s * f * n[0] * n[1] * n[2]) as u64;
    let out = (s * f * (n[0] / p[0]) * (n[1] / p[1]) * (n[2] / p[2])) as u64;
    B * (inp + out)
}

/// Memory of an MPF layer: input + p³ fragments of ⌊n/p⌋³ each.
pub fn mpf_memory_bytes(s: usize, f: usize, n: Vec3, p: Vec3) -> u64 {
    let inp = (s * f * n[0] * n[1] * n[2]) as u64;
    let frag = (n[0] / p[0]) * (n[1] / p[1]) * (n[2] / p[2]);
    let out = (s * f * p[0] * p[1] * p[2] * frag) as u64;
    B * (inp + out)
}

/// Serving-side Table II footprint of one whole-volume request: the
/// dense input plus the dense sliding-window output, both f32. The
/// serving frontend's micro-batcher admits requests against this — the
/// same analytic model the optimizer ranks plans with — so admission
/// and plan search never disagree about what fits. The output dims come
/// from [`crate::inference::dense_output_shape`] — the function the
/// coordinator allocates outputs with — so sizing and allocation share
/// one law; a volume smaller than the FoV simply has no output term.
pub fn request_memory_bytes(f_in: usize, f_out: usize, vdims: Vec3, fov: Vec3) -> u64 {
    use crate::tensor::Shape5;
    let inp = (f_in * vdims[0] * vdims[1] * vdims[2]) as u64;
    let out = if (0..3).all(|d| vdims[d] >= fov[d]) {
        let osh =
            crate::inference::dense_output_shape(Shape5::from_spatial(1, f_in, vdims), fov, f_out);
        (osh.f * osh.x * osh.y * osh.z) as u64
    } else {
        0
    };
    B * (inp + out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ConvDims {
        ConvDims { s: 2, f_in: 4, f_out: 8, n: [16, 16, 16], k: [3, 3, 3] }
    }

    #[test]
    fn direct_is_cheapest_memory() {
        let d = dims();
        let naive = conv_memory_bytes(ConvAlgo::DirectNaive, &d, 4);
        for a in [
            ConvAlgo::DirectMkl,
            ConvAlgo::DirectFused,
            ConvAlgo::FftDataParallel,
            ConvAlgo::FftTaskParallel,
        ] {
            assert!(conv_memory_bytes(a, &d, 4) >= naive, "{a:?}");
        }
    }

    #[test]
    fn fused_tiles_are_smaller_than_mkl_temporaries() {
        // The fused family's per-thread scratch is two rows, not a whole
        // result image — it must sit strictly between naive and MKL.
        let d = dims();
        let naive = conv_memory_bytes(ConvAlgo::DirectNaive, &d, 8);
        let fused = conv_memory_bytes(ConvAlgo::DirectFused, &d, 8);
        let mkl = conv_memory_bytes(ConvAlgo::DirectMkl, &d, 8);
        assert!(fused > naive);
        assert!(fused < mkl);
        assert_eq!(fused - naive, 8 * B * 2 * d.out_n()[2] as u64);
    }

    #[test]
    fn fused_pool_row_drops_the_inter_layer_tensor() {
        // Unfused CP pair peak: the conv's own row already holds the
        // full S·f'·n' pre-pool tensor. The fused row replaces it with
        // the pooled output plus per-thread tiles and must be smaller.
        let d = dims();
        let p = [2, 2, 2];
        let unfused = conv_memory_bytes(ConvAlgo::DirectFused, &d, 4);
        let fused = conv_pool_fused_memory_bytes(&d, p, 4);
        assert!(fused < unfused, "fused {fused} vs unfused {unfused}");
        // The delta is dominated by the eliminated (1 - 1/p³) share of
        // the inter-layer tensor.
        let tensor_share = B * (d.s * d.f_out) as u64 * (d.n_out_elems() - d.n_out_elems() / 8);
        assert!(unfused - fused > tensor_share / 2);
    }

    #[test]
    fn fused_pool_tiles_scale_with_threads() {
        let d = dims();
        let p = [2, 2, 2];
        let m1 = conv_pool_fused_memory_bytes(&d, p, 1);
        let m8 = conv_pool_fused_memory_bytes(&d, p, 8);
        let o = d.out_n();
        assert_eq!(m8 - m1, 7 * B * 2 * (p[0] * o[1] * o[2] + o[2]) as u64);
    }

    #[test]
    fn precomp_needs_more_than_default() {
        let d = dims();
        assert!(
            conv_memory_bytes(ConvAlgo::GpuDensePrecomp, &d, 1)
                > conv_memory_bytes(ConvAlgo::GpuDenseNoWorkspace, &d, 1)
        );
    }

    #[test]
    fn mkl_adds_thread_temporaries() {
        let d = dims();
        let m1 = conv_memory_bytes(ConvAlgo::DirectMkl, &d, 1);
        let m8 = conv_memory_bytes(ConvAlgo::DirectMkl, &d, 8);
        assert_eq!(m8 - m1, 7 * 4 * d.n_out_elems());
    }

    #[test]
    fn out_shape_table1() {
        let d = dims();
        assert_eq!(d.out_n(), [14, 14, 14]);
    }

    #[test]
    fn flops_scale_with_batch() {
        let mut d = dims();
        let f1 = d.direct_flops();
        d.s = 4;
        assert!((d.direct_flops() / f1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn pool_and_mpf_memory() {
        // MPF keeps ~all voxels: p³ fragments of n/p³ each.
        let pm = pool_memory_bytes(1, 2, [8, 8, 8], [2, 2, 2]);
        let mm = mpf_memory_bytes(1, 2, [8, 8, 8], [2, 2, 2]);
        assert_eq!(pm, 4 * (2 * 512 + 2 * 64));
        assert_eq!(mm, 4 * (2 * 512 + 2 * 512));
    }

    #[test]
    fn request_memory_counts_input_and_dense_output() {
        // 1-channel 10³ input, FoV 3³ → 2-channel 8³ output.
        let b = request_memory_bytes(1, 2, [10, 10, 10], [3, 3, 3]);
        assert_eq!(b, 4 * (1000 + 2 * 512));
        // A volume smaller than the FoV has no valid output placement.
        assert_eq!(request_memory_bytes(1, 2, [2, 2, 2], [3, 3, 3]), 4 * 8);
    }

    #[test]
    fn kernel_spectra_row_counts_all_kernels() {
        let d = ConvDims { s: 1, f_in: 3, f_out: 5, n: [8, 8, 8], k: [3, 3, 3] };
        // 3·5 kernels × ñ = 640 float-equivalents × 4 bytes.
        assert_eq!(kernel_spectra_bytes(ConvAlgo::FftTaskParallel, &d), 15 * 640 * 4);
        assert_eq!(
            kernel_spectra_bytes(ConvAlgo::FftDataParallel, &d),
            kernel_spectra_bytes(ConvAlgo::GpuFft, &d)
        );
        // Direct algorithms have no spectra to cache.
        assert_eq!(kernel_spectra_bytes(ConvAlgo::DirectMkl, &d), 0);
        assert_eq!(kernel_spectra_bytes(ConvAlgo::GpuDensePrecomp, &d), 0);
    }

    #[test]
    fn half_precision_spectra_row_exactly_halves() {
        use crate::precision::Precision;
        let d = ConvDims { s: 1, f_in: 3, f_out: 5, n: [8, 8, 8], k: [3, 3, 3] };
        for algo in [ConvAlgo::FftDataParallel, ConvAlgo::FftTaskParallel, ConvAlgo::GpuFft] {
            let full = kernel_spectra_bytes_p(algo, &d, Precision::F32);
            assert_eq!(full, kernel_spectra_bytes(algo, &d), "f32 delegates");
            for p in Precision::HALF {
                assert_eq!(kernel_spectra_bytes_p(algo, &d, p) * 2, full, "{algo:?} {}", p.name());
            }
        }
        // Algorithms without spectra stay at zero at any precision.
        assert_eq!(kernel_spectra_bytes_p(ConvAlgo::DirectMkl, &d, Precision::F16), 0);
    }

    #[test]
    fn fft_kernel_flops_is_the_cacheable_share() {
        let d = dims();
        let kf = d.fft_kernel_flops();
        assert!(kf > 0.0);
        assert!(kf < d.fft_flops(), "kernel transforms are a strict share of the total");
    }

    #[test]
    fn n_tilde_counts_float_equivalents() {
        let d = ConvDims { s: 1, f_in: 1, f_out: 1, n: [8, 8, 8], k: [3, 3, 3] };
        // padded 8×8×8 → complex 8·8·5 → 2·320 float equivalents
        assert_eq!(d.n_tilde_elems(), 640);
    }
}
