//! Convolutional-layer primitives (§IV).
//!
//! Three CPU algorithms — direct (Algorithm 1), data-parallel FFT
//! (Algorithm 2), task-parallel FFT (§IV.A.3) — and the GPU-scheme
//! FFT algorithm (Algorithm 3) plus dense stand-ins for the cuDNN
//! primitives. All compute *true* convolution (kernel flipped), a
//! "valid"-region output of extent `n − k + 1`, matching Table I.

pub mod direct;
pub mod direct_fused;
pub mod fft_dp;
pub mod fft_gpu;
pub mod fft_tp;
pub mod precomp;

use crate::tensor::{Shape5, Tensor5, Vec3};
use crate::util::prng::Rng;

/// Post-convolution transfer function. Applied by the output stage of
/// every primitive (the paper applies ReLU after each conv layer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    /// Identity.
    None,
    /// max(0, x) - the paper's transfer function.
    Relu,
}

impl Activation {
    #[inline]
    /// Apply to one value.
    pub fn apply(&self, v: f32) -> f32 {
        match self {
            Activation::None => v,
            Activation::Relu => v.max(0.0),
        }
    }
}

/// Weights of one convolutional layer: `f' × f` kernels of extent `k`
/// plus one bias per output map.
pub struct Weights {
    /// Output maps (f').
    pub f_out: usize,
    /// Input maps (f).
    pub f_in: usize,
    /// Kernel extent per dimension.
    pub k: Vec3,
    data: Vec<f32>,
    bias: Vec<f32>,
}

impl Weights {
    /// All-zero weights of the given geometry.
    pub fn zeros(f_out: usize, f_in: usize, k: Vec3) -> Self {
        Weights {
            f_out,
            f_in,
            k,
            data: vec![0.0; f_out * f_in * k[0] * k[1] * k[2]],
            bias: vec![0.0; f_out],
        }
    }

    /// Deterministic random init scaled ~1/√(fan-in), so deep nets keep
    /// activations O(1) in tests and benches.
    pub fn random(f_out: usize, f_in: usize, k: Vec3, seed: u64) -> Self {
        let mut w = Self::zeros(f_out, f_in, k);
        let mut rng = Rng::new(seed);
        let scale = 1.0 / ((f_in * k[0] * k[1] * k[2]) as f32).sqrt();
        for v in w.data.iter_mut() {
            *v = rng.f32_range(-1.0, 1.0) * scale;
        }
        for b in w.bias.iter_mut() {
            *b = rng.f32_range(-0.1, 0.1);
        }
        w
    }

    /// Elements in one kernel (k^3).
    pub fn klen(&self) -> usize {
        self.k[0] * self.k[1] * self.k[2]
    }

    /// Kernel w[j][i] (output j ← input i) as a contiguous slice.
    pub fn kernel(&self, j: usize, i: usize) -> &[f32] {
        let o = (j * self.f_in + i) * self.klen();
        &self.data[o..o + self.klen()]
    }

    /// Mutable kernel w[j][i].
    pub fn kernel_mut(&mut self, j: usize, i: usize) -> &mut [f32] {
        let l = self.klen();
        let o = (j * self.f_in + i) * l;
        &mut self.data[o..o + l]
    }

    /// Bias of output map j.
    pub fn bias(&self, j: usize) -> f32 {
        self.bias[j]
    }

    /// Set the bias of output map j.
    pub fn set_bias(&mut self, j: usize, b: f32) {
        self.bias[j] = b;
    }

    /// All kernels flat (f'·f·k³), e.g. for handing to the PJRT runtime.
    pub fn raw(&self) -> &[f32] {
        &self.data
    }

    /// All biases, flat (f').
    pub fn raw_bias(&self) -> &[f32] {
        &self.bias
    }

    /// Restrict to a sub-range of output and input maps (the sub-layer
    /// decomposition of §VII.A needs weight windows).
    pub fn window(&self, j0: usize, jn: usize, i0: usize, in_: usize) -> Weights {
        let mut w = Weights::zeros(jn, in_, self.k);
        for j in 0..jn {
            for i in 0..in_ {
                w.kernel_mut(j, i).copy_from_slice(self.kernel(j0 + j, i0 + i));
            }
            w.bias[j] = self.bias[j0 + j];
        }
        w
    }
}

/// Output shape of a valid convolution (Table I row 1).
pub fn conv_out_shape(input: Shape5, f_out: usize, k: Vec3) -> Shape5 {
    assert!(input.x >= k[0] && input.y >= k[1] && input.z >= k[2], "kernel larger than image");
    Shape5 {
        s: input.s,
        f: f_out,
        x: input.x - k[0] + 1,
        y: input.y - k[1] + 1,
        z: input.z - k[2] + 1,
    }
}

/// Single-image valid **convolution** (flipped kernel), accumulating
/// into `out`. For each kernel tap the z-contiguous run of the input is
/// multiply-added into the output row through the SIMD kernel layer
/// ([`crate::simd::axpy`]) — the paper's "MKL" inner-loop shape. Used by
/// both direct primitives.
pub fn convolve_valid_accumulate(
    img: &[f32],
    n: Vec3,
    ker: &[f32],
    k: Vec3,
    out: &mut [f32],
) {
    let on = [n[0] - k[0] + 1, n[1] - k[1] + 1, n[2] - k[2] + 1];
    debug_assert_eq!(out.len(), on[0] * on[1] * on[2]);
    convolve_valid_accumulate_rows(img, n, ker, k, out, 0, on[0]);
}

/// [`convolve_valid_accumulate`] restricted to output x-rows
/// `[x0, x1)`. `out` covers exactly those rows (`(x1−x0)·n'_y·n'_z`
/// elements); the full input image is still read, since row `x` of the
/// output needs input rows `x..x+k`. This is the slab entry point the
/// direct primitives use to split one image across workers when
/// `S·f' <` the pool size.
pub fn convolve_valid_accumulate_rows(
    img: &[f32],
    n: Vec3,
    ker: &[f32],
    k: Vec3,
    out: &mut [f32],
    x0: usize,
    x1: usize,
) {
    let on = [n[0] - k[0] + 1, n[1] - k[1] + 1, n[2] - k[2] + 1];
    debug_assert_eq!(img.len(), n[0] * n[1] * n[2]);
    debug_assert_eq!(ker.len(), k[0] * k[1] * k[2]);
    debug_assert!(x0 <= x1 && x1 <= on[0]);
    debug_assert_eq!(out.len(), (x1 - x0) * on[1] * on[2]);
    // Resolve the dispatch tier once per image, not once per tap.
    let tier = crate::simd::active();
    for x in x0..x1 {
        for y in 0..on[1] {
            let ob = ((x - x0) * on[1] + y) * on[2];
            let orow = &mut out[ob..ob + on[2]];
            for a in 0..k[0] {
                for b in 0..k[1] {
                    let irow_base = ((x + a) * n[1] + (y + b)) * n[2];
                    for c in 0..k[2] {
                        let kv =
                            ker[((k[0] - 1 - a) * k[1] + (k[1] - 1 - b)) * k[2] + (k[2] - 1 - c)];
                        if kv == 0.0 {
                            continue;
                        }
                        crate::simd::axpy_tier(
                            tier,
                            orow,
                            &img[irow_base + c..irow_base + c + on[2]],
                            kv,
                        );
                    }
                }
            }
        }
    }
}

/// Scalar six-loop reference convolution (flipped kernel), accumulating
/// into `out`. O(n³k³), no SIMD, no reassociation — this is the oracle
/// every vectorised primitive is property-tested against.
pub fn convolve_valid_accumulate_scalar(
    img: &[f32],
    n: Vec3,
    ker: &[f32],
    k: Vec3,
    out: &mut [f32],
) {
    let on = [n[0] - k[0] + 1, n[1] - k[1] + 1, n[2] - k[2] + 1];
    debug_assert_eq!(img.len(), n[0] * n[1] * n[2]);
    debug_assert_eq!(ker.len(), k[0] * k[1] * k[2]);
    debug_assert_eq!(out.len(), on[0] * on[1] * on[2]);
    for x in 0..on[0] {
        for y in 0..on[1] {
            for z in 0..on[2] {
                let mut acc = 0.0f32;
                for a in 0..k[0] {
                    for b in 0..k[1] {
                        for c in 0..k[2] {
                            let iv = img[((x + a) * n[1] + (y + b)) * n[2] + (z + c)];
                            let kv = ker[((k[0] - 1 - a) * k[1] + (k[1] - 1 - b)) * k[2]
                                + (k[2] - 1 - c)];
                            acc += iv * kv;
                        }
                    }
                }
                out[(x * on[1] + y) * on[2] + z] += acc;
            }
        }
    }
}

/// Single-threaded reference convolutional layer (oracle for every
/// primitive): `O[s,j] = act(Σ_i w[j,i] * I[s,i] + bias[j])`. Built on
/// the scalar inner loop so it stays independent of the SIMD dispatch
/// it is used to validate.
pub fn conv_layer_reference(input: &Tensor5, w: &Weights, act: Activation) -> Tensor5 {
    let ish = input.shape();
    assert_eq!(ish.f, w.f_in);
    let osh = conv_out_shape(ish, w.f_out, w.k);
    let mut out = Tensor5::zeros(osh);
    for s in 0..ish.s {
        for j in 0..w.f_out {
            for i in 0..w.f_in {
                convolve_valid_accumulate_scalar(
                    input.image(s, i),
                    ish.spatial(),
                    w.kernel(j, i),
                    w.k,
                    out.image_mut(s, j),
                );
            }
            let b = w.bias(j);
            for v in out.image_mut(s, j).iter_mut() {
                *v = act.apply(*v + b);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_shape_valid() {
        let sh = conv_out_shape(Shape5::new(1, 2, 8, 9, 10), 4, [3, 3, 3]);
        assert_eq!(sh, Shape5::new(1, 4, 6, 7, 8));
    }

    #[test]
    #[should_panic(expected = "kernel larger")]
    fn out_shape_rejects_small_image() {
        conv_out_shape(Shape5::new(1, 1, 2, 2, 2), 1, [3, 3, 3]);
    }

    #[test]
    fn identity_kernel_convolution() {
        // 1³ kernel of value 1 must reproduce the image.
        let img = Tensor5::random(Shape5::new(1, 1, 4, 4, 4), 3);
        let mut w = Weights::zeros(1, 1, [1, 1, 1]);
        w.kernel_mut(0, 0)[0] = 1.0;
        let out = conv_layer_reference(&img, &w, Activation::None);
        assert_eq!(out.data(), img.data());
    }

    #[test]
    fn shift_kernel_is_true_convolution() {
        // Kernel with a single 1 at position (0,0,0) of a 2³ kernel:
        // true convolution flips it → output[x] = img[x + k - 1 - 0].
        let img = Tensor5::random(Shape5::new(1, 1, 3, 3, 3), 5);
        let mut w = Weights::zeros(1, 1, [2, 2, 2]);
        w.kernel_mut(0, 0)[0] = 1.0; // kernel[0,0,0]
        let out = conv_layer_reference(&img, &w, Activation::None);
        // valid conv output (2³): out[x,y,z] = img[x+1, y+1, z+1]
        for x in 0..2 {
            for y in 0..2 {
                for z in 0..2 {
                    assert_eq!(out.at(0, 0, x, y, z), img.at(0, 0, x + 1, y + 1, z + 1));
                }
            }
        }
    }

    #[test]
    fn bias_and_relu_applied() {
        let img = Tensor5::from_vec(Shape5::new(1, 1, 1, 1, 1), vec![-5.0]);
        let mut w = Weights::zeros(1, 1, [1, 1, 1]);
        w.kernel_mut(0, 0)[0] = 1.0;
        w.set_bias(0, 2.0);
        let out = conv_layer_reference(&img, &w, Activation::Relu);
        assert_eq!(out.data(), &[0.0]); // relu(-5 + 2) = 0
        let out = conv_layer_reference(&img, &w, Activation::None);
        assert_eq!(out.data(), &[-3.0]);
    }

    #[test]
    fn weights_window_extracts() {
        let w = Weights::random(4, 3, [2, 2, 2], 9);
        let sub = w.window(1, 2, 1, 2);
        assert_eq!(sub.kernel(0, 0), w.kernel(1, 1));
        assert_eq!(sub.kernel(1, 1), w.kernel(2, 2));
        assert_eq!(sub.bias(0), w.bias(1));
    }

    #[test]
    fn multi_channel_accumulates() {
        // Two input channels with 1³ unit kernels sum the channels.
        let mut img = Tensor5::zeros(Shape5::new(1, 2, 2, 2, 2));
        img.set(0, 0, 0, 0, 0, 3.0);
        img.set(0, 1, 0, 0, 0, 4.0);
        let mut w = Weights::zeros(1, 2, [1, 1, 1]);
        w.kernel_mut(0, 0)[0] = 1.0;
        w.kernel_mut(0, 1)[0] = 1.0;
        let out = conv_layer_reference(&img, &w, Activation::None);
        assert_eq!(out.at(0, 0, 0, 0, 0), 7.0);
    }
}
