//! Precomputed kernel spectra — amortize weight FFTs across every
//! patch, request, and shard.
//!
//! At inference the weights never change, yet the FFT-conv primitives
//! historically re-transformed every kernel `w(j,i)` on every `execute`
//! call — per output map per patch in `fft_dp`, per kernel wave in
//! `fft_tp`, per output map in `fft_gpu`. The training-oriented ZNN
//! ancestor (Zlateski et al. 2015) had to pay that cost because weights
//! update every iteration; inference does not, and inference-specialized
//! systems like PZnet (Popovych et al. 2019) eliminate it by
//! compile-time specialization.
//!
//! [`PrecomputedKernels`] is that specialization as a *planned, budgeted*
//! memory row: all `f'·f` kernel spectra of one layer, transformed once
//! (keyed by the plan's padded FFT shape) and shared through an `Arc`
//! across coordinator workers and server shards. Spectra cost
//! `f'·f·complex_len` complex words of RAM — exactly the paper's central
//! currency — so whether a layer caches is a decision the optimizer
//! searches ([`crate::optimizer::search`] weighs the spectra row against
//! spending the same bytes on a larger input image; see
//! [`crate::memory::model::kernel_spectra_bytes`]). The bytes are
//! registered with the process ledger and the
//! [`crate::memory::kernel_cache_bytes`] gauge, never drawn from the
//! execution arena: the cache outlives every [`crate::exec::ExecCtx`]
//! that consumes it.
//!
//! Bit-identity contract: the cache builder runs the *same* transform
//! code path the on-the-fly fallback uses (`Fft3::forward` line
//! transforms for the CPU primitives — `forward` and `forward_par` pair
//! lines identically — and `BatchedFft3::forward_scratch` for the GPU
//! scheme, which is deterministic per element regardless of the pool),
//! so cached and recomputed executions produce identical outputs down to
//! the last bit under any fixed SIMD tier.
//!
//! Reduced-precision tier: a cache built with a half-width
//! [`Precision`] ([`PrecomputedKernels::build_p`]) stores the *same*
//! f32 spectra narrowed to f16/bf16 bit patterns — half the resident
//! bytes, exactly — and the consuming primitives widen them back to f32
//! through arena scratch ([`PrecomputedKernels::widen_spectrum_into`] /
//! [`PrecomputedKernels::widen_batch_into`]). Narrowing is
//! round-to-nearest-even (relative error ≤ 2⁻¹¹ for f16, ≤ 2⁻⁸ for
//! bf16, per element) and widening is exact, so a half cache is still
//! fully deterministic: every execute consumes the same widened
//! spectra bit for bit.
//!
//! The `ZNNI_KERNEL_CACHE` environment variable (`off | auto | on`,
//! read once) gates the whole subsystem; [`force_cache_mode`] overrides
//! it programmatically for tests and benches (`ZNNI_PRECISION` gates
//! the storage precision the same way — see [`crate::precision`]).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, OnceLock};

use crate::fft::fft3d::Fft3Scratch;
use crate::memory;
use crate::memory::model::ConvAlgo;
use crate::precision::Precision;
use crate::tensor::{Complex32, Vec3};
use crate::util::pool::TaskPool;
use crate::util::sendptr::SendPtr;

use super::Weights;

/// Which spectrum layout a cache holds — the two FFT plan families store
/// transformed kernels differently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpectraLayout {
    /// [`crate::fft::Fft3`] layout (`[x][y][zc]`, one spectrum per
    /// kernel) — consumed by `fft_dp` and `fft_tp`.
    Cpu,
    /// [`crate::fft::batched::BatchedFft3`] transformed representation
    /// (`[zc][y'][x']`, one batch of `f` spectra per output map) —
    /// consumed by `fft_gpu`'s PARALLEL-MULT.
    Gpu,
}

impl SpectraLayout {
    /// The layout the given algorithm consumes, or `None` if the
    /// algorithm performs no kernel transforms (direct / dense conv).
    pub fn for_algo(algo: ConvAlgo) -> Option<SpectraLayout> {
        match algo {
            ConvAlgo::FftDataParallel | ConvAlgo::FftTaskParallel => Some(SpectraLayout::Cpu),
            ConvAlgo::GpuFft => Some(SpectraLayout::Gpu),
            _ => None,
        }
    }
}

/// All `f'·f` kernel spectra of one convolutional layer, transformed
/// once for a fixed padded FFT shape. Immutable after construction, so
/// one `Arc<PrecomputedKernels>` is safely shared by every worker of
/// every shard.
pub struct PrecomputedKernels {
    layout: SpectraLayout,
    padded: Vec3,
    f_out: usize,
    f_in: usize,
    /// Complex elements per kernel spectrum (both layouts:
    /// `x̃·ỹ·(z̃/2+1)`).
    spec_len: usize,
    /// f32 spectra ([`Precision::F32`] caches only; empty otherwise).
    data: Vec<Complex32>,
    /// Narrowed spectra as interleaved `[re, im]` storage bits
    /// (half-precision caches only; empty otherwise).
    half: Vec<u16>,
    precision: Precision,
    bytes: u64,
}

/// View a complex slice as interleaved `[re, im]` floats — sound
/// because [`Complex32`] is `#[repr(C)]` with two f32 fields (the same
/// reinterpretation the FFT I/O paths rely on).
fn complex_floats(src: &[Complex32]) -> &[f32] {
    unsafe { std::slice::from_raw_parts(src.as_ptr() as *const f32, src.len() * 2) }
}

fn complex_floats_mut(dst: &mut [Complex32]) -> &mut [f32] {
    unsafe { std::slice::from_raw_parts_mut(dst.as_mut_ptr() as *mut f32, dst.len() * 2) }
}

impl PrecomputedKernels {
    /// Transform every kernel of `w` for FFTs padded to `padded`.
    ///
    /// CPU layout: each kernel is forward-transformed with the shared
    /// [`crate::exec::fft3_plan`] (the same plan — hence the same
    /// twiddle tables and line pairing — the on-the-fly paths use),
    /// fanned out over the pool. GPU layout: each output map's kernel
    /// batch goes through the shared kernel-pruned
    /// [`crate::exec::batched_fft3_plan`], exactly as `fft_gpu` stage 2
    /// would. The spectra bytes are registered with the ledger and the
    /// [`crate::memory::kernel_cache_bytes`] gauge until drop.
    pub fn build(w: &Weights, layout: SpectraLayout, padded: Vec3, pool: &TaskPool) -> Self {
        Self::build_p(w, layout, padded, pool, Precision::F32)
    }

    /// [`PrecomputedKernels::build`] with an explicit storage
    /// [`Precision`]. A half-width precision transforms in f32 (the
    /// identical code path), then narrows the spectra to f16/bf16 bits
    /// — exactly half the resident bytes, with the ledger and
    /// [`crate::memory::kernel_cache_bytes`] gauge adjusted to the
    /// stored width.
    pub fn build_p(
        w: &Weights,
        layout: SpectraLayout,
        padded: Vec3,
        pool: &TaskPool,
        precision: Precision,
    ) -> Self {
        let full = match layout {
            SpectraLayout::Cpu => Self::build_cpu(w, padded, pool),
            SpectraLayout::Gpu => Self::build_gpu(w, padded, pool),
        };
        full.narrowed(precision)
    }

    /// Narrow a freshly built f32 cache to half-width storage bits,
    /// returning the ledger delta to the stored width. No-op for
    /// [`Precision::F32`].
    fn narrowed(mut self, precision: Precision) -> Self {
        if !precision.is_half() {
            return self;
        }
        let floats = complex_floats(&self.data);
        let mut half = vec![0u16; floats.len()];
        precision.narrow(&mut half, floats);
        let new_bytes = (half.len() * std::mem::size_of::<u16>()) as u64;
        let freed = self.bytes - new_bytes;
        memory::free(freed);
        memory::kernel_cache_gauge(-(freed as i64));
        self.bytes = new_bytes;
        self.data = Vec::new();
        self.half = half;
        self.precision = precision;
        self
    }

    fn register(spec_len: usize, f_out: usize, f_in: usize) -> (Vec<Complex32>, u64) {
        let elems = f_out * f_in * spec_len;
        let bytes = (elems * std::mem::size_of::<Complex32>()) as u64;
        memory::alloc(bytes);
        memory::kernel_cache_gauge(bytes as i64);
        (vec![Complex32::ZERO; elems], bytes)
    }

    fn build_cpu(w: &Weights, padded: Vec3, pool: &TaskPool) -> Self {
        let plan = crate::exec::fft3_plan(padded);
        let spec_len = plan.complex_len();
        let (mut data, bytes) = Self::register(spec_len, w.f_out, w.f_in);
        {
            let dp = SendPtr(data.as_mut_ptr());
            let plan = &*plan;
            pool.scope(|sc| {
                for j in 0..w.f_out {
                    for i in 0..w.f_in {
                        let off = (j * w.f_in + i) * spec_len;
                        sc.submit(move |_| {
                            let dst = unsafe { dp.slice_mut(off, spec_len) };
                            let mut tls = Fft3Scratch::new();
                            plan.forward(w.kernel(j, i), w.k, dst, &mut tls);
                        });
                    }
                }
            });
        }
        PrecomputedKernels {
            layout: SpectraLayout::Cpu,
            padded,
            f_out: w.f_out,
            f_in: w.f_in,
            spec_len,
            data,
            half: Vec::new(),
            precision: Precision::F32,
            bytes,
        }
    }

    fn build_gpu(w: &Weights, padded: Vec3, pool: &TaskPool) -> Self {
        let plan_ker = crate::exec::batched_fft3_plan(w.k, padded);
        let spec = plan_ker.spectrum_len();
        let (mut data, bytes) = Self::register(spec, w.f_out, w.f_in);
        // One-off build scratches (not arena buffers: this runs at plan
        // build time, not on the hot path).
        let mut s1 = vec![Complex32::ZERO; plan_ker.forward_scratch1_len(w.f_in)];
        let mut s2 = vec![Complex32::ZERO; plan_ker.forward_scratch2_len(w.f_in)];
        let klen = w.klen();
        for j in 0..w.f_out {
            let kbatch = &w.raw()[j * w.f_in * klen..(j + 1) * w.f_in * klen];
            let out = &mut data[j * w.f_in * spec..(j + 1) * w.f_in * spec];
            plan_ker.forward_scratch(w.f_in, kbatch, out, &mut s1, &mut s2, pool);
        }
        PrecomputedKernels {
            layout: SpectraLayout::Gpu,
            padded,
            f_out: w.f_out,
            f_in: w.f_in,
            spec_len: spec,
            data,
            half: Vec::new(),
            precision: Precision::F32,
            bytes,
        }
    }

    /// Whether this cache serves the given layout, padded FFT shape and
    /// layer geometry. A primitive executed at a shape other than the
    /// one the cache was built for falls back to on-the-fly transforms.
    pub fn matches(&self, layout: SpectraLayout, padded: Vec3, f_out: usize, f_in: usize) -> bool {
        self.layout == layout && self.padded == padded && self.f_out == f_out && self.f_in == f_in
    }

    /// The spectrum of kernel `w(j, i)` (CPU layout, f32 caches only —
    /// half caches are consumed via
    /// [`PrecomputedKernels::widen_spectrum_into`]).
    pub fn spectrum(&self, j: usize, i: usize) -> &[Complex32] {
        debug_assert_eq!(self.layout, SpectraLayout::Cpu);
        debug_assert_eq!(self.precision, Precision::F32);
        let off = (j * self.f_in + i) * self.spec_len;
        &self.data[off..off + self.spec_len]
    }

    /// The batched spectra of all `f` kernels of output map `j` (GPU
    /// layout, f32 caches only) — the `w̃` slab `fft_gpu`'s
    /// PARALLEL-MULT consumes.
    pub fn batch(&self, j: usize) -> &[Complex32] {
        debug_assert_eq!(self.layout, SpectraLayout::Gpu);
        debug_assert_eq!(self.precision, Precision::F32);
        let off = j * self.f_in * self.spec_len;
        &self.data[off..off + self.f_in * self.spec_len]
    }

    /// Widen the spectrum of kernel `w(j, i)` into `dst` (CPU layout,
    /// half caches only). Widening is exact, so `dst` receives the
    /// narrowed value of the f32 spectrum this cache was built from —
    /// the same bits on every call.
    pub fn widen_spectrum_into(&self, j: usize, i: usize, dst: &mut [Complex32]) {
        debug_assert_eq!(self.layout, SpectraLayout::Cpu);
        assert!(self.precision.is_half(), "f32 caches are consumed via spectrum()");
        assert_eq!(dst.len(), self.spec_len);
        let off = (j * self.f_in + i) * 2 * self.spec_len;
        self.precision.widen(complex_floats_mut(dst), &self.half[off..off + 2 * self.spec_len]);
    }

    /// Widen the batched spectra of output map `j` into `dst` (GPU
    /// layout, half caches only).
    pub fn widen_batch_into(&self, j: usize, dst: &mut [Complex32]) {
        debug_assert_eq!(self.layout, SpectraLayout::Gpu);
        assert!(self.precision.is_half(), "f32 caches are consumed via batch()");
        let n = self.f_in * self.spec_len;
        assert_eq!(dst.len(), n);
        let off = j * 2 * n;
        self.precision.widen(complex_floats_mut(dst), &self.half[off..off + 2 * n]);
    }

    /// Storage precision of the spectra. Compute always stays f32:
    /// half-width caches are widened into arena scratch at consume
    /// time.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Complex elements per kernel spectrum (what a widen destination
    /// for one [`PrecomputedKernels::widen_spectrum_into`] call holds).
    pub fn spec_len(&self) -> usize {
        self.spec_len
    }

    /// Resident bytes of this cache (what the optimizer budgeted) — the
    /// *stored* width, so a half cache reports exactly half its f32
    /// twin.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Padded FFT shape the spectra were transformed at.
    pub fn padded(&self) -> Vec3 {
        self.padded
    }

    /// The layout this cache stores.
    pub fn layout(&self) -> SpectraLayout {
        self.layout
    }
}

impl Drop for PrecomputedKernels {
    fn drop(&mut self) {
        memory::free(self.bytes);
        memory::kernel_cache_gauge(-(self.bytes as i64));
    }
}

/// A small per-padded-shape map of kernel spectra for one layer.
///
/// One layer served under mixed patch sizes (several tenants, or one
/// tenant whose optimizer picked different extents per device) sees a
/// different padded FFT shape per shape class — a single
/// [`PrecomputedKernels`] keyed to one shape forces every other shape
/// back to on-the-fly transforms. The map holds one cache per distinct
/// `(layout, padded)` key so *every* shape class a layer serves hits
/// precomputed spectra after its first warm.
///
/// The population is tiny (one entry per distinct patch shape routed
/// through the layer — in practice one per tenant), so lookups are a
/// linear scan over [`PrecomputedKernels::matches`]. Eviction under
/// memory pressure is largest-first via [`SpectraMap::evict_largest`],
/// mirroring the server's shed policy across layers.
#[derive(Default)]
pub struct SpectraMap {
    entries: Vec<Arc<PrecomputedKernels>>,
}

impl SpectraMap {
    /// An empty map.
    pub fn new() -> Self {
        SpectraMap { entries: Vec::new() }
    }

    /// The cache serving `(layout, padded, precision)` for a
    /// `f_out × f_in` layer, if one has been built. Precision is part
    /// of the key: an f32 entry does not satisfy a layer planned at
    /// f16 (and vice versa), so mixed-precision plans sharing one map
    /// each hit spectra of their own width.
    pub fn get(
        &self,
        layout: SpectraLayout,
        padded: Vec3,
        f_out: usize,
        f_in: usize,
        precision: Precision,
    ) -> Option<Arc<PrecomputedKernels>> {
        self.entries
            .iter()
            .find(|c| c.matches(layout, padded, f_out, f_in) && c.precision() == precision)
            .cloned()
    }

    /// Insert a freshly built cache. The caller is expected to have
    /// checked [`SpectraMap::get`] first; a duplicate key (same shape
    /// *and* precision) is replaced rather than doubled.
    pub fn insert(&mut self, cache: Arc<PrecomputedKernels>) {
        self.entries.retain(|c| {
            !(c.matches(cache.layout(), cache.padded(), cache.f_out, cache.f_in)
                && c.precision() == cache.precision())
        });
        self.entries.push(cache);
    }

    /// Total resident bytes across every cached shape — what the layer
    /// reports into `kernel_cache_bytes` accounting.
    pub fn bytes(&self) -> u64 {
        self.entries.iter().map(|c| c.bytes()).sum()
    }

    /// Number of distinct cached shapes.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether no shape is cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Drop the largest cached shape and return its bytes (0 if empty).
    /// Under memory pressure the server sheds one shape at a time,
    /// largest-first, so lightly-used big-patch spectra go before small
    /// hot ones.
    pub fn evict_largest(&mut self) -> u64 {
        let idx = self.entries.iter().enumerate().max_by_key(|(_, c)| c.bytes()).map(|(i, _)| i);
        match idx {
            Some(i) => self.entries.swap_remove(i).bytes(),
            None => 0,
        }
    }

    /// Drop every cached shape, returning the bytes released.
    pub fn clear(&mut self) -> u64 {
        let freed = self.bytes();
        self.entries.clear();
        freed
    }
}

/// Whether the kernel-spectra cache may be used, and who decides.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum CacheMode {
    /// Never cache — every execute re-transforms kernels (the pre-cache
    /// behaviour; also the runtime kill switch).
    Off = 1,
    /// The cost model decides per layer under the memory budget (the
    /// default). With the analytic model a cached layer is always at
    /// least as fast as recomputation, so today `auto` caches exactly
    /// like [`CacheMode::Force`] wherever the budget admits — the modes
    /// differ in *contract*, not (currently) in outcome: `auto` defers
    /// to whatever the model says, and would stop caching if a future
    /// measured model ever charged the cache more than it saves.
    Auto = 2,
    /// Cache every FFT layer the memory budget admits, unconditionally
    /// — a pledge independent of the cost model (the recompute
    /// candidate is not even considered).
    Force = 3,
}

impl CacheMode {
    /// Parse a `ZNNI_KERNEL_CACHE` value.
    pub fn parse(s: &str) -> Option<CacheMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "0" | "never" => Some(CacheMode::Off),
            "auto" => Some(CacheMode::Auto),
            "on" | "1" | "force" | "always" => Some(CacheMode::Force),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Option<CacheMode> {
        match v {
            1 => Some(CacheMode::Off),
            2 => Some(CacheMode::Auto),
            3 => Some(CacheMode::Force),
            _ => None,
        }
    }
}

const MODE_UNSET: u8 = 0;
static FORCED_MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);
static RESOLVED_MODE: OnceLock<CacheMode> = OnceLock::new();

/// The cache mode in effect: the [`force_cache_mode`]d mode if set, else
/// `ZNNI_KERNEL_CACHE` (read once), else [`CacheMode::Auto`].
pub fn cache_mode() -> CacheMode {
    match CacheMode::from_u8(FORCED_MODE.load(Ordering::Relaxed)) {
        Some(m) => m,
        None => *RESOLVED_MODE.get_or_init(|| {
            match std::env::var("ZNNI_KERNEL_CACHE") {
                Ok(v) if !v.trim().is_empty() => match CacheMode::parse(&v) {
                    Some(m) => m,
                    None => {
                        eprintln!("znni: unknown ZNNI_KERNEL_CACHE value {v:?}, using auto");
                        CacheMode::Auto
                    }
                },
                _ => CacheMode::Auto,
            }
        }),
    }
}

/// Force the cache mode for every subsequent decision (tests and the
/// cached-vs-recompute benches), or restore env/default resolution with
/// `None`.
pub fn force_cache_mode(mode: Option<CacheMode>) {
    match mode {
        Some(m) => FORCED_MODE.store(m as u8, Ordering::Relaxed),
        None => FORCED_MODE.store(MODE_UNSET, Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::fft_optimal_vec3;
    use crate::util::pool::{ChipTopology, TaskPool};

    fn tpool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    #[test]
    fn cpu_cache_matches_direct_transform() {
        let pool = tpool();
        let w = Weights::random(3, 2, [3, 2, 3], 77);
        let padded = fft_optimal_vec3([8, 7, 9]);
        let cache = PrecomputedKernels::build(&w, SpectraLayout::Cpu, padded, &pool);
        assert!(cache.matches(SpectraLayout::Cpu, padded, 3, 2));
        assert!(!cache.matches(SpectraLayout::Cpu, [4, 4, 4], 3, 2));
        assert!(!cache.matches(SpectraLayout::Gpu, padded, 3, 2));
        let plan = crate::exec::fft3_plan(padded);
        let mut sc = Fft3Scratch::new();
        let mut expect = vec![Complex32::ZERO; plan.complex_len()];
        for j in 0..3 {
            for i in 0..2 {
                plan.forward(w.kernel(j, i), w.k, &mut expect, &mut sc);
                let got = cache.spectrum(j, i);
                assert_eq!(got.len(), expect.len());
                for (g, e) in got.iter().zip(&expect) {
                    assert_eq!((g.re, g.im), (e.re, e.im), "spectrum ({j},{i}) bit-identical");
                }
            }
        }
    }

    #[test]
    fn gpu_cache_matches_batched_transform() {
        let pool = tpool();
        let w = Weights::random(2, 3, [2, 2, 2], 78);
        let padded = fft_optimal_vec3([6, 6, 6]);
        let cache = PrecomputedKernels::build(&w, SpectraLayout::Gpu, padded, &pool);
        let plan_ker = crate::exec::batched_fft3_plan(w.k, padded);
        let spec = plan_ker.spectrum_len();
        let mut expect = vec![Complex32::ZERO; 3 * spec];
        let mut s1 = vec![Complex32::ZERO; plan_ker.forward_scratch1_len(3)];
        let mut s2 = vec![Complex32::ZERO; plan_ker.forward_scratch2_len(3)];
        let klen = w.klen();
        for j in 0..2 {
            let kbatch = &w.raw()[j * 3 * klen..(j + 1) * 3 * klen];
            plan_ker.forward_scratch(3, kbatch, &mut expect, &mut s1, &mut s2, &pool);
            let got = cache.batch(j);
            for (g, e) in got.iter().zip(&expect) {
                assert_eq!((g.re, g.im), (e.re, e.im), "batch {j} must be bit-identical");
            }
        }
    }

    #[test]
    fn cache_bytes_register_with_ledger_and_gauge() {
        let pool = tpool();
        let w = Weights::random(2, 2, [3, 3, 3], 79);
        let padded = [4, 4, 4];
        let cache = PrecomputedKernels::build(&w, SpectraLayout::Cpu, padded, &pool);
        // 2·2 spectra of 4·4·3 complex bins, 8 bytes each.
        assert_eq!(cache.bytes(), 2 * 2 * (4 * 4 * 3 * 8) as u64);
        // The gauge is global (other tests build and drop caches
        // concurrently), but it sums *live* caches — so while ours is
        // alive it is a lower bound.
        assert!(memory::kernel_cache_bytes() >= cache.bytes());
        drop(cache);
    }

    #[test]
    fn mode_parse() {
        // `force_cache_mode` is process-global, so flipping it here
        // would race concurrently running search tests; the force path
        // is exercised (serialized) in tests/integration_kernel_cache.rs.
        assert_eq!(CacheMode::parse("off"), Some(CacheMode::Off));
        assert_eq!(CacheMode::parse("0"), Some(CacheMode::Off));
        assert_eq!(CacheMode::parse(" AUTO "), Some(CacheMode::Auto));
        assert_eq!(CacheMode::parse("on"), Some(CacheMode::Force));
        assert_eq!(CacheMode::parse("1"), Some(CacheMode::Force));
        assert_eq!(CacheMode::parse("bogus"), None);
    }

    #[test]
    fn spectra_map_keys_per_shape_and_evicts_largest() {
        let pool = tpool();
        let w = Weights::random(3, 2, [3, 3, 3], 80);
        let small = fft_optimal_vec3([6, 6, 6]);
        let big = fft_optimal_vec3([12, 12, 12]);
        let mut map = SpectraMap::new();
        assert!(map.is_empty());
        assert_eq!(map.evict_largest(), 0, "evicting an empty map is a no-op");

        let a = Arc::new(PrecomputedKernels::build(&w, SpectraLayout::Cpu, small, &pool));
        let b = Arc::new(PrecomputedKernels::build(&w, SpectraLayout::Cpu, big, &pool));
        let (a_bytes, b_bytes) = (a.bytes(), b.bytes());
        assert!(b_bytes > a_bytes, "bigger padded shape must cost more");
        map.insert(a.clone());
        map.insert(b.clone());
        assert_eq!(map.len(), 2);
        assert_eq!(map.bytes(), a_bytes + b_bytes);

        // Lookups key on (layout, padded, geometry, precision).
        let f32p = Precision::F32;
        let hit = map.get(SpectraLayout::Cpu, small, 3, 2, f32p).expect("small shape cached");
        assert!(Arc::ptr_eq(&hit, &a));
        let hit = map.get(SpectraLayout::Cpu, big, 3, 2, f32p).expect("big shape cached");
        assert!(Arc::ptr_eq(&hit, &b));
        assert!(map.get(SpectraLayout::Cpu, [5, 5, 5], 3, 2, f32p).is_none());
        assert!(map.get(SpectraLayout::Gpu, small, 3, 2, f32p).is_none());
        assert!(map.get(SpectraLayout::Cpu, small, 2, 3, f32p).is_none());
        assert!(map.get(SpectraLayout::Cpu, small, 3, 2, Precision::F16).is_none());

        // Re-inserting an existing key replaces rather than doubles.
        map.insert(a.clone());
        assert_eq!(map.len(), 2);
        assert_eq!(map.bytes(), a_bytes + b_bytes);

        // Eviction is largest-first and the accounting follows.
        assert_eq!(map.evict_largest(), b_bytes);
        assert_eq!(map.bytes(), a_bytes);
        assert!(map.get(SpectraLayout::Cpu, big, 3, 2, f32p).is_none());
        assert!(map.get(SpectraLayout::Cpu, small, 3, 2, f32p).is_some());
        assert_eq!(map.clear(), a_bytes);
        assert!(map.is_empty());
    }

    #[test]
    fn half_cache_halves_bytes_and_widens_within_bounds() {
        let pool = tpool();
        let w = Weights::random(3, 2, [3, 2, 3], 81);
        let padded = fft_optimal_vec3([8, 7, 9]);
        let full = PrecomputedKernels::build(&w, SpectraLayout::Cpu, padded, &pool);
        for p in Precision::HALF {
            let half = PrecomputedKernels::build_p(&w, SpectraLayout::Cpu, padded, &pool, p);
            assert_eq!(half.precision(), p);
            assert_eq!(half.bytes() * 2, full.bytes(), "{} stores exactly half", p.name());
            assert!(memory::kernel_cache_bytes() >= half.bytes());
            // Widened spectra sit within the format's per-element
            // relative bound of the f32 spectra they were narrowed
            // from, and widening is deterministic bit for bit.
            let rel = match p {
                Precision::F16 => 2.0f32.powi(-11),
                Precision::Bf16 => 2.0f32.powi(-8),
                Precision::F32 => unreachable!(),
            };
            let mut got = vec![Complex32::ZERO; half.spec_len()];
            let mut again = vec![Complex32::ZERO; half.spec_len()];
            for j in 0..3 {
                for i in 0..2 {
                    half.widen_spectrum_into(j, i, &mut got);
                    half.widen_spectrum_into(j, i, &mut again);
                    let exact = full.spectrum(j, i);
                    for (k, (g, e)) in got.iter().zip(exact).enumerate() {
                        assert_eq!(g.re.to_bits(), again[k].re.to_bits());
                        assert_eq!(g.im.to_bits(), again[k].im.to_bits());
                        // f16 subnormal floor: below ~2^-14 the
                        // absolute step dominates the relative bound.
                        let floor = 2.0f32.powi(-14);
                        for (gv, ev) in [(g.re, e.re), (g.im, e.im)] {
                            let tol = ev.abs().max(floor) * rel;
                            assert!(
                                (gv - ev).abs() <= tol,
                                "{} spectrum ({j},{i})[{k}]: {gv} vs {ev}",
                                p.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn gpu_half_cache_widens_batches() {
        let pool = tpool();
        let w = Weights::random(2, 3, [2, 2, 2], 82);
        let padded = fft_optimal_vec3([6, 6, 6]);
        let full = PrecomputedKernels::build(&w, SpectraLayout::Gpu, padded, &pool);
        let half = PrecomputedKernels::build_p(&w, SpectraLayout::Gpu, padded, &pool, Precision::Bf16);
        assert_eq!(half.bytes() * 2, full.bytes());
        let mut got = vec![Complex32::ZERO; 3 * half.spec_len()];
        for j in 0..2 {
            half.widen_batch_into(j, &mut got);
            let exact = full.batch(j);
            for (g, e) in got.iter().zip(exact) {
                for (gv, ev) in [(g.re, e.re), (g.im, e.im)] {
                    // bf16 keeps full range; relative bound 2^-8 (plus
                    // the subnormal floor for values near zero).
                    let tol = ev.abs().max(f32::MIN_POSITIVE) * 2.0f32.powi(-8);
                    assert!((gv - ev).abs() <= tol, "batch {j}: {gv} vs {ev}");
                }
            }
        }
    }

    #[test]
    fn mixed_precision_map_accounts_exactly() {
        let pool = tpool();
        let w = Weights::random(3, 2, [3, 3, 3], 83);
        let padded = fft_optimal_vec3([6, 6, 6]);
        let mut map = SpectraMap::new();
        let full = Arc::new(PrecomputedKernels::build(&w, SpectraLayout::Cpu, padded, &pool));
        let half =
            Arc::new(PrecomputedKernels::build_p(&w, SpectraLayout::Cpu, padded, &pool, Precision::F16));
        let (fb, hb) = (full.bytes(), half.bytes());
        assert_eq!(hb * 2, fb);
        // Same shape, different precisions: both coexist (precision is
        // part of the key), and byte accounting stays exact.
        map.insert(full.clone());
        map.insert(half.clone());
        assert_eq!(map.len(), 2);
        assert_eq!(map.bytes(), fb + hb);
        let hit = map.get(SpectraLayout::Cpu, padded, 3, 2, Precision::F16).expect("f16 entry");
        assert!(Arc::ptr_eq(&hit, &half));
        // Shedding goes largest-first: the f32 entry before the f16 one.
        assert_eq!(map.evict_largest(), fb);
        assert_eq!(map.bytes(), hb);
        assert_eq!(map.evict_largest(), hb);
        assert_eq!(map.bytes(), 0);
    }

    #[test]
    fn layout_for_algo() {
        assert_eq!(SpectraLayout::for_algo(ConvAlgo::FftDataParallel), Some(SpectraLayout::Cpu));
        assert_eq!(SpectraLayout::for_algo(ConvAlgo::FftTaskParallel), Some(SpectraLayout::Cpu));
        assert_eq!(SpectraLayout::for_algo(ConvAlgo::GpuFft), Some(SpectraLayout::Gpu));
        assert_eq!(SpectraLayout::for_algo(ConvAlgo::DirectMkl), None);
        assert_eq!(SpectraLayout::for_algo(ConvAlgo::GpuDensePrecomp), None);
    }
}
